//! Offline stand-in for the `anyhow` crate.
//!
//! This tree builds with no network access, so the real crates.io
//! `anyhow` cannot be fetched; this vendored shim provides exactly the
//! surface the repo uses — `Result`, `Error`, `Context` on `Result` and
//! `Option`, the `anyhow!` / `bail!` / `ensure!` macros, and the
//! alternate (`{:#}`) rendering of the context chain. Error values are a
//! plain message chain: no backtraces, no downcasting.

use std::fmt;

/// `Result` defaulting to [`Error`], matching `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chain error: `chain[0]` is the outermost context, the last
/// entry the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, like anyhow
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Like anyhow, `Error` deliberately does NOT implement std::error::Error,
// which is what makes this blanket conversion coherent.
impl<E> From<E> for Error
where
    E: std::error::Error,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to failure values (`Result` and `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)+) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            $crate::bail!($($t)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn context_chain_renders_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");
        assert_eq!(e.root_cause(), "no such file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing ]").unwrap_err();
        assert_eq!(format!("{e}"), "missing ]");
    }

    #[test]
    fn macros_compose() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(inner(true).unwrap(), 7);
        let e = inner(false).unwrap_err();
        assert_eq!(format!("{e}"), "flag was false");
        let m = anyhow!("code {}", 42);
        assert_eq!(format!("{m}"), "code 42");
    }

    #[test]
    fn error_msg_from_string() {
        let e = Error::msg(String::from("worker 3 is gone"));
        assert_eq!(format!("{e:#}"), "worker 3 is gone");
    }
}
