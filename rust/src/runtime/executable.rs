//! Compile-once / execute-many wrapper over the `xla` crate's PJRT client.
//!
//! Interchange is HLO *text* (see aot.py): `HloModuleProto::from_text_file`
//! reparses and reassigns instruction ids, sidestepping the 64-bit-id
//! protos jax >= 0.5 emits that xla_extension 0.5.1 rejects.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

use super::manifest::{Manifest, ManifestEntry};

/// One compiled entry point.
pub struct LoadedExecutable {
    pub entry: ManifestEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedExecutable {
    /// Execute with f32 inputs (the common case for attention tensors).
    /// Input slices must match the manifest specs; returns the flattened
    /// f32 output.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let literals = self.to_literals_f32(inputs)?;
        self.run_literals(&literals)
    }

    /// Execute with one s32 input (classifier tokens) -> f32 output.
    pub fn run_s32(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        if self.entry.inputs.len() != 1 {
            bail!(
                "{}: expected 1 input, manifest has {}",
                self.entry.name,
                self.entry.inputs.len()
            );
        }
        let spec = &self.entry.inputs[0];
        if spec.dtype != "s32" || tokens.len() != spec.elements() {
            bail!(
                "{}: input must be s32[{}], got {} elements",
                self.entry.name,
                spec.elements(),
                tokens.len()
            );
        }
        let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(tokens).reshape(&dims)?;
        self.run_literals(&[lit])
    }

    fn to_literals_f32(&self, inputs: &[&[f32]]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.entry.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.entry.name,
                self.entry.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, spec) in inputs.iter().zip(&self.entry.inputs) {
            if spec.dtype != "f32" {
                bail!("{}: input is {}, use the typed runner", self.entry.name, spec.dtype);
            }
            if data.len() != spec.elements() {
                bail!(
                    "{}: input needs {} elements ({:?}), got {}",
                    self.entry.name,
                    spec.elements(),
                    spec.dims,
                    data.len()
                );
            }
            let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        Ok(literals)
    }

    fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = self.exe.execute::<xla::Literal>(literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// The runtime engine: a PJRT CPU client plus compiled entry points.
pub struct Engine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    loaded: HashMap<String, LoadedExecutable>,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            loaded: HashMap::new(),
        })
    }

    /// Compile (or fetch the cached) entry point by manifest name.
    pub fn load(&mut self, name: &str) -> Result<&LoadedExecutable> {
        if !self.loaded.contains_key(name) {
            let entry = self.manifest.get(name)?.clone();
            let path = entry.file.to_str().context("non-utf8 path")?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.loaded.insert(
                name.to_string(),
                LoadedExecutable { entry, exe },
            );
        }
        Ok(&self.loaded[name])
    }

    /// Names of all available entry points.
    pub fn available(&self) -> Vec<&str> {
        self.manifest.entries.iter().map(|e| e.name.as_str()).collect()
    }
}

/// Locate the artifacts directory: $CAMFORMER_ARTIFACTS or ./artifacts
/// relative to the crate root.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("CAMFORMER_ARTIFACTS") {
        return d.into();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
