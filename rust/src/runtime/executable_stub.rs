//! Offline stand-in for `executable.rs`, compiled when the `pjrt` feature
//! is off (the default: the `xla` bindings and their native library are
//! not vendored). Presents the identical public surface so the
//! coordinator's `PjrtBackend`, the CLI and the examples type-check
//! unchanged; every constructor/execution path returns a descriptive
//! error instead of running.

use anyhow::{bail, Result};
use std::path::Path;

use super::manifest::ManifestEntry;

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: this build has no `xla` bindings (rebuild with \
     `--features pjrt` after adding the xla dependency)";

/// One compiled entry point (never constructed in stub builds).
pub struct LoadedExecutable {
    pub entry: ManifestEntry,
}

impl LoadedExecutable {
    /// Execute with f32 inputs (stub: always errors).
    pub fn run_f32(&self, _inputs: &[&[f32]]) -> Result<Vec<f32>> {
        bail!("{}: {UNAVAILABLE}", self.entry.name)
    }

    /// Execute with one s32 input (stub: always errors).
    pub fn run_s32(&self, _tokens: &[i32]) -> Result<Vec<f32>> {
        bail!("{}: {UNAVAILABLE}", self.entry.name)
    }
}

/// The runtime engine (stub: construction always errors, so `Engine`
/// values never exist in offline builds).
pub struct Engine {
    never: std::convert::Infallible,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory (stub: errors).
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        bail!("{UNAVAILABLE} (artifacts dir {artifacts_dir:?})")
    }

    /// Compile (or fetch the cached) entry point by manifest name.
    pub fn load(&mut self, _name: &str) -> Result<&LoadedExecutable> {
        match self.never {}
    }

    /// Names of all available entry points.
    pub fn available(&self) -> Vec<&str> {
        match self.never {}
    }
}

/// Locate the artifacts directory: $CAMFORMER_ARTIFACTS or ./artifacts
/// relative to the crate root. (Duplicated from `executable.rs` so both
/// cfg variants expose it.)
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("CAMFORMER_ARTIFACTS") {
        return d.into();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_reports_unavailable() {
        let err = Engine::new(Path::new("/nonexistent")).err().expect("stub must error");
        let msg = format!("{err:#}");
        assert!(msg.contains("PJRT runtime unavailable"), "{msg}");
    }

    #[test]
    fn artifacts_dir_override() {
        // default resolves under the crate root when the env var is unset
        if std::env::var("CAMFORMER_ARTIFACTS").is_err() {
            assert!(default_artifacts_dir().ends_with("artifacts"));
        }
    }
}
