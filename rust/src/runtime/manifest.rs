//! `artifacts/manifest.tsv` parsing: the AOT step records each entry
//! point's file, input specs and output spec; the runtime uses it to load
//! and validate executables without hard-coding shapes.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// A tensor spec like `f32[1024,64]` or `s32[512]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn parse(s: &str) -> Result<Self> {
        let (dtype, rest) = s
            .split_once('[')
            .with_context(|| format!("bad tensor spec {s:?}"))?;
        let dims_str = rest.strip_suffix(']').context("missing ]")?;
        let dims = if dims_str.is_empty() {
            vec![]
        } else {
            dims_str
                .split(',')
                .map(|d| d.trim().parse::<usize>().context("bad dim"))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSpec {
            dtype: dtype.to_string(),
            dims,
        })
    }

    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One manifest row.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if i == 0 || line.trim().is_empty() {
                continue; // header
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 4 {
                bail!("manifest line {i}: expected 4 columns, got {}", cols.len());
            }
            let inputs = cols[2]
                .split(';')
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            entries.push(ManifestEntry {
                name: cols[0].to_string(),
                file: dir.join(cols[1]),
                inputs,
                output: TensorSpec::parse(cols[3])?,
            });
        }
        Ok(Manifest {
            entries,
            dir: dir.to_path_buf(),
        })
    }

    pub fn get(&self, name: &str) -> Result<&ManifestEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .with_context(|| {
                format!(
                    "entry point {name:?} not in manifest (have: {:?})",
                    self.entries.iter().map(|e| &e.name).collect::<Vec<_>>()
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tensor_spec() {
        let t = TensorSpec::parse("f32[1024,64]").unwrap();
        assert_eq!(t.dtype, "f32");
        assert_eq!(t.dims, vec![1024, 64]);
        assert_eq!(t.elements(), 65536);
        let s = TensorSpec::parse("s32[512]").unwrap();
        assert_eq!(s.dtype, "s32");
        assert_eq!(s.dims, vec![512]);
    }

    #[test]
    fn parse_scalar_spec() {
        let t = TensorSpec::parse("f32[]").unwrap();
        assert!(t.dims.is_empty());
        assert_eq!(t.elements(), 1);
    }

    #[test]
    fn reject_garbage() {
        assert!(TensorSpec::parse("f32").is_err());
        assert!(TensorSpec::parse("f32[a,b]").is_err());
    }

    #[test]
    fn parse_manifest_text() {
        let text = "name\tfile\tinputs\toutput\n\
                    attn\tattn.hlo.txt\tf32[64];f32[1024,64]\tf32[64]\n";
        let m = Manifest::parse(text, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.get("attn").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.file, Path::new("/tmp/a/attn.hlo.txt"));
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // integration guard: if artifacts exist, they must parse
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.tsv").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.get("attn_single_query").is_ok());
            assert!(m.get("classifier_camformer").is_ok());
        }
    }
}
