//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! Rust hot path. Python never runs here — `make artifacts` produced the
//! HLO once; this module compiles it on the PJRT CPU client and serves
//! executions.

pub mod executable;
pub mod manifest;

pub use executable::{Engine, LoadedExecutable};
pub use manifest::{Manifest, ManifestEntry};
