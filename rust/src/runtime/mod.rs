//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! Rust hot path. Python never runs here — `make artifacts` produced the
//! HLO once; this module compiles it on the PJRT CPU client and serves
//! executions.
//!
//! The real client lives in `executable.rs` and needs the `xla` bindings
//! plus the native xla_extension library, so it is gated behind the
//! `pjrt` cargo feature. Default (offline) builds get
//! `executable_stub.rs`: the same API surface, with every entry point
//! reporting that the PJRT runtime is unavailable. Callers already treat
//! missing artifacts as "skip" (see `rust/tests/runtime_integration.rs`),
//! so the stub keeps the whole tree buildable and testable with no
//! network access.

#[cfg(feature = "pjrt")]
pub mod executable;
#[cfg(not(feature = "pjrt"))]
#[path = "executable_stub.rs"]
pub mod executable;

pub mod manifest;

pub use executable::{Engine, LoadedExecutable};
pub use manifest::{Manifest, ManifestEntry};
