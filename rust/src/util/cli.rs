//! Tiny CLI argument parser (clap is not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::HashMap;

/// Parsed command line: positionals plus key/value options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (program name excluded).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["serve", "--port", "8080", "--verbose", "--k=32"]);
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("k"), Some("32"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--n", "1024", "--sigma", "0.014"]);
        assert_eq!(a.get_usize("n", 0), 1024);
        assert!((a.get_f64("sigma", 0.0) - 0.014).abs() < 1e-12);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["cmd", "--fast"]);
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["cmd"]);
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_int_panics() {
        parse(&["--n", "xyz"]).get_usize("n", 0);
    }
}
