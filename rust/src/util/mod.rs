//! Std-only utilities: this environment vendors only the `xla` crate's
//! dependency closure, so the PRNG, bf16 arithmetic, table/figure printers,
//! CLI parsing, property-testing and bench harnesses live in-tree.

pub mod bench;
pub mod bf16;
pub mod check;
pub mod cli;
pub mod rng;
pub mod stats;
pub mod table;
