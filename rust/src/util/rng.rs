//! Deterministic PRNG for circuit noise models and test-input generation.
//!
//! SplitMix64 seeds a xoshiro256++ core — the standard construction; both
//! are public-domain algorithms (Blackman & Vigna). Gaussian variates use
//! Box–Muller with a cached spare.

/// xoshiro256++ PRNG with SplitMix64 seeding and Box–Muller gaussians.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    spare_gauss: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (fully deterministic).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_gauss: None,
        }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        self.range(0, n as u64) as usize
    }

    /// Fair coin.
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box–Muller (spare cached).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.spare_gauss.take() {
            return g;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_gauss = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.gauss()
    }

    /// Vector of standard normals (f32).
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gauss() as f32).collect()
    }

    /// Random ±1 binary vector (the CAM's storage domain).
    pub fn pm_one_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| if self.bool() { 1.0 } else { -1.0 }).collect()
    }

    /// Split off an independent child generator (for parallel workers).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let mean: f64 = (0..100_000).map(|_| r.uniform()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..200_000).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(17);
        for _ in 0..10_000 {
            let v = r.range(5, 12);
            assert!((5..12).contains(&v));
        }
    }

    #[test]
    fn pm_one_is_binary_and_balanced() {
        let mut r = Rng::new(19);
        let v = r.pm_one_vec(100_000);
        assert!(v.iter().all(|&x| x == 1.0 || x == -1.0));
        let ones = v.iter().filter(|&&x| x == 1.0).count();
        assert!((ones as f64 / 1e5 - 0.5).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
