//! Criterion-lite bench harness (criterion is not vendored).
//!
//! Measures wall time over warmup + timed iterations, reports mean / p50 /
//! p95 and derived throughput. Every `benches/*.rs` target builds on this.

use std::hint::black_box;
use std::time::Instant;

use super::stats;

/// One benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub std_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn throughput_per_s(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Bench runner with fixed warmup/measure budgets.
pub struct Bencher {
    pub warmup_iters: u64,
    pub min_iters: u64,
    pub max_seconds: f64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 20,
            min_iters: 50,
            max_seconds: 2.0,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick profile for slow end-to-end benches.
    pub fn coarse() -> Self {
        Bencher {
            warmup_iters: 3,
            min_iters: 10,
            max_seconds: 5.0,
            results: Vec::new(),
        }
    }

    /// Time `f` and record under `name`. Return value is black-boxed.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while iters < self.min_iters || start.elapsed().as_secs_f64() < self.max_seconds {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            iters += 1;
            if iters >= self.min_iters && start.elapsed().as_secs_f64() >= self.max_seconds {
                break;
            }
            if iters >= 1_000_000 {
                break;
            }
        }
        let res = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: stats::mean(&samples_ns),
            p50_ns: stats::percentile(&samples_ns, 50.0),
            p95_ns: stats::percentile(&samples_ns, 95.0),
            std_ns: stats::std_dev(&samples_ns),
        };
        println!(
            "bench {:40} {:>12.2} us/iter  p50 {:>10.2}  p95 {:>10.2}  ({} iters)",
            res.name,
            res.mean_ns / 1e3,
            res.p50_ns / 1e3,
            res.p95_ns / 1e3,
            res.iters
        );
        self.results.push(res.clone());
        res
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Summary block for bench_output.txt.
    pub fn summary(&self) -> String {
        let mut s = String::from("\n-- summary --\n");
        for r in &self.results {
            s.push_str(&format!(
                "{}\t{:.3} us\t{:.1}/s\n",
                r.name,
                r.mean_us(),
                r.throughput_per_s()
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            warmup_iters: 1,
            min_iters: 5,
            max_seconds: 0.05,
            results: Vec::new(),
        };
        let r = b.bench("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..100 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert_eq!(b.results().len(), 1);
    }
}
