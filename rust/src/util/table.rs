//! ASCII table / series printers for regenerating the paper's tables and
//! figures on stdout (every `camformer <table|fig>` subcommand uses these).

/// A simple right-padded ASCII table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Render to a string (also what tests assert on).
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for (c, cell) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", cell, w = widths[c]));
            }
            s.trim_end().to_string() + "\n"
        };
        out.push_str(&line(&self.headers, &widths));
        out.push_str(&format!(
            "{}\n",
            "-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1))
        ));
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Print an (x, y...) series as TSV — the "figure" output format; pipe to a
/// plotting tool of choice to regenerate the paper's plots.
pub struct Series {
    title: String,
    cols: Vec<String>,
    points: Vec<Vec<f64>>,
}

impl Series {
    pub fn new(title: &str, cols: &[&str]) -> Self {
        Series {
            title: title.to_string(),
            cols: cols.iter().map(|s| s.to_string()).collect(),
            points: Vec::new(),
        }
    }

    pub fn point(&mut self, vals: &[f64]) -> &mut Self {
        assert_eq!(vals.len(), self.cols.len());
        self.points.push(vals.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let mut out = format!("## {}\n", self.title);
        out.push_str(&self.cols.join("\t"));
        out.push('\n');
        for p in &self.points {
            let cells: Vec<String> = p.iter().map(|v| format_sig(*v, 6)).collect();
            out.push_str(&cells.join("\t"));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format with up to `sig` significant digits, trimming trailing zeros.
pub fn format_sig(v: f64, sig: usize) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    if !v.is_finite() {
        return format!("{v}");
    }
    let mag = v.abs().log10().floor() as i32;
    let decimals = (sig as i32 - 1 - mag).max(0) as usize;
    let s = format!("{:.*}", decimals, v);
    if s.contains('.') {
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    } else {
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row_strs(&["x", "y"]).row_strs(&["long", "z"]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("a     bb"));
        assert!(r.contains("long  z"));
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        Table::new("T", &["a"]).row_strs(&["x", "y"]);
    }

    #[test]
    fn series_tsv() {
        let mut s = Series::new("S", &["x", "y"]);
        s.point(&[1.0, 2.5]);
        let r = s.render();
        assert!(r.contains("x\ty"));
        assert!(r.contains("1\t2.5"));
    }

    #[test]
    fn format_sig_trims() {
        assert_eq!(format_sig(1.0, 6), "1");
        assert_eq!(format_sig(0.25, 6), "0.25");
        assert_eq!(format_sig(1234.5678, 6), "1234.57");
        assert_eq!(format_sig(0.000123456, 3), "0.000123");
    }
}
