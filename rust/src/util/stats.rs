//! Small statistics helpers shared by the circuit model, the accuracy
//! harness and the bench harness.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation on the sorted copy (p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Max absolute value.
pub fn max_abs(xs: &[f64]) -> f64 {
    xs.iter().fold(0.0f64, |m, x| m.max(x.abs()))
}

/// Pearson correlation coefficient.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (ma, mb) = (mean(a), mean(b));
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for i in 0..a.len() {
        let (x, y) = (a[i] - ma, b[i] - mb);
        num += x * y;
        da += x * x;
        db += y * y;
    }
    if da == 0.0 || db == 0.0 {
        0.0
    } else {
        num / (da * db).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn std_dev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 3.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
    }

    #[test]
    fn pearson_perfect() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }
}
