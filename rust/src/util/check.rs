//! Property-testing-lite: no proptest crate is vendored, so this provides
//! the same discipline — run a property over many seeded random inputs,
//! report the failing seed — with deterministic reproducibility.
//!
//! Usage:
//! ```
//! use camformer::util::check::check;
//! check("sum is commutative", 500, |rng| {
//!     let (a, b) = (rng.uniform(), rng.uniform());
//!     assert!((a + b - (b + a)).abs() < 1e-12);
//! });
//! ```

use super::rng::Rng;

/// Run `prop` over `cases` independently-seeded RNGs; panic with the seed
/// on the first failure so the case replays with `replay(name, seed, prop)`.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, cases: u64, prop: F) {
    for case in 0..cases {
        let seed = fixed_seed(name, case);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay with: check::replay(\"{name}\", {case}, prop)"
            );
        }
    }
}

/// Replay a single failing case of `check`.
pub fn replay<F: Fn(&mut Rng)>(name: &str, case: u64, prop: F) {
    let mut rng = Rng::new(fixed_seed(name, case));
    prop(&mut rng);
}

/// Stable per-(name, case) seed: FNV-1a over the name, mixed with the case.
fn fixed_seed(name: &str, case: u64) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ case.wrapping_mul(0x9E3779B97F4A7C15)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 100, |rng| {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports_seed() {
        check("always-fails", 10, |_rng| {
            panic!("boom");
        });
    }

    #[test]
    fn seeds_are_stable() {
        assert_eq!(fixed_seed("a", 0), fixed_seed("a", 0));
        assert_ne!(fixed_seed("a", 0), fixed_seed("a", 1));
        assert_ne!(fixed_seed("a", 0), fixed_seed("b", 0));
    }
}
