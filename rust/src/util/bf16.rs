//! Minimal bfloat16 arithmetic model.
//!
//! The contextualization stage computes in BF16 (Sec. III-B3), and the
//! normalization stage uses one BF16 accumulator + one BF16 divider
//! (Sec. III-B2). We model BF16 as round-to-nearest-even truncation of f32
//! — exactly what the hardware MAC's rounding stage does — so the Rust
//! functional model reproduces the jnp `astype(bfloat16)` results bit-for-
//! bit.

/// Round an f32 to the nearest bf16-representable value (ties to even).
pub fn round(x: f32) -> f32 {
    let bits = x.to_bits();
    if x.is_nan() {
        return f32::NAN;
    }
    // round-to-nearest-even on the low 16 bits
    let round_bit = 0x0000_8000u32;
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x0000_7FFF + lsb) & 0xFFFF_0000;
    let _ = round_bit;
    f32::from_bits(rounded)
}

/// BF16 multiply: round(a) * round(b), result rounded.
pub fn mul(a: f32, b: f32) -> f32 {
    round(round(a) * round(b))
}

/// BF16 add.
pub fn add(a: f32, b: f32) -> f32 {
    round(round(a) + round(b))
}

/// BF16 divide (the normalization stage's pipelined divider).
pub fn div(a: f32, b: f32) -> f32 {
    round(round(a) / round(b))
}

/// BF16 fused dot product as the MAC array computes it: elementwise BF16
/// multiply, BF16 accumulate in order.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc = add(acc, mul(x, y));
    }
    acc
}

/// Number of bits of mantissa kept (for docs/tests).
pub const MANTISSA_BITS: u32 = 7;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers() {
        for i in -256..=256 {
            let x = i as f32;
            assert_eq!(round(x), x, "{x}");
        }
    }

    #[test]
    fn truncates_mantissa() {
        // 1 + 2^-8 is not representable in bf16 (7 mantissa bits)
        let x = 1.0 + 2f32.powi(-8);
        assert_eq!(round(x), 1.0);
    }

    #[test]
    fn ties_to_even() {
        // halfway between 1.0 and 1.0078125 rounds to even (1.0)
        let x = 1.0 + 2f32.powi(-8);
        assert_eq!(round(x), 1.0);
        // just above halfway rounds up
        let y = 1.0 + 2f32.powi(-8) + 2f32.powi(-16);
        assert_eq!(round(y), 1.0 + 2f32.powi(-7));
    }

    #[test]
    fn idempotent() {
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..1000 {
            let x = rng.normal(0.0, 10.0) as f32;
            let r = round(x);
            assert_eq!(round(r), r);
        }
    }

    #[test]
    fn nan_propagates() {
        assert!(round(f32::NAN).is_nan());
    }

    #[test]
    fn infinity_preserved() {
        assert_eq!(round(f32::INFINITY), f32::INFINITY);
        assert_eq!(round(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }

    #[test]
    fn relative_error_bounded() {
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..10_000 {
            let x = (rng.normal(0.0, 100.0) as f32).abs() + 1e-3;
            let rel = ((round(x) - x) / x).abs();
            assert!(rel <= 2f32.powi(-8), "x={x} rel={rel}");
        }
    }

    #[test]
    fn dot_matches_scalar_chain() {
        let a = [1.5f32, -2.25, 0.125, 3.0];
        let b = [0.5f32, 1.0, -4.0, 0.25];
        let mut acc = 0.0;
        for i in 0..4 {
            acc = add(acc, mul(a[i], b[i]));
        }
        assert_eq!(dot(&a, &b), acc);
    }
}
