//! Synthesis-derived cost library (Sec. IV-A).
//!
//! The paper synthesises digital blocks with Design Compiler (TSMC 65 nm),
//! characterises the CAM in HSPICE, takes ADC / BF16-MAC / BF16-divider
//! costs from [39]-[41], scales to 45 nm via Stillmaker [42], and uses
//! 2.33 nJ/bit DRAM energy [43]. We carry the same published constants and
//! scaling equations so Tables I/II and Figs. 8/10 are regenerable
//! arithmetic, not refits.

pub mod blocks;
pub mod breakdown;
pub mod scaling;
pub mod system;

pub use blocks::BlockCost;
pub use breakdown::{area_breakdown, energy_breakdown, Component};
pub use scaling::{scale_area, scale_energy, Node};
pub use system::{CamformerCost, SystemConfig};
