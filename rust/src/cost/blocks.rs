//! Per-block cost constants on the 45 nm comparison plane of Table II.
//!
//! Sources, as in the paper: 6-bit SAR from Chen [39]; BF16 MAC from
//! Tiwari [40]; BF16 divider from Nagakalyan [41]; SRAM macros and sorter
//! comparators from Design-Compiler-class 65 nm synthesis scaled with
//! Stillmaker [42]; the CAM array from the HSPICE-calibrated circuit model
//! in `camcircuit`. Where the paper reports only aggregate fractions
//! (Fig. 8), per-op constants are back-solved from those fractions and the
//! Table II totals — each such constant is marked "back-solved" below and
//! the derivation asserted in tests.

/// Cost of one hardware block.
#[derive(Clone, Copy, Debug)]
pub struct BlockCost {
    /// Silicon area [mm^2] at 45 nm.
    pub area_mm2: f64,
    /// Dynamic energy per operation \[J\] (op defined per block below).
    pub energy_per_op: f64,
    /// Leakage + clock power \[W\] when instantiated.
    pub static_w: f64,
}

/// BA-CAM 16x64 array including drivers and precharge network.
/// op = one tile operation (program + search pair amortised).
pub fn ba_cam_array() -> BlockCost {
    BlockCost {
        area_mm2: 0.004, // 1024 x 10T1C cells + share switches
        // circuit model: ~105 pJ/tile-op at 65 nm -> 65 pJ at 45 nm;
        // x3.2 for query-broadcast drivers & control (back-solved to the
        // paper's 12% CAM share of Fig. 8)
        energy_per_op: 208e-12,
        static_w: 0.002,
    }
}

/// Shared 6-bit SAR ADC [39]. op = one conversion.
pub fn sar_adc() -> BlockCost {
    BlockCost {
        area_mm2: 0.005,
        energy_per_op: 1.36e-12 * 0.619, // [39] at 40nm≈65nm-class -> 45nm
        static_w: 0.001,
    }
}

/// Key SRAM (8 KB binary K). op = one byte read.
pub fn key_sram() -> BlockCost {
    BlockCost {
        area_mm2: 0.046,
        energy_per_op: 2.7e-12, // back-solved: 20% energy share
        static_w: 0.004,
    }
}

/// Value SRAM (top-k V-buffer + staging). op = one byte accessed
/// (prefetch write + MAC read each count).
pub fn value_sram() -> BlockCost {
    BlockCost {
        area_mm2: 0.052,
        energy_per_op: 4.2e-12, // back-solved: 31% energy share
        static_w: 0.005,
    }
}

/// Query buffer (64 b) + misc registers. op = one query load.
pub fn query_buffer() -> BlockCost {
    BlockCost {
        area_mm2: 0.011,
        energy_per_op: 0.5e-12,
        static_w: 0.001,
    }
}

/// Bitonic Top-2 filter over one 16-score tile. op = one tile filtered.
pub fn top2_sorter() -> BlockCost {
    BlockCost {
        // 16-input bitonic partial sort: 33 comparator stages' worth
        area_mm2: 0.012,
        energy_per_op: 18e-12,
        static_w: 0.002,
    }
}

/// 64-input bitonic Top-32 block (Sec. III-B2). op = one 64-input pass.
pub fn top32_sorter() -> BlockCost {
    BlockCost {
        // the paper's area hog: 26% of Fig. 8 area
        area_mm2: 0.068,
        energy_per_op: 190e-12,
        static_w: 0.008,
    }
}

/// SoftMax engine: 512 B LUT + BF16 accumulator + pipelined BF16 divider
/// [41]. op = one 32-score normalisation.
pub fn softmax_engine() -> BlockCost {
    BlockCost {
        area_mm2: 0.014,
        energy_per_op: 120e-12,
        static_w: 0.002,
    }
}

/// One BF16 MAC unit [40]. op = one MAC.
pub fn bf16_mac() -> BlockCost {
    BlockCost {
        area_mm2: 0.003,
        energy_per_op: 14e-12, // back-solved: 26% energy share over 2048 MACs
        static_w: 0.0008,
    }
}

/// DMA engine + local memory controller. op = one V-row transfer handled.
pub fn dma_mc() -> BlockCost {
    BlockCost {
        area_mm2: 0.022,
        energy_per_op: 25e-12,
        static_w: 0.004,
    }
}

/// Pipeline/control/clock overhead (per core).
pub fn control() -> BlockCost {
    BlockCost {
        area_mm2: 0.012,
        energy_per_op: 0.0,
        static_w: 0.006,
    }
}

/// Energy to contextualize one survivor V row of width `d_v` \[J\]: the
/// weighted-sum stage walks `d_v` BF16 MACs, touches `2 * d_v` V-SRAM
/// bytes (prefetch write + MAC read), and occupies the DMA/MC for one
/// V-row transfer. This is the per-`v_rows_touched` unit the serving
/// energy accountant charges (ISSUE 10); the paper-shape constant
/// (d_v = 64) lands at ~1.46 nJ/row.
pub fn context_row_energy_j(d_v: usize) -> f64 {
    d_v as f64 * bf16_mac().energy_per_op
        + (2 * d_v) as f64 * value_sram().energy_per_op
        + dma_mc().energy_per_op
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_blocks_positive() {
        for b in [
            ba_cam_array(),
            sar_adc(),
            key_sram(),
            value_sram(),
            query_buffer(),
            top2_sorter(),
            top32_sorter(),
            softmax_engine(),
            bf16_mac(),
            dma_mc(),
            control(),
        ] {
            assert!(b.area_mm2 >= 0.0 && b.energy_per_op >= 0.0 && b.static_w >= 0.0);
        }
    }

    #[test]
    fn context_row_energy_matches_components() {
        // d_v = 64: 64 MACs + 128 SRAM bytes + one DMA V-row op
        let want = 64.0 * 14e-12 + 128.0 * 4.2e-12 + 25e-12;
        assert!((context_row_energy_j(64) - want).abs() < 1e-18);
        // ~1.46 nJ/row at the paper shape
        assert!((context_row_energy_j(64) - 1.4586e-9).abs() < 1e-12);
        // linear-ish in d_v: doubling the width roughly doubles the cost
        assert!(context_row_energy_j(128) > 1.9 * context_row_energy_j(64) - 25e-12);
    }

    #[test]
    fn top32_is_area_hog_among_logic() {
        // Fig. 8: the Top-32 module is the single largest non-SRAM block
        let t32 = top32_sorter().area_mm2;
        for b in [ba_cam_array(), sar_adc(), top2_sorter(), softmax_engine(), bf16_mac(), dma_mc()]
        {
            assert!(t32 > b.area_mm2);
        }
    }

    #[test]
    fn sram_macros_dominate_area() {
        let sram = key_sram().area_mm2 + value_sram().area_mm2 + query_buffer().area_mm2;
        let logic = ba_cam_array().area_mm2
            + sar_adc().area_mm2
            + top2_sorter().area_mm2
            + softmax_engine().area_mm2
            + 8.0 * bf16_mac().area_mm2
            + dma_mc().area_mm2
            + control().area_mm2;
        // Fig. 8: SRAM ≈ 42% => bigger than any other group except within
        // ~composition noise of Top-32
        assert!(sram > logic * 0.7, "sram {sram} vs logic {logic}");
    }
}
