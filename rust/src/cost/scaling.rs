//! Stillmaker & Baas node-scaling equations [42]: predict CMOS area /
//! energy across nodes from 180 nm to 7 nm. We use the standard
//! feature-size-squared area rule and the published energy-per-op scaling
//! factors, which is how the paper moves 65 nm synthesis numbers to the
//! 45 nm comparison plane of Table II and projects 45 -> 22 nm in Fig. 10.

/// Process node \[nm\].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Node {
    N65,
    N45,
    N28,
    N22,
    N16,
    N7,
}

impl Node {
    pub fn nm(&self) -> f64 {
        match self {
            Node::N65 => 65.0,
            Node::N45 => 45.0,
            Node::N28 => 28.0,
            Node::N22 => 22.0,
            Node::N16 => 16.0,
            Node::N7 => 7.0,
        }
    }

    /// Stillmaker energy-per-op factor normalised to 65 nm = 1.0.
    /// (Table 7 of [42], general-purpose scaling of dynamic energy.)
    pub fn energy_factor(&self) -> f64 {
        match self {
            Node::N65 => 1.000,
            Node::N45 => 0.619, // 65->45: ~1.6x lower energy/op
            Node::N28 => 0.368,
            Node::N22 => 0.281,
            Node::N16 => 0.193,
            Node::N7 => 0.080,
        }
    }

    /// Delay factor normalised to 65 nm = 1.0 (higher node = slower).
    pub fn delay_factor(&self) -> f64 {
        match self {
            Node::N65 => 1.000,
            Node::N45 => 0.758,
            Node::N28 => 0.536,
            Node::N22 => 0.456,
            Node::N16 => 0.366,
            Node::N7 => 0.205,
        }
    }
}

/// Scale silicon area [mm^2] from one node to another (λ² rule).
pub fn scale_area(area_mm2: f64, from: Node, to: Node) -> f64 {
    area_mm2 * (to.nm() / from.nm()).powi(2)
}

/// Scale dynamic energy \[J\] between nodes via the Stillmaker factors.
pub fn scale_energy(energy_j: f64, from: Node, to: Node) -> f64 {
    energy_j * to.energy_factor() / from.energy_factor()
}

/// Scale achievable frequency between nodes (inverse delay).
pub fn scale_freq(freq_ghz: f64, from: Node, to: Node) -> f64 {
    freq_ghz * from.delay_factor() / to.delay_factor()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn area_scales_quadratically() {
        let a65 = 1.0;
        let a45 = scale_area(a65, Node::N65, Node::N45);
        assert!((a45 - (45.0f64 / 65.0).powi(2)).abs() < 1e-12);
        assert!((a45 - 0.479).abs() < 0.01);
    }

    #[test]
    fn roundtrip_identity() {
        let a = scale_area(scale_area(2.5, Node::N65, Node::N22), Node::N22, Node::N65);
        assert!((a - 2.5).abs() < 1e-12);
        let e = scale_energy(scale_energy(1e-12, Node::N65, Node::N7), Node::N7, Node::N65);
        assert!((e - 1e-12).abs() < 1e-24);
    }

    #[test]
    fn energy_monotone_with_node() {
        let nodes = [Node::N65, Node::N45, Node::N28, Node::N22, Node::N16, Node::N7];
        for w in nodes.windows(2) {
            assert!(
                scale_energy(1.0, Node::N65, w[1]) < scale_energy(1.0, Node::N65, w[0]),
                "{:?} -> {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn fig10_projection_45_to_22() {
        // the Fig. 10 "projected" point: 45 -> 22 nm gives ~4.2x area and
        // ~2.2x energy advantage combined
        let area_gain = 1.0 / scale_area(1.0, Node::N45, Node::N22);
        let energy_gain = 1.0 / scale_energy(1.0, Node::N45, Node::N22);
        assert!(area_gain > 4.0 && area_gain < 4.4, "{area_gain}");
        assert!(energy_gain > 2.0 && energy_gain < 2.4, "{energy_gain}");
    }

    #[test]
    fn freq_improves_at_smaller_nodes() {
        assert!(scale_freq(1.0, Node::N65, Node::N22) > 2.0);
    }
}
