//! Fig. 8: energy and area breakdown by component.
//!
//! Paper reads: energy dominated by the contextualization stage (57%);
//! component-wise Value/Key SRAM 31%/20%, MACs 26%, BA-CAM 12%. Area:
//! SRAM 42%, Top-32 module 26%, remainder across processing units.

use super::blocks;
use super::system::{OpCounts, SystemConfig};

/// A named component share.
#[derive(Clone, Debug)]
pub struct Component {
    pub name: &'static str,
    pub value: f64,
    pub pct: f64,
}

fn to_components(raw: Vec<(&'static str, f64)>) -> Vec<Component> {
    let total: f64 = raw.iter().map(|(_, v)| v).sum();
    raw.into_iter()
        .map(|(name, value)| Component {
            name,
            value,
            pct: 100.0 * value / total,
        })
        .collect()
}

/// Per-query energy by component \[J\] (Fig. 8 left).
pub fn energy_breakdown(cfg: &SystemConfig) -> Vec<Component> {
    let ops = OpCounts::for_query(cfg);
    to_components(vec![
        (
            "BA-CAM + ADC",
            ops.cam_tile_ops as f64 * blocks::ba_cam_array().energy_per_op
                + ops.adc_conversions as f64 * blocks::sar_adc().energy_per_op,
        ),
        (
            "Key SRAM",
            ops.key_sram_bytes as f64 * blocks::key_sram().energy_per_op,
        ),
        (
            "Value SRAM",
            ops.value_sram_bytes as f64 * blocks::value_sram().energy_per_op,
        ),
        (
            "BF16 MACs",
            ops.bf16_macs as f64 * blocks::bf16_mac().energy_per_op,
        ),
        (
            "Top-k sorters",
            ops.top2_passes as f64 * blocks::top2_sorter().energy_per_op
                + ops.top32_passes as f64 * blocks::top32_sorter().energy_per_op,
        ),
        (
            "SoftMax",
            ops.softmax_ops as f64 * blocks::softmax_engine().energy_per_op,
        ),
        (
            "DMA/MC",
            ops.dma_rows as f64 * blocks::dma_mc().energy_per_op,
        ),
    ])
}

/// Core area by component [mm^2] (Fig. 8 right).
pub fn area_breakdown(cfg: &SystemConfig) -> Vec<Component> {
    to_components(vec![
        (
            "SRAM (Key+Value+Query)",
            blocks::key_sram().area_mm2
                + blocks::value_sram().area_mm2
                + blocks::query_buffer().area_mm2,
        ),
        ("Top-32 module", blocks::top32_sorter().area_mm2),
        ("Top-2 sorters", blocks::top2_sorter().area_mm2),
        ("BA-CAM + ADC", blocks::ba_cam_array().area_mm2 + blocks::sar_adc().area_mm2),
        (
            "BF16 MACs",
            cfg.mac_units as f64 * blocks::bf16_mac().area_mm2,
        ),
        ("SoftMax", blocks::softmax_engine().area_mm2),
        ("DMA/MC + control", blocks::dma_mc().area_mm2 + blocks::control().area_mm2),
    ])
}

/// Energy by *pipeline stage* (the paper's 57% contextualization claim).
pub fn stage_energy_breakdown(cfg: &SystemConfig) -> Vec<Component> {
    let by_comp = energy_breakdown(cfg);
    let find = |n: &str| by_comp.iter().find(|c| c.name == n).unwrap().value;
    to_components(vec![
        // association: CAM + ADC + Key SRAM streaming + stage-1 filter
        (
            "Association",
            find("BA-CAM + ADC") + find("Key SRAM"),
        ),
        // normalization: top-k finalisation + softmax
        ("Normalization", find("Top-k sorters") + find("SoftMax")),
        // contextualization: V SRAM + MACs + DMA
        (
            "Contextualization",
            find("Value SRAM") + find("BF16 MACs") + find("DMA/MC"),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pct(components: &[Component], name: &str) -> f64 {
        components.iter().find(|c| c.name == name).unwrap().pct
    }

    #[test]
    fn fig8_energy_fractions() {
        let e = energy_breakdown(&SystemConfig::default());
        // paper: Value SRAM 31%, Key SRAM 20%, MACs 26%, BA-CAM 12%
        assert!((pct(&e, "Value SRAM") - 31.0).abs() < 6.0, "{}", pct(&e, "Value SRAM"));
        assert!((pct(&e, "Key SRAM") - 20.0).abs() < 5.0, "{}", pct(&e, "Key SRAM"));
        assert!((pct(&e, "BF16 MACs") - 26.0).abs() < 6.0, "{}", pct(&e, "BF16 MACs"));
        assert!((pct(&e, "BA-CAM + ADC") - 12.0).abs() < 5.0, "{}", pct(&e, "BA-CAM + ADC"));
    }

    #[test]
    fn fig8_contextualization_dominates_energy() {
        let s = stage_energy_breakdown(&SystemConfig::default());
        let ctx = pct(&s, "Contextualization");
        // paper: 57%
        assert!((ctx - 57.0).abs() < 10.0, "contextualization {ctx}%");
        assert!(ctx > pct(&s, "Association"));
        assert!(ctx > pct(&s, "Normalization"));
    }

    #[test]
    fn fig8_area_fractions() {
        let a = area_breakdown(&SystemConfig::default());
        // paper: SRAM 42%, Top-32 26%
        assert!(
            (pct(&a, "SRAM (Key+Value+Query)") - 42.0).abs() < 6.0,
            "{}",
            pct(&a, "SRAM (Key+Value+Query)")
        );
        assert!((pct(&a, "Top-32 module") - 26.0).abs() < 6.0, "{}", pct(&a, "Top-32 module"));
    }

    #[test]
    fn percentages_sum_to_100() {
        for comps in [
            energy_breakdown(&SystemConfig::default()),
            area_breakdown(&SystemConfig::default()),
            stage_energy_breakdown(&SystemConfig::default()),
        ] {
            let total: f64 = comps.iter().map(|c| c.pct).sum();
            assert!((total - 100.0).abs() < 1e-9);
        }
    }
}
