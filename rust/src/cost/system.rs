//! System-level cost rollup: per-query op counts x block costs
//! -> throughput / energy-efficiency / area / power (Table II).

use super::blocks;

/// Workload + microarchitecture parameters (paper defaults: BERT-Large
/// head, n = 1024, d_k = d_v = 64, 16x64 CAM, g = 16, k = 32, 1 GHz).
#[derive(Clone, Copy, Debug)]
pub struct SystemConfig {
    pub n: usize,
    pub d_k: usize,
    pub d_v: usize,
    pub cam_h: usize,
    pub cam_w: usize,
    pub stage1_k: usize,
    pub final_k: usize,
    pub mac_units: usize,
    /// SAR ADC instances per array (1 = the paper's shared SAR).
    pub adcs_per_array: usize,
    pub clock_ghz: f64,
    pub cores: usize,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            n: 1024,
            d_k: 64,
            d_v: 64,
            cam_h: 16,
            cam_w: 64,
            stage1_k: 2,
            final_k: 32,
            mac_units: 8,
            adcs_per_array: 1,
            clock_ghz: 1.0,
            cores: 1,
        }
    }
}

impl SystemConfig {
    /// The 16-head / 16-HBM-channel CAMformer_MHA variant of Table II.
    pub fn mha() -> Self {
        SystemConfig {
            cores: 16,
            ..Default::default()
        }
    }

    pub fn h_tiles(&self) -> usize {
        self.n.div_ceil(self.cam_h)
    }

    pub fn v_tiles(&self) -> usize {
        self.d_k.div_ceil(self.cam_w)
    }

    pub fn tiles_per_query(&self) -> usize {
        self.h_tiles() * self.v_tiles()
    }
}

/// Per-query operation counts (the cost model's workload abstraction).
#[derive(Clone, Copy, Debug)]
pub struct OpCounts {
    pub cam_tile_ops: usize,
    pub adc_conversions: usize,
    pub key_sram_bytes: usize,
    pub value_sram_bytes: usize,
    pub top2_passes: usize,
    pub top32_passes: usize,
    pub softmax_ops: usize,
    pub bf16_macs: usize,
    pub dma_rows: usize,
}

impl OpCounts {
    pub fn for_query(cfg: &SystemConfig) -> Self {
        let tiles = cfg.tiles_per_query();
        OpCounts {
            cam_tile_ops: tiles,
            adc_conversions: tiles * cfg.cam_h,
            // with batch = 1 every query re-streams K into the array
            key_sram_bytes: cfg.n * cfg.d_k / 8,
            // V-buffer: prefetch write + MAC read of k rows of d_v bf16
            value_sram_bytes: 2 * cfg.final_k * cfg.d_v * 2,
            top2_passes: cfg.h_tiles(),
            // 64-input refinement per 32 stage-1 candidates (Sec. III-B2);
            // candidates = h_tiles * stage1_k
            top32_passes: (cfg.h_tiles() * cfg.stage1_k).div_ceil(32),
            softmax_ops: 1,
            bf16_macs: cfg.final_k * cfg.d_v,
            dma_rows: cfg.final_k,
        }
    }
}

/// Rolled-up system cost (one Table II row).
#[derive(Clone, Copy, Debug)]
pub struct CamformerCost {
    pub throughput_qry_per_ms: f64,
    pub energy_eff_qry_per_mj: f64,
    pub area_mm2: f64,
    pub power_w: f64,
    pub energy_per_query_j: f64,
    pub latency_us: f64,
}

impl CamformerCost {
    /// Evaluate the cost model for a configuration.
    ///
    /// Latency model (matches `arch::pipeline`): with coarse-grained
    /// pipelining, throughput is set by the longest stage; association's
    /// tile cadence is gated by the shared SAR's serialization
    /// (cam_h conversions x 6 cycles) overlapped with the next tile's
    /// CAM phases (fine-grained pipelining, Fig. 7 left).
    pub fn evaluate(cfg: &SystemConfig) -> Self {
        let ops = OpCounts::for_query(cfg);
        let cycle_ns = 1.0 / cfg.clock_ghz;
        // geometry scale factors relative to the paper's 16x64 / 1-ADC
        // design point (the block library is characterised there)
        let geom = (cfg.cam_h * cfg.cam_w) as f64 / (16.0 * 64.0);
        let sorter_scale = cfg.cam_h as f64 / 16.0;
        let adcs = cfg.adcs_per_array.max(1) as f64;

        // -- association stage latency --
        let adc_cycles_per_tile = (6 * cfg.cam_h).div_ceil(cfg.adcs_per_array.max(1));
        let cam_phase_cycles = 4u64; // precharge/broadcast/match/share
        let tile_cadence = (adc_cycles_per_tile as u64).max(cam_phase_cycles);
        let assoc_cycles = tile_cadence * cfg.tiles_per_query() as u64;

        // -- normalization stage -- (off the critical path, Sec. III-C2)
        // top-32 refinement passes + pipelined softmax 31 + t_div
        let t_div = 14u64;
        let norm_cycles = ops.top32_passes as u64 * 64 + 31 + t_div;

        // -- contextualization stage --
        let ctx_cycles = (ops.bf16_macs / cfg.mac_units) as u64 + 8;

        let bottleneck = assoc_cycles.max(norm_cycles).max(ctx_cycles);
        let latency_ns = (assoc_cycles + norm_cycles + ctx_cycles) as f64 * cycle_ns;
        let cadence_ns = bottleneck as f64 * cycle_ns;
        let throughput_qry_per_ms = 1e6 / cadence_ns * cfg.cores as f64;

        // -- energy per query -- (CAM tile ops and tile sorts scale with
        // the tile geometry; ADC conversions already count per row)
        let e = ops.cam_tile_ops as f64 * blocks::ba_cam_array().energy_per_op * geom
            + ops.adc_conversions as f64 * blocks::sar_adc().energy_per_op
            + ops.key_sram_bytes as f64 * blocks::key_sram().energy_per_op
            + ops.value_sram_bytes as f64 * blocks::value_sram().energy_per_op
            + blocks::query_buffer().energy_per_op
            + ops.top2_passes as f64 * blocks::top2_sorter().energy_per_op * sorter_scale
            + ops.top32_passes as f64 * blocks::top32_sorter().energy_per_op
            + ops.softmax_ops as f64 * blocks::softmax_engine().energy_per_op
            + ops.bf16_macs as f64 * blocks::bf16_mac().energy_per_op
            + ops.dma_rows as f64 * blocks::dma_mc().energy_per_op;

        // -- area & power per core --
        let core_area = blocks::ba_cam_array().area_mm2 * geom
            + blocks::sar_adc().area_mm2 * adcs
            + blocks::key_sram().area_mm2
            + blocks::value_sram().area_mm2
            + blocks::query_buffer().area_mm2
            + blocks::top2_sorter().area_mm2 * sorter_scale
            + blocks::top32_sorter().area_mm2
            + blocks::softmax_engine().area_mm2
            + cfg.mac_units as f64 * blocks::bf16_mac().area_mm2
            + blocks::dma_mc().area_mm2
            + blocks::control().area_mm2;
        let static_w = blocks::ba_cam_array().static_w * geom
            + blocks::sar_adc().static_w * adcs
            + blocks::key_sram().static_w
            + blocks::value_sram().static_w
            + blocks::query_buffer().static_w
            + blocks::top2_sorter().static_w * sorter_scale
            + blocks::top32_sorter().static_w
            + blocks::softmax_engine().static_w
            + cfg.mac_units as f64 * blocks::bf16_mac().static_w
            + blocks::dma_mc().static_w
            + blocks::control().static_w;

        let qry_per_s_core = 1e9 / cadence_ns;
        let dynamic_w = e * qry_per_s_core;
        // clock-tree + pipeline register overhead dominates small cores;
        // back-solved so total lands at the paper's 0.17 W for 0.26 mm^2
        let overhead_w = 0.115 * core_area / 0.26;

        CamformerCost {
            throughput_qry_per_ms,
            energy_eff_qry_per_mj: 1e-3 / e,
            area_mm2: core_area * cfg.cores as f64,
            power_w: (static_w + dynamic_w + overhead_w) * cfg.cores as f64,
            energy_per_query_j: e,
            latency_us: latency_ns / 1e3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> CamformerCost {
        CamformerCost::evaluate(&SystemConfig::default())
    }

    #[test]
    fn table2_throughput_band() {
        // paper: 191 qry/ms single core at 1 GHz
        let c = base();
        assert!(
            c.throughput_qry_per_ms > 140.0 && c.throughput_qry_per_ms < 240.0,
            "thruput {} qry/ms",
            c.throughput_qry_per_ms
        );
    }

    #[test]
    fn table2_energy_eff_band() {
        // paper: 9045 qry/mJ => ~110 nJ/query
        let c = base();
        assert!(
            c.energy_eff_qry_per_mj > 7000.0 && c.energy_eff_qry_per_mj < 12000.0,
            "eff {} qry/mJ",
            c.energy_eff_qry_per_mj
        );
    }

    #[test]
    fn table2_area_band() {
        // paper: 0.26 mm^2
        let c = base();
        assert!(c.area_mm2 > 0.22 && c.area_mm2 < 0.30, "area {}", c.area_mm2);
    }

    #[test]
    fn table2_power_band() {
        // paper: 0.17 W
        let c = base();
        assert!(c.power_w > 0.12 && c.power_w < 0.24, "power {}", c.power_w);
    }

    #[test]
    fn mha_scales_16x() {
        let one = base();
        let mha = CamformerCost::evaluate(&SystemConfig::mha());
        assert!((mha.throughput_qry_per_ms / one.throughput_qry_per_ms - 16.0).abs() < 1e-9);
        assert!((mha.area_mm2 / one.area_mm2 - 16.0).abs() < 1e-9);
        // paper: 4.13 mm^2, 2.69 W, 3058 qry/ms
        assert!(mha.area_mm2 > 3.5 && mha.area_mm2 < 4.8, "{}", mha.area_mm2);
        assert!(mha.throughput_qry_per_ms > 2200.0, "{}", mha.throughput_qry_per_ms);
    }

    #[test]
    fn association_is_bottleneck_at_paper_config() {
        // Fig. 9: association and contextualization balanced, association
        // slightly dominant; normalization has slack
        let cfg = SystemConfig::default();
        let ops = OpCounts::for_query(&cfg);
        let assoc = 6 * cfg.cam_h * cfg.tiles_per_query();
        let ctx = ops.bf16_macs / cfg.mac_units + 8;
        let norm = ops.top32_passes * 64 + 45;
        assert!(assoc > ctx && assoc > norm);
    }

    #[test]
    fn longer_context_lowers_throughput() {
        let short = CamformerCost::evaluate(&SystemConfig { n: 512, ..Default::default() });
        let long = CamformerCost::evaluate(&SystemConfig { n: 4096, ..Default::default() });
        assert!(short.throughput_qry_per_ms > long.throughput_qry_per_ms * 3.0);
    }

    #[test]
    fn more_macs_dont_help_when_association_bound() {
        let base_cfg = SystemConfig::default();
        let more = SystemConfig { mac_units: 32, ..base_cfg };
        let a = CamformerCost::evaluate(&base_cfg);
        let b = CamformerCost::evaluate(&more);
        assert!((a.throughput_qry_per_ms - b.throughput_qry_per_ms).abs() < 1e-9);
    }
}
