//! Two-stage top-k recall analysis (Sec. III-B1).
//!
//! The paper's guarantees:
//! * margin condition — if stage-1 scores satisfy |s_hat - s| <= eps and
//!   the top-k margin Delta_k = s_(k) - s_(k+1) > 2*eps, recall@k = 1;
//! * Hoeffding bound — for binary similarity (mean of m Bernoulli
//!   matches), Pr[drop any true top-k] <= k(N-k) exp(-2 m delta_min^2).
//!
//! Plus the structural recall loss this module Monte-Carlos: two-stage
//! top-k drops a true top-k element iff more than stage1_k of the true
//! top-k land in one tile.

use super::functional;
use crate::util::rng::Rng;

/// Monte-Carlo recall@final_k of two-stage vs exact top-k over random
/// binarised-score vectors. Returns mean recall in [0,1].
pub fn monte_carlo_recall(
    n: usize,
    group: usize,
    stage1_k: usize,
    final_k: usize,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    let mut total = 0.0;
    for _ in 0..trials {
        // scores ~ Binomial(d_k=64) mapped to signed, the BA-CAM output
        // distribution for random Q/K
        let scores: Vec<f64> = (0..n)
            .map(|_| {
                let mut m = 0;
                for _ in 0..64 {
                    if rng.bool() {
                        m += 1;
                    }
                }
                2.0 * m as f64 - 64.0
            })
            .collect();
        total += recall_for_scores(&scores, group, stage1_k, final_k);
    }
    total / trials as f64
}

/// Recall of two-stage selection against the true top-final_k for one
/// score vector.
///
/// Measured over score *multisets*, not index identity: BA-CAM scores are
/// heavily tied (integer codes), and swapping equal-score keys changes
/// nothing downstream — softmax weights and therefore attention output are
/// identical. Index-based recall would spuriously penalise tie permutations.
pub fn recall_for_scores(scores: &[f64], group: usize, stage1_k: usize, final_k: usize) -> f64 {
    let truth = functional::single_stage_topk_mask(scores, final_k);
    let got = functional::two_stage_topk_mask(scores, group, stage1_k, final_k);
    let mut want: Vec<f64> = scores
        .iter()
        .zip(&truth)
        .filter(|(_, &t)| t)
        .map(|(&s, _)| s)
        .collect();
    let mut have: Vec<f64> = scores
        .iter()
        .zip(&got)
        .filter(|(_, &g)| g)
        .map(|(&s, _)| s)
        .collect();
    want.sort_by(|a, b| b.partial_cmp(a).unwrap());
    have.sort_by(|a, b| b.partial_cmp(a).unwrap());
    // multiset intersection via two pointers
    let (mut i, mut j, mut hits) = (0usize, 0usize, 0usize);
    while i < want.len() && j < have.len() {
        if (want[i] - have[j]).abs() < 1e-12 {
            hits += 1;
            i += 1;
            j += 1;
        } else if have[j] > want[i] {
            j += 1;
        } else {
            i += 1;
        }
    }
    hits as f64 / want.len() as f64
}

/// Softmax-mass-weighted recall: the fraction of the true top-k's softmax
/// probability mass the two-stage selection retains. This is the metric
/// that actually predicts accuracy impact — dropping a borderline key with
/// near-zero attention weight is harmless, and the paper's <0.4% GLUE
/// deltas reflect exactly that.
pub fn weighted_recall_for_scores(
    scores: &[f64],
    d_k: usize,
    group: usize,
    stage1_k: usize,
    final_k: usize,
) -> f64 {
    let truth = functional::single_stage_topk_mask(scores, final_k);
    let got = functional::two_stage_topk_mask(scores, group, stage1_k, final_k);
    let scale = 1.0 / (d_k as f64).sqrt();
    let mx = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mass = |mask: &[bool]| -> f64 {
        scores
            .iter()
            .zip(mask)
            .filter(|(_, &m)| m)
            .map(|(&s, _)| ((s - mx) * scale).exp())
            .sum()
    };
    let want = mass(&truth);
    if want == 0.0 {
        return 1.0;
    }
    (mass(&got) / want).min(1.0)
}

/// Monte-Carlo of [`weighted_recall_for_scores`] over binarised-score
/// vectors.
pub fn monte_carlo_weighted_recall(
    n: usize,
    group: usize,
    stage1_k: usize,
    final_k: usize,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    let mut total = 0.0;
    for _ in 0..trials {
        let scores: Vec<f64> = (0..n)
            .map(|_| {
                let mut m = 0;
                for _ in 0..64 {
                    if rng.bool() {
                        m += 1;
                    }
                }
                2.0 * m as f64 - 64.0
            })
            .collect();
        total += weighted_recall_for_scores(&scores, 64, group, stage1_k, final_k);
    }
    total / trials as f64
}

/// Sample a *trained-attention-like* score vector: a few relevant keys
/// with high Hamming similarity (HAD training concentrates attention —
/// the premise that makes top-k truncation viable at all) over a
/// Binomial(d_k, 1/2) background of unrelated keys.
pub fn realistic_scores(n: usize, n_relevant: usize, rng: &mut Rng) -> Vec<f64> {
    let mut scores: Vec<f64> = (0..n)
        .map(|_| {
            let mut m = 0;
            for _ in 0..64 {
                if rng.bool() {
                    m += 1;
                }
            }
            2.0 * m as f64 - 64.0
        })
        .collect();
    for _ in 0..n_relevant {
        let idx = rng.index(n);
        // relevant keys: 75-95% bit match
        let matches = 48 + rng.index(13);
        scores[idx] = 2.0 * matches as f64 - 64.0;
    }
    scores
}

/// Monte-Carlo weighted recall over the realistic (peaked) score model.
pub fn monte_carlo_weighted_recall_realistic(
    n: usize,
    n_relevant: usize,
    group: usize,
    stage1_k: usize,
    final_k: usize,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    let mut total = 0.0;
    for _ in 0..trials {
        let scores = realistic_scores(n, n_relevant, rng);
        total += weighted_recall_for_scores(&scores, 64, group, stage1_k, final_k);
    }
    total / trials as f64
}

/// The paper's Hoeffding drop bound:
/// Pr[drop any true top-k] <= k (N - k) exp(-2 m delta_min^2).
pub fn hoeffding_drop_bound(k: usize, n: usize, m: usize, delta_min: f64) -> f64 {
    (k * (n - k)) as f64 * (-2.0 * m as f64 * delta_min * delta_min).exp()
}

/// The margin condition: recall@k = 1 when Delta_k > 2 eps.
pub fn margin_guarantees_recall(scores_exact: &[f64], eps: f64, k: usize) -> bool {
    let idx = functional::topk_indices(scores_exact, k + 1);
    if idx.len() <= k {
        return true;
    }
    let s_k = scores_exact[idx[k - 1]];
    let s_k1 = scores_exact[idx[k]];
    (s_k - s_k1) > 2.0 * eps
}

/// Exhaustively verify the margin theorem on perturbed scores: if the
/// margin holds, ANY eps-bounded perturbation keeps the same top-k *set*.
pub fn check_margin_theorem(
    scores: &[f64],
    eps: f64,
    k: usize,
    trials: usize,
    rng: &mut Rng,
) -> bool {
    if !margin_guarantees_recall(scores, eps, k) {
        return true; // theorem vacuous
    }
    let truth: Vec<usize> = {
        let mut t = functional::topk_indices(scores, k);
        t.sort();
        t
    };
    for _ in 0..trials {
        let noisy: Vec<f64> = scores
            .iter()
            .map(|&s| s + (rng.uniform() * 2.0 - 1.0) * eps)
            .collect();
        let mut got = functional::topk_indices(&noisy, k);
        got.sort();
        if got != truth {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn paper_config_recall_is_high() {
        let mut rng = Rng::new(50);
        // N=1024, g=16, top-2/tile, Top-32: the operating point of Eq. 1
        let r = monte_carlo_recall(1024, 16, 2, 32, 100, &mut rng);
        assert!(r > 0.85, "recall {r} too low for k1=2");
    }

    #[test]
    fn recall_monotone_in_stage1_k() {
        let mut rng = Rng::new(51);
        let r1 = monte_carlo_recall(1024, 16, 1, 32, 60, &mut rng);
        let r2 = monte_carlo_recall(1024, 16, 2, 32, 60, &mut rng);
        let r4 = monte_carlo_recall(1024, 16, 4, 32, 60, &mut rng);
        let r8 = monte_carlo_recall(1024, 16, 8, 32, 60, &mut rng);
        assert!(r1 <= r2 + 0.02 && r2 <= r4 + 0.02 && r4 <= r8 + 0.02);
        assert!(r8 > 0.99, "k1=8 should be near-perfect, got {r8}");
        assert!(r1 < r8, "recall must improve from k1=1 to k1=8");
    }

    #[test]
    fn perfect_recall_when_stage1_keeps_all() {
        let mut rng = Rng::new(52);
        let r = monte_carlo_recall(512, 16, 16, 32, 30, &mut rng);
        assert_eq!(r, 1.0);
    }

    #[test]
    fn structural_drop_example() {
        // 3 giant scores in one tile with stage1_k=2: one must drop
        let mut scores = vec![-64.0f64; 64];
        scores[0] = 64.0;
        scores[1] = 62.0;
        scores[2] = 60.0;
        let r = recall_for_scores(&scores, 16, 2, 3);
        assert!((r - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn hoeffding_bound_shrinks_with_margin_and_m() {
        let b1 = hoeffding_drop_bound(32, 1024, 64, 0.05);
        let b2 = hoeffding_drop_bound(32, 1024, 64, 0.2);
        let b3 = hoeffding_drop_bound(32, 1024, 256, 0.2);
        assert!(b2 < b1);
        assert!(b3 < b2);
        // delta=0.2, m=256: 32*992*exp(-20.48) ≈ 4e-5 — negligible
        assert!(b3 < 1e-4);
    }

    #[test]
    fn property_margin_theorem_holds() {
        check("margin theorem", 25, |rng| {
            let scores: Vec<f64> = (0..128).map(|_| rng.normal(0.0, 20.0)).collect();
            assert!(check_margin_theorem(&scores, 0.5, 8, 50, rng));
        });
    }

    #[test]
    fn coarser_tiles_win_at_equal_budget() {
        // at the same candidate budget (1024/64*8 == 1024/16*2 == 128),
        // larger tiles lose less: clustering of hot keys within a tile is
        // less likely to exceed the per-tile k. The paper still picks
        // 16-wide tiles because CAM_H=16 bounds ADC sharing — an area/
        // accuracy trade, not an accuracy optimum (cf. DESIGN.md ablations).
        let mut rng = Rng::new(53);
        let coarse = monte_carlo_recall(1024, 64, 8, 32, 60, &mut rng); // 16 tiles x 8
        let fine = monte_carlo_recall(1024, 16, 2, 32, 60, &mut rng); // 64 tiles x 2
        assert!(coarse >= fine - 0.02, "coarse {coarse} vs fine {fine}");
        assert!(fine > 0.9, "fine-tile recall {fine} still high");
    }
}
