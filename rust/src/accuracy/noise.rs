//! Circuit noise -> algorithmic accuracy coupling.
//!
//! Fig. 3b / Table I characterise the matchline's electrical error; this
//! module closes the loop the paper argues qualitatively: inject the
//! measured voltage-error distribution into the score path and measure the
//! effect on top-k recall — showing the 1.12 % BA-CAM error is far below
//! what two-stage selection notices, while TD-CAM-class error (7.8 %)
//! visibly erodes recall.

use super::functional;
use super::recall;
use crate::util::rng::Rng;

/// Quantise a noisy matchline sample through the 6-bit SAR, like the
/// hardware does (noise is in normalised full-scale units).
pub fn noisy_scores(clean: &[f64], d_k: usize, sigma_fs: f64, rng: &mut Rng) -> Vec<f64> {
    let levels = 64.0; // 6-bit
    clean
        .iter()
        .map(|&s| {
            let v = (s + d_k as f64) / (2.0 * d_k as f64); // [0,1]
            let noisy = (v + rng.normal(0.0, sigma_fs)).clamp(0.0, 1.0);
            let code = (noisy * levels).round().clamp(0.0, levels);
            2.0 * code * (d_k as f64 / levels) - d_k as f64
        })
        .collect()
}

/// Weighted recall of the two-stage top-k under matchline noise, averaged
/// over trials of the realistic (peaked) score model.
pub fn recall_under_noise(
    n: usize,
    sigma_fs: f64,
    stage1_k: usize,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    let mut total = 0.0;
    for _ in 0..trials {
        let clean = recall::realistic_scores(n, 8, rng);
        let noisy = noisy_scores(&clean, 64, sigma_fs, rng);
        // selection runs on noisy scores; retained mass is judged on the
        // clean (true) scores: exactly the recall@k the paper's margin
        // condition bounds
        let got = functional::two_stage_topk_mask(&noisy, 16, stage1_k, 32);
        let truth = functional::single_stage_topk_mask(&clean, 32);
        let scale = 1.0 / (64f64).sqrt();
        let mx = clean.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mass = |mask: &[bool]| -> f64 {
            clean
                .iter()
                .zip(mask)
                .filter(|(_, &m)| m)
                .map(|(&s, _)| ((s - mx) * scale).exp())
                .sum()
        };
        total += (mass(&got) / mass(&truth)).min(1.0);
    }
    total / trials as f64
}

/// The Fig. 3b -> accuracy bridge: recall at the three sensing schemes'
/// measured error levels (BA-CAM 1.12 %, CiM ~5 %, TD-CAM ~7.8 % of full
/// scale).
pub fn sensing_scheme_recall(n: usize, trials: usize, seed: u64) -> Vec<(&'static str, f64, f64)> {
    let mut rng = Rng::new(seed);
    [("BA-CAM", 0.0112), ("CiM", 0.051), ("TD-CAM", 0.078)]
        .into_iter()
        .map(|(name, sigma)| {
            let r = recall_under_noise(n, sigma, 2, trials, &mut rng);
            (name, sigma, r)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_noise_matches_noiseless_recall() {
        let mut rng = Rng::new(50);
        let noisy = recall_under_noise(1024, 0.0, 2, 40, &mut rng);
        let clean = recall::monte_carlo_weighted_recall_realistic(1024, 8, 16, 2, 32, 40, &mut rng);
        assert!((noisy - clean).abs() < 0.03, "{noisy} vs {clean}");
    }

    #[test]
    fn bacam_noise_level_is_negligible() {
        // 1.12% full-scale error costs < 2% weighted recall at the paper's
        // operating point — the robustness claim of Sec. II-B1
        let mut rng = Rng::new(51);
        let r = recall_under_noise(1024, 0.0112, 2, 60, &mut rng);
        assert!(r > 0.97, "recall {r} under BA-CAM noise");
    }

    #[test]
    fn recall_degrades_monotonically_with_noise() {
        let mut rng = Rng::new(52);
        let r0 = recall_under_noise(512, 0.0, 2, 60, &mut rng);
        let r1 = recall_under_noise(512, 0.02, 2, 60, &mut rng);
        let r2 = recall_under_noise(512, 0.08, 2, 60, &mut rng);
        assert!(r0 >= r1 - 0.02);
        assert!(r1 > r2, "{r1} vs {r2}");
    }

    #[test]
    fn sensing_schemes_ordered_by_quality() {
        let rows = sensing_scheme_recall(512, 50, 53);
        assert_eq!(rows[0].0, "BA-CAM");
        assert!(rows[0].2 > rows[1].2, "BA-CAM should beat CiM");
        assert!(rows[1].2 > rows[2].2 - 0.02, "CiM ~>= TD-CAM");
        // TD-CAM-class error visibly erodes selection quality
        assert!(rows[0].2 - rows[2].2 > 0.02);
    }
}
