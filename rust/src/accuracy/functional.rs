//! The CAMformer attention datapath in pure Rust — Eq. 1 end to end.
//!
//! This is the behavioural twin of `python/compile/kernels/ref.py`; the
//! runtime integration tests assert the PJRT-executed Pallas artifacts,
//! this model and the jnp oracle all agree. It is also the model the
//! coordinator uses for golden checks on the serving path.
//!
//! # §Perf iterations (the serving hot path)
//!
//! 1. Per-call bit-packing of Q and K — **reverted**: packing cost more
//!    than the XNOR+popcount saved when K is packed again every call.
//! 2. Branchless u8 sign-match scorer ([`bacam_scores_cfg`]) — the
//!    autovectoriser turns the equality count into SIMD lanes.
//! 3. [`PackedKeys`]: pack K once, score many queries with one
//!    XNOR+popcount per 64 bits — serving reuses K across requests, so
//!    packing amortises to zero.
//! 4. Survivor-list sparsity ([`two_stage_topk_indices`],
//!    [`lut_softmax_sparse`], [`weighted_sum_bf16_sparse`]): the two-stage
//!    top-k keeps ≤ `final_k` rows (Sec. III-C4), so softmax and BF16
//!    contextualization walk the ≤ `final_k` survivors instead of a
//!    length-n boolean mask — O(k·d) instead of O(n·d) per query, and
//!    bit-identical to the dense mask path (adding a masked lane's 0.0 to
//!    a finite f32 accumulator is exact, and survivor order stays
//!    ascending). Stage-1 selection itself is allocation-free: an
//!    in-place insertion scan per tile into one reused scratch buffer
//!    ([`TopkScratch`]) replaced a heap-allocated `topk_indices` call per
//!    16-row tile.
//! 5. Incremental key packing: the packed bits moved *into* the serving
//!    KV store (`KvStore` packs exactly the appended row, O(d) per decode
//!    step, instead of the backend re-packing all n rows after every
//!    append) and execution borrows them through [`PackedKeysView`] — see
//!    `coordinator::kv_store`.
//! 6. **FlashCAM fusion** ([`camformer_attention_view_fused`] +
//!    [`FusedScratch`]): one streaming pass over 16-row key tiles instead
//!    of score → top-k → softmax → contextualize as separate passes over
//!    intermediate n-length vectors. Each tile is scored into a hot
//!    tile-sized buffer (u64 XOR+popcount words through a per-(d_k,
//!    adc_bits) match-count → ADC-score LUT — the SAR quantizer is a
//!    pure function of the match count, so LUT scores are the exact f64s
//!    the per-row path computes), its stage-1 winners fold into a running
//!    top-k threshold carried tile-to-tile ([`StreamingTopk`], the same
//!    insertion scan as stage 2, with online eviction of earlier
//!    survivors a later tile beats), and softmax + BF16
//!    contextualization walk only the ≤ `final_k` retained (index,
//!    score) pairs at stream end. The n-length score vector never
//!    materialises — scores round-trip through a 16-entry buffer the way
//!    Flash Attention keeps tiles in SRAM instead of HBM — yet every
//!    float op runs in the same order on the same values as the dense
//!    baseline, so the output is bit-identical.
//!
//! The dense mask path is kept, unoptimised, as the cross-check baseline
//! for the sparse and fused pipelines (`FunctionalBackend::new_dense`,
//! the `batcher_fuzz` harness, and the property tests below).

use crate::util::bf16;

/// Attention configuration (paper defaults via [`AttnConfig::paper`]).
#[derive(Clone, Copy, Debug)]
pub struct AttnConfig {
    pub n: usize,
    pub d_k: usize,
    /// Stage-1 group size g (= CAM_H).
    pub group: usize,
    /// Stage-1 top-k per group (the bitonic Top-2).
    pub stage1_k: usize,
    /// Final top-k (the Top-32 block).
    pub final_k: usize,
    pub adc_bits: u32,
}

impl AttnConfig {
    /// Eq. 1 defaults: g=16, top-2 per tile, Top-32 overall, 6-bit ADC.
    pub fn paper(n: usize, d_k: usize) -> Self {
        AttnConfig {
            n,
            d_k,
            group: 16,
            stage1_k: 2,
            final_k: 32,
            adc_bits: 6,
        }
    }
}

/// Sign-binarise to ±1 (zero maps to +1, matching ref.binarize).
pub fn binarize(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect()
}

/// BA-CAM association scores: binarise -> matchline -> 6-bit ADC.
/// `q`: d_k reals; `k`: row-major N x d_k. Output: N signed scores.
pub fn bacam_scores(q: &[f32], k: &[f32], d_k: usize) -> Vec<f64> {
    bacam_scores_cfg(q, k, d_k, 6)
}

/// As [`bacam_scores`] with explicit ADC resolution. One-shot hot path;
/// when the same K is scored repeatedly, use [`PackedKeys`] instead.
pub fn bacam_scores_cfg(q: &[f32], k: &[f32], d_k: usize, adc_bits: u32) -> Vec<f64> {
    assert_eq!(q.len(), d_k);
    assert_eq!(k.len() % d_k, 0);
    let n = k.len() / d_k;
    // branchless match count: one u8 equality per element, which the
    // autovectoriser turns into SIMD lanes (§Perf iteration 2 — the
    // per-call bit-packing of iteration 1 cost more than it saved)
    let q_sign: Vec<u8> = q.iter().map(|&x| (x >= 0.0) as u8).collect();
    let mut out = Vec::with_capacity(n);
    for r in 0..n {
        let row = &k[r * d_k..(r + 1) * d_k];
        let mut matches = 0u32;
        for (qs, &kv) in q_sign.iter().zip(row) {
            matches += (*qs == (kv >= 0.0) as u8) as u32;
        }
        out.push(quantize_matches(matches, d_k, adc_bits));
    }
    out
}

/// Shared SAR + multiply-subtract on an integer match count.
#[inline]
fn quantize_matches(matches: u32, d_k: usize, adc_bits: u32) -> f64 {
    let levels = (1u32 << adc_bits) as f64;
    let dot = 2.0 * matches as f64 - d_k as f64;
    let v = (dot + d_k as f64) / (2.0 * d_k as f64);
    let code = (v * levels).round().clamp(0.0, levels);
    2.0 * code * (d_k as f64 / levels) - d_k as f64
}

/// Sign-packed key memory: pack K once, score many queries with one
/// XNOR+popcount per 64 bits (§Perf iteration 3). Since §Perf iteration 5
/// the packing is maintainable *incrementally* ([`PackedKeys::all_pad`] +
/// [`PackedKeys::set_row`] / [`PackedKeys::pad_rows`]) so a growing KV
/// cache packs exactly the appended row per decode step, and execution
/// layers borrow the bits through [`PackedKeys::view`] instead of
/// re-deriving them.
#[derive(Clone, Debug)]
pub struct PackedKeys {
    pub n: usize,
    pub d_k: usize,
    words: usize,
    tail_mask: u64,
    bits: Vec<u64>, // row-major n x words
}

impl PackedKeys {
    pub fn new(k: &[f32], d_k: usize) -> Self {
        assert_eq!(k.len() % d_k, 0);
        let n = k.len() / d_k;
        let mut packed = Self::all_pad(n, d_k);
        for r in 0..n {
            packed.set_row(r, &k[r * d_k..(r + 1) * d_k]);
        }
        packed
    }

    /// A packed memory of `rows` rows all holding the pad pattern
    /// (all-(+1) keys, `KvStore::KEY_PAD`): every lane below `d_k` set.
    pub fn all_pad(rows: usize, d_k: usize) -> Self {
        let words = d_k.div_ceil(64);
        let tail_mask = if d_k % 64 == 0 { u64::MAX } else { (1u64 << (d_k % 64)) - 1 };
        let mut packed = PackedKeys {
            n: rows,
            d_k,
            words,
            tail_mask,
            bits: vec![u64::MAX; rows * words],
        };
        // lanes at or beyond d_k stay clear, like pack_signs_into leaves them
        for r in 0..rows {
            packed.bits[(r + 1) * words - 1] = tail_mask;
        }
        packed
    }

    /// Re-pack one row in place — O(d_k), the incremental-append hot path.
    pub fn set_row(&mut self, r: usize, key: &[f32]) {
        assert_eq!(key.len(), self.d_k);
        pack_signs_into(key, &mut self.bits[r * self.words..(r + 1) * self.words]);
    }

    /// Restore the pad pattern over rows `[from, to)` (load shrink /
    /// speculative rollback).
    pub fn pad_rows(&mut self, from: usize, to: usize) {
        for r in from..to {
            let row = &mut self.bits[r * self.words..(r + 1) * self.words];
            for w in row.iter_mut() {
                *w = u64::MAX;
            }
            row[self.words - 1] = self.tail_mask;
        }
    }

    /// Borrowed scoring view over the first `rows` rows.
    pub fn view(&self, rows: usize) -> PackedKeysView<'_> {
        assert!(rows <= self.n, "view rows {rows} beyond packed n {}", self.n);
        PackedKeysView {
            n: rows,
            d_k: self.d_k,
            words: self.words,
            tail_mask: self.tail_mask,
            bits: &self.bits[..rows * self.words],
        }
    }

    /// Scores for one query against the packed memory.
    pub fn scores(&self, q: &[f32], adc_bits: u32) -> Vec<f64> {
        self.scores_prefix(q, adc_bits, self.n)
    }

    /// As [`PackedKeys::scores`], but rows at or beyond `valid_rows` are
    /// scored as the pad pattern — see [`PackedKeysView::scores_prefix_into`].
    pub fn scores_prefix(&self, q: &[f32], adc_bits: u32, valid_rows: usize) -> Vec<f64> {
        self.view(self.n).scores_prefix(q, adc_bits, valid_rows)
    }
}

/// Borrowed view over a sign-packed key memory: what the serving layer
/// hands backends (`AttendItem::packed`) so they score store-owned bits
/// instead of re-packing the K buffer. `Copy`, so batch items stay cheap.
#[derive(Clone, Copy, Debug)]
pub struct PackedKeysView<'a> {
    /// Rows visible through this view (the padded execution geometry).
    pub n: usize,
    pub d_k: usize,
    words: usize,
    tail_mask: u64,
    bits: &'a [u64], // row-major n x words
}

impl PackedKeysView<'_> {
    /// Scores into a caller-owned buffer (allocation-free after warmup).
    ///
    /// Rows at or beyond `valid_rows` are scored as the pad pattern
    /// (all-(+1) keys, `KvStore::KEY_PAD`) regardless of what the packed
    /// buffer holds there. This is the speculative-fusion prefix
    /// contract: a fused decode burst applies every KV append up front,
    /// so the buffer behind an early step's view already holds that
    /// session's *later* keys — which that step, sequentially, would
    /// have seen as pre-written pad rows. A pad row matches exactly the
    /// query's non-negative lanes, so its score is computed analytically,
    /// bit-identical to packing a literal pad row.
    pub fn scores_prefix_into(
        &self,
        q: &[f32],
        adc_bits: u32,
        valid_rows: usize,
        out: &mut Vec<f64>,
    ) {
        assert_eq!(q.len(), self.d_k);
        assert!(valid_rows <= self.n, "prefix {valid_rows} beyond packed n {}", self.n);
        let qp = pack_signs(q, self.words);
        out.clear();
        out.reserve(self.n);
        for r in 0..valid_rows {
            let row = &self.bits[r * self.words..(r + 1) * self.words];
            let mut matches = 0u32;
            for w in 0..self.words {
                let mut eq = !(qp[w] ^ row[w]);
                if w == self.words - 1 {
                    eq &= self.tail_mask;
                }
                matches += eq.count_ones();
            }
            out.push(quantize_matches(matches, self.d_k, adc_bits));
        }
        if valid_rows < self.n {
            // an all-ones pad row turns !(qp ^ row) into qp itself, and
            // pack_signs never sets bits past d_k, so the match count is
            // just the query's non-negative-lane popcount
            let pad_matches: u32 = qp.iter().map(|w| w.count_ones()).sum();
            out.resize(self.n, quantize_matches(pad_matches, self.d_k, adc_bits));
        }
    }

    /// Allocating convenience for [`PackedKeysView::scores_prefix_into`].
    pub fn scores_prefix(&self, q: &[f32], adc_bits: u32, valid_rows: usize) -> Vec<f64> {
        let mut out = Vec::new();
        self.scores_prefix_into(q, adc_bits, valid_rows, &mut out);
        out
    }
}

/// Eq. 1 against a pre-packed key memory (the serving hot path).
pub fn camformer_attention_packed(
    q: &[f32],
    keys: &PackedKeys,
    v: &[f32],
    cfg: &AttnConfig,
) -> Vec<f32> {
    camformer_attention_packed_prefix(q, keys, v, cfg, cfg.n)
}

/// Eq. 1 against a pre-packed key memory of which only the first
/// `valid_rows` rows are live for this query (its causal prefix under
/// speculative multi-step fusion). Rows at or beyond the prefix behave
/// exactly like the pre-written pad rows a sequential dispatch would
/// have seen there — pad-pattern scores, zero V contribution — so a
/// fused burst's per-step outputs are bit-equal to stepping one
/// dispatch at a time.
pub fn camformer_attention_packed_prefix(
    q: &[f32],
    keys: &PackedKeys,
    v: &[f32],
    cfg: &AttnConfig,
    valid_rows: usize,
) -> Vec<f32> {
    camformer_attention_view_dense(q, &keys.view(keys.n), v, cfg, valid_rows)
}

/// The dense-mask pipeline over a borrowed packed view: every stage walks
/// all n rows. Kept as the cross-check baseline for
/// [`camformer_attention_view_sparse`] (§Perf iteration 4), to which it
/// is bit-identical.
pub fn camformer_attention_view_dense(
    q: &[f32],
    keys: &PackedKeysView<'_>,
    v: &[f32],
    cfg: &AttnConfig,
    valid_rows: usize,
) -> Vec<f32> {
    let scores = keys.scores_prefix(q, cfg.adc_bits, valid_rows);
    let mask = two_stage_topk_mask(&scores, cfg.group, cfg.stage1_k, cfg.final_k);
    let a = lut_softmax(&scores, &mask, cfg.d_k);
    weighted_sum_bf16_prefix(&a, v, cfg.n, cfg.d_k, valid_rows)
}

/// Reusable buffers for [`camformer_attention_view_sparse`]: scores,
/// selection scratch and the survivor list. One per backend/query stream;
/// after warmup the sparse pipeline allocates only its ≤ `final_k`-entry
/// weight vector and the d_v-lane output.
#[derive(Clone, Debug, Default)]
pub struct AttnScratch {
    scores: Vec<f64>,
    topk: TopkScratch,
    survivors: Vec<usize>,
}

impl AttnScratch {
    /// Survivor indices of the most recent sparse attention call (the
    /// rows contextualization actually touched).
    pub fn survivors(&self) -> &[usize] {
        &self.survivors
    }
}

/// Eq. 1 over a borrowed packed view through the survivor-list pipeline
/// (§Perf iteration 4): score all rows, select the ≤ `final_k` survivors
/// once, then softmax + BF16-contextualise only those rows — O(n + k·d)
/// per query instead of the dense path's O(n·d). Bit-identical to
/// [`camformer_attention_view_dense`]: a masked lane contributes exactly
/// 0.0 to the softmax normaliser and is skipped by the dense
/// contextualization loop, and survivors are visited in the same
/// ascending order either way. (The identity assumes the selection is
/// non-degenerate — `final_k >= 1` and `stage1_k >= 1`, as every paper
/// config has; with an empty survivor set the dense path's 0.0/0.0
/// normalisation yields NaN where this path yields zeros.)
pub fn camformer_attention_view_sparse(
    q: &[f32],
    keys: &PackedKeysView<'_>,
    v: &[f32],
    cfg: &AttnConfig,
    valid_rows: usize,
    scratch: &mut AttnScratch,
) -> Vec<f32> {
    keys.scores_prefix_into(q, cfg.adc_bits, valid_rows, &mut scratch.scores);
    two_stage_topk_indices_into(
        &scratch.scores,
        cfg.group,
        cfg.stage1_k,
        cfg.final_k,
        &mut scratch.topk,
        &mut scratch.survivors,
    );
    let w = lut_softmax_sparse(&scratch.scores, &scratch.survivors, cfg.d_k);
    weighted_sum_bf16_sparse(&w, &scratch.survivors, v, cfg.d_k, valid_rows)
}

/// Streaming two-stage top-k: the running (index, score) selection the
/// FlashCAM pass carries tile-to-tile (§Perf iteration 6). Each tile's
/// stage-1 winners are [`StreamingTopk::offer`]ed in ascending index
/// order; the buffer keeps the best ≤ k seen so far by (score desc,
/// index asc), evicting the current worst when a later candidate beats
/// the admission [`StreamingTopk::threshold`] — the *online correction*
/// that makes one pass equivalent to selecting over all candidates at
/// once. The insertion scan is exactly stage 2's (strict `<`, so a tie
/// at the threshold keeps the earlier index), which is what pins the
/// final entries, sorted ascending, to `two_stage_topk_indices`.
#[derive(Clone, Debug, Default)]
pub struct StreamingTopk {
    k: usize,
    /// (row, score) by (score desc, index asc); at most k entries.
    entries: Vec<(usize, f64)>,
    corrections: u64,
}

impl StreamingTopk {
    /// Empty the selection and set its capacity for a new stream.
    pub fn reset(&mut self, k: usize) {
        self.k = k;
        self.entries.clear();
        self.corrections = 0;
    }

    /// The current admission bar: the score of the worst retained entry
    /// once the selection is full. A later candidate must strictly beat
    /// it to enter (a tie at the threshold loses to the earlier index).
    /// `None` while the selection is still filling.
    pub fn threshold(&self) -> Option<f64> {
        (self.k > 0 && self.entries.len() == self.k).then(|| self.entries[self.k - 1].1)
    }

    /// Offer one stage-1 winner. Candidates MUST arrive in ascending row
    /// order (tiles walked in order, winners sorted within each tile):
    /// equal scores then sit in arrival order, which is what makes the
    /// tie-break identical to the batch selection's.
    pub fn offer(&mut self, row: usize, score: f64) {
        let mut pos = self.entries.len();
        while pos > 0 && self.entries[pos - 1].1 < score {
            pos -= 1;
        }
        if pos < self.k {
            if self.entries.len() == self.k {
                // online correction: a later tile evicts an earlier
                // tentative survivor
                self.entries.pop();
                self.corrections += 1;
            }
            self.entries.insert(pos, (row, score));
        }
    }

    /// Retained (row, score) pairs by (score desc, index asc).
    pub fn entries(&self) -> &[(usize, f64)] {
        &self.entries
    }

    /// How many tentative survivors later tiles evicted this stream.
    pub fn corrections(&self) -> u64 {
        self.corrections
    }
}

/// Reusable buffers for [`camformer_attention_view_fused`] (§Perf
/// iteration 6): the packed query, the match-count → ADC-score LUT, one
/// tile's scores, the tile's stage-1 winners, the running
/// [`StreamingTopk`] and the final survivor pairs — everything the
/// streaming pass touches, none of it O(n). One per backend/query
/// stream; per-call work counters are read back through the accessors.
#[derive(Clone, Debug, Default)]
pub struct FusedScratch {
    /// Sign-packed query words.
    qp: Vec<u64>,
    /// match count -> quantized ADC score, `d_k + 1` entries.
    score_lut: Vec<f64>,
    /// (d_k, adc_bits) the LUT was built for.
    lut_key: (usize, u32),
    /// The one live tile's scores (group entries) — the whole "score
    /// buffer" of the fused pass.
    tile: Vec<f64>,
    /// Stage-1 winners of the current tile, tile-local indices.
    stage1: Vec<usize>,
    topk: StreamingTopk,
    /// Final survivors as (row, score), ascending by row.
    pairs: Vec<(usize, f64)>,
    /// Final survivor rows, ascending (aligned with `pairs`).
    survivors: Vec<usize>,
    words_scored: u64,
    tiles_streamed: u64,
}

impl FusedScratch {
    /// Survivor rows of the most recent fused call, ascending.
    pub fn survivors(&self) -> &[usize] {
        &self.survivors
    }

    /// u64 score words XOR+popcounted in the most recent call (pad rows
    /// are scored analytically and cost no words).
    pub fn words_scored(&self) -> u64 {
        self.words_scored
    }

    /// 16-row key tiles streamed in the most recent call.
    pub fn tiles_streamed(&self) -> u64 {
        self.tiles_streamed
    }

    /// Online corrections (tentative survivors evicted by later tiles)
    /// in the most recent call.
    pub fn corrections(&self) -> u64 {
        self.topk.corrections()
    }
}

/// Eq. 1 over a borrowed packed view as ONE streaming pass over 16-row
/// key tiles — FlashCAM, §Perf iteration 6. Per tile: score its rows
/// into a hot `group`-entry buffer (u64 XOR+popcount per 64 key-bit
/// lanes, match counts looked up in a per-(d_k, adc_bits) score LUT, pad
/// rows at/beyond `valid_rows` scored analytically at zero word cost),
/// select the tile's stage-1 winners in place, and fold them into the
/// running [`StreamingTopk`] threshold carried tile-to-tile. Survivors
/// are contextualized at stream end from the retained (row, score) pairs
/// — softmax and the BF16 MACs never see a score that didn't survive, an
/// n-length score vector never materialises, and eviction of an earlier
/// tentative survivor by a later tile is the online correction.
///
/// Bit-identical to [`camformer_attention_view_dense`]: the LUT holds
/// the exact f64 the SAR quantizer computes per match count, the
/// streaming selection is provably `two_stage_topk_indices` (same
/// insertion scans, same arrival order, same tie-breaks — pinned by the
/// `property_streaming_*` tests below), and the final softmax +
/// contextualization execute the same f32 ops in the same ascending
/// survivor order as the sparse pipeline, which is itself pinned
/// bit-equal to dense.
pub fn camformer_attention_view_fused(
    q: &[f32],
    keys: &PackedKeysView<'_>,
    v: &[f32],
    cfg: &AttnConfig,
    valid_rows: usize,
    scratch: &mut FusedScratch,
) -> Vec<f32> {
    let (n, group, words) = (keys.n, cfg.group, keys.words);
    assert_eq!(n % group, 0, "N={n} not a multiple of group={group}");
    assert_eq!(q.len(), keys.d_k);
    assert!(valid_rows <= n, "prefix {valid_rows} beyond packed n {n}");
    scratch.qp.resize(words, 0);
    pack_signs_into(q, &mut scratch.qp);
    if scratch.lut_key != (keys.d_k, cfg.adc_bits) || scratch.score_lut.len() != keys.d_k + 1 {
        scratch.score_lut.clear();
        scratch
            .score_lut
            .extend((0..=keys.d_k).map(|m| quantize_matches(m as u32, keys.d_k, cfg.adc_bits)));
        scratch.lut_key = (keys.d_k, cfg.adc_bits);
    }
    // an all-ones pad row turns !(qp ^ row) into qp itself, so every pad
    // row scores the query's non-negative-lane popcount — computed once
    let pad_matches: u32 = scratch.qp.iter().map(|w| w.count_ones()).sum();
    let pad_score = scratch.score_lut[pad_matches as usize];
    scratch.topk.reset(cfg.final_k);
    scratch.tile.resize(group, 0.0);
    scratch.words_scored = 0;
    scratch.tiles_streamed = 0;
    for base in (0..n).step_by(group) {
        // ① score the tile into the hot buffer
        for i in 0..group {
            scratch.tile[i] = if base + i < valid_rows {
                let row = &keys.bits[(base + i) * words..(base + i + 1) * words];
                let mut matches = 0u32;
                for w in 0..words {
                    let mut eq = !(scratch.qp[w] ^ row[w]);
                    if w == words - 1 {
                        eq &= keys.tail_mask;
                    }
                    matches += eq.count_ones();
                }
                scratch.words_scored += words as u64;
                scratch.score_lut[matches as usize]
            } else {
                pad_score
            };
        }
        // ② the tile's stage-1 winners, ascending (the arrival order the
        // streaming tie-break relies on)
        select_topk_into(&scratch.tile, 0..group, cfg.stage1_k, &mut scratch.stage1);
        scratch.stage1.sort_unstable();
        // ③ fold into the running threshold
        for &i in &scratch.stage1 {
            scratch.topk.offer(base + i, scratch.tile[i]);
        }
        scratch.tiles_streamed += 1;
    }
    // ④ contextualize the ≤ final_k retained survivors, ascending
    scratch.pairs.clear();
    scratch.pairs.extend_from_slice(scratch.topk.entries());
    scratch.pairs.sort_unstable_by_key(|p| p.0);
    scratch.survivors.clear();
    scratch.survivors.extend(scratch.pairs.iter().map(|p| p.0));
    let w = lut_softmax_pairs(&scratch.pairs, cfg.d_k);
    weighted_sum_bf16_sparse(&w, &scratch.survivors, v, cfg.d_k, valid_rows)
}

/// [`lut_softmax_sparse`] over retained (row, score) pairs (ascending by
/// row) instead of survivor indices into an n-length score vector — the
/// same f32 ops in the same order on the same values, for the fused pass
/// that never materialises that vector.
fn lut_softmax_pairs(pairs: &[(usize, f64)], d_k: usize) -> Vec<f32> {
    let scale = 1.0 / (d_k as f32).sqrt();
    let mut mx = f32::NEG_INFINITY;
    for &(_, s) in pairs {
        mx = mx.max(s as f32 * scale);
    }
    let mut es: Vec<f32> = pairs
        .iter()
        .map(|&(_, s)| {
            let x = s as f32 * scale;
            if x.is_finite() { (x - mx).exp() } else { 0.0 }
        })
        .collect();
    let sum: f32 = es.iter().sum();
    for e in &mut es {
        *e /= sum;
    }
    es
}

/// The pre-optimisation scorer (float inner product): kept as the §Perf
/// baseline and as an independent cross-check of the packed path.
pub fn bacam_scores_float_reference(q: &[f32], k: &[f32], d_k: usize, adc_bits: u32) -> Vec<f64> {
    assert_eq!(q.len(), d_k);
    let n = k.len() / d_k;
    let qb = binarize(q);
    let levels = (1u32 << adc_bits) as f64;
    (0..n)
        .map(|r| {
            let row = &k[r * d_k..(r + 1) * d_k];
            let mut dot = 0.0f64;
            for (a, &b) in qb.iter().zip(row) {
                let kb = if b >= 0.0 { 1.0 } else { -1.0 };
                dot += (*a as f64) * kb;
            }
            let v = (dot + d_k as f64) / (2.0 * d_k as f64);
            let code = (v * levels).round().clamp(0.0, levels);
            2.0 * code * (d_k as f64 / levels) - d_k as f64
        })
        .collect()
}

/// Pack sign bits (x >= 0 -> 1) into u64 words, LSB-first.
fn pack_signs(x: &[f32], words: usize) -> Vec<u64> {
    let mut out = vec![0u64; words];
    pack_signs_into(x, &mut out);
    out
}

fn pack_signs_into(x: &[f32], out: &mut [u64]) {
    for w in out.iter_mut() {
        *w = 0;
    }
    for (i, &v) in x.iter().enumerate() {
        // f32 sign-bit test: v >= 0 (incl. +0) iff sign bit clear — but
        // -0.0 must binarise to +1 like the jnp oracle's `where(x >= 0)`
        if v >= 0.0 {
            out[i / 64] |= 1u64 << (i % 64);
        }
    }
}

/// Stable top-k indices of `scores` (ties to the lower index, matching a
/// stable hardware sorter / jnp stable argsort).
///
/// §Perf: selection (`select_nth_unstable_by`) + sort of the k survivors
/// instead of a full sort — O(n + k log k); the (score desc, index asc)
/// comparator is a total order, so the result is identical to the stable
/// full sort it replaced.
pub fn topk_indices(scores: &[f64], k: usize) -> Vec<usize> {
    let n = scores.len();
    let k = k.min(n);
    let mut idx: Vec<usize> = (0..n).collect();
    let cmp = |&a: &usize, &b: &usize| {
        scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b))
    };
    if k > 0 && k < n {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_by(cmp);
    idx.truncate(k);
    idx
}

/// Reusable buffers for [`two_stage_topk_indices_into`]: one per query
/// stream, so selection performs no heap allocation after warmup (§Perf
/// iteration 4 — the previous mask builder heap-allocated a fresh index
/// vector per 16-row tile, n/16 allocations per attend).
#[derive(Clone, Debug, Default)]
pub struct TopkScratch {
    /// Stage-1 winners of the current tile / stage-2 selection buffer.
    sel: Vec<usize>,
    /// Stage-1 survivors across all tiles, ascending.
    candidates: Vec<usize>,
}

/// Stable top-k selection over candidate indices (visited in ascending
/// index order) by (score desc, index asc), via an in-place insertion
/// scan: a candidate not beating the current k-th is rejected with one
/// comparison, so the common case is O(1) per candidate.
fn select_topk_into(
    scores: &[f64],
    cand: impl Iterator<Item = usize>,
    k: usize,
    buf: &mut Vec<usize>,
) {
    buf.clear();
    if k == 0 {
        return;
    }
    for i in cand {
        let si = scores[i];
        let mut pos = buf.len();
        // strict `<` keeps ties on the earlier (lower-index) entry, which
        // was inserted first because candidates arrive in ascending order
        while pos > 0 && scores[buf[pos - 1]] < si {
            pos -= 1;
        }
        if pos < k {
            if buf.len() == k {
                buf.pop();
            }
            buf.insert(pos, i);
        }
    }
}

/// Hierarchical two-stage top-k (Sec. III-C4) as a survivor list: the
/// ≤ `final_k` indices that survive both stages, ascending. The sparse
/// counterpart of [`two_stage_topk_mask`] — same selection, but the
/// output is sized by k, not n, so downstream stages can walk only the
/// survivors.
pub fn two_stage_topk_indices(
    scores: &[f64],
    group: usize,
    stage1_k: usize,
    final_k: usize,
) -> Vec<usize> {
    let mut scratch = TopkScratch::default();
    let mut out = Vec::new();
    two_stage_topk_indices_into(scores, group, stage1_k, final_k, &mut scratch, &mut out);
    out
}

/// Allocation-free core of [`two_stage_topk_indices`]: stage-1 top-k per
/// tile and stage-2 top-`final_k` over the survivors run as in-place
/// insertion scans over `scratch`; `out` ends ascending.
pub fn two_stage_topk_indices_into(
    scores: &[f64],
    group: usize,
    stage1_k: usize,
    final_k: usize,
    scratch: &mut TopkScratch,
    out: &mut Vec<usize>,
) {
    let n = scores.len();
    assert_eq!(n % group, 0, "N={n} not a multiple of group={group}");
    scratch.candidates.clear();
    for t in 0..n / group {
        select_topk_into(scores, t * group..(t + 1) * group, stage1_k, &mut scratch.sel);
        // ascending within the tile so stage 2 sees globally ascending
        // candidates (its tie-break relies on arrival order)
        scratch.sel.sort_unstable();
        scratch.candidates.extend_from_slice(&scratch.sel);
    }
    out.clear();
    if scratch.candidates.len() <= final_k {
        out.extend_from_slice(&scratch.candidates);
    } else {
        select_topk_into(scores, scratch.candidates.iter().copied(), final_k, &mut scratch.sel);
        out.extend_from_slice(&scratch.sel);
        out.sort_unstable();
    }
}

/// Hierarchical two-stage top-k mask (Sec. III-C4).
pub fn two_stage_topk_mask(
    scores: &[f64],
    group: usize,
    stage1_k: usize,
    final_k: usize,
) -> Vec<bool> {
    let mut keep = vec![false; scores.len()];
    for i in two_stage_topk_indices(scores, group, stage1_k, final_k) {
        keep[i] = true;
    }
    keep
}

/// Single-stage global top-k mask (HAD baseline).
pub fn single_stage_topk_mask(scores: &[f64], final_k: usize) -> Vec<bool> {
    let mut keep = vec![false; scores.len()];
    for i in topk_indices(scores, final_k) {
        keep[i] = true;
    }
    keep
}

/// LUT softmax over masked scores with the 1/sqrt(d_k) scale (f32 math to
/// match the jnp oracle).
pub fn lut_softmax(scores: &[f64], mask: &[bool], d_k: usize) -> Vec<f32> {
    let scale = 1.0 / (d_k as f32).sqrt();
    let xs: Vec<f32> = scores
        .iter()
        .zip(mask)
        .map(|(&s, &m)| if m { s as f32 * scale } else { f32::NEG_INFINITY })
        .collect();
    let mx = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let es: Vec<f32> = xs
        .iter()
        .map(|&x| if x.is_finite() { (x - mx).exp() } else { 0.0 })
        .collect();
    let sum: f32 = es.iter().sum();
    es.iter().map(|&e| e / sum).collect()
}

/// Sparse LUT softmax: weights for the survivor rows only (`survivors`
/// ascending, as [`two_stage_topk_indices`] emits them), aligned with
/// `survivors`. Bit-identical to [`lut_softmax`] over the equivalent
/// mask at the survivor positions: a masked lane is -inf to the running
/// max (the identity) and exactly 0.0 to the f32 normaliser sum, and
/// adding 0.0 to the non-negative accumulator never changes a bit.
pub fn lut_softmax_sparse(scores: &[f64], survivors: &[usize], d_k: usize) -> Vec<f32> {
    let scale = 1.0 / (d_k as f32).sqrt();
    let mut mx = f32::NEG_INFINITY;
    for &i in survivors {
        mx = mx.max(scores[i] as f32 * scale);
    }
    let mut es: Vec<f32> = survivors
        .iter()
        .map(|&i| {
            let x = scores[i] as f32 * scale;
            if x.is_finite() { (x - mx).exp() } else { 0.0 }
        })
        .collect();
    let sum: f32 = es.iter().sum();
    for e in &mut es {
        *e /= sum;
    }
    es
}

/// Eq. 1 end to end. `v`: row-major N x d_v (d_v = d_k here). BF16
/// contextualization: inputs rounded to bf16, products in f32, f32
/// accumulation, result rounded to bf16 (XLA CPU bf16-matmul semantics).
pub fn camformer_attention(q: &[f32], k: &[f32], v: &[f32], cfg: &AttnConfig) -> Vec<f32> {
    let scores = bacam_scores_cfg(q, k, cfg.d_k, cfg.adc_bits);
    let mask = two_stage_topk_mask(&scores, cfg.group, cfg.stage1_k, cfg.final_k);
    let a = lut_softmax(&scores, &mask, cfg.d_k);
    weighted_sum_bf16(&a, v, cfg.n, cfg.d_k)
}

/// Single-stage (HAD) variant.
pub fn single_stage_attention(q: &[f32], k: &[f32], v: &[f32], cfg: &AttnConfig) -> Vec<f32> {
    let scores = bacam_scores_cfg(q, k, cfg.d_k, cfg.adc_bits);
    let mask = single_stage_topk_mask(&scores, cfg.final_k);
    let a = lut_softmax(&scores, &mask, cfg.d_k);
    weighted_sum_bf16(&a, v, cfg.n, cfg.d_k)
}

/// Exact FP32 softmax attention (oracle).
pub fn exact_attention(q: &[f32], k: &[f32], v: &[f32], n: usize, d_k: usize) -> Vec<f32> {
    let scale = 1.0 / (d_k as f32).sqrt();
    let mut scores = vec![0f32; n];
    for r in 0..n {
        let mut dot = 0f32;
        for c in 0..d_k {
            dot += q[c] * k[r * d_k + c];
        }
        scores[r] = dot * scale;
    }
    let mx = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let es: Vec<f32> = scores.iter().map(|&s| (s - mx).exp()).collect();
    let sum: f32 = es.iter().sum();
    let a: Vec<f32> = es.iter().map(|&e| e / sum).collect();
    let mut out = vec![0f32; d_k];
    for r in 0..n {
        for c in 0..d_k {
            out[c] += a[r] * v[r * d_k + c];
        }
    }
    out
}

fn weighted_sum_bf16(a: &[f32], v: &[f32], n: usize, d_v: usize) -> Vec<f32> {
    weighted_sum_bf16_prefix(a, v, n, d_v, n)
}

/// BF16 contextualization where rows at or beyond `valid_rows` read a
/// zero V row (what a sequential dispatch's pad rows hold) instead of
/// the buffer contents. A selected pad row still adds an explicit
/// `ar * 0.0` per lane so even the sign of a zero accumulator matches
/// sequential execution bit for bit.
fn weighted_sum_bf16_prefix(
    a: &[f32],
    v: &[f32],
    n: usize,
    d_v: usize,
    valid_rows: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; d_v];
    for r in 0..n {
        if a[r] == 0.0 {
            continue; // sparse: only top-k rows contribute
        }
        let ar = bf16::round(a[r]);
        if r >= valid_rows {
            for c in 0..d_v {
                out[c] += ar * 0.0;
            }
            continue;
        }
        for c in 0..d_v {
            out[c] += ar * bf16::round(v[r * d_v + c]);
        }
    }
    out.iter().map(|&x| bf16::round(x)).collect()
}

/// Sparse BF16 contextualization: gather only the survivor V rows
/// (`survivors` ascending, `weights` aligned with it) — O(k·d_v) per
/// query. Bit-identical to the dense prefix walk: non-survivors carry
/// weight exactly 0.0 there and are skipped by its `a[r] == 0.0` guard,
/// so both paths execute the same accumulations in the same order,
/// including the explicit `ar * 0.0` lane adds for selected pad rows at
/// or beyond `valid_rows`.
pub fn weighted_sum_bf16_sparse(
    weights: &[f32],
    survivors: &[usize],
    v: &[f32],
    d_v: usize,
    valid_rows: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; d_v];
    for (&w, &r) in weights.iter().zip(survivors) {
        if w == 0.0 {
            continue; // underflowed survivor: the dense path skips it too
        }
        let ar = bf16::round(w);
        if r >= valid_rows {
            for c in 0..d_v {
                out[c] += ar * 0.0;
            }
            continue;
        }
        let row = &v[r * d_v..(r + 1) * d_v];
        for c in 0..d_v {
            out[c] += ar * bf16::round(row[c]);
        }
    }
    out.iter().map(|&x| bf16::round(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::rng::Rng;

    fn cfg128() -> AttnConfig {
        AttnConfig::paper(128, 64)
    }

    #[test]
    fn scores_are_exact_binary_dots_at_dk64() {
        let mut rng = Rng::new(40);
        let q = rng.normal_vec(64);
        let k = rng.normal_vec(128 * 64);
        let s = bacam_scores(&q, &k, 64);
        let qb = binarize(&q);
        for (r, &sv) in s.iter().enumerate() {
            let mut dot = 0.0;
            for c in 0..64 {
                let kb = if k[r * 64 + c] >= 0.0 { 1.0 } else { -1.0 };
                dot += qb[c] as f64 * kb;
            }
            assert_eq!(sv, dot);
        }
    }

    #[test]
    fn all_three_scorers_agree() {
        crate::util::check::check("scorer implementations agree", 40, |rng| {
            let d_k = [16usize, 48, 64, 96, 128][rng.index(5)];
            let n = 1 + rng.index(64);
            let q = rng.normal_vec(d_k);
            let k = rng.normal_vec(n * d_k);
            let bits = [4u32, 6, 8][rng.index(3)];
            let fast = bacam_scores_cfg(&q, &k, d_k, bits);
            let float_ref = bacam_scores_float_reference(&q, &k, d_k, bits);
            let packed = PackedKeys::new(&k, d_k).scores(&q, bits);
            assert_eq!(fast, float_ref, "d_k={d_k} n={n} bits={bits}");
            assert_eq!(fast, packed, "d_k={d_k} n={n} bits={bits}");
        });
    }

    #[test]
    fn packed_attention_equals_unpacked() {
        let mut rng = Rng::new(45);
        let q = rng.normal_vec(64);
        let k = rng.normal_vec(512 * 64);
        let v = rng.normal_vec(512 * 64);
        let cfg = AttnConfig::paper(512, 64);
        let packed = PackedKeys::new(&k, 64);
        assert_eq!(
            camformer_attention(&q, &k, &v, &cfg),
            camformer_attention_packed(&q, &packed, &v, &cfg)
        );
    }

    #[test]
    fn property_incremental_packing_equals_full_repack() {
        // §Perf iteration 5: a memory grown row by row (all_pad +
        // set_row) and rolled back (pad_rows) must score bit-identically
        // to packing the equivalent buffer from scratch
        check("incremental packing = full repack", 40, |rng| {
            let d_k = [16usize, 48, 64, 96][rng.index(4)];
            let capacity = 4 + rng.index(28);
            let live = rng.index(capacity + 1);
            let k = rng.normal_vec(live * d_k);
            let mut inc = PackedKeys::all_pad(capacity, d_k);
            // over-fill, then roll the tail back to `live` rows
            let extra = rng.index(capacity - live + 1);
            for r in 0..live + extra {
                let row = if r < live {
                    k[r * d_k..(r + 1) * d_k].to_vec()
                } else {
                    rng.normal_vec(d_k)
                };
                inc.set_row(r, &row);
            }
            inc.pad_rows(live, live + extra);
            let mut k_pad = k.clone();
            k_pad.resize(capacity * d_k, 1.0); // KvStore::KEY_PAD
            let full = PackedKeys::new(&k_pad, d_k);
            let q = rng.normal_vec(d_k);
            assert_eq!(
                inc.scores(&q, 6),
                full.scores(&q, 6),
                "d_k={d_k} capacity={capacity} live={live} extra={extra}"
            );
        });
    }

    #[test]
    fn property_prefix_scores_match_literal_pad_rows() {
        // masking rows at/beyond the prefix analytically must be
        // bit-identical to scoring a buffer whose tail literally holds
        // the all-(+1) pad pattern, whatever the masked rows contain
        check("prefix scores = literal pad", 40, |rng| {
            let d_k = [16usize, 48, 64, 96][rng.index(4)];
            let n = 1 + rng.index(48);
            let prefix = rng.index(n + 1);
            let q = rng.normal_vec(d_k);
            let k = rng.normal_vec(n * d_k); // rows >= prefix: live garbage
            let mut k_pad = k.clone();
            for x in &mut k_pad[prefix * d_k..] {
                *x = 1.0; // KvStore::KEY_PAD
            }
            let bits = [4u32, 6, 8][rng.index(3)];
            let masked = PackedKeys::new(&k, d_k).scores_prefix(&q, bits, prefix);
            let literal = PackedKeys::new(&k_pad, d_k).scores(&q, bits);
            assert_eq!(masked, literal, "d_k={d_k} n={n} prefix={prefix}");
        });
    }

    #[test]
    fn property_prefix_attention_matches_literal_pad_buffer() {
        // end-to-end Eq. 1 over a prefix view == Eq. 1 over a buffer
        // with a literal pad tail (keys all +1, values all zero)
        check("prefix attention = literal pad", 30, |rng| {
            let d = 64usize;
            let n = 16 * (1 + rng.index(6));
            let prefix = rng.index(n + 1);
            let q = rng.normal_vec(d);
            let k = rng.normal_vec(n * d);
            let v = rng.normal_vec(n * d);
            let (mut k_pad, mut v_pad) = (k.clone(), v.clone());
            for x in &mut k_pad[prefix * d..] {
                *x = 1.0;
            }
            for x in &mut v_pad[prefix * d..] {
                *x = 0.0;
            }
            let cfg = AttnConfig::paper(n, d);
            let packed = PackedKeys::new(&k, d);
            assert_eq!(
                camformer_attention_packed_prefix(&q, &packed, &v, &cfg, prefix),
                camformer_attention(&q, &k_pad, &v_pad, &cfg),
                "n={n} prefix={prefix}"
            );
        });
    }

    #[test]
    fn property_survivor_list_matches_mask() {
        // the sparse survivor list and the dense mask are the same
        // selection in two encodings, and the list is ascending
        check("survivors = mask positions", 50, |rng| {
            let group = [8usize, 16, 32][rng.index(3)];
            let n = group * (1 + rng.index(16));
            let stage1_k = 1 + rng.index(3);
            let final_k = [4usize, 32, 64][rng.index(3)];
            let scores: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 8.0)).collect();
            let idx = two_stage_topk_indices(&scores, group, stage1_k, final_k);
            assert!(idx.windows(2).all(|w| w[0] < w[1]), "not ascending: {idx:?}");
            assert!(idx.len() <= final_k);
            let mask = two_stage_topk_mask(&scores, group, stage1_k, final_k);
            let from_mask: Vec<usize> = (0..n).filter(|&i| mask[i]).collect();
            assert_eq!(idx, from_mask, "group={group} stage1_k={stage1_k} final_k={final_k}");
        });
    }

    #[test]
    fn property_sparse_attention_bitwise_equals_dense() {
        // ISSUE 4 acceptance: the survivor-list pipeline is bit-identical
        // to the dense mask path over random shapes, prefix views and
        // degenerate all-pad prefixes
        check("sparse attention = dense attention", 40, |rng| {
            let d_k = [48usize, 64, 96][rng.index(3)];
            let group = 16usize;
            let n = group * [1usize, 3, 4, 7][rng.index(4)];
            let valid_rows = match rng.index(4) {
                0 => 0,
                1 => 1,
                2 => n,
                _ => rng.index(n + 1),
            };
            let q = rng.normal_vec(d_k);
            let k = rng.normal_vec(n * d_k);
            let v = rng.normal_vec(n * d_k);
            let cfg = AttnConfig::paper(n, d_k);
            let packed = PackedKeys::new(&k, d_k);
            let dense = camformer_attention_packed_prefix(&q, &packed, &v, &cfg, valid_rows);
            let mut scratch = AttnScratch::default();
            let sparse = camformer_attention_view_sparse(
                &q,
                &packed.view(n),
                &v,
                &cfg,
                valid_rows,
                &mut scratch,
            );
            assert_eq!(dense, sparse, "d_k={d_k} n={n} valid_rows={valid_rows}");
            assert!(scratch.survivors().len() <= cfg.final_k);
        });
    }

    #[test]
    fn sparse_scratch_is_stateless_across_calls() {
        // reusing one scratch across different queries/geometries must
        // not leak state between calls
        let mut rng = Rng::new(46);
        let mut scratch = AttnScratch::default();
        for n in [32usize, 128, 64] {
            let q = rng.normal_vec(64);
            let k = rng.normal_vec(n * 64);
            let v = rng.normal_vec(n * 64);
            let cfg = AttnConfig::paper(n, 64);
            let packed = PackedKeys::new(&k, 64);
            let reused =
                camformer_attention_view_sparse(&q, &packed.view(n), &v, &cfg, n, &mut scratch);
            let fresh = camformer_attention_view_sparse(
                &q,
                &packed.view(n),
                &v,
                &cfg,
                n,
                &mut AttnScratch::default(),
            );
            assert_eq!(reused, fresh, "n={n}");
        }
    }

    #[test]
    fn property_word_parallel_scores_match_scalar_bool_oracle() {
        // ISSUE 7 satellite: the u64 XOR+popcount path (incl. its
        // analytic pad handling) vs a per-bit scalar bool-loop oracle at
        // word boundaries (d_k 63/64/65) and tile boundaries (n 15/16/17),
        // including all-pad (valid=0) and single-valid-row prefixes
        check("u64 word scores = scalar bool oracle", 6, |rng| {
            for &d_k in &[48usize, 63, 64, 65, 96, 128] {
                for &n in &[1usize, 15, 16, 17, 3 * 16 + 7] {
                    let q = rng.normal_vec(d_k);
                    let k = rng.normal_vec(n * d_k);
                    let bits = [4u32, 6, 8][rng.index(3)];
                    let packed = PackedKeys::new(&k, d_k);
                    for valid in [0usize, 1, n, rng.index(n + 1)] {
                        let got = packed.scores_prefix(&q, bits, valid);
                        let want: Vec<f64> = (0..n)
                            .map(|r| {
                                let mut matches = 0u32;
                                for c in 0..d_k {
                                    let qb = q[c] >= 0.0;
                                    // rows at/beyond the prefix hold the
                                    // all-(+1) pad key
                                    let kb = r >= valid || k[r * d_k + c] >= 0.0;
                                    matches += (qb == kb) as u32;
                                }
                                quantize_matches(matches, d_k, bits)
                            })
                            .collect();
                        assert_eq!(got, want, "d_k={d_k} n={n} valid={valid} bits={bits}");
                    }
                }
            }
        });
    }

    #[test]
    fn property_fused_attention_bitwise_equals_dense() {
        // ISSUE 7 acceptance: the FlashCAM streaming pass is
        // bit-identical to the dense mask path (and the PR-4 sparse
        // pipeline) over random shapes, word-boundary widths, prefix
        // views and degenerate all-pad prefixes
        check("fused attention = dense attention", 40, |rng| {
            let d_k = [48usize, 63, 64, 65, 96, 128][rng.index(6)];
            let group = 16usize;
            let n = group * [1usize, 3, 4, 7][rng.index(4)];
            let valid_rows = match rng.index(4) {
                0 => 0,
                1 => 1,
                2 => n,
                _ => rng.index(n + 1),
            };
            let q = rng.normal_vec(d_k);
            let k = rng.normal_vec(n * d_k);
            let v = rng.normal_vec(n * d_k);
            let cfg = AttnConfig::paper(n, d_k);
            let packed = PackedKeys::new(&k, d_k);
            let dense = camformer_attention_packed_prefix(&q, &packed, &v, &cfg, valid_rows);
            let mut fused_scratch = FusedScratch::default();
            let fused = camformer_attention_view_fused(
                &q,
                &packed.view(n),
                &v,
                &cfg,
                valid_rows,
                &mut fused_scratch,
            );
            let sparse = camformer_attention_view_sparse(
                &q,
                &packed.view(n),
                &v,
                &cfg,
                valid_rows,
                &mut AttnScratch::default(),
            );
            assert_eq!(dense, fused, "d_k={d_k} n={n} valid_rows={valid_rows}");
            assert_eq!(sparse, fused, "d_k={d_k} n={n} valid_rows={valid_rows}");
            // work accounting: only live rows cost score words, every
            // 16-row tile streams exactly once
            let words = d_k.div_ceil(64) as u64;
            assert_eq!(fused_scratch.words_scored(), valid_rows as u64 * words);
            assert_eq!(fused_scratch.tiles_streamed(), (n / group) as u64);
            assert!(fused_scratch.survivors().len() <= cfg.final_k);
        });
    }

    #[test]
    fn fused_scratch_is_stateless_across_calls() {
        // one scratch reused across geometries/widths (LUT rebuilds, tile
        // buffer resizes, carried top-k resets) must match a fresh one
        let mut rng = Rng::new(48);
        let mut scratch = FusedScratch::default();
        for (n, d_k) in [(32usize, 64usize), (128, 96), (64, 63), (64, 64)] {
            let q = rng.normal_vec(d_k);
            let k = rng.normal_vec(n * d_k);
            let v = rng.normal_vec(n * d_k);
            let cfg = AttnConfig::paper(n, d_k);
            let packed = PackedKeys::new(&k, d_k);
            let reused =
                camformer_attention_view_fused(&q, &packed.view(n), &v, &cfg, n, &mut scratch);
            let fresh = camformer_attention_view_fused(
                &q,
                &packed.view(n),
                &v,
                &cfg,
                n,
                &mut FusedScratch::default(),
            );
            assert_eq!(reused, fresh, "n={n} d_k={d_k}");
        }
    }

    #[test]
    fn property_streaming_topk_matches_two_stage_selection() {
        // ISSUE 7 satellite: folding each tile's stage-1 winners into the
        // running threshold selects EXACTLY two_stage_topk_indices'
        // survivor set (ascending). Coarse integer scores make exact
        // ties — including ties at the admission threshold — frequent.
        check("streaming top-k = two-stage top-k", 60, |rng| {
            let group = 16usize;
            let n = group * (1 + rng.index(20));
            let stage1_k = 1 + rng.index(3);
            let final_k = [4usize, 8, 32][rng.index(3)];
            let scores: Vec<f64> = (0..n).map(|_| rng.range(0, 9) as f64 - 4.0).collect();
            let want = two_stage_topk_indices(&scores, group, stage1_k, final_k);
            let mut topk = StreamingTopk::default();
            topk.reset(final_k);
            let mut sel = Vec::new();
            for t in 0..n / group {
                let tile = &scores[t * group..(t + 1) * group];
                select_topk_into(tile, 0..group, stage1_k, &mut sel);
                sel.sort_unstable();
                for &i in &sel {
                    topk.offer(t * group + i, tile[i]);
                }
            }
            let mut got = topk.entries().to_vec();
            got.sort_unstable_by_key(|p| p.0);
            let got_rows: Vec<usize> = got.iter().map(|p| p.0).collect();
            assert_eq!(got_rows, want, "n={n} stage1_k={stage1_k} final_k={final_k}");
            // the carried scores are the source scores, bit for bit
            for &(i, s) in &got {
                assert_eq!(s, scores[i]);
            }
            // retained entries stay (score desc, index asc) — the shape
            // threshold() and the eviction correction rely on
            let e = topk.entries();
            for w in e.windows(2) {
                assert!(w[0].1 > w[1].1 || (w[0].1 == w[1].1 && w[0].0 < w[1].0));
            }
            if let Some(th) = topk.threshold() {
                assert_eq!(th, e[e.len() - 1].1);
            }
        });
    }

    #[test]
    fn streaming_topk_eviction_and_threshold_ties() {
        // later-tile eviction: strictly ascending scores mean every tile
        // after the selection fills evicts earlier tentative survivors
        let n = 64;
        let scores: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let mut topk = StreamingTopk::default();
        topk.reset(4);
        let mut sel = Vec::new();
        for t in 0..n / 16 {
            select_topk_into(&scores[t * 16..(t + 1) * 16], 0..16, 2, &mut sel);
            sel.sort_unstable();
            for &i in &sel {
                topk.offer(t * 16 + i, scores[t * 16 + i]);
            }
        }
        let mut rows: Vec<usize> = topk.entries().iter().map(|p| p.0).collect();
        rows.sort_unstable();
        assert_eq!(rows, two_stage_topk_indices(&scores, 16, 2, 4));
        assert_eq!(rows, vec![46, 47, 62, 63]);
        // tiles 3 and 4 each evicted both survivors of the filled buffer
        assert_eq!(topk.corrections(), 4);
        assert_eq!(topk.threshold(), Some(46.0));

        // tie at the threshold: with all-equal scores the first final_k
        // candidates are retained and every later tie is rejected
        // without a correction
        let flat = vec![1.5f64; n];
        topk.reset(4);
        for t in 0..n / 16 {
            select_topk_into(&flat[t * 16..(t + 1) * 16], 0..16, 2, &mut sel);
            sel.sort_unstable();
            for &i in &sel {
                topk.offer(t * 16 + i, flat[t * 16 + i]);
            }
        }
        let mut rows: Vec<usize> = topk.entries().iter().map(|p| p.0).collect();
        rows.sort_unstable();
        assert_eq!(rows, two_stage_topk_indices(&flat, 16, 2, 4));
        assert_eq!(rows, vec![0, 1, 16, 17]);
        assert_eq!(topk.corrections(), 0);
        assert_eq!(topk.threshold(), Some(1.5));
    }

    #[test]
    fn property_mask_counts() {
        check("two-stage mask count", 50, |rng| {
            let n = 16 * (1 + rng.index(64));
            let scores: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 10.0)).collect();
            let mask = two_stage_topk_mask(&scores, 16, 2, 32);
            let kept = mask.iter().filter(|&&b| b).count();
            let candidates = (n / 16) * 2;
            assert_eq!(kept, candidates.min(32));
        });
    }

    #[test]
    fn property_two_stage_subset_of_stage1() {
        check("stage2 subset", 50, |rng| {
            let n = 16 * (2 + rng.index(32));
            let scores: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 5.0)).collect();
            let keep = two_stage_topk_mask(&scores, 16, 2, 32);
            // every kept element is within the top-2 of its tile
            for t in 0..n / 16 {
                let tile = &scores[t * 16..(t + 1) * 16];
                let top2 = topk_indices(tile, 2);
                for i in 0..16 {
                    if keep[t * 16 + i] {
                        assert!(top2.contains(&i));
                    }
                }
            }
        });
    }

    #[test]
    fn softmax_sums_to_one_over_mask() {
        let mut rng = Rng::new(41);
        let scores: Vec<f64> = (0..128).map(|_| rng.range(0, 129) as f64 - 64.0).collect();
        let mask = two_stage_topk_mask(&scores, 16, 2, 32);
        let a = lut_softmax(&scores, &mask, 64);
        let sum: f32 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        for (p, m) in a.iter().zip(&mask) {
            if !m {
                assert_eq!(*p, 0.0);
            } else {
                assert!(*p > 0.0);
            }
        }
    }

    #[test]
    fn sparse_softmax_matches_dense_at_survivors() {
        let mut rng = Rng::new(47);
        let scores: Vec<f64> = (0..128).map(|_| rng.range(0, 129) as f64 - 64.0).collect();
        let survivors = two_stage_topk_indices(&scores, 16, 2, 32);
        let sparse = lut_softmax_sparse(&scores, &survivors, 64);
        let mask = two_stage_topk_mask(&scores, 16, 2, 32);
        let dense = lut_softmax(&scores, &mask, 64);
        assert_eq!(sparse.len(), survivors.len());
        for (&i, &w) in survivors.iter().zip(&sparse) {
            assert_eq!(w, dense[i], "survivor {i}");
        }
    }

    #[test]
    fn attention_output_in_v_hull() {
        let mut rng = Rng::new(42);
        let q = rng.normal_vec(64);
        let k = rng.normal_vec(128 * 64);
        let v = rng.normal_vec(128 * 64);
        let out = camformer_attention(&q, &k, &v, &cfg128());
        let vmax = v.iter().cloned().fold(f32::MIN, f32::max);
        let vmin = v.iter().cloned().fold(f32::MAX, f32::min);
        for &o in &out {
            assert!(o <= vmax + 0.05 && o >= vmin - 0.05);
        }
    }

    #[test]
    fn two_stage_equals_single_when_group_is_n() {
        let mut rng = Rng::new(43);
        let q = rng.normal_vec(64);
        let k = rng.normal_vec(256 * 64);
        let scores = bacam_scores(&q, &k, 64);
        let two = two_stage_topk_mask(&scores, 256, 32, 32);
        let one = single_stage_topk_mask(&scores, 32);
        assert_eq!(two, one);
    }

    #[test]
    fn camformer_tracks_exact_attention_direction() {
        // binarised sparse attention correlates with exact attention
        let mut rng = Rng::new(44);
        let q = rng.normal_vec(64);
        let k = rng.normal_vec(1024 * 64);
        let v = rng.normal_vec(1024 * 64);
        let cam = camformer_attention(&q, &k, &v, &AttnConfig::paper(1024, 64));
        let exact = exact_attention(&q, &k, &v, 1024, 64);
        let cam64: Vec<f64> = cam.iter().map(|&x| x as f64).collect();
        let ex64: Vec<f64> = exact.iter().map(|&x| x as f64).collect();
        let r = crate::util::stats::pearson(&cam64, &ex64);
        assert!(r > 0.3, "correlation {r} too weak");
    }

    #[test]
    fn stage1_k_one_can_lose_the_best_key() {
        // craft a tile whose two best scores both beat every other tile:
        // stage1_k=1 must drop the global #2
        let mut scores = vec![-10.0f64; 64];
        scores[3] = 60.0; // tile 0, global #1
        scores[5] = 58.0; // tile 0, global #2
        scores[20] = 10.0;
        let k1 = two_stage_topk_mask(&scores, 16, 1, 32);
        assert!(k1[3] && !k1[5], "stage1_k=1 must drop the in-tile runner-up");
        let k2 = two_stage_topk_mask(&scores, 16, 2, 32);
        assert!(k2[3] && k2[5]);
    }

    #[test]
    fn property_ties_break_to_lower_index() {
        check("tie break", 30, |rng| {
            let n = 64;
            let v = rng.range(0, 10) as f64;
            let scores = vec![v; n];
            let idx = topk_indices(&scores, 5);
            assert_eq!(idx, vec![0, 1, 2, 3, 4]);
            // the in-place survivor selection breaks ties the same way
            let surv = two_stage_topk_indices(&scores, 16, 2, 5);
            assert_eq!(surv, vec![0, 1, 16, 17, 32]);
        });
    }
}
