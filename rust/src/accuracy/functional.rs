//! The CAMformer attention datapath in pure Rust — Eq. 1 end to end.
//!
//! This is the behavioural twin of `python/compile/kernels/ref.py`; the
//! runtime integration tests assert the PJRT-executed Pallas artifacts,
//! this model and the jnp oracle all agree. It is also the model the
//! coordinator uses for golden checks on the serving path.

use crate::util::bf16;

/// Attention configuration (paper defaults via [`AttnConfig::paper`]).
#[derive(Clone, Copy, Debug)]
pub struct AttnConfig {
    pub n: usize,
    pub d_k: usize,
    /// Stage-1 group size g (= CAM_H).
    pub group: usize,
    /// Stage-1 top-k per group (the bitonic Top-2).
    pub stage1_k: usize,
    /// Final top-k (the Top-32 block).
    pub final_k: usize,
    pub adc_bits: u32,
}

impl AttnConfig {
    /// Eq. 1 defaults: g=16, top-2 per tile, Top-32 overall, 6-bit ADC.
    pub fn paper(n: usize, d_k: usize) -> Self {
        AttnConfig {
            n,
            d_k,
            group: 16,
            stage1_k: 2,
            final_k: 32,
            adc_bits: 6,
        }
    }
}

/// Sign-binarise to ±1 (zero maps to +1, matching ref.binarize).
pub fn binarize(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect()
}

/// BA-CAM association scores: binarise -> matchline -> 6-bit ADC.
/// `q`: d_k reals; `k`: row-major N x d_k. Output: N signed scores.
pub fn bacam_scores(q: &[f32], k: &[f32], d_k: usize) -> Vec<f64> {
    bacam_scores_cfg(q, k, d_k, 6)
}

/// As [`bacam_scores`] with explicit ADC resolution. One-shot hot path;
/// when the same K is scored repeatedly, use [`PackedKeys`] instead.
pub fn bacam_scores_cfg(q: &[f32], k: &[f32], d_k: usize, adc_bits: u32) -> Vec<f64> {
    assert_eq!(q.len(), d_k);
    assert_eq!(k.len() % d_k, 0);
    let n = k.len() / d_k;
    // branchless match count: one u8 equality per element, which the
    // autovectoriser turns into SIMD lanes (§Perf iteration 2 — the
    // per-call bit-packing of iteration 1 cost more than it saved)
    let q_sign: Vec<u8> = q.iter().map(|&x| (x >= 0.0) as u8).collect();
    let mut out = Vec::with_capacity(n);
    for r in 0..n {
        let row = &k[r * d_k..(r + 1) * d_k];
        let mut matches = 0u32;
        for (qs, &kv) in q_sign.iter().zip(row) {
            matches += (*qs == (kv >= 0.0) as u8) as u32;
        }
        out.push(quantize_matches(matches, d_k, adc_bits));
    }
    out
}

/// Shared SAR + multiply-subtract on an integer match count.
#[inline]
fn quantize_matches(matches: u32, d_k: usize, adc_bits: u32) -> f64 {
    let levels = (1u32 << adc_bits) as f64;
    let dot = 2.0 * matches as f64 - d_k as f64;
    let v = (dot + d_k as f64) / (2.0 * d_k as f64);
    let code = (v * levels).round().clamp(0.0, levels);
    2.0 * code * (d_k as f64 / levels) - d_k as f64
}

/// Sign-packed key memory: pack K once, score many queries with one
/// XNOR+popcount per 64 bits (§Perf iteration 3 — the serving path
/// reuses K across every request, so packing amortises to zero).
pub struct PackedKeys {
    pub n: usize,
    pub d_k: usize,
    words: usize,
    tail_mask: u64,
    bits: Vec<u64>, // row-major n x words
}

impl PackedKeys {
    pub fn new(k: &[f32], d_k: usize) -> Self {
        assert_eq!(k.len() % d_k, 0);
        let n = k.len() / d_k;
        let words = d_k.div_ceil(64);
        let mut bits = vec![0u64; n * words];
        for r in 0..n {
            pack_signs_into(&k[r * d_k..(r + 1) * d_k], &mut bits[r * words..(r + 1) * words]);
        }
        PackedKeys {
            n,
            d_k,
            words,
            tail_mask: if d_k % 64 == 0 { u64::MAX } else { (1u64 << (d_k % 64)) - 1 },
            bits,
        }
    }

    /// Scores for one query against the packed memory.
    pub fn scores(&self, q: &[f32], adc_bits: u32) -> Vec<f64> {
        self.scores_prefix(q, adc_bits, self.n)
    }

    /// As [`PackedKeys::scores`], but rows at or beyond `valid_rows` are
    /// scored as the pad pattern (all-(+1) keys, `KvStore::KEY_PAD`)
    /// regardless of what the packed buffer holds there. This is the
    /// speculative-fusion prefix contract: a fused decode burst applies
    /// every KV append up front, so the buffer behind an early step's
    /// view already holds that session's *later* keys — which that step,
    /// sequentially, would have seen as pre-written pad rows. A pad row
    /// matches exactly the query's non-negative lanes, so its score is
    /// computed analytically, bit-identical to packing a literal pad row.
    pub fn scores_prefix(&self, q: &[f32], adc_bits: u32, valid_rows: usize) -> Vec<f64> {
        assert_eq!(q.len(), self.d_k);
        assert!(valid_rows <= self.n, "prefix {valid_rows} beyond packed n {}", self.n);
        let qp = pack_signs(q, self.words);
        let mut out = Vec::with_capacity(self.n);
        for r in 0..valid_rows {
            let row = &self.bits[r * self.words..(r + 1) * self.words];
            let mut matches = 0u32;
            for w in 0..self.words {
                let mut eq = !(qp[w] ^ row[w]);
                if w == self.words - 1 {
                    eq &= self.tail_mask;
                }
                matches += eq.count_ones();
            }
            out.push(quantize_matches(matches, self.d_k, adc_bits));
        }
        if valid_rows < self.n {
            // an all-ones pad row turns !(qp ^ row) into qp itself, and
            // pack_signs never sets bits past d_k, so the match count is
            // just the query's non-negative-lane popcount
            let pad_matches: u32 = qp.iter().map(|w| w.count_ones()).sum();
            out.resize(self.n, quantize_matches(pad_matches, self.d_k, adc_bits));
        }
        out
    }
}

/// Eq. 1 against a pre-packed key memory (the serving hot path).
pub fn camformer_attention_packed(
    q: &[f32],
    keys: &PackedKeys,
    v: &[f32],
    cfg: &AttnConfig,
) -> Vec<f32> {
    camformer_attention_packed_prefix(q, keys, v, cfg, cfg.n)
}

/// Eq. 1 against a pre-packed key memory of which only the first
/// `valid_rows` rows are live for this query (its causal prefix under
/// speculative multi-step fusion). Rows at or beyond the prefix behave
/// exactly like the pre-written pad rows a sequential dispatch would
/// have seen there — pad-pattern scores, zero V contribution — so a
/// fused burst's per-step outputs are bit-equal to stepping one
/// dispatch at a time.
pub fn camformer_attention_packed_prefix(
    q: &[f32],
    keys: &PackedKeys,
    v: &[f32],
    cfg: &AttnConfig,
    valid_rows: usize,
) -> Vec<f32> {
    let scores = keys.scores_prefix(q, cfg.adc_bits, valid_rows);
    let mask = two_stage_topk_mask(&scores, cfg.group, cfg.stage1_k, cfg.final_k);
    let a = lut_softmax(&scores, &mask, cfg.d_k);
    weighted_sum_bf16_prefix(&a, v, cfg.n, cfg.d_k, valid_rows)
}

/// The pre-optimisation scorer (float inner product): kept as the §Perf
/// baseline and as an independent cross-check of the packed path.
pub fn bacam_scores_float_reference(q: &[f32], k: &[f32], d_k: usize, adc_bits: u32) -> Vec<f64> {
    assert_eq!(q.len(), d_k);
    let n = k.len() / d_k;
    let qb = binarize(q);
    let levels = (1u32 << adc_bits) as f64;
    (0..n)
        .map(|r| {
            let row = &k[r * d_k..(r + 1) * d_k];
            let mut dot = 0.0f64;
            for (a, &b) in qb.iter().zip(row) {
                let kb = if b >= 0.0 { 1.0 } else { -1.0 };
                dot += (*a as f64) * kb;
            }
            let v = (dot + d_k as f64) / (2.0 * d_k as f64);
            let code = (v * levels).round().clamp(0.0, levels);
            2.0 * code * (d_k as f64 / levels) - d_k as f64
        })
        .collect()
}

/// Pack sign bits (x >= 0 -> 1) into u64 words, LSB-first.
fn pack_signs(x: &[f32], words: usize) -> Vec<u64> {
    let mut out = vec![0u64; words];
    pack_signs_into(x, &mut out);
    out
}

fn pack_signs_into(x: &[f32], out: &mut [u64]) {
    for w in out.iter_mut() {
        *w = 0;
    }
    for (i, &v) in x.iter().enumerate() {
        // f32 sign-bit test: v >= 0 (incl. +0) iff sign bit clear — but
        // -0.0 must binarise to +1 like the jnp oracle's `where(x >= 0)`
        if v >= 0.0 {
            out[i / 64] |= 1u64 << (i % 64);
        }
    }
}

/// Stable top-k indices of `scores` (ties to the lower index, matching a
/// stable hardware sorter / jnp stable argsort).
///
/// §Perf: selection (`select_nth_unstable_by`) + sort of the k survivors
/// instead of a full sort — O(n + k log k); the (score desc, index asc)
/// comparator is a total order, so the result is identical to the stable
/// full sort it replaced.
pub fn topk_indices(scores: &[f64], k: usize) -> Vec<usize> {
    let n = scores.len();
    let k = k.min(n);
    let mut idx: Vec<usize> = (0..n).collect();
    let cmp = |&a: &usize, &b: &usize| {
        scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b))
    };
    if k > 0 && k < n {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_by(cmp);
    idx.truncate(k);
    idx
}

/// Hierarchical two-stage top-k mask (Sec. III-C4).
pub fn two_stage_topk_mask(
    scores: &[f64],
    group: usize,
    stage1_k: usize,
    final_k: usize,
) -> Vec<bool> {
    let n = scores.len();
    assert_eq!(n % group, 0, "N={n} not a multiple of group={group}");
    let mut survive = vec![false; n];
    for t in 0..n / group {
        let tile = &scores[t * group..(t + 1) * group];
        for i in topk_indices(tile, stage1_k) {
            survive[t * group + i] = true;
        }
    }
    // stage 2 over survivors
    let masked: Vec<f64> = scores
        .iter()
        .zip(&survive)
        .map(|(&s, &ok)| if ok { s } else { f64::NEG_INFINITY })
        .collect();
    let mut keep = vec![false; n];
    for i in topk_indices(&masked, final_k) {
        if survive[i] {
            keep[i] = true;
        }
    }
    keep
}

/// Single-stage global top-k mask (HAD baseline).
pub fn single_stage_topk_mask(scores: &[f64], final_k: usize) -> Vec<bool> {
    let mut keep = vec![false; scores.len()];
    for i in topk_indices(scores, final_k) {
        keep[i] = true;
    }
    keep
}

/// LUT softmax over masked scores with the 1/sqrt(d_k) scale (f32 math to
/// match the jnp oracle).
pub fn lut_softmax(scores: &[f64], mask: &[bool], d_k: usize) -> Vec<f32> {
    let scale = 1.0 / (d_k as f32).sqrt();
    let xs: Vec<f32> = scores
        .iter()
        .zip(mask)
        .map(|(&s, &m)| if m { s as f32 * scale } else { f32::NEG_INFINITY })
        .collect();
    let mx = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let es: Vec<f32> = xs
        .iter()
        .map(|&x| if x.is_finite() { (x - mx).exp() } else { 0.0 })
        .collect();
    let sum: f32 = es.iter().sum();
    es.iter().map(|&e| e / sum).collect()
}

/// Eq. 1 end to end. `v`: row-major N x d_v (d_v = d_k here). BF16
/// contextualization: inputs rounded to bf16, products in f32, f32
/// accumulation, result rounded to bf16 (XLA CPU bf16-matmul semantics).
pub fn camformer_attention(q: &[f32], k: &[f32], v: &[f32], cfg: &AttnConfig) -> Vec<f32> {
    let scores = bacam_scores_cfg(q, k, cfg.d_k, cfg.adc_bits);
    let mask = two_stage_topk_mask(&scores, cfg.group, cfg.stage1_k, cfg.final_k);
    let a = lut_softmax(&scores, &mask, cfg.d_k);
    weighted_sum_bf16(&a, v, cfg.n, cfg.d_k)
}

/// Single-stage (HAD) variant.
pub fn single_stage_attention(q: &[f32], k: &[f32], v: &[f32], cfg: &AttnConfig) -> Vec<f32> {
    let scores = bacam_scores_cfg(q, k, cfg.d_k, cfg.adc_bits);
    let mask = single_stage_topk_mask(&scores, cfg.final_k);
    let a = lut_softmax(&scores, &mask, cfg.d_k);
    weighted_sum_bf16(&a, v, cfg.n, cfg.d_k)
}

/// Exact FP32 softmax attention (oracle).
pub fn exact_attention(q: &[f32], k: &[f32], v: &[f32], n: usize, d_k: usize) -> Vec<f32> {
    let scale = 1.0 / (d_k as f32).sqrt();
    let mut scores = vec![0f32; n];
    for r in 0..n {
        let mut dot = 0f32;
        for c in 0..d_k {
            dot += q[c] * k[r * d_k + c];
        }
        scores[r] = dot * scale;
    }
    let mx = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let es: Vec<f32> = scores.iter().map(|&s| (s - mx).exp()).collect();
    let sum: f32 = es.iter().sum();
    let a: Vec<f32> = es.iter().map(|&e| e / sum).collect();
    let mut out = vec![0f32; d_k];
    for r in 0..n {
        for c in 0..d_k {
            out[c] += a[r] * v[r * d_k + c];
        }
    }
    out
}

fn weighted_sum_bf16(a: &[f32], v: &[f32], n: usize, d_v: usize) -> Vec<f32> {
    weighted_sum_bf16_prefix(a, v, n, d_v, n)
}

/// BF16 contextualization where rows at or beyond `valid_rows` read a
/// zero V row (what a sequential dispatch's pad rows hold) instead of
/// the buffer contents. A selected pad row still adds an explicit
/// `ar * 0.0` per lane so even the sign of a zero accumulator matches
/// sequential execution bit for bit.
fn weighted_sum_bf16_prefix(
    a: &[f32],
    v: &[f32],
    n: usize,
    d_v: usize,
    valid_rows: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; d_v];
    for r in 0..n {
        if a[r] == 0.0 {
            continue; // sparse: only top-k rows contribute
        }
        let ar = bf16::round(a[r]);
        if r >= valid_rows {
            for c in 0..d_v {
                out[c] += ar * 0.0;
            }
            continue;
        }
        for c in 0..d_v {
            out[c] += ar * bf16::round(v[r * d_v + c]);
        }
    }
    out.iter().map(|&x| bf16::round(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::rng::Rng;

    fn cfg128() -> AttnConfig {
        AttnConfig::paper(128, 64)
    }

    #[test]
    fn scores_are_exact_binary_dots_at_dk64() {
        let mut rng = Rng::new(40);
        let q = rng.normal_vec(64);
        let k = rng.normal_vec(128 * 64);
        let s = bacam_scores(&q, &k, 64);
        let qb = binarize(&q);
        for (r, &sv) in s.iter().enumerate() {
            let mut dot = 0.0;
            for c in 0..64 {
                let kb = if k[r * 64 + c] >= 0.0 { 1.0 } else { -1.0 };
                dot += qb[c] as f64 * kb;
            }
            assert_eq!(sv, dot);
        }
    }

    #[test]
    fn all_three_scorers_agree() {
        crate::util::check::check("scorer implementations agree", 40, |rng| {
            let d_k = [16usize, 48, 64, 96, 128][rng.index(5)];
            let n = 1 + rng.index(64);
            let q = rng.normal_vec(d_k);
            let k = rng.normal_vec(n * d_k);
            let bits = [4u32, 6, 8][rng.index(3)];
            let fast = bacam_scores_cfg(&q, &k, d_k, bits);
            let float_ref = bacam_scores_float_reference(&q, &k, d_k, bits);
            let packed = PackedKeys::new(&k, d_k).scores(&q, bits);
            assert_eq!(fast, float_ref, "d_k={d_k} n={n} bits={bits}");
            assert_eq!(fast, packed, "d_k={d_k} n={n} bits={bits}");
        });
    }

    #[test]
    fn packed_attention_equals_unpacked() {
        let mut rng = Rng::new(45);
        let q = rng.normal_vec(64);
        let k = rng.normal_vec(512 * 64);
        let v = rng.normal_vec(512 * 64);
        let cfg = AttnConfig::paper(512, 64);
        let packed = PackedKeys::new(&k, 64);
        assert_eq!(
            camformer_attention(&q, &k, &v, &cfg),
            camformer_attention_packed(&q, &packed, &v, &cfg)
        );
    }

    #[test]
    fn property_prefix_scores_match_literal_pad_rows() {
        // masking rows at/beyond the prefix analytically must be
        // bit-identical to scoring a buffer whose tail literally holds
        // the all-(+1) pad pattern, whatever the masked rows contain
        check("prefix scores = literal pad", 40, |rng| {
            let d_k = [16usize, 48, 64, 96][rng.index(4)];
            let n = 1 + rng.index(48);
            let prefix = rng.index(n + 1);
            let q = rng.normal_vec(d_k);
            let k = rng.normal_vec(n * d_k); // rows >= prefix: live garbage
            let mut k_pad = k.clone();
            for x in &mut k_pad[prefix * d_k..] {
                *x = 1.0; // KvStore::KEY_PAD
            }
            let bits = [4u32, 6, 8][rng.index(3)];
            let masked = PackedKeys::new(&k, d_k).scores_prefix(&q, bits, prefix);
            let literal = PackedKeys::new(&k_pad, d_k).scores(&q, bits);
            assert_eq!(masked, literal, "d_k={d_k} n={n} prefix={prefix}");
        });
    }

    #[test]
    fn property_prefix_attention_matches_literal_pad_buffer() {
        // end-to-end Eq. 1 over a prefix view == Eq. 1 over a buffer
        // with a literal pad tail (keys all +1, values all zero)
        check("prefix attention = literal pad", 30, |rng| {
            let d = 64usize;
            let n = 16 * (1 + rng.index(6));
            let prefix = rng.index(n + 1);
            let q = rng.normal_vec(d);
            let k = rng.normal_vec(n * d);
            let v = rng.normal_vec(n * d);
            let (mut k_pad, mut v_pad) = (k.clone(), v.clone());
            for x in &mut k_pad[prefix * d..] {
                *x = 1.0;
            }
            for x in &mut v_pad[prefix * d..] {
                *x = 0.0;
            }
            let cfg = AttnConfig::paper(n, d);
            let packed = PackedKeys::new(&k, d);
            assert_eq!(
                camformer_attention_packed_prefix(&q, &packed, &v, &cfg, prefix),
                camformer_attention(&q, &k_pad, &v_pad, &cfg),
                "n={n} prefix={prefix}"
            );
        });
    }

    #[test]
    fn property_mask_counts() {
        check("two-stage mask count", 50, |rng| {
            let n = 16 * (1 + rng.index(64));
            let scores: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 10.0)).collect();
            let mask = two_stage_topk_mask(&scores, 16, 2, 32);
            let kept = mask.iter().filter(|&&b| b).count();
            let candidates = (n / 16) * 2;
            assert_eq!(kept, candidates.min(32));
        });
    }

    #[test]
    fn property_two_stage_subset_of_stage1() {
        check("stage2 subset", 50, |rng| {
            let n = 16 * (2 + rng.index(32));
            let scores: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 5.0)).collect();
            let keep = two_stage_topk_mask(&scores, 16, 2, 32);
            // every kept element is within the top-2 of its tile
            for t in 0..n / 16 {
                let tile = &scores[t * 16..(t + 1) * 16];
                let top2 = topk_indices(tile, 2);
                for i in 0..16 {
                    if keep[t * 16 + i] {
                        assert!(top2.contains(&i));
                    }
                }
            }
        });
    }

    #[test]
    fn softmax_sums_to_one_over_mask() {
        let mut rng = Rng::new(41);
        let scores: Vec<f64> = (0..128).map(|_| rng.range(0, 129) as f64 - 64.0).collect();
        let mask = two_stage_topk_mask(&scores, 16, 2, 32);
        let a = lut_softmax(&scores, &mask, 64);
        let sum: f32 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        for (p, m) in a.iter().zip(&mask) {
            if !m {
                assert_eq!(*p, 0.0);
            } else {
                assert!(*p > 0.0);
            }
        }
    }

    #[test]
    fn attention_output_in_v_hull() {
        let mut rng = Rng::new(42);
        let q = rng.normal_vec(64);
        let k = rng.normal_vec(128 * 64);
        let v = rng.normal_vec(128 * 64);
        let out = camformer_attention(&q, &k, &v, &cfg128());
        let vmax = v.iter().cloned().fold(f32::MIN, f32::max);
        let vmin = v.iter().cloned().fold(f32::MAX, f32::min);
        for &o in &out {
            assert!(o <= vmax + 0.05 && o >= vmin - 0.05);
        }
    }

    #[test]
    fn two_stage_equals_single_when_group_is_n() {
        let mut rng = Rng::new(43);
        let q = rng.normal_vec(64);
        let k = rng.normal_vec(256 * 64);
        let scores = bacam_scores(&q, &k, 64);
        let two = two_stage_topk_mask(&scores, 256, 32, 32);
        let one = single_stage_topk_mask(&scores, 32);
        assert_eq!(two, one);
    }

    #[test]
    fn camformer_tracks_exact_attention_direction() {
        // binarised sparse attention correlates with exact attention
        let mut rng = Rng::new(44);
        let q = rng.normal_vec(64);
        let k = rng.normal_vec(1024 * 64);
        let v = rng.normal_vec(1024 * 64);
        let cam = camformer_attention(&q, &k, &v, &AttnConfig::paper(1024, 64));
        let exact = exact_attention(&q, &k, &v, 1024, 64);
        let cam64: Vec<f64> = cam.iter().map(|&x| x as f64).collect();
        let ex64: Vec<f64> = exact.iter().map(|&x| x as f64).collect();
        let r = crate::util::stats::pearson(&cam64, &ex64);
        assert!(r > 0.3, "correlation {r} too weak");
    }

    #[test]
    fn stage1_k_one_can_lose_the_best_key() {
        // craft a tile whose two best scores both beat every other tile:
        // stage1_k=1 must drop the global #2
        let mut scores = vec![-10.0f64; 64];
        scores[3] = 60.0; // tile 0, global #1
        scores[5] = 58.0; // tile 0, global #2
        scores[20] = 10.0;
        let k1 = two_stage_topk_mask(&scores, 16, 1, 32);
        assert!(k1[3] && !k1[5], "stage1_k=1 must drop the in-tile runner-up");
        let k2 = two_stage_topk_mask(&scores, 16, 2, 32);
        assert!(k2[3] && k2[5]);
    }

    #[test]
    fn property_ties_break_to_lower_index() {
        check("tie break", 30, |rng| {
            let n = 64;
            let v = rng.range(0, 10) as f64;
            let scores = vec![v; n];
            let idx = topk_indices(&scores, 5);
            assert_eq!(idx, vec![0, 1, 2, 3, 4]);
        });
    }
}
