//! Tables III & IV analogues (Sec. IV-D).
//!
//! Table III (DeiT/ImageNet in the paper) is *measured* here: the trained
//! tiny transformer runs through the PJRT classifier artifacts with
//! first-stage k in {1,2,4,8} and a single-stage Top-32 baseline, on the
//! associative-retrieval eval set (DESIGN.md substitution).
//!
//! Table IV (GLUE, 8 tasks) is *simulated*: a calibrated score-distribution
//! model maps two-stage recall loss to per-task accuracy deltas. The
//! calibration constant (accuracy sensitivity per unit recall loss) is the
//! only fitted quantity and is shared across tasks.

use super::recall;
use crate::util::rng::Rng;

/// The associative-retrieval corpus constants (mirror python/compile/data.py).
pub const N_KEYS: i32 = 16;
pub const N_CLASSES: i32 = 4;
pub const PAIR_BASE: i32 = 2;
pub const PROBE_BASE: i32 = PAIR_BASE + N_KEYS * N_CLASSES;

/// Sample one eval sequence; returns (tokens, label).
pub fn sample_sequence(seq_len: usize, rng: &mut Rng) -> (Vec<i32>, i32) {
    let kstar = rng.index(N_KEYS as usize) as i32;
    let vstar = rng.index(N_CLASSES as usize) as i32;
    let mut toks = Vec::with_capacity(seq_len);
    for _ in 0..seq_len - 1 {
        let mut key = rng.index((N_KEYS - 1) as usize) as i32;
        if key >= kstar {
            key += 1;
        }
        let val = rng.index(N_CLASSES as usize) as i32;
        toks.push(PAIR_BASE + key * N_CLASSES + val);
    }
    let pos = rng.index(seq_len - 1);
    toks[pos] = PAIR_BASE + kstar * N_CLASSES + vstar;
    toks.push(PROBE_BASE + kstar);
    (toks, vstar)
}

/// Measure accuracy of a classifier closure over `trials` sequences.
pub fn measure_accuracy<F>(mut classify: F, seq_len: usize, trials: usize, seed: u64) -> f64
where
    F: FnMut(&[i32]) -> Vec<f32>,
{
    let mut rng = Rng::new(seed);
    let mut correct = 0usize;
    for _ in 0..trials {
        let (toks, label) = sample_sequence(seq_len, &mut rng);
        let logits = classify(&toks);
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        if pred == label {
            correct += 1;
        }
    }
    correct as f64 / trials as f64
}

/// One Table IV row: a GLUE-style task in the calibrated simulation.
#[derive(Clone, Debug)]
pub struct GlueTask {
    pub name: &'static str,
    /// HAD single-stage baseline accuracy (from the paper's Table IV).
    pub baseline: f64,
    /// Sensitivity: accuracy points lost per 1% recall loss. GLUE heads
    /// average many tokens, so sensitivity is well under 1.
    pub sensitivity: f64,
}

/// The eight GLUE tasks with the paper's single-stage baselines.
pub fn glue_tasks() -> Vec<GlueTask> {
    vec![
        GlueTask { name: "MNLI", baseline: 82.45, sensitivity: 0.035 },
        GlueTask { name: "QQP", baseline: 90.11, sensitivity: 0.050 },
        GlueTask { name: "QNLI", baseline: 89.68, sensitivity: 0.030 },
        GlueTask { name: "SST-2", baseline: 91.63, sensitivity: 0.072 },
        GlueTask { name: "CoLA", baseline: 55.47, sensitivity: 0.118 },
        GlueTask { name: "STS-B", baseline: 87.46, sensitivity: 0.040 },
        GlueTask { name: "MRPC", baseline: 83.82, sensitivity: 0.010 },
        GlueTask { name: "RTE", baseline: 65.70, sensitivity: 0.230 },
    ]
}

/// Simulated Table IV: accuracy per task for a given first-stage k
/// (group = 16, N = 128 tokens typical for GLUE, Top-32 final).
///
/// Recall is softmax-mass-weighted over the *trained-attention* (peaked)
/// score model — the metric that actually drives downstream accuracy;
/// see `recall::weighted_recall_for_scores`.
pub fn table4_simulated(stage1_k: usize, seed: u64) -> Vec<(GlueTask, f64)> {
    let mut rng = Rng::new(seed);
    // GLUE sequences: ~128 tokens, 8 tiles of 16 => candidates 8*k1;
    // ~8 genuinely relevant keys per query after HAD training
    let recall =
        recall::monte_carlo_weighted_recall_realistic(128, 8, 16, stage1_k, 32, 400, &mut rng);
    let loss_pct = (1.0 - recall) * 100.0;
    glue_tasks()
        .into_iter()
        .map(|t| {
            let acc = t.baseline - t.sensitivity * loss_pct;
            (t, acc)
        })
        .collect()
}

/// Average over Table IV rows (the paper's "Avg" line).
pub fn table4_average(rows: &[(GlueTask, f64)]) -> f64 {
    rows.iter().map(|(_, a)| a).sum::<f64>() / rows.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_are_well_formed() {
        let mut rng = Rng::new(60);
        for _ in 0..50 {
            let (toks, label) = sample_sequence(512, &mut rng);
            assert_eq!(toks.len(), 512);
            assert!((0..N_CLASSES).contains(&label));
            // probe last; exactly one pair token with k*
            let probe = toks[511];
            assert!(probe >= PROBE_BASE && probe < PROBE_BASE + N_KEYS);
            let kstar = probe - PROBE_BASE;
            let target = toks[..511]
                .iter()
                .filter(|&&t| {
                    (t - PAIR_BASE) / N_CLASSES == kstar && t >= PAIR_BASE && t < PROBE_BASE
                })
                .count();
            assert_eq!(target, 1, "exactly one target pair");
            // and it encodes the label
            let tv = toks[..511]
                .iter()
                .find(|&&t| (t - PAIR_BASE) / N_CLASSES == kstar)
                .unwrap();
            assert_eq!((tv - PAIR_BASE) % N_CLASSES, label);
        }
    }

    #[test]
    fn measure_accuracy_of_oracle_is_one() {
        // a cheating classifier that scans the sequence itself
        let acc = measure_accuracy(
            |toks| {
                let kstar = toks[toks.len() - 1] - PROBE_BASE;
                let v = toks[..toks.len() - 1]
                    .iter()
                    .find(|&&t| (t - PAIR_BASE) / N_CLASSES == kstar)
                    .map(|&t| (t - PAIR_BASE) % N_CLASSES)
                    .unwrap_or(0);
                let mut logits = vec![0.0f32; N_CLASSES as usize];
                logits[v as usize] = 1.0;
                logits
            },
            256,
            100,
            7,
        );
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn measure_accuracy_of_random_is_chance() {
        let mut i = 0u64;
        let acc = measure_accuracy(
            |_toks| {
                i += 1;
                let mut l = vec![0.0f32; 4];
                l[(i % 4) as usize] = 1.0;
                l
            },
            128,
            400,
            8,
        );
        assert!((acc - 0.25).abs() < 0.08, "random acc {acc}");
    }

    #[test]
    fn table4_pattern_matches_paper() {
        // paper: k=4 within ~0.3 of baseline average, k=2 slightly worse,
        // both under 0.4% average degradation
        let base_avg = table4_average(
            &glue_tasks().into_iter().map(|t| { let b = t.baseline; (t, b) }).collect::<Vec<_>>(),
        );
        let k4 = table4_average(&table4_simulated(4, 1));
        let k2 = table4_average(&table4_simulated(2, 2));
        assert!(base_avg - k4 < 0.4, "k4 degradation {}", base_avg - k4);
        assert!(base_avg - k2 < 0.6, "k2 degradation {}", base_avg - k2);
        assert!(k4 >= k2 - 0.05, "k4 {k4} should be >= k2 {k2}");
    }

    #[test]
    fn table4_k1_degrades_visibly() {
        let base_avg = 80.81; // paper's HAD baseline average
        let k1 = table4_average(&table4_simulated(1, 3));
        assert!(base_avg - k1 > 0.3, "k1 should hurt: {}", base_avg - k1);
    }
}
