//! Algorithmic-accuracy layer (Sec. III-B1 recall bound, Sec. IV-D).
//!
//! * `functional` — the CAMformer attention datapath in pure Rust,
//!   numerically matched to the jnp oracle (`python/compile/kernels/ref.py`)
//!   and cross-checked against the PJRT artifacts in integration tests.
//! * `recall` — two-stage top-k recall: Monte-Carlo measurement plus the
//!   paper's Hoeffding drop bound and margin condition.
//! * `tables` — Tables III/IV analogues: the measured tiny-model experiment
//!   (via the PJRT classifier artifacts) and the calibrated score-
//!   distribution simulation for the GLUE-style multi-task sweep.

pub mod functional;
pub mod noise;
pub mod recall;
pub mod tables;

pub use functional::AttnConfig;
