//! A live serving session: one request stream's growing KV state on one
//! head worker.
//!
//! In the paper's deployment (Sec. III-A / IV-C) the XPU writes each
//! generated token's (k, v) into the accelerator-resident memory and the
//! next decode step searches the grown cache. `Session` is the serving
//! unit of that state: the coordinator keeps one per (session id, shard,
//! head) inside the owning worker thread, so all mutation is
//! single-threaded and lock-free.

use super::kv_store::KvStore;

/// Stable caller-chosen session identifier (also the shard-routing key).
pub type SessionId = u64;

/// Live per-(session, head) state owned by a worker thread.
#[derive(Clone, Debug)]
pub struct Session {
    pub id: SessionId,
    /// The capacity-provisioned KV memory (grows via `Decode` appends).
    pub store: KvStore,
}

impl Session {
    pub fn new(id: SessionId, store: KvStore) -> Self {
        Session { id, store }
    }

    /// Current context length (tokens resident in the KV cache).
    pub fn seq_len(&self) -> usize {
        self.store.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_store_growth() {
        let mut s = Session::new(3, KvStore::new(4, 2, 2));
        assert_eq!(s.seq_len(), 0);
        s.store.append(&[1.0, 2.0], &[3.0, 4.0]).unwrap();
        assert_eq!(s.seq_len(), 1);
        assert_eq!(s.id, 3);
    }
}
