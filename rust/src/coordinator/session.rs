//! A live serving session: one request stream's growing KV state on one
//! head worker.
//!
//! In the paper's deployment (Sec. III-A / IV-C) the XPU writes each
//! generated token's (k, v) into the accelerator-resident memory and the
//! next decode step searches the grown cache. `Session` is the serving
//! unit of that state: the coordinator keeps one per (session id, shard,
//! head) inside the owning worker thread, so all mutation is
//! single-threaded and lock-free.
//!
//! Since the session-handle API (ISSUE 5) the session also carries its
//! **lifecycle state**: a logical last-touch sequence number (the
//! worker's program-order clock — wall-clock-free, so LRU victim choice
//! is deterministic and batched execution stays bit-equal to
//! sequential), a wall-clock last-touch instant (only the
//! `ReclaimPolicy::LruEvictIdle` idle gate reads it), and a pin count
//! (> 0 while a dispatch group holds in-flight queries against the
//! store — a pinned session must never be evicted). The pin count is
//! defense-in-depth: the worker is single-threaded and eviction only
//! runs inside `Prefill` barrier groups, after any dispatch group has
//! unpinned, so the structural guarantee already holds; the count keeps
//! the invariant explicit (and checkable) if execution ever overlaps.

use std::time::{Duration, Instant};

use super::kv_store::KvStore;

/// Stable caller-chosen session identifier (also the shard-routing key).
pub type SessionId = u64;

/// Live per-(session, head) state owned by a worker thread.
#[derive(Clone, Debug)]
pub struct Session {
    pub id: SessionId,
    /// The capacity-provisioned KV memory (grows via `Decode` appends).
    pub store: KvStore,
    /// Program-order position of the last request that touched this
    /// session (the worker's logical clock) — the deterministic LRU key.
    pub last_touch_seq: u64,
    /// Wall-clock time of that touch, for the LRU policies' `min_idle`
    /// eligibility gate.
    pub last_touch_at: Instant,
    /// Shard-directory generation this local copy belongs to (ISSUE 8).
    /// The directory bumps a session's generation on every shard-level
    /// demote/drop decision; a worker whose local copy carries an older
    /// generation learns at its next reconcile that the copy is stale and
    /// must be released (drop) or parked in the spill pool (demote) —
    /// that lazy fan-out is what makes eviction atomic across heads.
    pub generation: u64,
    /// In-flight queries of the currently-executing dispatch group that
    /// attend over this store. Eviction must skip pinned sessions.
    pins: u32,
}

impl Session {
    pub fn new(id: SessionId, store: KvStore) -> Self {
        Session {
            id,
            store,
            last_touch_seq: 0,
            last_touch_at: Instant::now(),
            generation: 0,
            pins: 0,
        }
    }

    /// Current context length (tokens resident in the KV cache).
    pub fn seq_len(&self) -> usize {
        self.store.len()
    }

    /// This session's draw on the worker's shared KV row budget
    /// (`ServerConfig::worker_kv_budget`): the rows it holds resident.
    /// Admission charges a `Prefill` its row count (net of rows it
    /// replaces) and a `Decode` one row, which is exactly the delta of
    /// this accessor — summed across sessions it IS the pool occupancy.
    pub fn kv_rows(&self) -> usize {
        self.store.len()
    }

    /// Record a request touching this session at logical position `seq`.
    pub fn touch(&mut self, seq: u64) {
        self.last_touch_seq = seq;
        self.last_touch_at = Instant::now();
    }

    /// Wall-clock idle time since the last touch.
    pub fn idle_for(&self) -> Duration {
        self.last_touch_at.elapsed()
    }

    /// Pin for the duration of a dispatch (an in-flight query borrows a
    /// view of the store).
    pub fn pin(&mut self) {
        self.pins += 1;
    }

    /// Release one pin after its query's response is delivered.
    pub fn unpin(&mut self) {
        debug_assert!(self.pins > 0, "unpin without matching pin");
        self.pins = self.pins.saturating_sub(1);
    }

    /// Whether any dispatch-group query is currently in flight against
    /// this store (an eviction exclusion).
    pub fn is_pinned(&self) -> bool {
        self.pins > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_store_growth() {
        let mut s = Session::new(3, KvStore::new(4, 2, 2));
        assert_eq!(s.seq_len(), 0);
        assert_eq!(s.kv_rows(), 0);
        s.store.append(&[1.0, 2.0], &[3.0, 4.0]).unwrap();
        assert_eq!(s.seq_len(), 1);
        assert_eq!(s.kv_rows(), 1, "budget cost tracks resident rows");
        assert_eq!(s.id, 3);
    }

    #[test]
    fn touch_advances_lru_state() {
        let mut s = Session::new(1, KvStore::new(2, 2, 2));
        assert_eq!(s.last_touch_seq, 0);
        s.touch(7);
        assert_eq!(s.last_touch_seq, 7);
        s.touch(9);
        assert_eq!(s.last_touch_seq, 9);
        // idle_for is measured from the last touch and only grows
        let idle = s.idle_for();
        assert!(s.idle_for() >= idle);
    }

    #[test]
    fn pins_balance() {
        let mut s = Session::new(1, KvStore::new(2, 2, 2));
        assert!(!s.is_pinned());
        s.pin();
        s.pin();
        assert!(s.is_pinned());
        s.unpin();
        assert!(s.is_pinned());
        s.unpin();
        assert!(!s.is_pinned());
    }
}
