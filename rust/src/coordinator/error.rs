//! Typed error surface for the serving layer.
//!
//! Admission and execution failures used to be bare `String`s; callers
//! (examples, tests, a future RPC shell) need to branch on the cause, so
//! every way a request can fail is an explicit variant. Errors are
//! `Clone + PartialEq` because worker threads report them inside
//! `Response` values and tests assert on them structurally.

use std::fmt;

use super::session::SessionId;

/// Everything that can go wrong admitting or serving a request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Routed to a head the server was not configured with.
    UnknownHead { head: usize, heads: usize },
    /// Decode/Attend against a session that was never prefilled on this
    /// worker.
    UnknownSession { session: SessionId },
    /// Admission refused: the worker already holds its maximum number of
    /// live sessions.
    SessionLimit { max_sessions: usize },
    /// The session's provisioned KV context is exhausted (the paper sizes
    /// the BA-CAM/V arrays to the target maximum context; eviction is the
    /// caller's policy).
    CapacityExhausted { capacity: usize },
    /// A query / key / value had the wrong dimension.
    DimMismatch {
        what: &'static str,
        got: usize,
        want: usize,
    },
    /// The worker thread is gone (server shutting down).
    WorkerGone { worker: usize },
    /// The execution backend failed.
    Backend(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::UnknownHead { head, heads } => {
                write!(f, "no worker for head {head} (server has {heads} heads)")
            }
            ServeError::UnknownSession { session } => {
                write!(f, "session {session} does not exist on this worker (prefill first)")
            }
            ServeError::SessionLimit { max_sessions } => {
                write!(f, "admission refused: worker at its {max_sessions}-session limit")
            }
            ServeError::CapacityExhausted { capacity } => {
                write!(f, "provisioned KV capacity {capacity} exhausted")
            }
            ServeError::DimMismatch { what, got, want } => {
                write!(f, "{what}: dimension {got}, want {want}")
            }
            ServeError::WorkerGone { worker } => write!(f, "worker {worker} is gone"),
            ServeError::Backend(msg) => write!(f, "backend failure: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_are_actionable() {
        let cases: Vec<(ServeError, &str)> = vec![
            (ServeError::UnknownHead { head: 5, heads: 2 }, "head 5"),
            (ServeError::UnknownSession { session: 9 }, "session 9"),
            (ServeError::SessionLimit { max_sessions: 4 }, "4-session"),
            (ServeError::CapacityExhausted { capacity: 64 }, "capacity 64"),
            (
                ServeError::DimMismatch { what: "decode query", got: 3, want: 64 },
                "decode query",
            ),
            (ServeError::WorkerGone { worker: 1 }, "worker 1"),
            (ServeError::Backend("boom".into()), "boom"),
        ];
        for (e, needle) in cases {
            let s = e.to_string();
            assert!(s.contains(needle), "{s:?} missing {needle:?}");
        }
    }

    #[test]
    fn is_a_std_error() {
        fn takes_err<E: std::error::Error>(_: E) {}
        takes_err(ServeError::WorkerGone { worker: 0 });
    }
}
