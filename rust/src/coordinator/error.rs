//! Typed error surface for the serving layer.
//!
//! Admission and execution failures used to be bare `String`s; callers
//! (examples, tests, a future RPC shell) need to branch on the cause, so
//! every way a request can fail is an explicit variant. Errors are
//! `Clone + PartialEq` because worker threads report them inside
//! `Response` values and tests assert on them structurally.

use std::fmt;

use super::server::ReclaimPolicy;
use super::session::SessionId;

/// Everything that can go wrong admitting or serving a request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Routed to a head the server was not configured with.
    UnknownHead { head: usize, heads: usize },
    /// Decode/Attend against a session that was never prefilled on this
    /// worker.
    UnknownSession { session: SessionId },
    /// Admission refused: the worker already holds its maximum number of
    /// live sessions (and the reclaim policy found no evictable victim).
    SessionLimit { max_sessions: usize },
    /// The session was reclaimed by a `ReclaimPolicy` path that truly
    /// drops state (`LruEvictIdle`); its KV is gone. Re-`open`
    /// (re-prefill) to continue on this worker. Under
    /// `ReclaimPolicy::LruSpillToDram` a victim is *demoted* to the host
    /// tier and promoted back on its next request, so clients never see
    /// this variant from spill-tier reclaims.
    Evicted { session: SessionId },
    /// The session's resident KV died with a crashed worker incarnation.
    /// Unlike [`ServeError::Evicted`] (a deliberate reclaim-policy
    /// decision) the state is gone because the worker panicked outside a
    /// containable dispatch and was respawned by the supervisor; unlike
    /// [`ServeError::WorkerGone`] the head is *serving again* — only the
    /// sessions whose KV lived on the dead incarnation are lost.
    /// Retryable by re-`open` (re-prefill), never by bare retry. Sessions
    /// that were spilled to the DRAM tier at crash time are recovered
    /// byte-identically and never surface this variant.
    SessionLost { session: SessionId },
    /// The session's provisioned KV context is exhausted (the paper sizes
    /// the BA-CAM/V arrays to the target maximum context; eviction is the
    /// caller's policy).
    CapacityExhausted { capacity: usize },
    /// A query / key / value had the wrong dimension.
    DimMismatch {
        what: &'static str,
        got: usize,
        want: usize,
    },
    /// The worker thread is gone (server shutting down).
    WorkerGone { worker: usize },
    /// Overload shed: the target worker's standing queue is at
    /// `ServerConfig::max_queue`, so the request was refused at
    /// submission instead of queueing unboundedly. Retryable under every
    /// reclaim policy — the backlog drains as the scheduler dispatches.
    Overloaded { queue_depth: usize },
    /// The execution backend failed.
    Backend(String),
}

impl ServeError {
    /// Whether retrying the same request (possibly after a short wait)
    /// can succeed under the server's [`ReclaimPolicy`]:
    ///
    /// * `SessionLimit` / `CapacityExhausted` are terminal under
    ///   [`ReclaimPolicy::Deny`] (nothing ever frees capacity without
    ///   the caller closing sessions) but retryable under an eviction
    ///   policy, where idle sessions are reclaimed on demand. Caveat:
    ///   eviction frees *session slots*, so this applies to
    ///   admission-time failures (`open`/`Prefill`); a `Decode` that
    ///   exhausted its own session's provisioned context needs a
    ///   re-`open` with a shorter prompt or larger provisioning, not a
    ///   retry;
    /// * `Backend` is retryable everywhere: a failed dispatch rolls its
    ///   speculative appends back, so a retry never double-appends;
    /// * `Overloaded` is retryable under *both* policies: the standing
    ///   queue drains as the scheduler dispatches, so a backoff-and-retry
    ///   converges regardless of how session slots are reclaimed;
    /// * shape/routing errors (`DimMismatch`, `UnknownHead`) and
    ///   state-gone errors (`UnknownSession`, `Evicted`, `SessionLost`,
    ///   `WorkerGone`) need a different request (or a re-`open`), not a
    ///   retry. The three state-gone variants differ in *why* and in what
    ///   the re-open costs: `Evicted` is a reclaim-policy decision (the
    ///   server chose to drop the KV), `SessionLost` is a crash (the KV
    ///   died with a worker incarnation; the respawned worker accepts the
    ///   re-open immediately), and `WorkerGone` means the worker is still
    ///   dead (server shut down) so not even a re-open can succeed here.
    pub fn is_retryable(&self, policy: &ReclaimPolicy) -> bool {
        match self {
            ServeError::SessionLimit { .. } | ServeError::CapacityExhausted { .. } => {
                !matches!(policy, ReclaimPolicy::Deny)
            }
            ServeError::Backend(_) | ServeError::Overloaded { .. } => true,
            ServeError::UnknownHead { .. }
            | ServeError::UnknownSession { .. }
            | ServeError::Evicted { .. }
            | ServeError::SessionLost { .. }
            | ServeError::DimMismatch { .. }
            | ServeError::WorkerGone { .. } => false,
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::UnknownHead { head, heads } => {
                write!(f, "no worker for head {head} (server has {heads} heads)")
            }
            ServeError::UnknownSession { session } => {
                write!(f, "session {session} does not exist on this worker (prefill first)")
            }
            ServeError::SessionLimit { max_sessions } => {
                write!(f, "admission refused: worker at its {max_sessions}-session limit")
            }
            ServeError::Evicted { session } => {
                write!(f, "session {session} was evicted to reclaim capacity (re-open to continue)")
            }
            ServeError::SessionLost { session } => {
                write!(f, "session {session} was lost to a worker crash (re-open to continue)")
            }
            ServeError::CapacityExhausted { capacity } => {
                write!(f, "provisioned KV capacity {capacity} exhausted")
            }
            ServeError::DimMismatch { what, got, want } => {
                write!(f, "{what}: dimension {got}, want {want}")
            }
            ServeError::WorkerGone { worker } => write!(f, "worker {worker} is gone"),
            ServeError::Overloaded { queue_depth } => {
                write!(f, "worker overloaded: {queue_depth} requests queued (back off and retry)")
            }
            ServeError::Backend(msg) => write!(f, "backend failure: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_are_actionable() {
        let cases: Vec<(ServeError, &str)> = vec![
            (ServeError::UnknownHead { head: 5, heads: 2 }, "head 5"),
            (ServeError::UnknownSession { session: 9 }, "session 9"),
            (ServeError::SessionLimit { max_sessions: 4 }, "4-session"),
            (ServeError::Evicted { session: 8 }, "session 8 was evicted"),
            (ServeError::SessionLost { session: 8 }, "session 8 was lost to a worker crash"),
            (ServeError::CapacityExhausted { capacity: 64 }, "capacity 64"),
            (
                ServeError::DimMismatch { what: "decode query", got: 3, want: 64 },
                "decode query",
            ),
            (ServeError::WorkerGone { worker: 1 }, "worker 1"),
            (ServeError::Overloaded { queue_depth: 128 }, "128 requests queued"),
            (ServeError::Backend("boom".into()), "boom"),
        ];
        for (e, needle) in cases {
            let s = e.to_string();
            assert!(s.contains(needle), "{s:?} missing {needle:?}");
        }
    }

    /// The two errors a well-behaved client loop must branch on —
    /// `Evicted` (re-open, don't retry) and `Overloaded` (back off, do
    /// retry) — round-trip structurally: the payload survives a clone,
    /// compares equal, and the Display string carries the payload so a
    /// logged error is enough to reconstruct what happened.
    #[test]
    fn evicted_and_overloaded_round_trip() {
        let ev = ServeError::Evicted { session: 42 };
        let ov = ServeError::Overloaded { queue_depth: 7 };
        assert_eq!(ev.clone(), ev);
        assert_eq!(ov.clone(), ov);
        assert_ne!(ev, ov);
        assert_ne!(ov, ServeError::Overloaded { queue_depth: 8 });
        assert!(ev.to_string().contains("42"));
        assert!(ov.to_string().contains('7'));
        // the payload is recoverable by matching, not just by Display
        match ov {
            ServeError::Overloaded { queue_depth } => assert_eq!(queue_depth, 7),
            other => panic!("wrong variant: {other}"),
        }
    }

    #[test]
    fn is_a_std_error() {
        fn takes_err<E: std::error::Error>(_: E) {}
        takes_err(ServeError::WorkerGone { worker: 0 });
    }

    #[test]
    fn retryability_depends_on_the_reclaim_policy() {
        use std::time::Duration;
        let deny = ReclaimPolicy::Deny;
        let lru = ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO };
        let spill = ReclaimPolicy::LruSpillToDram { min_idle: Duration::ZERO };
        // capacity errors: terminal under Deny, retryable under any
        // reclaiming policy (drop or demote both free capacity on demand)
        for e in [
            ServeError::SessionLimit { max_sessions: 4 },
            ServeError::CapacityExhausted { capacity: 64 },
        ] {
            assert!(!e.is_retryable(&deny), "{e}");
            assert!(e.is_retryable(&lru), "{e}");
            assert!(e.is_retryable(&spill), "{e}");
        }
        // a failed dispatch rolled its state back: always safe to retry
        assert!(ServeError::Backend("boom".into()).is_retryable(&deny));
        // overload shed: the standing queue drains regardless of how
        // session slots are reclaimed, so retry is sound under BOTH policies
        let shed = ServeError::Overloaded { queue_depth: 64 };
        assert!(shed.is_retryable(&deny), "{shed}");
        assert!(shed.is_retryable(&lru), "{shed}");
        // shape, routing and state-gone errors are never retryable
        for e in [
            ServeError::DimMismatch { what: "query", got: 3, want: 64 },
            ServeError::UnknownHead { head: 5, heads: 2 },
            ServeError::UnknownSession { session: 9 },
            ServeError::Evicted { session: 9 },
            ServeError::SessionLost { session: 9 },
            ServeError::WorkerGone { worker: 0 },
        ] {
            assert!(!e.is_retryable(&deny), "{e}");
            assert!(!e.is_retryable(&lru), "{e}");
        }
    }
}
