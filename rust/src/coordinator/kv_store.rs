//! Per-head key/value memory: the serving-level view of the Key SRAM and
//! the V tensor in DRAM (Sec. III-A / IV-C).
//!
//! Decoder-style usage appends one (k, v) pair per generated token — "CAM
//! search over a growing KV cache each step (causal)". The store is
//! capacity-bounded to the provisioned BA-CAM/V-SRAM size.
//!
//! §Perf: the buffers are allocated at full capacity up front with the
//! padding pattern pre-written, so `append` is a row copy and
//! [`KvStore::padded`] hands the execution layer a borrowed prefix — the
//! decode hot path never clones the cache (the seed implementation
//! re-cloned and re-padded the whole K/V on every step).
//!
//! §Perf iteration 5 (ISSUE 4): the store also owns the **sign-packed key
//! bits** the BA-CAM scorer consumes, maintained *incrementally*: `append`
//! packs exactly the one new row (O(d_k)), `load` packs the loaded rows,
//! and `truncate` (speculative rollback) restores the pad pattern over the
//! rolled-back rows. [`KvStore::packed_view`] hands backends a borrowed
//! [`PackedKeysView`] over the same buffer every execution view shares, so
//! the previous per-mutation full re-pack (`AttentionBackend::on_kv_update`
//! busting a backend-side cache, then an O(n·d_k) re-pack before the next
//! attend) is gone from the decode hot path. `packed_rows_total` counts
//! rows packed since creation — the long-context bench pins "one append
//! packs one row" with it.
//!
//! Cross-session batched decode leans on disjoint ownership: one dispatch
//! group borrows the padded views of *several* stores at once (they are
//! disjoint allocations, all owned by one worker).
//!
//! Speculative multi-step fusion adds the third view kind: a fused burst
//! applies every step's append up front, then each step attends over
//! [`KvStore::padded_prefix_view`] — the causal prefix at its own program
//! position, with the later appends still resident behind it (and
//! [`KvStore::truncate`] rolls them back if the dispatch fails).
//!
//! [`PackedKeysView`]: crate::accuracy::functional::PackedKeysView

use super::error::ServeError;
use crate::accuracy::functional::{PackedKeys, PackedKeysView};

/// Padding element for key rows: all-(+1) rows score mid-range against
/// random real keys, and their V rows are zero, so an accidentally
/// selected pad contributes nothing to the output.
pub const KEY_PAD: f32 = 1.0;

/// Per-session, per-head K/V memory.
#[derive(Clone, Debug)]
pub struct KvStore {
    pub d_k: usize,
    pub d_v: usize,
    /// Provisioned maximum context (BA-CAM + V sizing).
    pub capacity: usize,
    keys: Vec<f32>,   // capacity x d_k, rows >= len hold KEY_PAD
    values: Vec<f32>, // capacity x d_v, rows >= len hold 0.0
    /// Sign-packed mirror of `keys` (all capacity rows, pad rows hold the
    /// packed pad pattern), maintained incrementally on every mutation.
    packed: PackedKeys,
    packed_rows_total: u64,
    len: usize,
}

impl KvStore {
    pub fn new(capacity: usize, d_k: usize, d_v: usize) -> Self {
        KvStore {
            d_k,
            d_v,
            capacity,
            keys: vec![KEY_PAD; capacity * d_k],
            values: vec![0.0; capacity * d_v],
            packed: PackedKeys::all_pad(capacity, d_k),
            packed_rows_total: 0,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append one (key, value) row. Errors when the provisioned context is
    /// exhausted (the caller decides eviction policy — the paper sizes the
    /// arrays to the target maximum context). Packs exactly the one new
    /// row into the store-owned key bits — O(d_k), never a full re-pack.
    pub fn append(&mut self, key: &[f32], value: &[f32]) -> Result<(), ServeError> {
        if key.len() != self.d_k {
            return Err(ServeError::DimMismatch { what: "key", got: key.len(), want: self.d_k });
        }
        if value.len() != self.d_v {
            return Err(ServeError::DimMismatch { what: "value", got: value.len(), want: self.d_v });
        }
        if self.len >= self.capacity {
            return Err(ServeError::CapacityExhausted { capacity: self.capacity });
        }
        let (kd, vd) = (self.d_k, self.d_v);
        self.keys[self.len * kd..(self.len + 1) * kd].copy_from_slice(key);
        self.values[self.len * vd..(self.len + 1) * vd].copy_from_slice(value);
        self.packed.set_row(self.len, key);
        self.packed_rows_total += 1;
        self.len += 1;
        Ok(())
    }

    /// Bulk-load a prefill / encoder-style memory (replaces contents).
    pub fn load(&mut self, keys: &[f32], values: &[f32]) -> Result<(), ServeError> {
        if keys.len() % self.d_k != 0 {
            return Err(ServeError::DimMismatch { what: "keys", got: keys.len(), want: self.d_k });
        }
        if values.len() % self.d_v != 0 {
            return Err(ServeError::DimMismatch {
                what: "values",
                got: values.len(),
                want: self.d_v,
            });
        }
        let n = keys.len() / self.d_k;
        if n != values.len() / self.d_v {
            return Err(ServeError::DimMismatch {
                what: "K/V row count",
                got: values.len() / self.d_v,
                want: n,
            });
        }
        if n > self.capacity {
            return Err(ServeError::CapacityExhausted { capacity: self.capacity });
        }
        self.keys[..keys.len()].copy_from_slice(keys);
        self.values[..values.len()].copy_from_slice(values);
        for r in 0..n {
            self.packed.set_row(r, &keys[r * self.d_k..(r + 1) * self.d_k]);
        }
        self.packed_rows_total += n as u64;
        // restore the padding pattern over rows [n, old_len)
        let repad_to = self.len.max(n);
        for x in &mut self.keys[n * self.d_k..repad_to * self.d_k] {
            *x = KEY_PAD;
        }
        for x in &mut self.values[n * self.d_v..repad_to * self.d_v] {
            *x = 0.0;
        }
        self.packed.pad_rows(n, repad_to);
        self.len = n;
        Ok(())
    }

    /// Zero-copy execution view padded to `pad_to` rows (the decode hot
    /// path). Requires `len <= pad_to <= capacity`; the pad rows are
    /// pre-written, so this is a pure borrow.
    pub fn padded(&self, pad_to: usize) -> (&[f32], &[f32], usize) {
        self.padded_prefix_view(self.len, pad_to)
    }

    /// Length-bounded execution view for speculative multi-step fusion:
    /// the first `prefix` rows are the causal prefix one query is allowed
    /// to see, and the slices run out to `pad_to` rows. Requires
    /// `prefix <= len` and `prefix <= pad_to <= capacity`; still a pure
    /// borrow.
    ///
    /// When `prefix < len` (a fused burst applied later appends already),
    /// the rows in `[prefix, len)` hold live data, NOT the pad pattern —
    /// the consumer must honour the prefix, either natively
    /// (`AttentionBackend::supports_prefix_views`) or by letting the
    /// serving layer materialise a literal-pad copy. `padded` is the
    /// full-prefix special case.
    pub fn padded_prefix_view(&self, prefix: usize, pad_to: usize) -> (&[f32], &[f32], usize) {
        assert!(prefix <= self.len, "prefix {prefix} beyond live length {}", self.len);
        assert!(
            pad_to >= prefix && pad_to <= self.capacity,
            "pad_to {pad_to} outside [{prefix}, {}]",
            self.capacity
        );
        (
            &self.keys[..pad_to * self.d_k],
            &self.values[..pad_to * self.d_v],
            prefix,
        )
    }

    /// The store-owned sign-packed key bits of the same `pad_to`-row
    /// execution geometry as [`KvStore::padded`] /
    /// [`KvStore::padded_prefix_view`] — what `AttendItem::packed`
    /// carries so backends score without re-packing. Like the f32 views
    /// it exposes whatever is resident: rows in `[prefix, len)` hold live
    /// speculative appends (the scorer masks them per item via its
    /// `valid_rows` argument), rows at or beyond `len` hold the packed
    /// pad pattern.
    pub fn packed_view(&self, pad_to: usize) -> PackedKeysView<'_> {
        assert!(pad_to <= self.capacity, "pad_to {pad_to} beyond capacity {}", self.capacity);
        self.packed.view(pad_to)
    }

    /// Rows packed into the store-owned key bits since creation: one per
    /// appended/loaded row, never O(n) per mutation (asserted by the
    /// long-context hot-path bench).
    pub fn packed_rows_total(&self) -> u64 {
        self.packed_rows_total
    }

    /// Roll back to `len` rows (the failed-dispatch path of speculative
    /// fusion): discards rows `[len, self.len)` and restores the padding
    /// pattern — f32 and packed-bit — over them so later `padded*` views
    /// stay pure borrows.
    pub fn truncate(&mut self, len: usize) {
        assert!(len <= self.len, "truncate to {len} beyond live length {}", self.len);
        for x in &mut self.keys[len * self.d_k..self.len * self.d_k] {
            *x = KEY_PAD;
        }
        for x in &mut self.values[len * self.d_v..self.len * self.d_v] {
            *x = 0.0;
        }
        self.packed.pad_rows(len, self.len);
        self.len = len;
    }

    /// Release the provisioned buffers (session close / LRU eviction):
    /// consumes the store — the K/V buffers and packed key bits are
    /// freed here, not lazily at some later drop — and returns the
    /// provisioned row capacity reclaimed, which the serving layer
    /// accounts in `Metrics::kv_rows_released`.
    pub fn release(self) -> usize {
        // moving `self` in drops keys/values/packed right now; returning
        // the capacity first makes the reclaimed provisioning explicit
        self.capacity
    }

    /// The valid (unpadded) key rows.
    pub fn keys(&self) -> &[f32] {
        &self.keys[..self.len * self.d_k]
    }

    /// The valid (unpadded) value rows.
    pub fn values(&self) -> &[f32] {
        &self.values[..self.len * self.d_v]
    }

    /// Demote the store to the simulated host (DRAM spill) tier: consumes
    /// the accelerator-resident provisioning — the shared budget accounting
    /// treats this exactly like [`KvStore::release`] — and captures keys,
    /// values, AND the sign-packed key bits verbatim, so a later
    /// [`SpilledKv::restore`] is byte-identical and the promoted session
    /// never re-packs.
    pub fn demote(self) -> SpilledKv {
        SpilledKv { store: self }
    }
}

/// A session's KV memory demoted out of the accelerator tier into the
/// simulated host DRAM (the shard directory's spill pool). It no longer
/// counts against `ServerConfig::worker_kv_budget` — the writeback and the
/// later promotion are charged through the `dram::channel` model instead —
/// but stays addressable by session id so the victim's next request
/// promotes it back rather than observing `ServeError::Evicted`.
///
/// The pool that holds these lives in the shard directory, *outside*
/// every worker thread — so parked copies survive a worker crash and
/// promote byte-identically onto the respawned incarnation (ISSUE 9's
/// crash-durability tier).
#[derive(Clone, Debug)]
pub struct SpilledKv {
    store: KvStore,
}

impl SpilledKv {
    /// Live rows held in the spill tier.
    pub fn len(&self) -> usize {
        self.store.len
    }

    pub fn is_empty(&self) -> bool {
        self.store.len == 0
    }

    /// Row capacity the store will re-provision on promotion (what the
    /// admission path must find room for in the shared KV budget).
    pub fn capacity(&self) -> usize {
        self.store.capacity
    }

    /// Payload bytes a demotion writes / a promotion reads through the
    /// DRAM channel model: the live f32 K/V rows plus their packed key
    /// words (pad rows are reconstructed, not transferred).
    pub fn bytes(&self) -> usize {
        let words = self.store.d_k.div_ceil(64);
        self.store.len * (self.store.d_k + self.store.d_v) * 4 + self.store.len * words * 8
    }

    /// Promote back into the accelerator tier: returns the store exactly
    /// as demoted — same keys, values, packed bits, length, and capacity.
    pub fn restore(self) -> KvStore {
        self.store
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::rng::Rng;

    #[test]
    fn append_grows_until_capacity() {
        let mut s = KvStore::new(3, 4, 4);
        let row = vec![1.0f32; 4];
        assert!(s.append(&row, &row).is_ok());
        assert!(s.append(&row, &row).is_ok());
        assert!(s.append(&row, &row).is_ok());
        assert_eq!(s.len(), 3);
        assert_eq!(
            s.append(&row, &row),
            Err(ServeError::CapacityExhausted { capacity: 3 })
        );
    }

    #[test]
    fn dim_checked() {
        let mut s = KvStore::new(3, 4, 4);
        assert!(s.append(&[1.0; 3], &[1.0; 4]).is_err());
        assert!(s.append(&[1.0; 4], &[1.0; 5]).is_err());
    }

    #[test]
    fn load_replaces_and_repads() {
        let mut s = KvStore::new(8, 2, 2);
        // occupy 3 rows, then load 2: row 2 must be re-padded
        for _ in 0..3 {
            s.append(&[9.0, 9.0], &[8.0, 8.0]).unwrap();
        }
        let k: Vec<f32> = (0..4).map(|x| x as f32).collect();
        let v: Vec<f32> = (0..4).map(|x| -(x as f32)).collect();
        s.load(&k, &v).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.keys(), &k[..]);
        let (kp, vp, n) = s.padded(4);
        assert_eq!(n, 2);
        assert!(kp[2 * 2..].iter().all(|&x| x == KEY_PAD));
        assert!(vp[2 * 2..].iter().all(|&x| x == 0.0));
        assert!(s.load(&vec![0.0; 2 * 9], &vec![0.0; 2 * 9]).is_err());
    }

    #[test]
    fn prefix_view_bounds_and_content() {
        let mut s = KvStore::new(8, 2, 2);
        for i in 0..5 {
            s.append(&[i as f32; 2], &[-(i as f32); 2]).unwrap();
        }
        // prefix 3 padded to 8: the first 3 rows are the causal prefix;
        // rows 3..5 expose the speculative appends, rows 5..8 the pad
        let (k, v, n) = s.padded_prefix_view(3, 8);
        assert_eq!(n, 3);
        assert_eq!(k.len(), 16);
        assert_eq!(&k[..6], &[0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        assert_eq!(&k[6..10], &[3.0, 3.0, 4.0, 4.0]);
        assert!(k[10..].iter().all(|&x| x == KEY_PAD));
        assert!(v[10..].iter().all(|&x| x == 0.0));
        // padded() is the full-prefix special case
        assert_eq!(s.padded(8), s.padded_prefix_view(5, 8));
    }

    #[test]
    #[should_panic(expected = "beyond live length")]
    fn prefix_view_beyond_live_length_panics() {
        KvStore::new(4, 2, 2).padded_prefix_view(1, 4);
    }

    #[test]
    fn truncate_restores_pad_pattern() {
        let mut s = KvStore::new(4, 2, 2);
        for _ in 0..3 {
            s.append(&[9.0, 9.0], &[8.0, 8.0]).unwrap();
        }
        s.truncate(1);
        assert_eq!(s.len(), 1);
        let (k, v, n) = s.padded(4);
        assert_eq!(n, 1);
        assert_eq!(&k[..2], &[9.0, 9.0]);
        assert!(k[2..].iter().all(|&x| x == KEY_PAD));
        assert!(v[2..].iter().all(|&x| x == 0.0));
        // a rolled-back row can be re-appended
        s.append(&[1.0, 2.0], &[3.0, 4.0]).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(&s.keys()[2..], &[1.0, 2.0]);
    }

    #[test]
    fn property_packed_view_matches_full_repack_of_padded_buffer() {
        // the store-owned bits must stay bit-equivalent to packing the
        // padded f32 view from scratch, across every mutation kind
        use crate::accuracy::functional::PackedKeys;
        check("store packed bits = full repack", 30, |rng| {
            let d_k = [16usize, 48, 64][rng.index(3)];
            let capacity = 8 + rng.index(24);
            let mut s = KvStore::new(capacity, d_k, d_k);
            for _ in 0..12 {
                match rng.index(6) {
                    0 => {
                        let rows = rng.index(capacity) + 1;
                        let _ = s.load(&rng.normal_vec(rows * d_k), &rng.normal_vec(rows * d_k));
                    }
                    1 => s.truncate(rng.index(s.len() + 1)),
                    _ => {
                        let _ = s.append(&rng.normal_vec(d_k), &rng.normal_vec(d_k));
                    }
                }
                let pad_to = s.len() + rng.index(capacity - s.len() + 1);
                let (kp, _, _) = s.padded(pad_to);
                let full = PackedKeys::new(kp, d_k);
                let q = rng.normal_vec(d_k);
                let prefix = rng.index(s.len() + 1);
                assert_eq!(
                    s.packed_view(pad_to).scores_prefix(&q, 6, prefix),
                    full.scores_prefix(&q, 6, prefix),
                    "capacity={capacity} len={} pad_to={pad_to} prefix={prefix}",
                    s.len()
                );
            }
        });
    }

    #[test]
    fn packing_is_incremental_one_row_per_append() {
        let mut s = KvStore::new(16, 4, 4);
        assert_eq!(s.packed_rows_total(), 0);
        for i in 1..=10u64 {
            s.append(&[1.0; 4], &[0.0; 4]).unwrap();
            assert_eq!(s.packed_rows_total(), i, "append must pack exactly one row");
        }
        s.truncate(3); // rollback restores pad, packs nothing
        assert_eq!(s.packed_rows_total(), 10);
        s.load(&vec![0.5; 5 * 4], &vec![0.5; 5 * 4]).unwrap();
        assert_eq!(s.packed_rows_total(), 15, "load packs the loaded rows");
    }

    #[test]
    fn release_reports_provisioned_capacity() {
        let mut s = KvStore::new(8, 2, 2);
        s.append(&[1.0, 2.0], &[3.0, 4.0]).unwrap();
        // the reclaimed provisioning is the full capacity, not the live
        // length — eviction frees what admission reserved
        assert_eq!(s.release(), 8);
    }

    #[test]
    fn demote_restore_round_trip_is_byte_identical() {
        let mut s = KvStore::new(16, 48, 32);
        let mut rng = Rng::new(11);
        for _ in 0..7 {
            s.append(&rng.normal_vec(48), &rng.normal_vec(32)).unwrap();
        }
        let mirror = s.clone();
        let spilled = s.demote();
        assert_eq!(spilled.len(), 7);
        assert_eq!(spilled.capacity(), 16);
        // 7 rows x (48 + 32) f32 + 7 rows x 1 packed word
        assert_eq!(spilled.bytes(), 7 * 80 * 4 + 7 * 8);
        let restored = spilled.restore();
        assert_eq!(restored.len(), mirror.len());
        assert_eq!(restored.capacity, mirror.capacity);
        assert_eq!(restored.packed_rows_total(), mirror.packed_rows_total());
        // full provisioned buffers, pad rows included
        assert_eq!(restored.keys, mirror.keys);
        assert_eq!(restored.values, mirror.values);
        // the packed key bits round-trip too: scoring through the restored
        // view must be bit-equal to the never-demoted mirror
        let q = rng.normal_vec(48);
        assert_eq!(
            restored.packed_view(16).scores_prefix(&q, 6, 7),
            mirror.packed_view(16).scores_prefix(&q, 6, 7),
        );
    }

    #[test]
    fn padded_is_zero_copy_and_stable() {
        let mut s = KvStore::new(100, 64, 64);
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let k = rng.normal_vec(64);
            let v = rng.normal_vec(64);
            s.append(&k, &v).unwrap();
        }
        let ptr_before = s.padded(64).0.as_ptr();
        let (k, v, n) = s.padded(64);
        assert_eq!(n, 50);
        assert_eq!(k.len(), 64 * 64);
        assert_eq!(v.len(), 64 * 64);
        assert!(k[50 * 64..].iter().all(|&x| x == KEY_PAD));
        assert!(v[50 * 64..].iter().all(|&x| x == 0.0));
        // appends must not move the buffer (batched dispatch borrows
        // several stores' views at once and backends detect same-session
        // runs by buffer identity)
        drop((k, v));
        s.append(&rng.normal_vec(64), &rng.normal_vec(64)).unwrap();
        assert_eq!(s.padded(64).0.as_ptr(), ptr_before);
    }
}
