//! Per-head key/value memory: the serving-level view of the Key SRAM and
//! the V tensor in DRAM (Sec. III-A / IV-C).
//!
//! Decoder-style usage appends one (k, v) pair per generated token — "CAM
//! search over a growing KV cache each step (causal)". The store is
//! capacity-bounded to the provisioned BA-CAM/V-SRAM size and pads the
//! active prefix up to a tile multiple for execution.

/// Per-head K/V memory.
#[derive(Clone, Debug)]
pub struct KvStore {
    pub d_k: usize,
    pub d_v: usize,
    /// Provisioned maximum context (BA-CAM + V sizing).
    pub capacity: usize,
    keys: Vec<f32>,   // row-major len * d_k
    values: Vec<f32>, // row-major len * d_v
    len: usize,
}

impl KvStore {
    pub fn new(capacity: usize, d_k: usize, d_v: usize) -> Self {
        KvStore {
            d_k,
            d_v,
            capacity,
            keys: Vec::with_capacity(capacity * d_k),
            values: Vec::with_capacity(capacity * d_v),
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append one (key, value) row. Errors when the provisioned context is
    /// exhausted (the caller decides eviction policy — the paper sizes the
    /// arrays to the target maximum context).
    pub fn append(&mut self, key: &[f32], value: &[f32]) -> Result<(), String> {
        if key.len() != self.d_k || value.len() != self.d_v {
            return Err(format!(
                "dim mismatch: key {} (want {}), value {} (want {})",
                key.len(),
                self.d_k,
                value.len(),
                self.d_v
            ));
        }
        if self.len >= self.capacity {
            return Err(format!("KV capacity {} exhausted", self.capacity));
        }
        self.keys.extend_from_slice(key);
        self.values.extend_from_slice(value);
        self.len += 1;
        Ok(())
    }

    /// Bulk-load an encoder-style fixed memory (replaces contents).
    pub fn load(&mut self, keys: &[f32], values: &[f32]) -> Result<(), String> {
        if keys.len() % self.d_k != 0 || values.len() % self.d_v != 0 {
            return Err("ragged K/V load".into());
        }
        let n = keys.len() / self.d_k;
        if n != values.len() / self.d_v {
            return Err("K/V row count mismatch".into());
        }
        if n > self.capacity {
            return Err(format!("load of {n} rows exceeds capacity {}", self.capacity));
        }
        self.keys = keys.to_vec();
        self.values = values.to_vec();
        self.len = n;
        Ok(())
    }

    /// Execution view padded to `pad_to` rows: keys pad with +1 rows whose
    /// scores can never enter the top-k beyond real keys*, values pad with
    /// zeros. (*padding keys are all-(+1); with random real keys their
    /// scores are mid-range, and their V rows are zero so any accidental
    /// selection contributes nothing.)
    pub fn padded_view(&self, pad_to: usize) -> (Vec<f32>, Vec<f32>, usize) {
        assert!(pad_to >= self.len);
        let mut k = self.keys.clone();
        let mut v = self.values.clone();
        k.resize(pad_to * self.d_k, 1.0);
        v.resize(pad_to * self.d_v, 0.0);
        (k, v, self.len)
    }

    pub fn keys(&self) -> &[f32] {
        &self.keys
    }

    pub fn values(&self) -> &[f32] {
        &self.values
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn append_grows_until_capacity() {
        let mut s = KvStore::new(3, 4, 4);
        let row = vec![1.0f32; 4];
        assert!(s.append(&row, &row).is_ok());
        assert!(s.append(&row, &row).is_ok());
        assert!(s.append(&row, &row).is_ok());
        assert_eq!(s.len(), 3);
        assert!(s.append(&row, &row).is_err());
    }

    #[test]
    fn dim_checked() {
        let mut s = KvStore::new(3, 4, 4);
        assert!(s.append(&[1.0; 3], &[1.0; 4]).is_err());
        assert!(s.append(&[1.0; 4], &[1.0; 5]).is_err());
    }

    #[test]
    fn load_replaces() {
        let mut s = KvStore::new(8, 2, 2);
        s.append(&[1.0, 2.0], &[3.0, 4.0]).unwrap();
        let k: Vec<f32> = (0..8).map(|x| x as f32).collect();
        let v: Vec<f32> = (0..8).map(|x| -(x as f32)).collect();
        s.load(&k, &v).unwrap();
        assert_eq!(s.len(), 4);
        assert_eq!(s.keys()[0], 0.0);
        assert!(s.load(&vec![0.0; 2 * 9], &vec![0.0; 2 * 9]).is_err());
    }

    #[test]
    fn padded_view_shapes() {
        let mut s = KvStore::new(100, 64, 64);
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let k = rng.normal_vec(64);
            let v = rng.normal_vec(64);
            s.append(&k, &v).unwrap();
        }
        let (k, v, n) = s.padded_view(64);
        assert_eq!(n, 50);
        assert_eq!(k.len(), 64 * 64);
        assert_eq!(v.len(), 64 * 64);
        // padded V rows are zero
        assert!(v[50 * 64..].iter().all(|&x| x == 0.0));
    }
}
