//! The per-shard session directory (ISSUE 8): one coordination point per
//! shard that owns session **residency** and **LRU ordering**, so
//! reclamation is a shard-level decision instead of a per-worker one.
//!
//! # Why a directory
//!
//! `CamformerServer::open` admits a session on *every* head of its shard
//! (the PR-5 broadcast), but reclamation used to run per worker: each
//! head evicted by its own logical clock, so a shard-wide session could
//! be evicted on one head while its KV stayed live on the others — the
//! split-brain documented in the `server` module docs. The directory
//! closes that hole: every worker of a shard reports its touches into
//! one **shard clock**, and an over-budget `Prefill` (or a promotion)
//! selects ONE victim shard-wide through
//! [`ShardDirectory::evict_shard_wide`], which atomically marks the
//! victim on every head. A session is fully resident or fully
//! demoted/dropped — never split.
//!
//! # The residency state machine (per head)
//!
//! ```text
//!          admit (Prefill)                 evict_shard_wide
//!  Absent ────────────────► Resident ───────────────────────────┐
//!    ▲                         ▲                                │
//!    │ close / drop            │ promote                        ▼
//!    │                         │                     PendingDemote | PendingDrop
//!    │                      Spilled ◄──── park ──────────(apply at the
//!    └────── close_spilled ────┘          (reconcile)     next cycle)
//! ```
//!
//! The *decision* (marking) is atomic under the directory lock and is
//! counted exactly once; the *application* is lazy: the initiating
//! worker applies its own head's transition inside the same barrier,
//! and every other worker applies pending transitions at the top of its
//! next scheduling cycle ([`ShardDirectory::pending_for`]), mirroring
//! how the `open` broadcast fans admission out. Until a head applies,
//! its local copy keeps serving already-planned work — dispatch groups
//! never lose a store mid-flight.
//!
//! # The DRAM spill tier
//!
//! Under `ReclaimPolicy::LruSpillToDram` a victim's KV (keys, values,
//! packed key bits — [`SpilledKv`]) is **demoted** into the directory's
//! spill pool instead of dropped: the writeback is charged through the
//! [`HbmChannel`] model, the rows stay addressable by (session, head),
//! and the victim's next request **promotes** them back with a modeled
//! latency from the same channel — the client sees a slow first token,
//! never `ServeError::Evicted`. Demotions, promotions, modeled
//! promotion latencies and the channel's byte/energy totals fold into
//! [`Metrics`] at shutdown via [`ShardDirectory::fold_metrics`].
//!
//! # Determinism
//!
//! The shard clock advances once per touch under the lock, in each
//! worker's program order; on a single-head shard the shard order *is*
//! the worker's logical-clock order, so victim choice is bit-identical
//! to the per-worker LRU it replaces. Victim selection breaks
//! (impossible) ties by session id, and the modeled DRAM timeline is a
//! deterministic function of the demote/promote sequence.
//!
//! # Crash durability (ISSUE 9)
//!
//! The spill pool lives in the directory — *outside* every worker
//! thread — so a worker crash cannot take parked copies with it. When a
//! head's incarnation dies, the supervisor calls
//! [`ShardDirectory::fail_head`]: sessions resident on the dead head are
//! lost shard-wide (their copies on surviving heads are sentenced
//! `PendingLost`, answered [`ServeError::SessionLost`](super::ServeError::SessionLost)),
//! but sessions *spilled* on the dead head survive verbatim and promote
//! byte-identically onto the respawned incarnation — each such
//! promotion counts once in `Metrics::sessions_recovered`.

use std::collections::{HashMap, HashSet};
use std::sync::Mutex;

use super::kv_store::{KvStore, SpilledKv};
use super::metrics::Metrics;
use super::session::SessionId;
use crate::dram::{DramConfig, HbmChannel};

/// Transfer granule for spill writeback / promotion: one burst's worth
/// of bytes per modeled channel access, so a multi-row transfer
/// exercises the open-page behavior (first access misses, the rest of
/// the page hits) instead of being charged as one giant access.
const SPILL_CHUNK_BYTES: usize = 256;

/// Where one head's copy of a session lives, per the shard's directory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum HeadState {
    /// No copy on this head (never prefilled here, or closed/dropped).
    Absent,
    /// Live in the head worker's session table.
    Resident,
    /// Sentenced by a shard-wide spill decision: the worker parks its
    /// copy into the pool at its next reconcile ([`ShardDirectory::park`]).
    PendingDemote,
    /// Sentenced by a shard-wide drop decision (`LruEvictIdle`): the
    /// worker releases its copy and tombstones the id at its next
    /// reconcile.
    PendingDrop,
    /// Parked in the spill pool, promotable on the session's next request.
    Spilled,
    /// Sentenced by a worker crash on a *sibling* head
    /// ([`ShardDirectory::fail_head`]): this head's copy is stale — the
    /// session lost a head's KV and cannot be served consistently — so
    /// the worker releases it and tombstones the id for `SessionLost`
    /// answers at its next reconcile.
    PendingLost,
}

/// One session's shard-wide directory entry.
#[derive(Debug)]
struct DirEntry {
    /// Shard-clock position of the session's last touch — the LRU key.
    touch: u64,
    /// Bumped on every shard-wide demote/drop decision; local `Session`
    /// copies carry the generation they were admitted/promoted under.
    generation: u64,
    heads: Vec<HeadState>,
}

/// What a worker must do to its local copy of a session, decided
/// shard-wide at some earlier barrier (see [`ShardDirectory::pending_for`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PendingAction {
    /// Park the local copy into the spill pool (charge the writeback).
    Demote,
    /// Release the local copy and tombstone the id (`Evicted` answers).
    Drop,
    /// Release the local copy and tombstone the id for `SessionLost`
    /// answers — a sibling head's crash took part of the session's KV.
    Lost,
}

/// Outcome of a shard-wide victim selection.
#[derive(Debug, PartialEq, Eq)]
pub enum Reclaimed {
    /// This session was marked on every head; the caller applies its own
    /// head's transition now and counts the decision once.
    Victim(SessionId),
    /// No new victim was chosen because some candidate is already
    /// sentenced by a concurrent decision: apply pending transitions
    /// (freeing their rows) and re-check the pressure before asking again.
    PendingElsewhere,
    /// Nothing reclaimable among the candidates.
    None,
}

/// A spilled copy plus its simulated host-tier address.
#[derive(Debug)]
struct SpilledSlot {
    kv: SpilledKv,
    addr: u64,
}

#[derive(Debug)]
struct DirInner {
    /// The merged shard clock: advances once per touch, under the lock,
    /// in each worker's program order.
    clock: u64,
    entries: HashMap<SessionId, DirEntry>,
    /// The simulated host tier: demoted KV by (session, head).
    pool: HashMap<(SessionId, usize), SpilledSlot>,
    /// The modeled DRAM channel the spill traffic is charged through.
    channel: HbmChannel,
    /// Simulated-time cursor for channel accesses \[ns\].
    now_ns: f64,
    /// Bump allocator over the simulated host address space.
    next_addr: u64,
    demotions: u64,
    promotions: u64,
    promotion_ns: Vec<f64>,
    /// Spilled copies whose owning head crashed while they were parked
    /// ([`ShardDirectory::fail_head`]): promoting one onto the respawned
    /// incarnation is a crash *recovery*, counted in `recoveries`.
    crash_survivors: HashSet<(SessionId, usize)>,
    recoveries: u64,
}

/// One per shard, shared by its head workers (`Arc`). All state sits
/// behind one mutex; every operation is a short critical section (no
/// backend work, no allocation proportional to KV size except the park
/// hand-off, which moves — never copies — the spilled buffers).
#[derive(Debug)]
pub struct ShardDirectory {
    heads: usize,
    inner: Mutex<DirInner>,
}

impl ShardDirectory {
    pub fn new(heads: usize) -> Self {
        assert!(heads >= 1, "a shard has at least one head");
        ShardDirectory {
            heads,
            inner: Mutex::new(DirInner {
                clock: 0,
                entries: HashMap::new(),
                pool: HashMap::new(),
                channel: HbmChannel::new(DramConfig::default()),
                now_ns: 0.0,
                next_addr: 0,
                demotions: 0,
                promotions: 0,
                promotion_ns: Vec::new(),
                crash_survivors: HashSet::new(),
                recoveries: 0,
            }),
        }
    }

    /// Record a request touching `session` (shard-wide LRU order). Heads
    /// call this exactly where they advance their local logical clock,
    /// so on a single-head shard the two orders coincide. A miss (no
    /// entry) is a no-op — the request will be answered
    /// `UnknownSession`/`Evicted` by the worker anyway.
    pub fn touch(&self, session: SessionId) {
        let inner = &mut *self.inner.lock().unwrap();
        if let Some(entry) = inner.entries.get_mut(&session) {
            inner.clock += 1;
            entry.touch = inner.clock;
        }
    }

    /// Admit (or re-admit) `session` on `head` at a `Prefill` barrier:
    /// marks the head resident, discards any spilled copy this head held
    /// (the prefill replaces its content), touches the shard clock, and
    /// returns the session's current generation for the local `Session`.
    pub fn admit(&self, session: SessionId, head: usize) -> u64 {
        assert!(head < self.heads);
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        inner.pool.remove(&(session, head));
        inner.crash_survivors.remove(&(session, head));
        let heads = self.heads;
        let entry = inner.entries.entry(session).or_insert_with(|| DirEntry {
            touch: clock,
            generation: 0,
            heads: vec![HeadState::Absent; heads],
        });
        entry.touch = clock;
        entry.heads[head] = HeadState::Resident;
        entry.generation
    }

    /// Shard-wide victim selection at a reclaim barrier on `head`:
    /// `candidates` are the caller's locally-resident, unpinned,
    /// idle-eligible sessions (minus the one being admitted). Picks the
    /// least-recently-touched by shard clock (ties — impossible, since
    /// the clock is unique per touch — would break by id) and marks the
    /// decision on EVERY head atomically: resident heads become
    /// `PendingDemote`/`PendingDrop`, spilled copies of a dropped victim
    /// are discarded outright. Counts a demotion decision once, here.
    pub fn evict_shard_wide(&self, head: usize, candidates: &[SessionId], drop: bool) -> Reclaimed {
        assert!(head < self.heads);
        let mut inner = self.inner.lock().unwrap();
        let mut victim: Option<(u64, SessionId)> = None;
        let mut pending_elsewhere = false;
        for &sid in candidates {
            match inner.entries.get(&sid) {
                Some(e) if e.heads[head] == HeadState::Resident => {
                    let key = (e.touch, sid);
                    if victim.map_or(true, |best| key < best) {
                        victim = Some(key);
                    }
                }
                // locally resident but already sentenced by a concurrent
                // shard decision: applying it frees rows, so the caller
                // must reconcile before we pick an extra victim
                Some(e)
                    if matches!(
                        e.heads[head],
                        HeadState::PendingDemote | HeadState::PendingDrop | HeadState::PendingLost
                    ) =>
                {
                    pending_elsewhere = true;
                }
                _ => {}
            }
        }
        // A sentenced candidate takes precedence over picking a fresh
        // victim: applying its pending transition frees rows/slots, so a
        // second head racing into the same pressure must reconcile and
        // re-check instead of widening the eviction — this is what keeps
        // the victim SET identical across dispatch interleavings.
        if pending_elsewhere {
            return Reclaimed::PendingElsewhere;
        }
        let Some((_, sid)) = victim else {
            return Reclaimed::None;
        };
        let entry = inner.entries.get_mut(&sid).expect("victim was just seen");
        entry.generation += 1;
        let mut drop_spilled: Vec<usize> = Vec::new();
        for (h, state) in entry.heads.iter_mut().enumerate() {
            match *state {
                HeadState::Resident => {
                    *state = if drop { HeadState::PendingDrop } else { HeadState::PendingDemote };
                }
                HeadState::Spilled if drop => {
                    // a drop decision kills parked copies too
                    *state = HeadState::Absent;
                    drop_spilled.push(h);
                }
                _ => {}
            }
        }
        for h in drop_spilled {
            inner.pool.remove(&(sid, h));
            inner.crash_survivors.remove(&(sid, h));
        }
        if !drop {
            inner.demotions += 1;
        }
        Reclaimed::Victim(sid)
    }

    /// The transitions `head` must apply to its local copies — the lazy
    /// fan-out half of a shard-wide decision, called at the top of every
    /// scheduling cycle and inside reclaim loops.
    pub fn pending_for(&self, head: usize) -> Vec<(SessionId, PendingAction)> {
        let inner = self.inner.lock().unwrap();
        let mut out: Vec<(SessionId, PendingAction)> = inner
            .entries
            .iter()
            .filter_map(|(&sid, e)| match e.heads[head] {
                HeadState::PendingDemote => Some((sid, PendingAction::Demote)),
                HeadState::PendingDrop => Some((sid, PendingAction::Drop)),
                HeadState::PendingLost => Some((sid, PendingAction::Lost)),
                _ => None,
            })
            .collect();
        // deterministic application order (HashMap iteration is not)
        out.sort_unstable_by_key(|&(sid, _)| sid);
        out
    }

    /// Park `head`'s demoted copy in the spill pool, charging the
    /// writeback through the channel model. The copy moves — keys,
    /// values and packed key bits land in the pool verbatim.
    pub fn park(&self, session: SessionId, head: usize, kv: SpilledKv) {
        let mut inner = self.inner.lock().unwrap();
        let bytes = kv.bytes();
        let addr = inner.next_addr;
        inner.next_addr += bytes.max(1) as u64;
        let mut now = inner.now_ns;
        let mut off = 0usize;
        while off < bytes {
            let chunk = SPILL_CHUNK_BYTES.min(bytes - off);
            let (done, _) = inner.channel.write(now, addr + off as u64, chunk);
            now = done;
            off += chunk;
        }
        inner.now_ns = now;
        if let Some(entry) = inner.entries.get_mut(&session) {
            entry.heads[head] = HeadState::Spilled;
        }
        inner.pool.insert((session, head), SpilledSlot { kv, addr });
    }

    /// A worker crash took `head`'s whole session table (ISSUE 9). Called
    /// by the supervisor before respawning the incarnation; returns the
    /// sessions *lost* with it, sorted, so the caller can tombstone them
    /// and answer their queued work `SessionLost`.
    ///
    /// Per session, atomically under the lock:
    ///
    /// * a copy the dead head held in its table (`Resident`, or sentenced
    ///   `PendingDemote`/`PendingDrop`/`PendingLost` but not yet applied)
    ///   died with the thread → the head goes `Absent`, the generation is
    ///   bumped, and the session is **lost shard-wide**: surviving heads'
    ///   resident copies become `PendingLost` (released lazily, like any
    ///   shard decision) and their parked copies are discarded — a
    ///   session missing one head's KV cannot be served consistently;
    /// * a copy the dead head had **spilled** lives in this directory,
    ///   not the thread → it survives verbatim, is remembered as a crash
    ///   survivor, and its next promotion counts as a recovery.
    pub fn fail_head(&self, head: usize) -> Vec<SessionId> {
        assert!(head < self.heads);
        let inner = &mut *self.inner.lock().unwrap();
        let mut lost: Vec<SessionId> = Vec::new();
        let mut orphaned: Vec<(SessionId, usize)> = Vec::new();
        for (&sid, entry) in inner.entries.iter_mut() {
            match entry.heads[head] {
                HeadState::Resident
                | HeadState::PendingDemote
                | HeadState::PendingDrop
                | HeadState::PendingLost => {
                    entry.heads[head] = HeadState::Absent;
                    entry.generation += 1;
                    lost.push(sid);
                    for (h, state) in entry.heads.iter_mut().enumerate() {
                        match *state {
                            HeadState::Resident
                            | HeadState::PendingDemote
                            | HeadState::PendingDrop => *state = HeadState::PendingLost,
                            HeadState::Spilled => {
                                *state = HeadState::Absent;
                                orphaned.push((sid, h));
                            }
                            _ => {}
                        }
                    }
                }
                HeadState::Spilled => {
                    inner.crash_survivors.insert((sid, head));
                }
                HeadState::Absent => {}
            }
        }
        for key in orphaned {
            inner.pool.remove(&key);
            inner.crash_survivors.remove(&key);
        }
        inner.entries.retain(|_, e| e.heads.iter().any(|&h| h != HeadState::Absent));
        lost.sort_unstable();
        lost
    }

    /// Record that `head` dropped its local copy (a `PendingDrop`
    /// application, or a plain `Close` of a resident session). Forgets
    /// the whole entry once no head holds or owes a copy.
    pub fn note_gone(&self, session: SessionId, head: usize) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(entry) = inner.entries.get_mut(&session) {
            entry.heads[head] = HeadState::Absent;
            if entry.heads.iter().all(|&h| h == HeadState::Absent) {
                inner.entries.remove(&session);
            }
        }
    }

    /// Whether `head` has a promotable spilled copy of `session` (the
    /// promotion-barrier trigger for a Decode/Attend that misses the
    /// local table).
    pub fn is_spilled(&self, session: SessionId, head: usize) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.pool.contains_key(&(session, head))
    }

    /// Peek the spilled copy's (live rows, provisioned capacity) so the
    /// promotion barrier can reclaim budget/slot room *before* taking it.
    pub fn spilled_shape(&self, session: SessionId, head: usize) -> Option<(usize, usize)> {
        let inner = self.inner.lock().unwrap();
        inner.pool.get(&(session, head)).map(|s| (s.kv.len(), s.kv.capacity()))
    }

    /// Promote `head`'s spilled copy back into the accelerator tier:
    /// removes it from the pool, charges the read stream through the
    /// channel model, records the modeled promotion latency (the
    /// victim's slow first token), touches the shard clock, and returns
    /// the byte-identical restored store plus the generation the
    /// restored `Session` now belongs to.
    pub fn promote(&self, session: SessionId, head: usize) -> Option<(KvStore, u64, f64)> {
        let mut inner = self.inner.lock().unwrap();
        let slot = inner.pool.remove(&(session, head))?;
        let bytes = slot.kv.bytes();
        let start = inner.now_ns;
        let mut now = start;
        let mut off = 0usize;
        while off < bytes {
            let chunk = SPILL_CHUNK_BYTES.min(bytes - off);
            let (done, _) = inner.channel.read(now, slot.addr + off as u64, chunk);
            now = done;
            off += chunk;
        }
        inner.now_ns = now;
        let latency_ns = now - start;
        inner.promotions += 1;
        inner.promotion_ns.push(latency_ns);
        if inner.crash_survivors.remove(&(session, head)) {
            // the owning head crashed while this copy was parked: landing
            // it on the respawned incarnation is a crash recovery
            inner.recoveries += 1;
        }
        inner.clock += 1;
        let clock = inner.clock;
        let generation = match inner.entries.get_mut(&session) {
            Some(entry) => {
                entry.heads[head] = HeadState::Resident;
                entry.touch = clock;
                entry.generation
            }
            None => 0,
        };
        Some((slot.kv.restore(), generation, latency_ns))
    }

    /// Retire `head`'s spilled copy on an explicit `Close`: the session
    /// was demoted, then closed without ever being promoted. Returns the
    /// retired copy's live length (the close ack's `seq_len`). The
    /// accelerator-side rows were already accounted released at
    /// demotion, so the caller must NOT count them again.
    pub fn close_spilled(&self, session: SessionId, head: usize) -> Option<usize> {
        let len = {
            let mut inner = self.inner.lock().unwrap();
            inner.crash_survivors.remove(&(session, head));
            inner.pool.remove(&(session, head)).map(|s| s.kv.len())?
        };
        self.note_gone(session, head);
        Some(len)
    }

    /// Whether the directory still tracks `session` on any head (used by
    /// tests; `false` means the directory forgot it entirely).
    pub fn knows(&self, session: SessionId) -> bool {
        self.inner.lock().unwrap().entries.contains_key(&session)
    }

    /// Fold the shard's spill-tier accounting into a merged [`Metrics`]
    /// at shutdown: decision counters, rows still parked in the pool,
    /// modeled promotion latencies, and the channel's byte/energy totals.
    pub fn fold_metrics(&self, m: &mut Metrics) {
        let inner = self.inner.lock().unwrap();
        m.demotions += inner.demotions;
        m.promotions += inner.promotions;
        m.spilled_rows += inner.pool.values().map(|s| s.kv.len() as u64).sum::<u64>();
        m.dram_bytes_written += inner.channel.bytes_written;
        m.dram_bytes_read += inner.channel.bytes_read;
        m.dram_energy_j += inner.channel.energy_j();
        m.sessions_recovered += inner.recoveries;
        for &ns in &inner.promotion_ns {
            m.note_promotion_latency_ns(ns);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spilled(rows: usize) -> SpilledKv {
        let mut kv = KvStore::new(8, 4, 4);
        for i in 0..rows {
            kv.append(&[i as f32; 4], &[-(i as f32); 4]).unwrap();
        }
        kv.demote()
    }

    #[test]
    fn admit_then_touch_orders_victim_choice_by_shard_clock() {
        let dir = ShardDirectory::new(1);
        assert_eq!(dir.admit(1, 0), 0);
        assert_eq!(dir.admit(2, 0), 0);
        dir.touch(1); // 2 is now least-recently-touched
        assert_eq!(dir.evict_shard_wide(0, &[1, 2], false), Reclaimed::Victim(2));
        // the decision marked head 0; the initiator applies it
        assert_eq!(dir.pending_for(0), vec![(2, PendingAction::Demote)]);
    }

    #[test]
    fn decision_marks_every_resident_head_and_counts_once() {
        let dir = ShardDirectory::new(2);
        dir.admit(7, 0);
        dir.admit(7, 1);
        dir.admit(9, 0);
        dir.admit(9, 1);
        dir.touch(9);
        assert_eq!(dir.evict_shard_wide(0, &[7, 9], false), Reclaimed::Victim(7));
        // BOTH heads owe a demotion — no split-brain
        assert_eq!(dir.pending_for(0), vec![(7, PendingAction::Demote)]);
        assert_eq!(dir.pending_for(1), vec![(7, PendingAction::Demote)]);
        // a second selection on the other head must not pick a fresh
        // victim while 7's demotion is still pending there
        assert_eq!(dir.evict_shard_wide(1, &[7, 9], false), Reclaimed::PendingElsewhere);
        let mut m = Metrics::new();
        dir.fold_metrics(&mut m);
        assert_eq!(m.demotions, 1, "one decision, counted once, not per head");
    }

    #[test]
    fn park_and_promote_round_trip_with_modeled_latency() {
        let dir = ShardDirectory::new(1);
        dir.admit(3, 0);
        assert_eq!(dir.evict_shard_wide(0, &[3], false), Reclaimed::Victim(3));
        dir.park(3, 0, spilled(5));
        assert!(dir.is_spilled(3, 0));
        assert_eq!(dir.spilled_shape(3, 0), Some((5, 8)));
        let (kv, generation, latency_ns) = dir.promote(3, 0).expect("promotable");
        assert_eq!(kv.len(), 5);
        assert_eq!(generation, 1, "the demote decision bumped the generation");
        assert!(latency_ns > 0.0, "promotion pays a modeled DRAM latency");
        assert!(!dir.is_spilled(3, 0));
        let mut m = Metrics::new();
        dir.fold_metrics(&mut m);
        assert_eq!((m.demotions, m.promotions), (1, 1));
        assert_eq!(m.spilled_rows, 0, "promoted copies left the pool");
        assert!(m.dram_bytes_written > 0 && m.dram_bytes_read > 0);
        assert!(m.dram_energy_j > 0.0);
        assert!(m.promotion_p50_ns() > 0.0);
    }

    #[test]
    fn drop_decision_discards_parked_copies() {
        let dir = ShardDirectory::new(2);
        dir.admit(4, 0);
        dir.admit(4, 1);
        assert_eq!(dir.evict_shard_wide(0, &[4], false), Reclaimed::Victim(4));
        dir.park(4, 0, spilled(2));
        // head 1 still owes its demotion when a drop decision lands
        assert_eq!(dir.evict_shard_wide(1, &[4], true), Reclaimed::PendingElsewhere);
        // after head 1 parks too, the whole session is spilled; a drop
        // decision can then only come from a *resident* candidate, so
        // spilled-only sessions are never re-victimized
        dir.park(4, 1, spilled(2));
        assert_eq!(dir.evict_shard_wide(0, &[4], true), Reclaimed::None);
        // closes retire the parked copies and the directory forgets
        assert_eq!(dir.close_spilled(4, 0), Some(2));
        assert_eq!(dir.close_spilled(4, 1), Some(2));
        assert_eq!(dir.close_spilled(4, 0), None);
        assert!(!dir.knows(4));
    }

    #[test]
    fn close_of_all_heads_forgets_the_session() {
        let dir = ShardDirectory::new(2);
        dir.admit(5, 0);
        dir.admit(5, 1);
        dir.note_gone(5, 0);
        assert!(dir.knows(5), "head 1 still holds a copy");
        dir.note_gone(5, 1);
        assert!(!dir.knows(5));
    }

    #[test]
    fn fail_head_loses_resident_sessions_shard_wide() {
        let dir = ShardDirectory::new(2);
        dir.admit(1, 0);
        dir.admit(1, 1);
        dir.admit(2, 1); // not on the dead head: untouched
        assert_eq!(dir.fail_head(0), vec![1]);
        // the surviving head owes a lazy release + SessionLost tombstone
        assert_eq!(dir.pending_for(1), vec![(1, PendingAction::Lost)]);
        // and must reconcile before any fresh victim selection sees it
        assert_eq!(dir.evict_shard_wide(1, &[1, 2], false), Reclaimed::PendingElsewhere);
        // session 2 never touched head 0, so it is not lost
        dir.note_gone(1, 1);
        assert!(!dir.knows(1));
        assert!(dir.knows(2));
    }

    #[test]
    fn fail_head_keeps_spilled_copies_and_counts_their_promotion_as_recovery() {
        let dir = ShardDirectory::new(1);
        dir.admit(3, 0);
        assert_eq!(dir.evict_shard_wide(0, &[3], false), Reclaimed::Victim(3));
        dir.park(3, 0, spilled(5));
        // the parked copy lives in the directory, not the dead thread
        assert_eq!(dir.fail_head(0), Vec::<SessionId>::new());
        assert!(dir.is_spilled(3, 0), "spilled copies survive the crash");
        let (kv, _, _) = dir.promote(3, 0).expect("promotable onto the respawn");
        assert_eq!(kv.len(), 5, "recovered byte-for-byte from the pool");
        let mut m = Metrics::new();
        dir.fold_metrics(&mut m);
        assert_eq!(m.sessions_recovered, 1);
        // promoting it again (impossible) or promoting after a clean
        // demote/promote cycle must not inflate the recovery count
        assert!(dir.promote(3, 0).is_none());
    }

    #[test]
    fn fail_head_discards_a_lost_sessions_parked_sibling_copies() {
        let dir = ShardDirectory::new(2);
        dir.admit(4, 0);
        dir.admit(4, 1);
        // head 1's copy gets demoted; head 0 stays resident
        assert_eq!(dir.evict_shard_wide(1, &[4], false), Reclaimed::Victim(4));
        dir.park(4, 1, spilled(2));
        dir.pending_for(0).iter().for_each(|&(sid, _)| dir.park(sid, 0, spilled(2)));
        // un-spill head 0 so the session is resident there again
        let _ = dir.promote(4, 0).expect("head 0 promotes");
        // now: head 0 resident, head 1 spilled. Head 0 crashes: the
        // session is lost, so head 1's parked copy is stale — discarded
        assert_eq!(dir.fail_head(0), vec![4]);
        assert!(!dir.is_spilled(4, 1), "orphaned parked copy discarded");
        assert!(!dir.knows(4), "no head holds or owes anything");
        let mut m = Metrics::new();
        dir.fold_metrics(&mut m);
        assert_eq!(m.sessions_recovered, 0, "discards are not recoveries");
    }

    #[test]
    fn clean_promotions_are_not_recoveries() {
        let dir = ShardDirectory::new(1);
        dir.admit(6, 0);
        assert_eq!(dir.evict_shard_wide(0, &[6], false), Reclaimed::Victim(6));
        dir.park(6, 0, spilled(3));
        let _ = dir.promote(6, 0).expect("clean promote");
        let mut m = Metrics::new();
        dir.fold_metrics(&mut m);
        assert_eq!(m.promotions, 1);
        assert_eq!(m.sessions_recovered, 0);
    }

    #[test]
    fn readmission_discards_the_spilled_copy() {
        let dir = ShardDirectory::new(1);
        dir.admit(6, 0);
        assert_eq!(dir.evict_shard_wide(0, &[6], false), Reclaimed::Victim(6));
        dir.park(6, 0, spilled(3));
        // a re-open replaces content: the parked rows are stale
        dir.admit(6, 0);
        assert!(!dir.is_spilled(6, 0));
        assert!(dir.promote(6, 0).is_none());
    }
}
