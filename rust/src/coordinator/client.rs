//! The session-handle client surface (ISSUE 5): typed per-request
//! tickets and explicit session lifecycle over the serving internals.
//!
//! The PR-1 surface was fire-and-forget: `submit(Request)` plus an
//! unordered `collect(n)` pool that made every caller hand-correlate
//! responses by id. This module replaces it as the primary API:
//!
//! * [`CamformerServer::open`] performs a **shard-wide prefill
//!   fan-out** — one broadcast `Prefill` per head of the session's
//!   shard, admitted **all-or-nothing** (a partial admission is rolled
//!   back by closing the heads that succeeded) — and returns an owned
//!   [`SessionHandle`];
//! * [`SessionHandle::decode`] / [`SessionHandle::attend`] return a
//!   [`Ticket`] — a `#[must_use]` per-request completion slot that
//!   resolves to exactly that request's [`Response`] via
//!   [`Ticket::wait`] / [`Ticket::try_wait`] / [`Ticket::wait_timeout`];
//! * [`SessionHandle::close`] (and `Drop`) retires the session on every
//!   head, releasing its provisioned KV capacity through
//!   [`Request::Close`].
//!
//! The completion slot IS the ticket's private channel: a dropped
//! ticket discards its response with nothing left behind, and a worker
//! that dies with the request in flight surfaces as
//! [`ServeError::WorkerGone`] from `wait` (the slot's sender drops with
//! the worker's queue).

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

use super::error::ServeError;
use super::server::{CamformerServer, Request, Response};
use super::session::SessionId;

/// A per-request completion slot: resolves to exactly one [`Response`],
/// the one for the request that issued it. Must be consumed — an
/// unwaited ticket is almost always a lost result (dropping one is
/// legal and leaks nothing, but do it on purpose).
#[must_use = "a Ticket resolves to its Response only through wait()/try_wait()/wait_timeout()"]
#[derive(Debug)]
pub struct Ticket {
    id: u64,
    session: SessionId,
    head: usize,
    worker: usize,
    rx: Receiver<Response>,
}

impl Ticket {
    pub(crate) fn new(
        id: u64,
        session: SessionId,
        head: usize,
        worker: usize,
        rx: Receiver<Response>,
    ) -> Self {
        Ticket { id, session, head, worker, rx }
    }

    /// The request id this ticket resolves (echoed on the response).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The session the request targeted.
    pub fn session(&self) -> SessionId {
        self.session
    }

    /// The response synthesized when the owning worker died with this
    /// request in flight (its queue — and our slot's sender — dropped).
    fn worker_gone(&self) -> Response {
        Response {
            id: self.id,
            session: self.session,
            head: self.head,
            result: Err(ServeError::WorkerGone { worker: self.worker }),
            latency: Duration::ZERO,
        }
    }

    /// Block until the response arrives. A dead worker yields
    /// `Err(WorkerGone)` inside the response rather than hanging.
    pub fn wait(self) -> Response {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => self.worker_gone(),
        }
    }

    /// Non-blocking poll: the response if it already completed, the
    /// ticket back otherwise.
    pub fn try_wait(self) -> Result<Response, Ticket> {
        match self.rx.try_recv() {
            Ok(r) => Ok(r),
            Err(TryRecvError::Empty) => Err(self),
            Err(TryRecvError::Disconnected) => Ok(self.worker_gone()),
        }
    }

    /// Wait up to `timeout`; on expiry the ticket comes back and can be
    /// waited again (the request stays in flight — timing out does not
    /// cancel it).
    pub fn wait_timeout(self, timeout: Duration) -> Result<Response, Ticket> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Ok(r),
            Err(RecvTimeoutError::Timeout) => Err(self),
            Err(RecvTimeoutError::Disconnected) => Ok(self.worker_gone()),
        }
    }

    /// Wait until `deadline` — the absolute-time counterpart to
    /// [`Ticket::wait_timeout`], with the same expiry contract: past the
    /// deadline the ticket comes back and can be waited again (the
    /// request stays in flight). The natural shape for "resolve this
    /// whole batch of tickets within one budget" loops, where a relative
    /// timeout would compound per ticket.
    pub fn wait_deadline(self, deadline: Instant) -> Result<Response, Ticket> {
        self.wait_timeout(deadline.saturating_duration_since(Instant::now()))
    }
}

/// An open serving session: the owned client-side handle to the KV
/// state [`CamformerServer::open`] admitted on every head of the
/// session's shard. Requests issued through the handle return
/// [`Ticket`]s; dropping the handle closes the session (prefer the
/// explicit [`SessionHandle::close`], which confirms the release).
#[derive(Debug)]
pub struct SessionHandle<'srv> {
    server: &'srv CamformerServer,
    session: SessionId,
    heads: usize,
    closed: bool,
}

impl SessionHandle<'_> {
    /// The session id this handle owns.
    pub fn id(&self) -> SessionId {
        self.session
    }

    /// One autoregressive step on head 0 (the single-head convenience —
    /// multi-head callers use [`SessionHandle::decode_on`] per head):
    /// append `(new_key, new_value)`, attend `query` over the grown
    /// cache.
    pub fn decode(
        &self,
        query: Vec<f32>,
        new_key: Vec<f32>,
        new_value: Vec<f32>,
    ) -> Result<Ticket, ServeError> {
        self.decode_on(0, query, new_key, new_value)
    }

    /// One autoregressive step on the given head.
    pub fn decode_on(
        &self,
        head: usize,
        query: Vec<f32>,
        new_key: Vec<f32>,
        new_value: Vec<f32>,
    ) -> Result<Ticket, ServeError> {
        self.server.submit_ticket(Request::Decode {
            id: self.server.alloc_id(),
            session: self.session,
            head,
            query,
            new_key,
            new_value,
        })
    }

    /// Read-only attention over the current cache on head 0.
    pub fn attend(&self, query: Vec<f32>) -> Result<Ticket, ServeError> {
        self.attend_on(0, query)
    }

    /// Read-only attention on the given head.
    pub fn attend_on(&self, head: usize, query: Vec<f32>) -> Result<Ticket, ServeError> {
        self.server.submit_ticket(Request::Attend {
            id: self.server.alloc_id(),
            session: self.session,
            head,
            query,
        })
    }

    /// Issue a `Close` to every head of the shard (without waiting).
    /// Best-effort per head: one dead worker must not stop the closes
    /// for the live ones (their slots would otherwise leak until
    /// shutdown). Returns the issued tickets and every per-head
    /// submission error.
    fn close_tickets(&self) -> (Vec<Ticket>, Vec<ServeError>) {
        let mut tickets = Vec::with_capacity(self.heads);
        let mut errors = Vec::new();
        for head in 0..self.heads {
            let close = self.server.submit_ticket(Request::Close {
                id: self.server.alloc_id(),
                session: self.session,
                head,
            });
            match close {
                Ok(t) => tickets.push(t),
                Err(e) => errors.push(e),
            }
        }
        (tickets, errors)
    }

    /// Close the session on every head of its shard, waiting for each
    /// release to be confirmed. Every head is closed even if an earlier
    /// one fails (a dead worker must not leak the live workers' slots);
    /// the first per-head error is returned afterwards (e.g.
    /// [`ServeError::Evicted`] when the reclaim policy already took a
    /// head's slot). On `Ok`, the session's provisioned KV capacity is
    /// free for new admissions on all heads.
    pub fn close(mut self) -> Result<(), ServeError> {
        self.closed = true;
        let (tickets, errors) = self.close_tickets();
        let mut first_err = errors.into_iter().next();
        for ticket in tickets {
            if let Err(e) = ticket.wait().result {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for SessionHandle<'_> {
    /// Fire-and-forget close on every head: the session does not leak
    /// its KV capacity when a handle goes out of scope. The acks are
    /// discarded, but per-head closes that fail to *submit* are counted
    /// in `Metrics::close_failures` (surfaced at shutdown) instead of
    /// vanishing silently — call [`SessionHandle::close`] to get the
    /// errors themselves.
    fn drop(&mut self) {
        if !self.closed {
            self.closed = true;
            let (tickets, errors) = self.close_tickets();
            self.server.note_close_failures(errors.len() as u64);
            drop(tickets);
        }
    }
}

impl CamformerServer {
    /// Open a serving session: broadcast one `Prefill` of `keys`/`values`
    /// to **every head of the session's shard** and admit the session
    /// all-or-nothing — if any head refuses (session limit with
    /// [`ReclaimPolicy::Deny`], capacity, dimensions), the heads that
    /// admitted are closed again and the first error is returned, so a
    /// failed `open` never leaves per-head state behind.
    ///
    /// Re-opening a live session id resets its cache on every head (and
    /// revives an evicted id). The returned [`SessionHandle`] borrows
    /// the server; close (or drop) all handles before `shutdown`.
    ///
    /// [`ReclaimPolicy::Deny`]: super::server::ReclaimPolicy::Deny
    pub fn open(
        &self,
        session: SessionId,
        keys: Vec<f32>,
        values: Vec<f32>,
    ) -> Result<SessionHandle<'_>, ServeError> {
        let heads = self.config().heads;
        let mut pending: Vec<(usize, Ticket)> = Vec::with_capacity(heads);
        let mut refused: Option<ServeError> = None;
        for head in 0..heads {
            let req = Request::Prefill {
                id: self.alloc_id(),
                session,
                head,
                keys: keys.clone(),
                values: values.clone(),
            };
            // a synchronous refusal (dims on head 0, WorkerGone on any)
            // must still let the already-issued heads finish and roll back
            match self.submit_ticket(req) {
                Ok(t) => pending.push((head, t)),
                Err(e) => {
                    if refused.is_none() {
                        refused = Some(e);
                    }
                }
            }
        }
        let mut admitted: Vec<usize> = Vec::with_capacity(heads);
        for (head, ticket) in pending {
            match ticket.wait().result {
                Ok(_) => admitted.push(head),
                Err(e) => {
                    if refused.is_none() {
                        refused = Some(e);
                    }
                }
            }
        }
        if let Some(e) = refused {
            // roll back the partial admission, confirming each release
            for head in admitted {
                let close = self.submit_ticket(Request::Close {
                    id: self.alloc_id(),
                    session,
                    head,
                });
                if let Ok(t) = close {
                    let _ = t.wait();
                }
            }
            return Err(e);
        }
        Ok(SessionHandle { server: self, session, heads, closed: false })
    }
}
