//! Layer-3 coordinator: the serving side of CAMformer's system integration
//! (Sec. III-A).
//!
//! CAMformer is an attention *accelerator*: XPUs produce binary Q/K and
//! BF16 V into shared memory; the accelerator serves single-query
//! attention over a resident key/value memory. This module is the
//! deployment shell a downstream system would actually run:
//!
//! * [`kv_store`]  — per-head K/V memory with decode-style append
//!   (the growing KV cache of Sec. IV-C);
//! * [`batcher`]   — dynamic batching of incoming queries (batch = 16
//!   uses the `attn_batch` artifact; stragglers run single);
//! * [`backend`]   — pluggable execution: PJRT artifacts (the real hot
//!   path), the pure-Rust functional model, or the cycle-annotated
//!   architecture simulator;
//! * [`server`]    — worker-per-head routing, request/response plumbing,
//!   shutdown;
//! * [`metrics`]   — latency/throughput accounting for the examples and
//!   benches.
//!
//! Python never appears here: the PJRT backend replays AOT artifacts.

pub mod backend;
pub mod batcher;
pub mod kv_store;
pub mod metrics;
pub mod server;

pub use backend::{AttentionBackend, FunctionalBackend};
pub use kv_store::KvStore;
pub use metrics::Metrics;
pub use server::{CamformerServer, Request, Response, ServerConfig};
