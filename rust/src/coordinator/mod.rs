//! Layer-3 coordinator: the serving side of CAMformer's system integration
//! (Sec. III-A), as a session-oriented decode-serving system.
//!
//! CAMformer is an attention *accelerator*: XPUs produce binary Q/K and
//! BF16 V into shared memory; the accelerator serves single-query
//! attention over a resident key/value memory. The paper's headline
//! serving scenario is autoregressive decoding — "CAM search over a
//! growing KV cache each step (causal)", Sec. IV-C — so this module is a
//! decode-serving simulator, not a one-shot attention demo:
//!
//! * [`session`]   — [`Session`]: live per-(session, head) KV state owned
//!   by a worker thread; sessions route session id -> shard -> head;
//! * [`kv_store`]  — [`KvStore`]: capacity-provisioned K/V memory with
//!   O(row) decode append and zero-copy padded execution views;
//! * [`server`]    — [`CamformerServer`]: `Prefill` / `Decode` / `Attend`
//!   request enum, capacity-aware typed admission, worker-per-(shard,
//!   head) routing, shutdown;
//! * [`batcher`]   — dynamic batching of incoming requests (batch = 16
//!   uses the `attn_batch` artifact; stragglers run single);
//! * [`backend`]   — pluggable execution: PJRT artifacts (the real hot
//!   path, `pjrt` feature), the pure-Rust functional model, or the
//!   cycle-annotated architecture simulator;
//! * [`error`]     — [`ServeError`]: every admission / serving failure as
//!   a typed variant;
//! * [`metrics`]   — per-op counters, latency percentiles (p50/p95/p99)
//!   and throughput for the examples and benches.
//!
//! # Serving API sketch
//!
//! ```ignore
//! let cfg = ServerConfig { shards: 2, heads: 4, kv_capacity: 1024, ..Default::default() };
//! let server = CamformerServer::start(cfg, |_| FunctionalBackend::new(1024, 64));
//! server.submit(Request::Prefill { id: 0, session: 7, head: 0, keys, values })?;
//! server.submit(Request::Decode  { id: 1, session: 7, head: 0, query, new_key, new_value })?;
//! let resp = server.collect(2);            // acks + attention outputs
//! let (metrics, window) = server.shutdown(); // p50/p99, per-op counts
//! ```
//!
//! # Test matrix
//!
//! | layer       | kind        | where |
//! |-------------|-------------|-------|
//! | batcher/kv/metrics/session | unit | in-module `#[cfg(test)]` |
//! | scorers, masks, BIMV tiles | property (seeded, `util::check`) | `accuracy::functional`, `bimv::engine` |
//! | decode serving (≥2 sessions, live append, bit-equality vs functional reference) | integration | `rust/tests/decode_serving.rs` |
//! | serving flows over functional/arch backends | integration | `rust/tests/coordinator_integration.rs` |
//! | PJRT artifacts vs functional model | golden (skips without artifacts) | `rust/tests/runtime_integration.rs` |
//!
//! Python never appears here: the PJRT backend replays AOT artifacts.

pub mod backend;
pub mod batcher;
pub mod error;
pub mod kv_store;
pub mod metrics;
pub mod server;
pub mod session;

pub use backend::{AttentionBackend, FunctionalBackend};
pub use error::ServeError;
pub use kv_store::KvStore;
pub use metrics::Metrics;
pub use server::{CamformerServer, Output, Request, Response, ServerConfig};
pub use session::{Session, SessionId};
