//! Layer-3 coordinator: the serving side of CAMformer's system integration
//! (Sec. III-A), as a session-oriented decode-serving system.
//!
//! CAMformer is an attention *accelerator*: XPUs produce binary Q/K and
//! BF16 V into shared memory; the accelerator serves single-query
//! attention over a resident key/value memory. The paper's headline
//! serving scenario is autoregressive decoding — "CAM search over a
//! growing KV cache each step (causal)", Sec. IV-C — so this module is a
//! decode-serving simulator, not a one-shot attention demo:
//!
//! * [`session`]   — [`Session`]: live per-(session, head) KV state owned
//!   by a worker thread; sessions route session id -> shard -> head;
//! * [`kv_store`]  — [`KvStore`]: capacity-provisioned K/V memory with
//!   O(row) decode append, zero-copy padded execution views, and the
//!   store-owned sign-packed key bits, maintained *incrementally* (an
//!   append packs exactly one row) and lent to backends per dispatch
//!   item (`AttendItem::packed`) so the hot path never re-packs a
//!   session's keys;
//! * [`server`]    — [`CamformerServer`]: `Prefill` / `Decode` / `Attend`
//!   request enum, capacity-aware typed admission, worker-per-(shard,
//!   head) routing, shutdown;
//! * [`batcher`]   — batched decode with speculative multi-step fusion:
//!   the request-aware [`DecodeBatcher`] plans each wire batch into
//!   dispatch groups so decode steps and read-only attends — of
//!   different sessions AND, under [`PlanMode::Speculative`] (default),
//!   several steps of the *same* session — execute as one backend
//!   dispatch (the paper's key-stationary amortisation, Fig. 5). All
//!   appends apply first in program order; each query then attends over
//!   its own *causal prefix view* of its session cache, so even a deep
//!   single-session burst amortises dispatches while staying bit-equal
//!   to sequential execution. `Prefill` remains a barrier;
//! * [`backend`]   — pluggable execution: PJRT artifacts (the real hot
//!   path, `pjrt` feature), the pure-Rust functional model (serving
//!   through the survivor-list sparse pipeline by default — softmax and
//!   BF16 contextualization walk only the ≤ final_k top-k survivors,
//!   O(n + k·d) per decode step, bit-identical to the dense baseline),
//!   or the cycle-annotated architecture simulator; all take whole
//!   dispatch groups through [`AttentionBackend::attend_batch`];
//! * [`error`]     — [`ServeError`]: every admission / serving failure as
//!   a typed variant, reported per request (one refused batch member
//!   never poisons its batch-mates);
//! * [`metrics`]   — per-op counters, batch-occupancy (queries amortised
//!   per backend dispatch), latency percentiles (p50/p95/p99) and
//!   throughput for the examples and benches.
//!
//! # Serving API
//!
//! ```
//! use camformer::coordinator::{CamformerServer, FunctionalBackend, Request, ServerConfig};
//!
//! # fn main() -> Result<(), camformer::coordinator::ServeError> {
//! let cfg = ServerConfig { shards: 1, heads: 1, kv_capacity: 64, ..Default::default() };
//! let server = CamformerServer::start(cfg, |_| FunctionalBackend::new(64, 64));
//!
//! // prefill a 4-token prompt, then run one live decode step against it
//! let (keys, values) = (vec![1.0_f32; 4 * 64], vec![0.5_f32; 4 * 64]);
//! server.submit(Request::Prefill { id: 0, session: 7, head: 0, keys, values })?;
//! server.submit(Request::Decode {
//!     id: 1,
//!     session: 7,
//!     head: 0,
//!     query: vec![1.0; 64],
//!     new_key: vec![-1.0; 64],
//!     new_value: vec![0.25; 64],
//! })?;
//!
//! let mut responses = server.collect(2); // acks + attention outputs
//! responses.sort_by_key(|r| r.id);
//! assert_eq!(responses[1].output().len(), 64);
//! assert_eq!(responses[1].seq_len(), 5); // the decode appended one row
//!
//! let (metrics, _window) = server.shutdown(); // p50/p99, per-op counts
//! assert_eq!(metrics.prefills, 1);
//! assert_eq!(metrics.decodes, 1);
//! # Ok(())
//! # }
//! ```
//!
//! # Test matrix
//!
//! | layer | kind | where |
//! |-------|------|-------|
//! | batcher (incl. both planning modes), kv (incl. prefix views), metrics, session | unit | in-module `#[cfg(test)]` |
//! | scorers, masks, prefix masking, BIMV tiles | property (seeded, `util::check`) | `accuracy::functional`, `bimv::engine` |
//! | randomized batched-vs-sequential equivalence (dispatch configs × dense/sparse pipelines) + planner invariants + fused-burst prefix boundaries | fuzz/property | `rust/tests/batcher_fuzz.rs` |
//! | decode serving (interleaved sessions, live append, batched vs sequential bit-equality, per-item admission failures) | integration | `rust/tests/decode_serving.rs` |
//! | serving flows over functional/arch backends | integration | `rust/tests/coordinator_integration.rs` |
//! | PJRT artifacts vs functional model | golden (skips without artifacts) | `rust/tests/runtime_integration.rs` |
//!
//! Python never appears here: the PJRT backend replays AOT artifacts.

pub mod backend;
pub mod batcher;
pub mod error;
pub mod kv_store;
pub mod metrics;
pub mod server;
pub mod session;

pub use backend::{AttendItem, AttentionBackend, FunctionalBackend};
pub use batcher::{BatchPolicy, DecodeBatcher, DispatchGroup, PlanMode};
pub use error::ServeError;
pub use kv_store::KvStore;
pub use metrics::Metrics;
pub use server::{CamformerServer, Output, Request, Response, ServerConfig};
pub use session::{Session, SessionId};
