//! Layer-3 coordinator: the serving side of CAMformer's system integration
//! (Sec. III-A), as a session-oriented decode-serving system.
//!
//! CAMformer is an attention *accelerator*: XPUs produce binary Q/K and
//! BF16 V into shared memory; the accelerator serves single-query
//! attention over a resident key/value memory. The paper's headline
//! serving scenario is autoregressive decoding — "CAM search over a
//! growing KV cache each step (causal)", Sec. IV-C — so this module is a
//! decode-serving simulator, not a one-shot attention demo:
//!
//! * [`client`]    — the primary client surface (ISSUE 5):
//!   [`CamformerServer::open`] admits a session **shard-wide** (one
//!   broadcast `Prefill` per head, all-or-nothing with rollback) and
//!   returns an owned [`SessionHandle`]; `decode`/`attend` return typed
//!   `#[must_use]` [`Ticket`]s backed by per-request completion slots
//!   (`wait` / `try_wait` / `wait_timeout`), and `close`/`Drop` retire
//!   the session, releasing its provisioned KV capacity;
//! * [`session`]   — [`Session`]: live per-(session, head) KV state owned
//!   by a worker thread, with lifecycle bookkeeping (logical last-touch
//!   position for deterministic LRU, pin counts while a dispatch is in
//!   flight); sessions route session id -> shard -> head;
//! * [`directory`] — [`ShardDirectory`] (ISSUE 8): the per-shard session
//!   directory shared by a shard's head workers. It merges every head's
//!   logical clock into one shard clock, selects reclaim victims ONCE
//!   shard-wide (Resident → Demoted → Resident state machine, applied
//!   atomically across heads — no split-brain sessions), and owns the
//!   simulated host-DRAM spill pool: under
//!   [`ReclaimPolicy::LruSpillToDram`] a victim's KV is parked (keys,
//!   values, packed key bits — writeback charged through the `dram`
//!   channel model) and promoted back byte-identically on its next
//!   request, so clients see a slow first token instead of
//!   [`ServeError::Evicted`];
//! * [`kv_store`]  — [`KvStore`]: capacity-provisioned K/V memory with
//!   O(row) decode append, zero-copy padded execution views, the
//!   store-owned sign-packed key bits maintained *incrementally* and
//!   lent to backends per dispatch item (`AttendItem::packed`), and
//!   explicit release on close/eviction;
//! * [`server`]    — [`CamformerServer`]: `Prefill` / `Decode` / `Attend`
//!   / `Close` request enum, capacity-aware typed admission,
//!   worker-per-(shard, head) routing, [`ReclaimPolicy`] (deny, or LRU
//!   eviction of idle sessions when admission hits the session limit OR
//!   the shared per-worker KV row budget,
//!   `ServerConfig::worker_kv_budget`), bounded standing queues that
//!   shed past `max_queue` with the retryable [`ServeError::Overloaded`],
//!   shutdown. Every request flows as an [`Envelope`] to its worker's
//!   standing scheduler (queue → admit → extend → dispatch — see the
//!   [`server`] module docs). Dispatches run under panic containment,
//!   and each worker thread is a *supervisor* that respawns crashed
//!   backend incarnations onto the same queue, failing resident
//!   sessions typed ([`ServeError::SessionLost`]) while DRAM-spilled
//!   sessions recover byte-identically from the shard directory's pool
//!   (ISSUE 9 — see "Fault containment & supervised restart" in the
//!   [`server`] docs); [`ChaosBackend`] + [`FaultPlan`] drive all of it
//!   deterministically in tests;
//! * [`batcher`]   — continuous batching with speculative multi-step
//!   fusion: each worker keeps a standing [`WorkQueue`] and *extends* an
//!   in-flight [`GroupPlan`] as requests arrive, so decode steps and
//!   read-only attends — of different sessions AND, under
//!   [`PlanMode::Speculative`] (default), several steps of the *same*
//!   session — execute as one backend dispatch (the paper's
//!   key-stationary amortisation, Fig. 5). All appends apply first in
//!   program order; each query then attends over its own *causal prefix
//!   view* of its session cache, so even a deep single-session burst
//!   amortises dispatches while staying bit-equal to sequential
//!   execution. `Prefill` remains a barrier; `Close` is a same-session
//!   barrier (other sessions fuse around it). The one-shot
//!   [`DecodeBatcher`] planner survives as the reference formulation of
//!   the same admission rules;
//! * [`backend`]   — pluggable execution: PJRT artifacts (the real hot
//!   path, `pjrt` feature), the pure-Rust functional model (serving
//!   through the fused FlashCAM streaming kernel by default — u64-word
//!   packed scoring over 16-row tiles, a running top-k threshold
//!   carried tile-to-tile, survivors contextualized at stream end, no
//!   materialized n-length score vector, O(n·d/64 + k·d) per decode
//!   step — with the survivor-list sparse pipeline and the dense
//!   baseline retained as bit-identical cross-checks, selected by
//!   [`Pipeline`]), or the cycle-annotated architecture simulator; all
//!   take whole dispatch groups through
//!   [`AttentionBackend::attend_batch`], and hot-path work counters
//!   ([`WorkStats`]) fold into [`Metrics`] at worker exit;
//! * [`error`]     — [`ServeError`]: every admission / serving failure as
//!   a typed variant, reported per request (one refused batch member
//!   never poisons its batch-mates), with
//!   [`ServeError::is_retryable`] keyed to the reclaim policy.
//!   [`ServeError::SessionLost`] is the crash variant (ISSUE 9): a
//!   worker incarnation died holding the session's KV — retryable by
//!   re-`open`, unlike policy-decided [`ServeError::Evicted`] or
//!   still-dead [`ServeError::WorkerGone`];
//! * [`metrics`]   — per-op counters (including session lifecycle:
//!   closes, evictions, KV rows released), batch-occupancy (queries
//!   amortised per backend dispatch), scheduler gauges (shed requests,
//!   queue-depth high-water mark, KV rows admitted against the shared
//!   budget and the pool's peak residency), latency percentiles
//!   (p50/p95/p99) and throughput for the examples and benches; an
//!   attached [`EnergyStages`] breakdown (priced by the layer-4
//!   `workload::EnergyAccountant` from the same counters) surfaces
//!   J/token, watts and the DRAM energy share in `Metrics::summary`.
//!
//! # Serving API
//!
//! ```
//! use std::time::Duration;
//! use camformer::coordinator::{
//!     CamformerServer, FunctionalBackend, ReclaimPolicy, ServerConfig,
//! };
//!
//! # fn main() -> Result<(), camformer::coordinator::ServeError> {
//! let cfg = ServerConfig {
//!     kv_capacity: 64,
//!     // admission past max_sessions evicts the LRU idle session
//!     // instead of failing terminally
//!     reclaim: ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO },
//!     ..Default::default()
//! };
//! let server = CamformerServer::start(cfg, |_| FunctionalBackend::new(64, 64));
//!
//! // open = one broadcast prefill across every head of the session's
//! // shard, admitted all-or-nothing; the handle owns the session
//! let session = server.open(7, vec![1.0_f32; 4 * 64], vec![0.5_f32; 4 * 64])?;
//!
//! // every request returns a typed Ticket resolving to ITS response —
//! // no id bookkeeping, no shared collect() pool
//! let step = session.decode(vec![1.0; 64], vec![-1.0; 64], vec![0.25; 64])?;
//! let r = step.wait();
//! assert_eq!(r.output().len(), 64);
//! assert_eq!(r.seq_len(), 5); // the decode appended one row
//!
//! let read = session.attend(vec![1.0; 64])?;
//! assert_eq!(read.wait().seq_len(), 5);
//!
//! session.close()?; // frees the session's KV capacity on every head
//!
//! let (metrics, _window) = server.shutdown(); // p50/p99, per-op counts
//! assert_eq!(metrics.prefills, 1);
//! assert_eq!(metrics.decodes, 1);
//! assert_eq!(metrics.closes, 1);
//! # Ok(())
//! # }
//! ```
//!
//! # Test matrix
//!
//! | layer | kind | where |
//! |-------|------|-------|
//! | batcher (work queue, incremental plans, both planning modes + Close barriers), kv (incl. prefix views, release, demote/restore round-trip), directory (shard-clock LRU, atomic multi-head marking, spill park/promote, drop-vs-demote), metrics (incl. scheduler gauges + spill-tier counters), session (lifecycle state), server (overload shedding, shared KV budget, bounded tombstones) | unit | in-module `#[cfg(test)]` |
//! | scorers, masks, prefix masking, BIMV tiles, word-parallel scoring vs the scalar bool-loop oracle, streaming top-k vs batch two-stage selection, fused-kernel bit-equality | property (seeded, `util::check`) | `accuracy::functional`, `bimv::engine`, `bimv::bitslice` |
//! | randomized batched-vs-sequential equivalence (arrival-jittered streams × reclaim policies × dispatch configs × all three [`Pipeline`]s, incl. Close + LRU-eviction streams + counter parity + `WorkStats` work parity across prefix-native configs) + planner invariants + fused-burst prefix boundaries | fuzz/property | `rust/tests/batcher_fuzz.rs` |
//! | scheduler properties: budget high-water mark never exceeds `worker_kv_budget`; bounded queues — every submit enqueues, sheds `Overloaded`, or fails typed | property | `rust/tests/scheduler_props.rs` |
//! | chaos (ISSUE 9): random seeded [`FaultPlan`]s × dispatch configs — every submitted ticket resolves (no hang, no silent drop), fault-free sessions stay bit-equal to a fault-free run, and the fault counters (`backend_faults`/`worker_panics`/`worker_restarts`/`sessions_lost`/`sessions_recovered`) reconcile with the injected faults | fuzz/property | `rust/tests/batcher_fuzz.rs` |
//! | fault containment + supervised restart: contained dispatch panics, spilled-session crash recovery (byte-identical resume on the respawned worker), handle drop after worker death, tickets pending across a restart, `wait_deadline` | integration | `rust/tests/session_api.rs` |
//! | ticket semantics (out-of-order completion, timeout expiry, dropped tickets, WorkerGone), session handles, open fan-out, eviction | integration | `rust/tests/session_api.rs` |
//! | decode serving (interleaved sessions, live append, batched vs sequential bit-equality, per-item admission failures) | integration | `rust/tests/decode_serving.rs` |
//! | serving flows over functional/arch backends | integration | `rust/tests/coordinator_integration.rs` |
//! | PJRT artifacts vs functional model | golden (skips without artifacts) | `rust/tests/runtime_integration.rs` |
//!
//! Python never appears here: the PJRT backend replays AOT artifacts.

pub mod backend;
pub mod batcher;
pub mod client;
pub mod directory;
pub mod error;
pub mod kv_store;
pub mod metrics;
pub mod server;
pub mod session;

pub use backend::{
    AttendItem, AttentionBackend, ChaosBackend, ChaosStats, Fault, FaultPlan, FunctionalBackend,
    Pipeline, WorkStats, WorkerAbort,
};
pub use batcher::{
    ArrivalWait, BatchPolicy, DecodeBatcher, DispatchGroup, GroupPlan, PlanMode, WorkQueue,
};
pub use client::{SessionHandle, Ticket};
pub use directory::{PendingAction, Reclaimed, ShardDirectory};
pub use error::ServeError;
pub use kv_store::{KvStore, SpilledKv};
pub use metrics::{EnergyStages, Metrics};
pub use server::{
    CamformerServer, Envelope, Output, ReclaimPolicy, Request, Response, ServerConfig,
};
pub use session::{Session, SessionId};
