//! The CAMformer attention server: worker-per-head request routing over
//! pluggable backends (Sec. III-A's system integration, as a deployable
//! service).
//!
//! Architecture: one dispatcher mpsc per head-worker; each worker owns its
//! backend (PJRT clients are not shared across threads), its KV memory
//! snapshot, and a dynamic batcher. Responses flow back over a shared
//! channel keyed by request id.

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backend::AttentionBackend;
use super::batcher::{next_batch, BatchPolicy};
use super::metrics::Metrics;

/// One attention query.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub head: usize,
    pub query: Vec<f32>,
}

/// The served result.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub head: usize,
    pub output: Vec<f32>,
    pub latency: Duration,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub heads: usize,
    pub batch: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            heads: 1,
            batch: BatchPolicy::default(),
        }
    }
}

struct Worker {
    tx: Sender<(Request, Instant)>,
    handle: JoinHandle<Metrics>,
}

/// The running server.
pub struct CamformerServer {
    workers: Vec<Worker>,
    resp_rx: Receiver<Response>,
    started: Instant,
}

impl CamformerServer {
    /// Start one worker per head. `make_backend(head)` builds that head's
    /// backend; `kv(head)` supplies its (keys, values) memory (row-major,
    /// padded to the backend geometry by the caller).
    pub fn start<B, FB, FK>(cfg: ServerConfig, mut make_backend: FB, mut kv: FK) -> Self
    where
        B: AttentionBackend + 'static,
        FB: FnMut(usize) -> B,
        FK: FnMut(usize) -> (Vec<f32>, Vec<f32>),
    {
        let (resp_tx, resp_rx) = mpsc::channel::<Response>();
        let mut workers = Vec::with_capacity(cfg.heads);
        for head in 0..cfg.heads {
            let (tx, rx) = mpsc::channel::<(Request, Instant)>();
            let mut backend = make_backend(head);
            let (keys, values) = kv(head);
            let resp_tx = resp_tx.clone();
            let policy = cfg.batch;
            let handle = std::thread::spawn(move || {
                let mut metrics = Metrics::new();
                while let Some(batch) = next_batch(&rx, &policy) {
                    let t0 = Instant::now();
                    let qs: Vec<Vec<f32>> =
                        batch.iter().map(|(r, _)| r.query.clone()).collect();
                    match backend.attend_batch(&qs, &keys, &values) {
                        Ok(outs) => {
                            let done = Instant::now();
                            metrics.record_batch(batch.len(), done - t0);
                            for ((req, enq), out) in batch.into_iter().zip(outs) {
                                let _ = resp_tx.send(Response {
                                    id: req.id,
                                    head: req.head,
                                    output: out,
                                    latency: done - enq,
                                });
                            }
                        }
                        Err(e) => {
                            eprintln!("worker {head}: batch failed: {e:#}");
                            for _ in &batch {
                                metrics.record_error();
                            }
                        }
                    }
                }
                metrics
            });
            workers.push(Worker { tx, handle });
        }
        CamformerServer {
            workers,
            resp_rx,
            started: Instant::now(),
        }
    }

    /// Submit a request (routed by head id).
    pub fn submit(&self, req: Request) -> Result<(), String> {
        let head = req.head;
        self.workers
            .get(head)
            .ok_or_else(|| format!("no worker for head {head}"))?
            .tx
            .send((req, Instant::now()))
            .map_err(|_| format!("worker {head} is gone"))
    }

    /// Collect exactly `n` responses (blocking).
    pub fn collect(&self, n: usize) -> Vec<Response> {
        (0..n)
            .map(|_| self.resp_rx.recv().expect("server workers alive"))
            .collect()
    }

    /// Collect responses with a timeout; returns what arrived.
    pub fn collect_timeout(&self, n: usize, timeout: Duration) -> Vec<Response> {
        let deadline = Instant::now() + timeout;
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.resp_rx.recv_timeout(deadline - now) {
                Ok(r) => out.push(r),
                Err(_) => break,
            }
        }
        out
    }

    /// Shut down: close queues, join workers, return merged metrics and
    /// the serving window.
    pub fn shutdown(self) -> (Metrics, Duration) {
        let window = self.started.elapsed();
        let mut merged = Metrics::new();
        let CamformerServer { workers, resp_rx, .. } = self;
        drop(resp_rx);
        for w in workers {
            drop(w.tx);
            if let Ok(m) = w.handle.join() {
                merged.merge(&m);
            }
        }
        (merged, window)
    }
}

/// Route a stream of requests round-robin over heads (helper for load
/// generators that don't care about head affinity).
pub fn round_robin_heads(count: usize, heads: usize) -> impl Iterator<Item = usize> {
    (0..count).map(move |i| i % heads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::FunctionalBackend;
    use crate::util::rng::Rng;

    fn test_kv(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (rng.normal_vec(n * 64), rng.normal_vec(n * 64))
    }

    #[test]
    fn serves_and_shuts_down() {
        let cfg = ServerConfig { heads: 2, ..Default::default() };
        let server = CamformerServer::start(
            cfg,
            |_| FunctionalBackend::new(128, 64),
            |h| test_kv(128, h as u64),
        );
        let mut rng = Rng::new(120);
        for i in 0..10u64 {
            server
                .submit(Request {
                    id: i,
                    head: (i % 2) as usize,
                    query: rng.normal_vec(64),
                })
                .unwrap();
        }
        let resps = server.collect(10);
        assert_eq!(resps.len(), 10);
        for r in &resps {
            assert_eq!(r.output.len(), 64);
            assert!(r.latency > Duration::ZERO);
        }
        let (metrics, window) = server.shutdown();
        assert_eq!(metrics.completed, 10);
        assert_eq!(metrics.errors, 0);
        assert!(window > Duration::ZERO);
    }

    #[test]
    fn responses_match_direct_backend() {
        let (keys, values) = test_kv(128, 7);
        let kc = keys.clone();
        let vc = values.clone();
        let server = CamformerServer::start(
            ServerConfig::default(),
            |_| FunctionalBackend::new(128, 64),
            move |_| (kc.clone(), vc.clone()),
        );
        let mut rng = Rng::new(121);
        let q = rng.normal_vec(64);
        server.submit(Request { id: 99, head: 0, query: q.clone() }).unwrap();
        let r = server.collect(1).remove(0);
        assert_eq!(r.id, 99);
        let mut direct = FunctionalBackend::new(128, 64);
        use crate::coordinator::backend::AttentionBackend as _;
        assert_eq!(r.output, direct.attend(&q, &keys, &values).unwrap());
        server.shutdown();
    }

    #[test]
    fn bad_head_rejected() {
        let server = CamformerServer::start(
            ServerConfig::default(),
            |_| FunctionalBackend::new(128, 64),
            |_| test_kv(128, 1),
        );
        let err = server.submit(Request { id: 0, head: 5, query: vec![0.0; 64] });
        assert!(err.is_err());
        server.shutdown();
    }

    #[test]
    fn round_robin_coverage() {
        let heads: Vec<usize> = round_robin_heads(10, 3).collect();
        assert_eq!(heads, vec![0, 1, 2, 0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn throughput_under_load() {
        let server = CamformerServer::start(
            ServerConfig { heads: 4, ..Default::default() },
            |_| FunctionalBackend::new(256, 64),
            |h| test_kv(256, h as u64),
        );
        let mut rng = Rng::new(122);
        let n = 200u64;
        for i in 0..n {
            server
                .submit(Request {
                    id: i,
                    head: (i % 4) as usize,
                    query: rng.normal_vec(64),
                })
                .unwrap();
        }
        let resps = server.collect(n as usize);
        assert_eq!(resps.len(), n as usize);
        let (metrics, window) = server.shutdown();
        assert_eq!(metrics.completed, n);
        assert!(metrics.throughput_per_s(window) > 50.0);
    }
}
