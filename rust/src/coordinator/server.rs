//! The CAMformer serving layer: session-oriented decode serving over
//! pluggable backends (Sec. III-A's system integration as a deployable
//! service, driving the growing KV cache of Sec. IV-C).
//!
//! Topology: sessions are partitioned across `shards`; each shard runs
//! one worker thread per head, so a request routes session id -> shard ->
//! head worker. Each worker owns its backend (PJRT clients are not shared
//! across threads), the live KV state of every session assigned to it
//! (one [`KvStore`] per session), and a dynamic batcher. Responses flow
//! back over a shared channel keyed by request id.
//!
//! Request lifecycle:
//! * [`Request::Prefill`] creates (or resets) the session on the target
//!   worker and bulk-loads the prompt K/V;
//! * [`Request::Decode`] appends one generated (k, v) pair and attends
//!   the query over the grown cache — one autoregressive step;
//! * [`Request::Attend`] is a read-only query over the current cache.
//!
//! Admission is capacity-aware and typed ([`ServeError`]): dimension and
//! provisioning violations are rejected synchronously at `submit`;
//! state-dependent failures (unknown session, per-worker session limit,
//! exhausted KV capacity) come back inside the [`Response`].

use std::collections::HashMap;
use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backend::AttentionBackend;
use super::batcher::{next_batch, BatchPolicy};
use super::error::ServeError;
use super::kv_store::KvStore;
use super::metrics::Metrics;
use super::session::{Session, SessionId};

/// One serving operation. Every variant carries the (id, session, head)
/// routing triple; ids are caller-chosen and echoed on the response.
#[derive(Clone, Debug)]
pub enum Request {
    /// Bulk-load the prompt K/V, creating the session on this head worker
    /// (re-prefilling an existing session resets its cache).
    Prefill {
        id: u64,
        session: SessionId,
        head: usize,
        keys: Vec<f32>,
        values: Vec<f32>,
    },
    /// Append one generated (k, v) pair, then attend the query over the
    /// grown cache — the causal decode step.
    Decode {
        id: u64,
        session: SessionId,
        head: usize,
        query: Vec<f32>,
        new_key: Vec<f32>,
        new_value: Vec<f32>,
    },
    /// Read-only attention over the session's current cache.
    Attend {
        id: u64,
        session: SessionId,
        head: usize,
        query: Vec<f32>,
    },
}

impl Request {
    pub fn id(&self) -> u64 {
        match self {
            Request::Prefill { id, .. }
            | Request::Decode { id, .. }
            | Request::Attend { id, .. } => *id,
        }
    }

    pub fn session(&self) -> SessionId {
        match self {
            Request::Prefill { session, .. }
            | Request::Decode { session, .. }
            | Request::Attend { session, .. } => *session,
        }
    }

    pub fn head(&self) -> usize {
        match self {
            Request::Prefill { head, .. }
            | Request::Decode { head, .. }
            | Request::Attend { head, .. } => *head,
        }
    }
}

/// Successful payload of a served request.
#[derive(Clone, Debug, PartialEq)]
pub struct Output {
    /// Attention output (empty for `Prefill` acks).
    pub output: Vec<f32>,
    /// Session KV length after the operation.
    pub seq_len: usize,
}

/// The served result.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub session: SessionId,
    pub head: usize,
    pub result: Result<Output, ServeError>,
    pub latency: Duration,
}

impl Response {
    pub fn is_ok(&self) -> bool {
        self.result.is_ok()
    }

    /// The attention output; panics on a serving error (test/demo helper).
    pub fn output(&self) -> &[f32] {
        match &self.result {
            Ok(o) => &o.output,
            Err(e) => panic!("request {} (session {}) failed: {e}", self.id, self.session),
        }
    }

    /// The post-op KV length; panics on a serving error.
    pub fn seq_len(&self) -> usize {
        match &self.result {
            Ok(o) => o.seq_len,
            Err(e) => panic!("request {} (session {}) failed: {e}", self.id, self.session),
        }
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Session partitions; each shard runs `heads` workers and owns the
    /// sessions with `session % shards == shard`.
    pub shards: usize,
    /// Attention heads (one worker per head per shard).
    pub heads: usize,
    /// Provisioned per-session context rows (BA-CAM + V-SRAM sizing).
    /// Must be at least the backend's fixed geometry (1024 for PJRT) and
    /// a multiple of `pad_quantum` for flexible backends.
    pub kv_capacity: usize,
    pub d_k: usize,
    pub d_v: usize,
    /// Admission bound: live sessions per worker.
    pub max_sessions: usize,
    /// Flexible backends pad the live KV length up to a multiple of this
    /// (the stage-1 group size g); fixed-geometry backends override it
    /// via `AttentionBackend::required_rows`.
    pub pad_quantum: usize,
    pub batch: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            shards: 1,
            heads: 1,
            kv_capacity: 1024,
            d_k: 64,
            d_v: 64,
            max_sessions: 64,
            pad_quantum: 16,
            batch: BatchPolicy::default(),
        }
    }
}

impl ServerConfig {
    /// Total worker threads (`shards * heads`).
    pub fn workers(&self) -> usize {
        self.shards * self.heads
    }

    fn worker_index(&self, session: SessionId, head: usize) -> usize {
        let shard = (session % self.shards as u64) as usize;
        shard * self.heads + head
    }
}

struct Worker {
    tx: Sender<(Request, Instant)>,
    handle: JoinHandle<Metrics>,
}

/// The running server.
pub struct CamformerServer {
    cfg: ServerConfig,
    workers: Vec<Worker>,
    resp_rx: Receiver<Response>,
    started: Instant,
}

impl CamformerServer {
    /// Start `shards * heads` workers. `make_backend(w)` builds the
    /// backend owned by worker `w` (`w = shard * heads + head`). Sessions
    /// are created lazily by `Prefill` requests.
    pub fn start<B, FB>(cfg: ServerConfig, mut make_backend: FB) -> Self
    where
        B: AttentionBackend + 'static,
        FB: FnMut(usize) -> B,
    {
        assert!(cfg.shards >= 1 && cfg.heads >= 1, "need at least one worker");
        let (resp_tx, resp_rx) = mpsc::channel::<Response>();
        let mut workers = Vec::with_capacity(cfg.workers());
        for w in 0..cfg.workers() {
            let (tx, rx) = mpsc::channel::<(Request, Instant)>();
            let backend = make_backend(w);
            let resp_tx = resp_tx.clone();
            let wcfg = cfg.clone();
            let handle = std::thread::spawn(move || worker_loop(w, wcfg, backend, rx, resp_tx));
            workers.push(Worker { tx, handle });
        }
        CamformerServer {
            cfg,
            workers,
            resp_rx,
            started: Instant::now(),
        }
    }

    /// Submit a request, routed session id -> shard -> head worker.
    /// Shape/provisioning violations are rejected here, synchronously;
    /// state-dependent failures arrive as an error [`Response`].
    pub fn submit(&self, req: Request) -> Result<(), ServeError> {
        self.validate(&req)?;
        let w = self.cfg.worker_index(req.session(), req.head());
        self.workers[w]
            .tx
            .send((req, Instant::now()))
            .map_err(|_| ServeError::WorkerGone { worker: w })
    }

    fn validate(&self, req: &Request) -> Result<(), ServeError> {
        let cfg = &self.cfg;
        let head = req.head();
        if head >= cfg.heads {
            return Err(ServeError::UnknownHead { head, heads: cfg.heads });
        }
        match req {
            Request::Prefill { keys, values, .. } => {
                if keys.len() % cfg.d_k != 0 {
                    return Err(ServeError::DimMismatch {
                        what: "prefill keys",
                        got: keys.len(),
                        want: cfg.d_k,
                    });
                }
                if values.len() % cfg.d_v != 0 {
                    return Err(ServeError::DimMismatch {
                        what: "prefill values",
                        got: values.len(),
                        want: cfg.d_v,
                    });
                }
                let rows = keys.len() / cfg.d_k;
                if rows != values.len() / cfg.d_v {
                    return Err(ServeError::DimMismatch {
                        what: "prefill rows",
                        got: values.len() / cfg.d_v,
                        want: rows,
                    });
                }
                if rows > cfg.kv_capacity {
                    return Err(ServeError::CapacityExhausted { capacity: cfg.kv_capacity });
                }
            }
            Request::Decode { query, new_key, new_value, .. } => {
                if query.len() != cfg.d_k {
                    return Err(ServeError::DimMismatch {
                        what: "decode query",
                        got: query.len(),
                        want: cfg.d_k,
                    });
                }
                if new_key.len() != cfg.d_k {
                    return Err(ServeError::DimMismatch {
                        what: "decode key",
                        got: new_key.len(),
                        want: cfg.d_k,
                    });
                }
                if new_value.len() != cfg.d_v {
                    return Err(ServeError::DimMismatch {
                        what: "decode value",
                        got: new_value.len(),
                        want: cfg.d_v,
                    });
                }
            }
            Request::Attend { query, .. } => {
                if query.len() != cfg.d_k {
                    return Err(ServeError::DimMismatch {
                        what: "query",
                        got: query.len(),
                        want: cfg.d_k,
                    });
                }
            }
        }
        Ok(())
    }

    /// Collect exactly `n` responses (blocking).
    pub fn collect(&self, n: usize) -> Vec<Response> {
        (0..n)
            .map(|_| self.resp_rx.recv().expect("server workers alive"))
            .collect()
    }

    /// Collect responses with a timeout; returns what arrived.
    pub fn collect_timeout(&self, n: usize, timeout: Duration) -> Vec<Response> {
        let deadline = Instant::now() + timeout;
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.resp_rx.recv_timeout(deadline - now) {
                Ok(r) => out.push(r),
                Err(_) => break,
            }
        }
        out
    }

    /// Shut down: close queues, join workers, return merged metrics and
    /// the serving window.
    pub fn shutdown(self) -> (Metrics, Duration) {
        let window = self.started.elapsed();
        let mut merged = Metrics::new();
        let CamformerServer { workers, resp_rx, .. } = self;
        drop(resp_rx);
        for w in workers {
            drop(w.tx);
            if let Ok(m) = w.handle.join() {
                merged.merge(&m);
            }
        }
        (merged, window)
    }
}

/// Per-op label for the worker's metrics accounting.
#[derive(Clone, Copy)]
enum Op {
    Prefill,
    Decode,
    Attend,
}

fn deliver(resp_tx: &Sender<Response>, metrics: &mut Metrics, op: Op, resp: Response) {
    match &resp.result {
        Ok(_) => {
            metrics.record(resp.latency);
            match op {
                Op::Prefill => metrics.prefills += 1,
                Op::Decode => metrics.decodes += 1,
                Op::Attend => metrics.attends += 1,
            }
        }
        Err(_) => metrics.record_error(),
    }
    let _ = resp_tx.send(resp);
}

/// Padded execution rows for `len` live keys, admission-checked against
/// the provisioned capacity AND the backend's geometry: a fixed-geometry
/// backend whose compiled n is below `len` is as exhausted as a full
/// store (without this check it would trip `KvStore::padded`'s assert
/// and panic the worker).
fn padded_rows<B: AttentionBackend>(
    backend: &B,
    cfg: &ServerConfig,
    len: usize,
) -> Result<usize, ServeError> {
    let rows = backend.required_rows(len, cfg.pad_quantum);
    if rows > cfg.kv_capacity {
        return Err(ServeError::CapacityExhausted { capacity: cfg.kv_capacity });
    }
    if rows < len {
        return Err(ServeError::CapacityExhausted { capacity: rows });
    }
    Ok(rows)
}

fn attend_one<B: AttentionBackend>(
    backend: &mut B,
    cfg: &ServerConfig,
    s: &Session,
    q: &[f32],
) -> Result<Vec<f32>, ServeError> {
    let rows = padded_rows(backend, cfg, s.store.len())?;
    let (k, v, _) = s.store.padded(rows);
    backend.attend(q, k, v).map_err(|e| ServeError::Backend(format!("{e:#}")))
}

fn attend_batch_on<B: AttentionBackend>(
    backend: &mut B,
    cfg: &ServerConfig,
    s: &Session,
    qs: &[Vec<f32>],
) -> Result<Vec<Vec<f32>>, ServeError> {
    let rows = padded_rows(backend, cfg, s.store.len())?;
    let (k, v, _) = s.store.padded(rows);
    backend
        .attend_batch(qs, k, v)
        .map_err(|e| ServeError::Backend(format!("{e:#}")))
}

/// Execute one mutating request (Prefill/Decode) against the worker's
/// session table.
fn handle_mutating<B: AttentionBackend>(
    backend: &mut B,
    cfg: &ServerConfig,
    sessions: &mut HashMap<SessionId, Session>,
    req: Request,
) -> Result<Output, ServeError> {
    match req {
        Request::Prefill { session, keys, values, .. } => {
            if !sessions.contains_key(&session) {
                if sessions.len() >= cfg.max_sessions {
                    return Err(ServeError::SessionLimit { max_sessions: cfg.max_sessions });
                }
                sessions.insert(
                    session,
                    Session::new(session, KvStore::new(cfg.kv_capacity, cfg.d_k, cfg.d_v)),
                );
            }
            let s = sessions.get_mut(&session).unwrap();
            s.store.load(&keys, &values)?;
            backend.on_kv_update();
            Ok(Output { output: Vec::new(), seq_len: s.store.len() })
        }
        Request::Decode { session, query, new_key, new_value, .. } => {
            let s = sessions
                .get_mut(&session)
                .ok_or(ServeError::UnknownSession { session })?;
            // admission for the *grown* cache runs before the append so a
            // refused Decode leaves the session state untouched (a client
            // retry must not double-append its token)
            padded_rows(backend, cfg, s.store.len() + 1)?;
            s.store.append(&new_key, &new_value)?;
            backend.on_kv_update();
            let out = attend_one(backend, cfg, s, &query)?;
            Ok(Output { output: out, seq_len: s.store.len() })
        }
        Request::Attend { .. } => unreachable!("Attend is handled by flush_attends"),
    }
}

/// Execute a run of read-only Attends that share a session as one backend
/// batch.
#[allow(clippy::too_many_arguments)]
fn flush_attends<B: AttentionBackend>(
    backend: &mut B,
    cfg: &ServerConfig,
    sessions: &HashMap<SessionId, Session>,
    session: SessionId,
    pending: &mut Vec<(u64, Vec<f32>, Instant)>,
    head: usize,
    metrics: &mut Metrics,
    resp_tx: &Sender<Response>,
) {
    if pending.is_empty() {
        return;
    }
    let items = std::mem::take(pending);
    match sessions.get(&session) {
        None => {
            for (id, _, enq) in items {
                deliver(
                    resp_tx,
                    metrics,
                    Op::Attend,
                    Response {
                        id,
                        session,
                        head,
                        result: Err(ServeError::UnknownSession { session }),
                        latency: enq.elapsed(),
                    },
                );
            }
        }
        Some(s) => {
            // the queries are already owned — split them out rather than
            // deep-cloning on the hot path
            let (metas, qs): (Vec<(u64, Instant)>, Vec<Vec<f32>>) =
                items.into_iter().map(|(id, q, enq)| ((id, enq), q)).unzip();
            match attend_batch_on(backend, cfg, s, &qs) {
                Ok(outs) => {
                    for ((id, enq), out) in metas.into_iter().zip(outs) {
                        deliver(
                            resp_tx,
                            metrics,
                            Op::Attend,
                            Response {
                                id,
                                session,
                                head,
                                result: Ok(Output { output: out, seq_len: s.store.len() }),
                                latency: enq.elapsed(),
                            },
                        );
                    }
                }
                Err(e) => {
                    for (id, enq) in metas {
                        deliver(
                            resp_tx,
                            metrics,
                            Op::Attend,
                            Response {
                                id,
                                session,
                                head,
                                result: Err(e.clone()),
                                latency: enq.elapsed(),
                            },
                        );
                    }
                }
            }
        }
    }
}

fn worker_loop<B: AttentionBackend>(
    worker: usize,
    cfg: ServerConfig,
    mut backend: B,
    rx: Receiver<(Request, Instant)>,
    resp_tx: Sender<Response>,
) -> Metrics {
    let head = worker % cfg.heads;
    let mut metrics = Metrics::new();
    let mut sessions: HashMap<SessionId, Session> = HashMap::new();
    while let Some(batch) = next_batch(&rx, &cfg.batch) {
        metrics.note_batch();
        // Consecutive read-only Attends on the same session coalesce into
        // one backend batch; mutating ops (Prefill/Decode) are barriers,
        // so per-session program order is preserved.
        let mut pending: Vec<(u64, Vec<f32>, Instant)> = Vec::new();
        let mut pending_session: SessionId = 0;
        for (req, enq) in batch {
            match req {
                Request::Attend { id, session, query, .. } => {
                    if !pending.is_empty() && pending_session != session {
                        flush_attends(
                            &mut backend,
                            &cfg,
                            &sessions,
                            pending_session,
                            &mut pending,
                            head,
                            &mut metrics,
                            &resp_tx,
                        );
                    }
                    pending_session = session;
                    pending.push((id, query, enq));
                }
                other => {
                    flush_attends(
                        &mut backend,
                        &cfg,
                        &sessions,
                        pending_session,
                        &mut pending,
                        head,
                        &mut metrics,
                        &resp_tx,
                    );
                    let (id, session) = (other.id(), other.session());
                    let op = match other {
                        Request::Prefill { .. } => Op::Prefill,
                        _ => Op::Decode,
                    };
                    let result = handle_mutating(&mut backend, &cfg, &mut sessions, other);
                    deliver(
                        &resp_tx,
                        &mut metrics,
                        op,
                        Response { id, session, head, result, latency: enq.elapsed() },
                    );
                }
            }
        }
        flush_attends(
            &mut backend,
            &cfg,
            &sessions,
            pending_session,
            &mut pending,
            head,
            &mut metrics,
            &resp_tx,
        );
    }
    metrics
}

/// Route a stream of requests round-robin over heads (helper for load
/// generators that don't care about head affinity).
pub fn round_robin_heads(count: usize, heads: usize) -> impl Iterator<Item = usize> {
    (0..count).map(move |i| i % heads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::FunctionalBackend;
    use crate::util::rng::Rng;

    fn functional_server(cfg: ServerConfig) -> CamformerServer {
        let n = cfg.kv_capacity;
        CamformerServer::start(cfg, move |_| FunctionalBackend::new(n, 64))
    }

    #[test]
    fn serves_and_shuts_down() {
        let cfg = ServerConfig { heads: 2, kv_capacity: 128, ..Default::default() };
        let server = functional_server(cfg);
        let mut rng = Rng::new(120);
        // one session, prefilled independently on both head workers
        for h in 0..2usize {
            server
                .submit(Request::Prefill {
                    id: 1000 + h as u64,
                    session: 1,
                    head: h,
                    keys: rng.normal_vec(128 * 64),
                    values: rng.normal_vec(128 * 64),
                })
                .unwrap();
        }
        for i in 0..10u64 {
            server
                .submit(Request::Attend {
                    id: i,
                    session: 1,
                    head: (i % 2) as usize,
                    query: rng.normal_vec(64),
                })
                .unwrap();
        }
        let resps = server.collect(12);
        assert_eq!(resps.len(), 12);
        for r in &resps {
            assert!(r.is_ok(), "{:?}", r.result);
            assert!(r.latency > Duration::ZERO);
            if r.id < 1000 {
                assert_eq!(r.output().len(), 64);
                assert_eq!(r.seq_len(), 128);
            }
        }
        let (metrics, window) = server.shutdown();
        assert_eq!(metrics.completed, 12);
        assert_eq!(metrics.prefills, 2);
        assert_eq!(metrics.attends, 10);
        assert_eq!(metrics.errors, 0);
        assert!(window > Duration::ZERO);
    }

    #[test]
    fn responses_match_direct_backend() {
        let mut rng = Rng::new(121);
        let keys = rng.normal_vec(128 * 64);
        let values = rng.normal_vec(128 * 64);
        let cfg = ServerConfig { kv_capacity: 128, ..Default::default() };
        let server = functional_server(cfg);
        server
            .submit(Request::Prefill {
                id: 0,
                session: 7,
                head: 0,
                keys: keys.clone(),
                values: values.clone(),
            })
            .unwrap();
        let q = rng.normal_vec(64);
        server
            .submit(Request::Attend { id: 99, session: 7, head: 0, query: q.clone() })
            .unwrap();
        let mut resps = server.collect(2);
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps[1].id, 99);
        let mut direct = FunctionalBackend::new(128, 64);
        use crate::coordinator::backend::AttentionBackend as _;
        assert_eq!(resps[1].output(), &direct.attend(&q, &keys, &values).unwrap()[..]);
        server.shutdown();
    }

    #[test]
    fn bad_head_rejected_synchronously() {
        let server = functional_server(ServerConfig::default());
        let err = server.submit(Request::Attend {
            id: 0,
            session: 0,
            head: 5,
            query: vec![0.0; 64],
        });
        assert_eq!(err, Err(ServeError::UnknownHead { head: 5, heads: 1 }));
        server.shutdown();
    }

    #[test]
    fn bad_dims_rejected_synchronously() {
        let server = functional_server(ServerConfig::default());
        let err = server.submit(Request::Attend {
            id: 0,
            session: 0,
            head: 0,
            query: vec![0.0; 63],
        });
        assert_eq!(
            err,
            Err(ServeError::DimMismatch { what: "query", got: 63, want: 64 })
        );
        let err = server.submit(Request::Prefill {
            id: 1,
            session: 0,
            head: 0,
            keys: vec![0.0; 2 * 64],
            values: vec![0.0; 3 * 64],
        });
        assert!(matches!(err, Err(ServeError::DimMismatch { .. })));
        server.shutdown();
    }

    #[test]
    fn unknown_session_reported_in_response() {
        let server = functional_server(ServerConfig::default());
        server
            .submit(Request::Attend { id: 3, session: 42, head: 0, query: vec![0.0; 64] })
            .unwrap();
        let r = server.collect(1).remove(0);
        assert_eq!(r.result, Err(ServeError::UnknownSession { session: 42 }));
        let (m, _) = server.shutdown();
        assert_eq!(m.errors, 1);
        assert_eq!(m.completed, 0);
    }

    #[test]
    fn session_limit_enforced() {
        let cfg = ServerConfig { max_sessions: 2, kv_capacity: 16, ..Default::default() };
        let server = functional_server(cfg);
        let mut rng = Rng::new(122);
        for sid in 0..3u64 {
            server
                .submit(Request::Prefill {
                    id: sid,
                    session: sid,
                    head: 0,
                    keys: rng.normal_vec(16 * 64),
                    values: rng.normal_vec(16 * 64),
                })
                .unwrap();
        }
        let mut resps = server.collect(3);
        resps.sort_by_key(|r| r.id);
        assert!(resps[0].is_ok());
        assert!(resps[1].is_ok());
        assert_eq!(resps[2].result, Err(ServeError::SessionLimit { max_sessions: 2 }));
        server.shutdown();
    }

    /// A backend compiled for a fixed 16-row context, like PJRT but tiny.
    struct Fixed16Backend(FunctionalBackend);

    impl AttentionBackend for Fixed16Backend {
        fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> anyhow::Result<Vec<f32>> {
            self.0.attend(q, k, v)
        }

        fn required_rows(&self, _rows: usize, _quantum: usize) -> usize {
            16
        }

        fn on_kv_update(&mut self) {
            self.0.on_kv_update();
        }

        fn name(&self) -> &'static str {
            "fixed16"
        }
    }

    #[test]
    fn fixed_geometry_overflow_is_typed_not_a_panic() {
        // kv_capacity above the backend's compiled context: growing past
        // the geometry must yield CapacityExhausted, not panic the worker,
        // and a refused decode must not commit its append
        let cfg = ServerConfig { kv_capacity: 64, ..Default::default() };
        let server =
            CamformerServer::start(cfg, |_| Fixed16Backend(FunctionalBackend::new(16, 64)));
        let mut rng = Rng::new(124);
        server
            .submit(Request::Prefill {
                id: 0,
                session: 0,
                head: 0,
                keys: rng.normal_vec(16 * 64),
                values: rng.normal_vec(16 * 64),
            })
            .unwrap();
        server
            .submit(Request::Decode {
                id: 1,
                session: 0,
                head: 0,
                query: rng.normal_vec(64),
                new_key: rng.normal_vec(64),
                new_value: rng.normal_vec(64),
            })
            .unwrap();
        server
            .submit(Request::Attend { id: 2, session: 0, head: 0, query: rng.normal_vec(64) })
            .unwrap();
        let mut resps = server.collect(3);
        resps.sort_by_key(|r| r.id);
        assert!(resps[0].is_ok());
        assert_eq!(resps[1].result, Err(ServeError::CapacityExhausted { capacity: 16 }));
        assert!(resps[2].is_ok(), "worker must survive a refused decode");
        assert_eq!(resps[2].seq_len(), 16, "refused decode must not grow the cache");
        server.shutdown();
    }

    #[test]
    fn round_robin_coverage() {
        let heads: Vec<usize> = round_robin_heads(10, 3).collect();
        assert_eq!(heads, vec![0, 1, 2, 0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn throughput_under_load() {
        let cfg = ServerConfig { heads: 4, kv_capacity: 256, ..Default::default() };
        let server = functional_server(cfg);
        let mut rng = Rng::new(123);
        for h in 0..4usize {
            server
                .submit(Request::Prefill {
                    id: 1000 + h as u64,
                    session: 1,
                    head: h,
                    keys: rng.normal_vec(256 * 64),
                    values: rng.normal_vec(256 * 64),
                })
                .unwrap();
        }
        let n = 200u64;
        for i in 0..n {
            server
                .submit(Request::Attend {
                    id: i,
                    session: 1,
                    head: (i % 4) as usize,
                    query: rng.normal_vec(64),
                })
                .unwrap();
        }
        let resps = server.collect(n as usize + 4);
        assert_eq!(resps.len(), n as usize + 4);
        let (metrics, window) = server.shutdown();
        assert_eq!(metrics.completed, n + 4);
        assert_eq!(metrics.attends, n);
        assert!(metrics.throughput_per_s(window) > 50.0);
    }
}
