//! The CAMformer serving layer: session-oriented decode serving over
//! pluggable backends (Sec. III-A's system integration as a deployable
//! service, driving the growing KV cache of Sec. IV-C).
//!
//! Topology: sessions are partitioned across `shards`; each shard runs
//! one worker thread per head, so a request routes session id -> shard ->
//! head worker. Each worker owns its backend (PJRT clients are not shared
//! across threads), the live KV state of every session assigned to it
//! (one [`KvStore`] per session), and a standing [`WorkQueue`] driven by
//! the scheduling loop below. Every response flows back through its
//! request's per-request completion slot — the channel backing the
//! caller's [`Ticket`] (the legacy `submit`/`collect` response pool is
//! gone).
//!
//! # The standing scheduler
//!
//! Each worker runs a continuous-batching loop over its standing queue
//! (the TGI-router shape — standing `Queue` + background batching task —
//! adapted to the bit-equality constraint below):
//!
//! ```text
//!  submit_ticket ──► bounded queue ──► admit ──► extend ──► dispatch
//!   (sheds with      (WorkQueue,       (GroupPlan  (wait up   (one
//!    Overloaded       FIFO across       takes the   to        backend
//!    at max_queue)    scheduling        longest     max_wait  attend_batch
//!                     cycles)           eligible    for new   per plan;
//!                                       prefix)     arrivals) barriers
//!                                                             run alone)
//! ```
//!
//! * **queue** — submissions land on the worker's [`WorkQueue`] and
//!   persist across scheduling cycles; the queue is bounded by
//!   [`ServerConfig::max_queue`], and a submission past the bound is
//!   refused synchronously with the *retryable*
//!   [`ServeError::Overloaded`] (a `Close` is exempt: lifecycle teardown
//!   frees capacity, so shedding it could wedge an overloaded worker).
//! * **admit** — the scheduler opens a [`GroupPlan`] and moves the
//!   longest eligible queue prefix into it, under exactly the
//!   Prefill-barrier / same-session-`Close` / [`PlanMode`] hazard rules
//!   of the one-shot planner (they share the admission code). KV-row
//!   admission against the shared [`ServerConfig::worker_kv_budget`]
//!   happens at execution, in program order (prefill cost = its rows,
//!   decode cost = 1 row), so it is identical across groupings.
//! * **extend** — while the plan is below `max_batch` and within
//!   `max_wait` of its opening, new arrivals keep joining the in-flight
//!   plan. A blocked queue front (typically a waiting `Prefill`) stops
//!   the extension early once the backlog reaches
//!   `waiting_served_ratio * plan_len` — the TGI-style knob deciding
//!   when waiting prefills preempt decode extension.
//! * **dispatch** — the plan executes as one batched backend dispatch
//!   (appends first, then a single attend); a `Prefill` at the queue
//!   front executes alone, immediately, as a barrier.
//!
//! The scheduler never reorders: dispatch plans are contiguous prefixes
//! of per-worker arrival order, which is what keeps batched outputs —
//! and LRU eviction decisions — bit-equal to sequential dispatch (see
//! the [`batcher`](super::batcher) module docs).
//!
//! Request lifecycle:
//! * [`Request::Prefill`] creates (or resets) the session on the target
//!   worker and bulk-loads the prompt K/V — [`CamformerServer::open`]
//!   broadcasts one prefill to every head of the shard, all-or-nothing;
//! * [`Request::Decode`] appends one generated (k, v) pair and attends
//!   the query over the grown cache — one autoregressive step;
//! * [`Request::Attend`] is a read-only query over the current cache;
//! * [`Request::Close`] retires the session and releases its provisioned
//!   KV capacity (issued by `SessionHandle::close` / `Drop`).
//!
//! Execution is cross-session batched with speculative multi-step
//! fusion: the worker schedules a dispatch plan from its standing queue
//! (see above), applies every plan's KV appends first —
//! recording each query's *causal prefix*, the session KV length at its
//! own program position — then runs *one* batched attend in which each
//! query sees a prefix view of its own session cache. Outputs are
//! bit-equal to sequential dispatch: a group may hold many decode steps
//! of one session, but every query attends over exactly the rows it
//! would have observed sequentially (later speculative appends behave
//! as pad — natively for prefix-aware backends, via a materialised
//! literal-pad copy otherwise), and a failed dispatch rolls every
//! speculative append back. A `Close` rides in the group but executes
//! after the dispatch (the planner guarantees no same-session item
//! follows it in-group — the *same-session barrier*), so batch-mates
//! still borrow the store they were planned against.
//!
//! Admission is capacity-aware and typed ([`ServeError`]): dimension and
//! provisioning violations are rejected synchronously at submission;
//! state-dependent failures (unknown session, per-worker session limit,
//! exhausted KV capacity) come back inside the [`Response`] — and are
//! strictly per-request, so one refused item never poisons its
//! batch-mates. Under [`ReclaimPolicy::LruEvictIdle`] a `Prefill` that
//! hits the session limit evicts the least-recently-used idle session
//! instead of failing terminally; the victim's state is released and
//! its subsequent requests answer [`ServeError::Evicted`] until it is
//! re-opened. Under [`ReclaimPolicy::LruSpillToDram`] the victim is
//! *demoted* into the shard's simulated host DRAM tier instead — its
//! next request promotes the KV back (a slow first token, charged
//! through the `dram` channel model) and the client never observes
//! `Evicted`. Reclamation can only run inside a `Prefill` (or
//! promotion) barrier — never while a dispatch group is mid-flight —
//! which is the structural guarantee that a session with in-flight
//! (fused speculative) queries is never victimized; the pin counts on
//! [`Session`] restate that invariant as defense-in-depth.
//!
//! Reclamation is **shard-coordinated** (ISSUE 8): every worker of a
//! shard reports its touches into the shared
//! [`ShardDirectory`](super::directory::ShardDirectory), and an
//! over-budget barrier selects ONE victim shard-wide by the merged
//! shard clock, marking it on every head atomically — the initiating
//! worker applies its own transition inside the barrier and the other
//! heads apply theirs at the top of their next scheduling cycle, so a
//! session is fully resident, fully demoted, or fully dropped — never
//! split across heads (the pre-PR-8 per-worker eviction could answer
//! `Evicted` on one head while serving stale state on another). On a
//! single-head shard the shard clock *is* the worker's logical clock
//! (program-order request positions), so with `min_idle = ZERO` victim
//! choice is deterministic and batched execution stays bit-equal to
//! sequential dispatch (a non-zero `min_idle` gate reads the wall
//! clock and is inherently timing-dependent).
//!
//! # Fault containment & supervised restart (ISSUE 9)
//!
//! The dispatch is the containment boundary: `attend_batch` runs under
//! `catch_unwind`, so a panicking dispatch is rolled back and answered
//! with a typed [`ServeError::Backend`] exactly like an `Err`, and the
//! worker keeps serving (`worker_panics` counts it). Each worker thread
//! actually runs a *supervisor* owning the queue and tombstone state
//! across backend *incarnations*: a panic that escapes containment (a
//! [`WorkerAbort`](super::backend::WorkerAbort) payload, or a panic
//! outside any dispatch) kills the incarnation, and the supervisor
//! respawns a fresh backend onto the same queue. Sessions resident on
//! the dead incarnation are failed shard-wide (typed
//! [`ServeError::SessionLost`], retryable by re-`open`) — but sessions
//! parked in the shard's DRAM spill pool, which lives outside every
//! worker thread, survive the crash and promote byte-identically onto
//! the respawned worker (`sessions_lost` / `sessions_recovered`). No
//! ticket ever hangs: queued requests of lost sessions are drained with
//! typed errors and in-flight ones resolve `WorkerGone` through their
//! dropped response channels. Deterministic fault injection for all of
//! this lives in [`ChaosBackend`](super::backend::ChaosBackend).
//!
//! [`Ticket`]: super::client::Ticket
//! [`WorkQueue`]: super::batcher::WorkQueue
//! [`GroupPlan`]: super::batcher::GroupPlan
//! [`PlanMode`]: super::batcher::PlanMode

use std::collections::{HashMap, HashSet, VecDeque};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backend::{AttendItem, AttentionBackend, WorkerAbort};
use super::batcher::{ArrivalWait, BatchPolicy, GroupPlan, WorkQueue};
use super::client::Ticket;
use super::directory::{PendingAction, Reclaimed, ShardDirectory};
use super::error::ServeError;
use super::kv_store::{KvStore, KEY_PAD};
use super::metrics::Metrics;
use super::session::{Session, SessionId};

/// One serving operation. Every variant carries the (id, session, head)
/// routing triple; ids are caller-chosen and echoed on the response.
#[derive(Clone, Debug)]
pub enum Request {
    /// Bulk-load the prompt K/V, creating the session on this head worker
    /// (re-prefilling an existing session resets its cache).
    Prefill {
        id: u64,
        session: SessionId,
        head: usize,
        keys: Vec<f32>,
        values: Vec<f32>,
    },
    /// Append one generated (k, v) pair, then attend the query over the
    /// grown cache — the causal decode step.
    Decode {
        id: u64,
        session: SessionId,
        head: usize,
        query: Vec<f32>,
        new_key: Vec<f32>,
        new_value: Vec<f32>,
    },
    /// Read-only attention over the session's current cache.
    Attend {
        id: u64,
        session: SessionId,
        head: usize,
        query: Vec<f32>,
    },
    /// Retire the session on this head worker and release its
    /// provisioned KV capacity. Acknowledged with an empty [`Output`]
    /// whose `seq_len` is the context length at close time.
    Close {
        id: u64,
        session: SessionId,
        head: usize,
    },
}

impl Request {
    pub fn id(&self) -> u64 {
        match self {
            Request::Prefill { id, .. }
            | Request::Decode { id, .. }
            | Request::Attend { id, .. }
            | Request::Close { id, .. } => *id,
        }
    }

    pub fn session(&self) -> SessionId {
        match self {
            Request::Prefill { session, .. }
            | Request::Decode { session, .. }
            | Request::Attend { session, .. }
            | Request::Close { session, .. } => *session,
        }
    }

    pub fn head(&self) -> usize {
        match self {
            Request::Prefill { head, .. }
            | Request::Decode { head, .. }
            | Request::Attend { head, .. }
            | Request::Close { head, .. } => *head,
        }
    }
}

/// Successful payload of a served request.
#[derive(Clone, Debug, PartialEq)]
pub struct Output {
    /// Attention output (empty for `Prefill` / `Close` acks).
    pub output: Vec<f32>,
    /// Session KV length after the operation.
    pub seq_len: usize,
}

/// The served result.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub session: SessionId,
    pub head: usize,
    pub result: Result<Output, ServeError>,
    pub latency: Duration,
}

impl Response {
    pub fn is_ok(&self) -> bool {
        self.result.is_ok()
    }

    /// The attention output; panics on a serving error (test/demo helper).
    pub fn output(&self) -> &[f32] {
        match &self.result {
            Ok(o) => &o.output,
            Err(e) => panic!("request {} (session {}) failed: {e}", self.id, self.session),
        }
    }

    /// The post-op KV length; panics on a serving error.
    pub fn seq_len(&self) -> usize {
        match &self.result {
            Ok(o) => o.seq_len,
            Err(e) => panic!("request {} (session {}) failed: {e}", self.id, self.session),
        }
    }
}

/// One queued unit of serving work: the request, its enqueue time (for
/// latency accounting) and the per-request completion slot its
/// [`Response`] goes to — the channel backing the caller's [`Ticket`].
/// Dropping the receiving ticket simply discards the response (nothing
/// leaks — the slot IS the channel). This is what worker queues carry
/// and what [`GroupPlan`]s are built from.
///
/// [`Ticket`]: super::client::Ticket
#[derive(Debug)]
pub struct Envelope {
    pub req: Request,
    pub enq: Instant,
    pub sink: Sender<Response>,
}

impl Envelope {
    /// Wrap a request with a detached completion slot (the receiver is
    /// dropped immediately, so a delivered response is discarded): the
    /// constructor for planner tests and doctests that plan envelopes
    /// without ever executing them.
    pub fn detached(req: Request) -> Self {
        let (tx, _rx) = mpsc::channel();
        Envelope { req, enq: Instant::now(), sink: tx }
    }
}

/// What a worker does when a `Prefill` needs a session slot and the
/// worker is at `max_sessions`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ReclaimPolicy {
    /// Refuse admission with [`ServeError::SessionLimit`] (the pre-PR-5
    /// behavior): capacity only frees when the caller closes sessions.
    #[default]
    Deny,
    /// Evict the least-recently-used session that has been idle for at
    /// least `min_idle` and has no in-flight dispatch queries (pinned
    /// sessions are never victims). The victim's subsequent requests
    /// answer [`ServeError::Evicted`] until it is re-opened.
    ///
    /// Scope and determinism: the victim is selected once per *shard*
    /// (ISSUE 8) — the shard directory merges every head worker's
    /// logical clock and marks the single least-recently-used session on
    /// all heads atomically, so a shard-wide session is dropped
    /// everywhere or nowhere, never split. `min_idle = Duration::ZERO`
    /// makes victim choice fully deterministic (the shard clock alone
    /// decides); a non-zero gate compares wall-clock idle time and is
    /// timing-dependent by nature.
    LruEvictIdle { min_idle: Duration },
    /// Like `LruEvictIdle`, but the shard-wide victim is *demoted* into
    /// the simulated host DRAM tier instead of dropped: every head
    /// parks its copy of the victim's KV (keys, values, packed key
    /// bits) in the shard's spill pool, charging the writeback through
    /// the `dram` channel model. The victim's next `Decode`/`Attend`
    /// promotes the rows back (a slow first token with modeled read
    /// latency), so clients never observe [`ServeError::Evicted`] under
    /// this policy. Victim selection is shard-coordinated and
    /// deterministic exactly as for `LruEvictIdle`.
    LruSpillToDram { min_idle: Duration },
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Session partitions; each shard runs `heads` workers and owns the
    /// sessions with `session % shards == shard`.
    pub shards: usize,
    /// Attention heads (one worker per head per shard).
    pub heads: usize,
    /// Provisioned per-session context rows (BA-CAM + V-SRAM sizing).
    /// Must be at least the backend's fixed geometry (1024 for PJRT) and
    /// a multiple of `pad_quantum` for flexible backends. This is
    /// *physical provisioning* per session; the binding admission
    /// constraint across sessions is `worker_kv_budget`.
    pub kv_capacity: usize,
    /// Shared per-worker KV row budget — the pool every resident session
    /// draws from, modelling globally-budgeted on-chip memory (X-Former
    /// style) rather than per-sequence SRAM. Admission is charged in
    /// program order: a `Prefill` costs its row count (a re-prefill is
    /// charged net of the rows it replaces), a `Decode` costs 1 row, and
    /// `Close`/eviction refund their session's rows. A `Prefill` that
    /// would overdraw the pool evicts LRU-idle sessions under
    /// [`ReclaimPolicy::LruEvictIdle`] or is refused with
    /// [`ServeError::CapacityExhausted`]; an overdrawing `Decode` is
    /// always refused (eviction never runs mid-dispatch).
    pub worker_kv_budget: usize,
    /// Bound on each worker's standing queue: a submission finding the
    /// queue at this depth is refused synchronously with the retryable
    /// [`ServeError::Overloaded`] instead of queueing unboundedly
    /// (`Close` is exempt — see the module docs).
    pub max_queue: usize,
    pub d_k: usize,
    pub d_v: usize,
    /// Admission bound: live sessions per worker.
    pub max_sessions: usize,
    /// What to do when a `Prefill` hits `max_sessions` on a worker.
    pub reclaim: ReclaimPolicy,
    /// Flexible backends pad the live KV length up to a multiple of this
    /// (the stage-1 group size g); fixed-geometry backends override it
    /// via `AttentionBackend::required_rows`.
    pub pad_quantum: usize,
    pub batch: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            shards: 1,
            heads: 1,
            kv_capacity: 1024,
            d_k: 64,
            d_v: 64,
            max_sessions: 64,
            reclaim: ReclaimPolicy::Deny,
            pad_quantum: 16,
            batch: BatchPolicy::default(),
            // every session fully grown still fits (1024 rows x 64
            // sessions): the pool only binds when configured tighter
            worker_kv_budget: 1024 * 64,
            max_queue: 4096,
        }
    }
}

impl ServerConfig {
    /// Total worker threads (`shards * heads`).
    pub fn workers(&self) -> usize {
        self.shards * self.heads
    }

    pub(crate) fn worker_index(&self, session: SessionId, head: usize) -> usize {
        let shard = (session % self.shards as u64) as usize;
        shard * self.heads + head
    }
}

/// Cross-thread gauges shared between a worker and the submit path: the
/// live standing-queue depth (incremented at submission, decremented
/// when the scheduler pops the envelope into an execution plan), its
/// high-water mark, and the requests shed with
/// [`ServeError::Overloaded`]. The worker folds them into its
/// [`Metrics`] at exit.
#[derive(Default)]
struct WorkerGauges {
    depth: AtomicU64,
    depth_hwm: AtomicU64,
    sheds: AtomicU64,
}

struct Worker {
    tx: Sender<Envelope>,
    gauges: Arc<WorkerGauges>,
    handle: JoinHandle<Metrics>,
}

/// The running server.
pub struct CamformerServer {
    cfg: ServerConfig,
    workers: Vec<Worker>,
    /// One coordinated session directory per shard, shared by that
    /// shard's head workers: residency + merged-clock LRU order + the
    /// DRAM spill pool (ISSUE 8). Folded into the merged metrics at
    /// shutdown.
    dirs: Vec<Arc<ShardDirectory>>,
    started: Instant,
    /// Ids for internally-issued requests (session-handle tickets, open
    /// fan-out, drop-closes). They live in the top half of the id space
    /// so they never collide with caller-chosen request ids.
    next_id: AtomicU64,
    /// Per-head closes that failed inside `SessionHandle::drop`'s
    /// fire-and-forget teardown — the drop path cannot return them, so
    /// they are counted here instead of vanishing silently.
    close_failures: AtomicU64,
}

impl CamformerServer {
    /// Start `shards * heads` workers. `make_backend(w)` builds the
    /// backend owned by worker `w` (`w = shard * heads + head`). Sessions
    /// are created by [`CamformerServer::open`] (or legacy `Prefill`
    /// requests).
    ///
    /// The factory is `Fn + Send + Sync` (not `FnMut`) because it outlives
    /// this call: each worker's supervisor re-invokes it *on the worker
    /// thread* to build a fresh backend after a crashed incarnation
    /// (ISSUE 9's supervised restart). A factory that panics kills its
    /// supervisor outright — a worker that cannot rebuild its backend is
    /// genuinely gone, not restartable.
    pub fn start<B, FB>(cfg: ServerConfig, make_backend: FB) -> Self
    where
        B: AttentionBackend + 'static,
        FB: Fn(usize) -> B + Send + Sync + 'static,
    {
        assert!(cfg.shards >= 1 && cfg.heads >= 1, "need at least one worker");
        let dirs: Vec<Arc<ShardDirectory>> =
            (0..cfg.shards).map(|_| Arc::new(ShardDirectory::new(cfg.heads))).collect();
        let make = Arc::new(make_backend);
        let mut workers = Vec::with_capacity(cfg.workers());
        for w in 0..cfg.workers() {
            let (tx, rx) = mpsc::channel::<Envelope>();
            let gauges = Arc::new(WorkerGauges::default());
            let wgauges = gauges.clone();
            let wcfg = cfg.clone();
            let dir = dirs[w / cfg.heads].clone();
            let make = make.clone();
            let handle = std::thread::spawn(move || {
                supervise(w, wcfg, move |i| (*make)(i), rx, wgauges, dir)
            });
            workers.push(Worker { tx, gauges, handle });
        }
        CamformerServer {
            cfg,
            workers,
            dirs,
            started: Instant::now(),
            next_id: AtomicU64::new(1 << 62),
            close_failures: AtomicU64::new(0),
        }
    }

    /// The configuration this server was started with.
    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Allocate an id for an internally-issued request.
    pub(crate) fn alloc_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Record `n` failed per-head closes from a `SessionHandle`'s
    /// fire-and-forget drop teardown (surfaced as
    /// `Metrics::close_failures` at shutdown).
    pub(crate) fn note_close_failures(&self, n: u64) {
        if n > 0 {
            self.close_failures.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Submit a request and receive a typed [`Ticket`] — a per-request
    /// completion slot resolving to exactly this request's [`Response`]
    /// (`wait` / `try_wait` / `wait_timeout`), with no cross-request
    /// correlation needed. Shape/provisioning violations are rejected
    /// here, synchronously, and so is overload: a worker whose standing
    /// queue is at [`ServerConfig::max_queue`] answers the *retryable*
    /// [`ServeError::Overloaded`] instead of queueing unboundedly
    /// (`Close` is exempt — teardown always enqueues). Every other
    /// state-dependent failure arrives inside the ticket's response.
    /// This is the primitive under [`SessionHandle`]'s
    /// `decode`/`attend`/`close`.
    ///
    /// [`Ticket`]: super::client::Ticket
    /// [`SessionHandle`]: super::client::SessionHandle
    pub fn submit_ticket(&self, req: Request) -> Result<Ticket, ServeError> {
        self.validate(&req)?;
        let (id, session, head) = (req.id(), req.session(), req.head());
        let w = self.cfg.worker_index(session, head);
        let gauges = &self.workers[w].gauges;
        // count before sending, so the worker's dequeue decrement can
        // never precede this increment; revert on refusal. Concurrent
        // submitters racing the bound each see the other's increment and
        // shed conservatively — the depth never exceeds max_queue (plus
        // exempt closes).
        let depth = gauges.depth.fetch_add(1, Ordering::Relaxed) + 1;
        if depth > self.cfg.max_queue as u64 && !matches!(req, Request::Close { .. }) {
            gauges.depth.fetch_sub(1, Ordering::Relaxed);
            gauges.sheds.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::Overloaded { queue_depth: (depth - 1) as usize });
        }
        gauges.depth_hwm.fetch_max(depth, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel::<Response>();
        self.workers[w]
            .tx
            .send(Envelope { req, enq: Instant::now(), sink: tx })
            .map_err(|_| {
                gauges.depth.fetch_sub(1, Ordering::Relaxed);
                ServeError::WorkerGone { worker: w }
            })?;
        Ok(Ticket::new(id, session, head, w, rx))
    }

    /// Live standing-queue depth of the worker serving (`session`,
    /// `head`) — the load signal behind [`ServeError::Overloaded`]
    /// (useful for client-side backoff and load tests).
    pub fn queue_depth(&self, session: SessionId, head: usize) -> usize {
        let w = self.cfg.worker_index(session, head);
        self.workers[w].gauges.depth.load(Ordering::Relaxed) as usize
    }

    pub(crate) fn validate(&self, req: &Request) -> Result<(), ServeError> {
        let cfg = &self.cfg;
        let head = req.head();
        if head >= cfg.heads {
            return Err(ServeError::UnknownHead { head, heads: cfg.heads });
        }
        match req {
            Request::Prefill { keys, values, .. } => {
                if keys.len() % cfg.d_k != 0 {
                    return Err(ServeError::DimMismatch {
                        what: "prefill keys",
                        got: keys.len(),
                        want: cfg.d_k,
                    });
                }
                if values.len() % cfg.d_v != 0 {
                    return Err(ServeError::DimMismatch {
                        what: "prefill values",
                        got: values.len(),
                        want: cfg.d_v,
                    });
                }
                let rows = keys.len() / cfg.d_k;
                if rows != values.len() / cfg.d_v {
                    return Err(ServeError::DimMismatch {
                        what: "prefill rows",
                        got: values.len() / cfg.d_v,
                        want: rows,
                    });
                }
                if rows > cfg.kv_capacity {
                    return Err(ServeError::CapacityExhausted { capacity: cfg.kv_capacity });
                }
            }
            Request::Decode { query, new_key, new_value, .. } => {
                if query.len() != cfg.d_k {
                    return Err(ServeError::DimMismatch {
                        what: "decode query",
                        got: query.len(),
                        want: cfg.d_k,
                    });
                }
                if new_key.len() != cfg.d_k {
                    return Err(ServeError::DimMismatch {
                        what: "decode key",
                        got: new_key.len(),
                        want: cfg.d_k,
                    });
                }
                if new_value.len() != cfg.d_v {
                    return Err(ServeError::DimMismatch {
                        what: "decode value",
                        got: new_value.len(),
                        want: cfg.d_v,
                    });
                }
            }
            Request::Attend { query, .. } => {
                if query.len() != cfg.d_k {
                    return Err(ServeError::DimMismatch {
                        what: "query",
                        got: query.len(),
                        want: cfg.d_k,
                    });
                }
            }
            // the routing triple is all a Close carries; head was checked
            Request::Close { .. } => {}
        }
        Ok(())
    }

    /// Shut down: close queues, join workers (each drains its standing
    /// queue first), fold the shard directories' spill-tier counters and
    /// the drop-path close failures, return merged metrics and the
    /// serving window.
    ///
    /// A worker whose *supervisor* died (a panic outside every
    /// containment and restart scope — e.g. the backend factory itself
    /// panicking on a respawn) took its `Metrics` with it; this used to
    /// be swallowed silently (`if let Ok(m)`). Now the death is counted
    /// (`worker_panics`) and the submission-side gauges — which live
    /// outside the thread — are folded so sheds and the queue-depth peak
    /// survive the crash.
    pub fn shutdown(self) -> (Metrics, Duration) {
        let window = self.started.elapsed();
        let mut merged = Metrics::new();
        let CamformerServer { workers, dirs, close_failures, .. } = self;
        for w in workers {
            drop(w.tx);
            match w.handle.join() {
                Ok(m) => merged.merge(&m),
                Err(_) => {
                    merged.worker_panics += 1;
                    merged.shed_requests += w.gauges.sheds.load(Ordering::Relaxed);
                    merged.queue_depth_max =
                        merged.queue_depth_max.max(w.gauges.depth_hwm.load(Ordering::Relaxed));
                }
            }
        }
        for dir in &dirs {
            dir.fold_metrics(&mut merged);
        }
        merged.close_failures += close_failures.load(Ordering::Relaxed);
        (merged, window)
    }
}

/// Per-op label for the worker's metrics accounting.
#[derive(Clone, Copy)]
enum Op {
    Prefill,
    Decode,
    Attend,
    Close,
}

fn deliver(metrics: &mut Metrics, op: Op, sink: &Sender<Response>, resp: Response) {
    match &resp.result {
        Ok(_) => {
            metrics.record(resp.latency);
            match op {
                Op::Prefill => metrics.prefills += 1,
                Op::Decode => metrics.decodes += 1,
                Op::Attend => metrics.attends += 1,
                Op::Close => metrics.closes += 1,
            }
        }
        Err(_) => metrics.record_error(),
    }
    // a send error means the consumer is gone (dropped Ticket, server
    // shutting down): the response is simply discarded
    let _ = sink.send(resp);
}

/// Bounded tombstone set for sessions reclaimed by a dropping policy:
/// their requests answer [`ServeError::Evicted`] (not `UnknownSession`)
/// until the id is re-opened or the tombstone is acknowledged by a
/// `Close`. The pre-PR-8 `HashSet` grew without bound on workloads that
/// churn through session ids and never close the victims (the
/// acknowledgement path only pruned ids whose owner asked); this keeps
/// FIFO insertion order and drops the oldest tombstone past `cap`, so a
/// very stale victim degrades to the equally-terminal `UnknownSession`
/// instead of pinning memory forever.
struct EvictedSet {
    set: HashSet<SessionId>,
    order: VecDeque<SessionId>,
    cap: usize,
}

impl EvictedSet {
    fn new(cap: usize) -> Self {
        EvictedSet { set: HashSet::new(), order: VecDeque::new(), cap: cap.max(1) }
    }

    fn insert(&mut self, session: SessionId) {
        if self.set.insert(session) {
            self.order.push_back(session);
            while self.order.len() > self.cap {
                if let Some(oldest) = self.order.pop_front() {
                    self.set.remove(&oldest);
                }
            }
        }
    }

    fn remove(&mut self, session: SessionId) {
        if self.set.remove(&session) {
            self.order.retain(|&s| s != session);
        }
    }

    fn contains(&self, session: SessionId) -> bool {
        self.set.contains(&session)
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.set.len()
    }
}

/// The typed miss for a session absent from the worker's table: sessions
/// lost to a worker crash answer [`ServeError::SessionLost`], evicted
/// sessions answer [`ServeError::Evicted`], both until re-opened;
/// everything else is an [`ServeError::UnknownSession`]. Lost wins over
/// evicted — a crash is the fresher (and more actionable) cause.
fn missing_session(evicted: &EvictedSet, lost: &EvictedSet, session: SessionId) -> ServeError {
    if lost.contains(session) {
        ServeError::SessionLost { session }
    } else if evicted.contains(session) {
        ServeError::Evicted { session }
    } else {
        ServeError::UnknownSession { session }
    }
}

/// Padded execution rows for `len` live keys, admission-checked against
/// the provisioned capacity AND the backend's geometry: a fixed-geometry
/// backend whose compiled n is below `len` is as exhausted as a full
/// store (without this check it would trip `KvStore::padded`'s assert
/// and panic the worker).
fn padded_rows<B: AttentionBackend>(
    backend: &B,
    cfg: &ServerConfig,
    len: usize,
) -> Result<usize, ServeError> {
    let rows = backend.required_rows(len, cfg.pad_quantum);
    if rows > cfg.kv_capacity {
        return Err(ServeError::CapacityExhausted { capacity: cfg.kv_capacity });
    }
    if rows < len {
        return Err(ServeError::CapacityExhausted { capacity: rows });
    }
    Ok(rows)
}

/// KV rows currently resident across the worker's sessions — the draw
/// on the shared `worker_kv_budget` pool. Session counts are small
/// (bounded by `max_sessions`), so summing on demand stays O(sessions)
/// and is automatically consistent through closes, evictions, rollbacks
/// and re-prefills.
fn used_rows(sessions: &HashMap<SessionId, Session>) -> usize {
    sessions.values().map(|s| s.kv_rows()).sum()
}

/// Apply the shard directory's pending demote/drop decisions to this
/// worker's local state — the fan-out half of atomic shard-wide
/// eviction. Decisions are made once (under the directory mutex, by the
/// barrier that hit pressure) and applied lazily by every head: the
/// initiator inside its own barrier, the other heads here at the top of
/// their next scheduling cycle. A demote parks the session's whole KV
/// store (keys, values, packed key bits) in the shard's DRAM spill
/// pool; a drop releases it and leaves an `Evicted` tombstone. Both
/// refund the session's provisioned rows to the budget accounting
/// (`kv_rows_released`), exactly as the pre-PR-8 per-worker eviction
/// did. A *lost* sentence (a sibling head's worker crashed holding part
/// of the session's KV — ISSUE 9) releases the local copy the same way
/// but leaves a `SessionLost` tombstone, and applies even when this head
/// holds no copy: the tombstone is what turns the session's subsequent
/// requests into typed `SessionLost` answers. Returns whether anything
/// changed.
#[allow(clippy::too_many_arguments)]
fn apply_shard_transitions<B: AttentionBackend>(
    backend: &mut B,
    dir: &ShardDirectory,
    head: usize,
    sessions: &mut HashMap<SessionId, Session>,
    evicted: &mut EvictedSet,
    lost: &mut EvictedSet,
    metrics: &mut Metrics,
) -> bool {
    let mut changed = false;
    for (sid, action) in dir.pending_for(head) {
        if matches!(action, PendingAction::Lost) {
            if sessions.get(&sid).is_some_and(Session::is_pinned) {
                // see the pinned guard below: never tear down mid-dispatch
                continue;
            }
            if let Some(s) = sessions.remove(&sid) {
                metrics.kv_rows_released += s.store.release() as u64;
                changed = true;
            }
            lost.insert(sid);
            dir.note_gone(sid, head);
            continue;
        }
        match sessions.get(&sid) {
            None => {
                // no local copy to demote/drop (e.g. the id was only ever
                // prefilled on another head): just clear the sentence
                dir.note_gone(sid, head);
                continue;
            }
            // structurally impossible (decisions and applications both run
            // between dispatch groups, when pin counts are zero) — but a
            // pinned session must never be torn down, so leave the
            // decision pending rather than violate the invariant
            Some(s) if s.is_pinned() => continue,
            Some(_) => {}
        }
        let s = sessions.remove(&sid).expect("present above");
        match action {
            PendingAction::Demote => {
                metrics.kv_rows_released += s.store.capacity as u64;
                dir.park(sid, head, s.store.demote());
            }
            PendingAction::Drop => {
                metrics.kv_rows_released += s.store.release() as u64;
                evicted.insert(sid);
                dir.note_gone(sid, head);
            }
            PendingAction::Lost => unreachable!("handled above"),
        }
        changed = true;
    }
    if changed {
        // local stores went away: bust any backend identity caches
        backend.on_kv_update();
    }
    changed
}

/// One round of shard-coordinated reclamation under memory pressure
/// (budget rows or a session slot), run only inside `Prefill`/promotion
/// barriers. The shard directory selects ONE victim shard-wide — the
/// least-recently-used unpinned idle session by the merged shard clock,
/// never `keep` (its rows are being replaced / restored, not added) —
/// and marks it on every head atomically; this worker applies its own
/// transition immediately and the caller re-checks pressure (the
/// caller's `while pressure { reclaim_round()? }` loop). When every
/// eligible candidate is already sentenced by a concurrent decision
/// (both heads of a shard hitting pressure during a broadcast `open`),
/// no *new* victim is marked — the pending transitions are applied
/// instead, so victim SETS, demotion counts and eviction counts stay
/// deterministic across dispatch configs. `Err(refusal)` when the
/// policy denies reclamation or nothing is reclaimable.
#[allow(clippy::too_many_arguments)]
fn reclaim_round<B: AttentionBackend>(
    backend: &mut B,
    cfg: &ServerConfig,
    dir: &ShardDirectory,
    head: usize,
    sessions: &mut HashMap<SessionId, Session>,
    evicted: &mut EvictedSet,
    lost: &mut EvictedSet,
    metrics: &mut Metrics,
    keep: SessionId,
    refusal: ServeError,
) -> Result<(), ServeError> {
    let (drop_victim, min_idle) = match cfg.reclaim {
        ReclaimPolicy::Deny => return Err(refusal),
        ReclaimPolicy::LruEvictIdle { min_idle } => (true, min_idle),
        ReclaimPolicy::LruSpillToDram { min_idle } => (false, min_idle),
    };
    let candidates: Vec<SessionId> = sessions
        .values()
        .filter(|s| s.id != keep && !s.is_pinned() && s.idle_for() >= min_idle)
        .map(|s| s.id)
        .collect();
    match dir.evict_shard_wide(head, &candidates, drop_victim) {
        Reclaimed::Victim(_) => {
            if drop_victim {
                // counted once, by the deciding worker (demotions are
                // counted inside the directory the same way)
                metrics.evictions += 1;
            }
            apply_shard_transitions(backend, dir, head, sessions, evicted, lost, metrics);
            Ok(())
        }
        Reclaimed::PendingElsewhere => {
            // every candidate is already sentenced: applying the pending
            // transitions frees their rows — if that changes nothing
            // (unreachable: a sentenced local candidate is by definition
            // applicable), refuse rather than spin
            if apply_shard_transitions(backend, dir, head, sessions, evicted, lost, metrics) {
                Ok(())
            } else {
                Err(refusal)
            }
        }
        Reclaimed::None => Err(refusal),
    }
}

/// Execute a `Prefill` barrier against the worker's session table:
/// charge the shared KV budget (reclaiming LRU-idle sessions
/// shard-wide — drop or demote per the policy — until the load fits),
/// then reclaim a session *slot* the same way if the worker is at its
/// session limit, then admit the session into the shard directory.
#[allow(clippy::too_many_arguments)]
fn handle_prefill<B: AttentionBackend>(
    backend: &mut B,
    cfg: &ServerConfig,
    dir: &ShardDirectory,
    head: usize,
    sessions: &mut HashMap<SessionId, Session>,
    evicted: &mut EvictedSet,
    lost: &mut EvictedSet,
    metrics: &mut Metrics,
    clock: u64,
    session: SessionId,
    keys: Vec<f32>,
    values: Vec<f32>,
) -> Result<Output, ServeError> {
    // Shared-pool admission first, before any slot is created: prefill
    // cost = its rows, net of the rows a re-prefill replaces. A refused
    // prefill must leave the table untouched. `replaced` is re-read each
    // round because a concurrent shard decision (the other head of a
    // broadcast `open` under pressure) may demote the target itself.
    let rows = keys.len() / cfg.d_k;
    loop {
        let replaced = sessions.get(&session).map(|s| s.kv_rows()).unwrap_or(0);
        if used_rows(sessions) - replaced + rows <= cfg.worker_kv_budget {
            break;
        }
        reclaim_round(
            backend,
            cfg,
            dir,
            head,
            sessions,
            evicted,
            lost,
            metrics,
            session,
            ServeError::CapacityExhausted { capacity: cfg.worker_kv_budget },
        )?;
    }
    while !sessions.contains_key(&session) && sessions.len() >= cfg.max_sessions {
        reclaim_round(
            backend,
            cfg,
            dir,
            head,
            sessions,
            evicted,
            lost,
            metrics,
            session,
            ServeError::SessionLimit { max_sessions: cfg.max_sessions },
        )?;
    }
    if !sessions.contains_key(&session) {
        // (re-)opening revives an evicted or crash-lost id
        evicted.remove(session);
        lost.remove(session);
        sessions.insert(
            session,
            Session::new(session, KvStore::new(cfg.kv_capacity, cfg.d_k, cfg.d_v)),
        );
    }
    // directory admission: registers residency on this head, refreshes
    // the shard-clock LRU position, and discards any stale spilled copy
    // for this (session, head) — a re-prefill replaces it wholesale
    let generation = dir.admit(session, head);
    let s = sessions.get_mut(&session).unwrap();
    s.generation = generation;
    s.touch(clock);
    s.store.load(&keys, &values)?;
    backend.on_kv_update();
    let seq_len = s.store.len();
    metrics.note_kv_admission(rows, used_rows(sessions));
    Ok(Output { output: Vec::new(), seq_len })
}

/// A query surviving the append phase, waiting for the batched attend.
struct PendingQuery {
    id: u64,
    session: SessionId,
    op: Op,
    query: Vec<f32>,
    enq: Instant,
    /// Causal prefix: the session KV length at this query's own program
    /// position. Speculative fusion may grow the store past it before
    /// the dispatch runs, so the attend is bounded to these rows.
    prefix: usize,
    sink: Sender<Response>,
}

/// A `Close` admitted in phase 1, executed after the group's dispatch
/// (its program position is after every same-session batch-mate — the
/// planner's same-session-barrier rule — and earlier batch-mates still
/// borrow the store during the dispatch).
struct PendingClose {
    id: u64,
    session: SessionId,
    enq: Instant,
    sink: Sender<Response>,
}

/// Where a planned item's K/V execution view comes from.
enum ViewSource {
    /// Zero-copy prefix view of the session store.
    Store { rows: usize },
    /// Materialised literal-pad prefix copy (index into the dispatch's
    /// scratch arena) — the fallback for backends without native prefix
    /// support when the store already holds rows past the prefix.
    Scratch(usize),
}

/// Phases 2 and 3 of a dispatch group: bind each surviving query to a
/// view of its own causal prefix, run ONE backend dispatch, deliver.
///
/// Failures are strictly per-request: an item refused at admission is
/// answered with its typed error and dropped from the dispatch, and the
/// rest of the batch proceeds untouched. Only a backend execution
/// failure — which has no per-item attribution — fails the whole
/// dispatch; it rolls every speculative append of the group back (via
/// `baseline`), so an errored request never leaves state behind (a
/// client retry must not double-append). A *panicking* dispatch is
/// contained and takes the exact same rollback + typed-answer path
/// (`worker_panics` counts it); only a [`WorkerAbort`] payload escapes,
/// on purpose, to kill the incarnation.
#[allow(clippy::too_many_arguments)]
fn dispatch_pending<B: AttentionBackend>(
    backend: &mut B,
    cfg: &ServerConfig,
    sessions: &mut HashMap<SessionId, Session>,
    pending: &[PendingQuery],
    baseline: &[(SessionId, usize)],
    head: usize,
    metrics: &mut Metrics,
) {
    // Phase 2 — bind each surviving query to a view of its own causal
    // prefix. Same-session items are made adjacent (stable sort by
    // session, program order within a session) so backends that detect
    // same-memory runs by buffer identity (the PJRT artifact path) see
    // each key memory as one contiguous run per dispatch; response
    // identity rides on the pending index.
    let mut order: Vec<usize> = (0..pending.len()).collect();
    order.sort_by_key(|&i| pending[i].session);
    // (pending idx, seq_len reported, view source) per dispatched item
    let mut planned: Vec<(usize, usize, ViewSource)> = Vec::with_capacity(pending.len());
    let mut scratch: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
    let mut scratch_tags: Vec<(SessionId, usize, usize)> = Vec::new();
    for &i in &order {
        let p = &pending[i];
        let s = sessions.get(&p.session).expect("admission checked in phase 1");
        match padded_rows(backend, cfg, p.prefix) {
            Ok(rows) => {
                // masking only matters when the view would expose rows
                // appended after this query's program position
                let needs_mask = rows > p.prefix && s.store.len() > p.prefix;
                let source = if !needs_mask || backend.supports_prefix_views() {
                    ViewSource::Store { rows }
                } else {
                    // materialise the sequential view: causal prefix +
                    // literal pad tail. One copy per (session, prefix,
                    // rows) — burst-mates at the same prefix share it, so
                    // run-detecting backends still see one buffer.
                    let tag = (p.session, p.prefix, rows);
                    let slot = match scratch_tags.iter().position(|&t| t == tag) {
                        Some(j) => j,
                        None => {
                            let live_k = &s.store.keys()[..p.prefix * cfg.d_k];
                            let live_v = &s.store.values()[..p.prefix * cfg.d_v];
                            let mut k = vec![KEY_PAD; rows * cfg.d_k];
                            k[..live_k.len()].copy_from_slice(live_k);
                            let mut v = vec![0.0f32; rows * cfg.d_v];
                            v[..live_v.len()].copy_from_slice(live_v);
                            scratch.push((k, v));
                            scratch_tags.push(tag);
                            scratch.len() - 1
                        }
                    };
                    ViewSource::Scratch(slot)
                };
                planned.push((i, p.prefix, source));
            }
            Err(e) => deliver(
                metrics,
                p.op,
                &p.sink,
                Response {
                    id: p.id,
                    session: p.session,
                    head,
                    result: Err(e),
                    latency: p.enq.elapsed(),
                },
            ),
        }
    }
    if planned.is_empty() {
        return;
    }
    let mut batch: Vec<AttendItem<'_>> = Vec::with_capacity(planned.len());
    for (i, _, source) in &planned {
        let p = &pending[*i];
        // store-backed items also carry the store-owned sign-packed key
        // bits, so bit-level backends score without re-packing (the
        // scratch copies are detached buffers and carry none)
        let (keys, values, packed) = match source {
            ViewSource::Store { rows } => {
                let s = sessions.get(&p.session).expect("still resident");
                let (k, v, _) = s.store.padded_prefix_view(p.prefix, *rows);
                (k, v, Some(s.store.packed_view(*rows)))
            }
            ViewSource::Scratch(j) => (&scratch[*j].0[..], &scratch[*j].1[..], None),
        };
        batch.push(AttendItem { query: &p.query, keys, values, prefix_rows: p.prefix, packed });
    }

    // Phase 3 — one backend dispatch for the whole group, under panic
    // containment (ISSUE 9): a panicking dispatch is caught, rolled back
    // and answered typed exactly like an `Err`, so a poison request
    // cannot take the head down. The one deliberate exception is a
    // [`WorkerAbort`] payload — the "this incarnation must die" signal —
    // which containment re-raises for the supervisor to handle.
    // Occupancy is only recorded for dispatches that actually served
    // their queries.
    let caught = catch_unwind(AssertUnwindSafe(|| backend.attend_batch(&batch)));
    let occupancy = batch.len();
    drop(batch); // release the session borrows before any rollback
    let result: Result<Vec<Vec<f32>>, String> = match caught {
        Ok(Ok(outs)) => Ok(outs),
        Ok(Err(e)) => {
            metrics.backend_faults += 1;
            Err(format!("{e:#}"))
        }
        Err(payload) => {
            if payload.downcast_ref::<WorkerAbort>().is_some() {
                resume_unwind(payload);
            }
            metrics.worker_panics += 1;
            Err(format!("dispatch panicked: {}", panic_message(&*payload)))
        }
    };
    match result {
        Ok(outs) => {
            metrics.note_dispatch(occupancy);
            for ((i, seq_len, _), out) in planned.into_iter().zip(outs) {
                let p = &pending[i];
                deliver(
                    metrics,
                    p.op,
                    &p.sink,
                    Response {
                        id: p.id,
                        session: p.session,
                        head,
                        result: Ok(Output { output: out, seq_len }),
                        latency: p.enq.elapsed(),
                    },
                );
            }
        }
        Err(e) => {
            // every item of this dispatch answers with an error, so none
            // of the group's speculative appends may survive
            for &(session, len) in baseline {
                if let Some(s) = sessions.get_mut(&session) {
                    s.store.truncate(len);
                }
            }
            if !baseline.is_empty() {
                backend.on_kv_update();
            }
            let err = ServeError::Backend(e);
            for (i, _, _) in planned {
                let p = &pending[i];
                deliver(
                    metrics,
                    p.op,
                    &p.sink,
                    Response {
                        id: p.id,
                        session: p.session,
                        head,
                        result: Err(err.clone()),
                        latency: p.enq.elapsed(),
                    },
                );
            }
        }
    }
}

/// Execute one dispatch group: apply every `Decode`'s KV append first
/// (in program order), recording each query's causal prefix, then run a
/// *single* batched attend in which each query sees a view of its own
/// session cache bounded at that prefix — so speculative fusion of many
/// same-session steps stays bit-equal to sequential dispatch. `Close`
/// items are admitted in program order (touching the worker's logical
/// clock like every request) but execute after the dispatch, releasing
/// the session's provisioned capacity. Sessions with queries in flight
/// are pinned for the duration of the dispatch.
#[allow(clippy::too_many_arguments)]
fn execute_batch<B: AttentionBackend>(
    backend: &mut B,
    cfg: &ServerConfig,
    dir: &ShardDirectory,
    sessions: &mut HashMap<SessionId, Session>,
    evicted: &mut EvictedSet,
    lost: &mut EvictedSet,
    clock: &mut u64,
    items: Vec<Envelope>,
    head: usize,
    metrics: &mut Metrics,
) {
    // Phase 1 — the mutating half of each Decode, in program order.
    // Every query's causal prefix is captured here, so later appends of
    // the same session (speculative fusion) cannot leak into it.
    let mut pending: Vec<PendingQuery> = Vec::with_capacity(items.len());
    let mut closes: Vec<PendingClose> = Vec::new();
    // pre-group KV length per mutated session, for failed-dispatch rollback
    let mut baseline: Vec<(SessionId, usize)> = Vec::new();
    let mut mutated = false;
    for env in items {
        let Envelope { req, enq, sink } = env;
        *clock += 1;
        match req {
            Request::Decode { id, session, query, new_key, new_value, .. } => {
                // shared-budget admission: one row per decode append. The
                // residency sum runs in program order, before the append,
                // so the charge (and the high-water mark it implies) is
                // identical under every legal grouping of the same stream.
                let resident = used_rows(sessions);
                let appended = match sessions.get_mut(&session) {
                    None => Err(missing_session(evicted, lost, session)),
                    Some(s) => {
                        s.touch(*clock);
                        // mirror every local touch into the shard clock so
                        // LRU victim choice merges all heads' recency
                        dir.touch(session);
                        // admission for the *grown* cache runs before the
                        // append so a refused Decode leaves the session
                        // untouched (a client retry must not double-append)
                        match padded_rows(backend, cfg, s.store.len() + 1) {
                            Err(e) => Err(e),
                            Ok(_) if resident + 1 > cfg.worker_kv_budget => {
                                // a Decode never evicts (eviction runs only
                                // inside Prefill barriers): overdrawing the
                                // pool is refused outright
                                Err(ServeError::CapacityExhausted {
                                    capacity: cfg.worker_kv_budget,
                                })
                            }
                            Ok(_) => {
                                let before = s.store.len();
                                match s.store.append(&new_key, &new_value) {
                                    Err(e) => Err(e),
                                    Ok(()) => {
                                        if !baseline.iter().any(|&(sid, _)| sid == session) {
                                            baseline.push((session, before));
                                        }
                                        s.pin();
                                        Ok(before + 1)
                                    }
                                }
                            }
                        }
                    }
                };
                if appended.is_ok() {
                    metrics.note_kv_admission(1, resident + 1);
                }
                match appended {
                    Ok(prefix) => {
                        mutated = true;
                        pending.push(PendingQuery {
                            id,
                            session,
                            op: Op::Decode,
                            query,
                            enq,
                            prefix,
                            sink,
                        });
                    }
                    Err(e) => deliver(
                        metrics,
                        Op::Decode,
                        &sink,
                        Response { id, session, head, result: Err(e), latency: enq.elapsed() },
                    ),
                }
            }
            Request::Attend { id, session, query, .. } => match sessions.get_mut(&session) {
                Some(s) => {
                    s.touch(*clock);
                    dir.touch(session);
                    s.pin();
                    let prefix = s.store.len();
                    pending.push(PendingQuery {
                        id,
                        session,
                        op: Op::Attend,
                        query,
                        enq,
                        prefix,
                        sink,
                    });
                }
                None => deliver(
                    metrics,
                    Op::Attend,
                    &sink,
                    Response {
                        id,
                        session,
                        head,
                        result: Err(missing_session(evicted, lost, session)),
                        latency: enq.elapsed(),
                    },
                ),
            },
            Request::Close { id, session, .. } => match sessions.get_mut(&session) {
                Some(s) => {
                    s.touch(*clock);
                    dir.touch(session);
                    closes.push(PendingClose { id, session, enq, sink });
                }
                None => {
                    // a demoted session can be closed without promoting it
                    // back: discard the parked copy and acknowledge with
                    // its spilled context length (its provisioned rows
                    // were already refunded at demotion)
                    if let Some(len) = dir.close_spilled(session, head) {
                        deliver(
                            metrics,
                            Op::Close,
                            &sink,
                            Response {
                                id,
                                session,
                                head,
                                result: Ok(Output { output: Vec::new(), seq_len: len }),
                                latency: enq.elapsed(),
                            },
                        );
                    } else {
                        let err = missing_session(evicted, lost, session);
                        // a Close of an evicted or crash-lost id acknowledges
                        // the loss (handle drop/close does this): forget the
                        // tombstone so the sets stay bounded by
                        // un-acknowledged victims instead of growing with
                        // every id ever evicted or lost
                        evicted.remove(session);
                        lost.remove(session);
                        deliver(
                            metrics,
                            Op::Close,
                            &sink,
                            Response {
                                id,
                                session,
                                head,
                                result: Err(err),
                                latency: enq.elapsed(),
                            },
                        );
                    }
                }
            },
            Request::Prefill { .. } => unreachable!("prefills are Barrier groups"),
        }
    }
    if mutated {
        // the KV buffers mutate in place; the stores maintain their own
        // packed key bits incrementally, but a custom backend caching a
        // derivative by buffer identity still needs the explicit signal
        backend.on_kv_update();
    }
    if !pending.is_empty() {
        dispatch_pending(backend, cfg, sessions, &pending, &baseline, head, metrics);
    }
    // every pending query pinned its session exactly once in phase 1
    for p in &pending {
        if let Some(s) = sessions.get_mut(&p.session) {
            s.unpin();
        }
    }
    // Phase 4 — retire closed sessions, in program order (the planner
    // guarantees no same-session item followed them in this group). A
    // Close is not tied to the dispatch outcome: even after a failed
    // (rolled-back) dispatch the caller asked for the session to go.
    let closed_any = !closes.is_empty();
    for c in closes {
        let seq_len = sessions.get(&c.session).map(|s| s.store.len()).unwrap_or(0);
        if let Some(s) = sessions.remove(&c.session) {
            metrics.kv_rows_released += s.store.release() as u64;
            dir.note_gone(c.session, head);
        }
        deliver(
            metrics,
            Op::Close,
            &c.sink,
            Response {
                id: c.id,
                session: c.session,
                head,
                result: Ok(Output { output: Vec::new(), seq_len }),
                latency: c.enq.elapsed(),
            },
        );
    }
    if closed_any {
        // closed stores are gone: bust any backend identity caches
        backend.on_kv_update();
    }
}

/// Run one `Prefill` as its own barrier group: it rebuilds the session's
/// KV store (and may evict under the shared budget), so nothing may be
/// batched around it.
#[allow(clippy::too_many_arguments)]
fn run_prefill_barrier<B: AttentionBackend>(
    backend: &mut B,
    cfg: &ServerConfig,
    dir: &ShardDirectory,
    sessions: &mut HashMap<SessionId, Session>,
    evicted: &mut EvictedSet,
    lost: &mut EvictedSet,
    metrics: &mut Metrics,
    clock: &mut u64,
    env: Envelope,
    head: usize,
) {
    let Envelope { req, enq, sink } = env;
    let (id, session) = (req.id(), req.session());
    *clock += 1;
    let result = match req {
        Request::Prefill { keys, values, .. } => handle_prefill(
            backend, cfg, dir, head, sessions, evicted, lost, metrics, *clock, session, keys,
            values,
        ),
        _ => unreachable!("only prefills run as barriers"),
    };
    deliver(
        metrics,
        Op::Prefill,
        &sink,
        Response { id, session, head, result, latency: enq.elapsed() },
    );
}

/// Whether serving `req` first requires promoting its session out of
/// the shard's DRAM spill pool: a `Decode`/`Attend` whose session has
/// no local copy but a parked one. Such a request cannot join a
/// dispatch group — promotion rebuilds the session store, so it runs as
/// its own barrier, exactly like `Prefill`.
fn needs_promotion(
    dir: &ShardDirectory,
    sessions: &HashMap<SessionId, Session>,
    head: usize,
    req: &Request,
) -> bool {
    match req {
        Request::Decode { session, .. } | Request::Attend { session, .. } => {
            !sessions.contains_key(session) && dir.is_spilled(*session, head)
        }
        _ => false,
    }
}

/// Promote `session`'s parked KV out of the shard's DRAM spill pool
/// back into residency, as a front-of-queue barrier (the demotion
/// mirror of the `Prefill` barrier): first make room — budget rows for
/// the restored length, then a session slot — through the same
/// shard-coordinated reclaim loop, then charge the modeled DRAM read
/// and re-insert the session byte-identically (keys, values, packed key
/// bits). The triggering envelope is NOT consumed: on `Ok` it stays at
/// the front and executes in the next cycle against the restored store
/// (its slow first token now carries the promotion cost); on `Err` the
/// caller pops and refuses it.
#[allow(clippy::too_many_arguments)]
fn run_promotion_barrier<B: AttentionBackend>(
    backend: &mut B,
    cfg: &ServerConfig,
    dir: &ShardDirectory,
    head: usize,
    sessions: &mut HashMap<SessionId, Session>,
    evicted: &mut EvictedSet,
    lost: &mut EvictedSet,
    metrics: &mut Metrics,
    session: SessionId,
) -> Result<(), ServeError> {
    let Some((len, _capacity)) = dir.spilled_shape(session, head) else {
        // raced away (closed or re-admitted between the front check and
        // here): nothing to promote — the normal path serves the request
        return Ok(());
    };
    while used_rows(sessions) + len > cfg.worker_kv_budget {
        reclaim_round(
            backend,
            cfg,
            dir,
            head,
            sessions,
            evicted,
            lost,
            metrics,
            session,
            ServeError::CapacityExhausted { capacity: cfg.worker_kv_budget },
        )?;
    }
    while sessions.len() >= cfg.max_sessions {
        reclaim_round(
            backend,
            cfg,
            dir,
            head,
            sessions,
            evicted,
            lost,
            metrics,
            session,
            ServeError::SessionLimit { max_sessions: cfg.max_sessions },
        )?;
    }
    let Some((store, generation, _latency_ns)) = dir.promote(session, head) else {
        return Ok(());
    };
    let restored = store.len();
    let mut s = Session::new(session, store);
    s.generation = generation;
    sessions.insert(session, s);
    backend.on_kv_update();
    // restored rows re-draw on the shared pool, exactly like a prefill
    metrics.note_kv_admission(restored, used_rows(sessions));
    Ok(())
}

/// Extract a human-readable message from a contained panic payload
/// (the two payload types `panic!` produces, else a placeholder).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// The per-worker supervisor (ISSUE 9): owns everything that must
/// survive a worker crash — the envelope receiver, the standing queue,
/// the accumulated metrics, and the evicted/lost tombstone sets — and
/// runs successive worker *incarnations* under `catch_unwind`. A clean
/// incarnation exit (every submitter hung up and the queue drained)
/// ends the supervisor. A panic that escapes dispatch containment (a
/// [`WorkerAbort`], or a panic outside any dispatch) restarts the head:
/// the crash is counted (`worker_panics`/`worker_restarts`), the dead
/// incarnation's resident sessions are failed shard-wide through
/// [`ShardDirectory::fail_head`] (`sessions_lost`) — DRAM-spilled
/// copies survive in the pool and later promote byte-identically onto
/// the new incarnation — the doomed backlog (queued `Decode`/`Attend`
/// of lost sessions) is answered with typed [`ServeError::SessionLost`]
/// errors, and a fresh backend is built from the factory for the next
/// incarnation. In-flight envelopes of the dead incarnation resolve
/// through their dropped response channels as `WorkerGone`; queued
/// `Close`/`Prefill` envelopes stay queued on purpose — the new
/// incarnation acknowledges the Close (clearing the tombstone) and
/// re-opens on Prefill.
///
/// The factory itself runs *outside* containment on purpose: if the
/// environment can no longer produce a backend, restarting would be a
/// lie — the supervisor thread dies and `shutdown` reports the panic.
fn supervise<B, FB>(
    worker: usize,
    cfg: ServerConfig,
    make_backend: FB,
    rx: Receiver<Envelope>,
    gauges: Arc<WorkerGauges>,
    dir: Arc<ShardDirectory>,
) -> Metrics
where
    B: AttentionBackend,
    FB: Fn(usize) -> B,
{
    let head = worker % cfg.heads;
    let mut metrics = Metrics::new();
    // sessions reclaimed by a dropping policy answer `Evicted`; sessions
    // whose KV died with a crashed incarnation answer `SessionLost`.
    // Both tombstone sets outlive incarnations and are bounded well past
    // the live-session count so only pathologically stale entries age
    // out.
    let mut evicted = EvictedSet::new((4 * cfg.max_sessions).max(16));
    let mut lost = EvictedSet::new((4 * cfg.max_sessions).max(16));
    let mut queue = WorkQueue::new();
    loop {
        let backend = make_backend(worker);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            worker_incarnation(
                worker, &cfg, backend, &rx, &mut queue, &gauges, &dir, &mut evicted, &mut lost,
                &mut metrics,
            )
        }));
        match caught {
            Ok(()) => break,
            Err(_) => {
                metrics.worker_panics += 1;
                metrics.worker_restarts += 1;
                // Fail this head's sessions shard-wide: resident copies
                // die (tombstoned below), this head's spilled copies
                // survive in the directory pool for recovery.
                let lost_now = dir.fail_head(head);
                metrics.sessions_lost += lost_now.len() as u64;
                for &sid in &lost_now {
                    lost.insert(sid);
                }
                // Drain the doomed backlog so no queued ticket outlives
                // its session silently: every queued Decode/Attend of a
                // lost session answers typed, now. (`fail_head` returns
                // the ids sorted.)
                let drained = queue.drain_matching(|env| {
                    matches!(env.req, Request::Decode { .. } | Request::Attend { .. })
                        && lost_now.binary_search(&env.req.session()).is_ok()
                });
                for env in drained {
                    gauges.depth.fetch_sub(1, Ordering::Relaxed);
                    let op = match env.req {
                        Request::Decode { .. } => Op::Decode,
                        _ => Op::Attend,
                    };
                    let session = env.req.session();
                    deliver(
                        &mut metrics,
                        op,
                        &env.sink,
                        Response {
                            id: env.req.id(),
                            session,
                            head,
                            result: Err(ServeError::SessionLost { session }),
                            latency: env.enq.elapsed(),
                        },
                    );
                }
            }
        }
    }
    // fold the submission-side gauges into this worker's report — once,
    // for the supervisor's whole life (they are shared atomics, not
    // per-incarnation state)
    metrics.shed_requests += gauges.sheds.load(Ordering::Relaxed);
    metrics.queue_depth_max = metrics.queue_depth_max.max(gauges.depth_hwm.load(Ordering::Relaxed));
    metrics
}

/// The standing per-worker scheduler (see the module docs for the
/// queue → admit → extend → dispatch cycle), run as one backend
/// *incarnation* under the supervisor. The queue outlives every
/// dispatch — and every incarnation: whatever a cycle could not admit
/// stays at the front and seeds the next plan, and newly-arriving
/// envelopes *extend* the open plan until a bound fires. Envelopes
/// leave the bounded-queue gauge the moment the scheduler pops them
/// into a plan — from then on they are in-flight work, not backlog.
/// Session stores and the logical clock are incarnation-local (a crash
/// loses them — that is what [`supervise`] recovers from); the
/// tombstone sets and metrics are borrowed from the supervisor.
#[allow(clippy::too_many_arguments)]
fn worker_incarnation<B: AttentionBackend>(
    worker: usize,
    cfg: &ServerConfig,
    mut backend: B,
    rx: &Receiver<Envelope>,
    queue: &mut WorkQueue,
    gauges: &WorkerGauges,
    dir: &ShardDirectory,
    evicted: &mut EvictedSet,
    lost: &mut EvictedSet,
    metrics: &mut Metrics,
) {
    let head = worker % cfg.heads;
    let mut sessions: HashMap<SessionId, Session> = HashMap::new();
    // the incarnation's logical clock: one tick per request, in program
    // order — the deterministic LRU key (wall-clock ties would make
    // eviction, and therefore outputs, timing-dependent)
    let mut clock: u64 = 0;
    let policy = cfg.batch;
    loop {
        // Block until there is work (or every submitter hung up and the
        // standing queue drained — the shutdown condition).
        if !queue.wait_nonempty(rx) {
            break;
        }
        // Reconcile with the shard directory first: apply any demote /
        // drop / loss decided by another head since the last cycle, so a
        // victim is torn down on every head before this cycle's work can
        // observe it — the fan-out half of atomic eviction.
        apply_shard_transitions(&mut backend, dir, head, &mut sessions, evicted, lost, metrics);
        // A Prefill at the front is a barrier: run it alone, then loop.
        if matches!(queue.front().map(|e| &e.req), Some(Request::Prefill { .. })) {
            let env = queue.pop().expect("front checked");
            gauges.depth.fetch_sub(1, Ordering::Relaxed);
            metrics.note_batch();
            run_prefill_barrier(
                &mut backend,
                cfg,
                dir,
                &mut sessions,
                evicted,
                lost,
                metrics,
                &mut clock,
                env,
                head,
            );
            continue;
        }
        // A Decode/Attend against a spilled session is a promotion
        // barrier: restore the KV from the DRAM tier (or refuse the
        // request), then loop — on success the envelope is still at the
        // front and executes against the restored store.
        let promote = queue
            .front()
            .filter(|env| needs_promotion(dir, &sessions, head, &env.req))
            .map(|env| env.req.session());
        if let Some(session) = promote {
            metrics.note_batch();
            if let Err(e) = run_promotion_barrier(
                &mut backend,
                cfg,
                dir,
                head,
                &mut sessions,
                evicted,
                lost,
                metrics,
                session,
            ) {
                let env = queue.pop().expect("front checked");
                gauges.depth.fetch_sub(1, Ordering::Relaxed);
                let op = match env.req {
                    Request::Decode { .. } => Op::Decode,
                    _ => Op::Attend,
                };
                deliver(
                    metrics,
                    op,
                    &env.sink,
                    Response {
                        id: env.req.id(),
                        session,
                        head,
                        result: Err(e),
                        latency: env.enq.elapsed(),
                    },
                );
            }
            continue;
        }
        // Open a dispatch plan and extend it: admit the longest
        // admissible *prefix* of the queue (never reorder — see module
        // docs), waiting out the batching window for stragglers.
        let mut plan = GroupPlan::new(policy.mode);
        let deadline = Instant::now() + policy.max_wait;
        loop {
            while plan.len() < policy.max_batch {
                match queue.front() {
                    Some(env)
                        if !matches!(env.req, Request::Prefill { .. })
                            && !needs_promotion(dir, &sessions, head, &env.req)
                            && plan.admits(&env.req) =>
                    {
                        let env = queue.pop().expect("front checked");
                        gauges.depth.fetch_sub(1, Ordering::Relaxed);
                        plan.push(env);
                    }
                    _ => break,
                }
            }
            if plan.len() >= policy.max_batch {
                break;
            }
            // the waiting/served pressure valve: once enough backlog has
            // piled up behind the plan (a barrier at the front, or sheer
            // volume), dispatch now instead of idling out the window
            let waiting = queue.len();
            if waiting > 0 && waiting as f64 >= policy.waiting_served_ratio * plan.len() as f64 {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match queue.wait_arrival(rx, deadline - now) {
                ArrivalWait::Arrived => continue,
                // a timeout may fire early on coarse-timer platforms:
                // loop and let the deadline re-check decide
                ArrivalWait::TimedOut => continue,
                ArrivalWait::Disconnected => break,
            }
        }
        // a non-Prefill front always admits to an empty plan, so the plan
        // is non-empty here
        metrics.note_batch();
        execute_batch(
            &mut backend,
            cfg,
            dir,
            &mut sessions,
            evicted,
            lost,
            &mut clock,
            plan.take(),
            head,
            metrics,
        );
    }
    // the backend's hot-path work counters (ISSUE 7): dispatch configs
    // must agree not only on outputs but on the work performed. Folded
    // only on clean exit — a crashed incarnation's work dies with it.
    if let Some(work) = backend.work_stats() {
        metrics.work.add(&work);
    }
}

/// Route a stream of requests round-robin over heads (helper for load
/// generators that don't care about head affinity).
pub fn round_robin_heads(count: usize, heads: usize) -> impl Iterator<Item = usize> {
    (0..count).map(move |i| i % heads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::FunctionalBackend;
    use crate::util::rng::Rng;

    fn functional_server(cfg: ServerConfig) -> CamformerServer {
        let n = cfg.kv_capacity;
        CamformerServer::start(cfg, move |_| FunctionalBackend::new(n, 64))
    }

    /// Resolve every ticket and return the responses in id order (the
    /// successor of the old pool-collect + sort pattern).
    fn wait_all(tickets: Vec<Ticket>) -> Vec<Response> {
        let mut resps: Vec<Response> = tickets.into_iter().map(Ticket::wait).collect();
        resps.sort_by_key(|r| r.id);
        resps
    }

    #[test]
    fn serves_and_shuts_down() {
        let cfg = ServerConfig { heads: 2, kv_capacity: 128, ..Default::default() };
        let server = functional_server(cfg);
        let mut rng = Rng::new(120);
        let mut tickets = Vec::new();
        // one session, prefilled independently on both head workers
        for h in 0..2usize {
            tickets.push(
                server
                    .submit_ticket(Request::Prefill {
                        id: 1000 + h as u64,
                        session: 1,
                        head: h,
                        keys: rng.normal_vec(128 * 64),
                        values: rng.normal_vec(128 * 64),
                    })
                    .unwrap(),
            );
        }
        for i in 0..10u64 {
            tickets.push(
                server
                    .submit_ticket(Request::Attend {
                        id: i,
                        session: 1,
                        head: (i % 2) as usize,
                        query: rng.normal_vec(64),
                    })
                    .unwrap(),
            );
        }
        let resps = wait_all(tickets);
        assert_eq!(resps.len(), 12);
        for r in &resps {
            assert!(r.is_ok(), "{:?}", r.result);
            assert!(r.latency > Duration::ZERO);
            if r.id < 1000 {
                assert_eq!(r.output().len(), 64);
                assert_eq!(r.seq_len(), 128);
            }
        }
        let (metrics, window) = server.shutdown();
        assert_eq!(metrics.completed, 12);
        assert_eq!(metrics.prefills, 2);
        assert_eq!(metrics.attends, 10);
        assert_eq!(metrics.errors, 0);
        assert!(window > Duration::ZERO);
    }

    #[test]
    fn responses_match_direct_backend() {
        let mut rng = Rng::new(121);
        let keys = rng.normal_vec(128 * 64);
        let values = rng.normal_vec(128 * 64);
        let cfg = ServerConfig { kv_capacity: 128, ..Default::default() };
        let server = functional_server(cfg);
        let t0 = server
            .submit_ticket(Request::Prefill {
                id: 0,
                session: 7,
                head: 0,
                keys: keys.clone(),
                values: values.clone(),
            })
            .unwrap();
        let q = rng.normal_vec(64);
        let t1 = server
            .submit_ticket(Request::Attend { id: 99, session: 7, head: 0, query: q.clone() })
            .unwrap();
        let resps = wait_all(vec![t0, t1]);
        assert_eq!(resps[1].id, 99);
        let mut direct = FunctionalBackend::new(128, 64);
        use crate::coordinator::backend::AttentionBackend as _;
        assert_eq!(resps[1].output(), &direct.attend(&q, &keys, &values).unwrap()[..]);
        server.shutdown();
    }

    #[test]
    fn bad_head_rejected_synchronously() {
        let server = functional_server(ServerConfig::default());
        let err = server
            .submit_ticket(Request::Attend { id: 0, session: 0, head: 5, query: vec![0.0; 64] })
            .unwrap_err();
        assert_eq!(err, ServeError::UnknownHead { head: 5, heads: 1 });
        server.shutdown();
    }

    #[test]
    fn bad_dims_rejected_synchronously() {
        let server = functional_server(ServerConfig::default());
        let err = server
            .submit_ticket(Request::Attend { id: 0, session: 0, head: 0, query: vec![0.0; 63] })
            .unwrap_err();
        assert_eq!(err, ServeError::DimMismatch { what: "query", got: 63, want: 64 });
        let err = server
            .submit_ticket(Request::Prefill {
                id: 1,
                session: 0,
                head: 0,
                keys: vec![0.0; 2 * 64],
                values: vec![0.0; 3 * 64],
            })
            .unwrap_err();
        assert!(matches!(err, ServeError::DimMismatch { .. }));
        server.shutdown();
    }

    #[test]
    fn unknown_session_reported_in_response() {
        let server = functional_server(ServerConfig::default());
        let r = server
            .submit_ticket(Request::Attend { id: 3, session: 42, head: 0, query: vec![0.0; 64] })
            .unwrap()
            .wait();
        assert_eq!(r.result, Err(ServeError::UnknownSession { session: 42 }));
        let (m, _) = server.shutdown();
        assert_eq!(m.errors, 1);
        assert_eq!(m.completed, 0);
    }

    #[test]
    fn overload_sheds_synchronously_but_never_a_close() {
        // max_queue = 0: every queueable submission is refused up front
        // with the retryable Overloaded — except lifecycle teardown,
        // which must always drain
        let cfg = ServerConfig { max_queue: 0, ..Default::default() };
        let server = functional_server(cfg);
        let err = server
            .submit_ticket(Request::Attend { id: 0, session: 0, head: 0, query: vec![0.0; 64] })
            .unwrap_err();
        assert_eq!(err, ServeError::Overloaded { queue_depth: 0 });
        assert!(err.is_retryable(&ReclaimPolicy::Deny));
        let r = server
            .submit_ticket(Request::Close { id: 1, session: 9, head: 0 })
            .expect("Close is exempt from shedding")
            .wait();
        assert_eq!(r.result, Err(ServeError::UnknownSession { session: 9 }));
        let (m, _) = server.shutdown();
        assert_eq!(m.shed_requests, 1);
        assert!(m.queue_depth_max >= 1, "the exempt close reached the queue");
    }

    #[test]
    fn shared_kv_budget_binds_across_sessions_under_deny() {
        // two 16-row sessions fill a 32-row pool: a third prefill and an
        // overdrawing decode are refused with the POOL size; closing one
        // session refunds its rows and decode proceeds
        let cfg = ServerConfig {
            worker_kv_budget: 32,
            kv_capacity: 32,
            ..Default::default()
        };
        let server = functional_server(cfg);
        let mut rng = Rng::new(129);
        for sid in 0..2u64 {
            let r = server
                .submit_ticket(Request::Prefill {
                    id: sid,
                    session: sid,
                    head: 0,
                    keys: rng.normal_vec(16 * 64),
                    values: rng.normal_vec(16 * 64),
                })
                .unwrap()
                .wait();
            assert!(r.is_ok(), "{:?}", r.result);
        }
        let r = server
            .submit_ticket(Request::Prefill {
                id: 2,
                session: 2,
                head: 0,
                keys: rng.normal_vec(8 * 64),
                values: rng.normal_vec(8 * 64),
            })
            .unwrap()
            .wait();
        assert_eq!(r.result, Err(ServeError::CapacityExhausted { capacity: 32 }));
        let r = server
            .submit_ticket(Request::Decode {
                id: 3,
                session: 0,
                head: 0,
                query: rng.normal_vec(64),
                new_key: rng.normal_vec(64),
                new_value: rng.normal_vec(64),
            })
            .unwrap()
            .wait();
        assert_eq!(
            r.result,
            Err(ServeError::CapacityExhausted { capacity: 32 }),
            "a decode must never overdraw the pool"
        );
        let r = server
            .submit_ticket(Request::Close { id: 4, session: 1, head: 0 })
            .unwrap()
            .wait();
        assert!(r.is_ok());
        let r = server
            .submit_ticket(Request::Decode {
                id: 5,
                session: 0,
                head: 0,
                query: rng.normal_vec(64),
                new_key: rng.normal_vec(64),
                new_value: rng.normal_vec(64),
            })
            .unwrap()
            .wait();
        assert!(r.is_ok(), "refunded rows re-admit: {:?}", r.result);
        assert_eq!(r.seq_len(), 17);
        let (m, _) = server.shutdown();
        assert_eq!(m.kv_rows_admitted, 16 + 16 + 1, "refused requests admit nothing");
        assert_eq!(m.kv_rows_hwm, 32, "the pool filled exactly once");
        assert_eq!(m.evictions, 0, "Deny must never evict for budget");
    }

    #[test]
    fn shared_kv_budget_evicts_lru_idle_under_pressure() {
        // same pool, LruEvictIdle: the over-budget prefill evicts the
        // least-recently-used session instead of failing
        let cfg = ServerConfig {
            worker_kv_budget: 32,
            kv_capacity: 32,
            reclaim: ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO },
            ..Default::default()
        };
        let server = functional_server(cfg);
        let mut rng = Rng::new(130);
        for sid in 0..2u64 {
            let r = server
                .submit_ticket(Request::Prefill {
                    id: sid,
                    session: sid,
                    head: 0,
                    keys: rng.normal_vec(16 * 64),
                    values: rng.normal_vec(16 * 64),
                })
                .unwrap()
                .wait();
            assert!(r.is_ok(), "{:?}", r.result);
        }
        let r = server
            .submit_ticket(Request::Prefill {
                id: 2,
                session: 2,
                head: 0,
                keys: rng.normal_vec(16 * 64),
                values: rng.normal_vec(16 * 64),
            })
            .unwrap()
            .wait();
        assert!(r.is_ok(), "budget pressure must evict, not refuse: {:?}", r.result);
        // session 0 (logical-clock LRU) was the victim
        let r = server
            .submit_ticket(Request::Attend { id: 3, session: 0, head: 0, query: vec![0.0; 64] })
            .unwrap()
            .wait();
        assert_eq!(r.result, Err(ServeError::Evicted { session: 0 }));
        let (m, _) = server.shutdown();
        assert_eq!(m.evictions, 1);
        assert_eq!(m.kv_rows_released, 16);
        assert_eq!(m.kv_rows_hwm, 32);
    }

    #[test]
    fn session_limit_enforced_under_deny() {
        let cfg = ServerConfig { max_sessions: 2, kv_capacity: 16, ..Default::default() };
        let server = functional_server(cfg);
        let mut rng = Rng::new(122);
        let mut tickets = Vec::new();
        for sid in 0..3u64 {
            tickets.push(
                server
                    .submit_ticket(Request::Prefill {
                        id: sid,
                        session: sid,
                        head: 0,
                        keys: rng.normal_vec(16 * 64),
                        values: rng.normal_vec(16 * 64),
                    })
                    .unwrap(),
            );
        }
        let resps = wait_all(tickets);
        assert!(resps[0].is_ok());
        assert!(resps[1].is_ok());
        assert_eq!(resps[2].result, Err(ServeError::SessionLimit { max_sessions: 2 }));
        let (m, _) = server.shutdown();
        assert_eq!(m.evictions, 0, "Deny must never evict");
    }

    #[test]
    fn lru_policy_evicts_idle_sessions_deterministically() {
        // max_sessions 2, eviction allowed with no idle gate: every
        // over-limit prefill evicts the LRU (logical-clock) session, the
        // victim's later requests answer Evicted, and re-opening revives
        // the id (evicting the next LRU in turn)
        let cfg = ServerConfig {
            max_sessions: 2,
            kv_capacity: 16,
            reclaim: ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO },
            ..Default::default()
        };
        let server = functional_server(cfg);
        let mut rng = Rng::new(123);
        let mut tickets = Vec::new();
        let mut prefill = |tickets: &mut Vec<Ticket>, id: u64, session: u64| {
            tickets.push(
                server
                    .submit_ticket(Request::Prefill {
                        id,
                        session,
                        head: 0,
                        keys: rng.normal_vec(16 * 64),
                        values: rng.normal_vec(16 * 64),
                    })
                    .unwrap(),
            );
        };
        let attend = |tickets: &mut Vec<Ticket>, id: u64, session: u64| {
            tickets.push(
                server
                    .submit_ticket(Request::Attend { id, session, head: 0, query: vec![0.0; 64] })
                    .unwrap(),
            );
        };
        prefill(&mut tickets, 0, 0); // clock 1
        prefill(&mut tickets, 1, 1); // clock 2
        attend(&mut tickets, 2, 0); // clock 3: session 0 is now the most recent
        prefill(&mut tickets, 3, 2); // clock 4: at limit -> evicts session 1 (seq 2)
        attend(&mut tickets, 4, 1); // the victim answers Evicted
        prefill(&mut tickets, 5, 1); // clock 6: revives 1, evicts session 0 (seq 3)
        attend(&mut tickets, 6, 0);
        attend(&mut tickets, 7, 1);
        let resps = wait_all(tickets);
        assert!(resps[0].is_ok() && resps[1].is_ok() && resps[2].is_ok());
        assert!(
            resps[3].is_ok(),
            "LRU policy must admit the over-limit open: {:?}",
            resps[3].result
        );
        assert_eq!(resps[4].result, Err(ServeError::Evicted { session: 1 }));
        assert!(resps[5].is_ok(), "re-open of an evicted session: {:?}", resps[5].result);
        assert_eq!(resps[6].result, Err(ServeError::Evicted { session: 0 }));
        assert!(resps[7].is_ok(), "revived session must serve: {:?}", resps[7].result);
        let (m, _) = server.shutdown();
        assert_eq!(m.evictions, 2);
        assert_eq!(m.kv_rows_released, 2 * 16);
        assert_eq!(m.errors, 2);
    }

    #[test]
    fn close_frees_the_session_slot() {
        // with max_sessions = 1 and Deny, a second session is admissible
        // only because the first was explicitly closed
        let cfg = ServerConfig { max_sessions: 1, kv_capacity: 16, ..Default::default() };
        let server = functional_server(cfg);
        let mut rng = Rng::new(124);
        let mut tickets = Vec::new();
        tickets.push(
            server
                .submit_ticket(Request::Prefill {
                    id: 0,
                    session: 0,
                    head: 0,
                    keys: rng.normal_vec(16 * 64),
                    values: rng.normal_vec(16 * 64),
                })
                .unwrap(),
        );
        tickets.push(server.submit_ticket(Request::Close { id: 1, session: 0, head: 0 }).unwrap());
        tickets.push(
            server
                .submit_ticket(Request::Prefill {
                    id: 2,
                    session: 1,
                    head: 0,
                    keys: rng.normal_vec(8 * 64),
                    values: rng.normal_vec(8 * 64),
                })
                .unwrap(),
        );
        // a closed (not evicted) session is simply unknown afterwards
        tickets.push(
            server
                .submit_ticket(Request::Attend { id: 3, session: 0, head: 0, query: vec![0.0; 64] })
                .unwrap(),
        );
        let resps = wait_all(tickets);
        assert!(resps[0].is_ok());
        assert!(resps[1].is_ok(), "close must ack: {:?}", resps[1].result);
        assert_eq!(resps[1].seq_len(), 16, "close reports the final context length");
        assert!(resps[2].is_ok(), "closed slot must be reusable: {:?}", resps[2].result);
        assert_eq!(resps[3].result, Err(ServeError::UnknownSession { session: 0 }));
        let (m, _) = server.shutdown();
        assert_eq!(m.closes, 1);
        assert_eq!(m.kv_rows_released, 16);
    }

    #[test]
    fn close_is_a_same_session_barrier_in_the_stream() {
        // decode, close, decode on ONE session submitted back-to-back:
        // whatever the wire batcher fuses, the pre-close decode succeeds,
        // the close acks at the grown length, the post-close decode is
        // refused — exactly sequential semantics
        let cfg = ServerConfig { kv_capacity: 32, ..Default::default() };
        let server = functional_server(cfg);
        let mut rng = Rng::new(125);
        let mut tickets = Vec::new();
        tickets.push(
            server
                .submit_ticket(Request::Prefill {
                    id: 0,
                    session: 5,
                    head: 0,
                    keys: rng.normal_vec(8 * 64),
                    values: rng.normal_vec(8 * 64),
                })
                .unwrap(),
        );
        tickets.push(
            server
                .submit_ticket(Request::Decode {
                    id: 1,
                    session: 5,
                    head: 0,
                    query: rng.normal_vec(64),
                    new_key: rng.normal_vec(64),
                    new_value: rng.normal_vec(64),
                })
                .unwrap(),
        );
        tickets.push(server.submit_ticket(Request::Close { id: 2, session: 5, head: 0 }).unwrap());
        tickets.push(
            server
                .submit_ticket(Request::Decode {
                    id: 3,
                    session: 5,
                    head: 0,
                    query: rng.normal_vec(64),
                    new_key: rng.normal_vec(64),
                    new_value: rng.normal_vec(64),
                })
                .unwrap(),
        );
        let resps = wait_all(tickets);
        assert!(resps[0].is_ok());
        assert!(resps[1].is_ok(), "pre-close decode: {:?}", resps[1].result);
        assert_eq!(resps[1].seq_len(), 9);
        assert!(resps[2].is_ok(), "close ack: {:?}", resps[2].result);
        assert_eq!(resps[2].seq_len(), 9);
        assert_eq!(resps[3].result, Err(ServeError::UnknownSession { session: 5 }));
        let (m, _) = server.shutdown();
        assert_eq!(m.closes, 1);
        assert_eq!(m.decodes, 1);
        assert_eq!(m.errors, 1);
    }

    /// A backend compiled for a fixed 16-row context, like PJRT but tiny.
    struct Fixed16Backend(FunctionalBackend);

    impl AttentionBackend for Fixed16Backend {
        fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> anyhow::Result<Vec<f32>> {
            self.0.attend(q, k, v)
        }

        fn required_rows(&self, _rows: usize, _quantum: usize) -> usize {
            16
        }

        fn name(&self) -> &'static str {
            "fixed16"
        }
    }

    #[test]
    fn fixed_geometry_overflow_is_typed_not_a_panic() {
        // kv_capacity above the backend's compiled context: growing past
        // the geometry must yield CapacityExhausted, not panic the worker,
        // and a refused decode must not commit its append
        let cfg = ServerConfig { kv_capacity: 64, ..Default::default() };
        let server =
            CamformerServer::start(cfg, |_| Fixed16Backend(FunctionalBackend::new(16, 64)));
        let mut rng = Rng::new(124);
        let mut tickets = Vec::new();
        tickets.push(
            server
                .submit_ticket(Request::Prefill {
                    id: 0,
                    session: 0,
                    head: 0,
                    keys: rng.normal_vec(16 * 64),
                    values: rng.normal_vec(16 * 64),
                })
                .unwrap(),
        );
        tickets.push(
            server
                .submit_ticket(Request::Decode {
                    id: 1,
                    session: 0,
                    head: 0,
                    query: rng.normal_vec(64),
                    new_key: rng.normal_vec(64),
                    new_value: rng.normal_vec(64),
                })
                .unwrap(),
        );
        tickets.push(
            server
                .submit_ticket(Request::Attend {
                    id: 2,
                    session: 0,
                    head: 0,
                    query: rng.normal_vec(64),
                })
                .unwrap(),
        );
        let resps = wait_all(tickets);
        assert!(resps[0].is_ok());
        assert_eq!(resps[1].result, Err(ServeError::CapacityExhausted { capacity: 16 }));
        assert!(resps[2].is_ok(), "worker must survive a refused decode");
        assert_eq!(resps[2].seq_len(), 16, "refused decode must not grow the cache");
        server.shutdown();
    }

    #[test]
    fn cross_session_batch_keeps_queries_on_their_own_cache() {
        // two sessions on ONE worker with contrasting memories; their
        // decode steps interleave and (usually) share a dispatch — every
        // output must still be computed against its own session's cache
        let n = 64usize;
        let cfg = ServerConfig { kv_capacity: n, ..Default::default() };
        let quantum = cfg.pad_quantum;
        let server = functional_server(cfg);
        let mut rng = Rng::new(125);
        let mut mirrors = [KvStore::new(n, 64, 64), KvStore::new(n, 64, 64)];
        for (si, sid) in [2u64, 4u64].iter().enumerate() {
            let keys = rng.normal_vec(16 * 64);
            let values = rng.normal_vec(16 * 64);
            mirrors[si].load(&keys, &values).unwrap();
            let r = server
                .submit_ticket(Request::Prefill {
                    id: 100 + si as u64,
                    session: *sid,
                    head: 0,
                    keys,
                    values,
                })
                .unwrap()
                .wait();
            assert!(r.is_ok(), "{:?}", r.result);
        }
        let mut tickets = Vec::new();
        let mut expected: Vec<Vec<f32>> = Vec::new();
        let mut id = 0u64;
        for _step in 0..8 {
            for (si, sid) in [2u64, 4u64].iter().enumerate() {
                let q = rng.normal_vec(64);
                let nk = rng.normal_vec(64);
                let nv = rng.normal_vec(64);
                mirrors[si].append(&nk, &nv).unwrap();
                let rows = mirrors[si].len().div_ceil(quantum) * quantum;
                let (kp, vp, _) = mirrors[si].padded(rows);
                let mut reference = FunctionalBackend::new(n, 64);
                use crate::coordinator::backend::AttentionBackend as _;
                expected.push(reference.attend(&q, kp, vp).unwrap());
                tickets.push(
                    server
                        .submit_ticket(Request::Decode {
                            id,
                            session: *sid,
                            head: 0,
                            query: q,
                            new_key: nk,
                            new_value: nv,
                        })
                        .unwrap(),
                );
                id += 1;
            }
        }
        let resps = wait_all(tickets);
        for (r, want) in resps.iter().zip(&expected) {
            assert_eq!(r.output(), &want[..], "request {}", r.id);
        }
        let (m, _) = server.shutdown();
        assert_eq!(m.errors, 0);
        assert_eq!(m.decodes, 16);
        assert!(m.dispatches >= 1);
        assert!(m.mean_occupancy() >= 1.0);
        server_metrics_sane(&m);
    }

    fn server_metrics_sane(m: &Metrics) {
        assert!(m.dispatched_queries >= m.dispatches);
        assert!(m.max_occupancy as f64 >= m.mean_occupancy());
    }

    /// Backend whose dispatches fail while the shared flag is set (the
    /// flag outlives the move into the worker thread).
    struct FaultInjected {
        inner: FunctionalBackend,
        fail: std::sync::Arc<std::sync::atomic::AtomicBool>,
    }

    impl AttentionBackend for FaultInjected {
        fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> anyhow::Result<Vec<f32>> {
            self.inner.attend(q, k, v)
        }

        fn attend_batch(&mut self, items: &[AttendItem<'_>]) -> anyhow::Result<Vec<Vec<f32>>> {
            if self.fail.load(std::sync::atomic::Ordering::SeqCst) {
                anyhow::bail!("injected dispatch failure");
            }
            self.inner.attend_batch(items)
        }

        fn supports_prefix_views(&self) -> bool {
            self.inner.supports_prefix_views()
        }

        fn name(&self) -> &'static str {
            "fault-injected"
        }
    }

    #[test]
    fn failed_dispatch_rolls_back_speculative_appends() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let n = 64usize;
        let prefill_rows = 8usize;
        let fail = Arc::new(AtomicBool::new(false));
        let cfg = ServerConfig { kv_capacity: n, ..Default::default() };
        let server = {
            let fail = fail.clone();
            CamformerServer::start(cfg, move |_| FaultInjected {
                inner: FunctionalBackend::new(n, 64),
                fail: fail.clone(),
            })
        };
        let mut rng = Rng::new(126);
        let keys = rng.normal_vec(prefill_rows * 64);
        let values = rng.normal_vec(prefill_rows * 64);
        let r = server
            .submit_ticket(Request::Prefill {
                id: 0,
                session: 0,
                head: 0,
                keys: keys.clone(),
                values: values.clone(),
            })
            .unwrap()
            .wait();
        assert!(r.is_ok());

        // every dispatch fails while the flag is set: however the
        // scheduler groups these decodes, each group's appends roll back
        fail.store(true, Ordering::SeqCst);
        let mut tickets = Vec::new();
        for id in 1..=3u64 {
            tickets.push(
                server
                    .submit_ticket(Request::Decode {
                        id,
                        session: 0,
                        head: 0,
                        query: rng.normal_vec(64),
                        new_key: rng.normal_vec(64),
                        new_value: rng.normal_vec(64),
                    })
                    .unwrap(),
            );
        }
        for r in wait_all(tickets) {
            assert!(matches!(r.result, Err(ServeError::Backend(_))), "{:?}", r.result);
        }

        // heal the backend: the session must serve at its pre-burst
        // length with its pre-burst contents (errored decodes committed
        // nothing)
        fail.store(false, Ordering::SeqCst);
        let q = rng.normal_vec(64);
        let r = server
            .submit_ticket(Request::Attend { id: 9, session: 0, head: 0, query: q.clone() })
            .unwrap()
            .wait();
        assert!(r.is_ok(), "{:?}", r.result);
        assert_eq!(r.seq_len(), prefill_rows, "rolled-back appends must not linger");
        let mut mirror = KvStore::new(n, 64, 64);
        mirror.load(&keys, &values).unwrap();
        let (kp, vp, _) = mirror.padded(16);
        let mut reference = FunctionalBackend::new(n, 64);
        use crate::coordinator::backend::AttentionBackend as _;
        assert_eq!(r.output(), &reference.attend(&q, kp, vp).unwrap()[..]);
        let (m, _) = server.shutdown();
        assert_eq!(m.errors, 3);
        server_metrics_sane(&m);
    }

    /// Backend without native prefix views: keeps the trait defaults, so
    /// fused bursts must be served through the serving layer's
    /// materialised literal-pad copies.
    struct NoPrefixViews(FunctionalBackend);

    impl AttentionBackend for NoPrefixViews {
        fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> anyhow::Result<Vec<f32>> {
            self.0.attend(q, k, v)
        }

        fn name(&self) -> &'static str {
            "no-prefix-views"
        }
    }

    #[test]
    fn fused_burst_over_non_prefix_backend_matches_reference() {
        // a single-session decode burst through a backend that cannot
        // mask prefixes natively: whatever grouping the wire batcher
        // achieves, each step must see exactly its causal prefix (the
        // scratch materialisation path when steps do fuse)
        let n = 64usize;
        let steps = 12usize;
        let cfg = ServerConfig { kv_capacity: n, ..Default::default() };
        let quantum = cfg.pad_quantum;
        let server = CamformerServer::start(cfg, |_| NoPrefixViews(FunctionalBackend::new(n, 64)));
        let mut rng = Rng::new(127);
        let keys = rng.normal_vec(8 * 64);
        let values = rng.normal_vec(8 * 64);
        let mut mirror = KvStore::new(n, 64, 64);
        mirror.load(&keys, &values).unwrap();
        let r = server
            .submit_ticket(Request::Prefill { id: 1000, session: 0, head: 0, keys, values })
            .unwrap()
            .wait();
        assert!(r.is_ok(), "{:?}", r.result);
        let mut tickets = Vec::new();
        let mut expected: Vec<(Vec<f32>, usize)> = Vec::new();
        for id in 0..steps as u64 {
            let q = rng.normal_vec(64);
            let nk = rng.normal_vec(64);
            let nv = rng.normal_vec(64);
            mirror.append(&nk, &nv).unwrap();
            let rows = mirror.len().div_ceil(quantum) * quantum;
            let (kp, vp, _) = mirror.padded(rows);
            let mut reference = FunctionalBackend::new(n, 64);
            use crate::coordinator::backend::AttentionBackend as _;
            expected.push((reference.attend(&q, kp, vp).unwrap(), mirror.len()));
            tickets.push(
                server
                    .submit_ticket(Request::Decode {
                        id,
                        session: 0,
                        head: 0,
                        query: q,
                        new_key: nk,
                        new_value: nv,
                    })
                    .unwrap(),
            );
        }
        let resps = wait_all(tickets);
        for (r, (want, seq_len)) in resps.iter().zip(&expected) {
            assert_eq!(r.output(), &want[..], "step {}", r.id);
            assert_eq!(r.seq_len(), *seq_len, "step {}", r.id);
        }
        let (m, _) = server.shutdown();
        assert_eq!(m.errors, 0);
        assert_eq!(m.decodes, steps as u64);
        server_metrics_sane(&m);
    }

    #[test]
    fn round_robin_coverage() {
        let heads: Vec<usize> = round_robin_heads(10, 3).collect();
        assert_eq!(heads, vec![0, 1, 2, 0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn evicted_set_drops_oldest_tombstone_past_the_cap() {
        let mut set = EvictedSet::new(3);
        for sid in 1..=3u64 {
            set.insert(sid);
        }
        assert_eq!(set.len(), 3);
        // a duplicate insert neither grows the set nor refreshes order
        set.insert(2);
        assert_eq!(set.len(), 3);
        // the 4th tombstone ages out the FIFO-oldest (1), not the cap'th
        set.insert(4);
        assert_eq!(set.len(), 3);
        assert!(!set.contains(1), "oldest tombstone must age out");
        assert!(set.contains(2) && set.contains(3) && set.contains(4));
        // explicit removal (revive / close-ack) also drops order state,
        // so the freed slot is reusable
        set.remove(3);
        assert_eq!(set.len(), 2);
        set.insert(5);
        set.insert(6);
        assert_eq!(set.len(), 3, "cap re-binds after removals");
        assert!(!set.contains(2), "2 was the oldest survivor");
    }

    /// Regression for the unbounded pre-PR-8 tombstone set: churn far
    /// more evictions through a worker than the bound allows and check
    /// that (a) stale victims degrade to `UnknownSession` instead of
    /// pinning memory forever, while (b) recent victims still answer the
    /// typed `Evicted`.
    #[test]
    fn tombstone_set_stays_bounded_under_eviction_churn() {
        // max_sessions = 2 -> cap = (4 * 2).max(16) = 16 tombstones
        let cfg = ServerConfig {
            max_sessions: 2,
            kv_capacity: 16,
            reclaim: ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO },
            ..Default::default()
        };
        let server = functional_server(cfg);
        let mut rng = Rng::new(8031);
        // churn 40 sessions through 2 slots: 38 evictions, in id order
        for sid in 1..=40u64 {
            let r = server
                .submit_ticket(Request::Prefill {
                    id: sid,
                    session: sid,
                    head: 0,
                    keys: rng.normal_vec(2 * 64),
                    values: rng.normal_vec(2 * 64),
                })
                .unwrap()
                .wait();
            assert!(r.is_ok(), "session {sid}: {:?}", r.result);
        }
        // victims 1..=22 aged out of the 16-slot tombstone set; 23..=38
        // are the survivors
        let stale = server
            .submit_ticket(Request::Attend { id: 100, session: 1, head: 0, query: vec![0.0; 64] })
            .unwrap()
            .wait();
        assert_eq!(stale.result, Err(ServeError::UnknownSession { session: 1 }));
        let recent = server
            .submit_ticket(Request::Attend { id: 101, session: 30, head: 0, query: vec![0.0; 64] })
            .unwrap()
            .wait();
        assert_eq!(recent.result, Err(ServeError::Evicted { session: 30 }));
        let (m, _) = server.shutdown();
        assert_eq!(m.evictions, 38);
    }

    #[test]
    fn throughput_under_load() {
        let cfg = ServerConfig { heads: 4, kv_capacity: 256, ..Default::default() };
        let server = functional_server(cfg);
        let mut rng = Rng::new(123);
        let mut tickets = Vec::new();
        for h in 0..4usize {
            tickets.push(
                server
                    .submit_ticket(Request::Prefill {
                        id: 1000 + h as u64,
                        session: 1,
                        head: h,
                        keys: rng.normal_vec(256 * 64),
                        values: rng.normal_vec(256 * 64),
                    })
                    .unwrap(),
            );
        }
        let n = 200u64;
        for i in 0..n {
            tickets.push(
                server
                    .submit_ticket(Request::Attend {
                        id: i,
                        session: 1,
                        head: (i % 4) as usize,
                        query: rng.normal_vec(64),
                    })
                    .unwrap(),
            );
        }
        let resps = wait_all(tickets);
        assert_eq!(resps.len(), n as usize + 4);
        let (metrics, window) = server.shutdown();
        assert_eq!(metrics.completed, n + 4);
        assert_eq!(metrics.attends, n);
        assert!(metrics.throughput_per_s(window) > 50.0);
    }
}
