//! Serving metrics: latency distribution, throughput and per-op counters.

use std::time::Duration;

use super::backend::WorkStats;
use crate::util::stats;

/// Accounted per-stage serving energy \[J\] (ISSUE 10): the output of the
/// workload layer's `EnergyAccountant`, attached to a [`Metrics`] after
/// shutdown so summaries report J/token, watts and the per-stage split
/// alongside the latency percentiles. Pure data — every field is a joule
/// total for one pipeline stage, and the struct is exactly additive
/// (merging two metrics sums their stages), which is what the energy
/// additivity property test pins.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyStages {
    /// BA-CAM search: tile precharge + broadcast + ADC, per tile streamed.
    pub search_j: f64,
    /// CAM programming: one key-row write per KV row admitted/packed.
    pub program_j: f64,
    /// Survivor selection: top-k sorter passes + streaming corrections.
    pub selection_j: f64,
    /// Softmax normalisation of the survivor scores, per query.
    pub softmax_j: f64,
    /// Contextualization: BF16 MACs + V-SRAM + DMA per survivor V row.
    pub context_j: f64,
    /// Host-DRAM spill traffic, as charged by the channel model.
    pub dram_j: f64,
}

impl EnergyStages {
    /// Total accounted energy \[J\].
    pub fn total_j(&self) -> f64 {
        self.search_j + self.program_j + self.selection_j + self.softmax_j + self.context_j
            + self.dram_j
    }

    /// Field-wise accumulate (metrics merging).
    pub fn add(&mut self, other: &EnergyStages) {
        self.search_j += other.search_j;
        self.program_j += other.program_j;
        self.selection_j += other.selection_j;
        self.softmax_j += other.softmax_j;
        self.context_j += other.context_j;
        self.dram_j += other.dram_j;
    }

    /// DRAM's share of the total, in \[0, 1\] (0.0 when nothing was
    /// accounted).
    pub fn dram_share(&self) -> f64 {
        let total = self.total_j();
        if total > 0.0 {
            self.dram_j / total
        } else {
            0.0
        }
    }
}

/// Rolling metrics for one server (or one worker).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<f64>,
    pub completed: u64,
    pub batches: u64,
    pub errors: u64,
    /// Per-op accounting for the session serving API.
    pub prefills: u64,
    pub decodes: u64,
    pub attends: u64,
    /// Session lifecycle (ISSUE 5): explicit `Close` requests served
    /// (handle close / drop), LRU reclaims performed to admit new
    /// sessions, and the provisioned KV rows those two paths released.
    pub closes: u64,
    pub evictions: u64,
    pub kv_rows_released: u64,
    /// Batched backend dispatches issued (one per dispatch group).
    pub dispatches: u64,
    /// Queries served through those dispatches; `dispatched_queries /
    /// dispatches` is the batch occupancy — how many decode/attend steps
    /// each BA-CAM search amortised over (1.0 = no amortisation).
    pub dispatched_queries: u64,
    /// Largest single dispatch.
    pub max_occupancy: u64,
    /// Standing-scheduler gauges (ISSUE 6): requests refused with
    /// [`ServeError::Overloaded`](super::ServeError::Overloaded) because
    /// the worker's queue was at `max_queue`, the deepest that queue ever
    /// got, KV rows admitted against the shared `worker_kv_budget`
    /// (monotone: prefill = rows, decode = 1), and the budget high-water
    /// mark — the largest number of rows ever resident at once.
    pub shed_requests: u64,
    pub queue_depth_max: u64,
    pub kv_rows_admitted: u64,
    pub kv_rows_hwm: u64,
    /// Backend hot-path work counters (ISSUE 7), folded in from
    /// [`AttentionBackend::work_stats`](super::AttentionBackend::work_stats)
    /// when a worker retires its backend. All flows, so dispatch-config
    /// equivalence extends to the work performed: the fuzz harness
    /// asserts parity on these across scheduling modes.
    pub work: WorkStats,
    /// Spill-tier accounting (ISSUE 8): shard-wide demotions into the
    /// simulated host DRAM tier, promotions back on the victim's next
    /// request, the KV rows currently parked in the spill pool at
    /// shutdown, and the modeled DRAM traffic/energy those transfers
    /// charged through the channel model. Demotions/promotions count once
    /// per shard-level decision (not once per head), so they are
    /// dispatch-config invariant alongside `evictions`.
    pub demotions: u64,
    pub promotions: u64,
    pub spilled_rows: u64,
    pub dram_bytes_written: u64,
    pub dram_bytes_read: u64,
    pub dram_energy_j: f64,
    /// Modeled promotion latencies \[ns\] — what the victim's next request
    /// pays to stream its KV back in (the "slow first token").
    promotion_ns: Vec<f64>,
    /// `SessionHandle::drop` closes that failed to submit (worker gone /
    /// queue shed): a head that may leak its session copy, previously
    /// discarded silently.
    pub close_failures: u64,
    /// Fault-containment accounting (ISSUE 9): backend dispatches that
    /// returned an error (rolled back and answered typed), dispatch
    /// panics caught by containment *plus* incarnation-killing crashes,
    /// supervised worker restarts, resident sessions lost to a crashed
    /// incarnation, and sessions recovered byte-identically from the
    /// DRAM spill pool after a crash.
    pub backend_faults: u64,
    pub worker_panics: u64,
    pub worker_restarts: u64,
    pub sessions_lost: u64,
    pub sessions_recovered: u64,
    /// Accounted serving energy (ISSUE 10), attached by the workload
    /// layer's `EnergyAccountant` after shutdown — `None` until priced.
    pub energy: Option<EnergyStages>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, latency: Duration) {
        self.latencies_us.push(latency.as_secs_f64() * 1e6);
        self.completed += 1;
    }

    /// Count a coalesced wire batch (latencies recorded per response).
    pub fn note_batch(&mut self) {
        self.batches += 1;
    }

    /// Count one batched backend dispatch serving `occupancy` queries.
    pub fn note_dispatch(&mut self, occupancy: usize) {
        self.dispatches += 1;
        self.dispatched_queries += occupancy as u64;
        self.max_occupancy = self.max_occupancy.max(occupancy as u64);
    }

    /// Mean queries per backend dispatch; 0.0 before the first dispatch.
    pub fn mean_occupancy(&self) -> f64 {
        if self.dispatches == 0 {
            0.0
        } else {
            self.dispatched_queries as f64 / self.dispatches as f64
        }
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn merge(&mut self, other: &Metrics) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.completed += other.completed;
        self.batches += other.batches;
        self.errors += other.errors;
        self.prefills += other.prefills;
        self.decodes += other.decodes;
        self.attends += other.attends;
        self.closes += other.closes;
        self.evictions += other.evictions;
        self.kv_rows_released += other.kv_rows_released;
        self.dispatches += other.dispatches;
        self.dispatched_queries += other.dispatched_queries;
        self.max_occupancy = self.max_occupancy.max(other.max_occupancy);
        self.shed_requests += other.shed_requests;
        self.kv_rows_admitted += other.kv_rows_admitted;
        self.work.add(&other.work);
        self.demotions += other.demotions;
        self.promotions += other.promotions;
        self.spilled_rows += other.spilled_rows;
        self.dram_bytes_written += other.dram_bytes_written;
        self.dram_bytes_read += other.dram_bytes_read;
        self.dram_energy_j += other.dram_energy_j;
        self.promotion_ns.extend_from_slice(&other.promotion_ns);
        self.close_failures += other.close_failures;
        self.backend_faults += other.backend_faults;
        self.worker_panics += other.worker_panics;
        self.worker_restarts += other.worker_restarts;
        self.sessions_lost += other.sessions_lost;
        self.sessions_recovered += other.sessions_recovered;
        // high-water marks are per-worker peaks, not additive flows
        self.queue_depth_max = self.queue_depth_max.max(other.queue_depth_max);
        self.kv_rows_hwm = self.kv_rows_hwm.max(other.kv_rows_hwm);
        // accounted energy is a flow: stage-wise summed when both sides
        // were priced, carried over when only one was
        match (&mut self.energy, &other.energy) {
            (Some(mine), Some(theirs)) => mine.add(theirs),
            (mine @ None, Some(theirs)) => *mine = Some(*theirs),
            (_, None) => {}
        }
    }

    /// Attach the accounted per-stage energy (the workload layer's
    /// `EnergyAccountant` output) so summaries report J/token and watts.
    pub fn attach_energy(&mut self, stages: EnergyStages) {
        self.energy = Some(stages);
    }

    /// Accounted energy per decoded token \[J\]; 0.0 until energy is
    /// attached or before the first decode.
    pub fn energy_per_token_j(&self) -> f64 {
        match (&self.energy, self.decodes) {
            (Some(e), d) if d > 0 => e.total_j() / d as f64,
            _ => 0.0,
        }
    }

    /// Mean accounted power over a measured window \[W\]; 0.0 until
    /// energy is attached.
    pub fn watts(&self, window: Duration) -> f64 {
        match &self.energy {
            Some(e) if window > Duration::ZERO => e.total_j() / window.as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Decoded tokens per accounted joule \[1/J\] — throughput/W in its
    /// window-free form (tokens/s ÷ W); 0.0 until energy is attached.
    pub fn tokens_per_joule(&self) -> f64 {
        match &self.energy {
            Some(e) if e.total_j() > 0.0 => self.decodes as f64 / e.total_j(),
            _ => 0.0,
        }
    }

    /// Record one modeled promotion latency (spill tier → accelerator).
    pub fn note_promotion_latency_ns(&mut self, ns: f64) {
        self.promotion_ns.push(ns);
    }

    /// Any percentile of the modeled promotion latencies \[ns\]; 0.0
    /// before any promotion. The promotion-side twin of
    /// [`Metrics::latency_percentile_us`] — both distributions go through
    /// the same `stats::percentile` plumbing.
    pub fn promotion_percentile_ns(&self, p: f64) -> f64 {
        stats::percentile(&self.promotion_ns, p)
    }

    /// Median modeled promotion latency \[ns\]; 0.0 before any promotion.
    pub fn promotion_p50_ns(&self) -> f64 {
        self.promotion_percentile_ns(50.0)
    }

    /// Tail modeled promotion latency \[ns\].
    pub fn promotion_p99_ns(&self) -> f64 {
        self.promotion_percentile_ns(99.0)
    }

    /// Record the budget occupancy after a successful admission; keeps
    /// the high-water mark that the fuzz harness asserts never exceeds
    /// `worker_kv_budget`.
    pub fn note_kv_admission(&mut self, rows_admitted: usize, resident_rows: usize) {
        self.kv_rows_admitted += rows_admitted as u64;
        self.kv_rows_hwm = self.kv_rows_hwm.max(resident_rows as u64);
    }

    pub fn mean_latency_us(&self) -> f64 {
        stats::mean(&self.latencies_us)
    }

    /// Any percentile of the request latency distribution \[µs\] — the
    /// single helper every named latency accessor goes through (the
    /// promotion percentiles share the same plumbing via
    /// [`Metrics::promotion_percentile_ns`]).
    pub fn latency_percentile_us(&self, p: f64) -> f64 {
        stats::percentile(&self.latencies_us, p)
    }

    /// [`Metrics::latency_percentile_us`] as a `Duration`.
    pub fn latency_percentile(&self, p: f64) -> Duration {
        Duration::from_secs_f64(self.latency_percentile_us(p) / 1e6)
    }

    pub fn p50_us(&self) -> f64 {
        self.latency_percentile_us(50.0)
    }

    pub fn p95_us(&self) -> f64 {
        self.latency_percentile_us(95.0)
    }

    pub fn p99_us(&self) -> f64 {
        self.latency_percentile_us(99.0)
    }

    /// Median latency as a `Duration`.
    pub fn p50(&self) -> Duration {
        self.latency_percentile(50.0)
    }

    /// 95th-percentile latency as a `Duration`.
    pub fn p95(&self) -> Duration {
        self.latency_percentile(95.0)
    }

    /// Tail latency as a `Duration`.
    pub fn p99(&self) -> Duration {
        self.latency_percentile(99.0)
    }

    /// Throughput over a measured wall-clock window.
    pub fn throughput_per_s(&self, window: Duration) -> f64 {
        self.completed as f64 / window.as_secs_f64()
    }

    pub fn summary(&self, window: Duration) -> String {
        let mut s = format!(
            "completed={} (prefill={} decode={} attend={} close={}) evictions={} demotions={} \
             promotions={} spilled_rows={} dram_rd={} dram_wr={} promo_p50={:.0}ns batches={} \
             occupancy={:.2}x (max {}) queue_max={} shed={} kv_admitted={} kv_hwm={} errors={} \
             close_failures={} faults={} panics={} restarts={} sess_lost={} sess_recovered={} \
             thruput={:.1}/s mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us",
            self.completed,
            self.prefills,
            self.decodes,
            self.attends,
            self.closes,
            self.evictions,
            self.demotions,
            self.promotions,
            self.spilled_rows,
            self.dram_bytes_read,
            self.dram_bytes_written,
            self.promotion_p50_ns(),
            self.batches,
            self.mean_occupancy(),
            self.max_occupancy,
            self.queue_depth_max,
            self.shed_requests,
            self.kv_rows_admitted,
            self.kv_rows_hwm,
            self.errors,
            self.close_failures,
            self.backend_faults,
            self.worker_panics,
            self.worker_restarts,
            self.sessions_lost,
            self.sessions_recovered,
            self.throughput_per_s(window),
            self.mean_latency_us(),
            self.p50_us(),
            self.p95_us(),
            self.p99_us()
        );
        if let Some(e) = &self.energy {
            s.push_str(&format!(
                " energy_total={:.3e}J j_per_token={:.3e} watts={:.3} dram_share={:.1}%",
                e.total_j(),
                self.energy_per_token_j(),
                self.watts(window),
                e.dram_share() * 100.0
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarises() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record(Duration::from_micros(i));
        }
        assert_eq!(m.completed, 100);
        assert!((m.p50_us() - 50.5).abs() < 1.0);
        assert!(m.p95_us() > 90.0);
        assert!(m.mean_latency_us() > 49.0 && m.mean_latency_us() < 52.0);
        assert!(m.p99() >= m.p50());
        assert!(m.p50() > Duration::ZERO);
    }

    #[test]
    fn merge_combines() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.record(Duration::from_micros(10));
        a.decodes += 1;
        b.record(Duration::from_micros(20));
        b.attends += 1;
        b.record_error();
        b.closes += 2;
        b.evictions += 1;
        b.kv_rows_released += 64;
        a.merge(&b);
        assert_eq!(a.completed, 2);
        assert_eq!(a.errors, 1);
        assert_eq!(a.decodes, 1);
        assert_eq!(a.attends, 1);
        assert_eq!(a.closes, 2);
        assert_eq!(a.evictions, 1);
        assert_eq!(a.kv_rows_released, 64);
    }

    #[test]
    fn summary_reports_lifecycle_counters() {
        let mut m = Metrics::new();
        m.closes = 3;
        m.evictions = 2;
        let s = m.summary(Duration::from_secs(1));
        assert!(s.contains("close=3"), "{s}");
        assert!(s.contains("evictions=2"), "{s}");
    }

    #[test]
    fn summary_reports_scheduler_gauges() {
        let mut m = Metrics::new();
        m.shed_requests = 5;
        m.queue_depth_max = 12;
        m.note_kv_admission(16, 16);
        m.note_kv_admission(1, 17);
        let s = m.summary(Duration::from_secs(1));
        assert!(s.contains("shed=5"), "{s}");
        assert!(s.contains("queue_max=12"), "{s}");
        assert!(s.contains("kv_admitted=17"), "{s}");
        assert!(s.contains("kv_hwm=17"), "{s}");
    }

    #[test]
    fn kv_admission_tracks_monotone_flow_and_peak_residency() {
        let mut m = Metrics::new();
        m.note_kv_admission(32, 32); // prefill: 32 rows
        m.note_kv_admission(1, 33); // decode append
        m.note_kv_admission(8, 25); // re-prefill after a close shrank residency
        assert_eq!(m.kv_rows_admitted, 41, "admitted flow is monotone");
        assert_eq!(m.kv_rows_hwm, 33, "hwm keeps the peak, not the latest");
    }

    #[test]
    fn merge_maxes_high_water_marks_and_sums_sheds() {
        let mut a = Metrics::new();
        a.shed_requests = 2;
        a.queue_depth_max = 4;
        a.kv_rows_admitted = 10;
        a.kv_rows_hwm = 30;
        let mut b = Metrics::new();
        b.shed_requests = 3;
        b.queue_depth_max = 9;
        b.kv_rows_admitted = 7;
        b.kv_rows_hwm = 20;
        a.merge(&b);
        assert_eq!(a.shed_requests, 5, "sheds are a flow: summed");
        assert_eq!(a.kv_rows_admitted, 17, "admissions are a flow: summed");
        assert_eq!(a.queue_depth_max, 9, "queue peak is per-worker: maxed");
        assert_eq!(a.kv_rows_hwm, 30, "budget peak is per-worker: maxed");
    }

    #[test]
    fn merge_sums_backend_work_counters() {
        let mut a = Metrics::new();
        a.work.attends = 3;
        a.work.words_scored = 100;
        let mut b = Metrics::new();
        b.work.attends = 2;
        b.work.words_scored = 50;
        b.work.tiles_streamed = 7;
        b.work.survivor_corrections = 4;
        a.merge(&b);
        assert_eq!(a.work.attends, 5, "work counters are flows: summed");
        assert_eq!(a.work.words_scored, 150);
        assert_eq!(a.work.tiles_streamed, 7);
        assert_eq!(a.work.survivor_corrections, 4);
    }

    #[test]
    fn merge_sums_spill_tier_counters() {
        let mut a = Metrics::new();
        a.demotions = 2;
        a.dram_bytes_written = 1000;
        a.note_promotion_latency_ns(100.0);
        let mut b = Metrics::new();
        b.demotions = 1;
        b.promotions = 3;
        b.spilled_rows = 16;
        b.dram_bytes_written = 500;
        b.dram_bytes_read = 750;
        b.dram_energy_j = 1e-6;
        b.close_failures = 1;
        b.note_promotion_latency_ns(300.0);
        a.merge(&b);
        assert_eq!(a.demotions, 3, "spill counters are flows: summed");
        assert_eq!(a.promotions, 3);
        assert_eq!(a.spilled_rows, 16);
        assert_eq!(a.dram_bytes_written, 1500);
        assert_eq!(a.dram_bytes_read, 750);
        assert!((a.dram_energy_j - 1e-6).abs() < 1e-18);
        assert_eq!(a.close_failures, 1);
        // latencies concatenate: percentiles see both workers' promotions
        assert!((a.promotion_p50_ns() - 200.0).abs() < 1e-9);
        assert!(a.promotion_p99_ns() > 290.0);
    }

    #[test]
    fn summary_reports_spill_tier() {
        let mut m = Metrics::new();
        m.demotions = 4;
        m.promotions = 3;
        m.spilled_rows = 32;
        m.close_failures = 2;
        let s = m.summary(Duration::from_secs(1));
        assert!(s.contains("demotions=4"), "{s}");
        assert!(s.contains("promotions=3"), "{s}");
        assert!(s.contains("spilled_rows=32"), "{s}");
        assert!(s.contains("close_failures=2"), "{s}");
    }

    #[test]
    fn merge_sums_fault_containment_counters() {
        let mut a = Metrics::new();
        a.backend_faults = 2;
        a.worker_panics = 1;
        let mut b = Metrics::new();
        b.backend_faults = 3;
        b.worker_panics = 2;
        b.worker_restarts = 2;
        b.sessions_lost = 4;
        b.sessions_recovered = 3;
        a.merge(&b);
        assert_eq!(a.backend_faults, 5, "fault counters are flows: summed");
        assert_eq!(a.worker_panics, 3);
        assert_eq!(a.worker_restarts, 2);
        assert_eq!(a.sessions_lost, 4);
        assert_eq!(a.sessions_recovered, 3);
    }

    #[test]
    fn summary_reports_fault_containment() {
        let mut m = Metrics::new();
        m.backend_faults = 6;
        m.worker_panics = 2;
        m.worker_restarts = 1;
        m.sessions_lost = 3;
        m.sessions_recovered = 2;
        let s = m.summary(Duration::from_secs(1));
        assert!(s.contains("faults=6"), "{s}");
        assert!(s.contains("panics=2"), "{s}");
        assert!(s.contains("restarts=1"), "{s}");
        assert!(s.contains("sess_lost=3"), "{s}");
        assert!(s.contains("sess_recovered=2"), "{s}");
    }

    #[test]
    fn promotion_percentiles_zero_before_any_promotion() {
        let m = Metrics::new();
        assert_eq!(m.promotion_p50_ns(), 0.0);
        assert_eq!(m.promotion_p99_ns(), 0.0);
    }

    #[test]
    fn duration_accessors_cover_all_percentiles() {
        // p50/p95/p99 each have BOTH a µs accessor and a Duration
        // accessor, and the pairs agree (p95 used to be µs-only)
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record(Duration::from_micros(i));
        }
        for (us, d) in [(m.p50_us(), m.p50()), (m.p95_us(), m.p95()), (m.p99_us(), m.p99())] {
            // Duration rounds to whole nanoseconds, so agree within 1ns
            assert!((d.as_secs_f64() * 1e6 - us).abs() < 1e-3, "{d:?} vs {us}us");
            assert!(d > Duration::ZERO);
        }
        assert!(m.p50() <= m.p95() && m.p95() <= m.p99());
    }

    #[test]
    fn percentile_helpers_agree_with_named_accessors() {
        // the deduplicated plumbing: every named accessor is the generic
        // helper at a fixed p, for latencies and promotions alike
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record(Duration::from_micros(i));
            m.note_promotion_latency_ns(i as f64 * 10.0);
        }
        assert_eq!(m.p50_us(), m.latency_percentile_us(50.0));
        assert_eq!(m.p95_us(), m.latency_percentile_us(95.0));
        assert_eq!(m.p99_us(), m.latency_percentile_us(99.0));
        assert_eq!(m.p95(), m.latency_percentile(95.0));
        assert_eq!(m.promotion_p50_ns(), m.promotion_percentile_ns(50.0));
        assert_eq!(m.promotion_p99_ns(), m.promotion_percentile_ns(99.0));
    }

    #[test]
    fn energy_stages_total_and_add() {
        let mut a = EnergyStages {
            search_j: 1.0,
            program_j: 2.0,
            selection_j: 3.0,
            softmax_j: 4.0,
            context_j: 5.0,
            dram_j: 5.0,
        };
        assert!((a.total_j() - 20.0).abs() < 1e-12);
        assert!((a.dram_share() - 0.25).abs() < 1e-12);
        let twin = a;
        a.add(&twin);
        assert!((a.total_j() - 40.0).abs() < 1e-12);
        assert_eq!(EnergyStages::default().total_j(), 0.0);
        assert_eq!(EnergyStages::default().dram_share(), 0.0);
    }

    #[test]
    fn attached_energy_surfaces_in_summary_and_accessors() {
        let mut m = Metrics::new();
        m.decodes = 10;
        // unpriced metrics report zero energy and no energy line
        assert_eq!(m.energy_per_token_j(), 0.0);
        assert_eq!(m.tokens_per_joule(), 0.0);
        assert!(!m.summary(Duration::from_secs(1)).contains("j_per_token"));
        m.attach_energy(EnergyStages { search_j: 3.0, dram_j: 1.0, ..Default::default() });
        assert!((m.energy_per_token_j() - 0.4).abs() < 1e-12);
        assert!((m.watts(Duration::from_secs(2)) - 2.0).abs() < 1e-12);
        assert!((m.tokens_per_joule() - 2.5).abs() < 1e-12);
        let s = m.summary(Duration::from_secs(2));
        assert!(s.contains("j_per_token=4.000e-1"), "{s}");
        assert!(s.contains("watts=2.000"), "{s}");
        assert!(s.contains("dram_share=25.0%"), "{s}");
    }

    #[test]
    fn merge_sums_attached_energy() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        b.attach_energy(EnergyStages { context_j: 2.0, ..Default::default() });
        // None + Some carries the priced side over
        a.merge(&b);
        assert!((a.energy.unwrap().total_j() - 2.0).abs() < 1e-12);
        // Some + Some sums stage-wise
        a.attach_energy(EnergyStages { context_j: 2.0, dram_j: 1.0, ..Default::default() });
        a.merge(&b);
        let e = a.energy.unwrap();
        assert!((e.context_j - 4.0).abs() < 1e-12);
        assert!((e.dram_j - 1.0).abs() < 1e-12);
        // Some + None is unchanged
        a.merge(&Metrics::new());
        assert!((a.energy.unwrap().context_j - 4.0).abs() < 1e-12);
    }

    #[test]
    fn occupancy_tracks_queries_per_dispatch() {
        let mut m = Metrics::new();
        assert_eq!(m.mean_occupancy(), 0.0);
        m.note_dispatch(8);
        m.note_dispatch(2);
        assert_eq!(m.dispatches, 2);
        assert_eq!(m.dispatched_queries, 10);
        assert_eq!(m.max_occupancy, 8);
        assert!((m.mean_occupancy() - 5.0).abs() < 1e-12);
        let mut other = Metrics::new();
        other.note_dispatch(12);
        m.merge(&other);
        assert_eq!(m.dispatches, 3);
        assert_eq!(m.max_occupancy, 12);
    }

    #[test]
    fn batches_counted_separately_from_completions() {
        let mut m = Metrics::new();
        m.note_batch();
        for _ in 0..16 {
            m.record(Duration::from_micros(10));
        }
        assert_eq!(m.completed, 16);
        assert_eq!(m.batches, 1);
        m.note_batch();
        assert_eq!(m.batches, 2);
    }
}
