//! Serving metrics: latency distribution and throughput counters.

use std::time::Duration;

use crate::util::stats;

/// Rolling metrics for one server (or one worker).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<f64>,
    pub completed: u64,
    pub batches: u64,
    pub errors: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, latency: Duration) {
        self.latencies_us.push(latency.as_secs_f64() * 1e6);
        self.completed += 1;
    }

    pub fn record_batch(&mut self, size: usize, latency: Duration) {
        let per = latency.as_secs_f64() * 1e6;
        for _ in 0..size {
            self.latencies_us.push(per);
        }
        self.completed += size as u64;
        self.batches += 1;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn merge(&mut self, other: &Metrics) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.completed += other.completed;
        self.batches += other.batches;
        self.errors += other.errors;
    }

    pub fn mean_latency_us(&self) -> f64 {
        stats::mean(&self.latencies_us)
    }

    pub fn p50_us(&self) -> f64 {
        stats::percentile(&self.latencies_us, 50.0)
    }

    pub fn p95_us(&self) -> f64 {
        stats::percentile(&self.latencies_us, 95.0)
    }

    pub fn p99_us(&self) -> f64 {
        stats::percentile(&self.latencies_us, 99.0)
    }

    /// Throughput over a measured wall-clock window.
    pub fn throughput_per_s(&self, window: Duration) -> f64 {
        self.completed as f64 / window.as_secs_f64()
    }

    pub fn summary(&self, window: Duration) -> String {
        format!(
            "completed={} batches={} errors={} thruput={:.1}/s mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us",
            self.completed,
            self.batches,
            self.errors,
            self.throughput_per_s(window),
            self.mean_latency_us(),
            self.p50_us(),
            self.p95_us(),
            self.p99_us()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarises() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record(Duration::from_micros(i));
        }
        assert_eq!(m.completed, 100);
        assert!((m.p50_us() - 50.5).abs() < 1.0);
        assert!(m.p95_us() > 90.0);
        assert!(m.mean_latency_us() > 49.0 && m.mean_latency_us() < 52.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.record(Duration::from_micros(10));
        b.record(Duration::from_micros(20));
        b.record_error();
        a.merge(&b);
        assert_eq!(a.completed, 2);
        assert_eq!(a.errors, 1);
    }

    #[test]
    fn batch_counts_each_query() {
        let mut m = Metrics::new();
        m.record_batch(16, Duration::from_micros(160));
        assert_eq!(m.completed, 16);
        assert_eq!(m.batches, 1);
    }
}
