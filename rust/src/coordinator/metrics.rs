//! Serving metrics: latency distribution, throughput and per-op counters.

use std::time::Duration;

use crate::util::stats;

/// Rolling metrics for one server (or one worker).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<f64>,
    pub completed: u64,
    pub batches: u64,
    pub errors: u64,
    /// Per-op accounting for the session serving API.
    pub prefills: u64,
    pub decodes: u64,
    pub attends: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, latency: Duration) {
        self.latencies_us.push(latency.as_secs_f64() * 1e6);
        self.completed += 1;
    }

    /// Count a coalesced batch (latencies recorded per response).
    pub fn note_batch(&mut self) {
        self.batches += 1;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn merge(&mut self, other: &Metrics) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.completed += other.completed;
        self.batches += other.batches;
        self.errors += other.errors;
        self.prefills += other.prefills;
        self.decodes += other.decodes;
        self.attends += other.attends;
    }

    pub fn mean_latency_us(&self) -> f64 {
        stats::mean(&self.latencies_us)
    }

    pub fn p50_us(&self) -> f64 {
        stats::percentile(&self.latencies_us, 50.0)
    }

    pub fn p95_us(&self) -> f64 {
        stats::percentile(&self.latencies_us, 95.0)
    }

    pub fn p99_us(&self) -> f64 {
        stats::percentile(&self.latencies_us, 99.0)
    }

    /// Median latency as a `Duration`.
    pub fn p50(&self) -> Duration {
        Duration::from_secs_f64(self.p50_us() / 1e6)
    }

    /// Tail latency as a `Duration`.
    pub fn p99(&self) -> Duration {
        Duration::from_secs_f64(self.p99_us() / 1e6)
    }

    /// Throughput over a measured wall-clock window.
    pub fn throughput_per_s(&self, window: Duration) -> f64 {
        self.completed as f64 / window.as_secs_f64()
    }

    pub fn summary(&self, window: Duration) -> String {
        format!(
            "completed={} (prefill={} decode={} attend={}) batches={} errors={} \
             thruput={:.1}/s mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us",
            self.completed,
            self.prefills,
            self.decodes,
            self.attends,
            self.batches,
            self.errors,
            self.throughput_per_s(window),
            self.mean_latency_us(),
            self.p50_us(),
            self.p95_us(),
            self.p99_us()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarises() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record(Duration::from_micros(i));
        }
        assert_eq!(m.completed, 100);
        assert!((m.p50_us() - 50.5).abs() < 1.0);
        assert!(m.p95_us() > 90.0);
        assert!(m.mean_latency_us() > 49.0 && m.mean_latency_us() < 52.0);
        assert!(m.p99() >= m.p50());
        assert!(m.p50() > Duration::ZERO);
    }

    #[test]
    fn merge_combines() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.record(Duration::from_micros(10));
        a.decodes += 1;
        b.record(Duration::from_micros(20));
        b.attends += 1;
        b.record_error();
        a.merge(&b);
        assert_eq!(a.completed, 2);
        assert_eq!(a.errors, 1);
        assert_eq!(a.decodes, 1);
        assert_eq!(a.attends, 1);
    }

    #[test]
    fn batches_counted_separately_from_completions() {
        let mut m = Metrics::new();
        m.note_batch();
        for _ in 0..16 {
            m.record(Duration::from_micros(10));
        }
        assert_eq!(m.completed, 16);
        assert_eq!(m.batches, 1);
        m.note_batch();
        assert_eq!(m.batches, 2);
    }
}
