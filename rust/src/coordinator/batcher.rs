//! Dynamic batcher: accumulate queries up to the batch size or a deadline,
//! whichever first — the standard serving trade between utilisation (the
//! `attn_batch` artifact amortises dispatch) and tail latency.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 16, // the attn_batch artifact's geometry
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Pull one batch from `rx` under the policy. Returns collected items
/// (possibly fewer than max_batch on timeout) or None when the channel is
/// closed and drained.
pub fn next_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Option<Vec<T>> {
    // block for the first item
    let first = match rx.recv() {
        Ok(item) => item,
        Err(_) => return None,
    };
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            // A timeout only says the OS wait elapsed *approximately*;
            // loop back and let the deadline check decide, so an early
            // timer wakeup can never return an under-waited partial batch
            // (the source of flakes on loaded CI machines).
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    #[test]
    fn collects_full_batch_when_available() {
        let (tx, rx) = mpsc::channel();
        for i in 0..20 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(50) };
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b.len(), 16);
        let b2 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b2.len(), 4);
    }

    // De-flaked (ISSUE 1): asserts only the guaranteed lower bound — the
    // deadline loop cannot return before `max_wait` has fully elapsed —
    // and puts no upper bound on elapsed time, which a loaded CI machine
    // cannot honour.
    #[test]
    fn times_out_with_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let policy = BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(10) };
        let t0 = Instant::now();
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b.len(), 1);
        assert!(
            t0.elapsed() >= policy.max_wait,
            "returned after {:?}, before the {:?} deadline",
            t0.elapsed(),
            policy.max_wait
        );
        drop(tx);
    }

    #[test]
    fn none_when_closed() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    // De-flaked (ISSUE 1): the seed version staggered sends with
    // micro-sleeps, so a preempted sender could race the batcher's
    // deadline. Arrival timing is irrelevant to the property under test —
    // every sent item is drained, in order, in batches of at most
    // max_batch — so the sends are unstaggered and the only timing left
    // (a generous max_wait) has no bearing on the assertions.
    #[test]
    fn drains_after_sender_thread_finishes() {
        let (tx, rx) = mpsc::channel();
        let h = thread::spawn(move || {
            for i in 0..5 {
                tx.send(i).unwrap();
            }
            // tx drops here: the channel disconnects once drained
        });
        h.join().unwrap();
        let policy = BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(5) };
        let mut got = Vec::new();
        while let Some(b) = next_batch(&rx, &policy) {
            assert!(b.len() <= 3);
            got.extend(b);
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }
}
