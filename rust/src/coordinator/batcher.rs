//! Dynamic batching for the serving hot path.
//!
//! Two layers:
//!
//! * [`next_batch`] — the wire batcher: accumulate queued requests up to
//!   the batch size or a deadline, whichever first. The standard serving
//!   trade between utilisation and tail latency.
//! * [`DecodeBatcher`] — the request-aware planner on top: partition one
//!   wire batch of [`Envelope`]s into [`DispatchGroup`]s so that decode
//!   steps and read-only attends of *different sessions* execute as a
//!   single backend dispatch against their own (stationary) key
//!   memories. This is the paper's key-stationary amortisation (Fig. 5):
//!   the BA-CAM search cost is paid once per dispatch, not once per
//!   query.
//!
//! # Batch-safety invariant
//!
//! A dispatch group executes as "apply every `Decode`'s KV append first
//! (in program order), then one batched attend over the resulting
//! caches". That is bit-equal to sequential execution if and only if no
//! query in the group observes an append that, sequentially, happens
//! *after* it. The two planning modes ([`PlanMode`]) discharge that
//! obligation differently:
//!
//! * [`PlanMode::Conservative`] ([`DecodeBatcher::plan`]) *avoids* the
//!   hazard: at most one `Decode` per session per group (a second one
//!   would leak its append into the first's query), and a `Decode` must
//!   be its session's *first* item in the group (an `Attend` enqueued
//!   before it must not see its append). Every query then attends over
//!   its session's final in-group cache, which equals its sequential
//!   view. The cost: a deep single-session decode burst — the dominant
//!   decode-serving shape — flushes at every step and degrades to
//!   dispatch occupancy 1, forfeiting the paper's key-stationary
//!   amortisation (Fig. 5).
//!
//! * [`PlanMode::Speculative`] ([`DecodeBatcher::plan_speculative`], the
//!   default) *represents* the hazard instead of splitting on it:
//!   several decode steps of one session may share a group, because the
//!   worker records each query's **causal prefix** — the session KV
//!   length at the query's own program position — while applying the
//!   appends in program order, and each query then attends over a
//!   prefix view of its session's store
//!   (`KvStore::padded_prefix_view`, `AttendItem::prefix_rows`) — with
//!   the store-owned sign-packed key bits riding along
//!   (`AttendItem::packed`) so backends score without re-packing. Rows
//!   at or beyond a query's prefix are scored and contextualised
//!   exactly as the pre-written pad rows they replace, so every step's
//!   output is bit-equal to sequential dispatch; mid-burst admission
//!   refusals leave the store untouched and never poison batch-mates,
//!   and a failed dispatch rolls all speculative appends back.
//!
//! `Prefill` is a bulk cache replacement (it can shrink the cache, which
//! no prefix view can represent) and always executes alone, as a
//! barrier, in both modes.
//!
//! `Close` (ISSUE 5) is a **same-session barrier** in both modes: it may
//! join the open group (the worker executes closes *after* the group's
//! dispatch, and every same-session batch-mate planned before it still
//! sees the live store), but any later item of the *closed* session must
//! start a new group — sequentially it runs after the close and must
//! observe the session gone. Items of *other* sessions keep fusing
//! around a close, so lifecycle traffic does not forfeit occupancy.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use super::server::{Envelope, Request};
use super::session::SessionId;

/// How [`DecodeBatcher`] fuses one wire batch into dispatch groups (see
/// the module docs for the batch-safety invariant each mode upholds).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanMode {
    /// Split at every same-session hazard: at most one `Decode` per
    /// session per group, `Decode` first. Deep per-session bursts run at
    /// occupancy 1.
    Conservative,
    /// Speculative multi-step fusion: fuse same-session steps into one
    /// dispatch; each query attends over its own causal prefix view.
    Speculative,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub mode: PlanMode,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 16, // the attn_batch artifact's geometry
            max_wait: Duration::from_millis(2),
            mode: PlanMode::Speculative,
        }
    }
}

impl BatchPolicy {
    /// Policy with the given wire-batch bounds and the default
    /// (speculative) planning mode.
    pub fn bounds(max_batch: usize, max_wait: Duration) -> Self {
        BatchPolicy { max_batch, max_wait, ..Default::default() }
    }

    /// Same bounds, conservative planning.
    pub fn conservative(max_batch: usize, max_wait: Duration) -> Self {
        BatchPolicy { max_batch, max_wait, mode: PlanMode::Conservative }
    }
}

/// Pull one batch from `rx` under the policy. Returns collected items
/// (possibly fewer than max_batch on timeout) or None when the channel is
/// closed and drained.
pub fn next_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Option<Vec<T>> {
    // block for the first item
    let first = match rx.recv() {
        Ok(item) => item,
        Err(_) => return None,
    };
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            // A timeout only says the OS wait elapsed *approximately*;
            // loop back and let the deadline check decide, so an early
            // timer wakeup can never return an under-waited partial batch
            // (the source of flakes on loaded CI machines).
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// One unit of backend work planned by [`DecodeBatcher::plan`].
#[derive(Debug)]
pub enum DispatchGroup {
    /// A `Prefill` barrier: bulk cache replacement, executes alone.
    Barrier(Envelope),
    /// `Decode` / `Attend` / `Close` steps of (possibly distinct)
    /// sessions that are safe to execute as one backend dispatch: all
    /// appends first, then a single batched attend over each item's own
    /// session cache, then the group's closes.
    Batch(Vec<Envelope>),
}

/// Request-aware planner for cross-session batched decode.
///
/// Wraps the wire-level [`next_batch`] and partitions what it pulls into
/// [`DispatchGroup`]s under the batch-safety invariant (module docs) of
/// the policy's [`PlanMode`]. A worker drives it in a loop: every
/// `Batch` group becomes exactly one
/// [`AttentionBackend::attend_batch`] call.
///
/// [`AttentionBackend::attend_batch`]: super::backend::AttentionBackend::attend_batch
///
/// # Example
///
/// ```
/// use camformer::coordinator::batcher::{DecodeBatcher, DispatchGroup};
/// use camformer::coordinator::{Envelope, Request};
///
/// let step = |id, session| {
///     Envelope::pool(Request::Decode {
///         id,
///         session,
///         head: 0,
///         query: vec![0.0; 64],
///         new_key: vec![0.0; 64],
///         new_value: vec![0.0; 64],
///     })
/// };
/// let close = |id, session| Envelope::pool(Request::Close { id, session, head: 0 });
///
/// // one decode step from each of four sessions: a single dispatch
/// let groups = DecodeBatcher::plan(vec![step(0, 1), step(1, 2), step(2, 3), step(3, 4)]);
/// assert!(matches!(&groups[..], [DispatchGroup::Batch(items)] if items.len() == 4));
///
/// // conservatively, a session's *second* step must not share a
/// // dispatch with its first…
/// let groups = DecodeBatcher::plan(vec![step(0, 1), step(1, 2), step(2, 1)]);
/// assert_eq!(groups.len(), 2);
///
/// // …while speculative fusion serves even a deep single-session burst
/// // as ONE dispatch (each step attends over its own causal prefix)
/// let groups = DecodeBatcher::plan_speculative(vec![step(0, 1), step(1, 1), step(2, 1)]);
/// assert!(matches!(&groups[..], [DispatchGroup::Batch(items)] if items.len() == 3));
///
/// // a Close is a same-session barrier: a later item of ITS session
/// // starts a new group, while other sessions keep fusing around it
/// let groups =
///     DecodeBatcher::plan_speculative(vec![step(0, 1), close(1, 1), step(2, 2), step(3, 1)]);
/// let sizes: Vec<usize> = groups
///     .iter()
///     .map(|g| match g {
///         DispatchGroup::Batch(items) => items.len(),
///         DispatchGroup::Barrier(..) => 0,
///     })
///     .collect();
/// assert_eq!(sizes, vec![3, 1]);
/// ```
pub struct DecodeBatcher {
    pub policy: BatchPolicy,
}

impl DecodeBatcher {
    pub fn new(policy: BatchPolicy) -> Self {
        DecodeBatcher { policy }
    }

    /// Pull one wire batch and plan it under the policy's mode. `None`
    /// when the request channel is closed and drained (worker shutdown).
    pub fn next_groups(&self, rx: &Receiver<Envelope>) -> Option<Vec<DispatchGroup>> {
        next_batch(rx, &self.policy).map(|items| Self::plan_mode(self.policy.mode, items))
    }

    /// Plan under an explicit [`PlanMode`].
    pub fn plan_mode(mode: PlanMode, items: Vec<Envelope>) -> Vec<DispatchGroup> {
        match mode {
            PlanMode::Conservative => Self::plan(items),
            PlanMode::Speculative => Self::plan_speculative(items),
        }
    }

    /// Speculative multi-step fusion: partition a wire batch into
    /// dispatch groups, preserving arrival order, splitting ONLY at
    /// `Prefill` barriers and at items following a same-session `Close`
    /// — same-session decode runs fuse, and the worker's prefix views
    /// carry the causal ordering (module docs).
    pub fn plan_speculative(items: Vec<Envelope>) -> Vec<DispatchGroup> {
        let mut groups: Vec<DispatchGroup> = Vec::new();
        let mut open: Vec<Envelope> = Vec::new();
        // sessions with a Close in `open`: their later items must not
        // share the group (they run after the close, sequentially)
        let mut closed: Vec<SessionId> = Vec::new();
        for env in items {
            match &env.req {
                Request::Prefill { .. } => {
                    if !open.is_empty() {
                        groups.push(DispatchGroup::Batch(std::mem::take(&mut open)));
                        closed.clear();
                    }
                    groups.push(DispatchGroup::Barrier(env));
                }
                req => {
                    let session = req.session();
                    if closed.contains(&session) {
                        groups.push(DispatchGroup::Batch(std::mem::take(&mut open)));
                        closed.clear();
                    }
                    if matches!(req, Request::Close { .. }) {
                        closed.push(session);
                    }
                    open.push(env);
                }
            }
        }
        if !open.is_empty() {
            groups.push(DispatchGroup::Batch(open));
        }
        groups
    }

    /// Conservative planning: partition a wire batch into dispatch
    /// groups, preserving arrival order, splitting at every same-session
    /// hazard:
    ///
    /// * `Prefill` flushes the open group and becomes a [`DispatchGroup::Barrier`];
    /// * `Decode` on a session already present in the open group flushes
    ///   first (its append must stay invisible to the group's queries);
    /// * `Attend` joins the open group unless its session was closed in
    ///   it;
    /// * `Close` joins the open group (it executes after the dispatch)
    ///   and bars later same-session items from it.
    pub fn plan(items: Vec<Envelope>) -> Vec<DispatchGroup> {
        let mut groups: Vec<DispatchGroup> = Vec::new();
        let mut open: Vec<Envelope> = Vec::new();
        // sessions with an item in `open`; wire batches are small (max 16
        // by default), so linear scans beat hash sets here
        let mut touched: Vec<SessionId> = Vec::new();
        let mut closed: Vec<SessionId> = Vec::new();
        for env in items {
            match &env.req {
                Request::Prefill { .. } => {
                    if !open.is_empty() {
                        groups.push(DispatchGroup::Batch(std::mem::take(&mut open)));
                        touched.clear();
                        closed.clear();
                    }
                    groups.push(DispatchGroup::Barrier(env));
                }
                Request::Decode { session, .. } => {
                    if touched.contains(session) || closed.contains(session) {
                        groups.push(DispatchGroup::Batch(std::mem::take(&mut open)));
                        touched.clear();
                        closed.clear();
                    }
                    touched.push(*session);
                    open.push(env);
                }
                Request::Attend { session, .. } => {
                    if closed.contains(session) {
                        groups.push(DispatchGroup::Batch(std::mem::take(&mut open)));
                        touched.clear();
                        closed.clear();
                    }
                    if !touched.contains(session) {
                        touched.push(*session);
                    }
                    open.push(env);
                }
                Request::Close { session, .. } => {
                    if closed.contains(session) {
                        groups.push(DispatchGroup::Batch(std::mem::take(&mut open)));
                        touched.clear();
                        closed.clear();
                    }
                    if !touched.contains(session) {
                        touched.push(*session);
                    }
                    closed.push(*session);
                    open.push(env);
                }
            }
        }
        if !open.is_empty() {
            groups.push(DispatchGroup::Batch(open));
        }
        groups
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    #[test]
    fn collects_full_batch_when_available() {
        let (tx, rx) = mpsc::channel();
        for i in 0..20 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy::bounds(16, Duration::from_millis(50));
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b.len(), 16);
        let b2 = next_batch(&rx, &policy).unwrap();
        assert_eq!(b2.len(), 4);
    }

    // De-flaked (ISSUE 1): asserts only the guaranteed lower bound — the
    // deadline loop cannot return before `max_wait` has fully elapsed —
    // and puts no upper bound on elapsed time, which a loaded CI machine
    // cannot honour.
    #[test]
    fn times_out_with_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let policy = BatchPolicy::bounds(16, Duration::from_millis(10));
        let t0 = Instant::now();
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b.len(), 1);
        assert!(
            t0.elapsed() >= policy.max_wait,
            "returned after {:?}, before the {:?} deadline",
            t0.elapsed(),
            policy.max_wait
        );
        drop(tx);
    }

    #[test]
    fn none_when_closed() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    // De-flaked (ISSUE 1): the seed version staggered sends with
    // micro-sleeps, so a preempted sender could race the batcher's
    // deadline. Arrival timing is irrelevant to the property under test —
    // every sent item is drained, in order, in batches of at most
    // max_batch — so the sends are unstaggered and the only timing left
    // (a generous max_wait) has no bearing on the assertions.
    #[test]
    fn drains_after_sender_thread_finishes() {
        let (tx, rx) = mpsc::channel();
        let h = thread::spawn(move || {
            for i in 0..5 {
                tx.send(i).unwrap();
            }
            // tx drops here: the channel disconnects once drained
        });
        h.join().unwrap();
        let policy = BatchPolicy::bounds(3, Duration::from_secs(5));
        let mut got = Vec::new();
        while let Some(b) = next_batch(&rx, &policy) {
            assert!(b.len() <= 3);
            got.extend(b);
        }
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    // ---- DecodeBatcher planning ----

    fn decode(id: u64, session: u64) -> Envelope {
        Envelope::pool(Request::Decode {
            id,
            session,
            head: 0,
            query: vec![0.0; 4],
            new_key: vec![0.0; 4],
            new_value: vec![0.0; 4],
        })
    }

    fn attend(id: u64, session: u64) -> Envelope {
        Envelope::pool(Request::Attend { id, session, head: 0, query: vec![0.0; 4] })
    }

    fn prefill(id: u64, session: u64) -> Envelope {
        Envelope::pool(Request::Prefill {
            id,
            session,
            head: 0,
            keys: vec![0.0; 4],
            values: vec![0.0; 4],
        })
    }

    fn close(id: u64, session: u64) -> Envelope {
        Envelope::pool(Request::Close { id, session, head: 0 })
    }

    fn batch_sizes(groups: &[DispatchGroup]) -> Vec<usize> {
        groups
            .iter()
            .map(|g| match g {
                DispatchGroup::Barrier(..) => 0,
                DispatchGroup::Batch(items) => items.len(),
            })
            .collect()
    }

    #[test]
    fn distinct_sessions_coalesce_into_one_dispatch() {
        let groups = DecodeBatcher::plan(vec![
            decode(0, 10),
            decode(1, 11),
            attend(2, 12),
            decode(3, 13),
        ]);
        assert_eq!(batch_sizes(&groups), vec![4]);
    }

    #[test]
    fn second_decode_of_a_session_starts_a_new_group() {
        // round-robin decode over 2 sessions, 2 steps each: two groups
        let groups =
            DecodeBatcher::plan(vec![decode(0, 1), decode(1, 2), decode(2, 1), decode(3, 2)]);
        assert_eq!(batch_sizes(&groups), vec![2, 2]);
    }

    #[test]
    fn decode_after_attend_on_same_session_is_a_barrier() {
        // the attend must not observe the decode's append
        let groups = DecodeBatcher::plan(vec![attend(0, 1), decode(1, 1)]);
        assert_eq!(batch_sizes(&groups), vec![1, 1]);
    }

    #[test]
    fn attends_after_decode_share_its_group() {
        // sequentially these attends all see the post-append cache, which
        // is exactly what appends-first batched execution gives them
        let groups = DecodeBatcher::plan(vec![decode(0, 1), attend(1, 1), attend(2, 1)]);
        assert_eq!(batch_sizes(&groups), vec![3]);
    }

    #[test]
    fn prefill_is_always_a_barrier() {
        let groups = DecodeBatcher::plan(vec![decode(0, 1), prefill(1, 2), decode(2, 3)]);
        assert_eq!(batch_sizes(&groups), vec![1, 0, 1]);
        assert!(matches!(
            &groups[1],
            DispatchGroup::Barrier(Envelope { req: Request::Prefill { .. }, .. })
        ));
    }

    #[test]
    fn plan_preserves_arrival_order() {
        let groups = DecodeBatcher::plan(vec![
            attend(0, 1),
            decode(1, 2),
            attend(2, 1),
            decode(3, 1), // flush: session 1 already present
            attend(4, 2),
        ]);
        let ids: Vec<Vec<u64>> = groups
            .iter()
            .map(|g| match g {
                DispatchGroup::Barrier(e) => vec![e.req.id()],
                DispatchGroup::Batch(items) => items.iter().map(|e| e.req.id()).collect(),
            })
            .collect();
        assert_eq!(ids, vec![vec![0, 1, 2], vec![3, 4]]);
    }

    #[test]
    fn empty_plan_is_empty() {
        assert!(DecodeBatcher::plan(Vec::new()).is_empty());
        assert!(DecodeBatcher::plan_speculative(Vec::new()).is_empty());
    }

    // ---- speculative fusion ----

    #[test]
    fn speculative_fuses_deep_single_session_burst() {
        let groups = DecodeBatcher::plan_speculative(vec![
            decode(0, 1),
            decode(1, 1),
            decode(2, 1),
            decode(3, 1),
        ]);
        assert_eq!(batch_sizes(&groups), vec![4]);
    }

    #[test]
    fn speculative_fuses_attend_before_and_after_decode() {
        // representable with prefix views: the leading attend's prefix
        // stops before the appends, the trailing one sees them
        let groups = DecodeBatcher::plan_speculative(vec![
            attend(0, 1),
            decode(1, 1),
            decode(2, 1),
            attend(3, 1),
        ]);
        assert_eq!(batch_sizes(&groups), vec![4]);
    }

    #[test]
    fn speculative_still_treats_prefill_as_barrier() {
        let groups = DecodeBatcher::plan_speculative(vec![
            decode(0, 1),
            decode(1, 1),
            prefill(2, 1),
            decode(3, 1),
        ]);
        assert_eq!(batch_sizes(&groups), vec![2, 0, 1]);
        assert!(matches!(
            &groups[1],
            DispatchGroup::Barrier(Envelope { req: Request::Prefill { .. }, .. })
        ));
    }

    // ---- Close planning (ISSUE 5) ----

    #[test]
    fn speculative_close_bars_only_its_own_session() {
        // the close joins the group; a LATER item of the closed session
        // starts a new group, while another session fuses right through
        let groups = DecodeBatcher::plan_speculative(vec![
            decode(0, 1),
            close(1, 1),
            decode(2, 2),
            decode(3, 1),
            attend(4, 2),
        ]);
        assert_eq!(batch_sizes(&groups), vec![3, 2]);
    }

    #[test]
    fn speculative_close_before_decode_of_same_session_splits() {
        let groups = DecodeBatcher::plan_speculative(vec![close(0, 1), decode(1, 1)]);
        assert_eq!(batch_sizes(&groups), vec![1, 1]);
    }

    #[test]
    fn double_close_splits_in_both_modes() {
        // the second close must observe the first one's effect
        // (UnknownSession), so it cannot share the group
        for mode in [PlanMode::Conservative, PlanMode::Speculative] {
            let groups = DecodeBatcher::plan_mode(mode, vec![close(0, 1), close(1, 1)]);
            assert_eq!(batch_sizes(&groups), vec![1, 1], "{mode:?}");
        }
    }

    #[test]
    fn conservative_close_rules() {
        // decode-then-close fuses (close runs after the dispatch);
        // attend-after-close splits; close counts as the session's item,
        // so a decode after it splits too
        let groups = DecodeBatcher::plan(vec![decode(0, 1), close(1, 1), attend(2, 1)]);
        assert_eq!(batch_sizes(&groups), vec![2, 1]);
        let groups = DecodeBatcher::plan(vec![close(0, 1), decode(1, 1)]);
        assert_eq!(batch_sizes(&groups), vec![1, 1]);
        // a close does not bar OTHER sessions from the group
        let groups = DecodeBatcher::plan(vec![close(0, 1), decode(1, 2), attend(2, 3)]);
        assert_eq!(batch_sizes(&groups), vec![3]);
    }

    #[test]
    fn plan_mode_dispatches_to_the_right_planner() {
        let items = || vec![decode(0, 1), decode(1, 1)];
        let cons = DecodeBatcher::plan_mode(PlanMode::Conservative, items());
        assert_eq!(batch_sizes(&cons), vec![1, 1]);
        let spec = DecodeBatcher::plan_mode(PlanMode::Speculative, items());
        assert_eq!(batch_sizes(&spec), vec![2]);
    }

    #[test]
    fn policy_constructors_set_mode() {
        let b = BatchPolicy::bounds(4, Duration::from_millis(1));
        assert_eq!((b.max_batch, b.mode), (4, PlanMode::Speculative));
        let c = BatchPolicy::conservative(4, Duration::from_millis(1));
        assert_eq!((c.max_batch, c.mode), (4, PlanMode::Conservative));
    }
}
