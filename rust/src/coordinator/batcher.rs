//! Continuous batching for the serving hot path.
//!
//! Three layers:
//!
//! * [`WorkQueue`] — the standing per-worker queue: every submitted
//!   [`Envelope`] lands here (in arrival order) and waits until the
//!   scheduling loop admits it into a dispatch plan. Unlike the old
//!   one-shot wire batcher, the queue persists across scheduling cycles,
//!   so a straggler never forces the pipeline to drain.
//! * [`GroupPlan`] — an *incremental* dispatch plan: the scheduler feeds
//!   it envelopes one at a time and asks, before each, whether the item
//!   may join the open plan ([`GroupPlan::admits`]) under the
//!   batch-safety invariant of its [`PlanMode`]. The worker's scheduling
//!   loop keeps a plan open and **extends** it as new tickets arrive,
//!   dispatching when the plan fills, a barrier blocks the queue front,
//!   the waiting backlog trips [`BatchPolicy::waiting_served_ratio`], or
//!   [`BatchPolicy::max_wait`] expires.
//! * [`DecodeBatcher`] — the one-shot planner over a whole slice of
//!   envelopes, used by tests and by anyone replaying a recorded wire
//!   batch. It is implemented by folding the slice through a
//!   [`GroupPlan`], so the standing scheduler and the batch planner
//!   cannot disagree about grouping rules: they are the same code.
//!
//! # Batch-safety invariant
//!
//! A dispatch group executes as "apply every `Decode`'s KV append first
//! (in program order), then one batched attend over the resulting
//! caches". That is bit-equal to sequential execution if and only if no
//! query in the group observes an append that, sequentially, happens
//! *after* it. The two planning modes ([`PlanMode`]) discharge that
//! obligation differently:
//!
//! * [`PlanMode::Conservative`] ([`DecodeBatcher::plan`]) *avoids* the
//!   hazard: at most one `Decode` per session per group (a second one
//!   would leak its append into the first's query), and a `Decode` must
//!   be its session's *first* item in the group (an `Attend` enqueued
//!   before it must not see its append). Every query then attends over
//!   its session's final in-group cache, which equals its sequential
//!   view. The cost: a deep single-session decode burst — the dominant
//!   decode-serving shape — flushes at every step and degrades to
//!   dispatch occupancy 1, forfeiting the paper's key-stationary
//!   amortisation (Fig. 5).
//!
//! * [`PlanMode::Speculative`] ([`DecodeBatcher::plan_speculative`], the
//!   default) *represents* the hazard instead of splitting on it:
//!   several decode steps of one session may share a group, because the
//!   worker records each query's **causal prefix** — the session KV
//!   length at the query's own program position — while applying the
//!   appends in program order, and each query then attends over a
//!   prefix view of its session's store
//!   (`KvStore::padded_prefix_view`, `AttendItem::prefix_rows`) — with
//!   the store-owned sign-packed key bits riding along
//!   (`AttendItem::packed`) so backends score without re-packing. Rows
//!   at or beyond a query's prefix are scored and contextualised
//!   exactly as the pre-written pad rows they replace, so every step's
//!   output is bit-equal to sequential dispatch; mid-burst admission
//!   refusals leave the store untouched and never poison batch-mates,
//!   and a failed dispatch rolls all speculative appends back.
//!
//! `Prefill` is a bulk cache replacement (it can shrink the cache, which
//! no prefix view can represent) and always executes alone, as a
//! barrier, in both modes.
//!
//! Since the shard directory (ISSUE 8), a `Decode`/`Attend` whose
//! session is parked in the DRAM spill tier acts as a **promotion
//! barrier** at the scheduling layer: the worker stops extending the
//! open plan at that envelope, restores the session's KV from the spill
//! pool (demoting another victim if the budget or slot limit demands
//! it), and only then lets the envelope execute — in its original
//! program position, one cycle later. Promotion thus sits exactly where
//! a `Prefill` barrier would, so the planner's no-reorder guarantee (and
//! with it bit-equality to sequential dispatch) is untouched.
//!
//! `Close` (ISSUE 5) is a **same-session barrier** in both modes: it may
//! join the open group (the worker executes closes *after* the group's
//! dispatch, and every same-session batch-mate planned before it still
//! sees the live store), but any later item of the *closed* session must
//! start a new group — sequentially it runs after the close and must
//! observe the session gone. Items of *other* sessions keep fusing
//! around a close, so lifecycle traffic does not forfeit occupancy.
//!
//! # Why the scheduler never reorders
//!
//! A TGI-style router reorders freely (waiting prefills can overtake a
//! running decode batch). Here dispatch plans are always a **contiguous
//! prefix of per-worker arrival order**: reordering would permute the
//! worker's logical clock, which drives LRU eviction, and evictions
//! would then diverge between batched and sequential dispatch — the
//! bit-equality invariant the whole fuzz harness pivots on. The
//! `waiting_served_ratio` knob therefore controls only *when the open
//! plan stops extending* (letting a blocked barrier — typically a
//! waiting prefill — run sooner), never *what order work runs in*.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::Duration;

use super::server::{Envelope, Request};
use super::session::SessionId;

/// How dispatch plans fuse envelopes into groups (see the module docs
/// for the batch-safety invariant each mode upholds).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanMode {
    /// Split at every same-session hazard: at most one `Decode` per
    /// session per group, `Decode` first. Deep per-session bursts run at
    /// occupancy 1.
    Conservative,
    /// Speculative multi-step fusion: fuse same-session steps into one
    /// dispatch; each query attends over its own causal prefix view.
    Speculative,
}

/// Batching policy for the standing scheduler.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Largest dispatch plan (one backend dispatch serves at most this
    /// many queries).
    pub max_batch: usize,
    /// How long an open plan may wait for more arrivals before it
    /// dispatches anyway.
    pub max_wait: Duration,
    pub mode: PlanMode,
    /// When the queue holds `waiting` items that *cannot* join the open
    /// plan (a prefill barrier or a same-session hazard at the front),
    /// the plan stops extending and dispatches as soon as
    /// `waiting >= waiting_served_ratio * plan_len`. Small values let a
    /// lone waiting prefill preempt decode extension immediately; large
    /// values let the plan keep filling toward `max_batch` first. The
    /// knob trades barrier latency against dispatch occupancy and never
    /// affects outputs (plans are contiguous prefixes of arrival order
    /// either way).
    pub waiting_served_ratio: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 16, // the attn_batch artifact's geometry
            max_wait: Duration::from_millis(2),
            mode: PlanMode::Speculative,
            // TGI's default: a blocked barrier preempts extension once the
            // backlog is ~1.2x the open plan, i.e. almost immediately for
            // small plans, later for well-filled ones.
            waiting_served_ratio: 1.2,
        }
    }
}

impl BatchPolicy {
    /// Policy with the given plan bounds and the default (speculative)
    /// planning mode.
    pub fn bounds(max_batch: usize, max_wait: Duration) -> Self {
        BatchPolicy { max_batch, max_wait, ..Default::default() }
    }

    /// Same bounds, conservative planning.
    pub fn conservative(max_batch: usize, max_wait: Duration) -> Self {
        BatchPolicy { max_batch, max_wait, mode: PlanMode::Conservative, ..Default::default() }
    }
}

/// Outcome of waiting for one more arrival during plan extension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalWait {
    /// At least one new envelope was queued.
    Arrived,
    /// The wait elapsed (approximately — callers re-check their own
    /// deadline) with nothing new.
    TimedOut,
    /// All senders are gone; nothing further will ever arrive.
    Disconnected,
}

/// The standing per-worker queue: accumulates submitted [`Envelope`]s in
/// arrival order across scheduling cycles. The scheduler pops from the
/// front only — dispatch plans are contiguous prefixes of arrival order
/// (module docs) — so this is strictly FIFO.
#[derive(Default)]
pub struct WorkQueue {
    queue: VecDeque<Envelope>,
}

impl WorkQueue {
    pub fn new() -> Self {
        WorkQueue { queue: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// The next envelope the scheduler must place (FIFO head).
    pub fn front(&self) -> Option<&Envelope> {
        self.queue.front()
    }

    pub fn pop(&mut self) -> Option<Envelope> {
        self.queue.pop_front()
    }

    /// Move everything already sitting on the wire into the queue
    /// without blocking.
    pub fn drain_ready(&mut self, rx: &Receiver<Envelope>) {
        while let Ok(env) = rx.try_recv() {
            self.queue.push_back(env);
        }
    }

    /// Block until the queue is non-empty (also sweeping in anything
    /// else already on the wire). Returns `false` when the channel is
    /// closed *and* the queue is drained — worker shutdown.
    pub fn wait_nonempty(&mut self, rx: &Receiver<Envelope>) -> bool {
        if self.queue.is_empty() {
            match rx.recv() {
                Ok(env) => self.queue.push_back(env),
                Err(_) => return false,
            }
        }
        self.drain_ready(rx);
        true
    }

    /// Wait up to `timeout` for at least one more arrival (sweeping in
    /// everything that shows up with it). A [`ArrivalWait::TimedOut`]
    /// only says the OS wait elapsed *approximately*; callers loop back
    /// and let their own deadline check decide, so an early timer wakeup
    /// can never cut an extension window short (the source of flakes on
    /// loaded CI machines).
    pub fn wait_arrival(&mut self, rx: &Receiver<Envelope>, timeout: Duration) -> ArrivalWait {
        match rx.recv_timeout(timeout) {
            Ok(env) => {
                self.queue.push_back(env);
                self.drain_ready(rx);
                ArrivalWait::Arrived
            }
            Err(RecvTimeoutError::Timeout) => ArrivalWait::TimedOut,
            Err(RecvTimeoutError::Disconnected) => ArrivalWait::Disconnected,
        }
    }

    /// Remove every queued envelope matching `pred`, preserving FIFO
    /// order among both the drained and the kept. The supervisor uses
    /// this after a worker crash (ISSUE 9) to answer the dead
    /// incarnation's doomed envelopes with typed errors while leaving
    /// everything still serviceable — spilled sessions, fresh prefills —
    /// queued for the respawned incarnation.
    pub fn drain_matching<F>(&mut self, mut pred: F) -> Vec<Envelope>
    where
        F: FnMut(&Envelope) -> bool,
    {
        let mut drained = Vec::new();
        let mut kept = VecDeque::with_capacity(self.queue.len());
        for env in self.queue.drain(..) {
            if pred(&env) {
                drained.push(env);
            } else {
                kept.push_back(env);
            }
        }
        self.queue = kept;
        drained
    }
}

/// An in-flight dispatch plan the scheduler extends incrementally.
///
/// `admits` answers, for the envelope at the queue front, whether it may
/// join the open plan without violating the mode's batch-safety
/// invariant; `push` adds it and updates the hazard trackers. A
/// `Prefill` is never admitted (it executes alone as a barrier), so a
/// plan only ever holds `Decode` / `Attend` / `Close` items.
pub struct GroupPlan {
    mode: PlanMode,
    items: Vec<Envelope>,
    /// Sessions with any item in the plan (conservative hazard: a
    /// `Decode` must be its session's first item). Plans are small (max
    /// 16 by default), so linear scans beat hash sets here.
    touched: Vec<SessionId>,
    /// Sessions with a `Close` in the plan: their later items must not
    /// share it (they run after the close, sequentially).
    closed: Vec<SessionId>,
}

impl GroupPlan {
    pub fn new(mode: PlanMode) -> Self {
        GroupPlan { mode, items: Vec::new(), touched: Vec::new(), closed: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// May `req` join the open plan? `Prefill` never joins (barrier); a
    /// session closed within the plan bars all its later items in both
    /// modes; conservative planning additionally bars a `Decode` whose
    /// session already has an item in the plan.
    pub fn admits(&self, req: &Request) -> bool {
        match req {
            Request::Prefill { .. } => false,
            Request::Decode { session, .. } => {
                !self.closed.contains(session)
                    && (self.mode == PlanMode::Speculative || !self.touched.contains(session))
            }
            Request::Attend { session, .. } | Request::Close { session, .. } => {
                !self.closed.contains(session)
            }
        }
    }

    /// Add an envelope the caller has already cleared with [`admits`].
    ///
    /// [`admits`]: GroupPlan::admits
    pub fn push(&mut self, env: Envelope) {
        debug_assert!(self.admits(&env.req), "pushed an item the plan does not admit");
        let session = env.req.session();
        if !self.touched.contains(&session) {
            self.touched.push(session);
        }
        if matches!(env.req, Request::Close { .. }) {
            self.closed.push(session);
        }
        self.items.push(env);
    }

    /// Hand the planned items to the dispatcher and reset the plan.
    pub fn take(&mut self) -> Vec<Envelope> {
        self.touched.clear();
        self.closed.clear();
        std::mem::take(&mut self.items)
    }
}

/// One unit of backend work planned by [`DecodeBatcher::plan`].
#[derive(Debug)]
pub enum DispatchGroup {
    /// A `Prefill` barrier: bulk cache replacement, executes alone.
    Barrier(Envelope),
    /// `Decode` / `Attend` / `Close` steps of (possibly distinct)
    /// sessions that are safe to execute as one backend dispatch: all
    /// appends first, then a single batched attend over each item's own
    /// session cache, then the group's closes.
    Batch(Vec<Envelope>),
}

/// One-shot planner over a slice of envelopes.
///
/// Partitions the slice into [`DispatchGroup`]s under the batch-safety
/// invariant (module docs) of the requested [`PlanMode`] by folding it
/// through a [`GroupPlan`] — the same admission code the standing
/// scheduler runs incrementally, so the two can never disagree. Used by
/// tests, the fuzz harness's planner-invariant checks, and anyone
/// replaying a recorded arrival stream.
///
/// # Example
///
/// ```
/// use camformer::coordinator::batcher::{DecodeBatcher, DispatchGroup};
/// use camformer::coordinator::{Envelope, Request};
///
/// let step = |id, session| {
///     Envelope::detached(Request::Decode {
///         id,
///         session,
///         head: 0,
///         query: vec![0.0; 64],
///         new_key: vec![0.0; 64],
///         new_value: vec![0.0; 64],
///     })
/// };
/// let close = |id, session| Envelope::detached(Request::Close { id, session, head: 0 });
///
/// // one decode step from each of four sessions: a single dispatch
/// let groups = DecodeBatcher::plan(vec![step(0, 1), step(1, 2), step(2, 3), step(3, 4)]);
/// assert!(matches!(&groups[..], [DispatchGroup::Batch(items)] if items.len() == 4));
///
/// // conservatively, a session's *second* step must not share a
/// // dispatch with its first…
/// let groups = DecodeBatcher::plan(vec![step(0, 1), step(1, 2), step(2, 1)]);
/// assert_eq!(groups.len(), 2);
///
/// // …while speculative fusion serves even a deep single-session burst
/// // as ONE dispatch (each step attends over its own causal prefix)
/// let groups = DecodeBatcher::plan_speculative(vec![step(0, 1), step(1, 1), step(2, 1)]);
/// assert!(matches!(&groups[..], [DispatchGroup::Batch(items)] if items.len() == 3));
///
/// // a Close is a same-session barrier: a later item of ITS session
/// // starts a new group, while other sessions keep fusing around it
/// let groups =
///     DecodeBatcher::plan_speculative(vec![step(0, 1), close(1, 1), step(2, 2), step(3, 1)]);
/// let sizes: Vec<usize> = groups
///     .iter()
///     .map(|g| match g {
///         DispatchGroup::Batch(items) => items.len(),
///         DispatchGroup::Barrier(..) => 0,
///     })
///     .collect();
/// assert_eq!(sizes, vec![3, 1]);
/// ```
pub struct DecodeBatcher;

impl DecodeBatcher {
    /// Plan under an explicit [`PlanMode`].
    pub fn plan_mode(mode: PlanMode, items: Vec<Envelope>) -> Vec<DispatchGroup> {
        let mut groups: Vec<DispatchGroup> = Vec::new();
        let mut open = GroupPlan::new(mode);
        for env in items {
            if matches!(env.req, Request::Prefill { .. }) {
                if !open.is_empty() {
                    groups.push(DispatchGroup::Batch(open.take()));
                }
                groups.push(DispatchGroup::Barrier(env));
            } else {
                if !open.admits(&env.req) {
                    groups.push(DispatchGroup::Batch(open.take()));
                }
                open.push(env);
            }
        }
        if !open.is_empty() {
            groups.push(DispatchGroup::Batch(open.take()));
        }
        groups
    }

    /// Conservative planning (see [`PlanMode::Conservative`]).
    pub fn plan(items: Vec<Envelope>) -> Vec<DispatchGroup> {
        Self::plan_mode(PlanMode::Conservative, items)
    }

    /// Speculative multi-step fusion (see [`PlanMode::Speculative`]).
    pub fn plan_speculative(items: Vec<Envelope>) -> Vec<DispatchGroup> {
        Self::plan_mode(PlanMode::Speculative, items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;
    use std::time::Instant;

    fn decode(id: u64, session: u64) -> Envelope {
        Envelope::detached(Request::Decode {
            id,
            session,
            head: 0,
            query: vec![0.0; 4],
            new_key: vec![0.0; 4],
            new_value: vec![0.0; 4],
        })
    }

    fn attend(id: u64, session: u64) -> Envelope {
        Envelope::detached(Request::Attend { id, session, head: 0, query: vec![0.0; 4] })
    }

    fn prefill(id: u64, session: u64) -> Envelope {
        Envelope::detached(Request::Prefill {
            id,
            session,
            head: 0,
            keys: vec![0.0; 4],
            values: vec![0.0; 4],
        })
    }

    fn close(id: u64, session: u64) -> Envelope {
        Envelope::detached(Request::Close { id, session, head: 0 })
    }

    // ---- WorkQueue: the standing accumulator ----

    #[test]
    fn work_queue_preserves_arrival_order_across_sweeps() {
        let (tx, rx) = mpsc::channel();
        let mut q = WorkQueue::new();
        for i in 0..3 {
            tx.send(decode(i, 1)).unwrap();
        }
        q.drain_ready(&rx);
        assert_eq!(q.len(), 3);
        // later arrivals queue BEHIND what's already standing
        tx.send(decode(3, 2)).unwrap();
        q.drain_ready(&rx);
        let ids: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|e| e.req.id()).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert!(q.is_empty());
    }

    #[test]
    fn wait_nonempty_blocks_until_arrival_and_false_on_disconnect() {
        let (tx, rx) = mpsc::channel();
        let h = thread::spawn(move || {
            tx.send(decode(7, 1)).unwrap();
            // tx drops: channel disconnects once drained
        });
        let mut q = WorkQueue::new();
        assert!(q.wait_nonempty(&rx));
        assert_eq!(q.front().unwrap().req.id(), 7);
        h.join().unwrap();
        q.pop();
        assert!(!q.wait_nonempty(&rx), "closed + drained means shutdown");
    }

    #[test]
    fn drain_matching_keeps_fifo_order_on_both_sides() {
        let mut q = WorkQueue::new();
        let (tx, rx) = mpsc::channel();
        tx.send(decode(0, 1)).unwrap();
        tx.send(decode(1, 2)).unwrap();
        tx.send(prefill(2, 1)).unwrap();
        tx.send(attend(3, 1)).unwrap();
        tx.send(close(4, 2)).unwrap();
        q.drain_ready(&rx);
        // the supervisor's shape: pull session 1's non-prefill envelopes
        let drained = q.drain_matching(|env| {
            env.req.session() == 1 && !matches!(env.req, Request::Prefill { .. })
        });
        let drained_ids: Vec<u64> = drained.iter().map(|e| e.req.id()).collect();
        assert_eq!(drained_ids, vec![0, 3]);
        let kept_ids: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|e| e.req.id()).collect();
        assert_eq!(kept_ids, vec![1, 2, 4], "kept envelopes stay in arrival order");
        assert!(q.drain_matching(|_| true).is_empty(), "drained queue yields nothing");
    }

    #[test]
    fn wait_arrival_reports_timeout_without_consuming_the_wait_budget_twice() {
        let (tx, rx) = mpsc::channel::<Envelope>();
        let mut q = WorkQueue::new();
        let t0 = Instant::now();
        let wait = Duration::from_millis(5);
        assert_eq!(q.wait_arrival(&rx, wait), ArrivalWait::TimedOut);
        // lower bound only: a loaded CI machine cannot honour an upper bound
        assert!(t0.elapsed() >= wait, "returned after {:?}", t0.elapsed());
        drop(tx);
        assert_eq!(q.wait_arrival(&rx, wait), ArrivalWait::Disconnected);
    }

    #[test]
    fn wait_arrival_sweeps_everything_that_arrived_together() {
        let (tx, rx) = mpsc::channel();
        let mut q = WorkQueue::new();
        for i in 0..5 {
            tx.send(decode(i, 1)).unwrap();
        }
        assert_eq!(q.wait_arrival(&rx, Duration::from_secs(5)), ArrivalWait::Arrived);
        assert_eq!(q.len(), 5, "one wait sweeps the whole burst");
    }

    // ---- GroupPlan: incremental admission ----

    #[test]
    fn plan_never_admits_a_prefill() {
        for mode in [PlanMode::Conservative, PlanMode::Speculative] {
            let plan = GroupPlan::new(mode);
            assert!(!plan.admits(&prefill(0, 1).req), "{mode:?}");
        }
    }

    #[test]
    fn conservative_plan_admits_one_decode_per_session() {
        let mut plan = GroupPlan::new(PlanMode::Conservative);
        let d = decode(0, 1);
        assert!(plan.admits(&d.req));
        plan.push(d);
        assert!(!plan.admits(&decode(1, 1).req), "second same-session decode");
        assert!(plan.admits(&decode(1, 2).req), "other sessions still join");
        assert!(plan.admits(&attend(1, 1).req), "attend after decode fuses");
    }

    #[test]
    fn speculative_plan_admits_same_session_bursts_until_close() {
        let mut plan = GroupPlan::new(PlanMode::Speculative);
        for i in 0..4 {
            let d = decode(i, 1);
            assert!(plan.admits(&d.req), "step {i}");
            plan.push(d);
        }
        let c = close(4, 1);
        assert!(plan.admits(&c.req), "close joins its own group");
        plan.push(c);
        assert!(!plan.admits(&decode(5, 1).req), "closed session is barred");
        assert!(plan.admits(&decode(5, 2).req), "other sessions fuse around a close");
        assert_eq!(plan.len(), 5);
    }

    #[test]
    fn take_resets_hazard_trackers() {
        let mut plan = GroupPlan::new(PlanMode::Conservative);
        plan.push(decode(0, 1));
        plan.push(close(1, 2));
        assert_eq!(plan.take().len(), 2);
        assert!(plan.is_empty());
        // a fresh plan admits what the old one barred
        assert!(plan.admits(&decode(2, 1).req));
        assert!(plan.admits(&attend(3, 2).req));
    }

    /// The one-shot planner IS the incremental plan folded over a slice;
    /// spot-check the equivalence on a hazard-dense stream.
    #[test]
    fn incremental_admission_matches_one_shot_planning() {
        let stream = || {
            vec![
                decode(0, 1),
                attend(1, 2),
                decode(2, 1), // conservative hazard
                close(3, 2),
                attend(4, 2), // post-close: splits in both modes
                decode(5, 3),
            ]
        };
        for mode in [PlanMode::Conservative, PlanMode::Speculative] {
            let groups = DecodeBatcher::plan_mode(mode, stream());
            // replay incrementally and compare the split points
            let mut plan = GroupPlan::new(mode);
            let mut sizes = Vec::new();
            for env in stream() {
                if !plan.admits(&env.req) {
                    sizes.push(plan.take().len());
                }
                plan.push(env);
            }
            if !plan.is_empty() {
                sizes.push(plan.take().len());
            }
            assert_eq!(batch_sizes(&groups), sizes, "{mode:?}");
        }
    }

    // ---- DecodeBatcher planning ----

    fn batch_sizes(groups: &[DispatchGroup]) -> Vec<usize> {
        groups
            .iter()
            .map(|g| match g {
                DispatchGroup::Barrier(..) => 0,
                DispatchGroup::Batch(items) => items.len(),
            })
            .collect()
    }

    #[test]
    fn distinct_sessions_coalesce_into_one_dispatch() {
        let groups = DecodeBatcher::plan(vec![
            decode(0, 10),
            decode(1, 11),
            attend(2, 12),
            decode(3, 13),
        ]);
        assert_eq!(batch_sizes(&groups), vec![4]);
    }

    #[test]
    fn second_decode_of_a_session_starts_a_new_group() {
        // round-robin decode over 2 sessions, 2 steps each: two groups
        let groups =
            DecodeBatcher::plan(vec![decode(0, 1), decode(1, 2), decode(2, 1), decode(3, 2)]);
        assert_eq!(batch_sizes(&groups), vec![2, 2]);
    }

    #[test]
    fn decode_after_attend_on_same_session_is_a_barrier() {
        // the attend must not observe the decode's append
        let groups = DecodeBatcher::plan(vec![attend(0, 1), decode(1, 1)]);
        assert_eq!(batch_sizes(&groups), vec![1, 1]);
    }

    #[test]
    fn attends_after_decode_share_its_group() {
        // sequentially these attends all see the post-append cache, which
        // is exactly what appends-first batched execution gives them
        let groups = DecodeBatcher::plan(vec![decode(0, 1), attend(1, 1), attend(2, 1)]);
        assert_eq!(batch_sizes(&groups), vec![3]);
    }

    #[test]
    fn prefill_is_always_a_barrier() {
        let groups = DecodeBatcher::plan(vec![decode(0, 1), prefill(1, 2), decode(2, 3)]);
        assert_eq!(batch_sizes(&groups), vec![1, 0, 1]);
        assert!(matches!(
            &groups[1],
            DispatchGroup::Barrier(Envelope { req: Request::Prefill { .. }, .. })
        ));
    }

    #[test]
    fn plan_preserves_arrival_order() {
        let groups = DecodeBatcher::plan(vec![
            attend(0, 1),
            decode(1, 2),
            attend(2, 1),
            decode(3, 1), // flush: session 1 already present
            attend(4, 2),
        ]);
        let ids: Vec<Vec<u64>> = groups
            .iter()
            .map(|g| match g {
                DispatchGroup::Barrier(e) => vec![e.req.id()],
                DispatchGroup::Batch(items) => items.iter().map(|e| e.req.id()).collect(),
            })
            .collect();
        assert_eq!(ids, vec![vec![0, 1, 2], vec![3, 4]]);
    }

    #[test]
    fn empty_plan_is_empty() {
        assert!(DecodeBatcher::plan(Vec::new()).is_empty());
        assert!(DecodeBatcher::plan_speculative(Vec::new()).is_empty());
    }

    // ---- speculative fusion ----

    #[test]
    fn speculative_fuses_deep_single_session_burst() {
        let groups = DecodeBatcher::plan_speculative(vec![
            decode(0, 1),
            decode(1, 1),
            decode(2, 1),
            decode(3, 1),
        ]);
        assert_eq!(batch_sizes(&groups), vec![4]);
    }

    #[test]
    fn speculative_fuses_attend_before_and_after_decode() {
        // representable with prefix views: the leading attend's prefix
        // stops before the appends, the trailing one sees them
        let groups = DecodeBatcher::plan_speculative(vec![
            attend(0, 1),
            decode(1, 1),
            decode(2, 1),
            attend(3, 1),
        ]);
        assert_eq!(batch_sizes(&groups), vec![4]);
    }

    #[test]
    fn speculative_still_treats_prefill_as_barrier() {
        let groups = DecodeBatcher::plan_speculative(vec![
            decode(0, 1),
            decode(1, 1),
            prefill(2, 1),
            decode(3, 1),
        ]);
        assert_eq!(batch_sizes(&groups), vec![2, 0, 1]);
        assert!(matches!(
            &groups[1],
            DispatchGroup::Barrier(Envelope { req: Request::Prefill { .. }, .. })
        ));
    }

    // ---- Close planning (ISSUE 5) ----

    #[test]
    fn speculative_close_bars_only_its_own_session() {
        // the close joins the group; a LATER item of the closed session
        // starts a new group, while another session fuses right through
        let groups = DecodeBatcher::plan_speculative(vec![
            decode(0, 1),
            close(1, 1),
            decode(2, 2),
            decode(3, 1),
            attend(4, 2),
        ]);
        assert_eq!(batch_sizes(&groups), vec![3, 2]);
    }

    #[test]
    fn speculative_close_before_decode_of_same_session_splits() {
        let groups = DecodeBatcher::plan_speculative(vec![close(0, 1), decode(1, 1)]);
        assert_eq!(batch_sizes(&groups), vec![1, 1]);
    }

    #[test]
    fn double_close_splits_in_both_modes() {
        // the second close must observe the first one's effect
        // (UnknownSession), so it cannot share the group
        for mode in [PlanMode::Conservative, PlanMode::Speculative] {
            let groups = DecodeBatcher::plan_mode(mode, vec![close(0, 1), close(1, 1)]);
            assert_eq!(batch_sizes(&groups), vec![1, 1], "{mode:?}");
        }
    }

    #[test]
    fn conservative_close_rules() {
        // decode-then-close fuses (close runs after the dispatch);
        // attend-after-close splits; close counts as the session's item,
        // so a decode after it splits too
        let groups = DecodeBatcher::plan(vec![decode(0, 1), close(1, 1), attend(2, 1)]);
        assert_eq!(batch_sizes(&groups), vec![2, 1]);
        let groups = DecodeBatcher::plan(vec![close(0, 1), decode(1, 1)]);
        assert_eq!(batch_sizes(&groups), vec![1, 1]);
        // a close does not bar OTHER sessions from the group
        let groups = DecodeBatcher::plan(vec![close(0, 1), decode(1, 2), attend(2, 3)]);
        assert_eq!(batch_sizes(&groups), vec![3]);
    }

    #[test]
    fn plan_mode_dispatches_to_the_right_planner() {
        let items = || vec![decode(0, 1), decode(1, 1)];
        let cons = DecodeBatcher::plan_mode(PlanMode::Conservative, items());
        assert_eq!(batch_sizes(&cons), vec![1, 1]);
        let spec = DecodeBatcher::plan_mode(PlanMode::Speculative, items());
        assert_eq!(batch_sizes(&spec), vec![2]);
    }

    #[test]
    fn policy_constructors_set_mode() {
        let b = BatchPolicy::bounds(4, Duration::from_millis(1));
        assert_eq!((b.max_batch, b.mode), (4, PlanMode::Speculative));
        let c = BatchPolicy::conservative(4, Duration::from_millis(1));
        assert_eq!((c.max_batch, c.mode), (4, PlanMode::Conservative));
        assert!(b.waiting_served_ratio > 0.0 && c.waiting_served_ratio > 0.0);
    }
}
