//! Pluggable attention execution backends for the coordinator.
//!
//! * [`PjrtBackend`] — the production path: replays the AOT Pallas/JAX
//!   artifacts through PJRT (fixed n = 1024, d = 64 geometry).
//! * [`FunctionalBackend`] — pure-Rust Eq. 1 (any geometry); used for
//!   tests, fallbacks and as the golden cross-check.
//! * [`ArchSimBackend`] — the cycle-annotated architecture simulator;
//!   returns outputs *and* simulated hardware latency.
//!
//! Backends see K/V as row-major buffers whose row count is whatever the
//! serving layer padded to ([`AttentionBackend::required_rows`]); flexible
//! backends derive n per call so a session's growing KV cache needs no
//! re-construction. The batched entry point
//! ([`AttentionBackend::attend_batch`]) takes each query bound to *its
//! own* session's K/V view, so one dispatch can span decode steps of
//! different sessions (key-stationary amortisation, Fig. 5) — and, since
//! speculative multi-step fusion, several decode steps of the *same*
//! session: each item carries the causal prefix length it is allowed to
//! see ([`AttendItem::prefix_rows`]), and rows at or beyond it must
//! behave as pad. Items dispatched from a live `KvStore` additionally
//! carry the store-owned sign-packed key bits ([`AttendItem::packed`]),
//! so bit-level backends score without re-deriving them — the serving
//! hot path packs each key row exactly once, at append time.

use anyhow::Result;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::accuracy::functional::{self, AttnConfig, PackedKeysView};
use crate::arch::{config::ArchConfig, pipeline};
use crate::runtime::executable::Engine;
use crate::util::rng::Rng;

/// One query of a (possibly cross-session) batched dispatch, bound to the
/// padded K/V execution view of the session it attends over. The borrows
/// come straight out of the owning worker's `KvStore`s — building a batch
/// never copies cache contents.
#[derive(Clone, Copy)]
pub struct AttendItem<'a> {
    pub query: &'a [f32],
    /// Row-major padded keys (`rows x d_k`).
    pub keys: &'a [f32],
    /// Row-major padded values (`rows x d_v`).
    pub values: &'a [f32],
    /// Leading rows live for THIS query — its causal prefix under
    /// speculative multi-step fusion. Rows at or beyond it must be
    /// treated as pad (`KEY_PAD` keys, zero values). The serving layer
    /// guarantees such rows literally ARE pad unless the backend reports
    /// [`AttentionBackend::supports_prefix_views`].
    pub prefix_rows: usize,
    /// Store-owned sign-packed bits of `keys` (same rows), when the item
    /// is served from a live `KvStore` (`KvStore::packed_view`). `None`
    /// for detached buffers (the serving layer's materialised literal-pad
    /// copies, hand-built test items); backends that consume packed bits
    /// fall back to packing `keys` themselves then.
    pub packed: Option<PackedKeysView<'a>>,
}

/// An attention executor over a (query, keys, values) triple.
/// `k`/`v` are row-major; implementations derive the row count from the
/// buffer length (or require their fixed geometry — see
/// [`AttentionBackend::required_rows`]).
pub trait AttentionBackend: Send {
    /// Compute Eq. 1 for one query. `k`/`v` are row-major n x d.
    fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Result<Vec<f32>>;

    /// Serve a batch of queries, each against its own K/V view, in one
    /// dispatch. Items of the same session share the same `keys` /
    /// `values` borrow, so implementations can detect runs by buffer
    /// identity (plus [`AttendItem::prefix_rows`]) and amortise
    /// per-memory work (artifact batch slots) across them. The default
    /// loops [`AttentionBackend::attend`] per item, so every backend
    /// works unchanged — the serving layer only hands a default
    /// implementation buffers whose beyond-prefix rows are literal pad;
    /// outputs are returned in item order and must be bit-equal to
    /// sequential per-item dispatch.
    ///
    /// # Example
    ///
    /// ```
    /// use camformer::coordinator::backend::{AttendItem, AttentionBackend, FunctionalBackend};
    ///
    /// let mut be = FunctionalBackend::new(16, 64);
    /// // two sessions with distinct key memories, one query each
    /// let (k_a, v_a) = (vec![1.0f32; 16 * 64], vec![0.5f32; 16 * 64]);
    /// let (k_b, v_b) = (vec![-1.0f32; 16 * 64], vec![2.0f32; 16 * 64]);
    /// let q = vec![1.0f32; 64];
    /// let outs = be
    ///     .attend_batch(&[
    ///         AttendItem { query: &q, keys: &k_a, values: &v_a, prefix_rows: 16, packed: None },
    ///         AttendItem { query: &q, keys: &k_b, values: &v_b, prefix_rows: 16, packed: None },
    ///     ])
    ///     .unwrap();
    /// assert_eq!(outs.len(), 2);
    /// assert_eq!(outs[0], be.attend(&q, &k_a, &v_a).unwrap());
    /// assert_eq!(outs[1], be.attend(&q, &k_b, &v_b).unwrap());
    /// ```
    fn attend_batch(&mut self, items: &[AttendItem<'_>]) -> Result<Vec<Vec<f32>>> {
        items.iter().map(|it| self.attend(it.query, it.keys, it.values)).collect()
    }

    /// Whether this backend natively honours [`AttendItem::prefix_rows`]
    /// when the buffers hold live (non-pad) data beyond the prefix — the
    /// zero-copy fused-burst path. When `false` (the default), the
    /// serving layer materialises a literal-pad copy of the causal
    /// prefix before dispatching such items, so the default per-item
    /// [`AttentionBackend::attend`] loop stays bit-correct.
    fn supports_prefix_views(&self) -> bool {
        false
    }

    /// Execution-geometry rows for `rows` valid keys: flexible backends
    /// round up to the stage-1 group `quantum`; fixed-geometry backends
    /// (the PJRT artifacts) return their compiled n.
    fn required_rows(&self, rows: usize, quantum: usize) -> usize {
        rows.max(1).div_ceil(quantum) * quantum
    }

    /// Invalidate any cached derivative of the key memory. The serving
    /// layer calls this after every KV mutation: the KV buffers mutate in
    /// place (see `KvStore`), so a backend caching by pointer identity
    /// cannot detect staleness on its own. Since the store took ownership
    /// of the packed key bits this is a no-op for every in-tree backend,
    /// but the hook remains the contract for custom backends that derive
    /// per-memory state.
    fn on_kv_update(&mut self) {}

    /// Hot-path work counters, for backends that keep them
    /// ([`WorkStats`]). The serving layer folds them into `Metrics` when
    /// a worker retires its backend, so dispatch-config equivalence can
    /// be asserted down to the work performed, not just the outputs.
    fn work_stats(&self) -> Option<WorkStats> {
        None
    }

    fn name(&self) -> &'static str;
}

/// Hot-path work accounting for [`FunctionalBackend`], read by the
/// long-context bench to pin the fast paths' asymptotics (ISSUEs 4, 7)
/// and folded into `Metrics` at worker exit. `PartialEq` so the fuzz
/// harness can assert counter parity across dispatch configs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkStats {
    /// Queries served (single attends + batch items).
    pub attends: u64,
    /// V rows contextualization actually walked: ≤ `final_k` per query
    /// on the sparse/fused paths, the full padded context on the dense
    /// baseline.
    pub v_rows_touched: u64,
    /// Key rows the backend packed itself because no store-owned packed
    /// view was supplied — the O(n·d_k) fallback that incremental
    /// `KvStore` packing retires from the serving hot path (must stay 0
    /// when every item carries `AttendItem::packed`).
    pub fallback_rows_packed: u64,
    /// u64 key-bit words XOR+popcounted by the fused pipeline — only
    /// live (pre-prefix) rows cost words; pad rows are scored
    /// analytically for free.
    pub words_scored: u64,
    /// 16-row key tiles the fused pipeline streamed.
    pub tiles_streamed: u64,
    /// Tentative streaming-top-k survivors evicted by later tiles (the
    /// fused pipeline's online corrections).
    pub survivor_corrections: u64,
}

impl WorkStats {
    /// Field-wise accumulate (worker metrics folding).
    pub fn add(&mut self, other: &WorkStats) {
        self.attends += other.attends;
        self.v_rows_touched += other.v_rows_touched;
        self.fallback_rows_packed += other.fallback_rows_packed;
        self.words_scored += other.words_scored;
        self.tiles_streamed += other.tiles_streamed;
        self.survivor_corrections += other.survivor_corrections;
    }

    /// Field-wise difference against an earlier snapshot of the same
    /// monotonically-growing counters — the per-dispatch ledger unit the
    /// workload energy accountant prices (ISSUE 10): snapshot before a
    /// dispatch, subtract after, and the deltas sum back to the totals
    /// exactly. Panics in debug builds if `earlier` is not actually
    /// earlier.
    pub fn delta_since(&self, earlier: &WorkStats) -> WorkStats {
        debug_assert!(
            self.attends >= earlier.attends && self.tiles_streamed >= earlier.tiles_streamed,
            "delta_since wants an earlier snapshot of the same counters"
        );
        WorkStats {
            attends: self.attends - earlier.attends,
            v_rows_touched: self.v_rows_touched - earlier.v_rows_touched,
            fallback_rows_packed: self.fallback_rows_packed - earlier.fallback_rows_packed,
            words_scored: self.words_scored - earlier.words_scored,
            tiles_streamed: self.tiles_streamed - earlier.tiles_streamed,
            survivor_corrections: self.survivor_corrections - earlier.survivor_corrections,
        }
    }
}

/// Which functional pipeline serves a query — all three are bit-identical
/// on the same inputs (pinned by `accuracy::functional` property tests
/// and the `batcher_fuzz` dispatch-config matrix).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pipeline {
    /// FlashCAM (§Perf iteration 6, default): one streaming pass over
    /// 16-row tiles — u64-word scoring into a hot tile buffer, a running
    /// top-k threshold carried tile-to-tile, survivors contextualized at
    /// stream end. No n-length score vector.
    Fused,
    /// Survivor-list sparse pipeline (§Perf iteration 4): full score
    /// vector, then softmax + BF16 walk only the ≤ `final_k` survivors.
    /// Retained as the first cross-check baseline.
    Sparse,
    /// Dense mask baseline: every stage walks all n rows. Unoptimised on
    /// purpose — the reference everything else is pinned against.
    Dense,
}

/// Pure-Rust functional backend.
///
/// §Perf: serves through the FlashCAM fused pipeline by default
/// (`functional::camformer_attention_view_fused`) — one streaming pass
/// over 16-row key tiles with u64 XOR+popcount word scoring and a
/// running top-k threshold, no materialized n-length score vector — and
/// batch items dispatched from a live `KvStore` carry the store-owned
/// packed key bits (`AttendItem::packed`), so a decode step costs
/// O(n/64·w + k·d) with no packing at all on the serving path.
/// [`FunctionalBackend::new_sparse`] keeps the PR-4 survivor-list
/// pipeline and [`FunctionalBackend::new_dense`] the dense boolean-mask
/// path as bit-identical cross-check baselines (enforced by the
/// randomized `batcher_fuzz` harness and the `accuracy::functional`
/// property tests).
pub struct FunctionalBackend {
    pub cfg: AttnConfig,
    /// Serving pipeline; all variants produce bit-identical outputs.
    pub pipeline: Pipeline,
    /// Work counters (see [`WorkStats`]).
    pub work: WorkStats,
    scratch: functional::AttnScratch,
    fused: functional::FusedScratch,
}

impl FunctionalBackend {
    /// FlashCAM fused serving (the hot path).
    pub fn new(n: usize, d_k: usize) -> Self {
        FunctionalBackend {
            cfg: AttnConfig::paper(n, d_k),
            pipeline: Pipeline::Fused,
            work: WorkStats::default(),
            scratch: functional::AttnScratch::default(),
            fused: functional::FusedScratch::default(),
        }
    }

    /// Survivor-list sparse pipeline (the PR-4 hot path). Kept as a
    /// cross-check baseline for the fused default.
    pub fn new_sparse(n: usize, d_k: usize) -> Self {
        FunctionalBackend { pipeline: Pipeline::Sparse, ..Self::new(n, d_k) }
    }

    /// Dense-mask baseline: every stage walks all n rows. Kept as the
    /// cross-check the fast pipelines are asserted bit-identical against.
    pub fn new_dense(n: usize, d_k: usize) -> Self {
        FunctionalBackend { pipeline: Pipeline::Dense, ..Self::new(n, d_k) }
    }

    /// One query over a packed view bounded at `valid_rows`, through the
    /// configured pipeline.
    fn run(
        &mut self,
        q: &[f32],
        view: &PackedKeysView<'_>,
        v: &[f32],
        cfg: &AttnConfig,
        valid_rows: usize,
    ) -> Vec<f32> {
        self.work.attends += 1;
        match self.pipeline {
            Pipeline::Fused => {
                let out = functional::camformer_attention_view_fused(
                    q,
                    view,
                    v,
                    cfg,
                    valid_rows,
                    &mut self.fused,
                );
                self.work.v_rows_touched += self.fused.survivors().len() as u64;
                self.work.words_scored += self.fused.words_scored();
                self.work.tiles_streamed += self.fused.tiles_streamed();
                self.work.survivor_corrections += self.fused.corrections();
                out
            }
            Pipeline::Sparse => {
                let out = functional::camformer_attention_view_sparse(
                    q,
                    view,
                    v,
                    cfg,
                    valid_rows,
                    &mut self.scratch,
                );
                self.work.v_rows_touched += self.scratch.survivors().len() as u64;
                out
            }
            Pipeline::Dense => {
                self.work.v_rows_touched += cfg.n as u64;
                functional::camformer_attention_view_dense(q, view, v, cfg, valid_rows)
            }
        }
    }
}

impl AttentionBackend for FunctionalBackend {
    /// Packs `k` on every call (counted in `WorkStats::fallback_rows_packed`):
    /// with the identity cache retired, a detached buffer has no packed
    /// bits to reuse. The serving hot path never takes this route — it
    /// dispatches through `attend_batch` with store-owned bits attached
    /// ([`AttendItem::packed`]).
    fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        let mut cfg = self.cfg;
        cfg.n = k.len() / cfg.d_k; // geometry follows the (padded) cache
        let packed = functional::PackedKeys::new(k, cfg.d_k);
        self.work.fallback_rows_packed += cfg.n as u64;
        Ok(self.run(q, &packed.view(cfg.n), v, &cfg, cfg.n))
    }

    /// Serves each item over its own causal prefix: scoring and V reads
    /// are masked at [`AttendItem::prefix_rows`], bit-equal to a
    /// literal-pad tail, so fused multi-step groups stay zero-copy —
    /// items of one session share a buffer while attending over
    /// different prefixes of it. Items carrying [`AttendItem::packed`]
    /// score the store-owned bits directly (no packing at all on the
    /// serving path); detached items fall back to a one-off pack.
    fn attend_batch(&mut self, items: &[AttendItem<'_>]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(items.len());
        for it in items {
            let mut cfg = self.cfg;
            cfg.n = it.keys.len() / cfg.d_k;
            let fallback;
            let view = match it.packed {
                Some(view) => {
                    debug_assert_eq!(view.n, cfg.n, "packed view rows != K buffer rows");
                    debug_assert_eq!(view.d_k, cfg.d_k, "packed view d_k != backend d_k");
                    view
                }
                None => {
                    fallback = functional::PackedKeys::new(it.keys, cfg.d_k);
                    self.work.fallback_rows_packed += cfg.n as u64;
                    fallback.view(cfg.n)
                }
            };
            out.push(self.run(it.query, &view, it.values, &cfg, it.prefix_rows.min(cfg.n)));
        }
        Ok(out)
    }

    fn supports_prefix_views(&self) -> bool {
        true
    }

    fn work_stats(&self) -> Option<WorkStats> {
        Some(self.work)
    }

    fn name(&self) -> &'static str {
        "functional"
    }
}

/// Architecture-simulator backend (functional + hardware cycle counts).
pub struct ArchSimBackend {
    pub cfg: ArchConfig,
    /// Cycles of the last simulated query per stage.
    pub last_latency: Option<pipeline::StageLatency>,
}

impl ArchSimBackend {
    pub fn new(n: usize) -> Self {
        ArchSimBackend {
            cfg: ArchConfig { n, ..Default::default() },
            last_latency: None,
        }
    }
}

impl AttentionBackend for ArchSimBackend {
    fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        self.cfg.n = k.len() / self.cfg.d_k; // geometry follows the cache
        let (out, lat) = pipeline::simulate_query(self.cfg, q, k, v);
        self.last_latency = Some(lat);
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "arch-sim"
    }
}

/// PJRT backend over the AOT artifacts (n = 1024, d = 64 fixed by aot.py).
pub struct PjrtBackend {
    engine: Engine,
    pub n: usize,
    pub d: usize,
    pub batch: usize,
}

impl PjrtBackend {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let mut engine = Engine::new(artifacts_dir)?;
        // compile both entry points up front (compile once, execute many)
        engine.load("attn_single_query")?;
        engine.load("attn_batch")?;
        Ok(PjrtBackend {
            engine,
            n: 1024,
            d: 64,
            batch: 16,
        })
    }

    /// Serve `qs` against one shared K/V: full `batch`-sized slices go
    /// through the `attn_batch` artifact, stragglers run single.
    fn run_shared_kv(&mut self, qs: &[&[f32]], k: &[f32], v: &[f32]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(qs.len());
        let mut i = 0;
        while i < qs.len() {
            if qs.len() - i >= self.batch {
                // full batch through the batched artifact
                let mut qflat = Vec::with_capacity(self.batch * self.d);
                for q in &qs[i..i + self.batch] {
                    qflat.extend_from_slice(q);
                }
                let exe = self.engine.load("attn_batch")?;
                let flat = exe.run_f32(&[&qflat, k, v])?;
                for b in 0..self.batch {
                    out.push(flat[b * self.d..(b + 1) * self.d].to_vec());
                }
                i += self.batch;
            } else {
                let exe = self.engine.load("attn_single_query")?;
                out.push(exe.run_f32(&[qs[i], k, v])?);
                i += 1;
            }
        }
        Ok(out)
    }
}

impl AttentionBackend for PjrtBackend {
    fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        let exe = self.engine.load("attn_single_query")?;
        exe.run_f32(&[q, k, v])
    }

    /// Cross-session batches are served run-by-run: consecutive items
    /// sharing a K/V buffer (same session) AND the same causal prefix
    /// form a run that reuses the shared-KV artifact path; the artifacts
    /// bake the key memory into the dispatch, so runs over *different*
    /// memories — or different prefixes of one memory, which fused
    /// bursts produce — cannot share one artifact call. (This backend
    /// does not claim [`AttentionBackend::supports_prefix_views`], so
    /// the serving layer hands it literal-pad buffers per prefix; the
    /// binarisation happens inside the artifact, so
    /// [`AttendItem::packed`] is ignored.)
    fn attend_batch(&mut self, items: &[AttendItem<'_>]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(items.len());
        let mut start = 0;
        while start < items.len() {
            // run detection must match BOTH buffers: keys identity alone
            // would silently serve a run that rebinds the values tensor
            // against the first item's V — and since speculative fusion,
            // the prefix too: same-KV-same-prefix, not just same-KV
            let (kp, kl) = (items[start].keys.as_ptr(), items[start].keys.len());
            let (vp, vl) = (items[start].values.as_ptr(), items[start].values.len());
            let prefix = items[start].prefix_rows;
            let mut end = start + 1;
            while end < items.len()
                && items[end].keys.as_ptr() == kp
                && items[end].keys.len() == kl
                && items[end].values.as_ptr() == vp
                && items[end].values.len() == vl
                && items[end].prefix_rows == prefix
            {
                end += 1;
            }
            let qs: Vec<&[f32]> = items[start..end].iter().map(|it| it.query).collect();
            out.extend(self.run_shared_kv(&qs, items[start].keys, items[start].values)?);
            start = end;
        }
        Ok(out)
    }

    /// The artifacts are compiled for a fixed context; the serving layer
    /// must pad every session's cache to it.
    fn required_rows(&self, _rows: usize, _quantum: usize) -> usize {
        self.n
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

// Safety: the PJRT client is only ever used from the worker thread that
// owns it (the coordinator moves each backend into exactly one thread).
unsafe impl Send for PjrtBackend {}

/// One injected fault of a [`FaultPlan`] (ISSUE 9). Each kind exercises a
/// different containment layer of the coordinator:
///
/// * `Error` — `attend_batch` returns `Err`: the dispatch rolls its
///   speculative appends back and every planned ticket resolves
///   [`ServeError::Backend`](super::ServeError::Backend);
/// * `Panic` — `attend_batch` panics with an ordinary payload: dispatch
///   containment (`catch_unwind`) absorbs it, rolls back, answers typed,
///   and the worker keeps serving;
/// * `Crash` — `attend_batch` panics with a [`WorkerAbort`] payload:
///   containment deliberately re-raises it, killing the worker
///   incarnation and exercising supervised restart + spill-tier session
///   recovery;
/// * `Stall` — `attend_batch` sleeps, then serves normally: exercises
///   queue backpressure and deadline paths without corrupting state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    Error,
    Panic,
    Crash,
    Stall(Duration),
}

/// Panic payload that dispatch containment must NOT absorb: the worker's
/// `catch_unwind` re-raises it so the whole incarnation dies and the
/// supervisor takes over. [`ChaosBackend`] throws it for
/// [`Fault::Crash`]; anything else (tests, a wedged backend) can throw it
/// too to force a deterministic worker death.
#[derive(Debug)]
pub struct WorkerAbort(pub String);

/// A deterministic schedule of [`Fault`]s keyed by dispatch ordinal:
/// fault `(n, f)` fires on the n-th `attend_batch` call (1-based) of a
/// backend incarnation. The ordinal counter lives in the [`ChaosBackend`]
/// instance, so a respawned worker's fresh backend replays the plan from
/// the start — which is what makes crash loops terminate: each crash
/// consumes at least the envelope that triggered it.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    faults: Vec<(u64, Fault)>,
}

impl FaultPlan {
    /// The empty plan: [`ChaosBackend`] becomes a transparent wrapper.
    pub fn none() -> Self {
        FaultPlan::default()
    }

    /// Fixed schedule: `(dispatch_ordinal, fault)` pairs, 1-based.
    pub fn at(faults: Vec<(u64, Fault)>) -> Self {
        FaultPlan { faults }
    }

    /// Seeded random plan over dispatches `1..=horizon`: each ordinal
    /// carries a fault with probability `density`. Same seed, same plan —
    /// the chaos fuzz family derives its plans from the case number.
    pub fn random(seed: u64, horizon: u64, density: f64) -> Self {
        let mut rng = Rng::new(seed);
        let mut faults = Vec::new();
        for n in 1..=horizon {
            if rng.uniform() < density {
                let fault = match rng.index(4) {
                    0 => Fault::Error,
                    1 => Fault::Panic,
                    2 => Fault::Crash,
                    _ => Fault::Stall(Duration::from_millis(1 + rng.index(4) as u64)),
                };
                faults.push((n, fault));
            }
        }
        FaultPlan { faults }
    }

    /// The fault scheduled for `dispatch` (1-based ordinal), if any.
    pub fn lookup(&self, dispatch: u64) -> Option<&Fault> {
        self.faults.iter().find(|(n, _)| *n == dispatch).map(|(_, f)| f)
    }

    /// Number of scheduled faults.
    pub fn len(&self) -> usize {
        self.faults.len()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }
}

/// What a [`ChaosBackend`] actually injected, shared across worker
/// incarnations via `Arc` so the fuzz harness can reconcile server
/// metrics against ground truth: `backend_faults == errors`,
/// `worker_panics == panics + crashes`, `worker_restarts == crashes`.
#[derive(Debug, Default)]
pub struct ChaosStats {
    pub errors: AtomicU64,
    pub panics: AtomicU64,
    pub crashes: AtomicU64,
    pub stalls: AtomicU64,
}

/// Fault-injecting wrapper over any [`AttentionBackend`] (ISSUE 9): runs
/// the inner backend unchanged except on dispatch ordinals where its
/// [`FaultPlan`] schedules a [`Fault`]. Only `attend_batch` counts as a
/// dispatch — the serving layer's dispatch path is the batched entry
/// point; single `attend` calls forward untouched.
pub struct ChaosBackend<B> {
    inner: B,
    plan: FaultPlan,
    stats: Arc<ChaosStats>,
    dispatches: u64,
}

impl<B: AttentionBackend> ChaosBackend<B> {
    pub fn new(inner: B, plan: FaultPlan) -> Self {
        Self::with_stats(inner, plan, Arc::new(ChaosStats::default()))
    }

    /// Share an injection ledger across instances — respawned workers get
    /// fresh backends, but the ground truth must accumulate across
    /// incarnations for the fuzz harness to reconcile against.
    pub fn with_stats(inner: B, plan: FaultPlan, stats: Arc<ChaosStats>) -> Self {
        ChaosBackend { inner, plan, stats, dispatches: 0 }
    }

    /// The shared injection ledger.
    pub fn stats(&self) -> Arc<ChaosStats> {
        self.stats.clone()
    }
}

impl<B: AttentionBackend> AttentionBackend for ChaosBackend<B> {
    fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        self.inner.attend(q, k, v)
    }

    fn attend_batch(&mut self, items: &[AttendItem<'_>]) -> Result<Vec<Vec<f32>>> {
        self.dispatches += 1;
        let n = self.dispatches;
        match self.plan.lookup(n) {
            Some(Fault::Error) => {
                self.stats.errors.fetch_add(1, Ordering::Relaxed);
                return Err(anyhow::anyhow!("chaos: injected backend fault at dispatch {n}"));
            }
            Some(Fault::Panic) => {
                self.stats.panics.fetch_add(1, Ordering::Relaxed);
                panic!("chaos: injected dispatch panic at dispatch {n}");
            }
            Some(Fault::Crash) => {
                self.stats.crashes.fetch_add(1, Ordering::Relaxed);
                std::panic::panic_any(WorkerAbort(format!(
                    "chaos: injected worker crash at dispatch {n}"
                )));
            }
            Some(Fault::Stall(d)) => {
                self.stats.stalls.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(*d);
            }
            None => {}
        }
        self.inner.attend_batch(items)
    }

    fn supports_prefix_views(&self) -> bool {
        self.inner.supports_prefix_views()
    }

    fn required_rows(&self, rows: usize, quantum: usize) -> usize {
        self.inner.required_rows(rows, quantum)
    }

    fn on_kv_update(&mut self) {
        self.inner.on_kv_update();
    }

    fn work_stats(&self) -> Option<WorkStats> {
        self.inner.work_stats()
    }

    fn name(&self) -> &'static str {
        "chaos"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv_store::KvStore;
    use crate::util::rng::Rng;

    #[test]
    fn functional_and_archsim_agree() {
        let mut rng = Rng::new(110);
        let q = rng.normal_vec(64);
        let k = rng.normal_vec(256 * 64);
        let v = rng.normal_vec(256 * 64);
        let mut f = FunctionalBackend::new(256, 64);
        let mut a = ArchSimBackend::new(256);
        let fo = f.attend(&q, &k, &v).unwrap();
        let ao = a.attend(&q, &k, &v).unwrap();
        for (x, y) in fo.iter().zip(&ao) {
            assert!((x - y).abs() < 0.05, "{x} vs {y}");
        }
        assert!(a.last_latency.is_some());
    }

    /// Backend that keeps the trait's default `attend_batch` (and thus
    /// default `supports_prefix_views` = false).
    struct DefaultLoop(FunctionalBackend);

    impl AttentionBackend for DefaultLoop {
        fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Result<Vec<f32>> {
            self.0.attend(q, k, v)
        }

        fn name(&self) -> &'static str {
            "default-loop"
        }
    }

    #[test]
    fn default_batch_loops() {
        let mut rng = Rng::new(111);
        let k = rng.normal_vec(128 * 64);
        let v = rng.normal_vec(128 * 64);
        let qs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(64)).collect();
        let items: Vec<AttendItem<'_>> = qs
            .iter()
            .map(|q| AttendItem { query: q, keys: &k, values: &v, prefix_rows: 128, packed: None })
            .collect();
        let mut f = DefaultLoop(FunctionalBackend::new(128, 64));
        assert!(!f.supports_prefix_views());
        let batch = f.attend_batch(&items).unwrap();
        assert_eq!(batch.len(), 3);
        for (i, q) in qs.iter().enumerate() {
            assert_eq!(batch[i], f.attend(q, &k, &v).unwrap());
        }
    }

    #[test]
    fn batch_spanning_sessions_matches_per_item_attends() {
        // interleaved items over two distinct key memories: the batched
        // entry point must keep each query bound to its own cache
        let mut rng = Rng::new(114);
        let k0 = rng.normal_vec(64 * 64);
        let v0 = rng.normal_vec(64 * 64);
        let k1 = rng.normal_vec(64 * 64);
        let v1 = rng.normal_vec(64 * 64);
        let qs: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(64)).collect();
        let items: Vec<AttendItem<'_>> = qs
            .iter()
            .enumerate()
            .map(|(i, q)| {
                if i % 2 == 0 {
                    AttendItem { query: q, keys: &k0, values: &v0, prefix_rows: 64, packed: None }
                } else {
                    AttendItem { query: q, keys: &k1, values: &v1, prefix_rows: 64, packed: None }
                }
            })
            .collect();
        let mut f = FunctionalBackend::new(64, 64);
        let outs = f.attend_batch(&items).unwrap();
        let mut fresh = FunctionalBackend::new(64, 64);
        for (i, q) in qs.iter().enumerate() {
            let (k, v) = if i % 2 == 0 { (&k0, &v0) } else { (&k1, &v1) };
            assert_eq!(outs[i], fresh.attend(q, k, v).unwrap(), "item {i}");
        }
    }

    #[test]
    fn prefix_masked_batch_matches_literal_pad_buffers() {
        // a fused burst's view: one buffer holding the FINAL cache, three
        // items attending over growing causal prefixes of it — each must
        // equal a plain attend over a buffer whose tail is literal pad
        use crate::coordinator::kv_store::KEY_PAD;
        let mut rng = Rng::new(115);
        let rows = 32usize;
        let k = rng.normal_vec(rows * 64);
        let v = rng.normal_vec(rows * 64);
        let qs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(64)).collect();
        let prefixes = [18usize, 19, 20];
        let items: Vec<AttendItem<'_>> = qs
            .iter()
            .zip(prefixes)
            .map(|(q, p)| AttendItem {
                query: q,
                keys: &k,
                values: &v,
                prefix_rows: p,
                packed: None,
            })
            .collect();
        let mut f = FunctionalBackend::new(rows, 64);
        assert!(f.supports_prefix_views());
        let outs = f.attend_batch(&items).unwrap();
        for (i, p) in prefixes.into_iter().enumerate() {
            let (mut kp, mut vp) = (k.clone(), v.clone());
            for x in &mut kp[p * 64..] {
                *x = KEY_PAD;
            }
            for x in &mut vp[p * 64..] {
                *x = 0.0;
            }
            let mut fresh = FunctionalBackend::new(rows, 64);
            assert_eq!(outs[i], fresh.attend(&qs[i], &kp, &vp).unwrap(), "prefix {p}");
        }
    }

    #[test]
    fn store_packed_views_match_fallback_packing_and_skip_it() {
        // items carrying KvStore-owned packed bits must produce the same
        // outputs as detached items — without the backend packing anything
        let mut rng = Rng::new(116);
        let mut store = KvStore::new(64, 64, 64);
        for _ in 0..24 {
            store.append(&rng.normal_vec(64), &rng.normal_vec(64)).unwrap();
        }
        let rows = 32usize;
        let qs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(64)).collect();
        let prefixes = [21usize, 22, 23, 24];
        let (kp, vp, _) = store.padded_prefix_view(21, rows);
        let with_bits: Vec<AttendItem<'_>> = qs
            .iter()
            .zip(prefixes)
            .map(|(q, p)| AttendItem {
                query: q,
                keys: kp,
                values: vp,
                prefix_rows: p,
                packed: Some(store.packed_view(rows)),
            })
            .collect();
        let without: Vec<AttendItem<'_>> = with_bits
            .iter()
            .map(|it| AttendItem { packed: None, ..*it })
            .collect();
        let mut f = FunctionalBackend::new(64, 64);
        let outs_bits = f.attend_batch(&with_bits).unwrap();
        assert_eq!(f.work.fallback_rows_packed, 0, "store bits must be used as-is");
        assert_eq!(f.work.attends, 4);
        assert!(f.work.v_rows_touched <= 4 * f.cfg.final_k as u64);
        let outs_fallback = f.attend_batch(&without).unwrap();
        assert_eq!(f.work.fallback_rows_packed, 4 * rows as u64);
        assert_eq!(outs_bits, outs_fallback);
    }

    #[test]
    fn all_three_pipelines_agree_bitwise() {
        let mut rng = Rng::new(117);
        let k = rng.normal_vec(96 * 64);
        let v = rng.normal_vec(96 * 64);
        let qs: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(64)).collect();
        let items: Vec<AttendItem<'_>> = qs
            .iter()
            .enumerate()
            .map(|(i, q)| AttendItem {
                query: q,
                keys: &k,
                values: &v,
                prefix_rows: 90 + i,
                packed: None,
            })
            .collect();
        let mut fused = FunctionalBackend::new(96, 64);
        let mut sparse = FunctionalBackend::new_sparse(96, 64);
        let mut dense = FunctionalBackend::new_dense(96, 64);
        assert_eq!(fused.pipeline, Pipeline::Fused);
        let outs = dense.attend_batch(&items).unwrap();
        assert_eq!(fused.attend_batch(&items).unwrap(), outs);
        assert_eq!(sparse.attend_batch(&items).unwrap(), outs);
        // the fast paths walk only survivors; the dense baseline walks
        // the whole context every query
        assert!(fused.work.v_rows_touched <= fused.work.attends * 32);
        assert!(sparse.work.v_rows_touched <= sparse.work.attends * 32);
        assert_eq!(dense.work.v_rows_touched, dense.work.attends * 96);
        // fused work accounting: five batch items over prefixes 90..94 at
        // d_k=64 (one word per live row), 6 tiles each; only the fused
        // pipeline streams tiles or scores words
        assert_eq!(fused.work.words_scored, (90 + 91 + 92 + 93 + 94) as u64);
        assert_eq!(fused.work.tiles_streamed, 5 * 6);
        assert_eq!(sparse.work.words_scored, 0);
        assert_eq!(dense.work.tiles_streamed, 0);
        assert_eq!(fused.work_stats(), Some(fused.work));
        assert_eq!(fused.attend(&qs[0], &k, &v).unwrap(), dense.attend(&qs[0], &k, &v).unwrap());
    }

    #[test]
    fn work_stats_delta_reconciles_per_dispatch() {
        // the energy ledger's contract: snapshot before each dispatch,
        // delta after — the deltas must sum back to the totals exactly
        let mut rng = Rng::new(311);
        let k = rng.normal_vec(64 * 64);
        let v = rng.normal_vec(64 * 64);
        let mut f = FunctionalBackend::new(64, 64);
        let mut ledger = WorkStats::default();
        for _ in 0..4 {
            let before = f.work;
            let q = rng.normal_vec(64);
            f.attend(&q, &k, &v).unwrap();
            ledger.add(&f.work.delta_since(&before));
        }
        assert_eq!(ledger, f.work, "summed deltas must equal the folded totals");
        assert_eq!(f.work.delta_since(&f.work), WorkStats::default());
    }

    #[test]
    fn geometry_follows_buffer_length() {
        // constructed for n=1024, served with a 64-row padded cache
        let mut rng = Rng::new(113);
        let q = rng.normal_vec(64);
        let k = rng.normal_vec(64 * 64);
        let v = rng.normal_vec(64 * 64);
        let mut f = FunctionalBackend::new(1024, 64);
        let got = f.attend(&q, &k, &v).unwrap();
        let want = functional::camformer_attention(&q, &k, &v, &AttnConfig::paper(64, 64));
        assert_eq!(got, want);
    }

    #[test]
    fn in_place_kv_mutation_is_visible_without_invalidation() {
        // the backend holds no derivative of K anymore (the store owns
        // the packed bits): mutating K in place — same pointer, same
        // length — must be visible on the very next attend, with no
        // on_kv_update call
        let mut rng = Rng::new(112);
        let q = rng.normal_vec(64);
        let mut k = rng.normal_vec(32 * 64);
        let v = rng.normal_vec(32 * 64);
        let mut f = FunctionalBackend::new(32, 64);
        let first = f.attend(&q, &k, &v).unwrap();
        for x in k.iter_mut() {
            *x = -*x;
        }
        let second = f.attend(&q, &k, &v).unwrap();
        let mut fresh = FunctionalBackend::new(32, 64);
        assert_eq!(second, fresh.attend(&q, &k, &v).unwrap());
        assert_ne!(first, second, "sign-flipped keys must change the output");
    }

    #[test]
    fn required_rows_quantized() {
        let f = FunctionalBackend::new(64, 64);
        assert_eq!(f.required_rows(0, 16), 16);
        assert_eq!(f.required_rows(1, 16), 16);
        assert_eq!(f.required_rows(16, 16), 16);
        assert_eq!(f.required_rows(17, 16), 32);
        assert_eq!(f.required_rows(1024, 16), 1024);
    }

    #[test]
    fn chaos_with_empty_plan_is_transparent() {
        let mut rng = Rng::new(118);
        let k = rng.normal_vec(64 * 64);
        let v = rng.normal_vec(64 * 64);
        let q = rng.normal_vec(64);
        let items =
            [AttendItem { query: &q, keys: &k, values: &v, prefix_rows: 64, packed: None }];
        let mut chaos = ChaosBackend::new(FunctionalBackend::new(64, 64), FaultPlan::none());
        let mut plain = FunctionalBackend::new(64, 64);
        assert!(chaos.supports_prefix_views(), "chaos must forward capability queries");
        assert_eq!(chaos.required_rows(17, 16), 32);
        assert_eq!(chaos.name(), "chaos");
        assert_eq!(chaos.attend_batch(&items).unwrap(), plain.attend_batch(&items).unwrap());
        assert_eq!(chaos.attend(&q, &k, &v).unwrap(), plain.attend(&q, &k, &v).unwrap());
        assert_eq!(chaos.work_stats(), plain.work_stats());
        let stats = chaos.stats();
        assert_eq!(stats.errors.load(Ordering::Relaxed), 0);
        assert_eq!(stats.panics.load(Ordering::Relaxed), 0);
        assert_eq!(stats.crashes.load(Ordering::Relaxed), 0);
        assert_eq!(stats.stalls.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn chaos_fires_on_the_scheduled_dispatch_only() {
        let mut rng = Rng::new(119);
        let k = rng.normal_vec(32 * 64);
        let v = rng.normal_vec(32 * 64);
        let q = rng.normal_vec(64);
        let items =
            [AttendItem { query: &q, keys: &k, values: &v, prefix_rows: 32, packed: None }];
        let mut chaos = ChaosBackend::new(
            FunctionalBackend::new(32, 64),
            FaultPlan::at(vec![
                (2, Fault::Error),
                (3, Fault::Stall(Duration::from_millis(1))),
            ]),
        );
        assert!(chaos.attend_batch(&items).is_ok(), "dispatch 1 is clean");
        let err = chaos.attend_batch(&items).unwrap_err();
        assert!(err.to_string().contains("dispatch 2"), "{err}");
        assert!(chaos.attend_batch(&items).is_ok(), "a stall still serves");
        assert!(chaos.attend_batch(&items).is_ok(), "past the plan horizon");
        let stats = chaos.stats();
        assert_eq!(stats.errors.load(Ordering::Relaxed), 1);
        assert_eq!(stats.stalls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn chaos_panic_and_crash_payloads_are_distinguishable() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let mut rng = Rng::new(120);
        let k = rng.normal_vec(32 * 64);
        let v = rng.normal_vec(32 * 64);
        let q = rng.normal_vec(64);
        let items =
            [AttendItem { query: &q, keys: &k, values: &v, prefix_rows: 32, packed: None }];
        let stats = Arc::new(ChaosStats::default());
        let mut chaos = ChaosBackend::with_stats(
            FunctionalBackend::new(32, 64),
            FaultPlan::at(vec![(1, Fault::Panic), (2, Fault::Crash)]),
            stats.clone(),
        );
        // an ordinary panic payload: containment should absorb it
        let p = catch_unwind(AssertUnwindSafe(|| chaos.attend_batch(&items))).unwrap_err();
        assert!(p.downcast_ref::<WorkerAbort>().is_none());
        assert!(p.downcast_ref::<String>().is_some_and(|s| s.contains("dispatch 1")));
        // a WorkerAbort payload: containment must re-raise it
        let c = catch_unwind(AssertUnwindSafe(|| chaos.attend_batch(&items))).unwrap_err();
        let abort = c.downcast_ref::<WorkerAbort>().expect("crash carries WorkerAbort");
        assert!(abort.0.contains("dispatch 2"), "{}", abort.0);
        assert_eq!(stats.panics.load(Ordering::Relaxed), 1);
        assert_eq!(stats.crashes.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn random_fault_plans_are_seed_deterministic() {
        let a = FaultPlan::random(42, 64, 0.25);
        let b = FaultPlan::random(42, 64, 0.25);
        assert_eq!(a.len(), b.len());
        for n in 1..=64 {
            assert_eq!(a.lookup(n), b.lookup(n), "dispatch {n}");
        }
        assert!(!a.is_empty(), "density 0.25 over 64 dispatches should schedule something");
        assert!(FaultPlan::random(42, 64, 0.0).is_empty());
        // a different seed must (overwhelmingly likely) differ somewhere
        let c = FaultPlan::random(43, 64, 0.25);
        assert!((1..=64).any(|n| a.lookup(n) != c.lookup(n)));
    }
}
