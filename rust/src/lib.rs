//! CAMformer — attention as associative memory.
pub mod util;
pub mod camcircuit;
pub mod bimv;
pub mod arch;
pub mod dram;
pub mod cost;
pub mod baselines;
pub mod accuracy;
pub mod coordinator;
pub mod runtime;
pub mod workload;
