//! `camformer` — CLI for the CAMformer reproduction.
//!
//! Every table and figure in the paper's evaluation has a subcommand that
//! regenerates it (DESIGN.md per-experiment index). `serve` runs the
//! Layer-3 coordinator over the PJRT artifacts.

use camformer::util::cli::Args;

mod commands {
    pub mod figures;
    pub mod serve;
    pub mod tables;
}

const HELP: &str = "\
camformer — attention as associative memory (paper reproduction)

USAGE: camformer <command> [options]

Paper experiments:
  fig3a    matchline voltage vs matches (1x10 BA-CAM transients)
  fig3b    PVT deviation across TT/SS/FF corners (16x64 array)
  table1   circuit-level BIMV comparison (CiM / TD-CAM / BA-CAM)
  fig5     per-op energy vs amortisation dimension M
  fig7     pipelining timelines and stall accounting
  fig8     energy & area breakdown by component and stage
  fig9     per-stage throughput with/without optimisations
  table2   accelerator comparison at 1 GHz
  fig10    Pareto frontier: perf/W vs perf/mm^2, industry + academic
  table3   first-stage-k accuracy sweep, MEASURED via PJRT classifiers
  table4   GLUE-style multi-task sweep (calibrated simulation)
  dse      design-space exploration (MAC balance, CAM geometry, ADC bits)

Serving / demo:
  serve    session-oriented decode serving through the coordinator:
           open (shard-wide prefill fan-out) + ticketed live KV-append
           decode steps per session handle, explicit close
           [--sessions N] [--steps N] [--prefill ROWS] [--heads H]
           [--backend functional|arch|pjrt] [--reclaim deny|lru|spill]
           --trace bert|vit|zipf replays a seeded workload trace instead
           and prices it through the circuit models (J/token, watts):
           [--seed N] [--speedup X] [--shards N] [--max-sessions N]
  quickstart  one query end-to-end through every layer (needs artifacts)

Common options:
  --seed S         RNG seed (default 42)
  --trials N       Monte-Carlo trials where applicable
  --artifacts DIR  artifacts directory (default ./artifacts)
";

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "fig3a" => commands::figures::fig3a(&args),
        "fig3b" => commands::figures::fig3b(&args),
        "fig5" => commands::figures::fig5(&args),
        "fig7" => commands::figures::fig7(&args),
        "fig8" => commands::figures::fig8(&args),
        "fig9" => commands::figures::fig9(&args),
        "fig10" => commands::figures::fig10(&args),
        "table1" => commands::tables::table1(&args),
        "table2" => commands::tables::table2(&args),
        "table3" => commands::tables::table3(&args),
        "table4" => commands::tables::table4(&args),
        "dse" => commands::figures::dse(&args),
        "serve" => commands::serve::serve(&args),
        "quickstart" => commands::serve::quickstart(&args),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
