//! Association stage (Sec. III-B1): BA-CAM scores + hierarchical stage-1
//! ranking + V-prefetch triggers.
//!
//! Per tile: program the CAM, broadcast the query, digitise cam_h scores
//! through the shared SAR, run the bitonic Top-2, push the two survivors
//! to the potential-top register and their indices to the MC/DMA for V
//! prefetch. The Key SRAM holds the full binarised K and is off the
//! critical path (keys are reused across queries).

use super::bitonic::{self, Entry};
use super::config::ArchConfig;
use crate::bimv::engine::BimvEngine;

/// Output of the association stage for one query.
#[derive(Clone, Debug)]
pub struct AssociationResult {
    /// All N quantised scores (for validation; hardware only keeps
    /// candidates).
    pub scores: Vec<f64>,
    /// Stage-1 survivors: the potential-top register contents, in tile
    /// order (h_tiles x stage1_k entries).
    pub candidates: Vec<Entry>,
    /// Prefetch stream: key indices in the order they were issued.
    pub prefetch_indices: Vec<usize>,
    /// Cycle count of the stage (fine-grained pipelined, Fig. 7 left).
    pub cycles: u64,
    /// Sorter comparator work (for the cost cross-check).
    pub sorter_comparators: usize,
}

/// The association stage bound to one BIMV engine.
pub struct AssociationStage {
    pub cfg: ArchConfig,
    pub engine: BimvEngine,
}

impl AssociationStage {
    pub fn new(cfg: ArchConfig) -> Self {
        AssociationStage {
            engine: BimvEngine::new(cfg.cam_h, cfg.cam_w),
            cfg,
        }
    }

    /// Run one query against the (binarised) key memory.
    pub fn run(&mut self, query: &[bool], keys: &[Vec<bool>]) -> AssociationResult {
        assert_eq!(keys.len(), self.cfg.n);
        let scores = self.engine.scores(query, keys);

        let mut candidates = Vec::with_capacity(self.cfg.candidates());
        let mut prefetch = Vec::with_capacity(self.cfg.candidates());
        let mut comparators = 0usize;
        for t in 0..self.cfg.h_tiles() {
            let lo = t * self.cfg.cam_h;
            let hi = ((t + 1) * self.cfg.cam_h).min(self.cfg.n);
            let tile = &scores[lo..hi];
            let (top, stats) = bitonic::bitonic_topk(
                &tile
                    .iter()
                    .enumerate()
                    .map(|(i, &s)| Entry { score: s, index: lo + i })
                    .collect::<Vec<_>>(),
                self.cfg.stage1_k,
            );
            comparators += stats.comparators;
            for e in &top {
                prefetch.push(e.index);
            }
            candidates.extend(top);
        }

        // Fine-grained pipelining (Fig. 7 left): program/search of tile
        // t+1 overlaps ADC of tile t overlaps Top-2 of tile t-1, so the
        // cadence is the slowest of the three; ADC serialization dominates.
        let tile_cadence = self
            .cfg
            .adc_cycles_per_tile()
            .max(self.cfg.cam_phases)
            .max(bitonic_depth_cycles(self.cfg.cam_h));
        let fill = self.cfg.cam_phases + bitonic_depth_cycles(self.cfg.cam_h);
        let cycles = tile_cadence * self.cfg.tiles() as u64 + fill;

        AssociationResult {
            scores,
            candidates,
            prefetch_indices: prefetch,
            cycles,
            sorter_comparators: comparators,
        }
    }

    /// Stage latency without fine-grained pipelining (for Fig. 7/9's
    /// "before" bars): phases serialize per tile.
    pub fn cycles_unpipelined(&self) -> u64 {
        let per_tile = self.cfg.cam_phases
            + self.cfg.adc_cycles_per_tile()
            + bitonic_depth_cycles(self.cfg.cam_h);
        per_tile * self.cfg.tiles() as u64
    }
}

/// Depth (cycles) of the tile's bitonic network.
fn bitonic_depth_cycles(width: usize) -> u64 {
    let p = width.next_power_of_two().trailing_zeros() as u64;
    p * (p + 1) / 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::functional;
    use crate::util::rng::Rng;

    fn setup(n: usize) -> (AssociationStage, Vec<bool>, Vec<Vec<bool>>) {
        let cfg = ArchConfig { n, ..Default::default() };
        let mut rng = Rng::new(80);
        let q: Vec<bool> = (0..cfg.d_k).map(|_| rng.bool()).collect();
        let keys: Vec<Vec<bool>> = (0..n)
            .map(|_| (0..cfg.d_k).map(|_| rng.bool()).collect())
            .collect();
        (AssociationStage::new(cfg), q, keys)
    }

    #[test]
    fn candidates_match_functional_model() {
        let (mut stage, q, keys) = setup(256);
        let res = stage.run(&q, &keys);
        // compare stage-1 survivors with the functional two-stage mask's
        // stage-1 (tile top-2) set
        let qf: Vec<f32> = q.iter().map(|&b| if b { 1.0 } else { -1.0 }).collect();
        let kf: Vec<f32> = keys
            .iter()
            .flat_map(|r| r.iter().map(|&b| if b { 1.0f32 } else { -1.0 }))
            .collect();
        let scores = functional::bacam_scores(&qf, &kf, 64);
        for t in 0..16 {
            let tile = &scores[t * 16..(t + 1) * 16];
            let want = functional::topk_indices(tile, 2);
            let got: Vec<usize> = res.candidates[t * 2..t * 2 + 2]
                .iter()
                .map(|e| e.index - t * 16)
                .collect();
            assert_eq!(got, want, "tile {t}");
        }
    }

    #[test]
    fn prefetch_stream_covers_candidates() {
        let (mut stage, q, keys) = setup(128);
        let res = stage.run(&q, &keys);
        assert_eq!(res.prefetch_indices.len(), 16); // 8 tiles x 2
        for (e, &i) in res.candidates.iter().zip(&res.prefetch_indices) {
            assert_eq!(e.index, i);
        }
    }

    #[test]
    fn pipelining_beats_serial() {
        let (stage, _, _) = setup(1024);
        let piped = {
            let mut s = AssociationStage::new(stage.cfg);
            let mut rng = Rng::new(81);
            let q: Vec<bool> = (0..64).map(|_| rng.bool()).collect();
            let keys: Vec<Vec<bool>> = (0..1024)
                .map(|_| (0..64).map(|_| rng.bool()).collect())
                .collect();
            s.run(&q, &keys).cycles
        };
        assert!(piped < stage.cycles_unpipelined());
        // ADC-dominated: cadence 96 cycles x 64 tiles ≈ 6.1k cycles
        assert!(piped >= 96 * 64);
        assert!(piped < 96 * 64 + 100);
    }

    #[test]
    fn scores_are_complete_and_bounded() {
        let (mut stage, q, keys) = setup(512);
        let res = stage.run(&q, &keys);
        assert_eq!(res.scores.len(), 512);
        assert!(res.scores.iter().all(|s| s.abs() <= 64.0));
    }

    #[test]
    fn sorter_work_scales_with_tiles() {
        let (mut s1, q1, k1) = setup(128);
        let (mut s2, q2, k2) = setup(1024);
        let r1 = s1.run(&q1, &k1);
        let r2 = s2.run(&q2, &k2);
        assert_eq!(r2.sorter_comparators, 8 * r1.sorter_comparators);
    }
}
