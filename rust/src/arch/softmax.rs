//! The Normalization stage's SoftMax engine (Sec. III-B2).
//!
//! Hardware: a 512 B LUT (256 bf16 entries indexed by the 8-bit quantised
//! score), one BF16 accumulator, one pipelined BF16 divider. Because the
//! fully-binarised score range is bounded ([-64, 64], Sec. III-C1), the
//! LUT covers exp(s/sqrt(d_k)) exactly over all reachable codes — "the
//! bounded score range makes SoftMax cheap".
//!
//! Latency: accumulation is serial (one score/cycle); the pipelined
//! divider turns 32 divisions from 32*t_div into 31 + t_div (Sec. III-C2).

use crate::util::bf16;

/// The 512 B exp LUT: 256 bf16 entries for 8-bit signed scores.
pub struct SoftmaxEngine {
    lut: Vec<f32>, // bf16-valued
    /// Scores map to LUT index as (s - min_score) / step.
    min_score: f64,
    step: f64,
    pub d_k: usize,
}

impl SoftmaxEngine {
    /// Build the LUT for scores in [-d_k, d_k] (the BA-CAM output range).
    pub fn new(d_k: usize) -> Self {
        let entries = 256usize; // 512 B / 2 B per bf16
        let min_score = -(d_k as f64);
        let step = (2.0 * d_k as f64) / (entries - 1) as f64;
        let scale = 1.0 / (d_k as f64).sqrt();
        let lut = (0..entries)
            .map(|i| {
                let s = min_score + i as f64 * step;
                // store exp((s - d_k)/sqrt(d_k)): pre-shifted by the max
                // possible score so entries are all <= 1 (no overflow in
                // bf16, and the shift cancels in the normalisation)
                bf16::round(((s - d_k as f64) * scale).exp() as f32)
            })
            .collect();
        SoftmaxEngine {
            lut,
            min_score,
            step,
            d_k,
        }
    }

    pub fn lut_bytes(&self) -> usize {
        self.lut.len() * 2
    }

    /// One LUT lookup: quantise the score to its code, return exp entry.
    pub fn lookup(&self, score: f64) -> f32 {
        let idx = ((score - self.min_score) / self.step).round();
        let idx = (idx.max(0.0) as usize).min(self.lut.len() - 1);
        self.lut[idx]
    }

    /// Normalise the top-k scores: returns bf16-valued probabilities.
    /// Functionally this is softmax(s/sqrt(d_k)) with LUT+bf16 rounding.
    pub fn normalize(&self, scores: &[f64]) -> Vec<f32> {
        // serial BF16 accumulation, as the hardware accumulator does
        let mut denom = 0.0f32;
        let exps: Vec<f32> = scores.iter().map(|&s| self.lookup(s)).collect();
        for &e in &exps {
            denom = bf16::add(denom, e);
        }
        exps.iter().map(|&e| bf16::div(e, denom)).collect()
    }

    /// Engine latency in cycles for `k` scores with a pipelined divider:
    /// k-1 overlapped issues + one end-to-end division (Sec. III-C2:
    /// "from 32*t_div to 31 + t_div").
    pub fn latency_cycles(&self, k: usize, t_div: u64, pipelined: bool) -> u64 {
        let accumulate = k as u64; // one lookup+add per cycle
        let divide = if pipelined {
            (k as u64 - 1) + t_div
        } else {
            k as u64 * t_div
        };
        accumulate + divide
    }
}

/// Exact reference softmax over the same inputs (f64).
pub fn softmax_exact(scores: &[f64], d_k: usize) -> Vec<f64> {
    let scale = 1.0 / (d_k as f64).sqrt();
    let mx = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let es: Vec<f64> = scores.iter().map(|&s| ((s - mx) * scale).exp()).collect();
    let sum: f64 = es.iter().sum();
    es.iter().map(|&e| e / sum).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn lut_is_512_bytes() {
        assert_eq!(SoftmaxEngine::new(64).lut_bytes(), 512);
    }

    #[test]
    fn probabilities_sum_to_one_ish() {
        let eng = SoftmaxEngine::new(64);
        let scores = vec![30.0, 28.0, 10.0, -5.0, 0.0, 22.0, 18.0, -64.0];
        let p = eng.normalize(&scores);
        let sum: f32 = p.iter().sum();
        // bf16 accumulator + divider: within ~1% of exactly 1
        assert!((sum - 1.0).abs() < 0.02, "sum {sum}");
        assert!(p.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }

    #[test]
    fn property_close_to_exact_softmax() {
        check("lut softmax vs exact", 40, |rng| {
            let k = 1 + rng.index(32);
            let scores: Vec<f64> = (0..k)
                .map(|_| (rng.range(0, 129) as f64) - 64.0)
                .collect();
            let eng = SoftmaxEngine::new(64);
            let got = eng.normalize(&scores);
            let want = softmax_exact(&scores, 64);
            for (g, w) in got.iter().zip(&want) {
                // 8-bit LUT + bf16 arithmetic: a few percent absolute
                assert!(
                    (*g as f64 - w).abs() < 0.03,
                    "lut {g} vs exact {w}"
                );
            }
        });
    }

    #[test]
    fn ordering_preserved() {
        let eng = SoftmaxEngine::new(64);
        let scores = vec![40.0, 10.0, 35.0, -20.0];
        let p = eng.normalize(&scores);
        assert!(p[0] > p[2] && p[2] > p[1] && p[1] > p[3]);
    }

    #[test]
    fn pipelined_divider_latency_matches_paper() {
        let eng = SoftmaxEngine::new(64);
        let t_div = 14;
        // paper: 32*t_div -> 31 + t_div for the divide part
        let serial = eng.latency_cycles(32, t_div, false);
        let piped = eng.latency_cycles(32, t_div, true);
        assert_eq!(serial - 32, 32 * t_div);
        assert_eq!(piped - 32, 31 + t_div);
        assert!(piped < serial);
    }

    #[test]
    fn bounded_range_never_overflows() {
        let eng = SoftmaxEngine::new(64);
        for s in [-64.0, 0.0, 64.0] {
            let e = eng.lookup(s);
            assert!(e.is_finite() && e <= 1.0 + 1e-3);
        }
    }
}
