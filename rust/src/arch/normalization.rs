//! Normalization stage (Sec. III-B2): finalise the Top-32 ranking with the
//! 64-input bitonic refinement block, then LUT softmax.
//!
//! "To reduce area, we use a 64-input module and refine across batches as
//! each 16-tile group yields 32 new top-2 candidates" — candidates arrive
//! in groups of 32 (16 tiles x top-2) and merge with the running top-32.

use super::bitonic::{self, Entry};
use super::config::ArchConfig;
use super::softmax::SoftmaxEngine;

/// Output of the normalization stage.
#[derive(Clone, Debug)]
pub struct NormalizationResult {
    /// Final selected entries (<= final_k), sorted by descending score.
    pub selected: Vec<Entry>,
    /// BF16 probabilities aligned with `selected` (sum ~= 1).
    pub probs: Vec<f32>,
    /// Stage cycles (refinement passes + pipelined softmax).
    pub cycles: u64,
    pub sorter_comparators: usize,
}

/// The normalization stage.
pub struct NormalizationStage {
    pub cfg: ArchConfig,
    softmax: SoftmaxEngine,
}

impl NormalizationStage {
    pub fn new(cfg: ArchConfig) -> Self {
        NormalizationStage {
            softmax: SoftmaxEngine::new(cfg.d_k),
            cfg,
        }
    }

    /// Consume the association stage's candidate stream.
    pub fn run(&self, candidates: &[Entry]) -> NormalizationResult {
        // refine in batches of 32 through the 64-input block
        let batch = 32usize;
        let mut running: Vec<Entry> = Vec::new();
        let mut comparators = 0usize;
        let mut passes = 0u64;
        for chunk in candidates.chunks(batch) {
            let (r, stats) = bitonic::top32_refine(&running, chunk);
            running = r;
            comparators += stats.comparators;
            passes += 1;
        }
        running.truncate(self.cfg.final_k);

        let scores: Vec<f64> = running.iter().map(|e| e.score).collect();
        let probs = self.softmax.normalize(&scores);

        // refinement block is depth-21, pipelined one pass at a time;
        // softmax overlaps the last pass's output stream
        let sort_cycles = passes * 21;
        let sm_cycles = self
            .softmax
            .latency_cycles(running.len().max(1), self.cfg.t_div, true);
        NormalizationResult {
            selected: running,
            probs,
            cycles: sort_cycles + sm_cycles,
            sorter_comparators: comparators,
        }
    }

    /// Latency with a serial (unpipelined) divider, for Fig. 9's ablation.
    pub fn cycles_unpipelined(&self, k: usize, passes: u64) -> u64 {
        passes * 21 + self.softmax.latency_cycles(k, self.cfg.t_div, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::functional;
    use crate::util::rng::Rng;

    fn candidates_from(scores: &[f64], group: usize, k1: usize) -> Vec<Entry> {
        let mut out = Vec::new();
        for t in 0..scores.len() / group {
            let tile = &scores[t * group..(t + 1) * group];
            for i in functional::topk_indices(tile, k1) {
                out.push(Entry { score: tile[i], index: t * group + i });
            }
        }
        out
    }

    #[test]
    fn selection_matches_functional_two_stage() {
        let mut rng = Rng::new(90);
        let scores: Vec<f64> = (0..1024)
            .map(|_| (rng.range(0, 129) as f64) - 64.0)
            .collect();
        let stage = NormalizationStage::new(ArchConfig::default());
        let res = stage.run(&candidates_from(&scores, 16, 2));
        let mask = functional::two_stage_topk_mask(&scores, 16, 2, 32);
        let want: std::collections::BTreeSet<usize> = mask
            .iter()
            .enumerate()
            .filter(|(_, &m)| m)
            .map(|(i, _)| i)
            .collect();
        let got: std::collections::BTreeSet<usize> =
            res.selected.iter().map(|e| e.index).collect();
        // same score multiset is guaranteed; index sets can differ only
        // across equal scores (tie order between sorter batches)
        let mut ws: Vec<f64> = want.iter().map(|&i| scores[i]).collect();
        let mut gs: Vec<f64> = got.iter().map(|&i| scores[i]).collect();
        ws.sort_by(|a, b| b.partial_cmp(a).unwrap());
        gs.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert_eq!(ws, gs);
    }

    #[test]
    fn probs_are_normalised() {
        let mut rng = Rng::new(91);
        let scores: Vec<f64> = (0..512).map(|_| rng.normal(0.0, 20.0).clamp(-64.0, 64.0)).collect();
        let stage = NormalizationStage::new(ArchConfig::default());
        let res = stage.run(&candidates_from(&scores, 16, 2));
        assert_eq!(res.selected.len(), 32);
        let sum: f32 = res.probs.iter().sum();
        assert!((sum - 1.0).abs() < 0.02, "sum {sum}");
    }

    #[test]
    fn fewer_candidates_than_k_all_selected() {
        let scores: Vec<f64> = (0..64).map(|i| i as f64).collect();
        let stage = NormalizationStage::new(ArchConfig { n: 64, ..Default::default() });
        let res = stage.run(&candidates_from(&scores, 16, 2));
        assert_eq!(res.selected.len(), 8); // 4 tiles x 2 candidates
    }

    #[test]
    fn pipelined_softmax_latency() {
        let stage = NormalizationStage::new(ArchConfig::default());
        let mut rng = Rng::new(92);
        let scores: Vec<f64> = (0..1024).map(|_| rng.normal(0.0, 20.0)).collect();
        let res = stage.run(&candidates_from(&scores, 16, 2));
        let serial = stage.cycles_unpipelined(32, 4);
        assert!(res.cycles < serial, "{} !< {}", res.cycles, serial);
    }
}
