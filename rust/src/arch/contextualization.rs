//! Contextualization stage (Sec. III-B3): the BF16 sparse MV — the
//! selected probabilities times their prefetched V rows, on `mac_units`
//! parallel BF16 MACs with fine-grained pipelining.

use super::bitonic::Entry;
use super::config::ArchConfig;
use crate::util::bf16;

/// Output of the contextualization stage.
#[derive(Clone, Debug)]
pub struct ContextualizationResult {
    /// The attention output vector (d_v, bf16-valued f32).
    pub output: Vec<f32>,
    pub cycles: u64,
    pub macs: usize,
}

/// The contextualization stage.
pub struct ContextualizationStage {
    pub cfg: ArchConfig,
}

impl ContextualizationStage {
    pub fn new(cfg: ArchConfig) -> Self {
        ContextualizationStage { cfg }
    }

    /// `selected`/`probs` from normalization; `v` is the full row-major
    /// N x d_v value matrix (the V-SRAM holds the prefetched subset).
    pub fn run(&self, selected: &[Entry], probs: &[f32], v: &[f32]) -> ContextualizationResult {
        assert_eq!(selected.len(), probs.len());
        let d_v = self.cfg.d_v;
        let mut out = vec![0.0f32; d_v];
        for (e, &p) in selected.iter().zip(probs) {
            let row = &v[e.index * d_v..(e.index + 1) * d_v];
            let pb = bf16::round(p);
            for c in 0..d_v {
                // bf16 inputs, f32 accumulate (MAC array semantics)
                out[c] += pb * bf16::round(row[c]);
            }
        }
        for o in &mut out {
            *o = bf16::round(*o);
        }

        let macs = selected.len() * d_v;
        // mac_units lanes, fully pipelined: ceil(macs/units) + drain
        let cycles = (macs as u64).div_ceil(self.cfg.mac_units as u64) + 8;
        ContextualizationResult {
            output: out,
            cycles,
            macs,
        }
    }

    /// Cycles for a given selection size (for the pipeline model).
    pub fn cycles_for(&self, k: usize) -> u64 {
        ((k * self.cfg.d_v) as u64).div_ceil(self.cfg.mac_units as u64) + 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn weighted_sum_correct() {
        let cfg = ArchConfig { d_v: 4, ..Default::default() };
        let stage = ContextualizationStage::new(cfg);
        let v = vec![
            1.0, 0.0, 0.0, 0.0, // row 0
            0.0, 2.0, 0.0, 0.0, // row 1
            0.0, 0.0, 4.0, 0.0, // row 2
        ];
        let selected = vec![
            Entry { score: 10.0, index: 0 },
            Entry { score: 5.0, index: 2 },
        ];
        let probs = vec![0.75f32, 0.25f32];
        let res = stage.run(&selected, &probs, &v);
        assert_eq!(res.output, vec![0.75, 0.0, 1.0, 0.0]);
        assert_eq!(res.macs, 8);
    }

    #[test]
    fn output_in_convex_hull() {
        let cfg = ArchConfig::default();
        let stage = ContextualizationStage::new(cfg);
        let mut rng = Rng::new(95);
        let v: Vec<f32> = rng.normal_vec(1024 * 64);
        let selected: Vec<Entry> = (0..32)
            .map(|i| Entry { score: 0.0, index: i * 30 })
            .collect();
        let probs = vec![1.0f32 / 32.0; 32];
        let res = stage.run(&selected, &probs, &v);
        let vmax = v.iter().cloned().fold(f32::MIN, f32::max);
        let vmin = v.iter().cloned().fold(f32::MAX, f32::min);
        for &o in &res.output {
            assert!(o <= vmax + 0.05 && o >= vmin - 0.05);
        }
    }

    #[test]
    fn mac_units_scale_cycles() {
        let c1 = ContextualizationStage::new(ArchConfig { mac_units: 1, ..Default::default() });
        let c8 = ContextualizationStage::new(ArchConfig { mac_units: 8, ..Default::default() });
        // 32 x 64 = 2048 MACs
        assert_eq!(c1.cycles_for(32), 2048 + 8);
        assert_eq!(c8.cycles_for(32), 256 + 8);
    }
}
