//! Comparator-exact bitonic sorting networks (the Top-2 tile filter and
//! the 64-input Top-32 block of Secs. III-B1/B2).
//!
//! The networks are executed element-by-element so the comparator count
//! and stage depth are *measured*, not estimated — those numbers feed the
//! sorter area/latency entries in the cost model, and "the bitonic sorter
//! also makes sparsity easily configurable" (Sec. III-B1) because top-k
//! just taps the k hottest outputs.

/// A scored candidate flowing through the sorter (score + key index).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Entry {
    pub score: f64,
    pub index: usize,
}

impl Entry {
    pub const NEG_INF: Entry = Entry {
        score: f64::NEG_INFINITY,
        index: usize::MAX,
    };
}

/// Execution statistics of one network pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SortStats {
    /// Compare-exchange operations performed.
    pub comparators: usize,
    /// Network depth (cycles when one stage per cycle, fully pipelined).
    pub depth: usize,
}

/// Bitonic sort network over a power-of-two array, descending by score;
/// ties broken by lower index (stable with respect to the tile order, like
/// the jnp oracle). Returns the measured stats.
pub fn bitonic_sort(data: &mut [Entry]) -> SortStats {
    let n = data.len();
    assert!(n.is_power_of_two(), "bitonic network needs power-of-two width");
    let mut stats = SortStats::default();
    let mut k = 2;
    while k <= n {
        let mut j = k / 2;
        while j > 0 {
            stats.depth += 1;
            for i in 0..n {
                let l = i ^ j;
                if l > i {
                    stats.comparators += 1;
                    let ascending = (i & k) != 0;
                    let a = data[i];
                    let b = data[l];
                    // descending block: bigger score (or equal score with
                    // smaller index) stays on top
                    let a_before_b = match a.score.partial_cmp(&b.score).unwrap() {
                        std::cmp::Ordering::Greater => true,
                        std::cmp::Ordering::Less => false,
                        std::cmp::Ordering::Equal => a.index <= b.index,
                    };
                    let swap = if ascending { a_before_b } else { !a_before_b };
                    if swap {
                        data.swap(i, l);
                    }
                }
            }
            j /= 2;
        }
        k *= 2;
    }
    stats
}

/// Top-k through a full bitonic sort (what the hardware blocks implement,
/// with the tail outputs simply unrouted). `data` is padded to the next
/// power of two with -inf.
pub fn bitonic_topk(data: &[Entry], k: usize) -> (Vec<Entry>, SortStats) {
    let width = data.len().next_power_of_two();
    let mut padded = data.to_vec();
    padded.resize(width, Entry::NEG_INF);
    let stats = bitonic_sort(&mut padded);
    padded.truncate(k.min(data.len()));
    (padded, stats)
}

/// The per-tile Top-2 filter: a 16-input bitonic max-2 (Sec. III-B1).
pub fn tile_top2(scores: &[f64], base_index: usize) -> (Vec<Entry>, SortStats) {
    let entries: Vec<Entry> = scores
        .iter()
        .enumerate()
        .map(|(i, &s)| Entry {
            score: s,
            index: base_index + i,
        })
        .collect();
    bitonic_topk(&entries, 2)
}

/// The 64-input Top-32 refinement block (Sec. III-B2): merge the running
/// top-32 with 32 new candidates, keep the best 32.
pub fn top32_refine(running: &[Entry], fresh: &[Entry]) -> (Vec<Entry>, SortStats) {
    assert!(running.len() <= 32 && fresh.len() <= 32);
    let mut all: Vec<Entry> = Vec::with_capacity(64);
    all.extend_from_slice(running);
    all.extend_from_slice(fresh);
    all.resize(64, Entry::NEG_INF);
    let stats = bitonic_sort(&mut all);
    all.truncate(32);
    all.retain(|e| e.score > f64::NEG_INFINITY);
    (all, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::rng::Rng;

    fn entries(scores: &[f64]) -> Vec<Entry> {
        scores
            .iter()
            .enumerate()
            .map(|(i, &s)| Entry { score: s, index: i })
            .collect()
    }

    #[test]
    fn sorts_descending() {
        let mut d = entries(&[3.0, 1.0, 4.0, 1.5, 9.0, 2.6, 5.0, 3.5]);
        bitonic_sort(&mut d);
        for w in d.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
        assert_eq!(d[0].score, 9.0);
    }

    #[test]
    fn property_matches_std_sort() {
        check("bitonic vs std", 100, |rng| {
            let n = [4usize, 8, 16, 32, 64][rng.index(5)];
            let scores: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 10.0)).collect();
            let mut d = entries(&scores);
            bitonic_sort(&mut d);
            let mut want = scores.clone();
            want.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let got: Vec<f64> = d.iter().map(|e| e.score).collect();
            assert_eq!(got, want);
        });
    }

    #[test]
    fn comparator_count_matches_formula() {
        // bitonic sort of n = 2^p uses n*p*(p+1)/4 comparators
        for p in 2..=6u32 {
            let n = 1usize << p;
            let mut d = entries(&vec![0.0; n]);
            let stats = bitonic_sort(&mut d);
            assert_eq!(
                stats.comparators,
                n * p as usize * (p as usize + 1) / 4,
                "n={n}"
            );
            assert_eq!(stats.depth, (p * (p + 1) / 2) as usize);
        }
    }

    #[test]
    fn sixtyfour_input_block_depth() {
        // the Top-32 module: 64 inputs => depth 21, 672 comparators
        let mut d = entries(&vec![1.0; 64]);
        let stats = bitonic_sort(&mut d);
        assert_eq!(stats.depth, 21);
        assert_eq!(stats.comparators, 672);
    }

    #[test]
    fn tile_top2_finds_best_two() {
        let scores = [5.0, -3.0, 8.0, 8.0, 1.0, 0.0, 7.5, 2.0,
                      -1.0, 4.0, 3.0, 6.0, 2.5, 0.5, -2.0, 1.5];
        let (top, _) = tile_top2(&scores, 160);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].score, 8.0);
        assert_eq!(top[1].score, 8.0);
        // ties break to the lower index
        assert_eq!(top[0].index, 160 + 2);
        assert_eq!(top[1].index, 160 + 3);
    }

    #[test]
    fn property_topk_is_true_topk() {
        check("bitonic topk", 60, |rng| {
            let n = 1 + rng.index(64);
            let k = 1 + rng.index(n);
            let scores: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 10.0)).collect();
            let (top, _) = bitonic_topk(&entries(&scores), k);
            let mut want = scores.clone();
            want.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let got: Vec<f64> = top.iter().map(|e| e.score).collect();
            assert_eq!(got, want[..k].to_vec());
        });
    }

    #[test]
    fn refinement_accumulates_global_top32() {
        let mut rng = Rng::new(70);
        let all: Vec<f64> = (0..128).map(|_| rng.normal(0.0, 10.0)).collect();
        // feed in 4 batches of 32 through the refinement block
        let mut running: Vec<Entry> = Vec::new();
        for b in 0..4 {
            let fresh: Vec<Entry> = (0..32)
                .map(|i| Entry { score: all[b * 32 + i], index: b * 32 + i })
                .collect();
            let (r, _) = top32_refine(&running, &fresh);
            running = r;
        }
        let mut want = all.clone();
        want.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let mut got: Vec<f64> = running.iter().map(|e| e.score).collect();
        got.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert_eq!(got, want[..32].to_vec());
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn non_power_of_two_rejected() {
        let mut d = entries(&[1.0, 2.0, 3.0]);
        bitonic_sort(&mut d);
    }
}
