//! Microarchitecture configuration shared by the stage models.

/// Cycle-level architecture parameters (paper defaults in `Default`).
#[derive(Clone, Copy, Debug)]
pub struct ArchConfig {
    /// CAM geometry (Sec. III-B1: 16x64).
    pub cam_h: usize,
    pub cam_w: usize,
    /// Workload: keys in memory and head dimension.
    pub n: usize,
    pub d_k: usize,
    pub d_v: usize,
    /// Stage-1 top-k per tile and final top-k.
    pub stage1_k: usize,
    pub final_k: usize,
    /// Parallel BF16 MAC units in contextualization (DSE: 8 balances).
    pub mac_units: usize,
    /// System clock \[GHz\] (Table II runs at 1 GHz).
    pub clock_ghz: f64,
    /// SAR ADC bits (6) and ADC instances per array (1 = shared).
    pub adc_bits: u32,
    pub adcs_per_array: usize,
    /// CAM phase count (precharge/broadcast/match/share).
    pub cam_phases: u64,
    /// Pipelined BF16 divider end-to-end latency \[cycles\].
    pub t_div: u64,
}

impl Default for ArchConfig {
    fn default() -> Self {
        ArchConfig {
            cam_h: 16,
            cam_w: 64,
            n: 1024,
            d_k: 64,
            d_v: 64,
            stage1_k: 2,
            final_k: 32,
            mac_units: 8,
            clock_ghz: 1.0,
            adc_bits: 6,
            adcs_per_array: 1,
            cam_phases: 4,
            t_div: 14,
        }
    }
}

impl ArchConfig {
    pub fn h_tiles(&self) -> usize {
        self.n.div_ceil(self.cam_h)
    }

    pub fn v_tiles(&self) -> usize {
        self.d_k.div_ceil(self.cam_w)
    }

    pub fn tiles(&self) -> usize {
        self.h_tiles() * self.v_tiles()
    }

    /// Stage-1 candidates produced per query.
    pub fn candidates(&self) -> usize {
        self.h_tiles() * self.stage1_k
    }

    pub fn cycle_ns(&self) -> f64 {
        1.0 / self.clock_ghz
    }

    /// ADC serialization cycles per tile: cam_h conversions, 6 cycles
    /// each, divided over the instantiated ADCs.
    pub fn adc_cycles_per_tile(&self) -> u64 {
        let convs = self.cam_h.div_ceil(self.adcs_per_array) as u64;
        convs * self.adc_bits as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults() {
        let c = ArchConfig::default();
        assert_eq!(c.h_tiles(), 64);
        assert_eq!(c.v_tiles(), 1);
        assert_eq!(c.candidates(), 128);
        assert_eq!(c.adc_cycles_per_tile(), 96);
    }

    #[test]
    fn two_adcs_halve_serialization() {
        let c = ArchConfig { adcs_per_array: 2, ..Default::default() };
        assert_eq!(c.adc_cycles_per_tile(), 48);
    }

    #[test]
    fn vertical_tiling_for_wide_dk() {
        let c = ArchConfig { d_k: 128, ..Default::default() };
        assert_eq!(c.v_tiles(), 2);
        assert_eq!(c.tiles(), 128);
    }
}
