//! CAMformer microarchitecture (Sec. III): the three pipelined stages —
//! association, normalization, contextualization — plus the bitonic
//! sorter networks, the LUT softmax engine and the pipeline/throughput
//! model behind Figs. 7 and 9.
//!
//! Everything here is *cycle-annotated functional* simulation: each stage
//! both computes its real outputs (validated against `accuracy::functional`)
//! and reports the cycle counts the pipeline model aggregates.

pub mod association;
pub mod bitonic;
pub mod config;
pub mod contextualization;
pub mod dse;
pub mod normalization;
pub mod pipeline;
pub mod softmax;

pub use config::ArchConfig;
pub use pipeline::{PipelineModel, StageLatency};
