//! Systematic design-space exploration (Sec. IV-B).
//!
//! The paper balances stage throughput "in our design space exploration";
//! this module makes that search reproducible: grid-search the co-design
//! axes (CAM height, ADC sharing, MAC count, stage-1 k), evaluate each
//! point's throughput / area / energy / weighted recall, and return the
//! Pareto-optimal set.

use super::config::ArchConfig;
use super::contextualization::ContextualizationStage;
use super::pipeline::PipelineModel;
use crate::accuracy::recall;
use crate::cost::system::{CamformerCost, SystemConfig};
use crate::util::rng::Rng;

/// One evaluated design point.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    pub cam_h: usize,
    pub adcs_per_array: usize,
    pub mac_units: usize,
    pub stage1_k: usize,
    pub throughput_qry_per_ms: f64,
    pub area_mm2: f64,
    pub energy_eff_qry_per_mj: f64,
    pub weighted_recall: f64,
    /// Stall fraction under coarse pipelining (0 = perfectly balanced).
    pub stall_frac: f64,
}

impl DesignPoint {
    /// `other` dominates when it is at least as good on all four objective
    /// axes (throughput, area, efficiency, recall) and better on one.
    pub fn dominated_by(&self, other: &DesignPoint) -> bool {
        let ge = other.throughput_qry_per_ms >= self.throughput_qry_per_ms
            && other.area_mm2 <= self.area_mm2
            && other.energy_eff_qry_per_mj >= self.energy_eff_qry_per_mj
            && other.weighted_recall >= self.weighted_recall;
        let gt = other.throughput_qry_per_ms > self.throughput_qry_per_ms
            || other.area_mm2 < self.area_mm2
            || other.energy_eff_qry_per_mj > self.energy_eff_qry_per_mj
            || other.weighted_recall > self.weighted_recall;
        ge && gt
    }
}

/// Evaluate one configuration (n fixed to the Table II workload).
pub fn evaluate(
    n: usize,
    cam_h: usize,
    adcs: usize,
    macs: usize,
    stage1_k: usize,
    rng: &mut Rng,
) -> DesignPoint {
    let arch = ArchConfig {
        n,
        cam_h,
        adcs_per_array: adcs,
        mac_units: macs,
        stage1_k,
        ..Default::default()
    };
    let pm = PipelineModel { cfg: arch, fine_grained: true };
    let lat = pm.latencies();
    let sys = SystemConfig {
        n,
        cam_h,
        mac_units: macs,
        stage1_k,
        adcs_per_array: adcs,
        ..Default::default()
    };
    let cost = CamformerCost::evaluate(&sys);
    let wr = recall::monte_carlo_weighted_recall_realistic(n, 8, cam_h, stage1_k, 32, 60, rng);
    DesignPoint {
        cam_h,
        adcs_per_array: adcs,
        mac_units: macs,
        stage1_k,
        throughput_qry_per_ms: pm.throughput_qry_per_ms(),
        area_mm2: cost.area_mm2,
        energy_eff_qry_per_mj: cost.energy_eff_qry_per_mj,
        weighted_recall: wr,
        stall_frac: lat.stall_cycles() as f64 / (3 * lat.bottleneck()) as f64,
    }
}

/// Grid search over the co-design axes; returns all evaluated points.
/// Recall is evaluated with a per-(cam_h, k1) deterministic seed (common
/// random numbers), so configurations that share the selection geometry
/// tie exactly instead of differing by Monte-Carlo noise.
pub fn sweep(n: usize, seed: u64) -> Vec<DesignPoint> {
    let mut out = Vec::new();
    for &cam_h in &[8usize, 16, 32] {
        for &adcs in &[1usize, 2, 4] {
            for &macs in &[1usize, 4, 8, 16] {
                for &k1 in &[1usize, 2, 4] {
                    let mut rng = Rng::new(seed ^ (cam_h as u64 * 131 + k1 as u64));
                    out.push(evaluate(n, cam_h, adcs, macs, k1, &mut rng));
                }
            }
        }
    }
    out
}

/// Non-dominated subset of a sweep.
pub fn pareto(points: &[DesignPoint]) -> Vec<DesignPoint> {
    points
        .iter()
        .filter(|p| !points.iter().any(|q| p.dominated_by(q)))
        .cloned()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_point_pareto_within_shared_sar_slice() {
        // Within the paper's own structural choices (one shared SAR,
        // 8 MACs), the 16-high / k1=2 point must be Pareto-optimal over
        // the remaining axes (CAM height, stage-1 k).
        //
        // Across *all* axes it is NOT optimal in our model: duplicating
        // the cheap SAR doubles association cadence almost for free, and
        // with association ADC-bound at n=1024 the 8 MACs are headroom,
        // not necessity. That divergence from the paper's "8 MACs
        // required" DSE narrative is documented in EXPERIMENTS.md (our
        // shared-SAR serialization model makes association relatively
        // slower than theirs).
        let pts = sweep(1024, 42);
        let slice: Vec<DesignPoint> = pts
            .iter()
            .filter(|p| p.adcs_per_array == 1 && p.mac_units == 8)
            .cloned()
            .collect();
        let paper = slice
            .iter()
            .find(|p| p.cam_h == 16 && p.stage1_k == 2)
            .unwrap()
            .clone();
        for q in &slice {
            assert!(
                !paper.dominated_by(q),
                "paper point dominated by cam_h={} k1={}",
                q.cam_h,
                q.stage1_k
            );
        }
    }

    #[test]
    fn extra_adcs_cost_area() {
        let mut rng = Rng::new(47);
        let one = evaluate(1024, 16, 1, 8, 2, &mut rng);
        let four = evaluate(1024, 16, 4, 8, 2, &mut rng);
        assert!(four.area_mm2 > one.area_mm2);
    }

    #[test]
    fn pareto_set_is_nonempty_and_nondominated() {
        let pts = sweep(512, 43);
        let front = pareto(&pts);
        assert!(!front.is_empty() && front.len() < pts.len());
        for a in &front {
            assert!(!front.iter().any(|b| a.dominated_by(b)));
        }
    }

    #[test]
    fn more_adcs_trade_area_for_throughput() {
        let mut rng = Rng::new(44);
        let one = evaluate(1024, 16, 1, 8, 2, &mut rng);
        let four = evaluate(1024, 16, 4, 8, 2, &mut rng);
        assert!(four.throughput_qry_per_ms > one.throughput_qry_per_ms * 2.0);
    }

    #[test]
    fn smaller_k1_never_improves_recall() {
        let mut rng = Rng::new(45);
        let k1 = evaluate(1024, 16, 1, 8, 1, &mut rng);
        let k4 = evaluate(1024, 16, 1, 8, 4, &mut rng);
        assert!(k4.weighted_recall >= k1.weighted_recall);
    }

    #[test]
    fn stall_fraction_bounded() {
        for p in sweep(256, 46) {
            assert!((0.0..1.0).contains(&p.stall_frac));
        }
    }
}
