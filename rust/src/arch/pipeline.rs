//! Pipeline model (Sec. III-C2/C3, Figs. 7 & 9): fine-grained pipelining
//! within stages, coarse-grained pipelining across stages, stall
//! accounting, per-stage throughput, and the full functional simulation of
//! one query through all three stages.

use super::association::AssociationStage;
use super::config::ArchConfig;
use super::contextualization::ContextualizationStage;
use super::normalization::NormalizationStage;

/// Per-stage latency for one query \[cycles\].
#[derive(Clone, Copy, Debug)]
pub struct StageLatency {
    pub association: u64,
    pub normalization: u64,
    pub contextualization: u64,
}

impl StageLatency {
    pub fn bottleneck(&self) -> u64 {
        self.association
            .max(self.normalization)
            .max(self.contextualization)
    }

    pub fn total(&self) -> u64 {
        self.association + self.normalization + self.contextualization
    }

    /// Per-query stall (no-op) cycles under coarse-grained pipelining:
    /// each stage idles for (bottleneck - its latency) (Fig. 7 right).
    pub fn stall_cycles(&self) -> u64 {
        let b = self.bottleneck();
        (b - self.association) + (b - self.normalization) + (b - self.contextualization)
    }
}

/// The pipeline-level performance model.
#[derive(Clone, Copy, Debug)]
pub struct PipelineModel {
    pub cfg: ArchConfig,
    pub fine_grained: bool,
}

impl PipelineModel {
    pub fn paper() -> Self {
        PipelineModel {
            cfg: ArchConfig::default(),
            fine_grained: true,
        }
    }

    /// Stage latencies for one query.
    pub fn latencies(&self) -> StageLatency {
        let assoc_stage = AssociationStage::new(self.cfg);
        let norm_stage = NormalizationStage::new(self.cfg);
        let ctx_stage = ContextualizationStage::new(self.cfg);

        let association = if self.fine_grained {
            // cadence-dominated (see AssociationStage::run's model)
            let cadence = self
                .cfg
                .adc_cycles_per_tile()
                .max(self.cfg.cam_phases)
                .max(tile_sorter_depth(self.cfg.cam_h));
            cadence * self.cfg.tiles() as u64
        } else {
            assoc_stage.cycles_unpipelined()
        };

        let passes = (self.cfg.candidates() as u64).div_ceil(32);
        let normalization = if self.fine_grained {
            passes * 21
                + super::softmax::SoftmaxEngine::new(self.cfg.d_k).latency_cycles(
                    self.cfg.final_k,
                    self.cfg.t_div,
                    true,
                )
        } else {
            norm_stage.cycles_unpipelined(self.cfg.final_k, passes)
        };

        let contextualization = if self.fine_grained {
            ctx_stage.cycles_for(self.cfg.final_k)
        } else {
            // unpipelined MACs: one MAC at a time regardless of units
            (self.cfg.final_k * self.cfg.d_v) as u64 + 8
        };

        StageLatency {
            association,
            normalization,
            contextualization,
        }
    }

    /// Single-query end-to-end latency \[ns\] (stages in series).
    pub fn query_latency_ns(&self) -> f64 {
        self.latencies().total() as f64 * self.cfg.cycle_ns()
    }

    /// Steady-state throughput [queries/ms] with coarse-grained pipelining
    /// (cadence = bottleneck stage).
    pub fn throughput_qry_per_ms(&self) -> f64 {
        let cadence_ns = self.latencies().bottleneck() as f64 * self.cfg.cycle_ns();
        1e6 / cadence_ns
    }

    /// Throughput without coarse-grained pipelining (stages serialize).
    pub fn throughput_unpiped_qry_per_ms(&self) -> f64 {
        1e6 / self.query_latency_ns()
    }

    /// Per-stage standalone throughput [queries/ms] (Fig. 9's bars).
    pub fn stage_throughputs(&self) -> [(&'static str, f64); 3] {
        let l = self.latencies();
        let f = |c: u64| 1e6 / (c as f64 * self.cfg.cycle_ns());
        [
            ("association", f(l.association)),
            ("normalization", f(l.normalization)),
            ("contextualization", f(l.contextualization)),
        ]
    }

    /// DSE (Sec. IV-B): smallest MAC count whose contextualization
    /// throughput matches or exceeds the association stage's.
    pub fn balance_mac_units(&self) -> usize {
        let assoc = self.latencies().association;
        for units in 1..=64usize {
            let cfg = ArchConfig { mac_units: units, ..self.cfg };
            let ctx = ContextualizationStage::new(cfg).cycles_for(cfg.final_k);
            if ctx <= assoc {
                return units;
            }
        }
        64
    }
}

fn tile_sorter_depth(width: usize) -> u64 {
    let p = width.next_power_of_two().trailing_zeros() as u64;
    p * (p + 1) / 2
}

/// Full functional simulation of one query through the three stages.
/// Returns (attention output, per-stage latencies).
pub fn simulate_query(
    cfg: ArchConfig,
    q: &[f32],
    k: &[f32],
    v: &[f32],
) -> (Vec<f32>, StageLatency) {
    let qb: Vec<bool> = q.iter().map(|&x| x >= 0.0).collect();
    let keys: Vec<Vec<bool>> = (0..cfg.n)
        .map(|r| k[r * cfg.d_k..(r + 1) * cfg.d_k].iter().map(|&x| x >= 0.0).collect())
        .collect();

    let mut assoc = AssociationStage::new(cfg);
    let a = assoc.run(&qb, &keys);
    let norm = NormalizationStage::new(cfg).run(&a.candidates);
    let ctx = ContextualizationStage::new(cfg).run(&norm.selected, &norm.probs, v);

    (
        ctx.output,
        StageLatency {
            association: a.cycles,
            normalization: norm.cycles,
            contextualization: ctx.cycles,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::functional::{self, AttnConfig};
    use crate::util::rng::Rng;

    #[test]
    fn association_is_bottleneck_at_paper_point() {
        let m = PipelineModel::paper();
        let l = m.latencies();
        assert!(l.association > l.normalization);
        assert!(l.association > l.contextualization);
    }

    #[test]
    fn paper_throughput_band() {
        // Table II: 191 qry/ms at 1 GHz (our ADC-serialization model gives
        // the same order: 96 cyc/tile x 64 tiles = 6144 cyc => 163 qry/ms)
        let t = PipelineModel::paper().throughput_qry_per_ms();
        assert!(t > 120.0 && t < 260.0, "throughput {t}");
    }

    #[test]
    fn coarse_pipelining_multiplies_throughput() {
        let m = PipelineModel::paper();
        let piped = m.throughput_qry_per_ms();
        let serial = m.throughput_unpiped_qry_per_ms();
        assert!(piped > serial * 1.05, "piped {piped} vs serial {serial}");
    }

    #[test]
    fn fine_grained_pipelining_helps_every_stage() {
        let fine = PipelineModel { cfg: ArchConfig::default(), fine_grained: true }.latencies();
        let coarse = PipelineModel { cfg: ArchConfig::default(), fine_grained: false }.latencies();
        assert!(fine.association < coarse.association);
        assert!(fine.normalization < coarse.normalization);
        assert!(fine.contextualization < coarse.contextualization);
    }

    #[test]
    fn dse_lands_on_paper_mac_count() {
        // Sec. IV-B: "the contextualization stage requires 8 parallel MAC
        // units to match the association stage's throughput"
        let m = PipelineModel::paper();
        let units = m.balance_mac_units();
        assert!(units <= 8, "needed {units} MACs (paper: 8 suffices)");
        assert!(units >= 1);
    }

    #[test]
    fn stall_accounting_consistent() {
        let l = PipelineModel::paper().latencies();
        assert_eq!(
            l.stall_cycles(),
            3 * l.bottleneck() - l.total()
        );
    }

    #[test]
    fn simulate_query_matches_functional_model() {
        let cfg = ArchConfig { n: 256, ..Default::default() };
        let mut rng = Rng::new(96);
        let q = rng.normal_vec(64);
        let k = rng.normal_vec(256 * 64);
        let v = rng.normal_vec(256 * 64);
        let (out, lat) = simulate_query(cfg, &q, &k, &v);
        let want = functional::camformer_attention(&q, &k, &v, &AttnConfig::paper(256, 64));
        assert_eq!(out.len(), 64);
        for (i, (g, w)) in out.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 0.05,
                "dim {i}: arch sim {g} vs functional {w}"
            );
        }
        assert!(lat.association > 0 && lat.normalization > 0 && lat.contextualization > 0);
    }

    #[test]
    fn longer_sequences_scale_association_linearly() {
        let t1 = PipelineModel {
            cfg: ArchConfig { n: 1024, ..Default::default() },
            fine_grained: true,
        }
        .latencies()
        .association;
        let t2 = PipelineModel {
            cfg: ArchConfig { n: 2048, ..Default::default() },
            fine_grained: true,
        }
        .latencies()
        .association;
        assert_eq!(t2, 2 * t1);
    }
}
