//! V-prefetch engine (Sec. III-C4): every stage-1 Top-2 hit sends its key
//! index to the memory controller, which fetches the corresponding V row
//! ahead of the contextualization stage. The pipeline hides the DRAM
//! latency when prefetches are issued at least one stage-latency early.

use super::channel::{DramConfig, HbmChannel};

/// Prefetch accounting for one query's worth of V fetches.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefetchStats {
    pub issued: usize,
    pub bytes: u64,
    /// Latest completion time \[ns\] relative to issue start.
    pub last_done_ns: f64,
    /// How much of the fetch latency the pipeline could NOT hide \[ns\]
    /// (0 = fully hidden).
    pub exposed_ns: f64,
}

/// The MC/DMA-driven prefetcher: maps key indices to V-row addresses and
/// schedules them on an HBM channel.
pub struct PrefetchEngine {
    pub channel: HbmChannel,
    /// Bytes per V row (d_v x 16-bit BF16; paper: 64 x 2 B = 128 B).
    pub v_row_bytes: usize,
    /// Base address of the V tensor.
    pub v_base: u64,
}

impl PrefetchEngine {
    pub fn new(cfg: DramConfig, d_v: usize) -> Self {
        PrefetchEngine {
            channel: HbmChannel::new(cfg),
            v_row_bytes: d_v * 2,
            v_base: 0,
        }
    }

    /// Issue prefetches for `indices` starting at `now_ns`; the consumer
    /// (contextualization) will need the data at `deadline_ns`.
    pub fn prefetch(&mut self, now_ns: f64, indices: &[usize], deadline_ns: f64) -> PrefetchStats {
        let mut stats = PrefetchStats {
            issued: indices.len(),
            ..Default::default()
        };
        let mut t = now_ns;
        for &idx in indices {
            let addr = self.v_base + (idx * self.v_row_bytes) as u64;
            let (done, _) = self.channel.read(t, addr, self.v_row_bytes);
            t = done;
            stats.last_done_ns = stats.last_done_ns.max(done);
            stats.bytes += self.v_row_bytes as u64;
        }
        stats.exposed_ns = (stats.last_done_ns - deadline_ns).max(0.0);
        stats
    }

    /// Required sustained bandwidth [GB/s] for a target query rate:
    /// k V-rows per query (the paper's ~50 GB/s check).
    pub fn required_gbps(&self, k: usize, queries_per_s: f64) -> f64 {
        k as f64 * self.v_row_bytes as f64 * queries_per_s / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper workload: k=32 V rows of 128 B per query.
    fn engine() -> PrefetchEngine {
        PrefetchEngine::new(DramConfig::default(), 64)
    }

    #[test]
    fn contiguous_topk_fetch_is_fast() {
        let mut e = engine();
        // top-32 indices spread over a 1024-key memory: worst case 32
        // different pages — but V is laid out contiguously so indices
        // within 64 rows share a page
        let indices: Vec<usize> = (0..32).map(|i| i * 2).collect(); // within 1 page
        let stats = e.prefetch(0.0, &indices, f64::MAX);
        assert_eq!(stats.issued, 32);
        assert_eq!(stats.bytes, 32 * 128);
        assert_eq!(e.channel.row_misses, 1);
    }

    #[test]
    fn pipeline_hides_latency_at_association_cadence() {
        // association stage takes ~64 tiles x ADC serialization; the paper
        // claims one t_RC per 64 scores fully hides. With a 2 us deadline
        // (one query's association latency) nothing should be exposed.
        let mut e = engine();
        let indices: Vec<usize> = (0..32).map(|i| i * 31 % 1024).collect();
        let stats = e.prefetch(0.0, &indices, 2000.0);
        assert_eq!(stats.exposed_ns, 0.0, "exposed {} ns", stats.exposed_ns);
    }

    #[test]
    fn scattered_indices_cost_more_misses() {
        let mut near = engine();
        let near_idx: Vec<usize> = (0..32).collect();
        near.prefetch(0.0, &near_idx, f64::MAX);

        let mut far = engine();
        // stride of 64 rows = one page per index
        let far_idx: Vec<usize> = (0..32).map(|i| i * 64).collect();
        far.prefetch(0.0, &far_idx, f64::MAX);

        assert!(far.channel.row_misses > near.channel.row_misses);
    }

    #[test]
    fn paper_bandwidth_estimate() {
        // Table II: CAMformer at 191 qry/ms => 191k qry/s x 32 rows x 128 B
        // ≈ 0.78 GB/s per head; 16 heads across 16 channels ≈ 12.5 GB/s
        // total, well under the ~50 GB/s budget the paper quotes and far
        // under a channel's 64 GB/s.
        let e = engine();
        let per_head = e.required_gbps(32, 191_000.0);
        assert!(per_head < 1.0, "{per_head} GB/s");
        assert!(16.0 * per_head < 50.0);
    }

    #[test]
    fn exposure_when_deadline_tight() {
        let mut e = engine();
        let indices: Vec<usize> = (0..32).map(|i| i * 64).collect(); // all misses
        let stats = e.prefetch(0.0, &indices, 10.0);
        assert!(stats.exposed_ns > 0.0);
    }
}
