//! One HBM3 channel: banks, open rows (pages), t_RC timing, bandwidth and
//! access energy accounting.

/// HBM3 channel timing/geometry (JESD238 ballpark; t_RC from [33]).
#[derive(Clone, Copy, Debug)]
pub struct DramConfig {
    /// Row cycle time \[ns\] — min time between ACT of the same bank.
    pub t_rc_ns: f64,
    /// CAS latency for an open-row hit \[ns\].
    pub t_cas_ns: f64,
    /// Page (row buffer) size \[bytes\]. Paper: 8 KB.
    pub page_bytes: usize,
    /// Banks per channel.
    pub banks: usize,
    /// Peak channel bandwidth [GB/s]. One HBM3 channel: ~64 GB/s
    /// (signalling 6.4 Gb/s x 64 bits wide / 8).
    pub peak_gbps: f64,
    /// Access energy [nJ/bit] (Kawata et al. [43]: 2.33 nJ/bit... the
    /// paper uses this figure for DRAM energy).
    pub energy_nj_per_bit: f64,
}

impl Default for DramConfig {
    fn default() -> Self {
        DramConfig {
            t_rc_ns: 48.0,
            t_cas_ns: 16.0,
            page_bytes: 8192,
            banks: 16,
            peak_gbps: 64.0,
            energy_nj_per_bit: 2.33,
        }
    }
}

/// Outcome of one access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessKind {
    /// Open-row hit: data served at CAS latency.
    RowHit,
    /// Row miss: precharge + activate, full t_RC exposure.
    RowMiss,
}

/// Simple open-page channel model.
#[derive(Clone, Debug)]
pub struct HbmChannel {
    pub cfg: DramConfig,
    /// Open row id per bank (None = precharged).
    open_rows: Vec<Option<u64>>,
    /// Earliest time each bank can activate again \[ns\].
    bank_ready_ns: Vec<f64>,
    /// Running totals.
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub busy_ns: f64,
}

impl HbmChannel {
    pub fn new(cfg: DramConfig) -> Self {
        HbmChannel {
            open_rows: vec![None; cfg.banks],
            bank_ready_ns: vec![0.0; cfg.banks],
            bytes_read: 0,
            bytes_written: 0,
            row_hits: 0,
            row_misses: 0,
            busy_ns: 0.0,
            cfg,
        }
    }

    /// Map a byte address to (bank, row).
    fn locate(&self, addr: u64) -> (usize, u64) {
        let page = addr / self.cfg.page_bytes as u64;
        ((page % self.cfg.banks as u64) as usize, page / self.cfg.banks as u64)
    }

    /// Read `bytes` at `addr` starting no earlier than `now_ns`.
    /// Returns (completion time \[ns\], access kind).
    pub fn read(&mut self, now_ns: f64, addr: u64, bytes: usize) -> (f64, AccessKind) {
        self.bytes_read += bytes as u64;
        self.access(now_ns, addr, bytes)
    }

    /// Write `bytes` at `addr` starting no earlier than `now_ns` (the KV
    /// spill-tier writeback path). Same open-page timing as a read — the
    /// simple model charges symmetric column access — tallied separately
    /// so read bandwidth claims stay clean.
    pub fn write(&mut self, now_ns: f64, addr: u64, bytes: usize) -> (f64, AccessKind) {
        self.bytes_written += bytes as u64;
        self.access(now_ns, addr, bytes)
    }

    /// The shared open-page access path: bank/row decode, hit/miss timing,
    /// bank-ready bookkeeping. Byte tallies belong to `read`/`write`.
    fn access(&mut self, now_ns: f64, addr: u64, bytes: usize) -> (f64, AccessKind) {
        let (bank, row) = self.locate(addr);
        let transfer_ns = bytes as f64 / (self.cfg.peak_gbps * 1e9) * 1e9;

        let kind = if self.open_rows[bank] == Some(row) {
            self.row_hits += 1;
            AccessKind::RowHit
        } else {
            self.row_misses += 1;
            self.open_rows[bank] = Some(row);
            AccessKind::RowMiss
        };
        let start = now_ns.max(self.bank_ready_ns[bank]);
        let latency = match kind {
            AccessKind::RowHit => self.cfg.t_cas_ns,
            AccessKind::RowMiss => self.cfg.t_rc_ns,
        };
        let done = start + latency + transfer_ns;
        self.bank_ready_ns[bank] = match kind {
            // t_RC gates successive activates of the same bank
            AccessKind::RowMiss => start + self.cfg.t_rc_ns,
            AccessKind::RowHit => start + transfer_ns,
        };
        self.busy_ns += latency + transfer_ns;
        (done, kind)
    }

    /// Total DRAM access energy so far \[J\]: reads and writes at the same
    /// per-bit figure [43].
    pub fn energy_j(&self) -> f64 {
        (self.bytes_read + self.bytes_written) as f64 * 8.0 * self.cfg.energy_nj_per_bit * 1e-9
    }

    /// Achieved bandwidth over a window [GB/s].
    pub fn achieved_gbps(&self, window_ns: f64) -> f64 {
        if window_ns <= 0.0 {
            return 0.0;
        }
        self.bytes_read as f64 / window_ns
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_access_misses_then_hits_within_page() {
        let mut ch = HbmChannel::new(DramConfig::default());
        let (_, k1) = ch.read(0.0, 0, 128);
        let (_, k2) = ch.read(100.0, 128, 128);
        assert_eq!(k1, AccessKind::RowMiss);
        assert_eq!(k2, AccessKind::RowHit);
    }

    #[test]
    fn page_boundary_misses() {
        let mut ch = HbmChannel::new(DramConfig::default());
        ch.read(0.0, 0, 128);
        let (_, k) = ch.read(100.0, 8192 * 16, 128); // same bank, next row
        assert_eq!(k, AccessKind::RowMiss);
    }

    #[test]
    fn different_banks_independent() {
        let mut ch = HbmChannel::new(DramConfig::default());
        let (t1, _) = ch.read(0.0, 0, 128);
        let (t2, _) = ch.read(0.0, 8192, 128); // next page -> next bank
        // both start at 0 (no bank conflict): completion within one t_RC+xfer
        assert!(t1 < 50.0 + 1.0 && t2 < 50.0 + 1.0);
    }

    #[test]
    fn same_bank_activates_gated_by_trc() {
        let cfg = DramConfig::default();
        let mut ch = HbmChannel::new(cfg);
        ch.read(0.0, 0, 128);
        // same bank, different row immediately after
        let (t2, k2) = ch.read(0.0, 8192 * 16, 128);
        assert_eq!(k2, AccessKind::RowMiss);
        assert!(t2 >= 2.0 * cfg.t_rc_ns - 1e-9, "t2={t2}");
    }

    #[test]
    fn paper_v_fetch_claim_one_trc_per_64_rows() {
        // V rows are 128 B; 64 rows = one 8 KB page = one t_RC (Sec III-C4)
        let cfg = DramConfig::default();
        let mut ch = HbmChannel::new(cfg);
        let mut t = 0.0;
        for row in 0..64u64 {
            let (done, kind) = ch.read(t, row * 128, 128);
            t = done;
            if row == 0 {
                assert_eq!(kind, AccessKind::RowMiss);
            } else {
                assert_eq!(kind, AccessKind::RowHit);
            }
        }
        assert_eq!(ch.row_misses, 1);
        // total: one t_RC + 64 transfers + 63 CAS ≈ well under 2 us
        assert!(t < 2000.0, "64-row fetch took {t} ns");
    }

    #[test]
    fn bandwidth_requirement_feasible() {
        // paper: ~50 GB/s needed; single channel peak is 64 GB/s
        let cfg = DramConfig::default();
        assert!(cfg.peak_gbps > 50.0);
    }

    #[test]
    fn energy_tracks_bits() {
        let mut ch = HbmChannel::new(DramConfig::default());
        ch.read(0.0, 0, 1000);
        let expect = 1000.0 * 8.0 * 2.33e-9;
        assert!((ch.energy_j() - expect).abs() < 1e-15);
    }

    #[test]
    fn writes_share_page_timing_and_count_separately() {
        let mut ch = HbmChannel::new(DramConfig::default());
        let (_, k1) = ch.write(0.0, 0, 256);
        let (_, k2) = ch.read(100.0, 256, 128); // same page the write opened
        assert_eq!(k1, AccessKind::RowMiss);
        assert_eq!(k2, AccessKind::RowHit);
        assert_eq!(ch.bytes_written, 256);
        assert_eq!(ch.bytes_read, 128);
        // energy charges both directions at 2.33 nJ/bit
        let expect = (256.0 + 128.0) * 8.0 * 2.33e-9;
        assert!((ch.energy_j() - expect).abs() < 1e-15);
    }

    #[test]
    fn hit_rate_statistics() {
        let mut ch = HbmChannel::new(DramConfig::default());
        let mut t = 0.0;
        for i in 0..64 {
            let (d, _) = ch.read(t, i * 128, 128);
            t = d;
        }
        assert!(ch.hit_rate() > 0.95);
    }
}
