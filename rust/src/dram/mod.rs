//! HBM3 DRAM model (Sec. III-C4).
//!
//! The paper lays V out contiguously: rows of 64 x 16 b (128 B), so 64 rows
//! fit an 8 KB page; with no interleaving one t_RC (48 ns, HBM3) serves
//! each set of 64 scores, the pipeline hides DRAM latency entirely, and the
//! required ~50 GB/s fits a single HBM3 channel. This module models pages,
//! banks, row cycles and bandwidth so the prefetch claims are checkable,
//! plus the 2.33 nJ/bit access energy [43] the system energy model uses.

pub mod channel;
pub mod prefetch;

pub use channel::{DramConfig, HbmChannel};
pub use prefetch::{PrefetchEngine, PrefetchStats};
