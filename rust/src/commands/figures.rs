//! Figure-regeneration subcommands (Figs. 3a/3b/5/7/8/9/10 + DSE).

use anyhow::Result;

use camformer::arch::config::ArchConfig;
use camformer::arch::pipeline::PipelineModel;
use camformer::baselines::industry;
use camformer::camcircuit::cell::CellParams;
use camformer::camcircuit::energy::EnergyModel;
use camformer::camcircuit::matchline::Matchline;
use camformer::camcircuit::pvt;
use camformer::cost::breakdown;
use camformer::cost::system::SystemConfig;
use camformer::util::cli::Args;
use camformer::util::table::{Series, Table};

/// Fig. 3a: matchline voltage traces for varying partial matches (1x10).
pub fn fig3a(_args: &Args) -> Result<()> {
    let params = CellParams::default();
    let width = 10usize;
    let bits = vec![true; width];
    let ml = Matchline::new(&bits, &params);
    let mut cols: Vec<String> = vec!["t_ns".into()];
    for m in 0..=width {
        cols.push(format!("V(m={m})"));
    }
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut series = Series::new(
        "Fig 3a — matchline voltage vs time, 1x10 BA-CAM (V)",
        &col_refs,
    );
    for step in 0..=20 {
        let t_ns = step as f64 * 0.05;
        let mut row = vec![t_ns];
        for m in 0..=width {
            let query: Vec<bool> = (0..width).map(|i| i < m).collect();
            row.push(ml.transient(&query, &params, t_ns));
        }
        series.point(&row);
    }
    series.print();
    println!("\nsettled voltages are linear in match count (paper: linear, delay-free sensing):");
    for m in 0..=width {
        let query: Vec<bool> = (0..width).map(|i| i < m).collect();
        println!("  m={m:2}  V={:.4}", ml.settled_voltage(&query, &params));
    }
    Ok(())
}

/// Fig. 3b: PVT deviation across corners for the 16x64 array.
pub fn fig3b(args: &Args) -> Result<()> {
    let sigma = args.get_f64("sigma", 0.014);
    let trials = args.get_usize("trials", 300);
    let seed = args.get_u64("seed", 42);
    let pts = pvt::fig3b_sweep(64, sigma, trials, seed);
    let mut t = Table::new(
        &format!("Fig 3b — PVT deviation, 16x64 BA-CAM, sigma={:.1}%", sigma * 100.0),
        &["corner", "matches", "mean err %", "max dev %"],
    );
    for p in &pts {
        t.row(&[
            p.corner.name().to_string(),
            p.matches.to_string(),
            format!("{:.3}", p.mean_err_pct),
            format!("{:.3}", p.max_dev_pct),
        ]);
    }
    t.print();
    let mean_all: f64 =
        pts.iter().map(|p| p.mean_err_pct).sum::<f64>() / pts.len() as f64;
    let worst = pts.iter().map(|p| p.max_dev_pct).fold(0.0, f64::max);
    println!("\noverall mean error {mean_all:.2}% (paper: 1.12%), worst deviation {worst:.2}% (paper: <=5.05%)");
    Ok(())
}

/// Fig. 5: per-op energy vs amortisation dimension M.
pub fn fig5(_args: &Args) -> Result<()> {
    let model = EnergyModel::new(16, 64);
    let mut s = Series::new(
        "Fig 5 — BA-CAM per-op energy vs M (fJ/op)",
        &["M", "per_op_fJ", "search_only_bound_fJ", "total_bound_fJ"],
    );
    for (m, fj) in model.fig5_sweep(14) {
        s.point(&[
            m as f64,
            fj,
            model.search_only_bound() * 1e15,
            model.total_bound() * 1e15,
        ]);
    }
    s.print();
    Ok(())
}

/// Fig. 7: pipelining timelines and stall accounting.
pub fn fig7(_args: &Args) -> Result<()> {
    let fine = PipelineModel { cfg: ArchConfig::default(), fine_grained: true };
    let coarse = PipelineModel { cfg: ArchConfig::default(), fine_grained: false };

    let lf = fine.latencies();
    let lc = coarse.latencies();
    let mut t = Table::new(
        "Fig 7 — stage latencies [cycles] with/without fine-grained pipelining",
        &["stage", "fine-grained", "unpipelined", "speedup"],
    );
    for (name, f, c) in [
        ("association", lf.association, lc.association),
        ("normalization", lf.normalization, lc.normalization),
        ("contextualization", lf.contextualization, lc.contextualization),
    ] {
        t.row(&[
            name.to_string(),
            f.to_string(),
            c.to_string(),
            format!("{:.2}x", c as f64 / f as f64),
        ]);
    }
    t.print();

    println!("\ncoarse-grained pipelining (Fig 7 right):");
    println!("  bottleneck stage cadence : {} cycles", lf.bottleneck());
    println!("  per-query total latency  : {} cycles", lf.total());
    println!("  no-op (stall) per query  : {} cycles", lf.stall_cycles());
    println!(
        "  pipelined throughput     : {:.1} qry/ms vs serial {:.1} qry/ms",
        fine.throughput_qry_per_ms(),
        fine.throughput_unpiped_qry_per_ms()
    );
    Ok(())
}

/// Fig. 8: energy and area breakdown.
pub fn fig8(_args: &Args) -> Result<()> {
    let cfg = SystemConfig::default();
    let mut t = Table::new(
        "Fig 8 (left) — per-query energy breakdown",
        &["component", "nJ/query", "%"],
    );
    for c in breakdown::energy_breakdown(&cfg) {
        t.row(&[
            c.name.to_string(),
            format!("{:.2}", c.value * 1e9),
            format!("{:.1}", c.pct),
        ]);
    }
    t.print();

    let mut t2 = Table::new(
        "Fig 8 — energy by pipeline stage",
        &["stage", "nJ/query", "%"],
    );
    for c in breakdown::stage_energy_breakdown(&cfg) {
        t2.row(&[
            c.name.to_string(),
            format!("{:.2}", c.value * 1e9),
            format!("{:.1}", c.pct),
        ]);
    }
    t2.print();

    let mut t3 = Table::new(
        "Fig 8 (right) — core area breakdown",
        &["component", "mm^2", "%"],
    );
    for c in breakdown::area_breakdown(&cfg) {
        t3.row(&[
            c.name.to_string(),
            format!("{:.4}", c.value),
            format!("{:.1}", c.pct),
        ]);
    }
    t3.print();
    println!("\npaper reads: energy — contextualization 57%, V-SRAM 31%, K-SRAM 20%, MACs 26%, BA-CAM 12%;");
    println!("             area   — SRAM 42%, Top-32 26%.");
    Ok(())
}

/// Fig. 9: per-stage throughput with/without optimisations.
pub fn fig9(_args: &Args) -> Result<()> {
    let mut t = Table::new(
        "Fig 9 — per-stage throughput [qry/ms] at 1 GHz",
        &["configuration", "association", "normalization", "contextualization", "pipeline"],
    );
    let configs: Vec<(&str, ArchConfig, bool)> = vec![
        ("baseline (no fine pipelining, 1 MAC)",
         ArchConfig { mac_units: 1, ..Default::default() }, false),
        ("+ fine-grained pipelining (1 MAC)",
         ArchConfig { mac_units: 1, ..Default::default() }, true),
        ("+ 8 parallel MACs (paper DSE point)",
         ArchConfig { mac_units: 8, ..Default::default() }, true),
        ("+ 2 ADCs per array (beyond-paper ablation)",
         ArchConfig { mac_units: 8, adcs_per_array: 2, ..Default::default() }, true),
    ];
    for (name, cfg, fine) in configs {
        let m = PipelineModel { cfg, fine_grained: fine };
        let st = m.stage_throughputs();
        t.row(&[
            name.to_string(),
            format!("{:.1}", st[0].1),
            format!("{:.1}", st[1].1),
            format!("{:.1}", st[2].1),
            format!("{:.1}", m.throughput_qry_per_ms()),
        ]);
    }
    t.print();
    println!("\npaper: normalization has slack; 8 MACs balance contextualization against association.");
    Ok(())
}

/// Fig. 10: Pareto frontier.
pub fn fig10(_args: &Args) -> Result<()> {
    let pts = industry::fig10_points();
    let front = industry::pareto_frontier(&pts);
    let mut t = Table::new(
        "Fig 10 — effective attention perf/W and perf/area (45 nm plane)",
        &["point", "GOPS/W", "GOPS/mm^2", "class", "on frontier"],
    );
    for p in &pts {
        let on = front.iter().any(|f| f.name == p.name);
        t.row(&[
            p.name.clone(),
            format!("{:.1}", p.gops_per_w),
            format!("{:.1}", p.gops_per_mm2),
            if p.industry { "industry" } else { "academic" }.to_string(),
            if on { "*" } else { "" }.to_string(),
        ]);
    }
    t.print();
    println!("\npaper: the research frontier (defined at the CAMformer point) exceeds the industry frontier (TPUv4 point).");
    Ok(())
}

/// Design-space exploration (Sec. IV-B + DESIGN.md ablations).
pub fn dse(_args: &Args) -> Result<()> {
    // 1) MAC balance
    let m = PipelineModel::paper();
    println!("== DSE 1: contextualization MAC balance ==");
    println!(
        "association latency {} cycles; minimal MAC count matching it: {}",
        m.latencies().association,
        m.balance_mac_units()
    );

    // 2) CAM geometry sweep
    let mut t = Table::new(
        "DSE 2: CAM height vs throughput & ADC overhead (N=1024)",
        &["CAM_H", "tiles", "adc cyc/tile", "throughput qry/ms", "candidates"],
    );
    for cam_h in [8usize, 16, 32, 64] {
        let cfg = ArchConfig { cam_h, ..Default::default() };
        let pm = PipelineModel { cfg, fine_grained: true };
        t.row(&[
            cam_h.to_string(),
            cfg.tiles().to_string(),
            cfg.adc_cycles_per_tile().to_string(),
            format!("{:.1}", pm.throughput_qry_per_ms()),
            cfg.candidates().to_string(),
        ]);
    }
    t.print();
    println!("(total ADC work per query is constant; CAM_H=16 bounds the shared-SAR serialization per tile\n while keeping the stage-1 candidate count at 2N/16 — the paper's co-design point.)");

    // 3) ADC precision ablation
    let mut t2 = Table::new(
        "DSE 3: ADC bits vs association cadence",
        &["adc bits", "cycles/tile", "throughput qry/ms"],
    );
    for bits in [4u32, 5, 6, 8] {
        let cfg = ArchConfig { adc_bits: bits, ..Default::default() };
        let pm = PipelineModel { cfg, fine_grained: true };
        t2.row(&[
            bits.to_string(),
            cfg.adc_cycles_per_tile().to_string(),
            format!("{:.1}", pm.throughput_qry_per_ms()),
        ]);
    }
    t2.print();
    println!("(6 bits is the accuracy floor for d_k=64 — fewer bits quantise real match counts; see accuracy tests.)");

    // 4) full multi-axis Pareto sweep
    let pts = camformer::arch::dse::sweep(1024, 42);
    let front = camformer::arch::dse::pareto(&pts);
    let mut t3 = Table::new(
        &format!(
            "DSE 4: Pareto-optimal designs ({} of {} evaluated points)",
            front.len(),
            pts.len()
        ),
        &["CAM_H", "ADCs", "MACs", "k1", "qry/ms", "qry/mJ", "mm^2", "recall"],
    );
    let mut sorted = front.clone();
    sorted.sort_by(|a, b| b.throughput_qry_per_ms.partial_cmp(&a.throughput_qry_per_ms).unwrap());
    for p in sorted.iter().take(12) {
        t3.row(&[
            p.cam_h.to_string(),
            p.adcs_per_array.to_string(),
            p.mac_units.to_string(),
            p.stage1_k.to_string(),
            format!("{:.0}", p.throughput_qry_per_ms),
            format!("{:.0}", p.energy_eff_qry_per_mj),
            format!("{:.3}", p.area_mm2),
            format!("{:.4}", p.weighted_recall),
        ]);
    }
    t3.print();
    Ok(())
}
