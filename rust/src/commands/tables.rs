//! Table-regeneration subcommands (Tables I, II, III, IV).

use anyhow::{Context, Result};
use std::path::PathBuf;

use camformer::accuracy::tables as acc_tables;
use camformer::baselines::accelerators;
use camformer::baselines::circuit;
use camformer::runtime::executable::{default_artifacts_dir, Engine};
use camformer::util::cli::Args;
use camformer::util::table::Table;

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir)
}

/// Table I: circuit-level comparison with measured error columns.
pub fn table1(args: &Args) -> Result<()> {
    let seed = args.get_u64("seed", 42);
    let rows = circuit::table1_rows(seed);
    let mut t = Table::new(
        "Table I — circuit-level BIMV comparison (errors MEASURED at sigma=1.4%)",
        &["module", "sensing", "peripherals", "freq MHz", "mean err %", "max dev %"],
    );
    for r in &rows {
        t.row(&[
            r.name.to_string(),
            r.sensing.to_string(),
            r.peripherals.to_string(),
            format!("{:.1}", r.freq_mhz),
            format!("{:.2}", r.mean_err_pct),
            format!("{:.2}", r.max_dev_pct),
        ]);
    }
    t.print();
    println!("\npaper error rows: CiM ~7% (pred.), TD-CAM 7.76%, BA-CAM 1.12%.");
    Ok(())
}

/// Table II: accelerator comparison.
pub fn table2(_args: &Args) -> Result<()> {
    let rows = accelerators::table2_rows();
    let mut t = Table::new(
        "Table II — performance comparison at 1 GHz (BERT-Large head, n=1024)",
        &["accelerator", "Q/K/V bits", "cores", "thruput qry/ms", "qry/mJ", "area mm^2", "power W"],
    );
    for r in &rows {
        t.row(&[
            r.name.clone(),
            r.qkv_bits.to_string(),
            r.cores.to_string(),
            format!("{:.1}", r.throughput_qry_per_ms),
            format!("{:.0}", r.energy_eff_qry_per_mj),
            r.area_mm2.map(|a| format!("{a:.2}")).unwrap_or_else(|| "-".into()),
            format!("{:.2}", r.power_w),
        ]);
    }
    t.print();
    println!("\npaper CAMformer row: 191 qry/ms, 9045 qry/mJ, 0.26 mm^2, 0.17 W (model-derived rows above;");
    println!("baseline rows carry the published numbers).");
    Ok(())
}

/// Table III analogue: MEASURED accuracy vs first-stage k via the PJRT
/// classifier artifacts on the associative-retrieval task.
pub fn table3(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let trials = args.get_usize("trials", 60);
    let seed = args.get_u64("seed", 42);
    let mut engine = Engine::new(&dir)
        .with_context(|| format!("artifacts at {dir:?}; run `make artifacts`"))?;

    let variants: &[(&str, &str)] = &[
        ("exact attention (oracle)", "classifier_exact"),
        ("single-stage Top-32 (HAD baseline)", "classifier_single_stage"),
        ("two-stage, k=8", "classifier_cam_k8"),
        ("two-stage, k=4", "classifier_cam_k4"),
        ("two-stage, k=2", "classifier_cam_k2"),
        ("two-stage, k=1", "classifier_cam_k1"),
    ];
    let mut t = Table::new(
        "Table III analogue — MEASURED accuracy on associative retrieval (512 tokens)",
        &["attention", "accuracy %"],
    );
    for (label, entry) in variants {
        let exe = engine.load(entry)?;
        let acc = acc_tables::measure_accuracy(
            |toks| exe.run_s32(toks).expect("classifier run"),
            512,
            trials,
            seed,
        );
        t.row(&[label.to_string(), format!("{:.1}", acc * 100.0)]);
    }
    t.print();
    println!("\npaper pattern (DeiT): accuracy near baseline for k>=2, visible drop at k=1.");
    Ok(())
}

/// Table IV: GLUE-style calibrated simulation.
pub fn table4(args: &Args) -> Result<()> {
    let seed = args.get_u64("seed", 42);
    let k4 = acc_tables::table4_simulated(4, seed);
    let k2 = acc_tables::table4_simulated(2, seed + 1);
    let mut t = Table::new(
        "Table IV — GLUE-style two-stage accuracy (calibrated simulation, g=16)",
        &["task", "HAD baseline", "first-stage k=4", "first-stage k=2"],
    );
    for i in 0..k4.len() {
        t.row(&[
            k4[i].0.name.to_string(),
            format!("{:.2}", k4[i].0.baseline),
            format!("{:.2}", k4[i].1),
            format!("{:.2}", k2[i].1),
        ]);
    }
    let base_avg: f64 =
        k4.iter().map(|(t, _)| t.baseline).sum::<f64>() / k4.len() as f64;
    t.row(&[
        "Avg".to_string(),
        format!("{base_avg:.2}"),
        format!("{:.2}", acc_tables::table4_average(&k4)),
        format!("{:.2}", acc_tables::table4_average(&k2)),
    ]);
    t.print();
    println!("\npaper: avg 80.81 -> 80.54 (k=4) / 80.48 (k=2); <0.4% average degradation.");
    Ok(())
}
