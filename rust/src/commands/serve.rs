//! Serving subcommands: the coordinator demo and the all-layers quickstart.

use anyhow::{Context, Result};
use std::path::PathBuf;

use camformer::accuracy::functional::{self, AttnConfig};
use camformer::coordinator::backend::{ArchSimBackend, FunctionalBackend, PjrtBackend};
use camformer::coordinator::server::{CamformerServer, Request, ServerConfig};
use camformer::runtime::executable::{default_artifacts_dir, Engine};
use camformer::util::cli::Args;
use camformer::util::rng::Rng;

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir)
}

/// Run the coordinator over a synthetic request stream.
pub fn serve(args: &Args) -> Result<()> {
    let heads = args.get_usize("heads", 4);
    let requests = args.get_usize("requests", 256);
    let backend_kind = args.get_or("backend", "pjrt");
    let seed = args.get_u64("seed", 42);
    let n = 1024usize;
    let d = 64usize;

    println!("camformer serve: {requests} requests over {heads} heads, backend={backend_kind}");
    let mut kv_rng = Rng::new(seed);
    let kv_data: Vec<(Vec<f32>, Vec<f32>)> = (0..heads)
        .map(|_| (kv_rng.normal_vec(n * d), kv_rng.normal_vec(n * d)))
        .collect();

    let dir = artifacts_dir(args);
    let cfg = ServerConfig { heads, ..Default::default() };
    let kv_for = {
        let kv = kv_data.clone();
        move |h: usize| kv[h].clone()
    };

    let server = match backend_kind {
        "pjrt" => CamformerServer::start(
            cfg,
            |h| {
                PjrtBackend::new(&dir)
                    .with_context(|| format!("PJRT backend for head {h}"))
                    .expect("artifacts present — run `make artifacts`")
            },
            kv_for,
        ),
        "functional" => CamformerServer::start(cfg, |_| FunctionalBackend::new(n, d), kv_for),
        "arch" => CamformerServer::start(cfg, |_| ArchSimBackend::new(n), kv_for),
        other => anyhow::bail!("unknown backend {other:?} (pjrt|functional|arch)"),
    };

    let mut rng = Rng::new(seed + 1);
    for i in 0..requests as u64 {
        server
            .submit(Request {
                id: i,
                head: (i as usize) % heads,
                query: rng.normal_vec(d),
            })
            .map_err(anyhow::Error::msg)?;
    }
    let resps = server.collect(requests);
    anyhow::ensure!(resps.len() == requests, "lost responses");

    // golden cross-check on a sample of responses
    let acfg = AttnConfig::paper(n, d);
    let mut checked = 0;
    for r in resps.iter().take(8) {
        let (k, v) = &kv_data[r.head];
        // reconstruct the query by id (the stream above is deterministic)
        let mut rng2 = Rng::new(seed + 1);
        let mut q = Vec::new();
        for i in 0..=r.id {
            q = rng2.normal_vec(d);
            let _ = i;
        }
        let want = functional::camformer_attention(&q, k, v, &acfg);
        for (a, b) in r.output.iter().zip(&want) {
            anyhow::ensure!((a - b).abs() < 0.05, "golden check failed: {a} vs {b}");
        }
        checked += 1;
    }

    let (metrics, window) = server.shutdown();
    println!("golden-checked {checked} responses against the functional model: OK");
    println!("{}", metrics.summary(window));
    Ok(())
}

/// One query through every layer, narrated.
pub fn quickstart(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let seed = args.get_u64("seed", 42);
    println!("== CAMformer quickstart: one query through all three layers ==\n");

    let mut rng = Rng::new(seed);
    let q = rng.normal_vec(64);
    let k = rng.normal_vec(1024 * 64);
    let v = rng.normal_vec(1024 * 64);

    println!("[L1/L2 via PJRT] loading artifacts from {dir:?}");
    let mut engine = Engine::new(&dir)?;
    let scores_exe = engine.load("bacam_scores")?;
    let scores = scores_exe.run_f32(&[&q, &k])?;
    let top: Vec<(usize, f32)> = {
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
        idx.iter().take(5).map(|&i| (i, scores[i])).collect()
    };
    println!("  BA-CAM scores computed for 1024 keys; top-5 matches: {top:?}");

    let attn_exe = engine.load("attn_single_query")?;
    let out = attn_exe.run_f32(&[&q, &k, &v])?;
    println!("  Eq. 1 output (first 6 dims): {:?}", &out[..6]);

    println!("\n[L3 functional cross-check]");
    let want = functional::camformer_attention(&q, &k, &v, &AttnConfig::paper(1024, 64));
    let max_diff = out
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("  PJRT vs pure-Rust functional model: max |diff| = {max_diff:.6}");
    anyhow::ensure!(max_diff < 1e-2, "functional mismatch");

    println!("\n[L3 architecture simulation]");
    let arch_cfg = camformer::arch::config::ArchConfig::default();
    let (arch_out, lat) = camformer::arch::pipeline::simulate_query(arch_cfg, &q, &k, &v);
    let arch_diff = out
        .iter()
        .zip(&arch_out)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "  cycle-annotated sim agrees within {arch_diff:.4}; stage latencies [cycles]: assoc={} norm={} ctx={}",
        lat.association, lat.normalization, lat.contextualization
    );
    println!(
        "  => at 1 GHz: {:.1} us/query latency, {:.1} qry/ms pipelined throughput",
        (lat.total()) as f64 / 1000.0,
        camformer::arch::pipeline::PipelineModel::paper().throughput_qry_per_ms()
    );
    println!("\nquickstart OK");
    Ok(())
}
