//! Serving subcommands: the session-oriented coordinator demo and the
//! all-layers quickstart.

use anyhow::{Context, Result};
use std::path::PathBuf;

use camformer::accuracy::functional::{self, AttnConfig};
use camformer::coordinator::backend::{ArchSimBackend, FunctionalBackend, PjrtBackend};
use camformer::coordinator::kv_store::KvStore;
use camformer::coordinator::server::{CamformerServer, Request, ServerConfig};
use camformer::runtime::executable::{default_artifacts_dir, Engine};
use camformer::util::cli::Args;
use camformer::util::rng::Rng;

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir)
}

/// Run the coordinator over a synthetic decode-serving workload:
/// `--sessions` streams, each prefilled with `--prefill` rows and decoded
/// for `--steps` live KV-append steps across `--heads` heads.
pub fn serve(args: &Args) -> Result<()> {
    let heads = args.get_usize("heads", 4);
    let sessions = args.get_usize("sessions", 4);
    let steps = args.get_usize("steps", 32);
    let prefill_rows = args.get_usize("prefill", 128);
    let backend_kind = args.get_or("backend", "functional");
    let seed = args.get_u64("seed", 42);
    let capacity = 1024usize;
    let d = 64usize;

    println!(
        "camformer serve: {sessions} sessions x {steps} decode steps over {heads} heads, \
         backend={backend_kind}"
    );
    anyhow::ensure!(
        prefill_rows + steps <= capacity,
        "prefill {prefill_rows} + steps {steps} exceeds the provisioned context {capacity}"
    );

    let dir = artifacts_dir(args);
    let cfg = ServerConfig {
        heads,
        kv_capacity: capacity,
        max_sessions: sessions.max(1),
        ..Default::default()
    };
    let quantum = cfg.pad_quantum;
    let server = match backend_kind {
        "pjrt" => CamformerServer::start(cfg, move |w| {
            PjrtBackend::new(&dir)
                .with_context(|| format!("PJRT backend for worker {w}"))
                .expect("artifacts present — run `make artifacts` and build with --features pjrt")
        }),
        "functional" => CamformerServer::start(cfg, |_| FunctionalBackend::new(capacity, d)),
        "arch" => CamformerServer::start(cfg, |_| ArchSimBackend::new(capacity)),
        other => anyhow::bail!("unknown backend {other:?} (pjrt|functional|arch)"),
    };

    // head-0 mirror per session for the golden cross-check
    let mut rng = Rng::new(seed);
    let mut mirrors: Vec<KvStore> =
        (0..sessions).map(|_| KvStore::new(capacity, d, d)).collect();

    let mut next_id = 0u64;
    for sid in 0..sessions as u64 {
        for h in 0..heads {
            let keys = rng.normal_vec(prefill_rows * d);
            let values = rng.normal_vec(prefill_rows * d);
            if h == 0 {
                mirrors[sid as usize].load(&keys, &values)?;
            }
            server.submit(Request::Prefill { id: next_id, session: sid, head: h, keys, values })?;
            next_id += 1;
        }
    }
    let acks = server.collect(sessions * heads);
    anyhow::ensure!(acks.iter().all(|a| a.is_ok()), "prefill failed");

    for _step in 0..steps {
        for sid in 0..sessions as u64 {
            for h in 0..heads {
                let q = rng.normal_vec(d);
                let nk = rng.normal_vec(d);
                let nv = rng.normal_vec(d);
                if h == 0 {
                    mirrors[sid as usize].append(&nk, &nv)?;
                }
                server.submit(Request::Decode {
                    id: next_id,
                    session: sid,
                    head: h,
                    query: q,
                    new_key: nk,
                    new_value: nv,
                })?;
                next_id += 1;
            }
        }
    }
    let total = sessions * heads * steps;
    let resps = server.collect(total);
    let failed = resps.iter().filter(|r| !r.is_ok()).count();
    anyhow::ensure!(failed == 0, "{failed} of {total} decode steps failed");

    // golden cross-check: a final head-0 query per session against the
    // functional model over the accumulated cache
    let mut checked = 0;
    let mut goldens = Vec::new();
    for sid in 0..sessions as u64 {
        let q = rng.normal_vec(d);
        server.submit(Request::Attend { id: next_id, session: sid, head: 0, query: q.clone() })?;
        goldens.push((next_id, sid, q));
        next_id += 1;
    }
    for r in server.collect(sessions) {
        let (_, sid, q) = goldens.iter().find(|(id, _, _)| *id == r.id).unwrap();
        let store = &mirrors[*sid as usize];
        // replay the backend's execution geometry: PJRT serves over its
        // fixed 1024-row context, flexible backends over the group quantum
        let rows = match backend_kind {
            "pjrt" => capacity,
            _ => store.len().div_ceil(quantum) * quantum,
        };
        let (kp, vp, _) = store.padded(rows);
        let want = functional::camformer_attention(q, kp, vp, &AttnConfig::paper(rows, d));
        for (a, b) in r.output().iter().zip(&want) {
            anyhow::ensure!((a - b).abs() < 0.05, "golden check failed: {a} vs {b}");
        }
        checked += 1;
    }

    let (metrics, window) = server.shutdown();
    println!("golden-checked {checked} sessions against the functional model: OK");
    println!("{}", metrics.summary(window));
    Ok(())
}

/// One query through every layer, narrated.
pub fn quickstart(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let seed = args.get_u64("seed", 42);
    println!("== CAMformer quickstart: one query through all three layers ==\n");

    let mut rng = Rng::new(seed);
    let q = rng.normal_vec(64);
    let k = rng.normal_vec(1024 * 64);
    let v = rng.normal_vec(1024 * 64);

    println!("[L1/L2 via PJRT] loading artifacts from {dir:?}");
    let mut engine = Engine::new(&dir)?;
    let scores_exe = engine.load("bacam_scores")?;
    let scores = scores_exe.run_f32(&[&q, &k])?;
    let top: Vec<(usize, f32)> = {
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
        idx.iter().take(5).map(|&i| (i, scores[i])).collect()
    };
    println!("  BA-CAM scores computed for 1024 keys; top-5 matches: {top:?}");

    let attn_exe = engine.load("attn_single_query")?;
    let out = attn_exe.run_f32(&[&q, &k, &v])?;
    println!("  Eq. 1 output (first 6 dims): {:?}", &out[..6]);

    println!("\n[L3 functional cross-check]");
    let want = functional::camformer_attention(&q, &k, &v, &AttnConfig::paper(1024, 64));
    let max_diff = out
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("  PJRT vs pure-Rust functional model: max |diff| = {max_diff:.6}");
    anyhow::ensure!(max_diff < 1e-2, "functional mismatch");

    println!("\n[L3 architecture simulation]");
    let arch_cfg = camformer::arch::config::ArchConfig::default();
    let (arch_out, lat) = camformer::arch::pipeline::simulate_query(arch_cfg, &q, &k, &v);
    let arch_diff = out
        .iter()
        .zip(&arch_out)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "  cycle-annotated sim agrees within {arch_diff:.4}; stage latencies [cycles]: assoc={} norm={} ctx={}",
        lat.association, lat.normalization, lat.contextualization
    );
    println!(
        "  => at 1 GHz: {:.1} us/query latency, {:.1} qry/ms pipelined throughput",
        (lat.total()) as f64 / 1000.0,
        camformer::arch::pipeline::PipelineModel::paper().throughput_qry_per_ms()
    );
    println!("\nquickstart OK");
    Ok(())
}
