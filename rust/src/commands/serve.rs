//! Serving subcommands: the session-oriented coordinator demo and the
//! all-layers quickstart.

use anyhow::{Context, Result};
use std::path::PathBuf;
use std::time::Duration;

use camformer::accuracy::functional::{self, AttnConfig};
use camformer::coordinator::backend::{ArchSimBackend, FunctionalBackend, PjrtBackend};
use camformer::coordinator::kv_store::KvStore;
use camformer::coordinator::server::{CamformerServer, ReclaimPolicy, ServerConfig};
use camformer::coordinator::{ServeError, Ticket};
use camformer::runtime::executable::{default_artifacts_dir, Engine};
use camformer::util::cli::Args;
use camformer::util::rng::Rng;
use camformer::workload::{generate, EnergyAccountant, TraceSpec, TrafficDriver};

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir)
}

/// Run the coordinator over a synthetic decode-serving workload through
/// the session-handle API: `--sessions` streams are `open`ed (one
/// shard-wide prefill fan-out each, `--prefill` rows), decoded for
/// `--steps` live KV-append steps across `--heads` heads via per-request
/// tickets, golden-checked, then explicitly closed. `--reclaim lru`
/// swaps the admission policy from Deny to LRU idle eviction, and
/// `--reclaim spill` to the ISSUE-8 DRAM spill tier (victims demote to
/// the modeled host tier and promote back on their next request).
/// `--kv-budget` caps the rows each worker's session pool may hold
/// resident (tight budgets surface typed `CapacityExhausted` refusals,
/// or evictions under `--reclaim lru`), and `--max-queue` bounds the
/// standing per-worker queue — submissions shed past it answer with the
/// retryable `Overloaded`, which this driver replays until admission.
pub fn serve(args: &Args) -> Result<()> {
    // ISSUE 10: `--trace bert|vit|zipf` switches from the synthetic
    // fixed-shape workload to the seeded trace-driven co-simulation
    if let Some(kind) = args.get("trace") {
        return serve_trace(kind, args);
    }
    let heads = args.get_usize("heads", 4);
    let sessions = args.get_usize("sessions", 4);
    let steps = args.get_usize("steps", 32);
    let prefill_rows = args.get_usize("prefill", 128);
    let backend_kind = args.get_or("backend", "functional");
    let reclaim_kind = args.get_or("reclaim", "deny");
    let seed = args.get_u64("seed", 42);
    let kv_budget = args.get_usize("kv-budget", 1024 * 64);
    let max_queue = args.get_usize("max-queue", 4096);
    let capacity = 1024usize;
    let d = 64usize;

    println!(
        "camformer serve: {sessions} sessions x {steps} decode steps over {heads} heads, \
         backend={backend_kind}, reclaim={reclaim_kind}, kv-budget={kv_budget}, \
         max-queue={max_queue}"
    );
    anyhow::ensure!(
        prefill_rows + steps <= capacity,
        "prefill {prefill_rows} + steps {steps} exceeds the provisioned context {capacity}"
    );
    let reclaim = match reclaim_kind {
        "deny" => ReclaimPolicy::Deny,
        "lru" => ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO },
        // ISSUE 8: over-budget admissions demote the shard-LRU victim's
        // KV into the modeled host DRAM tier instead of dropping it; a
        // demoted session's next request promotes it back (slow first
        // token, never `Evicted`)
        "spill" => ReclaimPolicy::LruSpillToDram { min_idle: Duration::ZERO },
        other => anyhow::bail!("unknown reclaim policy {other:?} (deny|lru|spill)"),
    };

    let dir = artifacts_dir(args);
    let cfg = ServerConfig {
        heads,
        kv_capacity: capacity,
        max_sessions: sessions.max(1),
        reclaim,
        worker_kv_budget: kv_budget,
        max_queue,
        ..Default::default()
    };
    let quantum = cfg.pad_quantum;
    let server = match backend_kind {
        "pjrt" => CamformerServer::start(cfg, move |w| {
            PjrtBackend::new(&dir)
                .with_context(|| format!("PJRT backend for worker {w}"))
                .expect("artifacts present — run `make artifacts` and build with --features pjrt")
        }),
        "functional" => CamformerServer::start(cfg, |_| FunctionalBackend::new(capacity, d)),
        "arch" => CamformerServer::start(cfg, |_| ArchSimBackend::new(capacity)),
        other => anyhow::bail!("unknown backend {other:?} (pjrt|functional|arch)"),
    };

    // one open per session: the broadcast prefill lands on every head,
    // so a single head-0 mirror per session covers the golden check
    let mut rng = Rng::new(seed);
    let mut mirrors: Vec<KvStore> =
        (0..sessions).map(|_| KvStore::new(capacity, d, d)).collect();
    let mut handles = Vec::with_capacity(sessions);
    for sid in 0..sessions as u64 {
        let keys = rng.normal_vec(prefill_rows * d);
        let values = rng.normal_vec(prefill_rows * d);
        mirrors[sid as usize].load(&keys, &values)?;
        handles.push(server.open(sid, keys, values)?);
    }

    // every decode step returns a ticket; submitting the whole workload
    // before waiting keeps the workers' wire batches full. Overload
    // sheds (bounded standing queues past --max-queue) are retryable by
    // contract: replay until the worker admits the request — nothing
    // was enqueued for a shed submission, so program order is intact.
    let mut tickets: Vec<Ticket> = Vec::with_capacity(sessions * heads * steps);
    let mut shed_replays = 0u64;
    for _step in 0..steps {
        for (sid, handle) in handles.iter().enumerate() {
            for h in 0..heads {
                let q = rng.normal_vec(d);
                let nk = rng.normal_vec(d);
                let nv = rng.normal_vec(d);
                if h == 0 {
                    mirrors[sid].append(&nk, &nv)?;
                }
                let ticket = loop {
                    match handle.decode_on(h, q.clone(), nk.clone(), nv.clone()) {
                        Ok(t) => break t,
                        Err(ServeError::Overloaded { .. }) => {
                            shed_replays += 1;
                            std::thread::yield_now();
                        }
                        Err(e) => return Err(e.into()),
                    }
                };
                tickets.push(ticket);
            }
        }
    }
    if shed_replays > 0 {
        println!("  replayed {shed_replays} overload sheds to admission (max-queue={max_queue})");
    }
    let total = tickets.len();
    let mut failed = 0usize;
    for t in tickets {
        if t.wait().result.is_err() {
            failed += 1;
        }
    }
    anyhow::ensure!(failed == 0, "{failed} of {total} decode steps failed");

    // golden cross-check: a final head-0 query per session against the
    // functional model over the accumulated cache — the ticket resolves
    // to exactly its session's response, no id bookkeeping needed
    let mut checked = 0;
    for (sid, handle) in handles.iter().enumerate() {
        let q = rng.normal_vec(d);
        let r = handle.attend(q.clone())?.wait();
        anyhow::ensure!(r.is_ok(), "golden attend failed: {:?}", r.result);
        let store = &mirrors[sid];
        // replay the backend's execution geometry: PJRT serves over its
        // fixed 1024-row context, flexible backends over the group quantum
        let rows = match backend_kind {
            "pjrt" => capacity,
            _ => store.len().div_ceil(quantum) * quantum,
        };
        let (kp, vp, _) = store.padded(rows);
        let want = functional::camformer_attention(&q, kp, vp, &AttnConfig::paper(rows, d));
        for (a, b) in r.output().iter().zip(&want) {
            anyhow::ensure!((a - b).abs() < 0.05, "golden check failed: {a} vs {b}");
        }
        checked += 1;
    }

    // explicit lifecycle teardown: each close releases the session's
    // provisioned KV capacity on every head of its shard
    for handle in handles {
        handle.close()?;
    }
    let (metrics, window) = server.shutdown();
    println!("golden-checked {checked} sessions against the functional model: OK");
    println!("{}", metrics.summary(window));
    Ok(())
}

/// Trace-driven traffic + energy co-simulation (ISSUE 10): generate a
/// seeded workload trace (`--trace bert|vit|zipf`, `--seed N`), replay
/// it against a live server through the session-handle API — full speed
/// by default, `--speedup X` paces arrivals at X× the trace timeline —
/// and price the accumulated work through the circuit models. The
/// default configuration (4 resident sessions under the DRAM spill
/// tier) keeps the reclaim path live; `--reclaim deny` needs
/// `--max-sessions` at least the trace population to admit every open.
fn serve_trace(kind: &str, args: &Args) -> Result<()> {
    let seed = args.get_u64("seed", 42);
    let speedup = args.get_f64("speedup", f64::INFINITY);
    let shards = args.get_usize("shards", 2);
    let reclaim_kind = args.get_or("reclaim", "spill");
    let spec = match kind {
        "bert" => TraceSpec::bert(),
        "vit" => TraceSpec::vit(),
        "zipf" => TraceSpec::zipf_hotset(),
        other => anyhow::bail!("unknown trace {other:?} (bert|vit|zipf)"),
    };
    let max_sessions = args.get_usize("max-sessions", 4);
    let reclaim = match reclaim_kind {
        "deny" => ReclaimPolicy::Deny,
        "lru" => ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO },
        "spill" => ReclaimPolicy::LruSpillToDram { min_idle: Duration::ZERO },
        other => anyhow::bail!("unknown reclaim policy {other:?} (deny|lru|spill)"),
    };
    let trace = generate(&spec, seed);
    let cap = spec.kv_capacity();
    println!(
        "camformer serve --trace {kind}: {} ops ({} decodes) over {} sessions, \
         seed={seed}, shards={shards}, max-sessions={max_sessions}, reclaim={reclaim_kind}",
        trace.ops.len(),
        trace.decode_ops(),
        spec.population,
    );

    let server = CamformerServer::start(
        ServerConfig {
            shards,
            kv_capacity: cap,
            max_sessions,
            reclaim,
            d_k: spec.d_k,
            d_v: spec.d_v,
            ..Default::default()
        },
        move |_| FunctionalBackend::new(cap, 64),
    );
    let driver = if speedup.is_finite() {
        TrafficDriver::paced(speedup)
    } else {
        TrafficDriver::full_speed()
    };
    let report = driver.replay(&trace, &server)?;
    let (mut metrics, window) = server.shutdown();
    EnergyAccountant::paper(spec.d_v).attach(&mut metrics);

    println!(
        "  replay: {} tokens in {:.1} ms ({:.0} tok/s), opens={} reopens={} \
         shed_replays={} closes={}",
        report.decoded_tokens,
        report.wall.as_secs_f64() * 1e3,
        report.tokens_per_s(),
        report.opens,
        report.reopens,
        report.shed_replays,
        report.closes,
    );
    println!(
        "  latency (scheduled arrival -> completion): mean={:.1}us p50={:.1}us p99={:.1}us",
        report.mean_us(),
        report.p50_us(),
        report.p99_us(),
    );
    println!("  {}", metrics.summary(window));
    anyhow::ensure!(
        report.completed(),
        "{} of {} ops never resolved",
        report.failed,
        trace.ops.len()
    );
    Ok(())
}

/// One query through every layer, narrated.
pub fn quickstart(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let seed = args.get_u64("seed", 42);
    println!("== CAMformer quickstart: one query through all three layers ==\n");

    let mut rng = Rng::new(seed);
    let q = rng.normal_vec(64);
    let k = rng.normal_vec(1024 * 64);
    let v = rng.normal_vec(1024 * 64);

    println!("[L1/L2 via PJRT] loading artifacts from {dir:?}");
    let mut engine = Engine::new(&dir)?;
    let scores_exe = engine.load("bacam_scores")?;
    let scores = scores_exe.run_f32(&[&q, &k])?;
    let top: Vec<(usize, f32)> = {
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
        idx.iter().take(5).map(|&i| (i, scores[i])).collect()
    };
    println!("  BA-CAM scores computed for 1024 keys; top-5 matches: {top:?}");

    let attn_exe = engine.load("attn_single_query")?;
    let out = attn_exe.run_f32(&[&q, &k, &v])?;
    println!("  Eq. 1 output (first 6 dims): {:?}", &out[..6]);

    println!("\n[L3 functional cross-check]");
    let want = functional::camformer_attention(&q, &k, &v, &AttnConfig::paper(1024, 64));
    let max_diff = out
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("  PJRT vs pure-Rust functional model: max |diff| = {max_diff:.6}");
    anyhow::ensure!(max_diff < 1e-2, "functional mismatch");

    println!("\n[L3 architecture simulation]");
    let arch_cfg = camformer::arch::config::ArchConfig::default();
    let (arch_out, lat) = camformer::arch::pipeline::simulate_query(arch_cfg, &q, &k, &v);
    let arch_diff = out
        .iter()
        .zip(&arch_out)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "  cycle-annotated sim agrees within {arch_diff:.4}; stage latencies [cycles]: assoc={} norm={} ctx={}",
        lat.association, lat.normalization, lat.contextualization
    );
    println!(
        "  => at 1 GHz: {:.1} us/query latency, {:.1} qry/ms pipelined throughput",
        (lat.total()) as f64 / 1000.0,
        camformer::arch::pipeline::PipelineModel::paper().throughput_qry_per_ms()
    );
    println!("\nquickstart OK");
    Ok(())
}
