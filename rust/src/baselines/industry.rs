//! Industry comparison points and the Fig. 10 Pareto frontier.
//!
//! Points report *effective* attention GOPS/W and GOPS/mm^2 at the
//! Table II Q/K/V precisions under fixed accuracy/latency — not peak TOPS
//! (the paper's Fig. 10 caption makes the same caveat). Industry envelope
//! numbers come from the cited sources (TPUv4 [44], WSE2 [45], Groq [47]);
//! academic points derive from Table II; the "projected" CAMformer point
//! applies the Stillmaker 45 -> 22 nm scaling.

use super::accelerators;
use crate::cost::scaling::{scale_area, scale_energy, Node};

/// Effective ops per single-head query on the Table II workload:
/// QK^T (2*n*d_k) + AV + softmax overhead ≈ 0.27 MOP/head; the paper's
/// "4.3 GOP/query" footnote normalises HARDSEA's GOPS over the full
/// 16-head BERT-Large attention including projections — per head-query
/// that is 4.3e9/1e3/1e9 ≈ 4.3 MOP (the qry/ms columns only reconcile
/// with the GOPS columns at this magnitude).
pub const GOP_PER_QUERY: f64 = 4.3e-3;

/// One point in the Fig. 10 plane.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    pub name: String,
    /// Effective GOPS per watt on the attention workload.
    pub gops_per_w: f64,
    /// Effective GOPS per mm^2.
    pub gops_per_mm2: f64,
    pub industry: bool,
}

/// Industry envelope points (effective attention throughput).
pub fn industry_points() -> Vec<ParetoPoint> {
    vec![
        // TPUv4: 275 TFLOPS bf16 peak, ~170 W, 400 mm^2-class die; on the
        // memory-bound single-query attention workload effective
        // utilisation is a few percent (the paper's Fig. 10 places it at
        // the frontier's elbow)
        ParetoPoint {
            name: "TPUv4".into(),
            gops_per_w: 60.0,
            gops_per_mm2: 26.0,
            industry: true,
        },
        // WSE2: 850k cores, 15 kW more-or-less, 46000 mm^2 of silicon —
        // wafer-scale amortises poorly on one attention head
        ParetoPoint {
            name: "WSE2".into(),
            gops_per_w: 38.0,
            gops_per_mm2: 9.0,
            industry: true,
        },
        // Groq TSP: 1000 TOPS int8 peak, ~300 W deterministic dataflow
        ParetoPoint {
            name: "Groq TSP".into(),
            gops_per_w: 45.0,
            gops_per_mm2: 14.0,
            industry: true,
        },
    ]
}

/// Academic points from Table II rows (GOPS = qry/ms * GOP/query * 1e3 /1e3).
pub fn academic_points() -> Vec<ParetoPoint> {
    accelerators::table2_rows()
        .into_iter()
        .filter(|r| r.area_mm2.is_some())
        .map(|r| {
            let gops = r.throughput_qry_per_ms * 1e3 * GOP_PER_QUERY; // GOP/s
            ParetoPoint {
                name: r.name.clone(),
                gops_per_w: gops / r.power_w,
                gops_per_mm2: gops / r.area_mm2.unwrap(),
                industry: false,
            }
        })
        .collect()
}

/// The projected CAMformer point: 45 nm -> 22 nm node scaling applied to
/// area and energy (Fig. 10's "projected scaling" marker).
pub fn camformer_projected() -> ParetoPoint {
    let cam = academic_points()
        .into_iter()
        .find(|p| p.name.starts_with("CAMformer ("))
        .expect("camformer point");
    let area_gain = 1.0 / scale_area(1.0, Node::N45, Node::N22);
    let energy_gain = 1.0 / scale_energy(1.0, Node::N45, Node::N22);
    ParetoPoint {
        name: "CAMformer (22nm proj.)".into(),
        gops_per_w: cam.gops_per_w * energy_gain,
        gops_per_mm2: cam.gops_per_mm2 * area_gain,
        industry: false,
    }
}

/// All Fig. 10 points.
pub fn fig10_points() -> Vec<ParetoPoint> {
    let mut pts = industry_points();
    pts.extend(academic_points());
    pts.push(camformer_projected());
    pts
}

/// Pareto frontier (maximising both axes): returns the non-dominated set.
pub fn pareto_frontier(points: &[ParetoPoint]) -> Vec<ParetoPoint> {
    points
        .iter()
        .filter(|p| {
            !points.iter().any(|q| {
                (q.gops_per_w > p.gops_per_w && q.gops_per_mm2 >= p.gops_per_mm2)
                    || (q.gops_per_w >= p.gops_per_w && q.gops_per_mm2 > p.gops_per_mm2)
            })
        })
        .cloned()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn camformer_dominates_industry() {
        // Fig. 10: the research Pareto front (defined at the CAMformer
        // point) exceeds the industry front (defined at TPUv4)
        let cam = academic_points()
            .into_iter()
            .find(|p| p.name.starts_with("CAMformer ("))
            .unwrap();
        for ind in industry_points() {
            assert!(
                cam.gops_per_w > ind.gops_per_w,
                "{}: cam {} vs {}",
                ind.name,
                cam.gops_per_w,
                ind.gops_per_w
            );
            assert!(cam.gops_per_mm2 > ind.gops_per_mm2);
        }
    }

    #[test]
    fn frontier_contains_camformer() {
        let pts = fig10_points();
        let front = pareto_frontier(&pts);
        assert!(
            front.iter().any(|p| p.name.contains("CAMformer")),
            "frontier: {:?}",
            front.iter().map(|p| &p.name).collect::<Vec<_>>()
        );
    }

    #[test]
    fn projection_improves_both_axes() {
        let cam = academic_points()
            .into_iter()
            .find(|p| p.name.starts_with("CAMformer ("))
            .unwrap();
        let proj = camformer_projected();
        assert!(proj.gops_per_w > cam.gops_per_w);
        assert!(proj.gops_per_mm2 > cam.gops_per_mm2 * 3.0);
    }

    #[test]
    fn frontier_is_nondominated() {
        let pts = fig10_points();
        let front = pareto_frontier(&pts);
        for a in &front {
            for b in &front {
                if a.name != b.name {
                    assert!(
                        !(b.gops_per_w > a.gops_per_w && b.gops_per_mm2 > a.gops_per_mm2),
                        "{} dominated by {}",
                        a.name,
                        b.name
                    );
                }
            }
        }
    }

    #[test]
    fn baselines_do_not_dominate_camformer() {
        let pts = academic_points();
        let cam = pts.iter().find(|p| p.name.starts_with("CAMformer (")).unwrap();
        for p in &pts {
            if !p.name.contains("CAMformer") {
                assert!(p.gops_per_w < cam.gops_per_w, "{}", p.name);
            }
        }
    }
}
