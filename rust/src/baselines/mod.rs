//! Baseline accelerator models (Secs. II/IV, Tables I/II, Fig. 10).
//!
//! Three kinds of comparator:
//! * `circuit` — circuit-level alternatives for the BIMV module (CiM
//!   XNOR+popcount, TD-CAM time-domain sensing) with behavioural error
//!   models, so Table I's error rows are *measured* against our BA-CAM;
//! * `accelerators` — the published academic accelerator numbers
//!   (MNNFast, A^3, SpAtten, HARDSEA) normalised to the Table II workload;
//! * `industry` — TPUv4 / WSE2 / Groq TSP envelope numbers for Fig. 10's
//!   Pareto frontier.

pub mod accelerators;
pub mod circuit;
pub mod industry;

pub use accelerators::{table2_rows, AcceleratorRow};
pub use industry::{fig10_points, ParetoPoint};
