//! Circuit-level BIMV alternatives (Table I): behavioural error models of
//! CiM (XNOR + popcount with calibrated flash ADC) and TD-CAM (time-domain
//! matchline sensing through a TDA), compared against BA-CAM's voltage
//! sensing under the same PVT conditions.
//!
//! The point the table makes: delay-domain sensing is *nonlinear* in the
//! match count and its device-delay variations accumulate, so TD-CAM needs
//! calibration and still shows up to 7.76% deviation; voltage-domain
//! charge sharing is linear and ratiometric, holding ~1% mean error.

use crate::util::rng::Rng;
use crate::util::stats;

/// Sensing scheme under comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// Bit-line XNOR+popcount with column-muxed flash ADC (CiM [29]).
    CiM,
    /// Time-domain matchline, TDA sensing (TD-CAM [28]).
    TdCam,
    /// Voltage-domain charge sharing (BA-CAM, ours).
    BaCam,
}

/// One Table I row's *measured* characteristics.
#[derive(Clone, Debug)]
pub struct CircuitRow {
    pub scheme: Scheme,
    pub name: &'static str,
    pub sensing: &'static str,
    pub peripherals: &'static str,
    pub freq_mhz: f64,
    pub mean_err_pct: f64,
    pub max_dev_pct: f64,
}

/// Simulate the *normalised match-count estimate* error of each scheme at
/// a given process sigma, over random match counts on a 64-wide row.
pub fn simulate_error(scheme: Scheme, sigma: f64, trials: usize, rng: &mut Rng) -> (f64, f64) {
    let width = 64usize;
    let mut errs = Vec::with_capacity(trials);
    for _ in 0..trials {
        let m = rng.index(width + 1);
        let ideal = m as f64 / width as f64;
        let measured = match scheme {
            Scheme::BaCam => {
                // ratiometric voltage: per-cell cap mismatch averages over
                // the row (error ~ sigma*sqrt(m)/width), plus the shared
                // SAR's comparator offset + reference noise referred to
                // full scale (the dominant residual — calibrated to the
                // paper's 1.12% overall error at sigma = 1.4%)
                let mut num = 0.0;
                let mut den = 0.0;
                for i in 0..width {
                    let c = 1.0 + sigma * rng.gauss();
                    den += c;
                    if i < m {
                        num += c;
                    }
                }
                num / den + 0.9 * sigma * rng.gauss()
            }
            Scheme::TdCam => {
                // discharge delay ~ 1/(m + m0): nonlinear; unlike charge
                // sharing, the discharge-path delay does NOT average over
                // the row — threshold/drive variation rides on the full
                // path and the TDA adds conversion jitter. The effective
                // sigma multipliers (11x drive, 5.5x TDA) are calibrated
                // to the published 7.76% TD-CAM deviation [28]; what the
                // model preserves is the *relative* robustness ordering
                // and its sigma scaling.
                let m0 = 4.0;
                let ideal_delay = 1.0 / (m as f64 + m0);
                let drive = 1.0 + 11.0 * sigma * rng.gauss();
                let tda_jitter = 1.0 + 5.5 * sigma * rng.gauss();
                let delay = (ideal_delay * drive * tda_jitter).max(1e-6);
                // invert through the nominal curve
                (1.0 / delay - m0) / width as f64
            }
            Scheme::CiM => {
                // digital popcount is exact; the flash ADC's per-column
                // gain/offset spread (needs calibration, Table I) is the
                // error source — multipliers calibrated to the ~7%
                // predicted CiM error [29]
                let gain = 1.0 + 8.0 * sigma * rng.gauss();
                let offset = 2.0 * sigma * rng.gauss();
                ideal * gain + offset
            }
        };
        errs.push((measured - ideal).abs() * 100.0);
    }
    (stats::mean(&errs), errs.iter().cloned().fold(0.0, f64::max))
}

/// Regenerate Table I with measured error columns at sigma = 1.4%.
pub fn table1_rows(seed: u64) -> Vec<CircuitRow> {
    let mut rng = Rng::new(seed);
    let trials = 4000;
    let (cim_mean, cim_max) = simulate_error(Scheme::CiM, 0.014, trials, &mut rng);
    let (td_mean, td_max) = simulate_error(Scheme::TdCam, 0.014, trials, &mut rng);
    let (ba_mean, ba_max) = simulate_error(Scheme::BaCam, 0.014, trials, &mut rng);
    vec![
        CircuitRow {
            scheme: Scheme::CiM,
            name: "CiM [29]",
            sensing: "BL sum (XNOR+Accumulate)",
            peripherals: "Flash ADC (MUX) + Adder Tree",
            freq_mhz: 18.5,
            mean_err_pct: cim_mean,
            max_dev_pct: cim_max,
        },
        CircuitRow {
            scheme: Scheme::TdCam,
            name: "TD-CAM [28]",
            sensing: "Time ML",
            peripherals: "TDA + tune",
            freq_mhz: 200.0,
            mean_err_pct: td_mean,
            max_dev_pct: td_max,
        },
        CircuitRow {
            scheme: Scheme::BaCam,
            name: "BA-CAM (Ours)",
            sensing: "Voltage ML",
            peripherals: "Shared SAR",
            freq_mhz: 500.0,
            mean_err_pct: ba_mean,
            max_dev_pct: ba_max,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bacam_beats_tdcam_and_cim() {
        let rows = table1_rows(42);
        let get = |s: Scheme| rows.iter().find(|r| r.scheme == s).unwrap();
        let ba = get(Scheme::BaCam);
        let td = get(Scheme::TdCam);
        let cim = get(Scheme::CiM);
        assert!(ba.mean_err_pct < td.mean_err_pct);
        assert!(ba.mean_err_pct < cim.mean_err_pct);
    }

    #[test]
    fn error_bands_match_table1() {
        // paper: BA-CAM 1.12% (sigma=1.4%), TD-CAM 7.76%, CiM ~7% (pred.)
        let rows = table1_rows(43);
        let get = |s: Scheme| rows.iter().find(|r| r.scheme == s).unwrap();
        let ba = get(Scheme::BaCam).mean_err_pct;
        let td = get(Scheme::TdCam).mean_err_pct;
        let cim = get(Scheme::CiM).mean_err_pct;
        assert!(ba < 2.0, "BA-CAM mean err {ba}% (paper 1.12%)");
        assert!((3.0..12.0).contains(&td), "TD-CAM mean err {td}% (paper 7.76%)");
        assert!((2.0..12.0).contains(&cim), "CiM err {cim}% (paper ~7%)");
    }

    #[test]
    fn tdcam_error_grows_faster_with_sigma() {
        let mut rng = Rng::new(44);
        let (ba_lo, _) = simulate_error(Scheme::BaCam, 0.01, 2000, &mut rng);
        let (ba_hi, _) = simulate_error(Scheme::BaCam, 0.04, 2000, &mut rng);
        let (td_lo, _) = simulate_error(Scheme::TdCam, 0.01, 2000, &mut rng);
        let (td_hi, _) = simulate_error(Scheme::TdCam, 0.04, 2000, &mut rng);
        assert!((td_hi - td_lo) > (ba_hi - ba_lo));
    }

    #[test]
    fn frequencies_match_table() {
        let rows = table1_rows(45);
        assert_eq!(rows[0].freq_mhz, 18.5);
        assert_eq!(rows[1].freq_mhz, 200.0);
        assert_eq!(rows[2].freq_mhz, 500.0);
    }
}
