//! Academic accelerator baselines (Table II).
//!
//! MNNFast, A^3, SpAtten and HARDSEA rows carry the *published* numbers at
//! the paper's normalisation (single-query BERT-Large attention, n=1024,
//! d_k=64, 1 GHz-class operation; HARDSEA converted from GOPS at
//! 4.3 GOP/query as in the paper's footnote). CAMformer rows are computed
//! live by `cost::CamformerCost::evaluate`, so the comparison is
//! model-vs-literature exactly like the paper's Table II.

use crate::cost::system::{CamformerCost, SystemConfig};

/// One Table II row.
#[derive(Clone, Debug)]
pub struct AcceleratorRow {
    pub name: String,
    pub qkv_bits: &'static str,
    pub cores: usize,
    pub throughput_qry_per_ms: f64,
    pub energy_eff_qry_per_mj: f64,
    pub area_mm2: Option<f64>,
    pub power_w: f64,
}

/// Published baseline rows (from the paper's Table II).
pub fn published_rows() -> Vec<AcceleratorRow> {
    vec![
        AcceleratorRow {
            name: "MNNFast [35]".into(),
            qkv_bits: "32/32/32",
            cores: 1,
            throughput_qry_per_ms: 28.4,
            energy_eff_qry_per_mj: 284.0,
            area_mm2: None,
            power_w: 1.00,
        },
        AcceleratorRow {
            name: "A3 [36]".into(),
            qkv_bits: "8/8/8",
            cores: 1,
            throughput_qry_per_ms: 52.3,
            energy_eff_qry_per_mj: 636.0,
            area_mm2: Some(2.08),
            power_w: 0.82,
        },
        AcceleratorRow {
            name: "SpAtten-1/8 [37]".into(),
            qkv_bits: "12/12/12",
            cores: 1,
            throughput_qry_per_ms: 85.2,
            energy_eff_qry_per_mj: 904.0,
            area_mm2: Some(1.55),
            power_w: 0.94,
        },
        AcceleratorRow {
            name: "HARDSEA [38]".into(),
            qkv_bits: "8/8/8",
            cores: 12,
            throughput_qry_per_ms: 187.0, // 802.1 GOPS / 4.3 GOP/query
            energy_eff_qry_per_mj: 191.0, // 821.3 GOPS/W / 4.3
            area_mm2: Some(4.95),
            power_w: 0.92,
        },
    ]
}

/// CAMformer rows evaluated from the cost model.
pub fn camformer_rows() -> Vec<AcceleratorRow> {
    let single = CamformerCost::evaluate(&SystemConfig::default());
    let mha = CamformerCost::evaluate(&SystemConfig::mha());
    vec![
        AcceleratorRow {
            name: "CAMformer (ours)".into(),
            qkv_bits: "1/1/16",
            cores: 1,
            throughput_qry_per_ms: single.throughput_qry_per_ms,
            energy_eff_qry_per_mj: single.energy_eff_qry_per_mj,
            area_mm2: Some(single.area_mm2),
            power_w: single.power_w,
        },
        AcceleratorRow {
            name: "CAMformer_MHA (ours)".into(),
            qkv_bits: "1/1/16",
            cores: 16,
            throughput_qry_per_ms: mha.throughput_qry_per_ms,
            energy_eff_qry_per_mj: mha.energy_eff_qry_per_mj,
            area_mm2: Some(mha.area_mm2),
            power_w: mha.power_w,
        },
    ]
}

/// The full Table II (baselines + CAMformer variants).
pub fn table2_rows() -> Vec<AcceleratorRow> {
    let mut rows = published_rows();
    rows.extend(camformer_rows());
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn camformer() -> AcceleratorRow {
        camformer_rows().remove(0)
    }

    #[test]
    fn headline_10x_energy_efficiency() {
        // abstract: "over 10x energy efficiency" vs the best baseline
        let best_baseline = published_rows()
            .iter()
            .map(|r| r.energy_eff_qry_per_mj)
            .fold(0.0, f64::max);
        let ours = camformer().energy_eff_qry_per_mj;
        assert!(
            ours > 10.0 * best_baseline * 0.8,
            "only {:.1}x (paper: >10x)",
            ours / best_baseline
        );
    }

    #[test]
    fn headline_throughput_advantage() {
        // abstract: "up to 4x higher throughput" (single core vs the best
        // single-core baseline, SpAtten at 85.2)
        let ours = camformer().throughput_qry_per_ms;
        let spatten = 85.2;
        let ratio = ours / spatten;
        assert!(ratio > 1.4 && ratio < 5.0, "throughput ratio {ratio}");
    }

    #[test]
    fn headline_area_advantage() {
        // abstract: "6-8x lower area" (vs A3 2.08 / SpAtten 1.55)
        let ours = camformer().area_mm2.unwrap();
        let vs_a3 = 2.08 / ours;
        let vs_spatten = 1.55 / ours;
        assert!(vs_a3 > 5.0 && vs_a3 < 11.0, "vs A3 {vs_a3}x");
        assert!(vs_spatten > 4.0 && vs_spatten < 9.0, "vs SpAtten {vs_spatten}x");
    }

    #[test]
    fn camformer_beats_hardsea_with_fewer_cores() {
        let ours = camformer();
        let hardsea = &published_rows()[3];
        assert!(ours.throughput_qry_per_ms > hardsea.throughput_qry_per_ms * 0.8);
        assert_eq!(ours.cores, 1);
        assert_eq!(hardsea.cores, 12);
    }

    #[test]
    fn table_has_six_rows() {
        assert_eq!(table2_rows().len(), 6);
    }
}
