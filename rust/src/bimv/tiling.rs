//! The Fig. 4 tiling plan: decompose Q in {-1,+1}^{1 x d_k} times
//! K^T in {-1,+1}^{d_k x N} into CAM_W x CAM_H tile operations.
//!
//! Step ① program a CAM_W x CAM_H tile of K^T; step ② load a CAM_W query
//! segment; step ③ associative tiled-MAC; step ④ concatenate horizontally
//! (N > CAM_H) and/or accumulate vertically (d_k > CAM_W).

/// One tile operation in the walk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileStep {
    /// Horizontal tile index (which CAM_H-wide segment of the N keys).
    pub h_tile: usize,
    /// Vertical tile index (which CAM_W-wide slice of d_k).
    pub v_tile: usize,
    /// Whether this step must program the array (first visit of this
    /// (h,v) key tile, or the array was evicted since).
    pub program: bool,
    /// Whether the partial result accumulates into an existing segment
    /// (true for v_tile > 0).
    pub accumulate: bool,
}

/// The full plan for one (or more) queries over an N x d_k key matrix.
#[derive(Clone, Debug)]
pub struct TilePlan {
    pub cam_h: usize,
    pub cam_w: usize,
    pub n: usize,
    pub d_k: usize,
    pub steps: Vec<TileStep>,
}

impl TilePlan {
    /// Plan a single-query BIMV (the association stage's unit of work).
    /// Tiles walk horizontally outer, vertically inner so each output
    /// segment finishes before the next begins — that ordering is what
    /// lets the Top-2 filter and V-prefetch fire per tile (Sec. III-C4).
    pub fn single_query(n: usize, d_k: usize, cam_h: usize, cam_w: usize) -> Self {
        assert!(n > 0 && d_k > 0);
        let h_tiles = n.div_ceil(cam_h);
        let v_tiles = d_k.div_ceil(cam_w);
        let mut steps = Vec::with_capacity(h_tiles * v_tiles);
        for h in 0..h_tiles {
            for v in 0..v_tiles {
                steps.push(TileStep {
                    h_tile: h,
                    v_tile: v,
                    // one physical array: every step reprograms unless the
                    // previous step used the same key tile
                    program: true,
                    accumulate: v > 0,
                });
            }
        }
        TilePlan {
            cam_h,
            cam_w,
            n,
            d_k,
            steps,
        }
    }

    /// Plan for `m` queries against the *same* keys: program each key tile
    /// once, then search it with all m query segments before moving on
    /// (key-stationary order — the Fig. 5 amortisation).
    pub fn key_stationary(m: usize, n: usize, d_k: usize, cam_h: usize, cam_w: usize) -> Self {
        let h_tiles = n.div_ceil(cam_h);
        let v_tiles = d_k.div_ceil(cam_w);
        let mut steps = Vec::new();
        for h in 0..h_tiles {
            for v in 0..v_tiles {
                for q in 0..m {
                    steps.push(TileStep {
                        h_tile: h,
                        v_tile: v,
                        program: q == 0,
                        accumulate: v > 0,
                    });
                }
            }
        }
        TilePlan {
            cam_h,
            cam_w,
            n,
            d_k,
            steps,
        }
    }

    pub fn h_tiles(&self) -> usize {
        self.n.div_ceil(self.cam_h)
    }

    pub fn v_tiles(&self) -> usize {
        self.d_k.div_ceil(self.cam_w)
    }

    /// Number of programming operations in the plan.
    pub fn programs(&self) -> usize {
        self.steps.iter().filter(|s| s.program).count()
    }

    /// Number of search operations in the plan.
    pub fn searches(&self) -> usize {
        self.steps.len()
    }

    /// Key rows covered by horizontal tile `h` (clipped at N).
    pub fn h_range(&self, h: usize) -> std::ops::Range<usize> {
        let lo = h * self.cam_h;
        lo..((h + 1) * self.cam_h).min(self.n)
    }

    /// d_k columns covered by vertical tile `v` (clipped at d_k).
    pub fn v_range(&self, v: usize) -> std::ops::Range<usize> {
        let lo = v * self.cam_w;
        lo..((v + 1) * self.cam_w).min(self.d_k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    #[test]
    fn paper_geometry_no_vertical_tiling() {
        // 16x64 CAM, d_k=64: "width 64 avoids vertical tiling" (Sec III-B1)
        let plan = TilePlan::single_query(1024, 64, 16, 64);
        assert_eq!(plan.v_tiles(), 1);
        assert_eq!(plan.h_tiles(), 64);
        assert_eq!(plan.searches(), 64);
        assert!(plan.steps.iter().all(|s| !s.accumulate));
    }

    #[test]
    fn vertical_tiling_accumulates() {
        let plan = TilePlan::single_query(32, 128, 16, 64);
        assert_eq!(plan.v_tiles(), 2);
        let acc = plan.steps.iter().filter(|s| s.accumulate).count();
        assert_eq!(acc, plan.h_tiles()); // one accumulating step per h tile
    }

    #[test]
    fn key_stationary_programs_once_per_tile() {
        let plan = TilePlan::key_stationary(100, 1024, 64, 16, 64);
        assert_eq!(plan.programs(), 64);
        assert_eq!(plan.searches(), 64 * 100);
    }

    #[test]
    fn ranges_clip_at_bounds() {
        let plan = TilePlan::single_query(20, 70, 16, 64);
        assert_eq!(plan.h_range(1), 16..20);
        assert_eq!(plan.v_range(1), 64..70);
    }

    #[test]
    fn property_every_cell_covered_exactly_once() {
        check("tile coverage", 100, |rng| {
            let n = 1 + rng.index(200);
            let d_k = 1 + rng.index(200);
            let plan = TilePlan::single_query(n, d_k, 16, 64);
            let mut covered = vec![vec![0u32; d_k]; n];
            for s in &plan.steps {
                for r in plan.h_range(s.h_tile) {
                    for c in plan.v_range(s.v_tile) {
                        covered[r][c] += 1;
                    }
                }
            }
            for row in &covered {
                for &c in row {
                    assert_eq!(c, 1);
                }
            }
        });
    }

    #[test]
    fn property_accumulate_iff_vertical_continuation() {
        check("accumulate flags", 50, |rng| {
            let n = 1 + rng.index(300);
            let d_k = 1 + rng.index(300);
            let plan = TilePlan::single_query(n, d_k, 16, 64);
            for s in &plan.steps {
                assert_eq!(s.accumulate, s.v_tile > 0);
            }
        });
    }
}
