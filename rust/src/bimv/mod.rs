//! BIMV — Binary In-Memory Vector-Matrix Multiplication engine
//! (Sec. II-B1, Fig. 4).
//!
//! Generalises a single BA-CAM tile to arbitrary binary matrices by the
//! paper's tiling walk: horizontal tiles concatenate partial result
//! segments, vertical tiles accumulate into the same segment through the
//! accumulation register. Bit-sliced extension handles int2/4/8 V
//! matrices (LSB→MSB slices, shift-and-add).

pub mod bitslice;
pub mod engine;
pub mod tiling;

pub use engine::{BimvEngine, PackedBitKeys};
pub use tiling::{TilePlan, TileStep};
