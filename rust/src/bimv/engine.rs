//! The BIMV engine: executes a `TilePlan` on a `BaCamArray`, producing the
//! signed score vector for arbitrary N x d_k binary key matrices
//! (Fig. 4 bottom-left datapath + right tiling walk).

use super::tiling::TilePlan;
use crate::camcircuit::array::BaCamArray;
use crate::camcircuit::energy::EnergyModel;

/// Execution statistics for one BIMV run (consumed by the energy model
/// and the pipeline simulator).
#[derive(Clone, Copy, Debug, Default)]
pub struct BimvStats {
    pub programs: usize,
    pub searches: usize,
    pub adc_conversions: usize,
}

/// Engine binding one physical BA-CAM array to the tiling walk.
pub struct BimvEngine {
    pub array: BaCamArray,
    pub stats: BimvStats,
    /// §Perf: reused tile/query scratch buffers — the walk reprograms the
    /// same physical array, so reallocation per step ① is pure overhead.
    tile_scratch: Vec<Vec<bool>>,
    qseg_scratch: Vec<bool>,
}

impl BimvEngine {
    pub fn new(cam_h: usize, cam_w: usize) -> Self {
        Self::with_array(BaCamArray::new(cam_h, cam_w))
    }

    pub fn with_array(array: BaCamArray) -> Self {
        let (h, w) = (array.height, array.width);
        BimvEngine {
            array,
            stats: BimvStats::default(),
            tile_scratch: vec![vec![true; w]; h],
            qseg_scratch: vec![true; w],
        }
    }

    /// Compute signed scores q . K^T for binary (true = +1) inputs.
    ///
    /// `query`: d_k bits; `keys`: N rows of d_k bits. Partial tiles pad
    /// with matching bits on both sides (a padded CAM column contributes a
    /// fixed +1 per padded bit, subtracted after accumulation) and padded
    /// rows are dropped — mirroring the padding note of Sec. II-B1.
    pub fn scores(&mut self, query: &[bool], keys: &[Vec<bool>]) -> Vec<f64> {
        let n = keys.len();
        let d_k = query.len();
        assert!(keys.iter().all(|k| k.len() == d_k), "ragged key matrix");
        let (cam_h, cam_w) = (self.array.height, self.array.width);
        let plan = TilePlan::single_query(n, d_k, cam_h, cam_w);
        let mut result = vec![0.0f64; n];

        for step in &plan.steps {
            let rows = plan.h_range(step.h_tile);
            let cols = plan.v_range(step.v_tile);
            let pad_d = cam_w - cols.len();

            // ① program the tile (pad columns with `true`, pad rows
            // full-true) — written into the reused scratch buffer (§Perf)
            let tile_rows = rows.len();
            for (slot, r) in rows.clone().enumerate() {
                let buf = &mut self.tile_scratch[slot];
                buf[..cols.len()].copy_from_slice(&keys[r][cols.clone()]);
                buf[cols.len()..].fill(true);
            }
            if step.program {
                self.array.program(&self.tile_scratch[..tile_rows]);
                self.stats.programs += 1;
            }

            // ② query segment, padded with `true` so pads always match
            self.qseg_scratch[..cols.len()].copy_from_slice(&query[cols.clone()]);
            self.qseg_scratch[cols.len()..].fill(true);

            // ③ associative tiled MAC
            let partial = self.array.search(&self.qseg_scratch);
            self.stats.searches += 1;
            self.stats.adc_conversions += partial.len();

            // ④ concatenate/accumulate, removing the pad offset (+pad_d)
            for (i, r) in rows.clone().enumerate() {
                result[r] += partial[i] - pad_d as f64;
            }
        }
        result
    }

    /// Key-stationary batch execution (Fig. 5's amortisation): program each
    /// key tile once, search it with every query before moving on.
    /// Returns one score vector per query; `stats` then shows
    /// programs = tiles and searches = tiles * m.
    pub fn scores_batch(&mut self, queries: &[Vec<bool>], keys: &[Vec<bool>]) -> Vec<Vec<f64>> {
        let m = queries.len();
        if m == 0 {
            return Vec::new();
        }
        let n = keys.len();
        let d_k = queries[0].len();
        assert!(queries.iter().all(|q| q.len() == d_k), "ragged queries");
        assert!(keys.iter().all(|k| k.len() == d_k), "ragged key matrix");
        let (cam_h, cam_w) = (self.array.height, self.array.width);
        let plan = TilePlan::single_query(n, d_k, cam_h, cam_w);
        let mut results = vec![vec![0.0f64; n]; m];

        for step in &plan.steps {
            let rows = plan.h_range(step.h_tile);
            let cols = plan.v_range(step.v_tile);
            let pad_d = cam_w - cols.len();
            let tile: Vec<Vec<bool>> = rows
                .clone()
                .map(|r| {
                    let mut bits: Vec<bool> = keys[r][cols.clone()].to_vec();
                    bits.extend(std::iter::repeat(true).take(pad_d));
                    bits
                })
                .collect();
            self.array.program(&tile); // once per tile
            self.stats.programs += 1;
            for (qi, query) in queries.iter().enumerate() {
                let mut qseg: Vec<bool> = query[cols.clone()].to_vec();
                qseg.extend(std::iter::repeat(true).take(pad_d));
                let partial = self.array.search(&qseg);
                self.stats.searches += 1;
                self.stats.adc_conversions += partial.len();
                for (i, r) in rows.clone().enumerate() {
                    results[qi][r] += partial[i] - pad_d as f64;
                }
            }
        }
        results
    }

    /// Ideal digital reference (XNOR-popcount) for the same inputs,
    /// evaluated per bit — the slow bool-loop oracle the word-parallel
    /// [`PackedBitKeys`] path is pinned against.
    pub fn scores_ideal(query: &[bool], keys: &[Vec<bool>]) -> Vec<f64> {
        keys.iter()
            .map(|k| {
                let matches = k.iter().zip(query).filter(|(a, b)| a == b).count();
                2.0 * matches as f64 - query.len() as f64
            })
            .collect()
    }

    /// Total energy of the run so far \[J\] under the given model.
    pub fn energy(&self, model: &EnergyModel) -> f64 {
        self.stats.programs as f64 * model.program_tile()
            + self.stats.searches as f64 * model.search_tile()
    }
}

/// Word-packed binary key memory for the exact digital search path: the
/// paper's bit-parallel BA-CAM match (all key bits compared in one
/// constant-time search) as one XOR+popcount per 64 key-bit lanes,
/// replacing the per-bit bool loop of [`BimvEngine::scores_ideal`] (§Perf
/// iteration 6, the bimv-level leg of FlashCAM). Pack once, score many
/// queries — the same key-stationary amortisation the analog walk gets
/// from reusing a programmed tile.
///
/// Layout matches `accuracy::functional::PackedKeys`: LSB-first u64
/// words, lanes at or past `d_k` left clear. Cleared tail lanes XNOR to
/// a match in both operands, so instead of a tail mask per row the fixed
/// overhang is subtracted once from every popcount sum.
#[derive(Clone, Debug)]
pub struct PackedBitKeys {
    pub n: usize,
    pub d_k: usize,
    words: usize,
    bits: Vec<u64>, // row-major n x words
}

impl PackedBitKeys {
    /// Pack N rows of d_k bits (true = +1).
    pub fn pack(keys: &[Vec<bool>]) -> Self {
        let n = keys.len();
        let d_k = keys.first().map_or(0, |k| k.len());
        assert!(keys.iter().all(|k| k.len() == d_k), "ragged key matrix");
        let words = d_k.div_ceil(64);
        let mut bits = vec![0u64; n * words];
        for (r, key) in keys.iter().enumerate() {
            pack_bools_into(key, &mut bits[r * words..(r + 1) * words]);
        }
        PackedBitKeys { n, d_k, words, bits }
    }

    /// Signed scores q . K^T, bit-identical to
    /// [`BimvEngine::scores_ideal`] on the same inputs.
    pub fn scores(&self, query: &[bool]) -> Vec<f64> {
        assert_eq!(query.len(), self.d_k, "query width != packed d_k");
        let mut qp = vec![0u64; self.words];
        pack_bools_into(query, &mut qp);
        let overhang = (self.words * 64 - self.d_k) as u32;
        (0..self.n)
            .map(|r| {
                let row = &self.bits[r * self.words..(r + 1) * self.words];
                let mut matches = 0u32;
                for w in 0..self.words {
                    matches += (!(qp[w] ^ row[w])).count_ones();
                }
                2.0 * (matches - overhang) as f64 - self.d_k as f64
            })
            .collect()
    }
}

/// Pack bits (true -> 1) into u64 words, LSB-first; lanes past the input
/// length stay clear.
fn pack_bools_into(x: &[bool], out: &mut [u64]) {
    for w in out.iter_mut() {
        *w = 0;
    }
    for (i, &b) in x.iter().enumerate() {
        if b {
            out[i / 64] |= 1u64 << (i % 64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::rng::Rng;

    fn rand_bits(rng: &mut Rng, n: usize) -> Vec<bool> {
        (0..n).map(|_| rng.bool()).collect()
    }

    #[test]
    fn exact_for_paper_geometry() {
        let mut rng = Rng::new(20);
        let mut eng = BimvEngine::new(16, 64);
        let q = rand_bits(&mut rng, 64);
        let keys: Vec<Vec<bool>> = (0..256).map(|_| rand_bits(&mut rng, 64)).collect();
        let got = eng.scores(&q, &keys);
        let want = BimvEngine::scores_ideal(&q, &keys);
        for (g, w) in got.iter().zip(&want) {
            // nominal array: only wire-parasitic dilution (≤ 2 codes)
            assert!((g - w).abs() <= 2.0, "{g} vs {w}");
        }
    }

    #[test]
    fn stats_match_plan() {
        let mut rng = Rng::new(21);
        let mut eng = BimvEngine::new(16, 64);
        let q = rand_bits(&mut rng, 64);
        let keys: Vec<Vec<bool>> = (0..64).map(|_| rand_bits(&mut rng, 64)).collect();
        eng.scores(&q, &keys);
        assert_eq!(eng.stats.programs, 4);
        assert_eq!(eng.stats.searches, 4);
        assert_eq!(eng.stats.adc_conversions, 64);
    }

    #[test]
    fn property_arbitrary_shapes_track_ideal() {
        check("bimv vs ideal", 30, |rng| {
            let n = 1 + rng.index(100);
            let d_k = 1 + rng.index(150);
            let mut eng = BimvEngine::new(16, 64);
            let q: Vec<bool> = (0..d_k).map(|_| rng.bool()).collect();
            let keys: Vec<Vec<bool>> =
                (0..n).map(|_| (0..d_k).map(|_| rng.bool()).collect()).collect();
            let got = eng.scores(&q, &keys);
            let want = BimvEngine::scores_ideal(&q, &keys);
            assert_eq!(got.len(), n);
            for (g, w) in got.iter().zip(&want) {
                // one ADC code per vertical tile of slack
                let v_tiles = d_k.div_ceil(64) as f64;
                assert!(
                    (g - w).abs() <= 2.0 * v_tiles,
                    "n={n} d_k={d_k}: {g} vs {w}"
                );
            }
        });
    }

    #[test]
    fn property_scores_have_correct_parity() {
        // binary dot products of ±1 vectors have fixed parity: d_k mod 2
        check("score parity", 30, |rng| {
            let d_k = 64; // exact ADC regime
            let mut eng = BimvEngine::new(16, 64);
            let q: Vec<bool> = (0..d_k).map(|_| rng.bool()).collect();
            let keys: Vec<Vec<bool>> =
                (0..16).map(|_| (0..d_k).map(|_| rng.bool()).collect()).collect();
            for s in eng.scores(&q, &keys) {
                let si = s.round() as i64;
                assert_eq!((si + d_k as i64) % 2, 0, "score {si} wrong parity");
            }
        });
    }

    #[test]
    fn energy_accounts_programs_and_searches() {
        let mut rng = Rng::new(22);
        let mut eng = BimvEngine::new(16, 64);
        let model = EnergyModel::new(16, 64);
        let q = rand_bits(&mut rng, 64);
        let keys: Vec<Vec<bool>> = (0..32).map(|_| rand_bits(&mut rng, 64)).collect();
        eng.scores(&q, &keys);
        let e = eng.energy(&model);
        let expect = 2.0 * model.program_tile() + 2.0 * model.search_tile();
        assert!((e - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn key_stationary_matches_per_query_results() {
        let mut rng = Rng::new(23);
        let queries: Vec<Vec<bool>> = (0..5).map(|_| rand_bits(&mut rng, 64)).collect();
        let keys: Vec<Vec<bool>> = (0..64).map(|_| rand_bits(&mut rng, 64)).collect();
        let mut batch_eng = BimvEngine::new(16, 64);
        let batched = batch_eng.scores_batch(&queries, &keys);
        for (q, got) in queries.iter().zip(&batched) {
            let mut single = BimvEngine::new(16, 64);
            assert_eq!(&single.scores(q, &keys), got);
        }
    }

    #[test]
    fn key_stationary_amortises_programming_energy() {
        // the measured Fig. 5 effect: per-query energy falls with batch
        let mut rng = Rng::new(24);
        let keys: Vec<Vec<bool>> = (0..64).map(|_| rand_bits(&mut rng, 64)).collect();
        let model = EnergyModel::new(16, 64);

        let queries1: Vec<Vec<bool>> = vec![rand_bits(&mut rng, 64)];
        let mut e1 = BimvEngine::new(16, 64);
        e1.scores_batch(&queries1, &keys);
        let per_query_1 = e1.energy(&model);

        let queries32: Vec<Vec<bool>> = (0..32).map(|_| rand_bits(&mut rng, 64)).collect();
        let mut e32 = BimvEngine::new(16, 64);
        e32.scores_batch(&queries32, &keys);
        let per_query_32 = e32.energy(&model) / 32.0;

        assert!(per_query_32 < per_query_1);
        assert_eq!(e32.stats.programs, 4); // one program per tile
        assert_eq!(e32.stats.searches, 4 * 32);
    }

    #[test]
    fn property_tile_boundary_shapes_match_i32_oracle() {
        // ISSUE 1 satellite: randomized sweep of every (n, d_k) pair from
        // the tile-boundary set {1, cam-1, cam, cam+1, 3*cam+7} against a
        // naive i32 ±1 dot-product oracle. Exercises exact-fit, one-off
        // and multi-tile-plus-remainder walks in both dimensions.
        let (cam_h, cam_w) = (16usize, 64usize);
        let ns = [1, cam_h - 1, cam_h, cam_h + 1, 3 * cam_h + 7];
        let ds = [1, cam_w - 1, cam_w, cam_w + 1, 3 * cam_w + 7];
        check("bimv tile-boundary shapes vs i32 oracle", 8, |rng| {
            for &n in &ns {
                for &d_k in &ds {
                    let mut eng = BimvEngine::new(cam_h, cam_w);
                    let q: Vec<bool> = (0..d_k).map(|_| rng.bool()).collect();
                    let keys: Vec<Vec<bool>> =
                        (0..n).map(|_| (0..d_k).map(|_| rng.bool()).collect()).collect();
                    let got = eng.scores(&q, &keys);
                    assert_eq!(got.len(), n, "n={n} d_k={d_k}: wrong score count");
                    // naive i32 oracle over the ±1 encoding
                    let want: Vec<i32> = keys
                        .iter()
                        .map(|k| {
                            k.iter()
                                .zip(&q)
                                .map(|(&kb, &qb)| {
                                    let kv: i32 = if kb { 1 } else { -1 };
                                    let qv: i32 = if qb { 1 } else { -1 };
                                    kv * qv
                                })
                                .sum()
                        })
                        .collect();
                    // analog slack: one ADC code (2 counts) per vertical tile
                    let tol = 2.0 * d_k.div_ceil(cam_w) as f64;
                    for (i, (g, &w)) in got.iter().zip(&want).enumerate() {
                        assert!(
                            (g - f64::from(w)).abs() <= tol,
                            "n={n} d_k={d_k} row {i}: engine {g} vs i32 oracle {w}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn property_word_parallel_search_matches_bool_loop_oracle() {
        // ISSUE 7 satellite: the u64 XOR+popcount search vs the scalar
        // bool-loop oracle over word-boundary widths × tile-boundary
        // heights, incl. the all-pad memory (every row the all-true pad
        // pattern) and the single-valid-row-in-pads edge cases
        let ds = [48usize, 63, 64, 65, 96, 128];
        let ns = [1usize, 15, 16, 17, 3 * 16 + 7];
        check("word-parallel search = bool-loop oracle", 6, |rng| {
            for &d_k in &ds {
                for &n in &ns {
                    let q: Vec<bool> = (0..d_k).map(|_| rng.bool()).collect();
                    let keys: Vec<Vec<bool>> =
                        (0..n).map(|_| (0..d_k).map(|_| rng.bool()).collect()).collect();
                    assert_eq!(
                        PackedBitKeys::pack(&keys).scores(&q),
                        BimvEngine::scores_ideal(&q, &keys),
                        "d_k={d_k} n={n}"
                    );
                    // all-pad: every row holds the all-(+1) pad pattern
                    let pad = vec![vec![true; d_k]; n];
                    assert_eq!(
                        PackedBitKeys::pack(&pad).scores(&q),
                        BimvEngine::scores_ideal(&q, &pad),
                        "d_k={d_k} n={n} all-pad"
                    );
                    // a single live row among pads
                    let mut one = pad.clone();
                    one[rng.index(n)] = (0..d_k).map(|_| rng.bool()).collect();
                    assert_eq!(
                        PackedBitKeys::pack(&one).scores(&q),
                        BimvEngine::scores_ideal(&q, &one),
                        "d_k={d_k} n={n} single-valid"
                    );
                }
            }
        });
    }

    #[test]
    fn word_parallel_search_tracks_analog_engine_within_slack() {
        // the packed digital path sits where scores_ideal did in the
        // analog-slack contract: within one ADC code per vertical tile
        let mut rng = Rng::new(25);
        let d_k = 3 * 64 + 7;
        let q = rand_bits(&mut rng, d_k);
        let keys: Vec<Vec<bool>> = (0..55).map(|_| rand_bits(&mut rng, d_k)).collect();
        let analog = BimvEngine::new(16, 64).scores(&q, &keys);
        let packed = PackedBitKeys::pack(&keys).scores(&q);
        let tol = 2.0 * d_k.div_ceil(64) as f64;
        for (a, p) in analog.iter().zip(&packed) {
            assert!((a - p).abs() <= tol, "{a} vs {p}");
        }
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_keys_rejected() {
        let mut eng = BimvEngine::new(16, 64);
        let keys = vec![vec![true; 64], vec![true; 63]];
        eng.scores(&vec![true; 64], &keys);
    }
}
