//! Bit-sliced binary-integer MatMul (Sec. II-B1, last paragraph).
//!
//! "For higher-precision V, we decompose K^T entries into binary slices
//! (LSB -> MSB) and run per-slice BIMM. Slice outputs are digitally shifted
//! and accumulated, adding precision without changing the CAM path. This
//! supports binary-integer MatMul and quantized V in int2, int4, int8."
//!
//! Slices use offset-binary encoding: an unsigned integer x in [0, 2^B) is
//! written in bits b_i in {0,1}; each bit maps to the CAM's ±1 domain as
//! (2*b_i - 1), so  x = sum_i 2^i * (s_i + 1)/2  where s_i is the ±1 slice.
//! The reconstruction therefore shifts/adds the per-slice ±1 BIMV outputs
//! plus a fixed offset the digital path subtracts — the same fixed-function
//! trick as the score map.

use super::engine::{BimvEngine, PackedBitKeys};

/// Decompose unsigned ints (< 2^bits) into ±1 bit slices, LSB first.
/// Returns `bits` matrices of shape `[n][d]`: `slice[s][r][c]` in
/// {true,false} (true = +1 = bit set).
pub fn decompose(values: &[Vec<u32>], bits: u32) -> Vec<Vec<Vec<bool>>> {
    let n = values.len();
    (0..bits)
        .map(|s| {
            (0..n)
                .map(|r| values[r].iter().map(|&v| (v >> s) & 1 == 1).collect())
                .collect()
        })
        .collect()
}

/// Binary query (±1) times unsigned-int matrix via per-slice BIMV.
///
/// `query`: d bits (±1 domain); `values`: N rows of d unsigned ints, each
/// < 2^bits. Returns the exact integer products q . v_r.
pub fn bimv_int(
    engine: &mut BimvEngine,
    query: &[bool],
    values: &[Vec<u32>],
    bits: u32,
) -> Vec<f64> {
    let d = query.len();
    assert!(values.iter().all(|r| r.len() == d));
    assert!(
        values.iter().flatten().all(|&v| v < (1 << bits)),
        "value exceeds {bits}-bit range"
    );
    let n = values.len();
    // sum of query elements (±1), needed for the offset term:
    // q . x = sum_i 2^i * (q . s_i + q . 1) / 2
    let q_sum: f64 = query.iter().map(|&b| if b { 1.0 } else { -1.0 }).sum();

    let mut out = vec![0.0f64; n];
    for (s, slice) in decompose(values, bits).iter().enumerate() {
        let partial = engine.scores(query, slice); // q . s_i per row
        let w = (1u64 << s) as f64;
        for r in 0..n {
            out[r] += w * (partial[r] + q_sum) / 2.0;
        }
    }
    out
}

/// As [`bimv_int`] over the word-parallel digital search path: each ±1
/// slice is scored through [`PackedBitKeys`] (one XOR+popcount per 64
/// lanes, §Perf iteration 6's bimv leg) instead of the analog tile walk,
/// then reconstructed with the identical shift/offset arithmetic. Exact
/// — no analog slack — and bit-identical to [`bimv_int_ideal`].
pub fn bimv_int_bitparallel(query: &[bool], values: &[Vec<u32>], bits: u32) -> Vec<f64> {
    let d = query.len();
    assert!(values.iter().all(|r| r.len() == d));
    assert!(
        values.iter().flatten().all(|&v| v < (1 << bits)),
        "value exceeds {bits}-bit range"
    );
    let n = values.len();
    if n == 0 {
        return Vec::new();
    }
    let q_sum: f64 = query.iter().map(|&b| if b { 1.0 } else { -1.0 }).sum();
    let mut out = vec![0.0f64; n];
    for (s, slice) in decompose(values, bits).iter().enumerate() {
        let partial = PackedBitKeys::pack(slice).scores(query);
        let w = (1u64 << s) as f64;
        for r in 0..n {
            out[r] += w * (partial[r] + q_sum) / 2.0;
        }
    }
    out
}

/// Ideal reference: exact integer dot products.
pub fn bimv_int_ideal(query: &[bool], values: &[Vec<u32>]) -> Vec<f64> {
    values
        .iter()
        .map(|row| {
            row.iter()
                .zip(query)
                .map(|(&v, &q)| v as f64 * if q { 1.0 } else { -1.0 })
                .sum()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;
    use crate::util::rng::Rng;

    #[test]
    fn decompose_roundtrip() {
        let vals = vec![vec![0u32, 1, 2, 3, 7, 255]];
        let slices = decompose(&vals, 8);
        for (c, &v) in vals[0].iter().enumerate() {
            let mut rec = 0u32;
            for (s, slice) in slices.iter().enumerate() {
                if slice[0][c] {
                    rec |= 1 << s;
                }
            }
            assert_eq!(rec, v);
        }
    }

    #[test]
    fn int8_exact_on_cam_path() {
        let mut rng = Rng::new(30);
        let mut eng = BimvEngine::new(16, 64);
        let q: Vec<bool> = (0..64).map(|_| rng.bool()).collect();
        let vals: Vec<Vec<u32>> = (0..16)
            .map(|_| (0..64).map(|_| rng.range(0, 256) as u32).collect())
            .collect();
        let got = bimv_int(&mut eng, &q, &vals, 8);
        let want = bimv_int_ideal(&q, &vals);
        for (g, w) in got.iter().zip(&want) {
            // 8 slices x <=1 code of analog slack, weighted by 2^s/2:
            // worst case sum_i 2^i/2 * 2 = 255; in practice the nominal
            // array is exact at d_k=64, so require exactness
            assert_eq!(g, w);
        }
    }

    #[test]
    fn property_int2_int4_exact() {
        check("bitslice int2/int4", 20, |rng| {
            let bits = if rng.bool() { 2 } else { 4 };
            let d = 64;
            let n = 1 + rng.index(32);
            let mut eng = BimvEngine::new(16, 64);
            let q: Vec<bool> = (0..d).map(|_| rng.bool()).collect();
            let vals: Vec<Vec<u32>> = (0..n)
                .map(|_| (0..d).map(|_| rng.range(0, 1 << bits) as u32).collect())
                .collect();
            let got = bimv_int(&mut eng, &q, &vals, bits);
            let want = bimv_int_ideal(&q, &vals);
            assert_eq!(got, want);
        });
    }

    #[test]
    fn property_bitparallel_int_matches_ideal_exactly() {
        // ISSUE 7 satellite: the word-parallel sliced path is EXACT (the
        // analog path is merely within slack) across word-boundary widths
        // and tile-boundary heights
        let ds = [48usize, 63, 64, 65, 96, 128];
        let ns = [1usize, 15, 16, 17, 3 * 16 + 7];
        check("bitparallel sliced BIMV = ideal", 6, |rng| {
            let bits = [2u32, 4, 8][rng.index(3)];
            for &d in &ds {
                for &n in &ns {
                    let q: Vec<bool> = (0..d).map(|_| rng.bool()).collect();
                    let vals: Vec<Vec<u32>> = (0..n)
                        .map(|_| (0..d).map(|_| rng.range(0, 1 << bits) as u32).collect())
                        .collect();
                    assert_eq!(
                        bimv_int_bitparallel(&q, &vals, bits),
                        bimv_int_ideal(&q, &vals),
                        "d={d} n={n} bits={bits}"
                    );
                }
            }
        });
    }

    #[test]
    fn bitparallel_int_matches_analog_cam_path() {
        // same reconstruction arithmetic on both paths: at d=64 the
        // nominal array is exact, so the two agree bit for bit
        let mut rng = Rng::new(32);
        let q: Vec<bool> = (0..64).map(|_| rng.bool()).collect();
        let vals: Vec<Vec<u32>> = (0..16)
            .map(|_| (0..64).map(|_| rng.range(0, 256) as u32).collect())
            .collect();
        let mut eng = BimvEngine::new(16, 64);
        assert_eq!(bimv_int(&mut eng, &q, &vals, 8), bimv_int_bitparallel(&q, &vals, 8));
    }

    #[test]
    #[should_panic(expected = "exceeds 2-bit range")]
    fn range_checked() {
        let mut eng = BimvEngine::new(16, 64);
        bimv_int(&mut eng, &vec![true; 4], &vec![vec![4u32; 4]], 2);
    }

    #[test]
    fn slice_count_scales_energy() {
        let mut rng = Rng::new(31);
        let q: Vec<bool> = (0..64).map(|_| rng.bool()).collect();
        let vals: Vec<Vec<u32>> = (0..16)
            .map(|_| (0..64).map(|_| rng.range(0, 16) as u32).collect())
            .collect();
        let mut e4 = BimvEngine::new(16, 64);
        bimv_int(&mut e4, &q, &vals, 4);
        let mut e8 = BimvEngine::new(16, 64);
        bimv_int(&mut e8, &q, &vals.iter().map(|r| r.clone()).collect::<Vec<_>>(), 8);
        assert_eq!(e8.stats.searches, 2 * e4.stats.searches);
    }
}
