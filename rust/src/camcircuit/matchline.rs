//! Matchline charge-sharing model (Sec. II-A2, Figs. 2/3a).
//!
//! After the match phase each cell capacitor holds either V_DD (match) or
//! ~0 (mismatch). Closing the share switches connects all caps of a row:
//! charge redistributes and the matchline settles to the capacitance-
//! weighted average voltage `V_ML = sum(C_i * V_i) / sum(C_i)`,
//! which for nominal (equal) caps is exactly `matches / width * V_DD` —
//! the linear, delay-free voltage response the paper contrasts with
//! TD-CAM's nonlinear discharge delay. kT/C sampling noise and the RC
//! settling transient are modelled so Fig. 3a's traces regenerate.

use super::cell::{Cell, CellParams};
use crate::util::rng::Rng;

const BOLTZMANN: f64 = 1.380649e-23;

/// One row's matchline: its cells plus parasitic line capacitance.
#[derive(Clone, Debug)]
pub struct Matchline {
    pub cells: Vec<Cell>,
    /// Parasitic wire capacitance \[F\] added to the share node (scales with
    /// row width; ~0.2 fF/cell of routing is a reasonable 65 nm estimate).
    pub wire_cap_f: f64,
    /// Equivalent share-switch resistance \[Ohm\] (sets the RC settle time).
    pub switch_r_ohm: f64,
}

impl Matchline {
    /// Nominal matchline of `width` cells storing `bits`.
    pub fn new(bits: &[bool], params: &CellParams) -> Self {
        Matchline {
            cells: bits.iter().map(|&b| Cell::new(b, params)).collect(),
            wire_cap_f: 0.2e-15 * bits.len() as f64,
            switch_r_ohm: 5e3,
        }
    }

    /// Matchline with per-cell capacitor mismatch.
    pub fn with_mismatch(bits: &[bool], params: &CellParams, sigma: f64, rng: &mut Rng) -> Self {
        Matchline {
            cells: bits
                .iter()
                .map(|&b| Cell::with_mismatch(b, params, sigma, rng))
                .collect(),
            wire_cap_f: 0.2e-15 * bits.len() as f64,
            switch_r_ohm: 5e3,
        }
    }

    pub fn width(&self) -> usize {
        self.cells.len()
    }

    /// Rewrite the stored bits in place (nominal capacitors). §Perf: lets
    /// the BIMV engine reprogram a tile without reallocating cell vectors
    /// on every Fig.-4 step ①.
    pub fn reprogram(&mut self, bits: &[bool], params: &CellParams) {
        self.cells.clear();
        self.cells.extend(bits.iter().map(|&b| Cell::new(b, params)));
        self.wire_cap_f = 0.2e-15 * bits.len() as f64;
    }

    /// Number of cells whose XNOR matches the query.
    pub fn match_count(&self, query: &[bool]) -> usize {
        debug_assert_eq!(query.len(), self.cells.len());
        self.cells
            .iter()
            .zip(query)
            .filter(|(c, &q)| c.matches(q))
            .count()
    }

    /// Final settled matchline voltage \[V\] after ideal charge sharing
    /// (capacitance-weighted average; wire parasitics start discharged).
    pub fn settled_voltage(&self, query: &[bool], params: &CellParams) -> f64 {
        let mut charge = 0.0;
        let mut cap = self.wire_cap_f;
        for (c, &q) in self.cells.iter().zip(query) {
            charge += c.post_match_charge(q, params);
            cap += c.cap_f;
        }
        charge / cap
    }

    /// Settled voltage plus kT/C thermal sampling noise.
    pub fn sensed_voltage(
        &self,
        query: &[bool],
        params: &CellParams,
        temp_k: f64,
        rng: &mut Rng,
    ) -> f64 {
        let total_cap: f64 = self.wire_cap_f + self.cells.iter().map(|c| c.cap_f).sum::<f64>();
        let v = self.settled_voltage(query, params);
        let ktc_sigma = (BOLTZMANN * temp_k / total_cap).sqrt();
        (v + rng.normal(0.0, ktc_sigma)).clamp(0.0, params.vdd)
    }

    /// RC settling transient: V(t) toward the settled value with time
    /// constant tau = R_switch * C_total/width (per-cell share path).
    /// Regenerates Fig. 3a's voltage-vs-time traces.
    pub fn transient(&self, query: &[bool], params: &CellParams, t_ns: f64) -> f64 {
        let v_final = self.settled_voltage(query, params);
        // before sharing, the sense node sits at the precharge rail only if
        // every cap matched; model the node starting from the mean of the
        // first cell's state for a simple single-pole response
        let total_cap: f64 = self.wire_cap_f + self.cells.iter().map(|c| c.cap_f).sum::<f64>();
        let tau_s = self.switch_r_ohm * total_cap / self.width().max(1) as f64;
        let t_s = t_ns * 1e-9;
        v_final * (1.0 - (-t_s / tau_s).exp())
    }

    /// 5-tau settle time in nanoseconds (the association stage's CAM
    /// serialization latency floor).
    pub fn settle_time_ns(&self) -> f64 {
        let total_cap: f64 = self.wire_cap_f + self.cells.iter().map(|c| c.cap_f).sum::<f64>();
        let tau_s = self.switch_r_ohm * total_cap / self.width().max(1) as f64;
        5.0 * tau_s * 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pattern(width: usize, matches: usize) -> (Matchline, Vec<bool>) {
        let params = CellParams::default();
        let bits: Vec<bool> = vec![true; width];
        let ml = Matchline::new(&bits, &params);
        // query matches on the first `matches` cells
        let query: Vec<bool> = (0..width).map(|i| i < matches).collect();
        (ml, query)
    }

    #[test]
    fn voltage_linear_in_match_count() {
        let params = CellParams::default();
        for width in [10usize, 16, 64] {
            for m in 0..=width {
                let (ml, query) = pattern(width, m);
                assert_eq!(ml.match_count(&query), m);
                let v = ml.settled_voltage(&query, &params);
                // wire parasitic dilutes slightly; relative linearity holds
                let ideal = m as f64 / width as f64 * params.vdd;
                let dilution = (width as f64 * 22e-15) / (width as f64 * 22e-15 + ml.wire_cap_f);
                assert!((v - ideal * dilution).abs() < 1e-9, "w={width} m={m}");
            }
        }
    }

    #[test]
    fn full_match_near_vdd() {
        let params = CellParams::default();
        let (ml, query) = pattern(64, 64);
        let v = ml.settled_voltage(&query, &params);
        assert!(v > 0.98 * params.vdd, "v={v}");
    }

    #[test]
    fn zero_match_is_zero() {
        let params = CellParams::default();
        let (ml, query) = pattern(64, 0);
        assert_eq!(ml.settled_voltage(&query, &params), 0.0);
    }

    #[test]
    fn transient_monotone_to_settled() {
        let params = CellParams::default();
        let (ml, query) = pattern(16, 9);
        let v_final = ml.settled_voltage(&query, &params);
        let mut last = -1.0;
        for t in [0.01, 0.05, 0.1, 0.5, 1.0, 5.0] {
            let v = ml.transient(&query, &params, t);
            assert!(v >= last);
            assert!(v <= v_final + 1e-12);
            last = v;
        }
        assert!((ml.transient(&query, &params, 100.0) - v_final).abs() < 1e-6);
    }

    #[test]
    fn settle_time_sub_nanosecond_for_500mhz() {
        // the paper's BA-CAM runs at 500 MHz (Table I) => settle << 2 ns
        let params = CellParams::default();
        let (ml, _q) = pattern(64, 32);
        assert!(
            ml.settle_time_ns() < 2.0,
            "settle {} ns too slow for 500 MHz",
            ml.settle_time_ns()
        );
    }

    #[test]
    fn ktc_noise_small_but_present() {
        let params = CellParams::default();
        let (ml, query) = pattern(64, 32);
        let mut rng = Rng::new(2);
        let clean = ml.settled_voltage(&query, &params);
        let samples: Vec<f64> = (0..500)
            .map(|_| ml.sensed_voltage(&query, &params, 300.0, &mut rng) - clean)
            .collect();
        let sd = crate::util::stats::std_dev(&samples);
        assert!(sd > 0.0);
        // kT/C at ~1.4 pF total is ~54 uV — far below half an ADC LSB
        assert!(sd < 1e-3, "ktc sigma {sd}");
    }

    #[test]
    fn mismatch_shifts_voltage_but_bounded() {
        let params = CellParams::default();
        let mut rng = Rng::new(3);
        let bits = vec![true; 64];
        let query: Vec<bool> = (0..64).map(|i| i < 32).collect();
        let mut devs = Vec::new();
        for _ in 0..200 {
            let ml = Matchline::with_mismatch(&bits, &params, 0.014, &mut rng);
            let v = ml.settled_voltage(&query, &params);
            let nominal = Matchline::new(&bits, &params).settled_voltage(&query, &params);
            devs.push(((v - nominal) / nominal * 100.0).abs());
        }
        // paper: matchline deviation within 5.05% under PVT
        let max_dev = devs.iter().cloned().fold(0.0f64, f64::max);
        assert!(max_dev < 5.05, "max deviation {max_dev}%");
    }
}
