//! BA-CAM array: program keys, broadcast a query, sense all matchlines
//! (Fig. 2). This is the circuit-accurate functional unit the BIMV engine
//! (Sec. II-B) tiles over and the association stage drives.
//!
//! The four-phase operation (precharge, broadcast, match, charge-share) is
//! folded into `search`: phases only matter for latency/energy, which the
//! `EnergyModel` and `arch::pipeline` account separately.

use super::adc::SarAdc;
use super::cell::CellParams;
use super::matchline::Matchline;
use super::pvt::{corner_params, Corner};
use crate::util::rng::Rng;

/// A CAM_H x CAM_W BA-CAM array with one shared SAR ADC.
#[derive(Clone, Debug)]
pub struct BaCamArray {
    pub height: usize,
    pub width: usize,
    pub params: CellParams,
    pub adc: SarAdc,
    rows: Vec<Matchline>,
    /// Matchline mismatch sigma baked at construction (0 = nominal).
    pub mismatch_sigma: f64,
    rng: Rng,
}

impl BaCamArray {
    /// Nominal (noise-free) array, paper geometry by default (16x64).
    pub fn new(height: usize, width: usize) -> Self {
        let params = CellParams::default();
        BaCamArray {
            height,
            width,
            params,
            adc: SarAdc::new(6, params.vdd),
            rows: Vec::new(),
            mismatch_sigma: 0.0,
            rng: Rng::new(0),
        }
    }

    /// Array with PVT corner and capacitor mismatch (Monte-Carlo instance).
    pub fn with_pvt(height: usize, width: usize, corner: Corner, sigma: f64, seed: u64) -> Self {
        let params = corner_params(corner);
        BaCamArray {
            height,
            width,
            params,
            adc: SarAdc::new(6, params.vdd),
            rows: Vec::new(),
            mismatch_sigma: sigma,
            rng: Rng::new(seed),
        }
    }

    /// Program step (Fig. 4 step ①): load a tile of binary keys. `keys` is
    /// row-major, `keys.len() <= height`, each row exactly `width` bits.
    ///
    /// §Perf: nominal (sigma = 0) arrays reprogram rows in place instead of
    /// reallocating cell vectors — programming is the per-tile hot path of
    /// every BIMV walk. Mismatched arrays rebuild (each programming is a
    /// fresh Monte-Carlo draw).
    pub fn program(&mut self, keys: &[Vec<bool>]) {
        assert!(keys.len() <= self.height, "tile taller than array");
        if self.mismatch_sigma > 0.0 {
            self.rows.clear();
            for bits in keys {
                assert_eq!(bits.len(), self.width, "key width mismatch");
                self.rows.push(Matchline::with_mismatch(
                    bits,
                    &self.params,
                    self.mismatch_sigma,
                    &mut self.rng,
                ));
            }
            return;
        }
        self.rows.truncate(keys.len());
        for (i, bits) in keys.iter().enumerate() {
            assert_eq!(bits.len(), self.width, "key width mismatch");
            match self.rows.get_mut(i) {
                Some(row) => row.reprogram(bits, &self.params),
                None => self.rows.push(Matchline::new(bits, &self.params)),
            }
        }
    }

    pub fn rows_programmed(&self) -> usize {
        self.rows.len()
    }

    /// Search (steps ②–③): broadcast `query`, sense every matchline,
    /// digitise through the shared ADC, apply the multiply-subtract.
    /// Returns signed scores in [-width, width], one per programmed row.
    pub fn search(&mut self, query: &[bool]) -> Vec<f64> {
        assert_eq!(query.len(), self.width, "query width mismatch");
        let temp = 300.0;
        let mut scores = Vec::with_capacity(self.rows.len());
        for row in &self.rows {
            let v = if self.mismatch_sigma > 0.0 {
                row.sensed_voltage(query, &self.params, temp, &mut self.rng)
            } else {
                row.settled_voltage(query, &self.params)
            };
            scores.push(self.adc.score(v, self.width));
        }
        scores
    }

    /// Ideal digital reference for the same tile (XNOR-popcount).
    pub fn search_ideal(&self, query: &[bool]) -> Vec<f64> {
        self.rows
            .iter()
            .map(|r| 2.0 * r.match_count(query) as f64 - self.width as f64)
            .collect()
    }
}

/// Pack a ±1 float vector into the boolean domain (+1 -> true).
pub fn pm_to_bits(x: &[f32]) -> Vec<bool> {
    x.iter().map(|&v| v >= 0.0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    fn random_keys(rng: &mut Rng, h: usize, w: usize) -> Vec<Vec<bool>> {
        (0..h).map(|_| (0..w).map(|_| rng.bool()).collect()).collect()
    }

    #[test]
    fn nominal_search_equals_ideal() {
        let mut rng = Rng::new(10);
        let mut arr = BaCamArray::new(16, 64);
        let keys = random_keys(&mut rng, 16, 64);
        arr.program(&keys);
        let q: Vec<bool> = (0..64).map(|_| rng.bool()).collect();
        // wire parasitic dilution (~0.9%) stays under half an ADC LSB for
        // mid-range codes but can flip codes at the extremes; allow 1 code
        let analog = arr.search(&q);
        let ideal = arr.search_ideal(&q);
        for (a, i) in analog.iter().zip(&ideal) {
            assert!((a - i).abs() <= 2.0, "analog {a} vs ideal {i}");
        }
    }

    #[test]
    fn property_scores_bounded_and_consistent() {
        check("array scores bounded", 50, |rng| {
            let h = 1 + rng.index(16);
            let mut arr = BaCamArray::new(16, 64);
            let keys: Vec<Vec<bool>> =
                (0..h).map(|_| (0..64).map(|_| rng.bool()).collect()).collect();
            arr.program(&keys);
            let q: Vec<bool> = (0..64).map(|_| rng.bool()).collect();
            for s in arr.search(&q) {
                assert!((-64.0..=64.0).contains(&s));
            }
            assert_eq!(arr.search(&q).len(), h);
        });
    }

    #[test]
    fn self_match_is_full_scale() {
        let mut rng = Rng::new(11);
        let mut arr = BaCamArray::new(16, 64);
        let keys = random_keys(&mut rng, 4, 64);
        arr.program(&keys);
        for (i, key) in keys.iter().enumerate() {
            let scores = arr.search(key);
            // row i stores exactly the query -> near +64 (wire dilution may
            // cost one code)
            assert!(scores[i] >= 62.0, "row {i} score {}", scores[i]);
        }
    }

    #[test]
    fn reprogram_replaces_contents() {
        let mut rng = Rng::new(12);
        let mut arr = BaCamArray::new(16, 64);
        arr.program(&random_keys(&mut rng, 16, 64));
        assert_eq!(arr.rows_programmed(), 16);
        arr.program(&random_keys(&mut rng, 3, 64));
        assert_eq!(arr.rows_programmed(), 3);
    }

    #[test]
    #[should_panic(expected = "tile taller")]
    fn overheight_rejected() {
        let mut arr = BaCamArray::new(2, 8);
        arr.program(&vec![vec![true; 8]; 3]);
    }

    #[test]
    fn pvt_instance_close_to_ideal() {
        let mut rng = Rng::new(13);
        let mut arr = BaCamArray::with_pvt(16, 64, Corner::SS, 0.014, 99);
        let keys = random_keys(&mut rng, 16, 64);
        arr.program(&keys);
        let q: Vec<bool> = (0..64).map(|_| rng.bool()).collect();
        let noisy = arr.search(&q);
        let ideal = arr.search_ideal(&q);
        for (a, i) in noisy.iter().zip(&ideal) {
            // a 1.4% voltage sigma is ~0.9 match counts => within a few codes
            assert!((a - i).abs() <= 8.0, "noisy {a} vs ideal {i}");
        }
    }

    #[test]
    fn pm_to_bits_roundtrip() {
        let x = [1.0f32, -1.0, 1.0, -1.0];
        assert_eq!(pm_to_bits(&x), vec![true, false, true, false]);
    }
}
