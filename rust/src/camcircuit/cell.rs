//! 10T1C BA-CAM cell model (Sec. II-A1, Fig. 2 inset).
//!
//! Each cell stores one bit in SRAM logic and holds its match result on a
//! 22 fF MIM capacitor: the cell XNORs the broadcast query bit against the
//! stored bit; on a match the precharged capacitor stays at V_DD, otherwise
//! it is discharged to ground. Charge sharing across a row's capacitors
//! then averages the per-cell voltages on the matchline.

use crate::util::rng::Rng;

/// Electrical parameters of one cell (65 nm nominal values from Sec. II).
#[derive(Clone, Copy, Debug)]
pub struct CellParams {
    /// Match-result MIM capacitor \[F\]. Paper: 22 fF.
    pub cap_f: f64,
    /// Supply voltage \[V\]. Paper: 1.2 V (Table I).
    pub vdd: f64,
    /// Residual voltage left on a *discharged* capacitor \[V\] — the pull-down
    /// path is not ideal; nominally ~0.
    pub v_residual: f64,
}

impl Default for CellParams {
    fn default() -> Self {
        CellParams {
            cap_f: 22e-15,
            vdd: 1.2,
            v_residual: 0.0,
        }
    }
}

/// One 10T1C cell: stored bit + its (possibly mismatched) capacitor.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    /// Stored key bit.
    pub bit: bool,
    /// Actual capacitance after process mismatch \[F\].
    pub cap_f: f64,
}

impl Cell {
    /// Nominal cell storing `bit`.
    pub fn new(bit: bool, params: &CellParams) -> Self {
        Cell {
            bit,
            cap_f: params.cap_f,
        }
    }

    /// Cell with lognormal-ish capacitor mismatch: C = C0 * (1 + sigma*g).
    /// `sigma` is the relative mismatch (the paper simulates 1.4 %).
    pub fn with_mismatch(bit: bool, params: &CellParams, sigma: f64, rng: &mut Rng) -> Self {
        let factor = (1.0 + sigma * rng.gauss()).max(0.05);
        Cell {
            bit,
            cap_f: params.cap_f * factor,
        }
    }

    /// XNOR compare against the broadcast query bit.
    pub fn matches(&self, query_bit: bool) -> bool {
        self.bit == query_bit
    }

    /// Voltage this cell contributes *before* charge sharing: V_DD if the
    /// precharged cap survived the match phase, else the residual.
    pub fn post_match_voltage(&self, query_bit: bool, params: &CellParams) -> f64 {
        if self.matches(query_bit) {
            params.vdd
        } else {
            params.v_residual
        }
    }

    /// Charge held after the match phase \[C\].
    pub fn post_match_charge(&self, query_bit: bool, params: &CellParams) -> f64 {
        self.cap_f * self.post_match_voltage(query_bit, params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xnor_truth_table() {
        let p = CellParams::default();
        for stored in [false, true] {
            let c = Cell::new(stored, &p);
            for q in [false, true] {
                assert_eq!(c.matches(q), stored == q);
            }
        }
    }

    #[test]
    fn match_keeps_full_rail() {
        let p = CellParams::default();
        let c = Cell::new(true, &p);
        assert_eq!(c.post_match_voltage(true, &p), p.vdd);
        assert_eq!(c.post_match_voltage(false, &p), 0.0);
    }

    #[test]
    fn charge_scales_with_cap() {
        let p = CellParams::default();
        let c = Cell::new(true, &p);
        let q = c.post_match_charge(true, &p);
        assert!((q - 22e-15 * 1.2).abs() < 1e-20);
    }

    #[test]
    fn mismatch_perturbs_cap_but_stays_positive() {
        let p = CellParams::default();
        let mut rng = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..1000 {
            let c = Cell::with_mismatch(true, &p, 0.014, &mut rng);
            assert!(c.cap_f > 0.0);
            sum += c.cap_f;
        }
        let mean = sum / 1000.0;
        assert!((mean / p.cap_f - 1.0).abs() < 0.01, "mean cap off: {mean}");
    }
}
