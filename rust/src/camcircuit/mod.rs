//! Analog BA-CAM circuit substrate (Sec. II).
//!
//! The paper characterises a 10T1C voltage-domain CAM in HSPICE; we have no
//! SPICE or silicon, so this module is the calibrated analytic equivalent
//! (DESIGN.md substitution table): per-cell capacitor behaviour, matchline
//! charge sharing, PVT corners with capacitor mismatch and supply offsets,
//! a 6-bit SAR ADC, and the per-op energy model behind Fig. 5.
//!
//! The architecture layers above consume only (a) the matchline voltage as
//! a function of match count and (b) its error statistics — exactly what
//! this model reproduces (Figs. 3a/3b, Table I error rows).

pub mod adc;
pub mod array;
pub mod cell;
pub mod energy;
pub mod matchline;
pub mod pvt;

pub use adc::SarAdc;
pub use array::BaCamArray;
pub use cell::{Cell, CellParams};
pub use energy::EnergyModel;
pub use matchline::Matchline;
pub use pvt::{Corner, PvtConfig};
