//! PVT (process / voltage / temperature) variation model (Fig. 3b, Table I).
//!
//! The paper reports BA-CAM matchline deviation within 5.05 % and mean
//! error as low as 1.12 % across TT/SS/FF at sigma = 1.4 % capacitor
//! mismatch, versus TD-CAM delay deviations up to 7.76 %. We model:
//!
//! * **Process**: per-cell capacitor mismatch (relative sigma) plus a
//!   corner-wide capacitance bias (slow = thicker dielectric = +C).
//! * **Voltage**: supply droop/boost per corner.
//! * **Temperature**: kT/C noise scales with T; switch resistance drifts.
//!
//! Voltage-mode sensing is first-order *ratiometric* — V_ML depends on the
//! ratio of matched to total capacitance — which is exactly why the paper's
//! scheme tolerates corners better than delay sensing; the model reproduces
//! that cancellation.

use super::cell::CellParams;
use super::matchline::Matchline;
use crate::util::rng::Rng;
use crate::util::stats;

/// Process corner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Corner {
    /// Typical-typical.
    TT,
    /// Slow-slow: -8 % supply, +5 % capacitance, hot (85 C).
    SS,
    /// Fast-fast: +8 % supply, -5 % capacitance, cold (-40 C).
    FF,
}

impl Corner {
    pub const ALL: [Corner; 3] = [Corner::TT, Corner::SS, Corner::FF];

    pub fn name(&self) -> &'static str {
        match self {
            Corner::TT => "TT",
            Corner::SS => "SS",
            Corner::FF => "FF",
        }
    }

    /// Supply multiplier for the corner.
    pub fn vdd_factor(&self) -> f64 {
        match self {
            Corner::TT => 1.0,
            Corner::SS => 0.92,
            Corner::FF => 1.08,
        }
    }

    /// Corner-wide capacitance bias.
    pub fn cap_factor(&self) -> f64 {
        match self {
            Corner::TT => 1.0,
            Corner::SS => 1.05,
            Corner::FF => 0.95,
        }
    }

    /// Junction temperature \[K\].
    pub fn temp_k(&self) -> f64 {
        match self {
            Corner::TT => 300.0,
            Corner::SS => 358.0,
            Corner::FF => 233.0,
        }
    }
}

/// A PVT experiment configuration.
#[derive(Clone, Copy, Debug)]
pub struct PvtConfig {
    pub corner: Corner,
    /// Relative per-cell capacitor mismatch sigma (paper: 0.014).
    pub mismatch_sigma: f64,
    /// Monte-Carlo trials per (corner, match-count) point.
    pub trials: usize,
}

impl Default for PvtConfig {
    fn default() -> Self {
        PvtConfig {
            corner: Corner::TT,
            mismatch_sigma: 0.014,
            trials: 200,
        }
    }
}

/// Result of one PVT sweep point.
#[derive(Clone, Debug)]
pub struct PvtPoint {
    pub corner: Corner,
    pub matches: usize,
    pub width: usize,
    /// Mean relative error vs the ideal (nominal-corner) voltage, percent.
    pub mean_err_pct: f64,
    /// Max relative deviation, percent.
    pub max_dev_pct: f64,
}

/// Corner-adjusted cell parameters.
pub fn corner_params(corner: Corner) -> CellParams {
    let nominal = CellParams::default();
    CellParams {
        cap_f: nominal.cap_f * corner.cap_factor(),
        vdd: nominal.vdd * corner.vdd_factor(),
        v_residual: nominal.v_residual,
    }
}

/// Monte-Carlo the *normalised* matchline voltage error at one match count.
///
/// The sensed quantity is V_ML / V_DD (the ADC's vref tracks the rail), so
/// supply variation cancels ratiometrically; what remains is capacitor
/// mismatch + kT/C noise — this is the voltage-domain robustness the paper
/// claims over TD-CAM.
pub fn pvt_point(
    cfg: &PvtConfig,
    width: usize,
    matches: usize,
    rng: &mut Rng,
) -> PvtPoint {
    let params = corner_params(cfg.corner);
    let bits = vec![true; width];
    let query: Vec<bool> = (0..width).map(|i| i < matches).collect();
    let ideal = matches as f64 / width as f64; // normalised ideal

    let mut errs = Vec::with_capacity(cfg.trials);
    for _ in 0..cfg.trials {
        let ml = Matchline::with_mismatch(&bits, &params, cfg.mismatch_sigma, rng);
        let v = ml.sensed_voltage(&query, &params, cfg.corner.temp_k(), rng);
        let normalised = v / params.vdd;
        // relative to full scale (avoids divide-by-zero at matches=0)
        errs.push((normalised - ideal).abs() / 1.0 * 100.0);
    }
    PvtPoint {
        corner: cfg.corner,
        matches,
        width,
        mean_err_pct: stats::mean(&errs),
        max_dev_pct: errs.iter().cloned().fold(0.0, f64::max),
    }
}

/// Full Fig. 3b sweep: all corners x a set of match counts on a 16x64 array
/// (we sweep the 64-wide matchline; 16 rows share the statistics).
pub fn fig3b_sweep(width: usize, sigma: f64, trials: usize, seed: u64) -> Vec<PvtPoint> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for corner in Corner::ALL {
        let cfg = PvtConfig {
            corner,
            mismatch_sigma: sigma,
            trials,
        };
        for matches in [0, 8, 16, 24, 32, 40, 48, 56, 64] {
            if matches <= width {
                out.push(pvt_point(&cfg, width, matches, &mut rng));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corners_have_distinct_rails() {
        let tt = corner_params(Corner::TT);
        let ss = corner_params(Corner::SS);
        let ff = corner_params(Corner::FF);
        assert!(ss.vdd < tt.vdd && tt.vdd < ff.vdd);
        assert!(ff.cap_f < tt.cap_f && tt.cap_f < ss.cap_f);
    }

    #[test]
    fn paper_error_band_reproduced() {
        // Table I: overall error 1.12% simulated at sigma = 1.4%;
        // Fig 3b: deviation within 5.05% across TT/SS/FF.
        let pts = fig3b_sweep(64, 0.014, 150, 42);
        let mean_of_means =
            stats::mean(&pts.iter().map(|p| p.mean_err_pct).collect::<Vec<_>>());
        let worst = pts.iter().map(|p| p.max_dev_pct).fold(0.0, f64::max);
        assert!(
            mean_of_means < 2.0,
            "mean err {mean_of_means}% should be ~1% (paper: 1.12%)"
        );
        assert!(worst < 5.05, "max deviation {worst}% exceeds paper's 5.05%");
    }

    #[test]
    fn ratiometric_cancellation() {
        // normalised error should NOT blow up at the SS corner despite the
        // -8% supply, because V_ML/VDD is supply-independent
        let mut rng = Rng::new(7);
        let tt = pvt_point(
            &PvtConfig { corner: Corner::TT, mismatch_sigma: 0.014, trials: 300 },
            64, 32, &mut rng,
        );
        let ss = pvt_point(
            &PvtConfig { corner: Corner::SS, mismatch_sigma: 0.014, trials: 300 },
            64, 32, &mut rng,
        );
        assert!(ss.mean_err_pct < tt.mean_err_pct * 2.0 + 0.5);
    }

    #[test]
    fn zero_mismatch_is_nearly_exact() {
        let mut rng = Rng::new(8);
        let p = pvt_point(
            &PvtConfig { corner: Corner::TT, mismatch_sigma: 0.0, trials: 50 },
            64, 17, &mut rng,
        );
        // only kT/C noise and wire dilution remain
        assert!(p.mean_err_pct < 0.5, "err {}", p.mean_err_pct);
    }

    #[test]
    fn error_grows_with_sigma() {
        let mut rng = Rng::new(9);
        let lo = pvt_point(
            &PvtConfig { corner: Corner::TT, mismatch_sigma: 0.005, trials: 300 },
            64, 32, &mut rng,
        );
        let hi = pvt_point(
            &PvtConfig { corner: Corner::TT, mismatch_sigma: 0.05, trials: 300 },
            64, 32, &mut rng,
        );
        assert!(hi.mean_err_pct > lo.mean_err_pct);
    }
}
