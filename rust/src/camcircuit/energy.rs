//! BA-CAM per-op energy model (Fig. 5).
//!
//! The CAM's energy splits into a *programming* part (writing a tile of
//! keys into the SRAM cells) and a *search* part (precharge + broadcast +
//! charge-share + ADC). Programming is paid once per tile and amortised
//! over every query that searches it — Fig. 5 plots per-op energy against
//! the amortisation dimension M, with dashed search-only (lower) and
//! total-at-M=1 (upper) bounds.
//!
//! Constants follow the paper's cited component numbers: the 6-bit SAR is
//! Chen et al. [39] (0.95 mW @ 700 MS/s => ~1.36 pJ/conv at 65 nm-ish
//! supply); cell precharge is C*V^2 on a 22 fF MIM cap at 1.2 V; SRAM write
//! energy is a standard 65 nm estimate.

use super::cell::CellParams;

/// Energy components for one BA-CAM tile geometry.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    pub cam_h: usize,
    pub cam_w: usize,
    /// Write energy per cell \[J\] (SRAM write, 65 nm): ~50 fJ/bit.
    pub e_write_cell: f64,
    /// Precharge energy per cell \[J\]: C * V_DD^2 (the cap charges from 0).
    pub e_precharge_cell: f64,
    /// Query broadcast driver energy per column \[J\]: wire + gate load.
    pub e_broadcast_col: f64,
    /// One 6-bit SAR conversion \[J\] (Chen et al. [39]).
    pub e_adc_conv: f64,
}

impl EnergyModel {
    /// Paper-calibrated model for a given geometry at 65 nm / 1.2 V.
    pub fn new(cam_h: usize, cam_w: usize) -> Self {
        let p = CellParams::default();
        EnergyModel {
            cam_h,
            cam_w,
            e_write_cell: 50e-15,
            e_precharge_cell: p.cap_f * p.vdd * p.vdd, // 31.7 fJ
            e_broadcast_col: 5e-15 * p.vdd * p.vdd,    // ~7 fJ per column driver
            e_adc_conv: 1.36e-12,
        }
    }

    /// Energy to program one full tile \[J\].
    pub fn program_tile(&self) -> f64 {
        self.e_write_cell * (self.cam_h * self.cam_w) as f64
    }

    /// Energy to program one key row \[J\] — the incremental-append unit
    /// the serving layer pays per admitted KV row (a decode packs exactly
    /// one row; a prefill of n rows packs n).
    pub fn program_row(&self) -> f64 {
        self.e_write_cell * self.cam_w as f64
    }

    /// Energy for one search (query broadcast over the whole tile) \[J\]:
    /// every cap precharges, every column broadcasts, every row converts
    /// through the shared ADC (CAM_H sequential conversions).
    pub fn search_tile(&self) -> f64 {
        self.e_precharge_cell * (self.cam_h * self.cam_w) as f64
            + self.e_broadcast_col * self.cam_w as f64
            + self.e_adc_conv * self.cam_h as f64
    }

    /// Binary MAC ops performed by one tile search.
    pub fn ops_per_search(&self) -> f64 {
        (self.cam_h * self.cam_w) as f64
    }

    /// Per-op energy [J/op] when one programming is amortised over `m`
    /// searches (Fig. 5's x-axis).
    pub fn per_op_energy(&self, m: usize) -> f64 {
        assert!(m >= 1);
        let total = self.program_tile() + m as f64 * self.search_tile();
        total / (m as f64 * self.ops_per_search())
    }

    /// Search-only asymptote [J/op] (Fig. 5 lower dashed line).
    pub fn search_only_bound(&self) -> f64 {
        self.search_tile() / self.ops_per_search()
    }

    /// Total-at-M=1 bound [J/op] (Fig. 5 upper dashed line).
    pub fn total_bound(&self) -> f64 {
        self.per_op_energy(1)
    }

    /// Fig. 5 sweep: (M, per-op energy in fJ/op) for M = 1..=2^max_log2.
    pub fn fig5_sweep(&self, max_log2: u32) -> Vec<(usize, f64)> {
        (0..=max_log2)
            .map(|l| {
                let m = 1usize << l;
                (m, self.per_op_energy(m) * 1e15)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_row_is_tile_share() {
        // cam_h rows per tile, so one row costs exactly 1/cam_h of a
        // full tile program
        let e = EnergyModel::new(16, 64);
        assert!((e.program_row() * 16.0 - e.program_tile()).abs() < 1e-18);
        assert!(e.program_row() > 0.0);
    }

    #[test]
    fn per_op_monotonically_decreasing_in_m() {
        let e = EnergyModel::new(16, 64);
        let mut last = f64::INFINITY;
        for (_, fj) in e.fig5_sweep(14) {
            assert!(fj < last);
            last = fj;
        }
    }

    #[test]
    fn converges_to_search_only_bound() {
        let e = EnergyModel::new(16, 64);
        let asymptote = e.search_only_bound();
        let at_16k = e.per_op_energy(16_384);
        assert!((at_16k - asymptote) / asymptote < 0.01);
        assert!(at_16k > asymptote);
    }

    #[test]
    fn bounds_bracket_all_points() {
        let e = EnergyModel::new(16, 64);
        let (lo, hi) = (e.search_only_bound(), e.total_bound());
        for m in [1usize, 3, 17, 100, 5000] {
            let v = e.per_op_energy(m);
            assert!(v >= lo && v <= hi, "m={m} v={v}");
        }
    }

    #[test]
    fn search_energy_dominated_by_precharge() {
        // 16*64 caps at 31.7 fJ ≈ 32.4 pJ vs ADC 16*1.36 ≈ 21.8 pJ — both
        // matter; broadcast is small
        let e = EnergyModel::new(16, 64);
        let total = e.search_tile();
        let pre = e.e_precharge_cell * (16.0 * 64.0);
        assert!(pre / total > 0.4 && pre / total < 0.8, "pre frac {}", pre / total);
    }

    #[test]
    fn sub_100fj_per_op_amortised() {
        // the whole point of analog association: amortised per-binary-op
        // energy lands in the tens of fJ (cf. XNOR-NE's 21.6 fJ/op digital)
        let e = EnergyModel::new(16, 64);
        assert!(e.per_op_energy(1024) < 100e-15 * 1e15 / 1e15 * 100.0);
        let fj = e.per_op_energy(1024) * 1e15;
        assert!(fj < 100.0, "amortised {fj} fJ/op");
    }

    #[test]
    fn taller_array_amortises_adc_better() {
        let short = EnergyModel::new(8, 64);
        let tall = EnergyModel::new(64, 64);
        // ADC energy per op falls with height (shared SAR across more rows
        // but one conversion each) — precharge dominates equally; taller
        // arrays win slightly on broadcast amortisation
        assert!(tall.search_only_bound() <= short.search_only_bound());
    }
}
