//! 6-bit SAR ADC + fixed multiply-subtract unit (Sec. II-B1).
//!
//! The matchline voltage (in [0, V_DD]) is digitised by a shared SAR ADC;
//! the fixed functional unit then applies `s = 2*ADC(v) - CAM_W`, mapping
//! the code range onto signed scores in [-CAM_W, CAM_W] while preserving
//! attention-score ordering. One ADC is shared across CAM_H matchlines
//! (column-muxed) — that sharing is the area win over CiM's flash-ADC-per-
//! column (Table I) and sets the association stage's serialization latency.

use crate::util::rng::Rng;

/// Successive-approximation ADC with the paper's cost/latency profile.
#[derive(Clone, Copy, Debug)]
pub struct SarAdc {
    pub bits: u32,
    /// Full-scale input voltage \[V\] (the matchline rail).
    pub vref: f64,
    /// Input-referred RMS noise \[V\] (comparator + DAC settling).
    pub noise_v: f64,
}

impl Default for SarAdc {
    fn default() -> Self {
        SarAdc {
            bits: 6,
            vref: 1.2,
            noise_v: 0.0,
        }
    }
}

impl SarAdc {
    pub fn new(bits: u32, vref: f64) -> Self {
        SarAdc {
            bits,
            vref,
            noise_v: 0.0,
        }
    }

    pub fn levels(&self) -> u32 {
        1 << self.bits
    }

    /// Ideal conversion: code in [0, 2^bits] (the top code captures the
    /// full-scale "all bits match" voltage — "ADC precision covers the
    /// full match range", Sec. III-B1).
    pub fn convert(&self, v: f64) -> u32 {
        let x = (v / self.vref).clamp(0.0, 1.0);
        let code = (x * self.levels() as f64).round() as i64;
        code.clamp(0, self.levels() as i64) as u32
    }

    /// Conversion with input-referred noise.
    pub fn convert_noisy(&self, v: f64, rng: &mut Rng) -> u32 {
        self.convert(v + rng.normal(0.0, self.noise_v))
    }

    /// The fixed multiply-subtract: code -> signed score in [-cam_w, cam_w].
    pub fn code_to_score(&self, code: u32, cam_w: usize) -> f64 {
        let matches = code as f64 * (cam_w as f64 / self.levels() as f64);
        2.0 * matches - cam_w as f64
    }

    /// Full path: matchline voltage -> signed score.
    pub fn score(&self, v: f64, cam_w: usize) -> f64 {
        self.code_to_score(self.convert(v), cam_w)
    }

    /// One conversion takes `bits` comparator cycles in a SAR; at the
    /// paper's 500 MHz internal clock (Table I) this is bits * 2 ns.
    pub fn conversion_latency_ns(&self, clock_ghz: f64) -> f64 {
        self.bits as f64 / clock_ghz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_cover_full_range() {
        let adc = SarAdc::default();
        assert_eq!(adc.convert(0.0), 0);
        assert_eq!(adc.convert(1.2), 64);
        assert_eq!(adc.convert(0.6), 32);
    }

    #[test]
    fn clamps_out_of_range() {
        let adc = SarAdc::default();
        assert_eq!(adc.convert(-0.5), 0);
        assert_eq!(adc.convert(2.0), 64);
    }

    #[test]
    fn monotone() {
        let adc = SarAdc::default();
        let mut last = 0;
        for i in 0..=1200 {
            let code = adc.convert(i as f64 / 1000.0);
            assert!(code >= last);
            last = code;
        }
    }

    #[test]
    fn score_map_matches_paper() {
        // s = 2*ADC(v) - CAM_W maps [0, VDD] -> [-64, 64]
        let adc = SarAdc::default();
        assert_eq!(adc.score(0.0, 64), -64.0);
        assert_eq!(adc.score(1.2, 64), 64.0);
        assert_eq!(adc.score(0.6, 64), 0.0);
    }

    #[test]
    fn exact_for_64_wide_match_counts() {
        // every integer match count on a 64-cell line has its own code
        let adc = SarAdc::default();
        for m in 0..=64u32 {
            let v = m as f64 / 64.0 * 1.2;
            let s = adc.score(v, 64);
            assert_eq!(s, 2.0 * m as f64 - 64.0, "m={m}");
        }
    }

    #[test]
    fn ordering_preserved_under_quantization() {
        let adc = SarAdc::new(4, 1.2); // coarse ADC
        let mut last = f64::NEG_INFINITY;
        for i in 0..=120 {
            let s = adc.score(i as f64 / 100.0, 64);
            assert!(s >= last);
            last = s;
        }
    }

    #[test]
    fn noise_perturbs_codes() {
        let mut adc = SarAdc::default();
        adc.noise_v = 0.02;
        let mut rng = Rng::new(4);
        let codes: Vec<u32> = (0..200).map(|_| adc.convert_noisy(0.609, &mut rng)).collect();
        let distinct: std::collections::HashSet<_> = codes.iter().collect();
        assert!(distinct.len() > 1, "noise should straddle code boundaries");
    }

    #[test]
    fn sar_latency() {
        let adc = SarAdc::default();
        assert_eq!(adc.conversion_latency_ns(0.5), 12.0); // 6 cycles @ 500MHz
        assert_eq!(adc.conversion_latency_ns(1.0), 6.0); // @ 1GHz
    }
}
