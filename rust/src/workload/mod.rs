//! Layer-4 workload engine (ISSUE 10): trace-driven traffic simulation
//! with energy/latency co-simulation over the serving stack.
//!
//! The paper evaluates CAMformer on throughput *and* energy (Table II /
//! Fig. 8); this module closes the loop at the system level by driving
//! the layer-3 server with statistically-shaped traffic and pricing
//! every dispatch through the layer-1/2 circuit models:
//!
//! * [`sampler`] — the statistical primitives: Poisson inter-arrivals
//!   (inverse CDF over the shared [`Rng`]) and [`Zipf`] session
//!   popularity (precomputed CDF + binary search);
//! * [`trace`]   — [`generate`]: a pure function of
//!   `(`[`TraceSpec`]`, u64 seed)` producing an explicit [`Trace`] — a
//!   `Vec` of microsecond-timestamped `Open`/`Decode`/`Close` ops in
//!   the paper's BERT-class (n ≈ 128–384) and ViT-class (n ≈ 197–577)
//!   shape bands, bit-identical per seed (golden-trace guarded);
//! * [`driver`]  — [`TrafficDriver`]: replays a trace against a live
//!   [`CamformerServer`] through the `SessionHandle`/`Ticket` API,
//!   open-loop (optionally paced) with a closed retry loop — sheds
//!   drain-and-resubmit, lost sessions re-open from their prefill
//!   recipe — recording scheduled-arrival → completion latency per
//!   decode in a [`DriverReport`];
//! * [`energy`]  — [`EnergyAccountant`]: a pure function from the
//!   server's accumulated `WorkStats` + DRAM counters to per-stage
//!   joules ([`EnergyStages`]) via `camcircuit::EnergyModel` and the
//!   `cost::blocks` constants, additive by construction and surfaced
//!   through `Metrics::summary()` as J/token, watts and DRAM share.
//!
//! [`Rng`]: crate::util::rng::Rng
//! [`CamformerServer`]: crate::coordinator::CamformerServer
//! [`EnergyStages`]: crate::coordinator::metrics::EnergyStages

pub mod driver;
pub mod energy;
pub mod sampler;
pub mod trace;

pub use driver::{DriverConfig, DriverReport, TrafficDriver};
pub use energy::EnergyAccountant;
pub use sampler::{Zipf, exp_interarrival};
pub use trace::{TimedOp, Trace, TraceOp, TraceSpec, generate};
