//! The energy-accounting bridge (ISSUE 10): price accumulated serving
//! work through the layer-1/2 circuit models.
//!
//! [`EnergyAccountant::account`] is a *pure function* from a [`Metrics`]
//! snapshot — per-dispatch [`WorkStats`] counters folded at worker exit
//! plus the spill tier's DRAM traffic — to per-stage joules
//! ([`EnergyStages`]):
//!
//! * **search** — one [`EnergyModel::search_tile`] (precharge +
//!   broadcast + ADC) per 16-row tile the fused kernel streamed;
//! * **program** — one [`EnergyModel::program_row`] per KV row admitted
//!   (prefill rows + decode appends) and per fallback row a backend had
//!   to pack itself;
//! * **selection** — one Top-32 sorter pass per query plus one Top-2
//!   comparator pass per streaming survivor correction
//!   (`cost::blocks`);
//! * **softmax** — one 32-score normalisation per query;
//! * **contextualization** — [`cost::blocks::context_row_energy_j`]
//!   (BF16 MACs + V-SRAM bytes + DMA) per survivor V row touched;
//! * **dram** — the spill tier's already-channel-priced
//!   `Metrics::dram_energy_j`, carried through unchanged.
//!
//! Every stage is counter × per-op constant, so the accounting is
//! exactly linear: the energy of a trace equals the sum of its
//! per-dispatch charges (the additivity property test below), and zero
//! work prices to exactly zero joules. Note the asymmetry this
//! structure gives the dense baseline: a dense dispatch streams no
//! tiles, so it pays *nothing* for scoring here — its `v_rows_touched`
//! covers the whole context instead of ≤ final_k survivors, which is
//! what makes fused J/token beat dense even with dense's scoring
//! energy charged at zero (the `check_bench.py` gate is conservative).

use crate::camcircuit::energy::EnergyModel;
use crate::coordinator::backend::WorkStats;
use crate::coordinator::metrics::{EnergyStages, Metrics};
use crate::cost::blocks;

/// Prices accumulated serving work through the circuit models. Built
/// once per server geometry; `account` can then be applied to any
/// number of metrics snapshots.
#[derive(Clone, Debug)]
pub struct EnergyAccountant {
    model: EnergyModel,
    d_v: usize,
    selection_pass_j: f64,
    correction_j: f64,
    softmax_j: f64,
    context_row_j: f64,
}

impl EnergyAccountant {
    /// Accountant for the paper geometry: 16×64 BA-CAM tiles at the
    /// given V width.
    pub fn paper(d_v: usize) -> Self {
        Self::new(EnergyModel::new(16, 64), d_v)
    }

    /// Accountant over an explicit tile energy model.
    pub fn new(model: EnergyModel, d_v: usize) -> Self {
        EnergyAccountant {
            model,
            d_v,
            selection_pass_j: blocks::top32_sorter().energy_per_op,
            correction_j: blocks::top2_sorter().energy_per_op,
            softmax_j: blocks::softmax_engine().energy_per_op,
            context_row_j: blocks::context_row_energy_j(d_v),
        }
    }

    /// The V width this accountant prices contextualization at.
    pub fn d_v(&self) -> usize {
        self.d_v
    }

    /// Price a full metrics snapshot: the folded [`WorkStats`], the KV
    /// admission flow (rows programmed into the CAM), and the spill
    /// tier's DRAM energy.
    pub fn account(&self, m: &Metrics) -> EnergyStages {
        self.account_work(&m.work, m.kv_rows_admitted, m.dram_energy_j)
    }

    /// Price raw counters — the per-dispatch ledger form: a dispatch's
    /// `WorkStats` delta (plus its admitted rows / DRAM charge) prices
    /// independently, and the charges sum to the trace total exactly
    /// because every stage is linear in its counter.
    pub fn account_work(&self, w: &WorkStats, rows_admitted: u64, dram_j: f64) -> EnergyStages {
        EnergyStages {
            search_j: w.tiles_streamed as f64 * self.model.search_tile(),
            program_j: (rows_admitted + w.fallback_rows_packed) as f64 * self.model.program_row(),
            selection_j: w.attends as f64 * self.selection_pass_j
                + w.survivor_corrections as f64 * self.correction_j,
            softmax_j: w.attends as f64 * self.softmax_j,
            context_j: w.v_rows_touched as f64 * self.context_row_j,
            dram_j,
        }
    }

    /// Price a metrics snapshot and attach the result, so
    /// `Metrics::summary` reports J/token, watts and the DRAM share.
    pub fn attach(&self, m: &mut Metrics) -> EnergyStages {
        let stages = self.account(m);
        m.attach_energy(stages);
        stages
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_metrics(rng: &mut Rng) -> Metrics {
        let mut m = Metrics::new();
        m.work.attends = rng.range(0, 1000);
        m.work.v_rows_touched = rng.range(0, 100_000);
        m.work.fallback_rows_packed = rng.range(0, 100);
        m.work.words_scored = rng.range(0, 1_000_000);
        m.work.tiles_streamed = rng.range(0, 100_000);
        m.work.survivor_corrections = rng.range(0, 10_000);
        m.kv_rows_admitted = rng.range(0, 100_000);
        m.dram_energy_j = rng.uniform() * 1e-3;
        m.decodes = rng.range(1, 1000);
        m
    }

    /// The additivity property (ISSUE 10): the energy of a merged run
    /// equals the sum of its parts' charges, stage by stage — i.e. the
    /// energy of a trace is the sum of its per-dispatch charges. u64
    /// counter sums are exact; the float rescale `(a + b)·c` vs
    /// `a·c + b·c` differs only in the last ulps, hence the 1e-12
    /// relative band.
    #[test]
    fn accounting_is_additive() {
        let acct = EnergyAccountant::paper(64);
        let mut rng = Rng::new(4242);
        for _ in 0..50 {
            let a = random_metrics(&mut rng);
            let b = random_metrics(&mut rng);
            let mut merged = a.clone();
            merged.merge(&b);
            let (ea, eb, em) = (acct.account(&a), acct.account(&b), acct.account(&merged));
            for (part, whole, what) in [
                (ea.search_j + eb.search_j, em.search_j, "search"),
                (ea.program_j + eb.program_j, em.program_j, "program"),
                (ea.selection_j + eb.selection_j, em.selection_j, "selection"),
                (ea.softmax_j + eb.softmax_j, em.softmax_j, "softmax"),
                (ea.context_j + eb.context_j, em.context_j, "context"),
                (ea.dram_j + eb.dram_j, em.dram_j, "dram"),
                (ea.total_j() + eb.total_j(), em.total_j(), "total"),
            ] {
                let scale = whole.abs().max(1e-30);
                assert!(
                    (part - whole).abs() / scale < 1e-12,
                    "{what}: sum of charges {part} != merged charge {whole}"
                );
            }
        }
    }

    /// Zero work ⇒ exactly zero joules, in every stage.
    #[test]
    fn zero_work_zero_energy() {
        let acct = EnergyAccountant::paper(64);
        let e = acct.account(&Metrics::new());
        assert_eq!(e, EnergyStages::default());
        assert_eq!(e.total_j(), 0.0);
    }

    /// Each counter feeds exactly its stage, priced at the model's
    /// per-op constants.
    #[test]
    fn stages_price_their_counters() {
        let acct = EnergyAccountant::paper(64);
        let model = EnergyModel::new(16, 64);
        let mut m = Metrics::new();
        m.work.tiles_streamed = 10;
        m.work.attends = 4;
        m.work.survivor_corrections = 3;
        m.work.v_rows_touched = 7;
        m.work.fallback_rows_packed = 2;
        m.kv_rows_admitted = 5;
        m.dram_energy_j = 1e-6;
        let e = acct.account(&m);
        assert!((e.search_j - 10.0 * model.search_tile()).abs() < 1e-18);
        assert!((e.program_j - 7.0 * model.program_row()).abs() < 1e-18);
        let want_sel = 4.0 * blocks::top32_sorter().energy_per_op
            + 3.0 * blocks::top2_sorter().energy_per_op;
        assert!((e.selection_j - want_sel).abs() < 1e-18);
        assert!((e.softmax_j - 4.0 * blocks::softmax_engine().energy_per_op).abs() < 1e-18);
        assert!((e.context_j - 7.0 * blocks::context_row_energy_j(64)).abs() < 1e-18);
        assert!((e.dram_j - 1e-6).abs() < 1e-18);
        assert!(e.total_j() > 0.0 && e.total_j().is_finite());
    }

    /// Attaching prices the snapshot into the metrics' summary surface.
    #[test]
    fn attach_surfaces_j_per_token() {
        let acct = EnergyAccountant::paper(64);
        let mut m = Metrics::new();
        m.work.attends = 8;
        m.work.tiles_streamed = 64;
        m.work.v_rows_touched = 8 * 32;
        m.decodes = 8;
        let stages = acct.attach(&mut m);
        assert_eq!(m.energy, Some(stages));
        let jt = m.energy_per_token_j();
        assert!(jt > 0.0 && jt.is_finite(), "J/token {jt}");
        // paper-shape sanity: tens of nJ per decoded token, not pJ or mJ
        assert!(jt > 1e-9 && jt < 1e-6, "J/token {jt} outside the plausible band");
    }

    /// Determinism: pricing is pure — the same snapshot prices to
    /// bit-identical joules every time.
    #[test]
    fn pricing_is_pure() {
        let acct = EnergyAccountant::paper(64);
        let mut rng = Rng::new(7);
        let m = random_metrics(&mut rng);
        let a = acct.account(&m);
        let b = acct.account(&m);
        assert_eq!(a, b);
        assert_eq!(a.total_j().to_bits(), b.total_j().to_bits());
    }
}
