//! Seeded arrival and popularity samplers for the trace generator.
//!
//! Both samplers draw exclusively from [`Rng`] (an explicit-`u64`-seed
//! xoshiro256++) — no wall clock, no global RNG — so a trace is a pure
//! function of its seed (the ISSUE 10 determinism guard). The arrival
//! process is open-loop Poisson (exponential inter-arrival times via the
//! inverse CDF); session popularity is Zipf, the standard heavy-tailed
//! model for multi-user serving hotsets (a few sessions absorb most of
//! the traffic, the long tail thrashes the spill tier).

use crate::util::rng::Rng;

/// One exponential inter-arrival gap \[s\] of a Poisson process with the
/// given event rate \[1/s\]: `-ln(1 - U) / rate`, `U ~ Uniform[0, 1)`.
pub fn exp_interarrival(rng: &mut Rng, rate_per_s: f64) -> f64 {
    assert!(rate_per_s > 0.0, "Poisson rate must be positive, got {rate_per_s}");
    -(1.0 - rng.uniform()).ln() / rate_per_s
}

/// Zipf sampler over ranks `0..n`: rank `r` is drawn with probability
/// proportional to `1 / (r + 1)^s`. The CDF is precomputed once so each
/// sample is one uniform draw plus a binary search.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build the sampler for `n` ranks with exponent `s` (s = 0 is
    /// uniform; larger s concentrates mass on the head ranks).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "Zipf needs at least one rank");
        assert!(s >= 0.0, "Zipf exponent must be non-negative, got {s}");
        let mut cdf = Vec::with_capacity(n);
        let mut total = 0.0f64;
        for r in 0..n {
            total += 1.0 / ((r + 1) as f64).powf(s);
            cdf.push(total);
        }
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Number of ranks.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// Whether the rank space is empty (never true: `new` asserts n > 0).
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Draw one rank in `0..n`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        // first rank whose CDF strictly exceeds u; the min guards the
        // float-dust case where u lands at/after the last partial sum
        self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    /// Seeded Poisson inter-arrivals must reproduce the exponential
    /// distribution's first two moments: mean 1/λ and CV = 1 (the
    /// standard deviation equals the mean), both tolerance-banded.
    #[test]
    fn poisson_interarrival_moments() {
        let mut rng = Rng::new(1234);
        let rate = 1000.0; // 1k req/s => 1 ms mean gap
        let gaps: Vec<f64> = (0..20_000).map(|_| exp_interarrival(&mut rng, rate)).collect();
        assert!(gaps.iter().all(|&g| g >= 0.0));
        let mean = stats::mean(&gaps);
        assert!((mean - 1e-3).abs() / 1e-3 < 0.05, "mean gap {mean} vs 1/λ = 1e-3");
        let sd = stats::std_dev(&gaps);
        assert!((sd - mean).abs() / mean < 0.10, "exponential CV must be ~1: sd {sd} mean {mean}");
    }

    /// The exponential right tail: P[gap > 2/λ] = e^-2 ≈ 13.5% — a
    /// skew-sensitive band a symmetric distribution with the same mean
    /// and variance would miss badly.
    #[test]
    fn poisson_interarrival_tail_mass() {
        let mut rng = Rng::new(99);
        let rate = 500.0;
        let n = 20_000;
        let over = (0..n)
            .filter(|_| exp_interarrival(&mut rng, rate) > 2.0 / rate)
            .count();
        let frac = over as f64 / n as f64;
        assert!((frac - 0.1353).abs() < 0.02, "P[gap > 2/λ] = {frac}, want ~e^-2");
    }

    /// Zipf(s = 1) rank frequencies must fall off as ~1/rank: rank 0
    /// roughly twice rank 1, roughly ten times rank 9, tolerance-banded.
    #[test]
    fn zipf_rank_frequencies() {
        let z = Zipf::new(100, 1.0);
        let mut rng = Rng::new(777);
        let mut counts = vec![0u64; z.len()];
        let n = 100_000;
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        let f = |r: usize| counts[r] as f64 / n as f64;
        let ratio10 = f(0) / f(9).max(1e-12);
        assert!((ratio10 - 10.0).abs() < 2.0, "rank0/rank9 = {ratio10}, want ~10");
        let ratio2 = f(0) / f(1).max(1e-12);
        assert!((ratio2 - 2.0).abs() < 0.4, "rank0/rank1 = {ratio2}, want ~2");
        // head concentration: with H_100 ≈ 5.19, the top-10 ranks carry
        // H_10/H_100 ≈ 56% of the mass
        let head: f64 = (0..10).map(f).sum();
        assert!((head - 0.564).abs() < 0.05, "top-10 mass {head}, want ~0.56");
    }

    /// s = 0 degenerates to uniform: every rank within a band of 1/n.
    #[test]
    fn zipf_zero_exponent_is_uniform() {
        let z = Zipf::new(10, 0.0);
        let mut rng = Rng::new(5);
        let mut counts = vec![0u64; 10];
        let n = 50_000;
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        for (r, &c) in counts.iter().enumerate() {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.1).abs() < 0.015, "rank {r} freq {frac}, want 0.1");
        }
    }

    /// Samples always land in range, including the single-rank edge.
    #[test]
    fn zipf_sample_in_range() {
        let z = Zipf::new(7, 1.2);
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            assert!(z.sample(&mut rng) < 7);
        }
        let one = Zipf::new(1, 2.0);
        assert_eq!(one.sample(&mut rng), 0);
    }
}
