//! Trace generation: seeded open-loop request schedules for the serving
//! layer.
//!
//! A [`Trace`] is an explicit, inspectable value — a `Vec` of
//! microsecond-timestamped [`TraceOp`]s — produced by a pure function of
//! a [`TraceSpec`] and a `u64` seed. Arrivals follow a Poisson process
//! (open-loop: the schedule never waits for completions), targets follow
//! Zipf session popularity, and per-session shapes (prefill length,
//! decode count before close) are drawn from the paper's workload bands:
//! BERT-class sequences (n ≈ 128–384, d_k = 64) and ViT-class sequences
//! (n ≈ 197–577), Sec. IV / Table 2.
//!
//! Determinism guard (ISSUE 10 satellite): generation consumes only the
//! explicit seed through [`Rng`] — no wall clock, no global RNG — so the
//! same `(spec, seed)` always yields a bit-identical trace. The golden
//! test below pins the first ops of a known seed so the sampling
//! pipeline can never silently drift across PRs.

use crate::util::rng::Rng;

use super::sampler::{exp_interarrival, Zipf};

/// One scheduled request against the serving API.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TraceOp {
    /// Admit the session (shard-wide prefill fan-out of `prefill_rows`
    /// K/V rows through [`CamformerServer::open`]).
    ///
    /// [`CamformerServer::open`]: crate::coordinator::CamformerServer::open
    Open { session: u64, prefill_rows: usize },
    /// One autoregressive step: append one K/V row, attend over the
    /// grown cache (a decoded token).
    Decode { session: u64 },
    /// Retire the session, releasing its provisioned KV capacity.
    Close { session: u64 },
}

impl TraceOp {
    /// The session this op targets.
    pub fn session(&self) -> u64 {
        match *self {
            TraceOp::Open { session, .. }
            | TraceOp::Decode { session }
            | TraceOp::Close { session } => session,
        }
    }
}

/// A [`TraceOp`] with its scheduled arrival time \[µs since trace start\].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TimedOp {
    pub at_us: u64,
    pub op: TraceOp,
}

/// A complete generated workload: the schedule plus the geometry every
/// payload is generated against.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// The seed the trace (and every replayed payload) derives from.
    pub seed: u64,
    pub d_k: usize,
    pub d_v: usize,
    pub ops: Vec<TimedOp>,
}

impl Trace {
    /// Decode ops in the schedule (the tokens a full replay decodes).
    pub fn decode_ops(&self) -> usize {
        self.ops.iter().filter(|t| matches!(t.op, TraceOp::Decode { .. })).count()
    }

    /// Largest per-session context any op can grow to: max prefill rows
    /// plus the decode band's upper bound — what `kv_capacity` must
    /// provision (rounded up to the server's pad quantum by the caller).
    pub fn max_context(&self, spec: &TraceSpec) -> usize {
        let _ = self;
        spec.prefill_rows.1 + spec.decode_steps.1
    }
}

/// The workload's statistical shape: everything [`generate`] samples
/// from. Bands are inclusive `(lo, hi)`.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    /// Scenario tag (bench/CLI display).
    pub label: &'static str,
    /// Decode events to schedule (opens/closes are emitted as sessions
    /// first appear and exhaust their sampled length).
    pub requests: usize,
    /// Session-id space the Zipf popularity draws over.
    pub population: usize,
    /// Zipf exponent: 0 = uniform popularity, ≥ 1 = strong hotset.
    pub zipf_s: f64,
    /// Poisson arrival rate of decode events \[1/s\].
    pub rate_per_s: f64,
    /// Prefill length band \[rows\].
    pub prefill_rows: (usize, usize),
    /// Decodes a session serves before it closes.
    pub decode_steps: (usize, usize),
    pub d_k: usize,
    pub d_v: usize,
}

impl TraceSpec {
    /// BERT-class serving mix: n ≈ 128–384 at d_k = 64 (Table 2's
    /// sequence-classification shapes), moderate hotset.
    pub fn bert() -> Self {
        TraceSpec {
            label: "bert",
            requests: 256,
            population: 8,
            zipf_s: 1.0,
            rate_per_s: 2000.0,
            prefill_rows: (128, 384),
            decode_steps: (8, 32),
            d_k: 64,
            d_v: 64,
        }
    }

    /// ViT-class serving mix: n ≈ 197–577 patch sequences (ViT-B/16 at
    /// 224²–384² inputs), denser arrivals.
    pub fn vit() -> Self {
        TraceSpec {
            label: "vit",
            requests: 256,
            population: 8,
            zipf_s: 1.0,
            rate_per_s: 4000.0,
            prefill_rows: (197, 577),
            decode_steps: (8, 32),
            d_k: 64,
            d_v: 64,
        }
    }

    /// Spill-pressure mix: a wide population under a strong Zipf hotset
    /// with short sessions — most ids are cold, so a tight KV budget
    /// keeps demoting the tail through the DRAM spill tier.
    pub fn zipf_hotset() -> Self {
        TraceSpec {
            label: "zipf",
            requests: 256,
            population: 16,
            zipf_s: 1.2,
            rate_per_s: 2000.0,
            prefill_rows: (128, 256),
            decode_steps: (4, 16),
            d_k: 64,
            d_v: 64,
        }
    }

    /// `kv_capacity` that provisions the worst-case per-session context,
    /// rounded up to the default pad quantum (16).
    pub fn kv_capacity(&self) -> usize {
        (self.prefill_rows.1 + self.decode_steps.1).div_ceil(16) * 16
    }
}

/// Generate the trace: a pure function of `(spec, seed)`.
///
/// Each Poisson arrival draws a Zipf session rank. The first touch of a
/// not-currently-open session samples its shape (prefill rows, decode
/// count) and emits an `Open`; every arrival emits a `Decode`; a session
/// that has served its sampled decode count emits a `Close` (its id can
/// re-open on a later touch — Zipf re-use is what builds the hotset).
/// Sessions still open after the last arrival close at the final
/// timestamp, so a full replay always releases every session.
pub fn generate(spec: &TraceSpec, seed: u64) -> Trace {
    assert!(spec.requests > 0, "a trace needs at least one request");
    assert!(spec.decode_steps.0 >= 1, "sessions must serve at least one decode");
    assert!(spec.prefill_rows.0 >= 1 && spec.prefill_rows.1 >= spec.prefill_rows.0);
    assert!(spec.decode_steps.1 >= spec.decode_steps.0);
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(spec.population, spec.zipf_s);
    let mut live: Vec<Option<usize>> = vec![None; spec.population];
    let mut ops = Vec::with_capacity(spec.requests * 2);
    let mut t_s = 0.0f64;
    for _ in 0..spec.requests {
        t_s += exp_interarrival(&mut rng, spec.rate_per_s);
        let at_us = (t_s * 1e6) as u64;
        let sid = zipf.sample(&mut rng);
        if live[sid].is_none() {
            let rows = spec.prefill_rows.0
                + rng.index(spec.prefill_rows.1 - spec.prefill_rows.0 + 1);
            let steps = spec.decode_steps.0
                + rng.index(spec.decode_steps.1 - spec.decode_steps.0 + 1);
            ops.push(TimedOp {
                at_us,
                op: TraceOp::Open { session: sid as u64, prefill_rows: rows },
            });
            live[sid] = Some(steps);
        }
        ops.push(TimedOp { at_us, op: TraceOp::Decode { session: sid as u64 } });
        let remaining = live[sid].as_mut().expect("decode targets an open session");
        *remaining -= 1;
        if *remaining == 0 {
            ops.push(TimedOp { at_us, op: TraceOp::Close { session: sid as u64 } });
            live[sid] = None;
        }
    }
    let end_us = ops.last().map(|t| t.at_us).unwrap_or(0);
    for (sid, slot) in live.iter().enumerate() {
        if slot.is_some() {
            ops.push(TimedOp { at_us: end_us, op: TraceOp::Close { session: sid as u64 } });
        }
    }
    Trace { seed, d_k: spec.d_k, d_v: spec.d_v, ops }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The determinism guard's teeth: same seed ⇒ bit-identical trace,
    /// different seed ⇒ a different one.
    #[test]
    fn same_seed_bit_identical() {
        let spec = TraceSpec::bert();
        let a = generate(&spec, 42);
        let b = generate(&spec, 42);
        assert_eq!(a, b);
        let c = generate(&spec, 43);
        assert_ne!(a, c);
    }

    /// Golden-trace regression (ISSUE 10 satellite): the first ops of
    /// seed 42 under the BERT spec, pinned literally. Session ids, op
    /// kinds and sampled shapes are integer-exact (they come from the
    /// raw xoshiro stream); timestamps are pinned within ±1 µs because
    /// the exponential inverse-CDF goes through libm `ln`, whose last
    /// ulp is the one platform-dependent bit in the pipeline. Any change
    /// to the sampling order, the RNG, or the spec constants lands far
    /// outside these pins.
    #[test]
    fn golden_trace_seed_42() {
        let trace = generate(&TraceSpec::bert(), 42);
        let golden: &[(u64, TraceOp)] = &[
            (841, TraceOp::Open { session: 0, prefill_rows: 155 }),
            (841, TraceOp::Decode { session: 0 }),
            (1630, TraceOp::Open { session: 2, prefill_rows: 375 }),
            (1630, TraceOp::Decode { session: 2 }),
            (1746, TraceOp::Open { session: 6, prefill_rows: 162 }),
            (1746, TraceOp::Decode { session: 6 }),
            (2316, TraceOp::Decode { session: 0 }),
            (2574, TraceOp::Open { session: 1, prefill_rows: 217 }),
            (2574, TraceOp::Decode { session: 1 }),
            (3004, TraceOp::Decode { session: 1 }),
            (3092, TraceOp::Open { session: 5, prefill_rows: 315 }),
            (3092, TraceOp::Decode { session: 5 }),
        ];
        for (i, (at_us, op)) in golden.iter().enumerate() {
            let got = &trace.ops[i];
            assert_eq!(&got.op, op, "golden op {i} drifted");
            assert!(
                (got.at_us as i64 - *at_us as i64).abs() <= 1,
                "golden timestamp {i} drifted: {} vs {at_us}",
                got.at_us
            );
        }
        // stream-level pins: the whole schedule, not just its head
        assert_eq!(trace.ops.len(), 288, "total op count drifted");
        assert_eq!(trace.decode_ops(), 256);
        let opens = trace
            .ops
            .iter()
            .filter(|t| matches!(t.op, TraceOp::Open { .. }))
            .count();
        assert_eq!(opens, 16, "open count drifted");
    }

    /// Structural invariants of every generated trace: opens precede
    /// decodes, every open eventually closes, decode count matches the
    /// spec, timestamps are non-decreasing.
    #[test]
    fn trace_is_well_formed() {
        for (spec, seed) in [
            (TraceSpec::bert(), 1u64),
            (TraceSpec::vit(), 2),
            (TraceSpec::zipf_hotset(), 3),
        ] {
            let trace = generate(&spec, seed);
            assert_eq!(trace.decode_ops(), spec.requests, "{}", spec.label);
            let mut open: Vec<bool> = vec![false; spec.population];
            let mut last_us = 0u64;
            for t in &trace.ops {
                assert!(t.at_us >= last_us, "timestamps must be non-decreasing");
                last_us = t.at_us;
                let sid = t.op.session() as usize;
                match t.op {
                    TraceOp::Open { prefill_rows, .. } => {
                        assert!(!open[sid], "double open of session {sid}");
                        assert!(
                            (spec.prefill_rows.0..=spec.prefill_rows.1).contains(&prefill_rows),
                            "prefill {prefill_rows} outside the {} band",
                            spec.label
                        );
                        open[sid] = true;
                    }
                    TraceOp::Decode { .. } => assert!(open[sid], "decode of closed session {sid}"),
                    TraceOp::Close { .. } => {
                        assert!(open[sid], "close of closed session {sid}");
                        open[sid] = false;
                    }
                }
            }
            assert!(open.iter().all(|&o| !o), "every session must close by trace end");
            assert!(
                trace.max_context(&spec) <= spec.kv_capacity(),
                "capacity helper must cover the worst-case context"
            );
        }
    }

    /// Zipf popularity shows up as a hotset: under s = 1.2 the most
    /// popular session serves strictly more decodes than the median one.
    #[test]
    fn hotset_concentrates_decodes() {
        let spec = TraceSpec::zipf_hotset();
        let trace = generate(&spec, 7);
        let mut per_session = vec![0usize; spec.population];
        for t in &trace.ops {
            if let TraceOp::Decode { session } = t.op {
                per_session[session as usize] += 1;
            }
        }
        let mut sorted = per_session.clone();
        sorted.sort_unstable();
        let hottest = *sorted.last().unwrap();
        let median = sorted[spec.population / 2];
        assert!(
            hottest >= median * 2,
            "hotset too flat: hottest {hottest} vs median {median} ({per_session:?})"
        );
    }
}
