//! Trace replay: drive a live [`CamformerServer`] through the
//! session-handle API from a generated [`Trace`].
//!
//! The driver is **open-loop with a closed retry loop**: arrivals follow
//! the trace's schedule (scaled by [`DriverConfig::speedup`], or
//! replayed as fast as the server admits them when the speedup is
//! infinite), but every retryable refusal is driven to completion — an
//! [`ServeError::Overloaded`] shed drains one in-flight ticket and
//! resubmits, a lost/evicted session is re-opened from its recorded
//! prefill recipe and the decode replayed — so a finished replay
//! accounts for every scheduled token, either as a completed decode or
//! an explicitly-counted failure.
//!
//! Latency is measured end-to-end per decode: the time from the op's
//! *scheduled* arrival to its response, i.e. admission delay (sheds,
//! backoff, re-opens) plus the server's own enqueue-to-completion
//! latency. Under an infinite speedup there is no schedule to be late
//! against, so the admission-delay term is zero and the number reduces
//! to the server-side latency.
//!
//! Determinism: every payload (prefill K/V, decode query/key/value) is
//! regenerated from `trace.seed` and the op's index — no payload state
//! is carried between runs, so the same trace replays bit-identical
//! request contents every time, and a re-opened session re-prefills
//! exactly the rows the original `Open` admitted.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::coordinator::client::{SessionHandle, Ticket};
use crate::coordinator::error::ServeError;
use crate::coordinator::server::{CamformerServer, ReclaimPolicy, Response};
use crate::util::rng::Rng;
use crate::util::stats;

use super::trace::{Trace, TraceOp};

/// Payload-stream tags: which kind of op an index-derived [`Rng`] feeds.
const TAG_PREFILL: u64 = 1;
const TAG_DECODE: u64 = 2;

/// Pause between retryable resubmissions with nothing local to drain:
/// long enough for the target worker to pop a few envelopes, short
/// enough to be invisible next to a dispatch.
const RETRY_BACKOFF: Duration = Duration::from_micros(100);

/// Replay knobs.
#[derive(Clone, Copy, Debug)]
pub struct DriverConfig {
    /// Trace-time compression: a scheduled gap of `t` µs is slept as
    /// `t / speedup`. `f64::INFINITY` (the default) disables pacing and
    /// replays as fast as the server admits — the right mode for
    /// benches, where throughput is the measurement.
    pub speedup: f64,
    /// Per-op bound on retryable resubmissions (sheds + re-opens)
    /// before the op is counted as failed.
    pub max_retries: usize,
}

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig { speedup: f64::INFINITY, max_retries: 64 }
    }
}

/// What one replay did: per-decode latencies and the retry-loop ledger.
#[derive(Clone, Debug, Default)]
pub struct DriverReport {
    /// End-to-end latency of every completed decode \[µs\], in
    /// completion order (scheduled arrival → response).
    pub latencies_us: Vec<f64>,
    /// Decodes that completed with an `Ok` response.
    pub decoded_tokens: u64,
    /// Sessions opened (including re-opens after loss/eviction).
    pub opens: u64,
    /// Sessions closed (handle teardown at trace `Close` ops and at
    /// replay end).
    pub closes: u64,
    /// Submissions refused or failed retryably ([`ServeError::Overloaded`],
    /// [`ServeError::Backend`]) and replayed.
    pub shed_replays: u64,
    /// Sessions re-opened from their prefill recipe after
    /// `SessionLost`/`Evicted`/`UnknownSession`.
    pub reopens: u64,
    /// Ops abandoned after [`DriverConfig::max_retries`] or a terminal
    /// error (e.g. [`ServeError::WorkerGone`]).
    pub failed: u64,
    /// Wall-clock duration of the whole replay.
    pub wall: Duration,
}

impl DriverReport {
    /// Median end-to-end decode latency \[µs\].
    pub fn p50_us(&self) -> f64 {
        stats::percentile(&self.latencies_us, 50.0)
    }

    /// Tail end-to-end decode latency \[µs\].
    pub fn p99_us(&self) -> f64 {
        stats::percentile(&self.latencies_us, 99.0)
    }

    /// Mean end-to-end decode latency \[µs\].
    pub fn mean_us(&self) -> f64 {
        stats::mean(&self.latencies_us)
    }

    /// Decode throughput over the replay wall clock \[tokens/s\].
    pub fn tokens_per_s(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs > 0.0 {
            self.decoded_tokens as f64 / secs
        } else {
            0.0
        }
    }

    /// Whether every scheduled op resolved (nothing failed).
    pub fn completed(&self) -> bool {
        self.failed == 0
    }
}

/// One submitted decode whose ticket has not resolved yet.
struct PendingOp {
    /// Index of the trace op (the payload-regeneration key).
    op_idx: u64,
    session: u64,
    /// Admission delay already accrued \[µs\] (scheduled arrival →
    /// successful submission; 0 when unpaced).
    admit_delay_us: f64,
    retries: usize,
    ticket: Ticket,
}

/// Replays a [`Trace`] against a live server. Construct with the replay
/// knobs, then [`TrafficDriver::replay`] per trace.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrafficDriver {
    cfg: DriverConfig,
}

impl TrafficDriver {
    pub fn new(cfg: DriverConfig) -> Self {
        TrafficDriver { cfg }
    }

    /// Full-speed driver (no pacing): the bench/throughput mode.
    pub fn full_speed() -> Self {
        TrafficDriver::new(DriverConfig::default())
    }

    /// Paced driver: trace time compressed by `speedup`.
    pub fn paced(speedup: f64) -> Self {
        assert!(speedup > 0.0, "speedup must be positive");
        TrafficDriver::new(DriverConfig { speedup, ..DriverConfig::default() })
    }

    /// Replay the trace. Returns the report, or the first *terminal*
    /// `open` error that aborts the replay outright (a server whose
    /// admission refuses non-retryably — e.g. a dimension mismatch
    /// between trace and server config — is a harness bug, not traffic).
    pub fn replay(
        &self,
        trace: &Trace,
        server: &CamformerServer,
    ) -> Result<DriverReport, ServeError> {
        let policy = server.config().reclaim;
        let mut report = DriverReport::default();
        let mut handles: HashMap<u64, SessionHandle<'_>> = HashMap::new();
        // session -> (open op index, prefill rows): enough to regenerate
        // the exact prefill payload for re-opens
        let mut recipes: HashMap<u64, (u64, usize)> = HashMap::new();
        let mut pending: Vec<PendingOp> = Vec::new();
        let paced = self.cfg.speedup.is_finite();
        let start = Instant::now();

        for (idx, timed) in trace.ops.iter().enumerate() {
            let idx = idx as u64;
            let scheduled = if paced {
                let at = Duration::from_micros((timed.at_us as f64 / self.cfg.speedup) as u64);
                let target = start + at;
                std::thread::sleep(target.saturating_duration_since(Instant::now()));
                Some(target)
            } else {
                None
            };
            match timed.op {
                TraceOp::Open { session, prefill_rows } => {
                    recipes.insert(session, (idx, prefill_rows));
                    // a re-used id may still hold a stale handle (its
                    // state was lost); tear it down before re-admitting
                    if handles.remove(&session).is_some() {
                        report.closes += 1;
                    }
                    match self.open_session(trace, server, policy, session, idx, prefill_rows) {
                        Ok(h) => {
                            handles.insert(session, h);
                            report.opens += 1;
                        }
                        Err(e) if e.is_retryable(&policy) => report.failed += 1,
                        Err(e) => return Err(e),
                    }
                }
                TraceOp::Decode { session } => {
                    self.submit_decode(
                        trace,
                        server,
                        policy,
                        &mut handles,
                        &recipes,
                        &mut pending,
                        &mut report,
                        session,
                        idx,
                        scheduled,
                    );
                }
                TraceOp::Close { session } => {
                    // resolve this session's in-flight decodes first, so
                    // the teardown Close can never overtake them
                    let (mine, rest): (Vec<_>, Vec<_>) =
                        pending.drain(..).partition(|p| p.session == session);
                    pending = rest;
                    for p in mine {
                        self.resolve(trace, server, policy, &mut handles, &recipes, &mut report, p);
                    }
                    if handles.remove(&session).is_some() {
                        report.closes += 1;
                    }
                }
            }
            // opportunistic non-blocking drain keeps the in-flight set
            // (and the final drain) small without stalling the schedule
            let mut still = Vec::with_capacity(pending.len());
            for p in pending {
                let PendingOp { op_idx, session, admit_delay_us, retries, ticket } = p;
                match ticket.try_wait() {
                    Ok(resp) => self.finish(
                        trace,
                        server,
                        policy,
                        &mut handles,
                        &recipes,
                        &mut report,
                        op_idx,
                        session,
                        admit_delay_us,
                        retries,
                        resp,
                    ),
                    Err(ticket) => {
                        still.push(PendingOp { op_idx, session, admit_delay_us, retries, ticket })
                    }
                }
            }
            pending = still;
        }

        // final drain: everything still in flight resolves (blocking),
        // retry loops included
        for p in std::mem::take(&mut pending) {
            self.resolve(trace, server, policy, &mut handles, &recipes, &mut report, p);
        }
        report.closes += handles.len() as u64;
        drop(handles);
        report.wall = start.elapsed();
        Ok(report)
    }

    /// Open with a retry loop: admission refusals under a reclaiming
    /// policy drain as the server evicts or demotes victims.
    fn open_session<'srv>(
        &self,
        trace: &Trace,
        server: &'srv CamformerServer,
        policy: ReclaimPolicy,
        session: u64,
        op_idx: u64,
        rows: usize,
    ) -> Result<SessionHandle<'srv>, ServeError> {
        let (keys, values) = prefill_payload(trace, op_idx, rows);
        let mut attempt = 0;
        loop {
            match server.open(session, keys.clone(), values.clone()) {
                Ok(h) => return Ok(h),
                Err(e) if e.is_retryable(&policy) && attempt < self.cfg.max_retries => {
                    attempt += 1;
                    std::thread::sleep(RETRY_BACKOFF);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Submit one decode, draining one in-flight ticket per
    /// [`ServeError::Overloaded`] shed until the server admits it (or
    /// the retry budget runs out).
    #[allow(clippy::too_many_arguments)]
    fn submit_decode<'srv>(
        &self,
        trace: &Trace,
        server: &'srv CamformerServer,
        policy: ReclaimPolicy,
        handles: &mut HashMap<u64, SessionHandle<'srv>>,
        recipes: &HashMap<u64, (u64, usize)>,
        pending: &mut Vec<PendingOp>,
        report: &mut DriverReport,
        session: u64,
        op_idx: u64,
        scheduled: Option<Instant>,
    ) {
        let mut retries = 0;
        loop {
            if !handles.contains_key(&session) {
                // the session died (lost/evicted) with no pending decode
                // left to notice it — re-open from the recipe
                if retries >= self.cfg.max_retries
                    || !self.reopen(trace, server, policy, handles, recipes, report, session)
                {
                    report.failed += 1;
                    return;
                }
                retries += 1;
                continue;
            }
            let (query, new_key, new_value) = decode_payload(trace, op_idx);
            let submitted =
                handles.get(&session).expect("checked above").decode(query, new_key, new_value);
            match submitted {
                Ok(ticket) => {
                    let admit_delay_us = scheduled
                        .map(|s| Instant::now().saturating_duration_since(s).as_secs_f64() * 1e6)
                        .unwrap_or(0.0);
                    pending.push(PendingOp { op_idx, session, admit_delay_us, retries, ticket });
                    return;
                }
                Err(e) if e.is_retryable(&policy) && retries < self.cfg.max_retries => {
                    retries += 1;
                    report.shed_replays += 1;
                    // make room: resolve the oldest in-flight ticket so
                    // the standing queue can drain
                    if pending.is_empty() {
                        std::thread::sleep(RETRY_BACKOFF);
                    } else {
                        let p = pending.remove(0);
                        self.resolve(trace, server, policy, handles, recipes, report, p);
                    }
                }
                Err(_) => {
                    report.failed += 1;
                    return;
                }
            }
        }
    }

    /// Block on a pending op's ticket and feed the response through the
    /// retry taxonomy.
    fn resolve<'srv>(
        &self,
        trace: &Trace,
        server: &'srv CamformerServer,
        policy: ReclaimPolicy,
        handles: &mut HashMap<u64, SessionHandle<'srv>>,
        recipes: &HashMap<u64, (u64, usize)>,
        report: &mut DriverReport,
        p: PendingOp,
    ) {
        let PendingOp { op_idx, session, admit_delay_us, retries, ticket } = p;
        let resp = ticket.wait();
        self.finish(
            trace,
            server,
            policy,
            handles,
            recipes,
            report,
            op_idx,
            session,
            admit_delay_us,
            retries,
            resp,
        );
    }

    /// The retry taxonomy: a completed decode records its latency; a
    /// retryable failure resubmits (synchronously — retries are rare); a
    /// state-gone failure re-opens from the recipe and resubmits; the
    /// rest count as failed. Mutual recursion with [`Self::retry_decode`]
    /// is bounded by [`DriverConfig::max_retries`].
    #[allow(clippy::too_many_arguments)]
    fn finish<'srv>(
        &self,
        trace: &Trace,
        server: &'srv CamformerServer,
        policy: ReclaimPolicy,
        handles: &mut HashMap<u64, SessionHandle<'srv>>,
        recipes: &HashMap<u64, (u64, usize)>,
        report: &mut DriverReport,
        op_idx: u64,
        session: u64,
        admit_delay_us: f64,
        retries: usize,
        resp: Response,
    ) {
        match resp.result {
            Ok(_) => {
                report.decoded_tokens += 1;
                report.latencies_us.push(admit_delay_us + resp.latency.as_secs_f64() * 1e6);
            }
            Err(_) if retries >= self.cfg.max_retries => report.failed += 1,
            Err(ServeError::Overloaded { .. }) | Err(ServeError::Backend(_)) => {
                report.shed_replays += 1;
                self.retry_decode(
                    trace,
                    server,
                    policy,
                    handles,
                    recipes,
                    report,
                    op_idx,
                    session,
                    admit_delay_us,
                    retries + 1,
                );
            }
            // state-gone (lost/evicted) and capacity-starved decodes both
            // resolve through a re-open: a fresh Prefill is the one
            // admission path that runs the reclaim barrier, so it demotes
            // or evicts victims to make room where a bare decode retry
            // would starve forever (eviction never runs mid-dispatch)
            Err(ServeError::SessionLost { .. })
            | Err(ServeError::Evicted { .. })
            | Err(ServeError::UnknownSession { .. })
            | Err(ServeError::CapacityExhausted { .. })
            | Err(ServeError::SessionLimit { .. }) => {
                if self.reopen(trace, server, policy, handles, recipes, report, session) {
                    self.retry_decode(
                        trace,
                        server,
                        policy,
                        handles,
                        recipes,
                        report,
                        op_idx,
                        session,
                        admit_delay_us,
                        retries + 1,
                    );
                } else {
                    report.failed += 1;
                }
            }
            Err(_) => report.failed += 1,
        }
    }

    /// Resubmit one decode synchronously (submit, block, feed back
    /// through [`Self::finish`]).
    #[allow(clippy::too_many_arguments)]
    fn retry_decode<'srv>(
        &self,
        trace: &Trace,
        server: &'srv CamformerServer,
        policy: ReclaimPolicy,
        handles: &mut HashMap<u64, SessionHandle<'srv>>,
        recipes: &HashMap<u64, (u64, usize)>,
        report: &mut DriverReport,
        op_idx: u64,
        session: u64,
        admit_delay_us: f64,
        mut retries: usize,
    ) {
        loop {
            if !handles.contains_key(&session) {
                if retries >= self.cfg.max_retries
                    || !self.reopen(trace, server, policy, handles, recipes, report, session)
                {
                    report.failed += 1;
                    return;
                }
                retries += 1;
                continue;
            }
            let (query, new_key, new_value) = decode_payload(trace, op_idx);
            let submitted =
                handles.get(&session).expect("checked above").decode(query, new_key, new_value);
            match submitted {
                Ok(ticket) => {
                    let resp = ticket.wait();
                    self.finish(
                        trace,
                        server,
                        policy,
                        handles,
                        recipes,
                        report,
                        op_idx,
                        session,
                        admit_delay_us,
                        retries,
                        resp,
                    );
                    return;
                }
                Err(e) if e.is_retryable(&policy) && retries < self.cfg.max_retries => {
                    retries += 1;
                    report.shed_replays += 1;
                    std::thread::sleep(RETRY_BACKOFF);
                }
                Err(_) => {
                    report.failed += 1;
                    return;
                }
            }
        }
    }

    /// Re-admit a lost/evicted session from its prefill recipe. The
    /// stale handle (if any) is dropped *before* the new `open`, so its
    /// fire-and-forget closes can never tear down the re-admitted state.
    fn reopen<'srv>(
        &self,
        trace: &Trace,
        server: &'srv CamformerServer,
        policy: ReclaimPolicy,
        handles: &mut HashMap<u64, SessionHandle<'srv>>,
        recipes: &HashMap<u64, (u64, usize)>,
        report: &mut DriverReport,
        session: u64,
    ) -> bool {
        let Some(&(open_idx, rows)) = recipes.get(&session) else {
            return false;
        };
        if handles.remove(&session).is_some() {
            report.closes += 1;
        }
        match self.open_session(trace, server, policy, session, open_idx, rows) {
            Ok(h) => {
                handles.insert(session, h);
                report.opens += 1;
                report.reopens += 1;
                true
            }
            Err(_) => false,
        }
    }
}

/// Prefill payload for the `Open` at trace index `op_idx`: `rows` binary
/// keys and gaussian values in the trace's geometry, derived purely from
/// `(trace.seed, op_idx)`.
pub fn prefill_payload(trace: &Trace, op_idx: u64, rows: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = payload_rng(trace.seed, TAG_PREFILL, op_idx);
    let keys = rng.pm_one_vec(rows * trace.d_k);
    let values = rng.normal_vec(rows * trace.d_v);
    (keys, values)
}

/// Decode payload for the `Decode` at trace index `op_idx`:
/// `(query, new_key, new_value)`.
pub fn decode_payload(trace: &Trace, op_idx: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = payload_rng(trace.seed, TAG_DECODE, op_idx);
    let query = rng.pm_one_vec(trace.d_k);
    let new_key = rng.pm_one_vec(trace.d_k);
    let new_value = rng.normal_vec(trace.d_v);
    (query, new_key, new_value)
}

fn payload_rng(seed: u64, tag: u64, op_idx: u64) -> Rng {
    // tag in the top byte, index whitened across the low 64 bits: the
    // prefill and decode streams of one trace can never collide
    Rng::new(seed ^ (tag << 56) ^ op_idx.wrapping_mul(0x9E3779B97F4A7C15))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::{generate, TraceSpec};

    #[test]
    fn payloads_are_deterministic_and_shaped() {
        let trace = generate(&TraceSpec::bert(), 42);
        let (k1, v1) = prefill_payload(&trace, 3, 10);
        let (k2, v2) = prefill_payload(&trace, 3, 10);
        assert_eq!(k1, k2);
        assert_eq!(v1, v2);
        assert_eq!(k1.len(), 10 * trace.d_k);
        assert_eq!(v1.len(), 10 * trace.d_v);
        assert!(k1.iter().all(|&x| x == 1.0 || x == -1.0), "keys live in the CAM's ±1 domain");
        let (q, nk, nv) = decode_payload(&trace, 3);
        assert_eq!(q.len(), trace.d_k);
        assert_eq!(nk.len(), trace.d_k);
        assert_eq!(nv.len(), trace.d_v);
        // same index, different tag: the streams must not alias
        assert_ne!(&k1[..trace.d_k], &q[..]);
    }

    #[test]
    fn payload_streams_differ_by_index_and_seed() {
        let trace = generate(&TraceSpec::bert(), 42);
        let (a, _) = prefill_payload(&trace, 1, 4);
        let (b, _) = prefill_payload(&trace, 2, 4);
        assert_ne!(a, b, "different ops must draw different payloads");
        let other = generate(&TraceSpec::bert(), 43);
        let (c, _) = prefill_payload(&other, 1, 4);
        assert_ne!(a, c, "different seeds must draw different payloads");
    }

    #[test]
    fn report_percentiles_and_throughput() {
        let mut r = DriverReport {
            latencies_us: (1..=100).map(|i| i as f64).collect(),
            decoded_tokens: 100,
            ..DriverReport::default()
        };
        r.wall = Duration::from_secs(2);
        assert!((r.p50_us() - 50.5).abs() < 1e-9);
        assert!((r.p99_us() - 99.01).abs() < 0.1);
        assert!((r.mean_us() - 50.5).abs() < 1e-9);
        assert!((r.tokens_per_s() - 50.0).abs() < 1e-9);
        assert!(r.completed());
        r.failed = 1;
        assert!(!r.completed());
    }

    #[test]
    fn empty_report_is_benign() {
        let r = DriverReport::default();
        assert_eq!(r.p50_us(), 0.0);
        assert_eq!(r.p99_us(), 0.0);
        assert_eq!(r.mean_us(), 0.0);
        assert_eq!(r.tokens_per_s(), 0.0);
        assert!(r.completed());
    }
}
