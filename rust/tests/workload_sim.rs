//! Workload co-simulation acceptance (ISSUE 10): a generated trace
//! replayed through a live multi-shard server under the DRAM spill
//! tier, with the energy accounting reconciled against a per-dispatch
//! `WorkStats` ledger — every joule the accountant charges traces back
//! to a recorded dispatch delta or an explicit flow counter (KV rows
//! admitted, DRAM traffic) — plus the end-to-end determinism guard:
//! the same seed yields bit-identical traces AND bit-identical energy
//! totals across independent server runs.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;
use camformer::coordinator::backend::{AttendItem, AttentionBackend, FunctionalBackend, WorkStats};
use camformer::coordinator::{CamformerServer, EnergyStages, ReclaimPolicy, ServerConfig};
use camformer::workload::{generate, EnergyAccountant, TraceSpec, TrafficDriver};

/// A recording wrapper: forwards everything to the inner functional
/// backend and appends each dispatch's `WorkStats` delta to a shared
/// ledger — the reconciliation oracle for the energy accountant.
struct LedgerBackend {
    inner: FunctionalBackend,
    ledger: Arc<Mutex<Vec<WorkStats>>>,
}

impl AttentionBackend for LedgerBackend {
    fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        let before = self.inner.work;
        let out = self.inner.attend(q, k, v);
        self.ledger.lock().unwrap().push(self.inner.work.delta_since(&before));
        out
    }

    fn attend_batch(&mut self, items: &[AttendItem<'_>]) -> Result<Vec<Vec<f32>>> {
        let before = self.inner.work;
        let out = self.inner.attend_batch(items);
        self.ledger.lock().unwrap().push(self.inner.work.delta_since(&before));
        out
    }

    fn supports_prefix_views(&self) -> bool {
        self.inner.supports_prefix_views()
    }

    fn required_rows(&self, rows: usize, quantum: usize) -> usize {
        self.inner.required_rows(rows, quantum)
    }

    fn on_kv_update(&mut self) {
        self.inner.on_kv_update()
    }

    fn work_stats(&self) -> Option<WorkStats> {
        self.inner.work_stats()
    }

    fn name(&self) -> &'static str {
        "ledger(functional)"
    }
}

fn rel_close(a: f64, b: f64, what: &str) {
    let scale = a.abs().max(b.abs()).max(1e-30);
    assert!((a - b).abs() / scale < 1e-9, "{what}: {a} vs {b}");
}

/// The tentpole end-to-end: zipf-hotset traffic on a 2-shard server
/// whose tight KV budget keeps demoting the session tail through the
/// DRAM spill tier. Every scheduled token completes, the spill tier
/// actually churns, the per-dispatch ledger reconciles with the folded
/// `Metrics::work` EXACTLY (u64), and the accountant's total equals the
/// sum of per-dispatch charges plus the flow charges (additivity at
/// system scale).
#[test]
fn spill_tier_replay_reconciles_energy_ledger() {
    let spec = TraceSpec::zipf_hotset();
    let trace = generate(&spec, 2026);
    let cap = spec.kv_capacity();
    let ledger: Arc<Mutex<Vec<WorkStats>>> = Arc::new(Mutex::new(Vec::new()));
    let cfg = ServerConfig {
        shards: 2,
        kv_capacity: cap,
        // two resident sessions per worker: the 16-session population
        // has to churn through the spill tier on every re-touch. The
        // session-slot bound (not a row budget) is the churn driver so
        // reclaim only ever runs inside prefill/promotion barriers —
        // deterministic in queue order — and no decode can starve
        max_sessions: 2,
        reclaim: ReclaimPolicy::LruSpillToDram { min_idle: Duration::ZERO },
        d_k: spec.d_k,
        d_v: spec.d_v,
        ..Default::default()
    };
    let sink = ledger.clone();
    let server = CamformerServer::start(cfg, move |_| LedgerBackend {
        inner: FunctionalBackend::new(cap, 64),
        ledger: sink.clone(),
    });

    let report = TrafficDriver::full_speed().replay(&trace, &server).unwrap();
    assert!(report.completed(), "replay left {} ops unresolved", report.failed);
    assert_eq!(report.decoded_tokens, spec.requests as u64);
    assert_eq!(report.reopens, 0, "the spill tier must hide eviction from clients");
    assert!(report.p99_us() >= report.p50_us());
    assert!(report.p50_us() > 0.0);

    let (mut metrics, window) = server.shutdown();
    assert_eq!(metrics.decodes, spec.requests as u64);
    assert_eq!(metrics.evictions, 0, "the spill tier must demote, never drop");
    assert!(metrics.demotions > 0, "tight budget must demote ({})", metrics.summary());
    assert!(metrics.promotions > 0, "hotset re-touches must promote ({})", metrics.summary());
    assert!(metrics.dram_energy_j > 0.0, "spill traffic must cost DRAM energy");

    // ledger reconciliation: the per-dispatch deltas sum to the folded
    // worker totals exactly — u64 counters, no tolerance
    let deltas = ledger.lock().unwrap();
    assert!(!deltas.is_empty());
    let mut summed = WorkStats::default();
    for d in deltas.iter() {
        summed.add(d);
    }
    assert_eq!(summed, metrics.work, "per-dispatch ledger must reconcile with Metrics::work");

    // energy reconciliation: total charge == sum of per-dispatch charges
    // + the flow charges (rows programmed, DRAM), stage by stage
    let acct = EnergyAccountant::paper(spec.d_v);
    let total = acct.account(&metrics);
    let mut recon = EnergyStages::default();
    for d in deltas.iter() {
        recon.add(&acct.account_work(d, 0, 0.0));
    }
    recon.add(&acct.account_work(
        &WorkStats::default(),
        metrics.kv_rows_admitted,
        metrics.dram_energy_j,
    ));
    rel_close(recon.search_j, total.search_j, "search_j");
    rel_close(recon.program_j, total.program_j, "program_j");
    rel_close(recon.selection_j, total.selection_j, "selection_j");
    rel_close(recon.softmax_j, total.softmax_j, "softmax_j");
    rel_close(recon.context_j, total.context_j, "context_j");
    rel_close(recon.dram_j, total.dram_j, "dram_j");
    rel_close(recon.total_j(), total.total_j(), "total_j");
    assert!(total.dram_share() > 0.0 && total.dram_share() < 1.0);

    // the attached surface: J/token, watts and the DRAM share land in
    // the summary line
    acct.attach(&mut metrics);
    assert!(metrics.energy_per_token_j() > 0.0);
    assert!(metrics.watts(window) > 0.0);
    let s = metrics.summary();
    assert!(s.contains("j_per_token="), "summary missing energy: {s}");
    assert!(s.contains("dram_share="), "summary missing dram share: {s}");
}

/// Determinism guard at full system scale: same seed ⇒ identical trace
/// ⇒ identical work counters, identical KV admission flow, identical
/// spill decisions — so the energy totals of two independent replays
/// compare EQUAL as f64 bit patterns, not merely close.
#[test]
fn same_seed_bit_identical_energy_totals() {
    let spec = TraceSpec::bert();
    let cap = spec.kv_capacity();
    let run = || {
        let trace = generate(&spec, 7);
        let cfg = ServerConfig {
            shards: 2,
            kv_capacity: cap,
            // slot-bound churn (see above): reclaim decisions stay in
            // deterministic queue order, so spill traffic — and with it
            // the DRAM energy charge — must be bit-identical per seed
            max_sessions: 2,
            reclaim: ReclaimPolicy::LruSpillToDram { min_idle: Duration::ZERO },
            d_k: spec.d_k,
            d_v: spec.d_v,
            ..Default::default()
        };
        let server = CamformerServer::start(cfg, move |_| FunctionalBackend::new(cap, 64));
        let report = TrafficDriver::full_speed().replay(&trace, &server).unwrap();
        assert!(report.completed());
        let (metrics, _) = server.shutdown();
        let energy = EnergyAccountant::paper(spec.d_v).account(&metrics);
        (metrics.work, metrics.kv_rows_admitted, metrics.dram_energy_j, energy)
    };
    let (work_a, rows_a, dram_a, energy_a) = run();
    let (work_b, rows_b, dram_b, energy_b) = run();
    assert_eq!(work_a, work_b, "work counters must be run-invariant");
    assert_eq!(rows_a, rows_b, "KV admission flow must be run-invariant");
    assert_eq!(dram_a.to_bits(), dram_b.to_bits(), "DRAM charge must be bit-identical");
    assert_eq!(energy_a, energy_b, "energy totals must be bit-identical");
    assert_eq!(energy_a.total_j().to_bits(), energy_b.total_j().to_bits());
}

/// The closed retry loop under deliberate overload: a queue bound of 4
/// under full-speed replay sheds constantly, and the driver's
/// drain-and-resubmit loop still lands every scheduled token.
#[test]
fn overload_sheds_are_replayed_to_completion() {
    let spec = TraceSpec::vit();
    let trace = generate(&spec, 11);
    let cap = spec.kv_capacity();
    let cfg = ServerConfig {
        kv_capacity: cap,
        max_queue: 4,
        d_k: spec.d_k,
        d_v: spec.d_v,
        ..Default::default()
    };
    let server = CamformerServer::start(cfg, move |_| FunctionalBackend::new(cap, 64));
    let report = TrafficDriver::full_speed().replay(&trace, &server).unwrap();
    assert!(report.completed(), "sheds must replay to completion, {} failed", report.failed);
    assert_eq!(report.decoded_tokens, spec.requests as u64);
    assert!(report.shed_replays > 0, "max_queue=4 under full-speed replay must shed");
    let (metrics, _) = server.shutdown();
    assert_eq!(metrics.decodes, spec.requests as u64, "retries must never double-decode");
    assert!(metrics.shed_requests > 0);
}
