//! Coordinator integration: serving flows over the functional and
//! arch-sim backends (the PJRT serving flow is covered by
//! `runtime_integration` and the examples).

use std::time::Duration;

use camformer::accuracy::functional::{self, AttnConfig};
use camformer::coordinator::backend::{ArchSimBackend, AttentionBackend, FunctionalBackend};
use camformer::coordinator::batcher::BatchPolicy;
use camformer::coordinator::kv_store::KvStore;
use camformer::coordinator::server::{CamformerServer, Request, ServerConfig};
use camformer::util::rng::Rng;

fn kv(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (rng.normal_vec(n * 64), rng.normal_vec(n * 64))
}

#[test]
fn serving_is_deterministic_and_correct_under_load() {
    let n = 512;
    let heads = 3;
    let kvs: Vec<(Vec<f32>, Vec<f32>)> = (0..heads).map(|h| kv(n, 100 + h as u64)).collect();
    let kvc = kvs.clone();
    let server = CamformerServer::start(
        ServerConfig {
            heads,
            batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
        },
        |_| FunctionalBackend::new(n, 64),
        move |h| kvc[h].clone(),
    );
    let mut rng = Rng::new(200);
    let queries: Vec<Vec<f32>> = (0..120).map(|_| rng.normal_vec(64)).collect();
    for (i, q) in queries.iter().enumerate() {
        server
            .submit(Request { id: i as u64, head: i % heads, query: q.clone() })
            .unwrap();
    }
    let mut resps = server.collect(120);
    resps.sort_by_key(|r| r.id);

    let cfg = AttnConfig::paper(n, 64);
    for r in &resps {
        let (k, v) = &kvs[r.head];
        let want = functional::camformer_attention(&queries[r.id as usize], k, v, &cfg);
        assert_eq!(r.output, want, "request {}", r.id);
    }
    let (m, _) = server.shutdown();
    assert_eq!(m.completed, 120);
    assert_eq!(m.errors, 0);
    assert!(m.batches <= 120); // batching actually coalesced some work
}

#[test]
fn arch_backend_serves_with_latency_annotation() {
    let n = 256;
    let (keys, values) = kv(n, 300);
    let kc = keys.clone();
    let vc = values.clone();
    let server = CamformerServer::start(
        ServerConfig::default(),
        |_| ArchSimBackend::new(n),
        move |_| (kc.clone(), vc.clone()),
    );
    let mut rng = Rng::new(301);
    for i in 0..10u64 {
        server
            .submit(Request { id: i, head: 0, query: rng.normal_vec(64) })
            .unwrap();
    }
    let resps = server.collect(10);
    assert_eq!(resps.len(), 10);
    // outputs agree with the functional model
    let cfg = AttnConfig::paper(n, 64);
    let mut rng2 = Rng::new(301);
    let mut sorted = resps;
    sorted.sort_by_key(|r| r.id);
    for r in &sorted {
        let q = rng2.normal_vec(64);
        let want = functional::camformer_attention(&q, &keys, &values, &cfg);
        for (a, b) in r.output.iter().zip(&want) {
            assert!((a - b).abs() < 0.05);
        }
    }
    server.shutdown();
}

#[test]
fn decode_style_kv_growth_through_store() {
    // simulate causal decoding: KV cache grows, each step queries it
    let mut store = KvStore::new(64, 64, 64);
    let mut rng = Rng::new(400);
    let mut backend = FunctionalBackend::new(64, 64);
    for step in 1..=64usize {
        let k = rng.normal_vec(64);
        let v = rng.normal_vec(64);
        store.append(&k, &v).unwrap();
        // pad to the backend's fixed geometry
        let (kp, vp, valid) = store.padded_view(64);
        assert_eq!(valid, step);
        let q = rng.normal_vec(64);
        let out = backend.attend(&q, &kp, &vp).unwrap();
        assert_eq!(out.len(), 64);
        assert!(out.iter().all(|x| x.is_finite()));
    }
    assert!(store.append(&rng.normal_vec(64), &rng.normal_vec(64)).is_err());
}

#[test]
fn partial_batches_flush_on_timeout() {
    let n = 128;
    let (keys, values) = kv(n, 500);
    let server = CamformerServer::start(
        ServerConfig {
            heads: 1,
            batch: BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(1) },
        },
        |_| FunctionalBackend::new(n, 64),
        move |_| (keys.clone(), values.clone()),
    );
    let mut rng = Rng::new(501);
    // submit 3 << max_batch and expect them all back quickly
    for i in 0..3u64 {
        server
            .submit(Request { id: i, head: 0, query: rng.normal_vec(64) })
            .unwrap();
    }
    let resps = server.collect_timeout(3, Duration::from_secs(5));
    assert_eq!(resps.len(), 3);
    server.shutdown();
}
