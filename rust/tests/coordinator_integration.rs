//! Coordinator integration: session-oriented serving flows over the
//! functional and arch-sim backends, including cross-session batched
//! dispatch (the PJRT serving flow is covered by `runtime_integration`
//! and the examples; the batched-vs-sequential decode acceptance tests
//! live in `decode_serving.rs`).

use std::time::Duration;

use camformer::accuracy::functional::{self, AttnConfig};
use camformer::coordinator::backend::{ArchSimBackend, AttentionBackend, FunctionalBackend};
use camformer::coordinator::batcher::BatchPolicy;
use camformer::coordinator::kv_store::KvStore;
use camformer::coordinator::server::{CamformerServer, Request, Response, ServerConfig};
use camformer::coordinator::Ticket;
use camformer::util::rng::Rng;

fn kv(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (rng.normal_vec(n * 64), rng.normal_vec(n * 64))
}

/// Resolve every ticket and return the responses in request-id order.
fn wait_all(tickets: Vec<Ticket>) -> Vec<Response> {
    let mut resps: Vec<Response> = tickets.into_iter().map(Ticket::wait).collect();
    resps.sort_by_key(|r| r.id);
    resps
}

#[test]
fn serving_is_deterministic_and_correct_under_load() {
    let n = 512;
    let heads = 3;
    let kvs: Vec<(Vec<f32>, Vec<f32>)> = (0..heads).map(|h| kv(n, 100 + h as u64)).collect();
    let server = CamformerServer::start(
        ServerConfig {
            heads,
            kv_capacity: n,
            batch: BatchPolicy::bounds(8, Duration::from_micros(500)),
            ..Default::default()
        },
        |_| FunctionalBackend::new(n, 64),
    );
    let mut acks = Vec::new();
    for (h, (keys, values)) in kvs.iter().enumerate() {
        acks.push(
            server
                .submit_ticket(Request::Prefill {
                    id: 10_000 + h as u64,
                    session: 1,
                    head: h,
                    keys: keys.clone(),
                    values: values.clone(),
                })
                .unwrap(),
        );
    }
    for ack in wait_all(acks) {
        assert!(ack.is_ok(), "prefill failed: {:?}", ack.result);
    }
    let mut rng = Rng::new(200);
    let queries: Vec<Vec<f32>> = (0..120).map(|_| rng.normal_vec(64)).collect();
    let mut tickets = Vec::new();
    for (i, q) in queries.iter().enumerate() {
        tickets.push(
            server
                .submit_ticket(Request::Attend {
                    id: i as u64,
                    session: 1,
                    head: i % heads,
                    query: q.clone(),
                })
                .unwrap(),
        );
    }
    let resps = wait_all(tickets);
    assert_eq!(resps.len(), 120);

    let cfg = AttnConfig::paper(n, 64);
    for r in &resps {
        let (k, v) = &kvs[r.head];
        let want = functional::camformer_attention(&queries[r.id as usize], k, v, &cfg);
        assert_eq!(r.output(), &want[..], "request {}", r.id);
    }
    let (m, _) = server.shutdown();
    assert_eq!(m.completed, 120 + heads as u64);
    assert_eq!(m.attends, 120);
    assert_eq!(m.errors, 0);
    assert!(m.batches <= 120 + heads as u64); // batching coalesced some work
}

#[test]
fn arch_backend_serves_with_latency_annotation() {
    let n = 256;
    let (keys, values) = kv(n, 300);
    let server = CamformerServer::start(
        ServerConfig { kv_capacity: n, ..Default::default() },
        |_| ArchSimBackend::new(n),
    );
    let ack = server
        .submit_ticket(Request::Prefill {
            id: 100,
            session: 0,
            head: 0,
            keys: keys.clone(),
            values: values.clone(),
        })
        .unwrap()
        .wait();
    assert!(ack.is_ok(), "prefill failed: {:?}", ack.result);
    let mut rng = Rng::new(301);
    let queries: Vec<Vec<f32>> = (0..10).map(|_| rng.normal_vec(64)).collect();
    let mut tickets = Vec::new();
    for (i, q) in queries.iter().enumerate() {
        tickets.push(
            server
                .submit_ticket(Request::Attend { id: i as u64, session: 0, head: 0, query: q.clone() })
                .unwrap(),
        );
    }
    let resps = wait_all(tickets);
    assert_eq!(resps.len(), 10);
    // outputs agree with the functional model
    let cfg = AttnConfig::paper(n, 64);
    for r in &resps {
        let want = functional::camformer_attention(&queries[r.id as usize], &keys, &values, &cfg);
        for (a, b) in r.output().iter().zip(&want) {
            assert!((a - b).abs() < 0.05);
        }
    }
    server.shutdown();
}

#[test]
fn decode_style_kv_growth_through_store() {
    // the KvStore layer alone: causal decoding against the zero-copy
    // padded view plus the store-owned packed key bits (no backend-side
    // cache to invalidate anymore — the store packs each appended row
    // incrementally and the dispatch view carries the bits)
    use camformer::coordinator::backend::AttendItem;
    let mut store = KvStore::new(64, 64, 64);
    let mut rng = Rng::new(400);
    let mut backend = FunctionalBackend::new(64, 64);
    for step in 1..=64usize {
        let k = rng.normal_vec(64);
        let v = rng.normal_vec(64);
        store.append(&k, &v).unwrap();
        let rows = backend.required_rows(store.len(), 16).min(64);
        let (kp, vp, valid) = store.padded(rows);
        assert_eq!(valid, step);
        let q = rng.normal_vec(64);
        let item = AttendItem {
            query: &q,
            keys: kp,
            values: vp,
            prefix_rows: valid,
            packed: Some(store.packed_view(rows)),
        };
        let out = backend.attend_batch(&[item]).unwrap().remove(0);
        assert_eq!(out.len(), 64);
        assert!(out.iter().all(|x| x.is_finite()));
    }
    assert_eq!(
        backend.work.fallback_rows_packed,
        0,
        "decode served entirely from store-owned packed bits"
    );
    assert_eq!(store.packed_rows_total(), 64, "one packed row per append");
    assert!(store.append(&rng.normal_vec(64), &rng.normal_vec(64)).is_err());
}

#[test]
fn sessions_are_isolated_across_shards() {
    // two sessions with different caches on different shards: each query
    // must see only its own session's memory
    let n = 128;
    let (k0, v0) = kv(n, 500);
    let (k1, v1) = kv(n, 501);
    let server = CamformerServer::start(
        ServerConfig { shards: 2, kv_capacity: n, ..Default::default() },
        |_| FunctionalBackend::new(n, 64),
    );
    // session 2 -> shard 0, session 3 -> shard 1
    let mut rng = Rng::new(502);
    let q = rng.normal_vec(64);
    let tickets = vec![
        server
            .submit_ticket(Request::Prefill {
                id: 0,
                session: 2,
                head: 0,
                keys: k0.clone(),
                values: v0.clone(),
            })
            .unwrap(),
        server
            .submit_ticket(Request::Prefill {
                id: 1,
                session: 3,
                head: 0,
                keys: k1.clone(),
                values: v1.clone(),
            })
            .unwrap(),
        server
            .submit_ticket(Request::Attend { id: 2, session: 2, head: 0, query: q.clone() })
            .unwrap(),
        server
            .submit_ticket(Request::Attend { id: 3, session: 3, head: 0, query: q.clone() })
            .unwrap(),
    ];
    let resps = wait_all(tickets);
    let cfg = AttnConfig::paper(n, 64);
    let want0 = functional::camformer_attention(&q, &k0, &v0, &cfg);
    let want1 = functional::camformer_attention(&q, &k1, &v1, &cfg);
    assert_eq!(resps[2].output(), &want0[..]);
    assert_eq!(resps[3].output(), &want1[..]);
    assert_ne!(resps[2].output(), resps[3].output());
    server.shutdown();
}

#[test]
fn attend_after_decode_sees_fresh_cache() {
    // staleness regression: the KV buffer mutates in place (same
    // pointer), so any layer serving a stale key derivative — once the
    // backend's identity cache, now the store-owned incremental packed
    // bits — would silently return old scores
    let n = 64;
    let cfg = ServerConfig { kv_capacity: n, ..Default::default() };
    let quantum = cfg.pad_quantum;
    let server = CamformerServer::start(cfg, |_| FunctionalBackend::new(n, 64));
    let mut rng = Rng::new(600);
    let mut mirror = KvStore::new(n, 64, 64);
    // 20 rows pad to 32 both before and after one append, so the K buffer
    // keeps the same pointer AND length across the mutation — the exact
    // situation where identity checks cannot detect staleness and the
    // packed bits must have been updated at append time
    let keys = rng.normal_vec(20 * 64);
    let values = rng.normal_vec(20 * 64);
    mirror.load(&keys, &values).unwrap();
    let q = rng.normal_vec(64);
    let nk = rng.normal_vec(64);
    let nv = rng.normal_vec(64);
    // attend (primes the cache), decode (mutates in place), attend again
    let tickets = vec![
        server
            .submit_ticket(Request::Prefill { id: 0, session: 0, head: 0, keys, values })
            .unwrap(),
        server
            .submit_ticket(Request::Attend { id: 1, session: 0, head: 0, query: q.clone() })
            .unwrap(),
        server
            .submit_ticket(Request::Decode {
                id: 2,
                session: 0,
                head: 0,
                query: q.clone(),
                new_key: nk.clone(),
                new_value: nv.clone(),
            })
            .unwrap(),
        server
            .submit_ticket(Request::Attend { id: 3, session: 0, head: 0, query: q.clone() })
            .unwrap(),
    ];
    mirror.append(&nk, &nv).unwrap();
    let resps = wait_all(tickets);
    let rows = mirror.len().div_ceil(quantum) * quantum;
    let (kp, vp, _) = mirror.padded(rows);
    let want = functional::camformer_attention(&q, kp, vp, &AttnConfig::paper(rows, 64));
    assert_eq!(resps[2].output(), &want[..], "decode must see the appended row");
    assert_eq!(resps[3].output(), &want[..], "attend must not serve a stale cache");
    assert_eq!(resps[3].seq_len(), 21);
    server.shutdown();
}

#[test]
fn cross_session_attends_share_dispatches_and_stay_isolated() {
    // many sessions on ONE worker, read-only attends interleaved: the
    // cross-session batcher may coalesce them into shared dispatches, and
    // every query must still see only its own session's memory
    let n = 128;
    let sessions = 4u64;
    let kvs: Vec<(Vec<f32>, Vec<f32>)> = (0..sessions).map(|s| kv(n, 700 + s)).collect();
    let server = CamformerServer::start(
        ServerConfig {
            kv_capacity: n,
            batch: BatchPolicy::bounds(16, Duration::from_millis(2)),
            ..Default::default()
        },
        |_| FunctionalBackend::new(n, 64),
    );
    let mut acks = Vec::new();
    for (s, (keys, values)) in kvs.iter().enumerate() {
        acks.push(
            server
                .submit_ticket(Request::Prefill {
                    id: 1000 + s as u64,
                    session: s as u64,
                    head: 0,
                    keys: keys.clone(),
                    values: values.clone(),
                })
                .unwrap(),
        );
    }
    for ack in wait_all(acks) {
        assert!(ack.is_ok(), "prefill failed: {:?}", ack.result);
    }
    let mut rng = Rng::new(701);
    let queries: Vec<Vec<f32>> = (0..40).map(|_| rng.normal_vec(64)).collect();
    let mut tickets = Vec::new();
    for (i, q) in queries.iter().enumerate() {
        tickets.push(
            server
                .submit_ticket(Request::Attend {
                    id: i as u64,
                    session: i as u64 % sessions,
                    head: 0,
                    query: q.clone(),
                })
                .unwrap(),
        );
    }
    let resps = wait_all(tickets);
    let cfg = AttnConfig::paper(n, 64);
    for r in &resps {
        let (k, v) = &kvs[(r.id % sessions) as usize];
        let want = functional::camformer_attention(&queries[r.id as usize], k, v, &cfg);
        assert_eq!(r.output(), &want[..], "request {}", r.id);
    }
    let (m, _) = server.shutdown();
    assert_eq!(m.errors, 0);
    assert_eq!(m.attends, 40);
    // every attend went through a counted dispatch; occupancy is >= 1 by
    // construction and > 1 whenever any coalescing happened (asserted
    // under controlled timing in the hotpath bench, not here)
    assert_eq!(m.dispatched_queries, 40);
    assert!(m.dispatches >= 1 && m.dispatches <= 40);
    assert!(m.mean_occupancy() >= 1.0);
}

#[test]
fn partial_batches_flush_on_timeout() {
    let n = 128;
    let (keys, values) = kv(n, 500);
    let server = CamformerServer::start(
        ServerConfig {
            kv_capacity: n,
            batch: BatchPolicy::bounds(16, Duration::from_millis(1)),
            ..Default::default()
        },
        |_| FunctionalBackend::new(n, 64),
    );
    let mut rng = Rng::new(501);
    // submit 1 prefill + 3 attends << max_batch: the standing scheduler
    // must flush the partial plan on its max_wait deadline, so every
    // ticket resolves well within the generous bound
    let mut tickets = vec![server
        .submit_ticket(Request::Prefill {
            id: 100,
            session: 0,
            head: 0,
            keys,
            values,
        })
        .unwrap()];
    for i in 0..3u64 {
        tickets.push(
            server
                .submit_ticket(Request::Attend { id: i, session: 0, head: 0, query: rng.normal_vec(64) })
                .unwrap(),
        );
    }
    for t in tickets {
        let r = t
            .wait_timeout(Duration::from_secs(5))
            .expect("partial batch did not flush before the timeout");
        assert!(r.is_ok(), "request failed: {:?}", r.result);
    }
    server.shutdown();
}
