//! Randomized batched-vs-sequential equivalence harness (ISSUE 3,
//! extended for the survivor-list sparse pipeline in ISSUE 4 and for
//! session lifecycle — `Close` + LRU eviction — in ISSUE 5).
//!
//! Speculative multi-step fusion changes the core batching invariant:
//! a dispatch group may hold many decode steps of one session, each
//! attending over its own causal prefix view. The invariant is subtle
//! enough that example-based tests cannot be trusted to pin it down, so
//! this harness generates ~200 arbitrary interleaved
//! Prefill/Decode/Attend/Close streams across sessions — including
//! capacity-refusal and unknown-session cases — and asserts, for every
//! stream, that every dispatch config (sequential / conservative /
//! fused / fused-scratch) crossed with all three functional pipelines
//! (dense mask baseline × survivor-list sparse × the ISSUE 7 fused
//! FlashCAM kernel, the serving default) is bit-equal to sequential
//! dense dispatch — and that the prefix-native dispatch configs agree
//! not only on outputs but on the backend's `WorkStats` work counters
//! (words scored, tiles streamed, survivor corrections): per-item
//! padded geometry depends only on each query's own causal prefix, so
//! how dispatch grouped the queries must never leak into the work
//! performed. (The scratch-materialisation config is excluded from
//! counter parity by design: without native prefix views the backend
//! re-packs and scores the literal pad tail.) Plus the planner invariants
//! (prefill is a barrier; Close is a same-session barrier; order
//! preservation; group occupancy bounds) on every generated wire batch.
//! A second stream family runs workers at `max_sessions = 2` under
//! `ReclaimPolicy::LruEvictIdle`, so admissions overflow and evict:
//! victim choice rides on the worker's logical clock, so eviction (and
//! every downstream `Evicted` response) must also be bit-equal across
//! dispatch configs — which is also what proves eviction can never
//! victimize a session with in-flight fused appends (eviction only runs
//! inside `Prefill` barriers, never mid-group; any violation would
//! diverge from sequential dispatch here). A deterministic boundary
//! property test pins the prefix-view semantics at fused-burst lengths
//! {1, 2, cam-1, cam, cam+1}.
//!
//! The standing-scheduler hardening (ISSUE 6) adds an **arrival-jitter
//! family**: the same streams submitted with randomized inter-arrival
//! delays against a tight shared `worker_kv_budget` and a tiny
//! `max_queue`, so plans are extended incrementally across scheduling
//! cycles, admission rides the shared budget, and `Overloaded` sheds
//! fire for real (each one replayed to completion — nothing was
//! enqueued, so program order is preserved). Bit-equality to unjittered
//! sequential dispatch must survive all of it, with counter parity on
//! admitted KV rows, pool residency high-water mark, evictions, and
//! closes.
//!
//! The shard-coordinated spill tier (ISSUE 8) adds a **spill family**:
//! the admission-overflowing streams re-run under
//! `ReclaimPolicy::LruSpillToDram`, where the pressure must be
//! *invisible* — every response bit-equal to an unlimited pressure-free
//! run (demoted KV promotes back byte-identically, spilled closes ack
//! like resident ones), zero `Evicted` anywhere, and demote/promote
//! counter parity across dispatch configs.
//!
//! The fault-injection layer (ISSUE 9) adds a **chaos family**: the
//! same streams served through a `ChaosBackend` running seeded random
//! `FaultPlan`s (typed backend errors, contained dispatch panics,
//! `WorkerAbort` crashes with supervised restart, stalls) across
//! dispatch configs and reclaim policies. Three invariants: every
//! submitted ticket resolves typed within a deadline (no hang, no
//! silent drop); sessions never touched by a fault stay bit-equal to a
//! fault-free run (a session only diverges after a fault-typed
//! response); and the fault counters reconcile exactly with the
//! injection ledger — `backend_faults == errors`,
//! `worker_panics == panics + crashes`, `worker_restarts == crashes`,
//! `WorkerGone` observed iff a crash fired, and crashes always lose at
//! least one resident session.

use std::collections::HashSet;
use std::sync::atomic::Ordering as AtomicOrdering;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use camformer::accuracy::functional::{self, AttnConfig};
use camformer::coordinator::backend::{
    AttendItem, AttentionBackend, ChaosBackend, ChaosStats, FaultPlan, FunctionalBackend, Pipeline,
};
use camformer::coordinator::batcher::{BatchPolicy, DecodeBatcher, DispatchGroup, PlanMode};
use camformer::coordinator::kv_store::KvStore;
use camformer::coordinator::server::{CamformerServer, Request, ServerConfig};
use camformer::coordinator::{Envelope, Metrics, ReclaimPolicy, Response, ServeError, Ticket};
use camformer::util::rng::Rng;

/// Small dimensions keep 200 x 4 server runs fast while still crossing
/// every pad-quantum boundary (capacity = 2 stage-1 tiles).
const D: usize = 32;
const CAPACITY: usize = 32;

/// Session pool: 1..3 get prefilled by the stream (usually); 77 never
/// does, so decodes/attends/closes against it exercise admission
/// failures inside fused groups.
const SESSIONS: [u64; 4] = [1, 2, 3, 77];

fn gen_stream(rng: &mut Rng, ops: usize) -> Vec<Request> {
    let mut out = Vec::with_capacity(ops);
    for id in 0..ops as u64 {
        let session = SESSIONS[rng.index(SESSIONS.len())];
        let req = match rng.index(20) {
            // occasional (re-)prefill: a barrier that can also SHRINK the
            // cache mid-stream
            0..=1 if session != 77 => {
                let rows = 1 + rng.index(12);
                Request::Prefill {
                    id,
                    session,
                    head: 0,
                    keys: rng.normal_vec(rows * D),
                    values: rng.normal_vec(rows * D),
                }
            }
            // decode-heavy: deep same-session bursts arise naturally and
            // eventually overflow CAPACITY (typed refusals mid-burst)
            2..=12 => Request::Decode {
                id,
                session,
                head: 0,
                query: rng.normal_vec(D),
                new_key: rng.normal_vec(D),
                new_value: rng.normal_vec(D),
            },
            // lifecycle traffic (ISSUE 5): closes mid-stream — the
            // session may be live (slot released), already closed
            // (UnknownSession) or never prefilled (77)
            13..=14 => Request::Close { id, session, head: 0 },
            _ => Request::Attend { id, session, head: 0, query: rng.normal_vec(D) },
        };
        out.push(req);
    }
    out
}

/// Generous defaults: neither the shared KV budget nor the queue bound
/// binds, so the legacy stream families pin batching semantics alone.
const WIDE_BUDGET: usize = 1024 * 64;
const DEEP_QUEUE: usize = 4096;

fn run_stream<B, F>(
    stream: &[Request],
    policy: BatchPolicy,
    max_sessions: usize,
    reclaim: ReclaimPolicy,
    make: F,
) -> (Vec<Response>, Metrics)
where
    B: AttentionBackend + 'static,
    F: Fn(usize) -> B + Send + Sync + 'static,
{
    run_scheduled(stream, &[], policy, max_sessions, reclaim, WIDE_BUDGET, DEEP_QUEUE, make)
}

/// Submit the stream one ticket at a time (optionally sleeping the
/// per-request arrival delay first), replaying `Overloaded` sheds until
/// admission — a shed request was never enqueued, so the replay keeps
/// program order intact. Responses return in request-id order; the
/// server's shed counter must agree exactly with the refusals the
/// client observed.
#[allow(clippy::too_many_arguments)]
fn run_scheduled<B, F>(
    stream: &[Request],
    delays: &[Duration],
    policy: BatchPolicy,
    max_sessions: usize,
    reclaim: ReclaimPolicy,
    worker_kv_budget: usize,
    max_queue: usize,
    make: F,
) -> (Vec<Response>, Metrics)
where
    B: AttentionBackend + 'static,
    F: Fn(usize) -> B + Send + Sync + 'static,
{
    let cfg = ServerConfig {
        kv_capacity: CAPACITY,
        d_k: D,
        d_v: D,
        max_sessions,
        reclaim,
        batch: policy,
        worker_kv_budget,
        max_queue,
        ..Default::default()
    };
    let server = CamformerServer::start(cfg, make);
    let mut tickets = Vec::with_capacity(stream.len());
    let mut shed_replays = 0u64;
    for (i, req) in stream.iter().enumerate() {
        if let Some(d) = delays.get(i) {
            if !d.is_zero() {
                thread::sleep(*d);
            }
        }
        loop {
            match server.submit_ticket(req.clone()) {
                Ok(t) => {
                    tickets.push(t);
                    break;
                }
                Err(ServeError::Overloaded { .. }) => {
                    shed_replays += 1;
                    thread::yield_now();
                }
                Err(e) => panic!("submit failed terminally: {e}"),
            }
        }
    }
    let mut resps: Vec<Response> = tickets.into_iter().map(Ticket::wait).collect();
    resps.sort_by_key(|r| r.id);
    let (m, _) = server.shutdown();
    assert_eq!(m.completed + m.errors, stream.len() as u64);
    assert_eq!(
        m.shed_requests, shed_replays,
        "every shed must surface as exactly one Overloaded refusal"
    );
    (resps, m)
}

fn assert_equivalent(case: u64, label: &str, sequential: &[Response], other: &[Response]) {
    assert_eq!(sequential.len(), other.len(), "case {case} {label}");
    for (s, o) in sequential.iter().zip(other) {
        assert_eq!(s.id, o.id, "case {case} {label}");
        match (&s.result, &o.result) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a.output, b.output, "case {case} {label} id {}", s.id);
                assert_eq!(a.seq_len, b.seq_len, "case {case} {label} id {}", s.id);
            }
            (Err(a), Err(b)) => assert_eq!(a, b, "case {case} {label} id {}", s.id),
            (a, b) => panic!("case {case} {label} id {}: {a:?} vs {b:?}", s.id),
        }
    }
}

/// Backend without native prefix views: keeps every trait default, so
/// fused bursts exercise the serving layer's literal-pad materialisation.
struct NoPrefixViews(FunctionalBackend);

impl AttentionBackend for NoPrefixViews {
    fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.0.attend(q, k, v)
    }

    fn name(&self) -> &'static str {
        "no-prefix-views"
    }
}

/// The functional backend in any of its three pipeline modes: the fused
/// FlashCAM kernel (ISSUE 7, the serving default), the survivor-list
/// sparse pipeline (ISSUE 4), and the dense mask baseline.
fn pipeline_backend(pipeline: Pipeline) -> FunctionalBackend {
    match pipeline {
        Pipeline::Fused => FunctionalBackend::new(CAPACITY, D),
        Pipeline::Sparse => FunctionalBackend::new_sparse(CAPACITY, D),
        Pipeline::Dense => FunctionalBackend::new_dense(CAPACITY, D),
    }
}

fn pipeline_tag(pipeline: Pipeline) -> &'static str {
    match pipeline {
        Pipeline::Fused => "/fused-kernel",
        Pipeline::Sparse => "/sparse",
        Pipeline::Dense => "",
    }
}

#[test]
fn batched_dispatch_bit_equals_sequential_on_random_streams() {
    let mut rng = Rng::new(0xF05EED);
    for case in 0..200u64 {
        let mut crng = rng.split();
        let ops = 8 + crng.index(25);
        let stream = gen_stream(&mut crng, ops);

        // ground truth: one request per dispatch, in submission order,
        // through the dense baseline pipeline
        let (sequential, m_seq) = run_stream(
            &stream,
            BatchPolicy::conservative(1, Duration::from_micros(50)),
            8,
            ReclaimPolicy::Deny,
            |_| pipeline_backend(Pipeline::Dense),
        );
        for pipeline in [Pipeline::Dense, Pipeline::Sparse, Pipeline::Fused] {
            let tag = pipeline_tag(pipeline);
            // sequential dispatch through this pipeline (the dense one IS
            // the ground truth above); its work counters anchor the
            // dispatch-config parity asserts below
            let m_seq_pipe = if pipeline == Pipeline::Dense {
                m_seq.work
            } else {
                let (seq_pipe, m) = run_stream(
                    &stream,
                    BatchPolicy::conservative(1, Duration::from_micros(50)),
                    8,
                    ReclaimPolicy::Deny,
                    |_| pipeline_backend(pipeline),
                );
                assert_equivalent(case, &format!("sequential{tag}"), &sequential, &seq_pipe);
                m.work
            };
            // conservative cross-session batching (the PR 2 invariant)
            let (conservative, m_cons) = run_stream(
                &stream,
                BatchPolicy::conservative(16, Duration::from_millis(1)),
                8,
                ReclaimPolicy::Deny,
                |_| pipeline_backend(pipeline),
            );
            assert_equivalent(case, &format!("conservative{tag}"), &sequential, &conservative);
            // speculative multi-step fusion, prefix-native backend
            let (fused, m_fused) = run_stream(
                &stream,
                BatchPolicy::bounds(16, Duration::from_millis(1)),
                8,
                ReclaimPolicy::Deny,
                |_| pipeline_backend(pipeline),
            );
            assert_equivalent(case, &format!("fused{tag}"), &sequential, &fused);
            // speculative fusion again, over a backend that cannot mask
            // prefixes natively (the scratch-materialisation path)
            let (scratch, _) = run_stream(
                &stream,
                BatchPolicy::bounds(16, Duration::from_millis(1)),
                8,
                ReclaimPolicy::Deny,
                |_| NoPrefixViews(pipeline_backend(pipeline)),
            );
            assert_equivalent(case, &format!("fused/scratch{tag}"), &sequential, &scratch);

            // work parity (ISSUE 7): each query's padded geometry derives
            // from its own causal prefix, so prefix-native dispatch
            // configs must perform IDENTICAL work — words scored, tiles
            // streamed, survivor corrections, V rows touched — no matter
            // how the scheduler grouped the stream. (The scratch config
            // scores materialised pad tails, so it is excluded.)
            assert_eq!(m_cons.work, m_seq_pipe, "case {case}{tag}: conservative work parity");
            assert_eq!(m_fused.work, m_seq_pipe, "case {case}{tag}: fused work parity");

            // amortisation accounting: the same queries were served,
            // through no more dispatches than one-at-a-time execution
            assert_eq!(m_fused.dispatched_queries, m_seq.dispatched_queries, "case {case}");
            assert!(m_fused.dispatches <= m_seq.dispatches, "case {case}");
        }
    }
}

/// ISSUE 5 acceptance: streams with `Close` and admission-overflowing
/// prefills, run at `max_sessions = 2` so `open`s evict under
/// `LruEvictIdle` — every dispatch config must stay bit-equal to
/// sequential dispatch (including every `Evicted` response, which pins
/// the LRU victim choice itself), with identical eviction/close
/// counters. Under `Deny` the same streams hit terminal `SessionLimit`
/// refusals; under the eviction policy none may remain.
#[test]
fn eviction_streams_stay_bit_equal_and_lru_unblocks_admission() {
    let lru = ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO };
    let seq_policy = BatchPolicy::conservative(1, Duration::from_micros(50));
    let mut rng = Rng::new(0xE71C7);
    let mut deny_refusals = 0u64;
    for case in 0..120u64 {
        let mut crng = rng.split();
        let ops = 10 + crng.index(30);
        let stream = gen_stream(&mut crng, ops);

        // Deny baseline: count the terminal session-limit admissions the
        // eviction policy is supposed to dissolve
        let (deny_seq, m_deny) = run_stream(&stream, seq_policy, 2, ReclaimPolicy::Deny, |_| {
            pipeline_backend(Pipeline::Dense)
        });
        deny_refusals += deny_seq
            .iter()
            .filter(|r| matches!(r.result, Err(ServeError::SessionLimit { .. })))
            .count() as u64;
        assert_eq!(m_deny.evictions, 0, "case {case}: Deny must never evict");

        // ground truth under eviction: sequential dense dispatch
        let (sequential, m_seq) =
            run_stream(&stream, seq_policy, 2, lru, |_| pipeline_backend(Pipeline::Dense));
        assert!(
            sequential
                .iter()
                .all(|r| !matches!(r.result, Err(ServeError::SessionLimit { .. }))),
            "case {case}: with an always-eligible LRU victim no admission may fail"
        );

        // every batched config: bit-equal responses AND identical
        // lifecycle counters (eviction runs only in prefill barriers, so
        // a victim with in-flight fused appends is structurally
        // impossible — any violation would diverge right here)
        let configs: [(&str, BatchPolicy); 3] = [
            ("conservative", BatchPolicy::conservative(16, Duration::from_millis(1))),
            ("fused", BatchPolicy::bounds(16, Duration::from_millis(1))),
            ("fused/scratch", BatchPolicy::bounds(16, Duration::from_millis(1))),
        ];
        for (label, policy) in configs {
            // batched configs serve through the fused FlashCAM kernel —
            // the pipeline the server actually runs in production
            let (resps, m) = if label == "fused/scratch" {
                run_stream(&stream, policy, 2, lru, |_| {
                    NoPrefixViews(pipeline_backend(Pipeline::Fused))
                })
            } else {
                run_stream(&stream, policy, 2, lru, |_| pipeline_backend(Pipeline::Fused))
            };
            assert_equivalent(case, label, &sequential, &resps);
            assert_eq!(m.evictions, m_seq.evictions, "case {case} {label}: eviction parity");
            assert_eq!(m.closes, m_seq.closes, "case {case} {label}: close parity");
            assert_eq!(
                m.kv_rows_released, m_seq.kv_rows_released,
                "case {case} {label}: release accounting parity"
            );
        }
    }
    assert!(
        deny_refusals > 0,
        "streams must actually overflow max_sessions, or this test pins nothing"
    );
}

/// ISSUE 6 acceptance: arrival-jittered streams against a tight shared
/// KV budget and a tiny queue bound. Randomized inter-arrival delays
/// mean the standing scheduler sees every plan shape — requests landing
/// mid-extension, plans flushed empty-queue on the deadline, prefills
/// arriving while a plan is open — and the tiny `max_queue` makes
/// `Overloaded` sheds real (each replayed to completion by
/// `run_scheduled`). For every reclaim policy and dispatch config the
/// responses must stay bit-equal to UNJITTERED sequential dense
/// dispatch, with counter parity on the budget gauges: admitted KV
/// rows, pool-residency high-water mark (which must also never exceed
/// the budget), evictions, closes, and released rows. That parity is
/// the proof that budget admission rides program order alone — wire
/// timing, plan shape, and shed/replay cycles never leak into it.
#[test]
fn arrival_jittered_streams_with_kv_budget_stay_bit_equal() {
    // 1.5x a single session's capacity: three sessions growing toward
    // CAPACITY=32 overflow the pool long before their own stores fill
    let budget = 48usize;
    let lru = ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO };
    let seq_policy = BatchPolicy::conservative(1, Duration::from_micros(50));
    let mut rng = Rng::new(0x717E12);
    let mut budget_refusals = 0u64;
    for case in 0..60u64 {
        let mut crng = rng.split();
        let ops = 10 + crng.index(25);
        let stream = gen_stream(&mut crng, ops);
        // ~30% of arrivals are delayed up to 400us; the rest land
        // back-to-back so deep plans still form
        let delays: Vec<Duration> = (0..stream.len())
            .map(|_| {
                if crng.index(10) < 7 {
                    Duration::ZERO
                } else {
                    Duration::from_micros(1 + crng.index(400) as u64)
                }
            })
            .collect();
        for reclaim in [ReclaimPolicy::Deny, lru] {
            // ground truth: unjittered sequential dense dispatch under
            // the SAME budget (so refusals/evictions are part of it)
            let (sequential, m_seq) = run_scheduled(
                &stream,
                &[],
                seq_policy,
                8,
                reclaim,
                budget,
                DEEP_QUEUE,
                |_| pipeline_backend(Pipeline::Dense),
            );
            budget_refusals += sequential
                .iter()
                .filter(|r| {
                    matches!(r.result, Err(ServeError::CapacityExhausted { capacity }) if capacity == budget)
                })
                .count() as u64;
            assert!(m_seq.kv_rows_hwm <= budget as u64, "case {case}: hwm over budget");

            let configs: [(&str, Pipeline, BatchPolicy); 5] = [
                ("sequential", Pipeline::Sparse, seq_policy),
                (
                    "conservative",
                    Pipeline::Sparse,
                    BatchPolicy::conservative(16, Duration::from_millis(1)),
                ),
                ("fused", Pipeline::Sparse, BatchPolicy::bounds(16, Duration::from_millis(1))),
                // the fused FlashCAM kernel under jitter + budget pressure
                // (ISSUE 7): the serving-default pipeline must survive the
                // standing scheduler's worst timing too
                (
                    "fused/kernel",
                    Pipeline::Fused,
                    BatchPolicy::bounds(16, Duration::from_millis(1)),
                ),
                (
                    "fused/scratch",
                    Pipeline::Fused,
                    BatchPolicy::bounds(16, Duration::from_millis(1)),
                ),
            ];
            for (label, pipeline, policy) in configs {
                let (resps, m) = if label == "fused/scratch" {
                    run_scheduled(&stream, &delays, policy, 8, reclaim, budget, 2, |_| {
                        NoPrefixViews(pipeline_backend(pipeline))
                    })
                } else {
                    run_scheduled(&stream, &delays, policy, 8, reclaim, budget, 2, |_| {
                        pipeline_backend(pipeline)
                    })
                };
                let tag = format!("jitter/{label}");
                assert_equivalent(case, &tag, &sequential, &resps);
                assert_eq!(
                    m.kv_rows_admitted, m_seq.kv_rows_admitted,
                    "case {case} {tag}: admitted-rows parity"
                );
                assert_eq!(
                    m.kv_rows_hwm, m_seq.kv_rows_hwm,
                    "case {case} {tag}: residency high-water-mark parity"
                );
                assert!(m.kv_rows_hwm <= budget as u64, "case {case} {tag}: hwm over budget");
                assert_eq!(m.evictions, m_seq.evictions, "case {case} {tag}: eviction parity");
                assert_eq!(m.closes, m_seq.closes, "case {case} {tag}: close parity");
                assert_eq!(
                    m.kv_rows_released, m_seq.kv_rows_released,
                    "case {case} {tag}: release accounting parity"
                );
            }
        }
    }
    assert!(
        budget_refusals > 0,
        "streams must actually hit the shared KV budget, or this family pins nothing"
    );
}

/// ISSUE 8 acceptance: the DRAM spill tier dissolves eviction. The same
/// admission-overflowing streams as the `LruEvictIdle` family run at
/// `max_sessions = 2` under `ReclaimPolicy::LruSpillToDram`: the shard
/// directory demotes the LRU victim's KV (keys, values, packed key
/// bits) into the simulated host tier and promotes it back on the
/// victim's next request. Unlike eviction, the pressure must be
/// INVISIBLE in the responses: every run is compared against an
/// UNLIMITED ground truth (`max_sessions = 8`, `Deny`, sequential dense
/// dispatch — no pressure at all), so zero `Evicted` responses, zero
/// evictions, and byte-identical outputs through the fused kernel after
/// however many demote/promote round-trips the stream forced — which is
/// exactly the packed-bit/value integrity proof, fuzzed. Demote and
/// promote decisions ride the merged shard clock (program order), so
/// their counters must agree across dispatch configs the same way
/// eviction counters do in the family above.
#[test]
fn spill_tier_streams_never_evict_and_stay_bit_equal() {
    let spill = ReclaimPolicy::LruSpillToDram { min_idle: Duration::ZERO };
    let seq_policy = BatchPolicy::conservative(1, Duration::from_micros(50));
    let mut rng = Rng::new(0x5B111);
    let mut demotions_total = 0u64;
    let mut promotions_total = 0u64;
    for case in 0..80u64 {
        let mut crng = rng.split();
        let ops = 10 + crng.index(30);
        let stream = gen_stream(&mut crng, ops);

        // unlimited ground truth: no slot pressure, nothing ever leaves
        // the accelerator tier
        let (unlimited, _) = run_stream(&stream, seq_policy, 8, ReclaimPolicy::Deny, |_| {
            pipeline_backend(Pipeline::Dense)
        });

        // spill ground truth: sequential dispatch under slot pressure,
        // through the serving-default fused kernel — anchors the
        // demote/promote counter parity across the batched configs
        let (sequential, m_seq) =
            run_stream(&stream, seq_policy, 2, spill, |_| pipeline_backend(Pipeline::Fused));
        assert_equivalent(case, "spill/sequential", &unlimited, &sequential);
        demotions_total += m_seq.demotions;
        promotions_total += m_seq.promotions;

        let configs: [(&str, BatchPolicy); 3] = [
            ("spill/conservative", BatchPolicy::conservative(16, Duration::from_millis(1))),
            ("spill/fused", BatchPolicy::bounds(16, Duration::from_millis(1))),
            ("spill/fused-scratch", BatchPolicy::bounds(16, Duration::from_millis(1))),
        ];
        for (label, policy) in [("spill/sequential", seq_policy)].into_iter().chain(configs) {
            let (resps, m) = if label == "spill/sequential" {
                (sequential.clone(), m_seq.clone())
            } else if label == "spill/fused-scratch" {
                run_stream(&stream, policy, 2, spill, |_| {
                    NoPrefixViews(pipeline_backend(Pipeline::Fused))
                })
            } else {
                run_stream(&stream, policy, 2, spill, |_| pipeline_backend(Pipeline::Fused))
            };
            assert_equivalent(case, label, &unlimited, &resps);
            assert!(
                resps.iter().all(|r| !matches!(r.result, Err(ServeError::Evicted { .. }))),
                "case {case} {label}: the spill tier must never answer Evicted"
            );
            assert_eq!(m.evictions, 0, "case {case} {label}: spill demotes, never drops");
            assert_eq!(m.demotions, m_seq.demotions, "case {case} {label}: demotion parity");
            assert_eq!(m.promotions, m_seq.promotions, "case {case} {label}: promotion parity");
            assert_eq!(
                m.spilled_rows, m_seq.spilled_rows,
                "case {case} {label}: parked-rows parity at shutdown"
            );
            assert_eq!(m.closes, m_seq.closes, "case {case} {label}: close parity");
            assert_eq!(
                m.kv_rows_released, m_seq.kv_rows_released,
                "case {case} {label}: release accounting parity"
            );
        }
    }
    assert!(
        demotions_total > 0 && promotions_total > 0,
        "streams must actually demote AND promote, or this family pins nothing"
    );
}

#[test]
fn planner_invariants_hold_on_random_wire_batches() {
    let mut rng = Rng::new(0xBA7C4);
    for case in 0..200u64 {
        let mut crng = rng.split();
        let n = 1 + crng.index(16);
        let stream = gen_stream(&mut crng, n);
        for mode in [PlanMode::Conservative, PlanMode::Speculative] {
            let items: Vec<Envelope> = stream.iter().cloned().map(Envelope::detached).collect();
            let groups = DecodeBatcher::plan_mode(mode, items);
            // order preservation: flattening the plan restores the batch
            let flat: Vec<u64> = groups
                .iter()
                .flat_map(|g| match g {
                    DispatchGroup::Barrier(e) => vec![e.req.id()],
                    DispatchGroup::Batch(b) => b.iter().map(|e| e.req.id()).collect(),
                })
                .collect();
            let want: Vec<u64> = stream.iter().map(|r| r.id()).collect();
            assert_eq!(flat, want, "case {case} {mode:?}");
            for g in &groups {
                match g {
                    // every prefill is a barrier, and only prefills are
                    DispatchGroup::Barrier(e) => {
                        assert!(
                            matches!(e.req, Request::Prefill { .. }),
                            "case {case} {mode:?}"
                        );
                    }
                    DispatchGroup::Batch(b) => {
                        // occupancy bounds: non-empty, within the wire batch
                        assert!(!b.is_empty() && b.len() <= stream.len(), "case {case}");
                        assert!(
                            b.iter().all(|e| !matches!(e.req, Request::Prefill { .. })),
                            "case {case} {mode:?}: prefill inside a batch group"
                        );
                        // Close is a same-session barrier in BOTH modes:
                        // no item of a session may follow its Close
                        // within one group (it must observe the close)
                        let mut closed: Vec<u64> = Vec::new();
                        for e in b {
                            assert!(
                                !closed.contains(&e.req.session()),
                                "case {case} {mode:?}: item after same-session Close"
                            );
                            if matches!(e.req, Request::Close { .. }) {
                                closed.push(e.req.session());
                            }
                        }
                        if mode == PlanMode::Conservative {
                            // at most one decode per session, and a decode
                            // must be its session's first item in the group
                            let mut seen: Vec<u64> = Vec::new();
                            for e in b {
                                if matches!(e.req, Request::Decode { .. }) {
                                    assert!(
                                        !seen.contains(&e.req.session()),
                                        "case {case}: decode after same-session item"
                                    );
                                }
                                if !seen.contains(&e.req.session()) {
                                    seen.push(e.req.session());
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Boundary property for prefix views: a fused burst of length
/// {1, 2, cam-1, cam, cam+1} decode steps sees exactly its own causal
/// prefix at each step. Fusion is constructed by hand at the backend
/// level (all appends applied, then ONE `attend_batch` over prefix
/// views) so wire-batch timing cannot weaken the test, and each step is
/// compared against the functional reference computed sequentially.
#[test]
fn fused_burst_sees_exact_causal_prefix_at_boundary_lengths() {
    let cam = 16usize; // stage-1 tile height == pad quantum
    let d = 64usize;
    let capacity = 64usize;
    let prefill_rows = 8usize;
    for burst in [1usize, 2, cam - 1, cam, cam + 1] {
        let mut rng = Rng::new(0xB0_0000 + burst as u64);
        let pk = rng.normal_vec(prefill_rows * d);
        let pv = rng.normal_vec(prefill_rows * d);
        let steps: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..burst)
            .map(|_| (rng.normal_vec(d), rng.normal_vec(d), rng.normal_vec(d)))
            .collect();

        // sequential reference: step i computed BEFORE step i+1 appends
        let mut mirror = KvStore::new(capacity, d, d);
        mirror.load(&pk, &pv).unwrap();
        let mut reference = Vec::with_capacity(burst);
        for (q, nk, nv) in &steps {
            mirror.append(nk, nv).unwrap();
            let rows = mirror.len().div_ceil(cam) * cam;
            let (kp, vp, _) = mirror.padded(rows);
            reference.push(functional::camformer_attention(q, kp, vp, &AttnConfig::paper(rows, d)));
        }

        // fused execution: ALL appends applied up front, then one
        // batched attend where step i is bounded to its causal prefix
        let mut store = KvStore::new(capacity, d, d);
        store.load(&pk, &pv).unwrap();
        for (_, nk, nv) in &steps {
            store.append(nk, nv).unwrap();
        }
        let items: Vec<AttendItem<'_>> = steps
            .iter()
            .enumerate()
            .map(|(i, (q, _, _))| {
                let prefix = prefill_rows + i + 1;
                let rows = prefix.div_ceil(cam) * cam;
                let (keys, values, _) = store.padded_prefix_view(prefix, rows);
                // store-owned packed bits ride along, as the worker's
                // dispatch builder attaches them
                let packed = Some(store.packed_view(rows));
                AttendItem { query: q, keys, values, prefix_rows: prefix, packed }
            })
            .collect();
        let mut fused_be = FunctionalBackend::new(capacity, d);
        let mut sparse_be = FunctionalBackend::new_sparse(capacity, d);
        let mut dense_be = FunctionalBackend::new_dense(capacity, d);
        for backend in [&mut fused_be, &mut sparse_be, &mut dense_be] {
            let outs = backend.attend_batch(&items).unwrap();
            for (i, (out, want)) in outs.iter().zip(&reference).enumerate() {
                assert_eq!(
                    out, want,
                    "burst {burst} step {i} ({:?}): prefix view diverged",
                    backend.pipeline
                );
            }
            assert_eq!(
                backend.work.fallback_rows_packed,
                0,
                "items carried store-owned bits; the backend must not re-pack"
            );
        }

        // the fused kernel's work is analytic at these geometries: step i
        // scores exactly its prefix_i live rows (one u64 word each at
        // d=64) and streams ceil(prefix_i / cam) key tiles — pad rows and
        // the full-length score vector cost nothing
        let want_words: u64 = (0..burst).map(|i| (prefill_rows + i + 1) as u64).sum();
        let want_tiles: u64 =
            (0..burst).map(|i| (prefill_rows + i + 1).div_ceil(cam) as u64).sum();
        assert_eq!(fused_be.work.words_scored, want_words, "burst {burst}: words scored");
        assert_eq!(fused_be.work.tiles_streamed, want_tiles, "burst {burst}: tiles streamed");
    }
}

/// Dedicated chaos runner (ISSUE 9): submits every request exactly once
/// against a [`ChaosBackend`] executing `plan`, then resolves every
/// ticket under one shared deadline — a ticket that misses it is a hang,
/// the bug this family exists to catch. The legacy runners'
/// `completed + errors == stream.len()` reconciliation does not hold
/// here (tickets killed by a crash resolve client-side as `WorkerGone`,
/// counted in neither), so the chaos test reconciles the server's fault
/// counters against the injection ledger instead.
fn run_chaos(
    stream: &[Request],
    policy: BatchPolicy,
    max_sessions: usize,
    reclaim: ReclaimPolicy,
    plan: &FaultPlan,
) -> (Vec<Response>, Metrics, Arc<ChaosStats>) {
    let cfg = ServerConfig {
        kv_capacity: CAPACITY,
        d_k: D,
        d_v: D,
        max_sessions,
        reclaim,
        batch: policy,
        worker_kv_budget: WIDE_BUDGET,
        max_queue: DEEP_QUEUE,
        ..Default::default()
    };
    let stats = Arc::new(ChaosStats::default());
    let server = {
        let stats = stats.clone();
        let plan = plan.clone();
        CamformerServer::start(cfg, move |_| {
            let inner = FunctionalBackend::new(CAPACITY, D);
            ChaosBackend::with_stats(inner, plan.clone(), stats.clone())
        })
    };
    let mut tickets = Vec::with_capacity(stream.len());
    for req in stream {
        loop {
            match server.submit_ticket(req.clone()) {
                Ok(t) => {
                    tickets.push(t);
                    break;
                }
                // DEEP_QUEUE makes sheds unlikely, but injected stalls can
                // back the queue up — replay; nothing was enqueued
                Err(ServeError::Overloaded { .. }) => thread::yield_now(),
                Err(e) => panic!("chaos submit failed terminally: {e}"),
            }
        }
    }
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut resps: Vec<Response> = tickets
        .into_iter()
        .map(|t| {
            let id = t.id();
            t.wait_deadline(deadline)
                .unwrap_or_else(|_| panic!("ticket {id} hung past the chaos deadline"))
        })
        .collect();
    resps.sort_by_key(|r| r.id);
    let (m, _) = server.shutdown();
    (resps, m, stats)
}

/// Could a fault have produced this response? The first such response
/// taints its session for the rest of the taint walk.
fn fault_typed(r: &Response) -> bool {
    match &r.result {
        Err(ServeError::Backend(msg)) => msg.contains("chaos") || msg.contains("panicked"),
        Err(ServeError::SessionLost { .. }) | Err(ServeError::WorkerGone { .. }) => true,
        _ => false,
    }
}

/// ISSUE 9 chaos family. Seeded random fault plans (typed backend
/// errors, contained dispatch panics, worker crashes with supervised
/// restart, stalls) run against the random streams under two serving
/// shapes — fused dispatch with `Deny`, and conservative dispatch over a
/// two-slot DRAM spill tier (so crashes hit a mix of resident and
/// spilled sessions, and spilled ones recover). Three invariants per
/// run:
///
/// 1. **No hang, no silent drop** — every submitted ticket resolves
///    typed within the shared deadline (asserted inside [`run_chaos`]).
/// 2. **Fault-free sessions stay bit-equal to a fault-free run.**
///    Walking responses in id order, a session becomes *tainted* at its
///    first fault-typed response — injected backend error, contained
///    panic, `SessionLost`, `WorkerGone`; group faults taint innocent
///    batch-mates too, since a dispatch failure has no per-item
///    attribution. Every response of an untainted session must equal
///    the clean sequential-dense run exactly (outputs, seq_lens, typed
///    refusals). Stalls never taint — a stalled dispatch serves
///    normally. Tainted sessions are unconstrained: rollbacks
///    legitimately shift their seq_lens.
/// 3. **Counters reconcile with the injection ledger** —
///    `backend_faults == errors`, `worker_panics == panics + crashes`,
///    `worker_restarts == crashes`; `WorkerGone` is observed iff a
///    crash fired (every crash kills its in-flight dispatch); distinct
///    `SessionLost` ids never exceed `sessions_lost`; a crash always
///    loses at least one resident session (the one it was dispatching);
///    and without crashes nothing is recovered.
#[test]
fn chaos_fault_plans_resolve_every_ticket_and_reconcile_counters() {
    let spill = ReclaimPolicy::LruSpillToDram { min_idle: Duration::ZERO };
    let mut rng = Rng::new(0xC4405);
    let (mut total_errors, mut total_panics, mut total_crashes) = (0u64, 0u64, 0u64);
    for case in 0..30u64 {
        let mut crng = rng.split();
        let ops = 10 + crng.index(25);
        let stream = gen_stream(&mut crng, ops);

        // fault-free ground truth: sequential dense dispatch, no pressure
        let (clean, _) = run_stream(
            &stream,
            BatchPolicy::conservative(1, Duration::from_micros(50)),
            8,
            ReclaimPolicy::Deny,
            |_| pipeline_backend(Pipeline::Dense),
        );

        let configs = [
            (
                "chaos/deny-fused",
                8,
                ReclaimPolicy::Deny,
                BatchPolicy::bounds(16, Duration::from_millis(1)),
            ),
            (
                "chaos/spill-conservative",
                2,
                spill,
                BatchPolicy::conservative(16, Duration::from_millis(1)),
            ),
        ];
        for (ci, (label, max_sessions, reclaim, policy)) in configs.into_iter().enumerate() {
            let plan = FaultPlan::random(0x9A0_0000 + case * 8 + ci as u64, 24, 0.28);
            let (resps, m, stats) = run_chaos(&stream, policy, max_sessions, reclaim, &plan);
            assert_eq!(resps.len(), clean.len(), "case {case} {label}: response count");

            let mut tainted: HashSet<u64> = HashSet::new();
            let mut lost_ids: HashSet<u64> = HashSet::new();
            let mut saw_worker_gone = false;
            for (r, c) in resps.iter().zip(&clean) {
                assert_eq!(r.id, c.id, "case {case} {label}");
                if let Err(ServeError::SessionLost { session }) = &r.result {
                    lost_ids.insert(*session);
                }
                if matches!(r.result, Err(ServeError::WorkerGone { .. })) {
                    saw_worker_gone = true;
                }
                if fault_typed(r) {
                    tainted.insert(r.session);
                    continue;
                }
                if tainted.contains(&r.session) {
                    continue;
                }
                match (&r.result, &c.result) {
                    (Ok(a), Ok(b)) => {
                        assert_eq!(a.output, b.output, "case {case} {label} id {}", r.id);
                        assert_eq!(a.seq_len, b.seq_len, "case {case} {label} id {}", r.id);
                    }
                    (Err(a), Err(b)) => assert_eq!(a, b, "case {case} {label} id {}", r.id),
                    (a, b) => {
                        panic!("case {case} {label} id {}: {a:?} vs clean {b:?}", r.id)
                    }
                }
            }

            let errors = stats.errors.load(AtomicOrdering::Relaxed);
            let panics = stats.panics.load(AtomicOrdering::Relaxed);
            let crashes = stats.crashes.load(AtomicOrdering::Relaxed);
            assert_eq!(m.backend_faults, errors, "case {case} {label}: backend_faults");
            assert_eq!(
                m.worker_panics,
                panics + crashes,
                "case {case} {label}: worker_panics must count contained panics AND crashes"
            );
            assert_eq!(m.worker_restarts, crashes, "case {case} {label}: worker_restarts");
            assert_eq!(
                saw_worker_gone,
                crashes > 0,
                "case {case} {label}: every crash kills its in-flight dispatch, and nothing else \
                 produces WorkerGone"
            );
            assert!(
                lost_ids.len() as u64 <= m.sessions_lost,
                "case {case} {label}: {} distinct SessionLost ids vs sessions_lost {}",
                lost_ids.len(),
                m.sessions_lost
            );
            if crashes > 0 {
                assert!(
                    m.sessions_lost >= 1,
                    "case {case} {label}: a crash always loses the session it was dispatching"
                );
            } else {
                assert_eq!(
                    m.sessions_recovered, 0,
                    "case {case} {label}: nothing to recover without a crash"
                );
            }
            total_errors += errors;
            total_panics += panics;
            total_crashes += crashes;
        }
    }
    assert!(
        total_errors > 0 && total_panics > 0 && total_crashes > 0,
        "the suite must exercise every fault kind at least once \
         (errors {total_errors}, panics {total_panics}, crashes {total_crashes})"
    );
}
