//! Cross-module integration: circuit -> BIMV -> architecture -> accuracy
//! -> cost must tell one consistent story.

use camformer::accuracy::functional::{self, AttnConfig};
use camformer::arch::config::ArchConfig;
use camformer::arch::pipeline::{self, PipelineModel};
use camformer::bimv::engine::BimvEngine;
use camformer::cost::breakdown;
use camformer::cost::system::{CamformerCost, SystemConfig};
use camformer::dram::channel::DramConfig;
use camformer::dram::prefetch::PrefetchEngine;
use camformer::util::rng::Rng;

#[test]
fn arch_sim_matches_functional_across_sizes() {
    for n in [128usize, 256, 512] {
        let cfg = ArchConfig { n, ..Default::default() };
        let mut rng = Rng::new(n as u64);
        let q = rng.normal_vec(64);
        let k = rng.normal_vec(n * 64);
        let v = rng.normal_vec(n * 64);
        let (out, _) = pipeline::simulate_query(cfg, &q, &k, &v);
        let want = functional::camformer_attention(&q, &k, &v, &AttnConfig::paper(n, 64));
        for (i, (g, w)) in out.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 0.05, "n={n} dim={i}: {g} vs {w}");
        }
    }
}

#[test]
fn bimv_engine_feeds_functional_identically() {
    let mut rng = Rng::new(1000);
    let qf = rng.normal_vec(64);
    let kf = rng.normal_vec(256 * 64);
    let q_bits: Vec<bool> = qf.iter().map(|&x| x >= 0.0).collect();
    let k_bits: Vec<Vec<bool>> = (0..256)
        .map(|r| kf[r * 64..(r + 1) * 64].iter().map(|&x| x >= 0.0).collect())
        .collect();
    let mut eng = BimvEngine::new(16, 64);
    let circuit_scores = eng.scores(&q_bits, &k_bits);
    let functional_scores = functional::bacam_scores(&qf, &kf, 64);
    for (c, f) in circuit_scores.iter().zip(&functional_scores) {
        assert!((c - f).abs() <= 2.0, "circuit {c} vs functional {f}");
    }
}

#[test]
fn cost_and_pipeline_models_agree_on_throughput() {
    // two independently-written models of the same architecture must agree
    let cost = CamformerCost::evaluate(&SystemConfig::default());
    let pipe = PipelineModel::paper().throughput_qry_per_ms();
    let ratio = cost.throughput_qry_per_ms / pipe;
    assert!(
        (0.8..1.25).contains(&ratio),
        "cost {} vs pipeline {} qry/ms",
        cost.throughput_qry_per_ms,
        pipe
    );
}

#[test]
fn energy_breakdown_sums_to_system_energy() {
    let cfg = SystemConfig::default();
    let total: f64 = breakdown::energy_breakdown(&cfg).iter().map(|c| c.value).sum();
    let sys = CamformerCost::evaluate(&cfg).energy_per_query_j;
    assert!(
        (total - sys).abs() / sys < 0.02,
        "breakdown {total} vs system {sys}"
    );
}

#[test]
fn prefetch_sustains_table2_rate() {
    // the modelled throughput must be feasible for one HBM3 channel
    let cost = CamformerCost::evaluate(&SystemConfig::default());
    let queries_per_s = cost.throughput_qry_per_ms * 1e3;
    let engine = PrefetchEngine::new(DramConfig::default(), 64);
    let need = engine.required_gbps(32, queries_per_s);
    assert!(
        need < DramConfig::default().peak_gbps,
        "{need} GB/s exceeds one channel"
    );
}

#[test]
fn prefetch_hidden_behind_association_latency() {
    // association takes ~6.1 us; the 32-row V fetch must complete well
    // inside it (Sec. III-C4's latency-hiding claim)
    let assoc_ns = PipelineModel::paper().latencies().association as f64; // 1 GHz
    let mut engine = PrefetchEngine::new(DramConfig::default(), 64);
    let mut rng = Rng::new(1001);
    let indices: Vec<usize> = (0..32).map(|_| rng.index(1024)).collect();
    let stats = engine.prefetch(0.0, &indices, assoc_ns);
    assert_eq!(stats.exposed_ns, 0.0, "exposed {} ns", stats.exposed_ns);
}

#[test]
fn adc_bits_accuracy_vs_speed_tradeoff() {
    // 6-bit is exact at d_k=64; 4-bit quantises scores (accuracy cost) but
    // shortens the SAR serialization (speed win) — both directions checked
    let mut rng = Rng::new(1002);
    let q = rng.normal_vec(64);
    let k = rng.normal_vec(256 * 64);
    let s6 = functional::bacam_scores_cfg(&q, &k, 64, 6);
    let s4 = functional::bacam_scores_cfg(&q, &k, 64, 4);
    let exact: Vec<f64> = functional::bacam_scores_cfg(&q, &k, 64, 16);
    let err6: f64 = s6.iter().zip(&exact).map(|(a, b)| (a - b).abs()).sum();
    let err4: f64 = s4.iter().zip(&exact).map(|(a, b)| (a - b).abs()).sum();
    assert_eq!(err6, 0.0);
    assert!(err4 > 0.0);

    let t6 = PipelineModel {
        cfg: ArchConfig { adc_bits: 6, ..Default::default() },
        fine_grained: true,
    }
    .throughput_qry_per_ms();
    let t4 = PipelineModel {
        cfg: ArchConfig { adc_bits: 4, ..Default::default() },
        fine_grained: true,
    }
    .throughput_qry_per_ms();
    assert!(t4 > t6);
}

#[test]
fn headline_claims_hold_in_models() {
    // the abstract's three claims, checked against the live models
    let cam = CamformerCost::evaluate(&SystemConfig::default());
    // >10x energy efficiency vs best published baseline (SpAtten 904)
    assert!(cam.energy_eff_qry_per_mj / 904.0 > 8.0);
    // higher throughput than the best single-core baseline (85.2)
    assert!(cam.throughput_qry_per_ms / 85.2 > 1.5);
    // 6-8x lower area than A3 (2.08 mm^2)
    let area_ratio = 2.08 / cam.area_mm2;
    assert!(area_ratio > 5.0, "area ratio {area_ratio}");
}
