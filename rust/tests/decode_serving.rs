//! Decode-serving acceptance (ISSUE 1): ≥2 concurrent sessions, prefill
//! then ≥32 live `Decode` steps each (every step appends to the session's
//! `KvStore`), outputs bit-equal to the functional reference applied to
//! the accumulated K/V, and `Metrics` reporting non-zero p50/p99.

use std::time::Duration;

use camformer::accuracy::functional::{self, AttnConfig};
use camformer::coordinator::backend::FunctionalBackend;
use camformer::coordinator::batcher::BatchPolicy;
use camformer::coordinator::kv_store::KvStore;
use camformer::coordinator::server::{CamformerServer, Request, ServerConfig};
use camformer::coordinator::ServeError;
use camformer::util::rng::Rng;

#[test]
fn decode_loop_matches_functional_reference_across_sessions() {
    let d = 64usize;
    let capacity = 128usize;
    let prefill_rows = 24usize;
    let steps = 32usize;
    let session_ids: &[u64] = &[11, 42, 99];

    let cfg = ServerConfig {
        shards: 2,
        kv_capacity: capacity,
        batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
        ..Default::default()
    };
    // the reference mirrors must replay the server's execution geometry
    let quantum = cfg.pad_quantum;
    let server = CamformerServer::start(cfg, |_| FunctionalBackend::new(capacity, 64));

    // mirror stores accumulate the same K/V for the reference computation
    let mut mirror: Vec<KvStore> =
        session_ids.iter().map(|_| KvStore::new(capacity, d, d)).collect();
    let mut rng = Rng::new(7000);
    let mut next_id = 0u64;

    for (si, &sid) in session_ids.iter().enumerate() {
        let keys = rng.normal_vec(prefill_rows * d);
        let values = rng.normal_vec(prefill_rows * d);
        mirror[si].load(&keys, &values).unwrap();
        server
            .submit(Request::Prefill { id: next_id, session: sid, head: 0, keys, values })
            .unwrap();
        next_id += 1;
    }
    for ack in server.collect(session_ids.len()) {
        assert!(ack.is_ok(), "prefill failed: {:?}", ack.result);
        assert_eq!(ack.seq_len(), prefill_rows);
    }

    // interleaved decode streams: session A step t executes between
    // session B's steps, so cross-session contamination would be caught
    let mut expected: Vec<(u64, Vec<f32>, usize)> = Vec::new();
    for _step in 0..steps {
        for (si, &sid) in session_ids.iter().enumerate() {
            let q = rng.normal_vec(d);
            let nk = rng.normal_vec(d);
            let nv = rng.normal_vec(d);
            mirror[si].append(&nk, &nv).unwrap();
            // the reference runs over the same padded execution geometry
            let rows = mirror[si].len().div_ceil(quantum) * quantum;
            let (kp, vp, _) = mirror[si].padded(rows);
            let want = functional::camformer_attention(&q, kp, vp, &AttnConfig::paper(rows, d));
            expected.push((next_id, want, mirror[si].len()));
            server
                .submit(Request::Decode {
                    id: next_id,
                    session: sid,
                    head: 0,
                    query: q,
                    new_key: nk,
                    new_value: nv,
                })
                .unwrap();
            next_id += 1;
        }
    }

    let total = steps * session_ids.len();
    let mut resps = server.collect(total);
    resps.sort_by_key(|r| r.id);
    assert_eq!(resps.len(), total);
    for (r, (id, want, seq_len)) in resps.iter().zip(&expected) {
        assert_eq!(r.id, *id);
        assert_eq!(
            r.output(),
            &want[..],
            "decode response {id} diverged from the functional reference"
        );
        assert_eq!(r.seq_len(), *seq_len, "response {id}: wrong live KV length");
    }

    let (m, _window) = server.shutdown();
    assert_eq!(m.prefills, session_ids.len() as u64);
    assert_eq!(m.decodes, total as u64);
    assert_eq!(m.errors, 0);
    assert!(m.p50_us() > 0.0, "p50 latency must be non-zero");
    assert!(m.p99_us() > 0.0, "p99 latency must be non-zero");
    assert!(m.p99() >= m.p50());
}

#[test]
fn decode_past_capacity_yields_typed_error() {
    let cfg = ServerConfig { kv_capacity: 16, ..Default::default() };
    let server = CamformerServer::start(cfg, |_| FunctionalBackend::new(16, 64));
    let mut rng = Rng::new(7100);
    server
        .submit(Request::Prefill {
            id: 0,
            session: 5,
            head: 0,
            keys: rng.normal_vec(16 * 64),
            values: rng.normal_vec(16 * 64),
        })
        .unwrap();
    server
        .submit(Request::Decode {
            id: 1,
            session: 5,
            head: 0,
            query: rng.normal_vec(64),
            new_key: rng.normal_vec(64),
            new_value: rng.normal_vec(64),
        })
        .unwrap();
    // the refused decode must not have committed its append: the session
    // still serves, at the original context length
    server
        .submit(Request::Attend { id: 2, session: 5, head: 0, query: rng.normal_vec(64) })
        .unwrap();
    let mut resps = server.collect(3);
    resps.sort_by_key(|r| r.id);
    assert!(resps[0].is_ok());
    assert_eq!(resps[1].result, Err(ServeError::CapacityExhausted { capacity: 16 }));
    assert!(resps[2].is_ok());
    assert_eq!(resps[2].seq_len(), 16);
    let (m, _) = server.shutdown();
    assert_eq!(m.errors, 1);
}

#[test]
fn decode_against_unknown_session_is_typed() {
    let server = CamformerServer::start(
        ServerConfig { kv_capacity: 64, ..Default::default() },
        |_| FunctionalBackend::new(64, 64),
    );
    let mut rng = Rng::new(7200);
    server
        .submit(Request::Decode {
            id: 9,
            session: 1234,
            head: 0,
            query: rng.normal_vec(64),
            new_key: rng.normal_vec(64),
            new_value: rng.normal_vec(64),
        })
        .unwrap();
    let r = server.collect(1).remove(0);
    assert_eq!(r.result, Err(ServeError::UnknownSession { session: 1234 }));
    server.shutdown();
}
