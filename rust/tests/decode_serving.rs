//! Decode-serving acceptance: concurrent sessions, prefill then live
//! `Decode` steps (every step appends to the session's `KvStore`),
//! outputs bit-equal to the functional reference applied to the
//! accumulated K/V, `Metrics` reporting non-zero p50/p99 — and the
//! cross-session batched path (ISSUE 2): interleaved sessions on one
//! head coalescing into shared backend dispatches, bit-equal to
//! single-dispatch execution, with admission failures isolated to the
//! refused request.

use std::time::Duration;

use camformer::accuracy::functional::{self, AttnConfig};
use camformer::coordinator::backend::FunctionalBackend;
use camformer::coordinator::batcher::BatchPolicy;
use camformer::coordinator::kv_store::KvStore;
use camformer::coordinator::server::{CamformerServer, Request, Response, ServerConfig};
use camformer::coordinator::{ServeError, Ticket};
use camformer::util::rng::Rng;

/// Resolve every ticket and return the responses in request-id order.
fn wait_all(tickets: Vec<Ticket>) -> Vec<Response> {
    let mut resps: Vec<Response> = tickets.into_iter().map(Ticket::wait).collect();
    resps.sort_by_key(|r| r.id);
    resps
}

#[test]
fn decode_loop_matches_functional_reference_across_sessions() {
    let d = 64usize;
    let capacity = 128usize;
    let prefill_rows = 24usize;
    let steps = 32usize;
    let session_ids: &[u64] = &[11, 42, 99];

    let cfg = ServerConfig {
        shards: 2,
        kv_capacity: capacity,
        batch: BatchPolicy::bounds(8, Duration::from_micros(500)),
        ..Default::default()
    };
    // the reference mirrors must replay the server's execution geometry
    let quantum = cfg.pad_quantum;
    let server = CamformerServer::start(cfg, |_| FunctionalBackend::new(capacity, 64));

    // mirror stores accumulate the same K/V for the reference computation
    let mut mirror: Vec<KvStore> =
        session_ids.iter().map(|_| KvStore::new(capacity, d, d)).collect();
    let mut rng = Rng::new(7000);
    let mut next_id = 0u64;

    let mut acks = Vec::new();
    for (si, &sid) in session_ids.iter().enumerate() {
        let keys = rng.normal_vec(prefill_rows * d);
        let values = rng.normal_vec(prefill_rows * d);
        mirror[si].load(&keys, &values).unwrap();
        acks.push(
            server
                .submit_ticket(Request::Prefill { id: next_id, session: sid, head: 0, keys, values })
                .unwrap(),
        );
        next_id += 1;
    }
    for ack in wait_all(acks) {
        assert!(ack.is_ok(), "prefill failed: {:?}", ack.result);
        assert_eq!(ack.seq_len(), prefill_rows);
    }

    // interleaved decode streams: session A step t executes between
    // session B's steps, so cross-session contamination would be caught
    let mut tickets = Vec::new();
    let mut expected: Vec<(u64, Vec<f32>, usize)> = Vec::new();
    for _step in 0..steps {
        for (si, &sid) in session_ids.iter().enumerate() {
            let q = rng.normal_vec(d);
            let nk = rng.normal_vec(d);
            let nv = rng.normal_vec(d);
            mirror[si].append(&nk, &nv).unwrap();
            // the reference runs over the same padded execution geometry
            let rows = mirror[si].len().div_ceil(quantum) * quantum;
            let (kp, vp, _) = mirror[si].padded(rows);
            let want = functional::camformer_attention(&q, kp, vp, &AttnConfig::paper(rows, d));
            expected.push((next_id, want, mirror[si].len()));
            tickets.push(
                server
                    .submit_ticket(Request::Decode {
                        id: next_id,
                        session: sid,
                        head: 0,
                        query: q,
                        new_key: nk,
                        new_value: nv,
                    })
                    .unwrap(),
            );
            next_id += 1;
        }
    }

    let total = steps * session_ids.len();
    let resps = wait_all(tickets);
    assert_eq!(resps.len(), total);
    for (r, (id, want, seq_len)) in resps.iter().zip(&expected) {
        assert_eq!(r.id, *id);
        assert_eq!(
            r.output(),
            &want[..],
            "decode response {id} diverged from the functional reference"
        );
        assert_eq!(r.seq_len(), *seq_len, "response {id}: wrong live KV length");
    }

    let (m, _window) = server.shutdown();
    assert_eq!(m.prefills, session_ids.len() as u64);
    assert_eq!(m.decodes, total as u64);
    assert_eq!(m.errors, 0);
    assert!(m.p50_us() > 0.0, "p50 latency must be non-zero");
    assert!(m.p99_us() > 0.0, "p99 latency must be non-zero");
    assert!(m.p99() >= m.p50());
}

/// Replay one pre-generated interleaved decode workload through a server
/// built with the given batching policy; responses sorted by request id.
fn run_workload(
    max_batch: usize,
    max_wait: Duration,
    session_ids: &[u64],
    prefills: &[(Vec<f32>, Vec<f32>)],
    decodes: &[(u64, Vec<f32>, Vec<f32>, Vec<f32>)],
    capacity: usize,
) -> (Vec<camformer::coordinator::Response>, camformer::coordinator::Metrics) {
    let cfg = ServerConfig {
        kv_capacity: capacity,
        batch: BatchPolicy::bounds(max_batch, max_wait),
        ..Default::default()
    };
    let server = CamformerServer::start(cfg, |_| FunctionalBackend::new(capacity, 64));
    let mut acks = Vec::new();
    for (i, (&sid, (keys, values))) in session_ids.iter().zip(prefills).enumerate() {
        acks.push(
            server
                .submit_ticket(Request::Prefill {
                    id: 100_000 + i as u64,
                    session: sid,
                    head: 0,
                    keys: keys.clone(),
                    values: values.clone(),
                })
                .unwrap(),
        );
    }
    let mut tickets = Vec::new();
    for (id, (sid, q, nk, nv)) in decodes.iter().enumerate() {
        tickets.push(
            server
                .submit_ticket(Request::Decode {
                    id: id as u64,
                    session: *sid,
                    head: 0,
                    query: q.clone(),
                    new_key: nk.clone(),
                    new_value: nv.clone(),
                })
                .unwrap(),
        );
    }
    for ack in wait_all(acks) {
        assert!(ack.is_ok(), "prefill failed: {:?}", ack.result);
    }
    let resps = wait_all(tickets);
    let (m, _) = server.shutdown();
    (resps, m)
}

/// ISSUE 2 acceptance: ≥4 sessions interleaved on ONE head. The batched
/// path (cross-session dispatch groups) must be bit-equal to forcing
/// every request through its own dispatch, and both must match the
/// functional-reference mirror of each session's accumulated K/V.
#[test]
fn interleaved_sessions_batched_path_bit_equals_sequential() {
    let d = 64usize;
    let capacity = 128usize;
    let prefill_rows = 16usize;
    let steps = 24usize;
    let session_ids: &[u64] = &[3, 14, 15, 92, 65];

    let mut rng = Rng::new(8200);
    let prefills: Vec<(Vec<f32>, Vec<f32>)> = session_ids
        .iter()
        .map(|_| (rng.normal_vec(prefill_rows * d), rng.normal_vec(prefill_rows * d)))
        .collect();
    // interleaved round-robin: consecutive requests always change session
    let decodes: Vec<(u64, Vec<f32>, Vec<f32>, Vec<f32>)> = (0..steps)
        .flat_map(|_| session_ids.to_vec())
        .map(|sid| (sid, rng.normal_vec(d), rng.normal_vec(d), rng.normal_vec(d)))
        .collect();

    let (sequential, m_seq) = run_workload(
        1,
        Duration::from_micros(100),
        session_ids,
        &prefills,
        &decodes,
        capacity,
    );
    let (batched, m_bat) = run_workload(
        16,
        Duration::from_millis(2),
        session_ids,
        &prefills,
        &decodes,
        capacity,
    );

    assert_eq!(sequential.len(), steps * session_ids.len());
    assert_eq!(batched.len(), sequential.len());
    for (s, b) in sequential.iter().zip(&batched) {
        assert_eq!(s.id, b.id);
        assert_eq!(
            s.output(),
            b.output(),
            "request {}: batched dispatch diverged from sequential",
            s.id
        );
        assert_eq!(s.seq_len(), b.seq_len());
    }

    // both agree with the functional reference over mirrored stores
    let quantum = ServerConfig::default().pad_quantum;
    let mut mirror: Vec<KvStore> =
        session_ids.iter().map(|_| KvStore::new(capacity, d, d)).collect();
    for (si, (keys, values)) in prefills.iter().enumerate() {
        mirror[si].load(keys, values).unwrap();
    }
    for (r, (sid, q, nk, nv)) in batched.iter().zip(&decodes) {
        let si = session_ids.iter().position(|s| s == sid).unwrap();
        mirror[si].append(nk, nv).unwrap();
        let rows = mirror[si].len().div_ceil(quantum) * quantum;
        let (kp, vp, _) = mirror[si].padded(rows);
        let want = functional::camformer_attention(q, kp, vp, &AttnConfig::paper(rows, d));
        assert_eq!(r.output(), &want[..], "request {}", r.id);
        assert_eq!(r.seq_len(), mirror[si].len());
    }

    assert_eq!(m_seq.errors, 0);
    assert_eq!(m_bat.errors, 0);
    assert_eq!(m_bat.decodes, (steps * session_ids.len()) as u64);
    // occupancy accounting is consistent in both modes (a strict >1 bound
    // would hang timing on CI; the hotpath bench asserts the amortisation)
    assert!(m_seq.dispatches >= 1 && m_bat.dispatches >= 1);
    assert!(m_seq.mean_occupancy() >= 1.0);
    assert!(m_bat.mean_occupancy() >= 1.0);
    assert!(m_bat.max_occupancy >= 1);
}

/// A request refused at admission inside a dispatch group must answer
/// with its typed error while every batch-mate still succeeds — and the
/// refused decode must not have committed its append.
#[test]
fn refused_request_does_not_poison_batch_mates() {
    let d = 64usize;
    let capacity = 32usize;
    let cfg = ServerConfig { kv_capacity: capacity, ..Default::default() };
    let quantum = cfg.pad_quantum;
    let server = CamformerServer::start(cfg, |_| FunctionalBackend::new(capacity, 64));
    let mut rng = Rng::new(8300);

    // sessions 1 and 2 have headroom; session 3 is prefilled to capacity,
    // so its decode step must be refused at admission
    let mut mirror: Vec<KvStore> = (0..3).map(|_| KvStore::new(capacity, d, d)).collect();
    let mut acks = Vec::new();
    for (si, &rows) in [16usize, 16, capacity].iter().enumerate() {
        let keys = rng.normal_vec(rows * d);
        let values = rng.normal_vec(rows * d);
        mirror[si].load(&keys, &values).unwrap();
        acks.push(
            server
                .submit_ticket(Request::Prefill {
                    id: 100 + si as u64,
                    session: si as u64 + 1,
                    head: 0,
                    keys,
                    values,
                })
                .unwrap(),
        );
    }
    for ack in wait_all(acks) {
        assert!(ack.is_ok(), "prefill failed: {:?}", ack.result);
    }

    // one interleaved decode step per session, plus an attend against a
    // session that was never prefilled: ids 0..=3 land in one wire batch
    // (and must behave identically even if the scheduler splits them)
    let mut tickets = Vec::new();
    let mut expected: Vec<(u64, Vec<f32>)> = Vec::new();
    for (si, sid) in [1u64, 2].iter().enumerate() {
        let q = rng.normal_vec(d);
        let nk = rng.normal_vec(d);
        let nv = rng.normal_vec(d);
        mirror[si].append(&nk, &nv).unwrap();
        let rows = mirror[si].len().div_ceil(quantum) * quantum;
        let (kp, vp, _) = mirror[si].padded(rows);
        expected.push((
            si as u64,
            functional::camformer_attention(&q, kp, vp, &AttnConfig::paper(rows, d)),
        ));
        tickets.push(
            server
                .submit_ticket(Request::Decode {
                    id: si as u64,
                    session: *sid,
                    head: 0,
                    query: q,
                    new_key: nk,
                    new_value: nv,
                })
                .unwrap(),
        );
    }
    tickets.push(
        server
            .submit_ticket(Request::Decode {
                id: 2,
                session: 3,
                head: 0,
                query: rng.normal_vec(d),
                new_key: rng.normal_vec(d),
                new_value: rng.normal_vec(d),
            })
            .unwrap(),
    );
    tickets.push(
        server
            .submit_ticket(Request::Attend { id: 3, session: 999, head: 0, query: rng.normal_vec(d) })
            .unwrap(),
    );

    let resps = wait_all(tickets);
    assert_eq!(resps.len(), 4);

    for (id, want) in &expected {
        let r = &resps[*id as usize];
        assert!(r.is_ok(), "batch-mate {id} was poisoned: {:?}", r.result);
        assert_eq!(r.output(), &want[..], "batch-mate {id} diverged");
    }
    assert_eq!(
        resps[2].result,
        Err(ServeError::CapacityExhausted { capacity }),
        "full session's decode must be refused with a typed error"
    );
    assert_eq!(resps[3].result, Err(ServeError::UnknownSession { session: 999 }));

    // the refused decode committed nothing: session 3 still serves reads
    // at its original context length
    let r = server
        .submit_ticket(Request::Attend { id: 50, session: 3, head: 0, query: rng.normal_vec(d) })
        .unwrap()
        .wait();
    assert!(r.is_ok());
    assert_eq!(r.seq_len(), capacity);

    let (m, _) = server.shutdown();
    assert_eq!(m.errors, 2);
    assert_eq!(m.decodes, 2);
}

#[test]
fn decode_past_capacity_yields_typed_error() {
    let cfg = ServerConfig { kv_capacity: 16, ..Default::default() };
    let server = CamformerServer::start(cfg, |_| FunctionalBackend::new(16, 64));
    let mut rng = Rng::new(7100);
    // the refused decode (id 1) must not commit its append: the follow-up
    // attend (id 2) still serves at the original context length
    let tickets = vec![
        server
            .submit_ticket(Request::Prefill {
                id: 0,
                session: 5,
                head: 0,
                keys: rng.normal_vec(16 * 64),
                values: rng.normal_vec(16 * 64),
            })
            .unwrap(),
        server
            .submit_ticket(Request::Decode {
                id: 1,
                session: 5,
                head: 0,
                query: rng.normal_vec(64),
                new_key: rng.normal_vec(64),
                new_value: rng.normal_vec(64),
            })
            .unwrap(),
        server
            .submit_ticket(Request::Attend { id: 2, session: 5, head: 0, query: rng.normal_vec(64) })
            .unwrap(),
    ];
    let resps = wait_all(tickets);
    assert!(resps[0].is_ok());
    assert_eq!(resps[1].result, Err(ServeError::CapacityExhausted { capacity: 16 }));
    assert!(resps[2].is_ok());
    assert_eq!(resps[2].seq_len(), 16);
    let (m, _) = server.shutdown();
    assert_eq!(m.errors, 1);
}

#[test]
fn decode_against_unknown_session_is_typed() {
    let server = CamformerServer::start(
        ServerConfig { kv_capacity: 64, ..Default::default() },
        |_| FunctionalBackend::new(64, 64),
    );
    let mut rng = Rng::new(7200);
    let r = server
        .submit_ticket(Request::Decode {
            id: 9,
            session: 1234,
            head: 0,
            query: rng.normal_vec(64),
            new_key: rng.normal_vec(64),
            new_value: rng.normal_vec(64),
        })
        .unwrap()
        .wait();
    assert_eq!(r.result, Err(ServeError::UnknownSession { session: 1234 }));
    server.shutdown();
}
