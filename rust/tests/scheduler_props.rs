//! Standing-scheduler safety properties (ISSUE 6).
//!
//! Two invariants that must hold on EVERY execution, not just the
//! bit-equality streams in `batcher_fuzz.rs`:
//!
//! 1. **Budget soundness** — the shared per-worker KV pool never holds
//!    more resident rows than `ServerConfig::worker_kv_budget`, no
//!    matter how streams interleave prefills (charged net of replaced
//!    rows), decode appends (charged one row), closes, and evictions.
//!    The pool-residency high-water mark gauge is the witness.
//!
//! 2. **No silent drops under overload** — with a bounded queue and a
//!    deliberately stalled backend (so the scheduler cannot drain),
//!    every `submit_ticket` either enqueues (and its ticket later
//!    resolves to a typed response) or is refused synchronously with
//!    retryable [`ServeError::Overloaded`]. Accounting closes exactly:
//!    resolved + shed == submitted, and the server's shed counter
//!    agrees with the refusals the client saw.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use camformer::coordinator::backend::{AttentionBackend, FunctionalBackend};
use camformer::coordinator::batcher::BatchPolicy;
use camformer::coordinator::server::{CamformerServer, Request, ServerConfig};
use camformer::coordinator::{ReclaimPolicy, ServeError};
use camformer::util::rng::Rng;

const D: usize = 32;
const CAPACITY: usize = 32;

fn gen_stream(rng: &mut Rng, ops: usize) -> Vec<Request> {
    let sessions: [u64; 3] = [1, 2, 3];
    let mut out = Vec::with_capacity(ops);
    for id in 0..ops as u64 {
        let session = sessions[rng.index(sessions.len())];
        let req = match rng.index(16) {
            0..=2 => {
                let rows = 1 + rng.index(CAPACITY);
                Request::Prefill {
                    id,
                    session,
                    head: 0,
                    keys: rng.normal_vec(rows * D),
                    values: rng.normal_vec(rows * D),
                }
            }
            3..=11 => Request::Decode {
                id,
                session,
                head: 0,
                query: rng.normal_vec(D),
                new_key: rng.normal_vec(D),
                new_value: rng.normal_vec(D),
            },
            12 => Request::Close { id, session, head: 0 },
            _ => Request::Attend { id, session, head: 0, query: rng.normal_vec(D) },
        };
        out.push(req);
    }
    out
}

/// Property 1: across randomized streams, reclaim policies, and plan
/// modes, the pool-residency high-water mark never exceeds the budget —
/// i.e. admission is checked BEFORE rows become resident, including the
/// net-of-replaced accounting for re-prefills and the one-row decode
/// charge inside fused groups.
#[test]
fn admission_never_exceeds_worker_kv_budget() {
    // three sessions of capacity 32 against a 40-row pool: any unchecked
    // admission path overshoots almost immediately
    let budget = 40usize;
    let mut rng = Rng::new(0x5CED0);
    for case in 0..100u64 {
        let mut crng = rng.split();
        let stream = gen_stream(&mut crng, 12 + crng.index(28));
        for reclaim in [
            ReclaimPolicy::Deny,
            ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO },
        ] {
            for policy in [
                BatchPolicy::conservative(8, Duration::from_micros(200)),
                BatchPolicy::bounds(8, Duration::from_micros(200)),
            ] {
                let cfg = ServerConfig {
                    kv_capacity: CAPACITY,
                    d_k: D,
                    d_v: D,
                    max_sessions: 8,
                    reclaim,
                    batch: policy,
                    worker_kv_budget: budget,
                    ..Default::default()
                };
                let server = CamformerServer::start(cfg, |_| FunctionalBackend::new(CAPACITY, D));
                let tickets: Vec<_> = stream
                    .iter()
                    .map(|req| server.submit_ticket(req.clone()).unwrap())
                    .collect();
                for t in tickets {
                    // every response is typed; refusals are fine, drops are not
                    let _ = t.wait();
                }
                let (m, _) = server.shutdown();
                assert_eq!(m.completed + m.errors, stream.len() as u64, "case {case}");
                assert!(
                    m.kv_rows_hwm <= budget as u64,
                    "case {case} ({reclaim:?}, {policy:?}): pool residency {} broke budget {budget}",
                    m.kv_rows_hwm
                );
            }
        }
    }
}

/// A functional backend whose dispatches spin until the gate opens —
/// the worker blocks mid-`execute_batch`, so the standing queue can only
/// fill while the gate is closed.
struct GatedBackend {
    inner: FunctionalBackend,
    gate: Arc<AtomicBool>,
}

impl AttentionBackend for GatedBackend {
    fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> anyhow::Result<Vec<f32>> {
        while !self.gate.load(Ordering::Acquire) {
            thread::yield_now();
        }
        self.inner.attend(q, k, v)
    }

    fn name(&self) -> &'static str {
        "gated"
    }
}

/// Property 2: flood a stalled worker far past `max_queue`. Every
/// submit must either hand back a ticket that later resolves, or shed
/// synchronously with retryable `Overloaded { queue_depth }` — and a
/// `Close` is exempt from shedding (retiring a session must stay
/// possible under overload). When the gate opens, every accepted
/// ticket resolves to a typed response: accepted + shed == submitted
/// with nothing unaccounted for.
#[test]
fn bounded_queue_never_drops_silently_under_overload() {
    let max_queue = 4usize;
    let flood = 64usize;
    let gate = Arc::new(AtomicBool::new(false));
    let cfg = ServerConfig {
        kv_capacity: CAPACITY,
        d_k: D,
        d_v: D,
        // one-at-a-time dispatch: the worker blocks inside the very first
        // attend, leaving the rest of the flood stuck in the queue
        batch: BatchPolicy::bounds(1, Duration::from_micros(50)),
        max_queue,
        ..Default::default()
    };
    let backend_gate = gate.clone();
    let server = CamformerServer::start(cfg, move |_| GatedBackend {
        inner: FunctionalBackend::new(CAPACITY, D),
        gate: backend_gate.clone(),
    });
    let mut rng = Rng::new(0x0F10D);

    // the prefill barrier admits while the queue is empty (no backend
    // attend runs, so it cannot block on the gate)
    let prefill = server
        .submit_ticket(Request::Prefill {
            id: 0,
            session: 1,
            head: 0,
            keys: rng.normal_vec(8 * D),
            values: rng.normal_vec(8 * D),
        })
        .unwrap();
    assert!(prefill.wait().is_ok());

    let mut accepted = Vec::new();
    let mut shed = 0u64;
    for id in 1..=flood as u64 {
        match server.submit_ticket(Request::Attend {
            id,
            session: 1,
            head: 0,
            query: rng.normal_vec(D),
        }) {
            Ok(t) => accepted.push(t),
            Err(ServeError::Overloaded { queue_depth }) => {
                assert!(
                    queue_depth >= max_queue,
                    "shed reported depth {queue_depth} below the bound {max_queue}"
                );
                assert!(
                    ServeError::Overloaded { queue_depth }.is_retryable(&ReclaimPolicy::Deny),
                    "overload must be retryable"
                );
                shed += 1;
            }
            Err(e) => panic!("submit failed with a non-overload error: {e}"),
        }
    }
    assert!(shed > 0, "a 64-deep flood against max_queue=4 on a stalled worker must shed");
    assert!(
        !accepted.is_empty(),
        "the queue bound admits up to its depth before shedding"
    );

    // Close is exempt: it must be accepted even while the queue is full
    let close = server
        .submit_ticket(Request::Close { id: 9_999, session: 1, head: 0 })
        .expect("Close must never be shed");

    gate.store(true, Ordering::Release);
    let mut resolved = 0u64;
    for t in accepted {
        // attends queued before the Close succeed; any admitted after it
        // would answer typed — either way the ticket must resolve
        let _typed = t
            .wait_timeout(Duration::from_secs(30))
            .expect("accepted ticket never resolved: a request was dropped silently");
        resolved += 1;
    }
    assert!(close.wait_timeout(Duration::from_secs(30)).expect("close ticket hung").is_ok());

    let (m, _) = server.shutdown();
    assert_eq!(
        resolved + shed,
        flood as u64,
        "accounting must close: every submit either resolved or shed"
    );
    assert_eq!(m.shed_requests, shed, "server shed counter agrees with observed refusals");
    assert_eq!(
        m.completed + m.errors,
        resolved + 2, // + prefill + close
        "every accepted request was executed exactly once"
    );
    assert!(m.queue_depth_max >= 1);
}
