//! Session-handle API acceptance (ISSUE 5): shard-wide `open` fan-out
//! with all-or-nothing admission, typed per-request `Ticket` semantics
//! (out-of-order completion, `try_wait`/`wait_timeout`, dropped tickets,
//! `WorkerGone` propagation), explicit `close` lifecycle, and
//! `ReclaimPolicy::LruEvictIdle` turning terminal admission failures
//! into evictions.
//!
//! Extended for shard-coordinated reclamation (ISSUE 8): eviction picks
//! ONE victim per shard and tears it down on every head atomically (no
//! split-brain sessions), and `ReclaimPolicy::LruSpillToDram` demotes
//! victims into the simulated host DRAM tier and promotes them back
//! byte-identically — packed key bits included — on their next request.
//!
//! Extended for fault containment and supervised restart (ISSUE 9):
//! dispatch panics are contained (typed `Backend` error, worker keeps
//! serving); a `WorkerAbort` crash kills the incarnation and the
//! supervisor respawns it — tickets pending across the restart resolve
//! typed (`WorkerGone`/`SessionLost`), spilled sessions survive the
//! crash and resume byte-identically, and a handle dropped on a
//! genuinely dead worker counts exactly one failed close per head.

use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use camformer::coordinator::backend::{
    AttendItem, AttentionBackend, ChaosBackend, Fault, FaultPlan, FunctionalBackend,
};
use camformer::coordinator::kv_store::KvStore;
use camformer::coordinator::server::{CamformerServer, Request, ServerConfig};
use camformer::coordinator::{ReclaimPolicy, ServeError};
use camformer::util::rng::Rng;

fn functional_server(cfg: ServerConfig) -> CamformerServer {
    let n = cfg.kv_capacity;
    CamformerServer::start(cfg, move |_| FunctionalBackend::new(n, 64))
}

#[test]
fn open_fans_out_to_every_head_and_close_retires_all_of_them() {
    let d = 64usize;
    let capacity = 64usize;
    let cfg = ServerConfig { heads: 2, kv_capacity: capacity, ..Default::default() };
    let quantum = cfg.pad_quantum;
    let server = functional_server(cfg);
    let mut rng = Rng::new(9100);
    let keys = rng.normal_vec(16 * d);
    let values = rng.normal_vec(16 * d);
    let mut mirror = KvStore::new(capacity, d, d);
    mirror.load(&keys, &values).unwrap();

    // ONE open call admits the session on BOTH head workers
    let session = server.open(4, keys, values).expect("open must fan out");
    let q = rng.normal_vec(d);
    let t0 = session.attend_on(0, q.clone()).unwrap();
    let t1 = session.attend_on(1, q.clone()).unwrap();
    let (r0, r1) = (t0.wait(), t1.wait());
    assert!(r0.is_ok() && r1.is_ok(), "{:?} / {:?}", r0.result, r1.result);
    // both heads hold the same broadcast prefill, so both match the
    // functional reference over the mirrored store
    let rows = mirror.len().div_ceil(quantum) * quantum;
    let (kp, vp, _) = mirror.padded(rows);
    let mut reference = FunctionalBackend::new(capacity, d);
    let want = reference.attend(&q, kp, vp).unwrap();
    assert_eq!(r0.output(), &want[..]);
    assert_eq!(r1.output(), &want[..]);
    assert_eq!((r0.head, r1.head), (0, 1));

    // close confirms the release on every head: the session is unknown
    // to both workers afterwards
    session.close().expect("close must confirm");
    for head in 0..2 {
        let t = server
            .submit_ticket(Request::Attend {
                id: 900 + head as u64,
                session: 4,
                head,
                query: q.clone(),
            })
            .unwrap();
        assert_eq!(t.wait().result, Err(ServeError::UnknownSession { session: 4 }));
    }
    let (m, _) = server.shutdown();
    assert_eq!(m.prefills, 2, "one broadcast prefill per head");
    assert_eq!(m.closes, 2, "one close per head");
    assert_eq!(m.kv_rows_released, 2 * capacity as u64);
}

#[test]
fn tickets_resolve_out_of_order_across_sessions() {
    let d = 64usize;
    let capacity = 64usize;
    let cfg = ServerConfig { kv_capacity: capacity, ..Default::default() };
    let quantum = cfg.pad_quantum;
    let server = functional_server(cfg);
    let mut rng = Rng::new(9200);

    let mut mirrors = Vec::new();
    let mut handles = Vec::new();
    for sid in [1u64, 2] {
        let keys = rng.normal_vec(16 * d);
        let values = rng.normal_vec(16 * d);
        let mut mirror = KvStore::new(capacity, d, d);
        mirror.load(&keys, &values).unwrap();
        mirrors.push(mirror);
        handles.push(server.open(sid, keys, values).unwrap());
    }

    // issue decode tickets A then B, but WAIT B before A: each ticket
    // must resolve to exactly its own request's response
    let mut tickets = Vec::new();
    let mut expected = Vec::new();
    for (si, h) in handles.iter().enumerate() {
        let q = rng.normal_vec(d);
        let nk = rng.normal_vec(d);
        let nv = rng.normal_vec(d);
        mirrors[si].append(&nk, &nv).unwrap();
        let rows = mirrors[si].len().div_ceil(quantum) * quantum;
        let (kp, vp, _) = mirrors[si].padded(rows);
        let mut reference = FunctionalBackend::new(capacity, d);
        expected.push(reference.attend(&q, kp, vp).unwrap());
        tickets.push(h.decode(q, nk, nv).unwrap());
    }
    let tb = tickets.pop().unwrap();
    let ta = tickets.pop().unwrap();
    let (ida, idb) = (ta.id(), tb.id());
    let rb = tb.wait();
    let ra = ta.wait();
    assert_eq!(rb.id, idb);
    assert_eq!(ra.id, ida);
    assert_eq!((ra.session, rb.session), (1, 2));
    assert_eq!(ra.output(), &expected[0][..]);
    assert_eq!(rb.output(), &expected[1][..]);
    assert_eq!(ra.seq_len(), 17);
    assert_eq!(rb.seq_len(), 17);
    drop(handles);
    server.shutdown();
}

/// Backend whose batched dispatches stall, so responses cannot arrive
/// before a short ticket timeout expires (prefill barriers don't
/// dispatch and stay fast).
struct SlowBackend {
    inner: FunctionalBackend,
    delay: Duration,
}

impl AttentionBackend for SlowBackend {
    fn attend(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.inner.attend(q, k, v)
    }

    fn attend_batch(&mut self, items: &[AttendItem<'_>]) -> anyhow::Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.delay);
        self.inner.attend_batch(items)
    }

    fn supports_prefix_views(&self) -> bool {
        self.inner.supports_prefix_views()
    }

    fn name(&self) -> &'static str {
        "slow"
    }
}

#[test]
fn wait_timeout_expires_then_the_recovered_ticket_still_resolves() {
    let capacity = 32usize;
    let cfg = ServerConfig { kv_capacity: capacity, ..Default::default() };
    let server = CamformerServer::start(cfg, move |_| SlowBackend {
        inner: FunctionalBackend::new(capacity, 64),
        delay: Duration::from_millis(300),
    });
    let mut rng = Rng::new(9300);
    let session = server.open(1, rng.normal_vec(8 * 64), rng.normal_vec(8 * 64)).unwrap();

    let ticket = session
        .decode(rng.normal_vec(64), rng.normal_vec(64), rng.normal_vec(64))
        .unwrap();
    // the dispatch sleeps 300ms and the wire batcher waits its full 2ms
    // deadline first, so a 1ms wait must expire — handing the ticket
    // back without cancelling the in-flight request
    let ticket = match ticket.try_wait() {
        Err(t) => t,
        Ok(r) => panic!("resolved before the dispatch could run: {:?}", r.result),
    };
    let ticket = match ticket.wait_timeout(Duration::from_millis(1)) {
        Err(t) => t,
        Ok(r) => panic!("resolved before the timeout: {:?}", r.result),
    };
    // the recovered ticket still resolves to the (slow) response
    let r = ticket.wait();
    assert!(r.is_ok(), "{:?}", r.result);
    assert_eq!(r.seq_len(), 9);
    session.close().unwrap();
    server.shutdown();
}

/// `wait_deadline` is the absolute-time counterpart to `wait_timeout`,
/// with the same expiry contract: past the deadline the ticket comes
/// back, still live, and can be waited again.
#[test]
fn wait_deadline_expires_then_the_recovered_ticket_still_resolves() {
    let capacity = 32usize;
    let cfg = ServerConfig { kv_capacity: capacity, ..Default::default() };
    let server = CamformerServer::start(cfg, move |_| SlowBackend {
        inner: FunctionalBackend::new(capacity, 64),
        delay: Duration::from_millis(300),
    });
    let mut rng = Rng::new(9310);
    let session = server.open(1, rng.normal_vec(8 * 64), rng.normal_vec(8 * 64)).unwrap();

    let ticket = session
        .decode(rng.normal_vec(64), rng.normal_vec(64), rng.normal_vec(64))
        .unwrap();
    // a near-term deadline expires before the 300ms dispatch completes,
    // handing the ticket back without cancelling the request
    let ticket = match ticket.wait_deadline(Instant::now() + Duration::from_millis(1)) {
        Err(t) => t,
        Ok(r) => panic!("resolved before the deadline: {:?}", r.result),
    };
    // a deadline that already passed expires immediately (saturating:
    // it must not panic or block)
    let ticket = match ticket.wait_deadline(Instant::now()) {
        Err(t) => t,
        Ok(r) => panic!("resolved on an already-expired deadline: {:?}", r.result),
    };
    // the recovered ticket still resolves to the (slow) response
    let r = ticket.wait_deadline(Instant::now() + Duration::from_secs(10)).expect("must resolve");
    assert!(r.is_ok(), "{:?}", r.result);
    assert_eq!(r.seq_len(), 9);
    session.close().unwrap();
    server.shutdown();
}

#[test]
fn dropped_tickets_leak_nothing_and_never_wedge_the_worker() {
    let capacity = 64usize;
    let cfg = ServerConfig { kv_capacity: capacity, ..Default::default() };
    let server = functional_server(cfg);
    let mut rng = Rng::new(9400);
    let session = server.open(3, rng.normal_vec(4 * 64), rng.normal_vec(4 * 64)).unwrap();

    // fire-and-forget: drop 5 decode tickets without waiting. The
    // completion slot IS the per-ticket channel, so the worker's sends
    // land in closed slots and nothing accumulates anywhere.
    for _ in 0..5 {
        let t = session
            .decode(rng.normal_vec(64), rng.normal_vec(64), rng.normal_vec(64))
            .unwrap();
        drop(t);
    }
    // the worker is alive and the dropped requests still executed
    let r = session.attend(rng.normal_vec(64)).unwrap().wait();
    assert!(r.is_ok(), "{:?}", r.result);
    assert_eq!(r.seq_len(), 4 + 5, "dropped tickets' decodes still appended");
    session.close().unwrap();
    let (m, _) = server.shutdown();
    assert_eq!(m.decodes, 5, "unobserved responses still count as served");
    assert_eq!(m.errors, 0);
}

/// Backend whose every dispatch panics (with an ordinary payload — NOT
/// a `WorkerAbort` — so containment must absorb it).
struct PanickingBackend;

impl AttentionBackend for PanickingBackend {
    fn attend(&mut self, _q: &[f32], _k: &[f32], _v: &[f32]) -> anyhow::Result<Vec<f32>> {
        panic!("injected dispatch panic (session_api test)")
    }

    fn name(&self) -> &'static str {
        "panicking"
    }
}

/// ISSUE 9: a panicking dispatch used to take the whole worker thread
/// down (the pending ticket resolved `WorkerGone` through its dropped
/// slot, and every later request hit a dead queue). Containment now
/// absorbs it: the ticket resolves with a typed `Backend` error, the
/// panic is counted, and the worker keeps serving.
#[test]
fn dispatch_panic_is_contained_and_the_worker_keeps_serving() {
    let cfg = ServerConfig { kv_capacity: 16, ..Default::default() };
    let server = CamformerServer::start(cfg, |_| PanickingBackend);
    let mut rng = Rng::new(9500);
    // prefill is a barrier (no dispatch), so open succeeds even here
    let session = server.open(0, rng.normal_vec(4 * 64), rng.normal_vec(4 * 64)).unwrap();
    let ticket = session.attend(rng.normal_vec(64)).unwrap();
    let r = ticket.wait();
    match &r.result {
        Err(ServeError::Backend(msg)) => {
            assert!(msg.contains("panic"), "containment must surface the payload: {msg}")
        }
        other => panic!("expected a contained-panic Backend error, got {other:?}"),
    }
    // the worker survived: the session is intact and teardown confirms
    session.close().expect("worker must still be serving after a contained panic");
    let (m, _) = server.shutdown();
    assert_eq!(m.worker_panics, 1, "the contained panic is counted");
    assert_eq!(m.worker_restarts, 0, "containment is not a restart");
    assert_eq!(m.sessions_lost, 0, "no state was lost");
    assert_eq!(m.errors, 1);
    assert_eq!(m.closes, 1);
    assert_eq!(m.close_failures, 0);
}

/// A crash (`Fault::Crash` raises `WorkerAbort`) escapes containment on
/// purpose and kills the backend incarnation. The supervisor respawns a
/// fresh backend from the factory onto the same queue: tickets pending
/// across the restart resolve typed — `WorkerGone` if in flight when
/// the incarnation died, `SessionLost` if their session's KV died with
/// it — and never hang; the lost id revives on re-open.
#[test]
fn tickets_pending_across_a_supervised_restart_resolve_typed() {
    let cfg = ServerConfig { kv_capacity: 32, ..Default::default() };
    // first incarnation crashes on its first dispatch; respawns are clean
    let builds = Arc::new(AtomicUsize::new(0));
    let server = {
        let builds = builds.clone();
        CamformerServer::start(cfg, move |_| {
            let plan = if builds.fetch_add(1, AtomicOrdering::SeqCst) == 0 {
                FaultPlan::at(vec![(1, Fault::Crash)])
            } else {
                FaultPlan::none()
            };
            ChaosBackend::new(FunctionalBackend::new(32, 64), plan)
        })
    };
    let mut rng = Rng::new(9510);
    let session = server.open(1, rng.normal_vec(8 * 64), rng.normal_vec(8 * 64)).unwrap();
    let mut tickets = Vec::new();
    for _ in 0..6 {
        tickets.push(
            session
                .decode(rng.normal_vec(64), rng.normal_vec(64), rng.normal_vec(64))
                .unwrap(),
        );
    }
    // every ticket must resolve typed within the deadline — in-flight
    // ones through their dropped slots, queued ones through the
    // supervisor's drain or the new incarnation's tombstone
    let deadline = Instant::now() + Duration::from_secs(10);
    for t in tickets {
        let r = match t.wait_deadline(deadline) {
            Ok(r) => r,
            Err(_) => panic!("a ticket hung across the supervised restart"),
        };
        assert!(
            matches!(
                r.result,
                Err(ServeError::WorkerGone { .. }) | Err(ServeError::SessionLost { session: 1 })
            ),
            "expected WorkerGone or SessionLost, got {:?}",
            r.result
        );
    }
    // the handle's id is tombstoned on the respawned worker
    let r = session.attend(rng.normal_vec(64)).unwrap().wait();
    assert_eq!(r.result, Err(ServeError::SessionLost { session: 1 }));
    drop(session); // fire-and-forget closes acknowledge the loss
    // re-opening the lost id revives it on the new incarnation
    let revived = server.open(1, rng.normal_vec(8 * 64), rng.normal_vec(8 * 64)).unwrap();
    assert!(revived.attend(rng.normal_vec(64)).unwrap().wait().is_ok());
    revived.close().unwrap();
    let (m, _) = server.shutdown();
    assert_eq!(m.worker_restarts, 1, "one supervised respawn");
    assert_eq!(m.worker_panics, 1, "the crash is a counted panic");
    assert_eq!(m.sessions_lost, 1, "the resident session died with the incarnation");
    assert_eq!(m.sessions_recovered, 0, "nothing was spilled, so nothing could recover");
    assert!(builds.load(AtomicOrdering::SeqCst) >= 2, "the factory rebuilt the backend");
}

/// ISSUE 9 acceptance: the DRAM spill pool lives in the shard directory,
/// outside every worker thread — so a session parked there when its
/// worker crashes survives, promotes byte-identically onto the
/// respawned incarnation, and counts as recovered. The resident session
/// dies (`SessionLost`), the spilled one never sees an error.
#[test]
fn spilled_session_survives_worker_crash_and_resumes_byte_identically() {
    let d = 64usize;
    let capacity = 32usize;
    let cfg = ServerConfig {
        kv_capacity: capacity,
        // two 16-row sessions overflow the pool: opening B demotes A
        worker_kv_budget: 24,
        reclaim: ReclaimPolicy::LruSpillToDram { min_idle: Duration::ZERO },
        ..Default::default()
    };
    let quantum = cfg.pad_quantum;
    let builds = Arc::new(AtomicUsize::new(0));
    let server = {
        let builds = builds.clone();
        CamformerServer::start(cfg, move |_| {
            let plan = if builds.fetch_add(1, AtomicOrdering::SeqCst) == 0 {
                FaultPlan::at(vec![(1, Fault::Crash)])
            } else {
                FaultPlan::none()
            };
            ChaosBackend::new(FunctionalBackend::new(capacity, d), plan)
        })
    };
    let mut rng = Rng::new(9520);
    let keys = rng.normal_vec(16 * d);
    let values = rng.normal_vec(16 * d);
    let mut mirror = KvStore::new(capacity, d, d);
    mirror.load(&keys, &values).unwrap();

    let ha = server.open(1, keys, values).unwrap();
    // opening B overflows the 24-row pool: A is demoted into the shard
    // directory's spill pool — crash-durable storage
    let hb = server.open(2, rng.normal_vec(16 * d), rng.normal_vec(16 * d)).unwrap();
    // B's attend is the first dispatch: the incarnation crashes holding
    // B's (resident) KV, while A's parked copy sits safely in the pool
    let r = hb.attend(rng.normal_vec(d)).unwrap().wait();
    assert!(
        matches!(
            r.result,
            Err(ServeError::WorkerGone { .. }) | Err(ServeError::SessionLost { session: 2 })
        ),
        "the crashed dispatch answers typed: {:?}",
        r.result
    );
    // A promotes back onto the RESPAWNED worker, byte-identically: the
    // output must match the functional reference over the pre-crash KV
    // (packed key bits included — the fused pipeline scores them)
    let q = rng.normal_vec(d);
    let r = ha.attend(q.clone()).unwrap().wait();
    assert!(r.is_ok(), "the spilled session must survive the crash: {:?}", r.result);
    assert_eq!(r.seq_len(), 16, "restored context length");
    let rows = mirror.len().div_ceil(quantum) * quantum;
    let (kp, vp, _) = mirror.padded(rows);
    let mut reference = FunctionalBackend::new(capacity, d);
    let want = reference.attend(&q, kp, vp).unwrap();
    assert_eq!(r.output(), &want[..], "recovered KV must be byte-identical");
    // B died with the incarnation: typed loss until re-opened
    let r = hb.attend(rng.normal_vec(d)).unwrap().wait();
    assert_eq!(r.result, Err(ServeError::SessionLost { session: 2 }));
    let hb2 = server.open(2, rng.normal_vec(4 * d), rng.normal_vec(4 * d)).unwrap();
    assert!(hb2.attend(rng.normal_vec(d)).unwrap().wait().is_ok());
    drop((ha, hb, hb2));
    let (m, _) = server.shutdown();
    assert_eq!(m.worker_restarts, 1);
    assert_eq!(m.sessions_lost, 1, "only the resident session was lost");
    assert_eq!(m.sessions_recovered, 1, "the spilled session promoted after the crash");
    assert_eq!(m.evictions, 0, "the spill tier never drops state");
}

/// A worker is *genuinely* gone only when its supervisor dies — here the
/// backend factory panics on the post-crash rebuild, so restart itself
/// fails. Requests answer `WorkerGone` synchronously, and a
/// `SessionHandle` dropped afterwards counts exactly one failed close
/// per head without hanging; shutdown still reports the death.
#[test]
fn handle_drop_after_genuine_worker_death_counts_one_close_failure() {
    let cfg = ServerConfig { kv_capacity: 16, ..Default::default() };
    let builds = Arc::new(AtomicUsize::new(0));
    let server = {
        let builds = builds.clone();
        CamformerServer::start(cfg, move |_| {
            if builds.fetch_add(1, AtomicOrdering::SeqCst) > 0 {
                panic!("factory exhausted: no backend for the respawn");
            }
            ChaosBackend::new(FunctionalBackend::new(16, 64), FaultPlan::at(vec![(1, Fault::Crash)]))
        })
    };
    let mut rng = Rng::new(9530);
    let session = server.open(7, rng.normal_vec(4 * 64), rng.normal_vec(4 * 64)).unwrap();
    // the crash kills the incarnation; the respawn kills the supervisor
    let r = session.attend(rng.normal_vec(64)).unwrap().wait();
    assert!(
        matches!(
            r.result,
            Err(ServeError::WorkerGone { .. }) | Err(ServeError::SessionLost { session: 7 })
        ),
        "{:?}",
        r.result
    );
    // give the supervisor thread time to die in the factory
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match session.attend(rng.normal_vec(64)) {
            Err(ServeError::WorkerGone { .. }) => break,
            Err(e) => panic!("unexpected submit error: {e:?}"),
            Ok(t) => {
                let _ = t.wait_deadline(deadline);
            }
        }
        assert!(Instant::now() < deadline, "worker never became gone");
        std::thread::sleep(Duration::from_millis(5));
    }
    // handle drop fires a close at the dead worker: exactly one failed
    // close (one head), no hang, no panic
    drop(session);
    let (m, _) = server.shutdown();
    assert_eq!(m.close_failures, 1, "the drop-path close failure is counted once");
    assert!(m.worker_panics >= 1, "the dead supervisor is reported at shutdown");
}

#[test]
fn open_past_the_session_limit_follows_the_reclaim_policy() {
    let mut rng = Rng::new(9600);
    let prefill = |rng: &mut Rng| (rng.normal_vec(8 * 64), rng.normal_vec(8 * 64));

    // Deny (default): the third open is a terminal SessionLimit
    let cfg = ServerConfig { max_sessions: 2, kv_capacity: 16, ..Default::default() };
    let server = functional_server(cfg);
    let (k, v) = prefill(&mut rng);
    let h1 = server.open(1, k, v).unwrap();
    let (k, v) = prefill(&mut rng);
    let h2 = server.open(2, k, v).unwrap();
    let (k, v) = prefill(&mut rng);
    let refused = server.open(3, k, v);
    assert!(
        matches!(refused, Err(ServeError::SessionLimit { max_sessions: 2 })),
        "{refused:?}"
    );
    assert!(!refused.err().unwrap().is_retryable(&ReclaimPolicy::Deny));
    drop((h1, h2));
    server.shutdown();

    // LruEvictIdle: the same third open succeeds by evicting the LRU
    // idle session; the victim's requests answer Evicted until re-open
    let cfg = ServerConfig {
        max_sessions: 2,
        kv_capacity: 16,
        reclaim: ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO },
        ..Default::default()
    };
    let server = functional_server(cfg);
    let (k, v) = prefill(&mut rng);
    let h1 = server.open(1, k, v).unwrap();
    let (k, v) = prefill(&mut rng);
    let h2 = server.open(2, k, v).unwrap();
    // touch session 1 so session 2 is the LRU victim
    assert!(h1.attend(rng.normal_vec(64)).unwrap().wait().is_ok());
    let (k, v) = prefill(&mut rng);
    let h3 = server.open(3, k, v).expect("LRU policy must admit by evicting");
    let evicted = h2.attend(rng.normal_vec(64)).unwrap().wait();
    assert_eq!(evicted.result, Err(ServeError::Evicted { session: 2 }));
    // the typed error is retryable-after-reopen semantics: re-opening
    // the evicted id revives it (evicting the next LRU in turn)
    let (k, v) = prefill(&mut rng);
    let h2b = server.open(2, k, v).expect("re-open of an evicted session");
    assert!(h2b.attend(rng.normal_vec(64)).unwrap().wait().is_ok());
    drop((h1, h2, h3, h2b));
    let (m, _) = server.shutdown();
    assert_eq!(m.evictions, 2);
    assert!(m.closes >= 1, "handle drops close whatever sessions remain");
}

/// ISSUE 8 acceptance: eviction is atomic across a shard's heads. The
/// pre-PR-8 per-worker eviction could reclaim a session on one head
/// while the other kept serving it (split-brain); the shard directory
/// must pick ONE victim and drop it on BOTH heads, counting one
/// eviction for the one shard-wide decision.
#[test]
fn shard_eviction_is_atomic_across_heads() {
    let cfg = ServerConfig {
        heads: 2,
        max_sessions: 2,
        kv_capacity: 16,
        reclaim: ReclaimPolicy::LruEvictIdle { min_idle: Duration::ZERO },
        ..Default::default()
    };
    let server = functional_server(cfg);
    let mut rng = Rng::new(9800);
    let h1 = server.open(1, rng.normal_vec(8 * 64), rng.normal_vec(8 * 64)).unwrap();
    let h2 = server.open(2, rng.normal_vec(8 * 64), rng.normal_vec(8 * 64)).unwrap();
    // touch session 2 on both heads so session 1 is the shard-wide LRU
    assert!(h2.attend_on(0, rng.normal_vec(64)).unwrap().wait().is_ok());
    assert!(h2.attend_on(1, rng.normal_vec(64)).unwrap().wait().is_ok());
    // the over-limit open broadcasts to both heads; each worker hits
    // slot pressure, but only ONE shard-wide victim may be chosen
    let h3 = server.open(3, rng.normal_vec(8 * 64), rng.normal_vec(8 * 64)).unwrap();
    // the victim is gone on BOTH heads — not evicted on one and stale
    // on the other
    for head in 0..2 {
        let r = h1.attend_on(head, rng.normal_vec(64)).unwrap().wait();
        assert_eq!(
            r.result,
            Err(ServeError::Evicted { session: 1 }),
            "head {head} must agree the victim is evicted"
        );
    }
    // the survivor still serves on both heads
    assert!(h2.attend_on(0, rng.normal_vec(64)).unwrap().wait().is_ok());
    assert!(h2.attend_on(1, rng.normal_vec(64)).unwrap().wait().is_ok());
    drop((h1, h2, h3));
    let (m, _) = server.shutdown();
    assert_eq!(m.evictions, 1, "one shard-wide decision, counted once");
    assert_eq!(m.demotions, 0, "the dropping policy never spills");
}

/// ISSUE 8 acceptance: under `LruSpillToDram` a victim is demoted to
/// the DRAM tier and its next request promotes it back byte-identically
/// (the attend output matches the functional reference over the
/// original KV — which exercises the restored packed key bits, since
/// the fused pipeline scores them directly). Clients never see
/// `Evicted`; the spill-tier counters surface the round trip.
#[test]
fn demoted_session_resumes_byte_identical_after_promotion() {
    let d = 64usize;
    let capacity = 32usize;
    let cfg = ServerConfig {
        kv_capacity: capacity,
        // two 16-row sessions overflow the pool: exactly one can be
        // resident at a time, so every switch demotes one and promotes
        // the other
        worker_kv_budget: 24,
        reclaim: ReclaimPolicy::LruSpillToDram { min_idle: Duration::ZERO },
        ..Default::default()
    };
    let quantum = cfg.pad_quantum;
    let server = functional_server(cfg);
    let mut rng = Rng::new(9900);
    let keys = rng.normal_vec(16 * d);
    let values = rng.normal_vec(16 * d);
    let mut mirror = KvStore::new(capacity, d, d);
    mirror.load(&keys, &values).unwrap();

    let ha = server.open(1, keys, values).unwrap();
    // opening session 2 overflows the 24-row pool: session 1 is demoted
    // (not dropped) to make room
    let hb = server.open(2, rng.normal_vec(16 * d), rng.normal_vec(16 * d)).unwrap();
    // touching the demoted session promotes it back — a slow first
    // token, NOT ServeError::Evicted — and the restored KV must be
    // byte-identical to what was demoted
    let q = rng.normal_vec(d);
    let r = ha.attend(q.clone()).unwrap().wait();
    assert!(r.is_ok(), "promotion must revive the session: {:?}", r.result);
    assert_eq!(r.seq_len(), 16, "restored context length");
    let rows = mirror.len().div_ceil(quantum) * quantum;
    let (kp, vp, _) = mirror.padded(rows);
    let mut reference = FunctionalBackend::new(capacity, d);
    let want = reference.attend(&q, kp, vp).unwrap();
    assert_eq!(r.output(), &want[..], "restored KV (incl. packed bits) must be byte-identical");

    // closing the (now spilled) session 2 discards its parked copy
    // without promoting it: the ack carries the spilled context length
    hb.close().expect("close of a demoted session");
    ha.close().expect("close of the promoted session");
    let (m, _) = server.shutdown();
    assert_eq!(m.evictions, 0, "the spill tier never drops state");
    assert_eq!(m.demotions, 2, "A demoted for B's open, B demoted for A's promotion");
    assert_eq!(m.promotions, 1);
    assert_eq!(m.spilled_rows, 0, "both parked copies were closed or promoted");
    assert!(m.dram_bytes_written > 0, "demotion writeback rides the DRAM channel");
    assert!(m.dram_bytes_read > 0, "promotion reads ride the DRAM channel");
    assert!(m.promotion_p50_ns() > 0.0, "promotion latency is modeled");
    assert_eq!(m.errors, 0, "no client-visible failure anywhere in the round trip");
    assert_eq!(m.closes, 2);
}

#[test]
fn open_is_all_or_nothing_across_heads() {
    // two head workers, max_sessions = 1, Deny. Head 1 is pre-occupied
    // by a legacy per-head prefill, so a shard-wide open admits on head
    // 0 but refuses on head 1 — and must roll the head-0 admission back.
    let cfg = ServerConfig { heads: 2, max_sessions: 1, kv_capacity: 16, ..Default::default() };
    let server = functional_server(cfg);
    let mut rng = Rng::new(9700);
    let occupy = server
        .submit_ticket(Request::Prefill {
            id: 1,
            session: 9,
            head: 1,
            keys: rng.normal_vec(8 * 64),
            values: rng.normal_vec(8 * 64),
        })
        .unwrap();
    assert!(occupy.wait().is_ok());

    let refused = server.open(11, rng.normal_vec(8 * 64), rng.normal_vec(8 * 64));
    assert!(
        matches!(refused, Err(ServeError::SessionLimit { max_sessions: 1 })),
        "{refused:?}"
    );
    // (the Result's type borrows the server even in the Err case; drop
    // it so shutdown below can take the server by value)
    drop(refused);
    // rollback: the partially-admitted session is gone from head 0 too
    let t = server
        .submit_ticket(Request::Attend { id: 2, session: 11, head: 0, query: rng.normal_vec(64) })
        .unwrap();
    assert_eq!(t.wait().result, Err(ServeError::UnknownSession { session: 11 }));
    // the bystander session on head 1 was never disturbed
    let t = server
        .submit_ticket(Request::Attend { id: 3, session: 9, head: 1, query: rng.normal_vec(64) })
        .unwrap();
    assert!(t.wait().is_ok());
    let (m, _) = server.shutdown();
    assert_eq!(m.closes, 1, "exactly the rollback close ran");
}
