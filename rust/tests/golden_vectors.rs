//! Golden-vector tests: the Rust functional model vs the jnp oracle.
//!
//! `make golden` produces artifacts/golden.tsv from ref.py; here we replay
//! the same inputs through `accuracy::functional` and require scores to be
//! bit-exact and attention outputs to agree within f32 exp/bf16 slack.
//! Skipped (not failed) when golden.tsv is absent.

use camformer::accuracy::functional::{self, AttnConfig};

struct Case {
    n: usize,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    scores: Vec<f64>,
    attention: Vec<f32>,
}

fn parse_cases(text: &str) -> Vec<Case> {
    let mut cases = Vec::new();
    let mut cur: Option<Case> = None;
    for line in text.lines() {
        let (tag, rest) = match line.split_once('\t') {
            Some(x) => x,
            None => continue,
        };
        let floats = |s: &str| -> Vec<f32> {
            s.split(',').map(|x| x.parse::<f32>().unwrap()).collect()
        };
        match tag {
            "case" => {
                if let Some(c) = cur.take() {
                    cases.push(c);
                }
                let mut it = rest.split('\t');
                let _id: usize = it.next().unwrap().parse().unwrap();
                let n: usize = it.next().unwrap().parse().unwrap();
                cur = Some(Case {
                    n,
                    q: vec![],
                    k: vec![],
                    v: vec![],
                    scores: vec![],
                    attention: vec![],
                });
            }
            "q" => cur.as_mut().unwrap().q = floats(rest),
            "k" => cur.as_mut().unwrap().k = floats(rest),
            "v" => cur.as_mut().unwrap().v = floats(rest),
            "scores" => {
                cur.as_mut().unwrap().scores =
                    rest.split(',').map(|x| x.parse::<f64>().unwrap()).collect()
            }
            "attention" => cur.as_mut().unwrap().attention = floats(rest),
            _ => {}
        }
    }
    if let Some(c) = cur.take() {
        cases.push(c);
    }
    cases
}

fn load() -> Option<Vec<Case>> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden.tsv");
    if !path.exists() {
        eprintln!("skipping golden tests: {path:?} missing (run `make golden`)");
        return None;
    }
    Some(parse_cases(&std::fs::read_to_string(path).unwrap()))
}

#[test]
fn golden_scores_bit_exact() {
    let Some(cases) = load() else { return };
    assert!(!cases.is_empty());
    for c in &cases {
        let got = functional::bacam_scores(&c.q, &c.k, 64);
        assert_eq!(got.len(), c.scores.len());
        for (i, (g, w)) in got.iter().zip(&c.scores).enumerate() {
            assert_eq!(g, w, "case n={} score {i}", c.n);
        }
    }
}

#[test]
fn golden_attention_close() {
    let Some(cases) = load() else { return };
    for c in &cases {
        let got = functional::camformer_attention(&c.q, &c.k, &c.v, &AttnConfig::paper(c.n, 64));
        assert_eq!(got.len(), c.attention.len());
        for (i, (g, w)) in got.iter().zip(&c.attention).enumerate() {
            assert!(
                (g - w).abs() < 1e-2,
                "case n={} dim {i}: rust {g} vs jnp {w}",
                c.n
            );
        }
    }
}
