//! Integration tests: the Rust PJRT runtime executes the AOT artifacts and
//! the numerics match the pure-Rust functional model / known properties.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (not failed) when the artifacts are absent so `cargo test` stays green
//! on a fresh checkout.

use camformer::accuracy::functional;
use camformer::runtime::executable::{default_artifacts_dir, Engine};
use camformer::util::rng::Rng;

fn engine_or_skip() -> Option<Engine> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("skipping: no artifacts at {dir:?} (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(&dir).expect("engine"))
}

#[test]
fn scores_kernel_matches_rust_model() {
    let Some(mut eng) = engine_or_skip() else { return };
    let exe = eng.load("bacam_scores").expect("load bacam_scores");

    let mut rng = Rng::new(100);
    let q: Vec<f32> = rng.normal_vec(64);
    let k: Vec<f32> = rng.normal_vec(1024 * 64);
    let out = exe.run_f32(&[&q, &k]).expect("run");
    assert_eq!(out.len(), 1024);

    // the pallas kernel's scores must equal the rust functional model's
    let want = functional::bacam_scores(&q, &k, 64);
    for (i, (g, w)) in out.iter().zip(&want).enumerate() {
        assert_eq!(*g as f64, *w, "score {i}: pjrt {g} vs rust {w}");
    }
}

#[test]
fn attn_single_query_runs_and_is_convex() {
    let Some(mut eng) = engine_or_skip() else { return };
    let exe = eng.load("attn_single_query").expect("load");

    let mut rng = Rng::new(101);
    let q: Vec<f32> = rng.normal_vec(64);
    let k: Vec<f32> = rng.normal_vec(1024 * 64);
    let v: Vec<f32> = rng.normal_vec(1024 * 64);
    let out = exe.run_f32(&[&q, &k, &v]).expect("run");
    assert_eq!(out.len(), 64);

    // output is a convex combination of V rows => bounded by V's range
    let vmax = v.iter().cloned().fold(f32::MIN, f32::max);
    let vmin = v.iter().cloned().fold(f32::MAX, f32::min);
    for &o in &out {
        assert!(o <= vmax + 0.05 && o >= vmin - 0.05, "out {o} outside V range");
    }
}

#[test]
fn attn_single_query_matches_functional_model() {
    let Some(mut eng) = engine_or_skip() else { return };
    let exe = eng.load("attn_single_query").expect("load");

    let mut rng = Rng::new(102);
    let q: Vec<f32> = rng.normal_vec(64);
    let k: Vec<f32> = rng.normal_vec(1024 * 64);
    let v: Vec<f32> = rng.normal_vec(1024 * 64);
    let got = exe.run_f32(&[&q, &k, &v]).expect("run");

    let want =
        functional::camformer_attention(&q, &k, &v, &functional::AttnConfig::paper(1024, 64));
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            (*g - *w).abs() < 1e-2,
            "dim {i}: pjrt {g} vs rust {w}"
        );
    }
}

#[test]
fn attn_batch_consistent_with_single() {
    let Some(mut eng) = engine_or_skip() else { return };
    let mut rng = Rng::new(103);
    let k: Vec<f32> = rng.normal_vec(1024 * 64);
    let v: Vec<f32> = rng.normal_vec(1024 * 64);
    let qs: Vec<f32> = rng.normal_vec(16 * 64);

    let batch_out = {
        let exe = eng.load("attn_batch").expect("load");
        exe.run_f32(&[&qs, &k, &v]).expect("run")
    };
    assert_eq!(batch_out.len(), 16 * 64);
    let single = eng.load("attn_single_query").expect("load");
    for b in [0usize, 7, 15] {
        let q = &qs[b * 64..(b + 1) * 64];
        let one = single.run_f32(&[q, &k, &v]).expect("run");
        for (i, (g, w)) in batch_out[b * 64..(b + 1) * 64].iter().zip(&one).enumerate() {
            assert!((g - w).abs() < 1e-4, "batch row {b} dim {i}: {g} vs {w}");
        }
    }
}

#[test]
fn classifier_predicts_planted_pair() {
    let Some(mut eng) = engine_or_skip() else { return };
    let exe = eng.load("classifier_camformer").expect("load");

    // build an associative-retrieval sequence exactly like data.py:
    // pair token = 2 + key*4 + value; probe = 2 + 16*4 + key
    let mut rng = Rng::new(104);
    let mut correct = 0;
    let trials = 20;
    for _ in 0..trials {
        let kstar = rng.index(16) as i32;
        let vstar = rng.index(4) as i32;
        let mut toks = Vec::with_capacity(512);
        for _ in 0..511 {
            let mut key = rng.index(15) as i32;
            if key >= kstar {
                key += 1; // distractors never use k*
            }
            let val = rng.index(4) as i32;
            toks.push(2 + key * 4 + val);
        }
        let pos = rng.index(511);
        toks[pos] = 2 + kstar * 4 + vstar;
        toks.push(2 + 64 + kstar); // probe
        let logits = exe.run_s32(&toks).expect("run");
        assert_eq!(logits.len(), 4);
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as i32;
        if pred == vstar {
            correct += 1;
        }
    }
    // trained to ~100% with exact attention; camformer attention should
    // retain high accuracy (Table III/IV analogue)
    assert!(
        correct >= trials * 7 / 10,
        "camformer classifier only {correct}/{trials} correct"
    );
}

#[test]
fn classifier_exact_beats_chance_strongly() {
    let Some(mut eng) = engine_or_skip() else { return };
    let exe = eng.load("classifier_exact").expect("load");
    let mut rng = Rng::new(105);
    let mut correct = 0;
    let trials = 20;
    for _ in 0..trials {
        let kstar = rng.index(16) as i32;
        let vstar = rng.index(4) as i32;
        let mut toks = Vec::with_capacity(512);
        for _ in 0..511 {
            let mut key = rng.index(15) as i32;
            if key >= kstar {
                key += 1;
            }
            toks.push(2 + key * 4 + rng.index(4) as i32);
        }
        let pos = rng.index(511);
        toks[pos] = 2 + kstar * 4 + vstar;
        toks.push(2 + 64 + kstar);
        let logits = exe.run_s32(&toks).expect("run");
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as i32;
        if pred == vstar {
            correct += 1;
        }
    }
    // the shipped weights are STE-fine-tuned for *binary* attention, so
    // the exact-attention path is the initialisation, not the product —
    // it must still beat chance decisively (25%), not be near-perfect
    assert!(correct >= trials * 6 / 10, "exact classifier {correct}/{trials}");
}

#[test]
fn engine_rejects_bad_shapes() {
    let Some(mut eng) = engine_or_skip() else { return };
    let exe = eng.load("bacam_scores").expect("load");
    let q = vec![0.0f32; 10]; // wrong size
    let k = vec![0.0f32; 1024 * 64];
    assert!(exe.run_f32(&[&q, &k]).is_err());
    assert!(exe.run_f32(&[&k]).is_err());
}
