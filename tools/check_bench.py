#!/usr/bin/env python3
"""Bench JSON regression smoke (ISSUE 7, satellite 5; spill tier +
noise margin in ISSUE 8; chaos-restart recovery keys in ISSUE 9; the
serving traffic / energy co-simulation surface in ISSUE 10).

Two bench surfaces share this gate, distinguished by schema:

* BENCH_hotpath.json  (``cargo bench --bench coordinator_hotpath``) —
  flat ``{scenario: ns}``. Gates:

  1. completeness — every scenario key the bench has historically
     emitted must still be present (a bench refactor that silently
     drops a scenario reads as "no regression" forever after). This
     gate is STRICT: a missing key fails regardless of any margin;
  2. the headline FlashCAM claim — the fused streaming kernel must beat
     the PR-4 sparse_incremental pipeline per decode step at the
     largest context (n = 4096), where the O(n·d) scoring loop
     dominates and the u64 word-parallel pass has the most room.

* BENCH_serving.json  (``cargo bench --bench serving_traffic``) —
  nested ``{scenario: {tokens_per_s, p99_ms, j_per_token, watts}}``.
  Gates:

  1. completeness — every traffic scenario and every metric of the
     co-simulation quartet present;
  2. the energy accounting is live — every J/token finite and nonzero
     (an accountant that silently stops pricing reads as free serving);
  3. the serving-scale energy claim — the fused FlashCAM kernel must
     decode cheaper per token than the dense baseline over the same
     long-context trace.

The cross-recipe comparisons carry a small configurable noise margin
(default 3%): paired numbers come from separate wall-clock loops on a
shared machine, so a hair's-width inversion is scheduler jitter, not a
regression. Override with ``--margin 0.05`` or ``CHECK_BENCH_MARGIN=0.05``
(0 restores the strict comparison).

Stdlib only; exits non-zero with a readable report on any violation.
"""

import json
import math
import os
import sys

HOTPATH_KEYS = [
    # long-context recipe x context-length matrix (ISSUEs 4, 7)
    *[
        f"long_context_{recipe}_n{n}"
        for recipe in (
            "dense_full_repack",
            "dense_incremental",
            "sparse_incremental",
            "fused_incremental",
        )
        for n in (256, 1024, 4096)
    ],
    # standing-scheduler open-loop burst (ISSUE 6)
    "bursty_open_loop_16sess_q8",
    # DRAM spill-tier churn (ISSUE 8): the ns/op headline plus the
    # decision/traffic counters that prove the tier actually cycled
    "spill_churn_8sess_budget64",
    "spill_churn_demotions",
    "spill_churn_promotions",
    "spill_churn_dram_bytes",
    # chaos restart (ISSUE 9): serving priced straight through periodic
    # worker crashes, plus the recovery counters that prove the
    # supervisor restarted, sessions were lost typed, and spilled
    # sessions actually recovered
    "chaos_restart_8sess_crash_every_16",
    "chaos_restart_worker_restarts",
    "chaos_restart_sessions_lost",
    "chaos_restart_sessions_recovered",
]

FUSED = "long_context_fused_incremental_n4096"
SPARSE = "long_context_sparse_incremental_n4096"

# the traffic scenarios serving_traffic.rs emits (ISSUE 10) and the
# co-simulation quartet each must report
SERVING_KEYS = [
    "bert_steady",
    "vit_bursty",
    "zipf_spill",
    "longctx_fused",
    "longctx_dense",
]
SERVING_METRICS = ["tokens_per_s", "p99_ms", "j_per_token", "watts"]

DEFAULT_MARGIN = 0.03


def parse_margin(argv: list) -> float:
    """The noise margin: --margin takes precedence over
    CHECK_BENCH_MARGIN, which takes precedence over the default."""
    margin = float(os.environ.get("CHECK_BENCH_MARGIN", DEFAULT_MARGIN))
    if "--margin" in argv:
        i = argv.index("--margin")
        margin = float(argv[i + 1])
        del argv[i : i + 2]
    if margin < 0:
        raise ValueError(f"margin must be >= 0, got {margin}")
    return margin


def check_hotpath(bench: dict, margin: float, failures: list) -> None:
    """Flat {scenario: ns} schema: completeness + fused-vs-sparse ns."""
    missing = [k for k in HOTPATH_KEYS if k not in bench]
    if missing:
        failures.append(f"missing scenario keys: {', '.join(missing)}")
    for key, ns in bench.items():
        if not isinstance(ns, (int, float)) or ns <= 0:
            failures.append(f"scenario {key!r}: non-positive value {ns!r}")

    if not missing:
        fused, sparse = bench[FUSED], bench[SPARSE]
        if fused >= sparse * (1.0 + margin):
            failures.append(
                f"fused kernel must beat the sparse pipeline at n=4096 "
                f"(margin {margin:.1%}): {FUSED} = {fused:.1f} ns/step >= "
                f"{SPARSE} = {sparse:.1f} ns/step * {1.0 + margin:.3f}"
            )
        else:
            print(
                f"check_bench: fused n=4096 {fused:.1f} ns/step vs sparse "
                f"{sparse:.1f} ns/step ({sparse / fused:.2f}x, margin {margin:.1%})"
            )


def check_serving(bench: dict, margin: float, failures: list) -> None:
    """Nested {scenario: quartet} schema: completeness, live energy
    accounting, fused-vs-dense J/token."""
    missing = [k for k in SERVING_KEYS if k not in bench]
    if missing:
        failures.append(f"missing traffic scenarios: {', '.join(missing)}")
    for scenario, row in bench.items():
        if not isinstance(row, dict):
            failures.append(f"scenario {scenario!r}: expected a metric dict, got {row!r}")
            continue
        for metric in SERVING_METRICS:
            v = row.get(metric)
            if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
                failures.append(
                    f"scenario {scenario!r}: metric {metric!r} must be finite "
                    f"and positive, got {v!r}"
                )

    fused = bench.get("longctx_fused", {})
    dense = bench.get("longctx_dense", {})
    fj, dj = fused.get("j_per_token"), dense.get("j_per_token")
    if isinstance(fj, float) and isinstance(dj, float) and fj > 0 and dj > 0:
        if fj >= dj * (1.0 + margin):
            failures.append(
                f"fused kernel must decode cheaper than the dense baseline "
                f"(margin {margin:.1%}): longctx_fused = {fj:.3e} J/token >= "
                f"longctx_dense = {dj:.3e} J/token * {1.0 + margin:.3f}"
            )
        else:
            print(
                f"check_bench: fused {fj:.3e} J/token vs dense {dj:.3e} J/token "
                f"({dj / fj:.2f}x, margin {margin:.1%})"
            )


def main() -> int:
    argv = sys.argv[1:]
    try:
        margin = parse_margin(argv)
    except (ValueError, IndexError) as e:
        print(f"check_bench: bad --margin / CHECK_BENCH_MARGIN: {e}", file=sys.stderr)
        return 2
    path = argv[0] if argv else "BENCH_hotpath.json"
    try:
        with open(path, encoding="utf-8") as f:
            bench = json.load(f)
    except OSError as e:
        print(f"check_bench: cannot read {path}: {e}", file=sys.stderr)
        return 1
    if not isinstance(bench, dict) or not bench:
        print(f"check_bench: {path} must hold a non-empty JSON object", file=sys.stderr)
        return 1

    failures = []
    # schema sniff: the serving surface nests a metric dict per scenario,
    # the hotpath surface maps straight to numbers
    if all(isinstance(v, dict) for v in bench.values()):
        check_serving(bench, margin, failures)
        count = len(SERVING_KEYS)
    else:
        check_hotpath(bench, margin, failures)
        count = len(HOTPATH_KEYS)

    if failures:
        for f_ in failures:
            print(f"check_bench: FAIL: {f_}", file=sys.stderr)
        return 1
    print(f"check_bench: OK ({count} scenarios present)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
